//! Algorithm 2 live: sweep the per-token deadline D and watch the
//! early-exit controller walk its escalation ladder — full-precision KV
//! shipping at generous deadlines, harder TAB-Q recompression as D
//! shrinks, then I_kv = 0, then token reduction.
//!
//!   make artifacts && cargo run --release --example latency_constrained

use std::rc::Rc;

use splitserve::coordinator::{build_pipeline, DeploymentSpec, Request};
use splitserve::model::ModelConfig;
use splitserve::runtime::Engine;
use splitserve::util::bench::Table;
use splitserve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let n_layers = args.usize_or("layers", 8);
    let split = args.usize_or("split", n_layers / 2);

    let mut cfg = ModelConfig::sim7b();
    cfg.n_layers = n_layers;
    let engine = Rc::new(Engine::load("artifacts", &cfg)?);

    let mut table = Table::new(
        "early exit under shrinking deadlines (Algorithm 2)",
        &["deadline ms", "tokens", "dropped", "final bits", "kv on", "mean step ms", "outages"],
    );
    for deadline_ms in [2000.0, 400.0, 120.0, 60.0, 25.0, 8.0, 0.5f64] {
        let mut spec = DeploymentSpec::defaults(cfg.clone(), split);
        spec.deadline_s = Some(deadline_ms / 1e3);
        let mut pipe = build_pipeline(engine.clone(), &spec)?;
        let res = pipe.generate(&Request::new(1, vec![5, 50, 250, 125], 14))?;
        let fs = res.final_settings.unwrap();
        let outages = res.steps.iter().filter(|s| s.outage).count();
        table.row(&[
            format!("{deadline_ms:.1}"),
            format!("{}", res.tokens.len()),
            format!("{}", res.tokens_dropped),
            format!("{}", fs.qa_bits),
            format!("{}", fs.include_kv),
            format!("{:.1}", res.mean_step_latency_s() * 1e3),
            format!("{outages}"),
        ]);
    }
    table.print();
    println!("\nladder reading: bits shrink first, then kv drops, then tokens are cut.");
    Ok(())
}
