//! Fig. 6 / Fig. 7 scenario as a runnable example: sweep the threshold τ
//! and the TAB-Q bit budget Q̄a over a real hidden-state block captured at
//! the split layer, and print the payload decomposition (CSR outliers vs
//! coded bulk) and compression ratios.
//!
//!   make artifacts && cargo run --release --example compression_sweep

use std::rc::Rc;

use splitserve::coordinator::{CompressedTensor, CompressionConfig};
use splitserve::eval::{ActTreatment, EvalRuntime};
use splitserve::model::{ModelConfig, ModelWeights};
use splitserve::runtime::Engine;
use splitserve::util::bench::Table;
use splitserve::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let n_layers = args.usize_or("layers", 8);
    let layer = args.usize_or("capture-layer", n_layers / 2);

    let mut cfg = ModelConfig::sim7b();
    cfg.n_layers = n_layers;
    let engine = Rc::new(Engine::load("artifacts", &cfg)?);
    let weights = Rc::new(ModelWeights::synthetic(&cfg, 42));
    let model = EvalRuntime::new(engine, weights, ActTreatment::None)?;

    // a real hidden-state block at the split layer
    let tokens: Vec<u32> = (1..=48u32).map(|i| (i * 11) % 511 + 1).collect();
    let h = model.capture_hidden(&tokens, layer)?;
    let rows = tokens.len();
    let cols = cfg.d_model;
    let dense = (rows * cols * 4) as u64;
    println!("hidden block at layer {layer}: {rows} x {cols} ({dense} B dense f32)");

    let mut table = Table::new(
        "two-stage compression sweep (TS + TAB-Q + rANS)",
        &["tau", "Qa", "chosen bits", "outliers", "CSR B", "bulk B", "total B", "ratio", "max bulk err"],
    );
    for tau in [1.0f32, 5.0, 10.0] {
        for q_bar in [2u32, 4, 8] {
            let c = CompressionConfig { tau, q_bar, delta: 0.2, use_rans: true };
            let packet = CompressedTensor::compress(&h, rows, cols, &c);
            let total = packet.wire_bytes();
            table.row(&[
                format!("{tau}"),
                format!("{q_bar}"),
                format!("{}", packet.chosen_bits),
                format!("{}", packet.above.nnz()),
                format!("{}", packet.above.payload_bytes()),
                format!("{}", total - packet.above.payload_bytes()),
                format!("{total}"),
                format!("{:.1}x", dense as f64 / total as f64),
                format!("{:.3}", packet.worst_bulk_error()),
            ]);
        }
    }
    table.print();
    println!("\nhigher tau -> sparser CSR side; lower Qa -> smaller coded bulk (paper Fig. 6/7).");
    Ok(())
}
