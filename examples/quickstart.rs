//! Quickstart: plan a memory-feasible split configuration (paper Eq. 8),
//! build the edge/cloud deployment over the AOT artifacts, and serve one
//! prompt end to end.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::rc::Rc;

use splitserve::coordinator::{build_pipeline, DeploymentSpec, Request};
use splitserve::model::ModelConfig;
use splitserve::planner::{plan, AnalyticAccuracyModel, PlanInputs};
use splitserve::quant::OpscConfig;
use splitserve::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::sim7b();
    println!("model: {} ({} layers, d={})", cfg.name, cfg.n_layers, cfg.d_model);

    // 1. Plan: maximize activation precision Ψ under a 16 MB edge budget
    //    (Eq. 8) at the full token budget W̄ = max_seq.
    let mut inputs = PlanInputs::defaults(cfg.clone(), 16 * 1024 * 1024, cfg.max_seq);
    // demonstrate a true split deployment: keep >= 4 layers on the cloud
    inputs.split_candidates.retain(|&s| s <= cfg.n_layers - 4);
    let choice = plan(&inputs, &AnalyticAccuracyModel)
        .ok_or_else(|| anyhow::anyhow!("no feasible configuration"))?;
    println!(
        "planned: split l={} Qw={}b/{}b Qa={}b/{}b  psi={}  edge mem {:.1} MB  predicted drop {:.2}%",
        choice.opsc.split_layer,
        choice.opsc.qw_front,
        choice.opsc.qw_back,
        choice.qa.front,
        choice.qa.back,
        choice.psi,
        choice.edge_bytes as f64 / (1024.0 * 1024.0),
        choice.predicted_drop,
    );

    // 2. Build the deployment (edge front quantized per the plan, cloud
    //    back full precision, ε-outage link at the Eq. 13 optimal rate).
    let engine = Rc::new(Engine::load("artifacts", &cfg)?);
    let mut spec = DeploymentSpec::defaults(cfg, choice.opsc.split_layer);
    spec.opsc = OpscConfig::new(choice.opsc.split_layer, choice.opsc.qw_front, 16);
    spec.compression.q_bar = choice.qa.front.clamp(2, 8);
    let mut pipeline = build_pipeline(engine, &spec)?;
    println!("link rate: {:.2} Mbps (Eq. 13 optimum)", pipeline.link().rate_bps / 1e6);

    // 3. Serve one request.
    let prompt: Vec<u32> = vec![12, 345, 67, 89, 101, 202];
    let res = pipeline.generate(&Request::new(1, prompt.clone(), 16))?;
    println!("\nprompt:  {prompt:?}");
    println!("tokens:  {:?}", res.tokens);
    println!(
        "latency: prefill {:.1} ms, mean decode step {:.1} ms",
        res.prefill.total_latency_s() * 1e3,
        res.mean_step_latency_s() * 1e3
    );
    println!(
        "wire:    {} B up ({} B/step avg), {} B down; TAB-Q bits used: {:?}",
        res.total_uplink_bytes(),
        res.total_uplink_bytes() / (res.steps.len().max(1) as u64 + 1),
        res.total_downlink_bytes(),
        res.steps.iter().map(|s| s.chosen_bits).collect::<Vec<_>>(),
    );
    Ok(())
}
