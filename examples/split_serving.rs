//! END-TO-END SERVING DRIVER (the EXPERIMENTS.md §E2E run).
//!
//! A real small deployment through the many-to-one serve loop: N edge
//! devices (each with its own OPSC front segment and its own fading link)
//! sharing ONE stateless cloud server, fed a Poisson workload trace
//! through the router with continuous (iteration-level) batching. All
//! compute goes through the engine, every payload is really compressed
//! and "transmitted", tokens stream through a per-token sink.
//!
//! Reports per-request latency, aggregate throughput + p95, wire traffic,
//! and the headline comparison vs a cloud-only deployment from the
//! `sim.rs` analytic fast path (cross-checked against the real loop's
//! measured step times), including the paper's ~1.49x speedup shape at
//! load.
//!
//!   make artifacts && cargo run --release --example split_serving -- \
//!       --devices 3 --requests 9 --layers 8
//!
//! Run with `--topk 40 --temperature 0.8` for seeded sampling instead of
//! greedy decode.

use std::rc::Rc;

use splitserve::coordinator::{
    build_serve_loop, simulate, BatcherParams, Deployment, SamplingSpec, ServeSpec, SimWorkload,
    TokenControl,
};
use splitserve::model::ModelConfig;
use splitserve::runtime::Engine;
use splitserve::trace::{generate_trace, WorkloadSpec};
use splitserve::util::bench::Table;
use splitserve::util::cli::Args;
use splitserve::util::mean;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let n_devices = args.usize_or("devices", 3);
    let n_requests = args.usize_or("requests", 9);
    let n_layers = args.usize_or("layers", 8);
    let split = args.usize_or("split", n_layers / 2);
    let topk = args.usize_or("topk", 0);

    let mut cfg = ModelConfig::sim7b();
    cfg.n_layers = n_layers;
    println!(
        "deployment: {n_devices} edge devices -> ONE shared cloud, split l={split}/{n_layers}, \
         Qw=4b edge front, cloud fp32"
    );
    let engine = Rc::new(Engine::load("artifacts", &cfg)?);

    // One serve loop: N edges, one shared stateless cloud, router
    // admission (Eq. 8c memory budgets), continuous batching.
    let mut spec = ServeSpec::defaults(cfg.clone(), split, n_devices);
    spec.deployment.link_seed = 1000;
    let mut serve = build_serve_loop(engine, &spec)?;

    // Workload.
    let mut trace = generate_trace(&WorkloadSpec {
        n_requests,
        prompt_len_min: 4,
        prompt_len_max: 16,
        output_len_min: 6,
        output_len_max: 14,
        seed: 3,
        ..Default::default()
    });
    if topk > 0 {
        let temperature = args.f64_or("temperature", 0.8) as f32;
        for r in &mut trace {
            r.sampling = SamplingSpec::TopK { k: topk, temperature, seed: 0xDECADE };
        }
    }

    // Run with a streaming sink (count tokens as they are committed).
    let mut streamed = 0u64;
    let t0 = std::time::Instant::now();
    let report = serve.run(trace, |_, _| {
        streamed += 1;
        TokenControl::Continue
    })?;
    let wall = t0.elapsed().as_secs_f64();

    let mut table = Table::new(
        "split serving: per-request results (completion order)",
        &["req", "tokens", "prefill ms", "step ms", "up B", "down B", "bits"],
    );
    let mut step_lat = Vec::new();
    let mut total_up = 0u64;
    let mut total_down = 0u64;
    for res in &report.results {
        step_lat.push(res.mean_step_latency_s());
        total_up += res.total_uplink_bytes();
        total_down += res.total_downlink_bytes();
        table.row(&[
            format!("{}", res.request_id),
            format!("{}", res.tokens.len()),
            format!("{:.1}", res.prefill.total_latency_s() * 1e3),
            format!("{:.1}", res.mean_step_latency_s() * 1e3),
            format!("{}", res.total_uplink_bytes()),
            format!("{}", res.total_downlink_bytes()),
            format!("{}", res.steps.first().map(|s| s.chosen_bits).unwrap_or(0)),
        ]);
    }
    table.print();

    println!("\naggregate ({} requests, {} tokens, {streamed} streamed):", report.results.len(), report.total_tokens);
    println!(
        "  mean request latency  {:.1} ms   p95 {:.1} ms (simulated clock, arrival -> done)",
        report.mean_latency_s() * 1e3,
        report.p95_latency_s() * 1e3
    );
    println!("  mean decode step      {:.2} ms", mean(&step_lat) * 1e3);
    println!(
        "  throughput            {:.1} tok/s over {:.2} s simulated ({} iterations, peak batch {})",
        report.throughput_tok_s(),
        report.clock_s,
        report.iterations,
        report.peak_batch
    );
    println!("  server busy           {:.2} s ({} cloud calls)", report.server_busy_s, serve.cloud.tokens_generated());
    println!("  wire                  {total_up} B up / {total_down} B down total");
    println!("  harness wall-clock    {wall:.1} s");

    // Headline: SC vs cloud-only server load at scale — the sim.rs
    // analytic fast path driven by the step times the REAL loop measured
    // above (the cross-check between the two serving paths).
    let measured_step = mean(&step_lat).max(1e-4);
    let server = BatcherParams {
        base_token_s: measured_step * 0.25, // cloud share of a step
        ..Default::default()
    };
    let wl = SimWorkload { n_devices: 16, arrival_rate: 0.5, ..Default::default() };
    let cloud_only = simulate(&wl, Deployment::CloudOnly, &server, measured_step);
    let sc = simulate(&wl, Deployment::Split { w_bar: 250 }, &server, measured_step);
    println!("\nheadline (16 devices, DES on measured step times):");
    println!(
        "  server busy time:   cloud-only {:.1} s | SC(W=250) {:.1} s | reduction {:.2}x",
        cloud_only.server_busy_s,
        sc.server_busy_s,
        cloud_only.server_busy_s / sc.server_busy_s.max(1e-9)
    );
    println!(
        "  mean req latency:   cloud-only {:.1} s | SC(W=250) {:.1} s | inference speedup {:.2}x",
        cloud_only.mean_request_latency_s(),
        sc.mean_request_latency_s(),
        cloud_only.mean_request_latency_s() / sc.mean_request_latency_s().max(1e-9)
    );
    Ok(())
}
