//! END-TO-END SERVING DRIVER (the EXPERIMENTS.md §E2E run).
//!
//! A real small deployment: N edge devices (each with its own OPSC front
//! segment and its own fading link) + one stateless cloud server, fed a
//! Poisson workload trace through the router. All compute goes through
//! PJRT, every payload is really compressed and "transmitted".
//!
//! Reports per-request latency, throughput, wire traffic, and the headline
//! comparison vs a cloud-only deployment (everything computed centrally),
//! including the paper's ~1.49x speedup shape at load.
//!
//!   make artifacts && cargo run --release --example split_serving -- \
//!       --devices 3 --requests 9 --layers 8

use std::rc::Rc;

use splitserve::coordinator::{
    build_pipeline, simulate, BatcherParams, Deployment, DeploymentSpec, Router, SimWorkload,
};
use splitserve::coordinator::router::DeviceSlot;
use splitserve::memory::ActBits;
use splitserve::model::ModelConfig;
use splitserve::runtime::Engine;
use splitserve::trace::{generate_trace, WorkloadSpec};
use splitserve::util::bench::Table;
use splitserve::util::cli::Args;
use splitserve::util::{mean, percentile};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(false);
    let n_devices = args.usize_or("devices", 3);
    let n_requests = args.usize_or("requests", 9);
    let n_layers = args.usize_or("layers", 8);
    let split = args.usize_or("split", n_layers / 2);

    let mut cfg = ModelConfig::sim7b();
    cfg.n_layers = n_layers;
    println!(
        "deployment: {n_devices} edge devices, split l={split}/{n_layers}, Qw=4b edge front, cloud fp32"
    );
    let engine = Rc::new(Engine::load("artifacts", &cfg)?);

    // One pipeline per edge device (separate link fading, same cloud-side
    // shape; the cloud is stateless so sharing it across devices is sound).
    let mut pipelines = Vec::new();
    for dev in 0..n_devices {
        let mut spec = DeploymentSpec::defaults(cfg.clone(), split);
        spec.link_seed = 1000 + dev as u64;
        pipelines.push(build_pipeline(engine.clone(), &spec)?);
    }

    // Router with per-device memory budgets (Eq. 8c admission).
    let qa = ActBits::uniform(spec_qa());
    let slots: Vec<DeviceSlot> = (0..n_devices)
        .map(|d| DeviceSlot::new(d, &cfg, split, 4, &qa, cfg.max_seq, 64 * 1024 * 1024))
        .collect();
    let mut router = Router::new(slots);

    // Workload.
    let trace = generate_trace(&WorkloadSpec {
        n_requests,
        prompt_len_min: 4,
        prompt_len_max: 16,
        output_len_min: 6,
        output_len_max: 14,
        seed: 3,
        ..Default::default()
    });

    let mut table = Table::new(
        "split serving: per-request results",
        &["req", "dev", "prompt", "tokens", "prefill ms", "step ms", "up B", "down B", "bits"],
    );
    let mut latencies = Vec::new();
    let mut step_lat = Vec::new();
    let mut total_tokens = 0usize;
    let mut total_up = 0u64;
    let mut total_down = 0u64;
    let t0 = std::time::Instant::now();
    for req in &trace {
        let dev = match router.route(req.max_new_tokens as u64) {
            splitserve::coordinator::RouteDecision::ToDevice(d) => d,
            splitserve::coordinator::RouteDecision::CloudFallback => 0,
        };
        let res = pipelines[dev].generate(req)?;
        router.complete(dev, req.max_new_tokens as u64);
        latencies.push(res.total_latency_s());
        step_lat.push(res.mean_step_latency_s());
        total_tokens += res.tokens.len();
        total_up += res.total_uplink_bytes();
        total_down += res.total_downlink_bytes();
        table.row(&[
            format!("{}", req.id),
            format!("{dev}"),
            format!("{}", req.prompt.len()),
            format!("{}", res.tokens.len()),
            format!("{:.1}", res.prefill.total_latency_s() * 1e3),
            format!("{:.1}", res.mean_step_latency_s() * 1e3),
            format!("{}", res.total_uplink_bytes()),
            format!("{}", res.total_downlink_bytes()),
            format!("{}", res.steps.first().map(|s| s.chosen_bits).unwrap_or(0)),
        ]);
    }
    let wall = t0.elapsed().as_secs_f64();
    table.print();

    let sim_time: f64 = latencies.iter().sum();
    println!("\naggregate ({n_requests} requests, {total_tokens} tokens):");
    println!("  mean request latency  {:.1} ms   p95 {:.1} ms", mean(&latencies) * 1e3,
        percentile(&latencies, 95.0) * 1e3);
    println!("  mean decode step      {:.2} ms", mean(&step_lat) * 1e3);
    println!("  throughput            {:.1} tok/s (simulated clock)", total_tokens as f64 / sim_time);
    println!("  wire                  {} B up / {} B down total", total_up, total_down);
    println!("  cloud served          {} calls", pipelines.iter().map(|p| p.cloud.tokens_generated).sum::<u64>());
    println!("  harness wall-clock    {wall:.1} s");

    // Headline: SC vs cloud-only server load at scale (Fig. 5 scenario,
    // DES driven by the measured step times above).
    let measured_step = mean(&step_lat).max(1e-4);
    let server = BatcherParams {
        base_token_s: measured_step * 0.25, // cloud share of a step
        ..Default::default()
    };
    let wl = SimWorkload { n_devices: 16, arrival_rate: 0.5, ..Default::default() };
    let cloud_only = simulate(&wl, Deployment::CloudOnly, &server, measured_step);
    let sc = simulate(&wl, Deployment::Split { w_bar: 250 }, &server, measured_step);
    println!("\nheadline (16 devices, DES on measured step times):");
    println!(
        "  server busy time:   cloud-only {:.1} s | SC(W=250) {:.1} s | reduction {:.2}x",
        cloud_only.server_busy_s,
        sc.server_busy_s,
        cloud_only.server_busy_s / sc.server_busy_s.max(1e-9)
    );
    println!(
        "  mean req latency:   cloud-only {:.1} s | SC(W=250) {:.1} s | inference speedup {:.2}x",
        cloud_only.mean_request_latency_s(),
        sc.mean_request_latency_s(),
        cloud_only.mean_request_latency_s() / sc.mean_request_latency_s().max(1e-9)
    );
    Ok(())
}

fn spec_qa() -> u32 {
    8
}
