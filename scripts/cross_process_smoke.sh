#!/usr/bin/env bash
# Cross-process loopback smoke: spawn `splitserve cloud`, run
# `splitserve edge` against it over a unix socket, and require the token
# stream to equal single-process `splitserve generate` on the same spec.
#
#   scripts/cross_process_smoke.sh            # builds release, runs smoke
#
# The same check runs inside `cargo test` (tests/cross_process.rs); this
# script is the standalone/CI form against the release binary.
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release
BIN=target/release/splitserve

SOCK="${TMPDIR:-/tmp}/splitserve-smoke-$$.sock"
MODEL_ARGS=(--layers 4 --split 2)
GEN_ARGS=(--prompt 3,141,59,26 --max-new 8)

"$BIN" cloud --listen "unix:$SOCK" "${MODEL_ARGS[@]}" --once &
CLOUD_PID=$!
trap 'kill "$CLOUD_PID" 2>/dev/null || true; rm -f "$SOCK"' EXIT

EDGE_OUT=$("$BIN" edge --connect "unix:$SOCK" "${MODEL_ARGS[@]}" "${GEN_ARGS[@]}")
SINGLE_OUT=$("$BIN" generate "${MODEL_ARGS[@]}" "${GEN_ARGS[@]}")

EDGE_TOKENS=$(grep '^tokens:' <<<"$EDGE_OUT" || true)
SINGLE_TOKENS=$(grep '^tokens:' <<<"$SINGLE_OUT" || true)
echo "edge (cross-process): $EDGE_TOKENS"
echo "generate (in-process): $SINGLE_TOKENS"

if [ -z "$EDGE_TOKENS" ] || [ "$EDGE_TOKENS" != "$SINGLE_TOKENS" ]; then
    echo "FAIL: cross-process token stream diverged from single-process generate"
    exit 1
fi
echo "cross-process smoke OK"
