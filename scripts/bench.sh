#!/usr/bin/env bash
# Run the hot-path + engine microbenchmarks and emit the machine-readable
# reports.
#
#   scripts/bench.sh            # release build, writes BENCH_hot_paths.json
#                               # and BENCH_engine.json
#   BENCH_JSON=out.json scripts/bench.sh
#   BENCH_SMOKE=1 scripts/bench.sh   # reduced CI configuration
#
# The JSON (name -> {median_ns, mean_ns, min_ns, p95_ns, iters}, plus a
# "metrics" object of tokens/s + speedup scalars for the engine bench) is
# the perf trajectory record referenced by EXPERIMENTS.md §Perf/§Engine;
# commit the numbers there (not the JSON) when they move. The engine
# bench also ASSERTS the zero-copy decode invariant — a panic fails this
# script.
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_JSON="${BENCH_JSON:-BENCH_hot_paths.json}"
cargo bench --bench hot_paths "$@"

ENGINE_JSON="${BENCH_ENGINE_JSON:-BENCH_engine.json}"
BENCH_JSON="$ENGINE_JSON" cargo bench --bench engine "$@"

WIRE_JSON="${BENCH_WIRE_JSON:-BENCH_wire.json}"
BENCH_JSON="$WIRE_JSON" cargo bench --bench wire "$@"

# Static vs adaptive serving across channel scenarios. The binary ASSERTS
# the adaptation invariants (constant channel ⇒ bit-identical to static;
# step change ⇒ the controller actually switches plans) — a panic fails
# this script.
ADAPT_JSON="${BENCH_ADAPT_JSON:-BENCH_adapt.json}"
BENCH_JSON="$ADAPT_JSON" cargo bench --bench adapt "$@"

# Fault-recovery costs: disconnect/restart recovery latency and serve-loop
# goodput retention under seeded fault storms. The binary ASSERTS the
# accounting invariants (every request ends completed or typed-failed) —
# a panic fails this script.
CHAOS_JSON="${BENCH_CHAOS_JSON:-BENCH_chaos.json}"
BENCH_JSON="$CHAOS_JSON" cargo bench --bench chaos "$@"

# Fleet-scale serving: 1k heterogeneous simulated devices against ONE
# cloud process (64 under BENCH_SMOKE; FLEET_DEVICES=N overrides, up to
# 10k). The binary ASSERTS the bit-identity invariant — every session's
# fleet-scheduled stream equals its solo run — a panic fails this script.
FLEET_JSON="${BENCH_FLEET_JSON:-BENCH_fleet.json}"
BENCH_JSON="$FLEET_JSON" cargo bench --bench fleet "$@"

# Sharded cloud pool: migration pause (p50/p95 stall tokens), failover
# time-to-first-recovered-token, and throughput retention under a rolling
# worker-restart storm. The binary ASSERTS bit-identity and zero-leak
# hygiene in every phase — a panic fails this script.
POOL_JSON="${BENCH_POOL_JSON:-BENCH_pool.json}"
BENCH_JSON="$POOL_JSON" cargo bench --bench pool "$@"

# Content-addressed prefix KV cache: cold vs warm TTFT (p50/p95), prefill
# wire bytes vs prefix share, and the edge hit rate under a diurnal
# trace. The binary ASSERTS bit-identity (every warm stream equals its
# caching-off oracle), the ≥50%-share wire-byte win, and zero leaked
# refcounts — a panic fails this script.
PREFIX_JSON="${BENCH_PREFIX_JSON:-BENCH_prefix.json}"
BENCH_JSON="$PREFIX_JSON" cargo bench --bench prefix "$@"

# Long-horizon soak: simulated hours of diurnal churn + restarts + chaos
# over an asymmetric multi-region pool. The binary ASSERTS that BOTH the
# leak audit and the drift audit come back clean, and that the
# multi-region p95 spread is visible — a panic fails this script.
SOAK_JSON="${BENCH_SOAK_JSON:-BENCH_soak.json}"
BENCH_JSON="$SOAK_JSON" cargo bench --bench soak "$@"

for f in "$BENCH_JSON" "$ENGINE_JSON" "$WIRE_JSON" "$ADAPT_JSON" "$CHAOS_JSON" "$FLEET_JSON" "$POOL_JSON" "$PREFIX_JSON" "$SOAK_JSON"; do
    if [ -f "$f" ]; then
        echo "--- $f ---"
        cat "$f"
    fi
done

# Roll every per-bench report into one BENCH_summary.json for the
# trajectory record (and for tooling that wants a single artifact).
cargo run --release --quiet -- bench-summary
echo "--- BENCH_summary.json ---"
cat BENCH_summary.json
