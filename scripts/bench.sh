#!/usr/bin/env bash
# Run the hot-path microbenchmarks and emit the machine-readable report.
#
#   scripts/bench.sh            # release build, writes BENCH_hot_paths.json
#   BENCH_JSON=out.json scripts/bench.sh
#
# The JSON (name -> {median_ns, mean_ns, min_ns, p95_ns, iters}) is the
# perf trajectory record referenced by EXPERIMENTS.md §Perf; commit the
# numbers there (not the JSON) when they move.
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_JSON="${BENCH_JSON:-BENCH_hot_paths.json}"
cargo bench --bench hot_paths "$@"

if [ -f "$BENCH_JSON" ]; then
    echo "--- $BENCH_JSON ---"
    cat "$BENCH_JSON"
fi
