#!/usr/bin/env bash
# Full chaos sweep: the seeded fault-injection property suite in release
# mode, at full seed count, plus the chaos bench.
#
#   scripts/chaos.sh              # 240-seed sweep + every pinned trace
#   CHAOS_SEEDS=64 scripts/chaos.sh
#   scripts/chaos.sh --nocapture  # extra args go to the test binary
#
# CI runs the reduced configuration (CHAOS_SEEDS=quick) as part of the
# normal test job; this script is the long-form evidence run behind
# EXPERIMENTS.md §Chaos. The invariant everywhere: a faulted run either
# completes with EXACTLY the fault-free token stream or fails with a
# typed error — never silent wrong tokens.
set -euo pipefail
cd "$(dirname "$0")/.."

# `scripts/chaos.sh --pool` additionally runs the cloud-pool robustness
# suite (worker kill storms, live migration at every decode step, drain/
# rebalance, bit-flips mid-frame into the worker-to-worker Migrate
# handoff, placement under corrupted headroom telemetry) plus the
# prefix-cache property suite and the pool bench in release mode.
#
# `scripts/chaos.sh --soak` runs the long-horizon soak on top: the
# virtual-time diurnal scenario with rolling restarts, drains, and armed
# chaos faults, gated on the leak + drift audits (tests + bench).
POOL=0
SOAK=0
while [ "${1:-}" = "--pool" ] || [ "${1:-}" = "--soak" ]; do
    case "$1" in
        --pool) POOL=1 ;;
        --soak) SOAK=1 ;;
    esac
    shift
done

export CHAOS_SEEDS="${CHAOS_SEEDS:-240}"
echo "chaos sweep: CHAOS_SEEDS=$CHAOS_SEEDS"
cargo test --release --test chaos -- "$@"

if [ "$POOL" = 1 ]; then
    echo "pool chaos: kill storms, migration sweep, drain/rebalance, frame faults"
    cargo test --release --test pool -- "$@"
    echo "prefix properties: warm==cold bit-identity, typed misses, refcount hygiene"
    cargo test --release --test prefix -- "$@"
    POOL_JSON="${BENCH_POOL_JSON:-BENCH_pool.json}"
    BENCH_JSON="$POOL_JSON" cargo bench --bench pool
    if [ -f "$POOL_JSON" ]; then
        echo "--- $POOL_JSON ---"
        cat "$POOL_JSON"
    fi
fi

if [ "$SOAK" = 1 ]; then
    echo "soak: long-horizon diurnal churn + restarts + chaos, audit-gated"
    cargo test --release --test soak -- "$@"
    SOAK_JSON="${BENCH_SOAK_JSON:-BENCH_soak.json}"
    BENCH_JSON="$SOAK_JSON" cargo bench --bench soak
    if [ -f "$SOAK_JSON" ]; then
        echo "--- $SOAK_JSON ---"
        cat "$SOAK_JSON"
    fi
fi

CHAOS_JSON="${BENCH_CHAOS_JSON:-BENCH_chaos.json}"
BENCH_JSON="$CHAOS_JSON" cargo bench --bench chaos
if [ -f "$CHAOS_JSON" ]; then
    echo "--- $CHAOS_JSON ---"
    cat "$CHAOS_JSON"
fi
