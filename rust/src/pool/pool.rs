//! The cloud pool runtime: edge frame routing over many fleet workers,
//! with failover, drain and live migration.
//!
//! A [`CloudPool`] owns N worker slots. Each slot is a full
//! [`FleetScheduler`] over its own [`CloudServer`], built by a stored
//! factory closure — so a crashed worker can be respawned with the exact
//! same weights and sampling keys, which is what makes failover
//! bit-identical rather than merely "close".
//!
//! Edges connect to the POOL (any [`WireTransport`]); per (edge, worker)
//! pair the pool lazily opens an internal loopback route whose worker
//! half is a polled fleet connection. The pool's event loop
//! ([`CloudPool::poll`]) then:
//!
//! 1. **pumps edges** — classifies each arriving frame from its header
//!    (payload prefix peek / control kind), places unknown sessions via
//!    [`placement::pick`] (per-worker Eq. 8c headroom, seeded
//!    deterministic tie-break), and forwards it down the owning worker's
//!    route, remembering the last unanswered payload per session;
//! 2. **steps workers** — intake + one DRR serve round each; a serve
//!    error or an armed seeded [`FaultPlan`] kill is a worker crash:
//!    the slot (scheduler, admission charges, fences, control entries,
//!    routes) is dropped WHOLESALE and respawned, and every victim
//!    session is re-placed and its unanswered payload re-delivered —
//!    at most one position is ever re-served, and re-serving is
//!    bit-identical because cloud sampling is (seed, request, pos)-keyed;
//! 3. **pumps workers** — forwards replies back to the owning edge,
//!    retiring pool placement and inflight state at EOS.
//!
//! Drain and rebalance ride the same machinery as failover but move
//! LIVE state: the source worker is quiesced, the session's cloud-side
//! residue is exported, shipped through the real kind-7 Migrate codec,
//! and imported on the target through the PR 6 `Resume` epoch fence —
//! duplicate or stale deliveries get a typed STALE_EPOCH, never a second
//! live copy. Rebalance is the placement-level analogue of the adaptive
//! controller's re-planning (re-plan can now also mean "move"); the
//! controller side holds its end of the bargain by deferring — typed,
//! never aborting — any per-session reconfig while a Resume handshake
//! is in flight (`adapt::ReconcileDecision::Defer`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::protocol::{reject, RejectFrame};
use crate::coordinator::CloudServer;
use crate::fleet::{FleetConfig, FleetScheduler};
use crate::obs::{self, EventKind, MetricSource, RegionProfile, Registry};
use crate::prefix::PrefixDigest;
use crate::wire::{
    self, FaultPlan, FrameKind, Loopback, PollRecv, Transport, WireError, WireTransport,
};

use super::placement::{self, Candidate, PlacementDecision};

/// Knobs of the pool.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Worker slots to spawn.
    pub workers: usize,
    /// Per-worker fleet scheduler config (`kv_budget_bytes` here is the
    /// PER-WORKER Eq. 8c budget the placement layer packs against).
    pub fleet: FleetConfig,
    /// Seed of the placement tie-break hash — the whole fleet layout
    /// replays identically under one seed.
    pub seed: u64,
    /// Run `maybe_rebalance` inside `poll` (the pool's own control loop).
    pub auto_rebalance: bool,
    /// Rebalance only when max and min worker occupancy differ by at
    /// least this many sessions (hysteresis).
    pub rebalance_gap: usize,
    /// Minimum polls between rebalance migrations (cooldown).
    pub rebalance_cooldown: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            workers: 2,
            fleet: FleetConfig::default(),
            seed: 0x5EED,
            auto_rebalance: false,
            rebalance_gap: 4,
            rebalance_cooldown: 32,
        }
    }
}

/// Counters of everything the pool did (tests and `benches/pool.rs`
/// assert on these).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Placement decisions taken (every new session, plus re-placements).
    pub placed: u64,
    /// Sessions refused because no worker had KV headroom.
    pub placement_rejected: u64,
    /// Worker crashes detected (armed fault, serve error, or `kill_worker`).
    pub kills: u64,
    /// Fresh workers spawned to replace crashed ones.
    pub respawns: u64,
    /// Victim sessions successfully re-placed after a worker loss.
    pub failovers: u64,
    /// Unanswered payloads re-delivered during failover — by construction
    /// at most one per victim per crash (the ≤1 re-served position bound).
    pub failover_redelivered: u64,
    /// Victim sessions that found no capacity (typed ADMISSION to edge).
    pub failover_rejected: u64,
    /// Live migrations completed (drain + rebalance + explicit).
    pub migrations: u64,
    /// Migrations refused by the target (typed, session rolled back).
    pub migration_rejected: u64,
    /// Placements steered onto a worker already holding the session's
    /// prefix digest (cross-worker prefix-cache affinity).
    pub prefix_placements: u64,
    /// Armed mid-handoff migrate-frame corruptions injected (chaos).
    pub migrate_frame_faults: u64,
    /// Drain operations started.
    pub drains: u64,
    /// Rebalance migrations triggered.
    pub rebalances: u64,
    /// Reply frames forwarded to edges.
    pub replies_forwarded: u64,
    /// Edge connections closed.
    pub edges_closed: u64,
}

/// Where a session lives: its worker and the edge connection that owns
/// its reply path.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub worker: usize,
    pub edge: u64,
}

struct WorkerSlot {
    scheduler: FleetScheduler,
    /// Pool-side halves of this worker's per-edge loopback routes,
    /// keyed by edge connection id (also the worker-side conn id).
    routes: BTreeMap<u64, WireTransport>,
    /// Draining workers accept no new placements.
    draining: bool,
    /// Armed seeded kill: the worker "crashes" once its served-payload
    /// count reaches `plan.disconnect_after` (mid-prefill at 0,
    /// mid-decode at k) — the pool-level use of the wire fault plans.
    fault: Option<FaultPlan>,
    /// Payloads this incarnation has served (the fault clock).
    ops: u64,
    /// Chaos: corrupted capacity telemetry. When set, the placement
    /// layer sees THIS headroom capacity (in sessions) instead of the
    /// real Eq. 8c figure. The worker's own admission gate is the
    /// backstop — a lie can cost typed ADMISSION rejects, never a
    /// silent over-commit.
    telemetry_override: Option<u64>,
    /// Where this worker lives. Placement scoring multiplies headroom
    /// by the region's weight, so a far/thin region needs proportionally
    /// more free capacity to win a session. Survives respawn (the
    /// replacement rack is in the same region).
    region: RegionProfile,
}

pub struct CloudPool {
    factory: Box<dyn Fn() -> Result<CloudServer>>,
    cfg: PoolConfig,
    workers: Vec<WorkerSlot>,
    /// Edge-facing transports, keyed by edge connection id.
    edges: BTreeMap<u64, WireTransport>,
    /// Session → (worker, owning edge). BTreeMaps keep every sweep and
    /// failover in sorted order — the layout is a pure function of the
    /// seed and the frame arrival order, never of hash iteration.
    placements: BTreeMap<u64, Placement>,
    /// Last unanswered payload frame per session: the ≤1-position
    /// failover replay buffer. Cleared when the reply is forwarded.
    inflight: BTreeMap<u64, Vec<u8>>,
    decisions: Vec<PlacementDecision>,
    next_edge: u64,
    polls: u64,
    last_rebalance: u64,
    /// Armed chaos: XOR one bit into the NEXT worker-to-worker migrate
    /// frame mid-handoff (one-shot; the bit index wraps over the frame).
    migrate_fault: Option<usize>,
    /// Metrics registry + structured event ring. Every pool owns one;
    /// `attach_obs` swaps in a shared registry (the soak driver and the
    /// `--metrics` CLI flag do this) so one snapshot covers the run.
    obs: Arc<Registry>,
    pub stats: PoolStats,
}

impl MetricSource for PoolStats {
    fn metrics(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("pool_placed", self.placed),
            ("pool_placement_rejected", self.placement_rejected),
            ("pool_kills", self.kills),
            ("pool_respawns", self.respawns),
            ("pool_failovers", self.failovers),
            ("pool_failover_redelivered", self.failover_redelivered),
            ("pool_failover_rejected", self.failover_rejected),
            ("pool_migrations", self.migrations),
            ("pool_migration_rejected", self.migration_rejected),
            ("pool_prefix_placements", self.prefix_placements),
            ("pool_migrate_frame_faults", self.migrate_frame_faults),
            ("pool_drains", self.drains),
            ("pool_rebalances", self.rebalances),
            ("pool_replies_forwarded", self.replies_forwarded),
            ("pool_edges_closed", self.edges_closed),
        ]
    }
}

impl CloudPool {
    /// Build a pool of `cfg.workers` workers, each from a fresh call to
    /// `factory` (same spec → same weights and sampling keys, the
    /// precondition for bit-identical failover and migration).
    pub fn new<F>(factory: F, cfg: PoolConfig) -> Result<CloudPool>
    where
        F: Fn() -> Result<CloudServer> + 'static,
    {
        anyhow::ensure!(cfg.workers >= 1, "a pool needs at least one worker");
        let factory: Box<dyn Fn() -> Result<CloudServer>> = Box::new(factory);
        let mut workers = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            workers.push(Self::spawn_worker(factory.as_ref(), cfg.fleet)?);
        }
        Ok(CloudPool {
            factory,
            cfg,
            workers,
            edges: BTreeMap::new(),
            placements: BTreeMap::new(),
            inflight: BTreeMap::new(),
            decisions: Vec::new(),
            next_edge: 0,
            polls: 0,
            last_rebalance: 0,
            migrate_fault: None,
            obs: Arc::new(Registry::new()),
            stats: PoolStats::default(),
        })
    }

    fn spawn_worker(
        factory: &dyn Fn() -> Result<CloudServer>,
        fleet: FleetConfig,
    ) -> Result<WorkerSlot> {
        Ok(WorkerSlot {
            scheduler: FleetScheduler::new(factory()?, fleet),
            routes: BTreeMap::new(),
            draining: false,
            fault: None,
            ops: 0,
            telemetry_override: None,
            region: RegionProfile::local(),
        })
    }

    /// Assign a worker to a region. Placement scoring weighs the
    /// region's RTT/goodput profile from the next poll on; the region
    /// sticks to the SLOT, so a respawned worker inherits it.
    pub fn set_worker_region(&mut self, idx: usize, region: RegionProfile) {
        self.workers[idx].region = region;
    }

    pub fn worker_region(&self, idx: usize) -> &RegionProfile {
        &self.workers[idx].region
    }

    /// The pool's metrics registry + event ring.
    pub fn obs(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// Swap in a shared registry (the `--metrics` flag and the soak
    /// driver do this so one snapshot covers the whole run).
    pub fn attach_obs(&mut self, obs: Arc<Registry>) {
        self.obs = obs;
    }

    /// Register an edge-facing connection. The pool owns the transport;
    /// sessions arriving on it are placed on first contact.
    pub fn add_edge(&mut self, transport: WireTransport) -> u64 {
        let id = self.next_edge;
        self.next_edge += 1;
        self.edges.insert(id, transport);
        id
    }

    /// Arm a seeded kill on a worker: it crashes when its served-payload
    /// count reaches the plan's `disconnect_after` (0 = before serving
    /// anything — mid-prefill; k = after its k-th payload — mid-decode).
    pub fn arm_worker_fault(&mut self, idx: usize, plan: FaultPlan) {
        self.workers[idx].fault = Some(plan);
    }

    /// Arm a one-shot mid-handoff fault: the next worker-to-worker
    /// Migrate frame gets one bit flipped in flight. The handoff must
    /// fail TYPED and roll the session back onto its source — never a
    /// half-imported session or a leaked charge.
    pub fn arm_migrate_fault(&mut self, bit: usize) {
        self.migrate_fault = Some(bit);
    }

    /// Chaos: corrupt one worker's capacity telemetry. The placement
    /// layer will believe the worker holds `lie` sessions of capacity
    /// regardless of its real Eq. 8c budget; the worker's own admission
    /// gate remains the backstop. Cleared on respawn (a fresh worker
    /// reports honestly) or via [`CloudPool::clear_headroom_telemetry`].
    pub fn corrupt_headroom_telemetry(&mut self, idx: usize, lie: u64) {
        self.workers[idx].telemetry_override = Some(lie);
    }

    pub fn clear_headroom_telemetry(&mut self, idx: usize) {
        self.workers[idx].telemetry_override = None;
    }

    // ---- observability ---------------------------------------------------

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Direct read access to one worker's scheduler (stats, hygiene
    /// counters; tests assert zero leaks through this).
    pub fn worker(&self, idx: usize) -> &FleetScheduler {
        &self.workers[idx].scheduler
    }

    pub fn is_draining(&self, idx: usize) -> bool {
        self.workers[idx].draining
    }

    /// Every placement decision taken so far, in order.
    pub fn decisions(&self) -> &[PlacementDecision] {
        &self.decisions
    }

    pub fn placement_of(&self, request_id: u64) -> Option<Placement> {
        self.placements.get(&request_id).copied()
    }

    /// Sessions currently placed (pool-side view).
    pub fn placed_sessions(&self) -> usize {
        self.placements.len()
    }

    /// Unanswered payload frames held for failover replay.
    pub fn inflight_frames(&self) -> usize {
        self.inflight.len()
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Aggregate admission charges across all workers.
    pub fn live_sessions(&self) -> usize {
        self.workers.iter().map(|w| w.scheduler.live_sessions()).sum()
    }

    /// Aggregate replay-fence entries across all workers.
    pub fn fence_entries(&self) -> usize {
        self.workers.iter().map(|w| w.scheduler.fence_entries()).sum()
    }

    /// Aggregate cloud control-plane entries across all workers.
    pub fn control_entries(&self) -> usize {
        self.workers.iter().map(|w| w.scheduler.cloud().control_entries()).sum()
    }

    /// Aggregate resume-epoch fence entries across all workers.
    pub fn resume_entries(&self) -> usize {
        self.workers.iter().map(|w| w.scheduler.cloud().resume_entries()).sum()
    }

    /// Aggregate prefix-store charged bytes across all workers (Eq. 8c
    /// ledger side; the leak audits assert this returns to baseline).
    pub fn prefix_charged_bytes(&self) -> u64 {
        self.workers.iter().map(|w| w.scheduler.cloud().prefix_charged_bytes()).sum()
    }

    /// Aggregate live prefix-store attachments (pinned refcounts) across
    /// all workers.
    pub fn prefix_attachments(&self) -> usize {
        self.workers.iter().map(|w| w.scheduler.cloud().prefix_live_attachments()).sum()
    }

    /// Aggregate prefix-store byte budgets across all workers (the leak
    /// audit allows charged bytes up to this — resident rows are cache).
    pub fn prefix_budget_bytes(&self) -> u64 {
        self.workers.iter().map(|w| w.scheduler.cloud().prefix_budget_bytes()).sum()
    }

    /// Publish every pool/fleet/cloud/prefix counter and gauge onto the
    /// registry. Runs at the end of each `poll`; also callable directly
    /// before a snapshot. Counters are mirrored with `set` (publication,
    /// not accumulation), so re-publishing is idempotent.
    pub fn publish_metrics(&self) {
        // Prefix attach/release transitions, observed as ledger deltas
        // (the stores themselves have no event channel).
        let prev = self.obs.gauge("pool_prefix_attachments").get();
        let now = self.prefix_attachments() as i64;
        if now > prev {
            self.obs.event(EventKind::PrefixAttach, 0, (now - prev) as u64, 0);
        } else if now < prev {
            self.obs.event(EventKind::PrefixRelease, 0, (prev - now) as u64, 0);
        }
        self.obs.publish(&self.stats);
        self.obs.gauge("pool_live_sessions").set(self.live_sessions() as i64);
        self.obs.gauge("pool_fence_entries").set(self.fence_entries() as i64);
        self.obs.gauge("pool_control_entries").set(self.control_entries() as i64);
        self.obs.gauge("pool_resume_entries").set(self.resume_entries() as i64);
        self.obs.gauge("pool_placed_sessions").set(self.placed_sessions() as i64);
        self.obs.gauge("pool_inflight_frames").set(self.inflight_frames() as i64);
        self.obs.gauge("pool_edge_count").set(self.edge_count() as i64);
        self.obs.gauge("pool_workers").set(self.workers.len() as i64);
        self.obs.gauge("pool_prefix_charged_bytes").set(self.prefix_charged_bytes() as i64);
        self.obs.gauge("pool_prefix_attachments").set(now);
        // Fleet + cloud + prefix-store totals, aggregated across workers.
        let mut totals: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut peak_batch = 0u64;
        let mut pending = 0u64;
        for slot in &self.workers {
            let s = &slot.scheduler;
            obs::accumulate(&mut totals, &s.stats);
            obs::accumulate(&mut totals, &s.cloud().prefix_stats());
            totals
                .entry("cloud_tokens_generated")
                .and_modify(|v| *v += s.cloud().tokens_generated())
                .or_insert(s.cloud().tokens_generated());
            totals
                .entry("cloud_tokens_stacked")
                .and_modify(|v| *v += s.cloud().tokens_stacked())
                .or_insert(s.cloud().tokens_stacked());
            totals
                .entry("cloud_reconfigs_applied")
                .and_modify(|v| *v += s.cloud().reconfigs_applied())
                .or_insert(s.cloud().reconfigs_applied());
            peak_batch = peak_batch.max(s.stats.peak_batch as u64);
            pending += s.pending_frames() as u64;
        }
        self.obs.publish_totals(&totals);
        self.obs.gauge("fleet_peak_batch").set(peak_batch as i64);
        self.obs.gauge("fleet_pending_frames").set(pending as i64);
    }

    // ---- event loop ------------------------------------------------------

    /// One pool step: pump edge frames in, step every worker (intake +
    /// one DRR round + health check), pump replies out, and — when
    /// enabled — let the rebalancer move one session. Returns payloads
    /// served this step.
    pub fn poll(&mut self) -> Result<usize> {
        self.polls += 1;
        self.pump_edges()?;
        let served = self.step_workers()?;
        self.pump_workers();
        if self.cfg.auto_rebalance {
            self.maybe_rebalance()?;
        }
        self.publish_metrics();
        Ok(served)
    }

    fn pump_edges(&mut self) -> Result<()> {
        let ids: Vec<u64> = self.edges.keys().copied().collect();
        for id in ids {
            let mut arrived: Vec<Vec<u8>> = Vec::new();
            let mut closed = false;
            {
                let Some(t) = self.edges.get_mut(&id) else { continue };
                loop {
                    match t.poll_recv() {
                        Ok(PollRecv::Frame(f, _)) => arrived.push(f),
                        Ok(PollRecv::Empty) => break,
                        Ok(PollRecv::Closed) | Err(_) => {
                            closed = true;
                            break;
                        }
                    }
                }
            }
            for f in arrived {
                if self.route_edge_frame(id, f).is_err() {
                    closed = true;
                    break;
                }
            }
            if closed {
                self.close_edge(id);
            }
        }
        Ok(())
    }

    /// Classify one edge frame from its header and route it to the
    /// owning (or newly chosen) worker. `Err` is edge-connection-fatal
    /// (wire damage, or a frame kind an edge must never send).
    fn route_edge_frame(&mut self, edge_id: u64, frame: Vec<u8>) -> Result<()> {
        match wire::peek_payload_prefix(&frame) {
            Ok(pfx) => {
                let rid = pfx.request_id;
                let w = match self.placements.get(&rid) {
                    Some(p) => p.worker,
                    // Prefix-bearing prefills prefer a worker already
                    // holding the digest (warm hit; insert dedups into
                    // an attach instead of a second copy of the rows).
                    None => match self.place_preferring(
                        rid,
                        edge_id,
                        pfx.prefix.as_ref().map(|(d, _)| d),
                    ) {
                        Some(w) => w,
                        None => {
                            self.stats.placement_rejected += 1;
                            self.obs.event(EventKind::AdmissionReject, rid, 0, 0);
                            self.reject_to_edge(edge_id, rid, "no worker has KV headroom");
                            return Ok(());
                        }
                    },
                };
                // The failover replay buffer: if the worker dies before
                // this frame's reply escapes, re-delivering it re-serves
                // AT MOST one position — bit-identically, since cloud
                // sampling is (seed, request, pos)-keyed.
                self.inflight.insert(rid, frame.clone());
                self.deliver(w, edge_id, frame)
            }
            Err(WireError::WrongKind { got: FrameKind::Reconfig, .. }) => {
                let rc = wire::decode_reconfig_frame(&frame)?;
                self.obs.event(EventKind::Reconfig, rc.request_id, 0, 0);
                self.route_control(edge_id, rc.request_id, frame)
            }
            Err(WireError::WrongKind { got: FrameKind::Resume, .. }) => {
                let rs = wire::decode_resume_frame(&frame)?;
                self.obs.event(EventKind::Resume, rs.request_id, 0, 0);
                self.route_control(edge_id, rs.request_id, frame)
            }
            Err(WireError::WrongKind { got: FrameKind::PrefixProbe, .. }) => {
                // The probe is the session's FIRST contact: place it
                // now, steering toward a worker where the digest is
                // already resident — that worker's ack turns the prefill
                // into a 32-byte token instead of a full re-upload.
                let probe = wire::decode_prefix_probe_frame(&frame)?;
                let rid = probe.request_id;
                let w = match self.placements.get(&rid) {
                    Some(p) => p.worker,
                    None => match self.place_preferring(rid, edge_id, Some(&probe.digest)) {
                        Some(w) => w,
                        None => {
                            self.stats.placement_rejected += 1;
                            self.obs.event(EventKind::AdmissionReject, rid, 0, 0);
                            self.reject_to_edge(edge_id, rid, "no worker has KV headroom");
                            return Ok(());
                        }
                    },
                };
                self.deliver(w, edge_id, frame)
            }
            Err(e) => Err(e.into()),
        }
    }

    fn route_control(&mut self, edge_id: u64, rid: u64, frame: Vec<u8>) -> Result<()> {
        let w = match self.placements.get(&rid) {
            Some(p) => p.worker,
            None => match self.place(rid, edge_id) {
                Some(w) => w,
                None => {
                    self.stats.placement_rejected += 1;
                    self.obs.event(EventKind::AdmissionReject, rid, 0, 0);
                    self.reject_to_edge(edge_id, rid, "no worker has KV headroom");
                    return Ok(());
                }
            },
        };
        self.deliver(w, edge_id, frame)
    }

    /// Send a frame down a worker route. A refused send means the
    /// worker's receiving half is gone — treat it as a crash and run
    /// failover now instead of waiting for the next health sweep.
    fn deliver(&mut self, w: usize, edge_id: u64, frame: Vec<u8>) -> Result<()> {
        if self.route(w, edge_id).send(&frame).is_ok() {
            return Ok(());
        }
        self.fail_worker(w)
    }

    /// The (edge × worker) loopback route, opened lazily: the worker
    /// half registers as a polled fleet connection under the EDGE's id.
    fn route(&mut self, w: usize, edge_id: u64) -> &mut WireTransport {
        let slot = &mut self.workers[w];
        if !slot.routes.contains_key(&edge_id) {
            let (pool_half, worker_half) = Loopback::pair();
            slot.scheduler.register_polled(edge_id, WireTransport::Loopback(worker_half));
            slot.routes.insert(edge_id, WireTransport::Loopback(pool_half));
        }
        slot.routes.get_mut(&edge_id).expect("route just ensured")
    }

    fn step_workers(&mut self) -> Result<usize> {
        let mut served = 0usize;
        let mut crashed: Vec<usize> = Vec::new();
        for w in 0..self.workers.len() {
            let slot = &mut self.workers[w];
            if let Some(at) = slot.fault.as_ref().and_then(|p| p.disconnect_after) {
                if slot.ops >= at {
                    crashed.push(w);
                    continue;
                }
            }
            slot.scheduler.poll_connections();
            match slot.scheduler.serve_round() {
                Ok(n) => {
                    slot.ops += n as u64;
                    served += n;
                }
                Err(_) => crashed.push(w),
            }
        }
        for w in crashed {
            self.fail_worker(w)?;
        }
        Ok(served)
    }

    fn pump_workers(&mut self) {
        for w in 0..self.workers.len() {
            let eids: Vec<u64> = self.workers[w].routes.keys().copied().collect();
            for eid in eids {
                let mut arrived: Vec<Vec<u8>> = Vec::new();
                let mut dead_route = false;
                {
                    let Some(t) = self.workers[w].routes.get_mut(&eid) else { continue };
                    loop {
                        match t.poll_recv() {
                            Ok(PollRecv::Frame(f, _)) => arrived.push(f),
                            Ok(PollRecv::Empty) => break,
                            Ok(PollRecv::Closed) | Err(_) => {
                                dead_route = true;
                                break;
                            }
                        }
                    }
                }
                for f in arrived {
                    self.forward_to_edge(eid, f);
                }
                if dead_route {
                    // The worker swept this connection (idle deadline,
                    // dead peer): drop our half too.
                    self.workers[w].scheduler.close_connection(eid);
                    self.workers[w].routes.remove(&eid);
                }
            }
        }
    }

    fn forward_to_edge(&mut self, edge_id: u64, frame: Vec<u8>) {
        match wire::peek_reply_meta(&frame) {
            Ok(meta) => {
                // Answered: the replay buffer entry is spent. EOS also
                // retires the placement — the pool-side mirror of the
                // worker's admission-charge release.
                self.inflight.remove(&meta.request_id);
                if meta.token == 0 {
                    self.placements.remove(&meta.request_id);
                }
                self.stats.replies_forwarded += 1;
            }
            Err(_) => {
                // ResumeAck, PrefixAck (passes through verbatim — the
                // edge owns the hit/miss decision), or a typed
                // rejection. A rejection that condemns the session
                // clears its pool residue too; a PREFIX reject does NOT
                // — the edge rebuilds the prefill as an insert and
                // retransmits on the same placement.
                if let Ok(rj) = wire::decode_error_frame(&frame) {
                    if rj.code == reject::ADMISSION || rj.code == reject::FAILED {
                        self.placements.remove(&rj.request_id);
                        self.inflight.remove(&rj.request_id);
                    }
                }
            }
        }
        let Some(t) = self.edges.get_mut(&edge_id) else { return };
        if t.send(&frame).is_err() {
            self.close_edge(edge_id);
        }
    }

    /// Tear down an edge connection: its worker routes, placements and
    /// replay buffers go with it (the worker-side close releases the
    /// admission charges, same as any fleet connection death).
    pub fn close_edge(&mut self, edge_id: u64) {
        if self.edges.remove(&edge_id).is_none() {
            return;
        }
        for slot in self.workers.iter_mut() {
            if slot.routes.remove(&edge_id).is_some() {
                slot.scheduler.close_connection(edge_id);
            }
        }
        let owned: Vec<u64> = self
            .placements
            .iter()
            .filter(|(_, p)| p.edge == edge_id)
            .map(|(&rid, _)| rid)
            .collect();
        for rid in owned {
            self.placements.remove(&rid);
            self.inflight.remove(&rid);
        }
        self.stats.edges_closed += 1;
        self.obs.event(EventKind::EdgeClosed, 0, edge_id, 0);
    }

    // ---- placement -------------------------------------------------------

    /// Eligible workers with per-worker KV headroom, measured in whole
    /// sessions against the POOL's placement ledger (not the workers'
    /// live counts, which lag by a serve round) — this keeps placement a
    /// pure function of arrival order and seed.
    fn candidates(&self, exclude: usize) -> Vec<Candidate> {
        let mut counts = vec![0u64; self.workers.len()];
        for p in self.placements.values() {
            counts[p.worker] += 1;
        }
        self.workers
            .iter()
            .enumerate()
            .filter(|&(w, slot)| w != exclude && !slot.draining)
            .map(|(w, slot)| {
                let cap = match (slot.telemetry_override, self.cfg.fleet.kv_budget_bytes) {
                    // Chaos: the lie replaces the real capacity figure.
                    (Some(lie), _) => lie,
                    (None, Some(b)) => b / slot.scheduler.session_kv_bytes().max(1),
                    (None, None) => u64::MAX / 2,
                };
                Candidate {
                    worker: w,
                    headroom: cap.saturating_sub(counts[w]),
                    weight: slot.region.weight(),
                }
            })
            .collect()
    }

    fn place(&mut self, request_id: u64, edge: u64) -> Option<usize> {
        self.place_preferring(request_id, edge, None)
    }

    /// Place a session, preferring — among workers with headroom — one
    /// whose prefix store already holds `digest`. Falls back to the
    /// plain most-headroom pick when no eligible worker is resident.
    fn place_preferring(
        &mut self,
        request_id: u64,
        edge: u64,
        digest: Option<&PrefixDigest>,
    ) -> Option<usize> {
        let cands = self.candidates(usize::MAX);
        let mut w = None;
        if let Some(dg) = digest {
            let resident: Vec<Candidate> = cands
                .iter()
                .filter(|c| {
                    c.headroom > 0
                        && self.workers[c.worker].scheduler.cloud().prefix_resident(dg)
                })
                .copied()
                .collect();
            w = placement::pick(self.cfg.seed, request_id, &resident);
            if w.is_some() {
                self.stats.prefix_placements += 1;
            }
        }
        let w = w.or_else(|| placement::pick(self.cfg.seed, request_id, &cands))?;
        let headroom =
            cands.iter().find(|c| c.worker == w).expect("picked from candidates").headroom;
        self.placements.insert(request_id, Placement { worker: w, edge });
        self.decisions.push(PlacementDecision { request_id, worker: w, headroom });
        self.stats.placed += 1;
        self.obs.event(EventKind::Admission, request_id, w as u64, headroom);
        Some(w)
    }

    fn reject_to_edge(&mut self, edge_id: u64, rid: u64, why: &str) {
        let rj = RejectFrame {
            code: reject::ADMISSION,
            request_id: rid,
            message: format!("pool: {why}"),
        };
        let out = wire::encode_error_frame(&rj);
        if let Some(t) = self.edges.get_mut(&edge_id) {
            if t.send(&out).is_err() {
                self.close_edge(edge_id);
            }
        }
    }

    // ---- failure handling ------------------------------------------------

    /// Crash a worker now (tests and the chaos harness drive this; the
    /// event loop calls the same path on serve errors and armed faults).
    pub fn kill_worker(&mut self, idx: usize) -> Result<()> {
        anyhow::ensure!(idx < self.workers.len(), "no worker {idx}");
        self.fail_worker(idx)
    }

    fn fail_worker(&mut self, idx: usize) -> Result<()> {
        self.stats.kills += 1;
        self.obs.event(EventKind::Kill, 0, idx as u64, 0);
        // The slot dies WHOLESALE: scheduler (admission charges, fences,
        // control entries), cloud server, and routes all drop together —
        // a dead worker cannot leak charges because the ledger that held
        // them no longer exists. A fresh worker from the same factory
        // takes the slot (same weights, same sampling keys); the
        // replacement rack stands in the same region.
        let region = self.workers[idx].region.clone();
        let mut fresh = Self::spawn_worker(self.factory.as_ref(), self.cfg.fleet)?;
        fresh.region = region;
        self.workers[idx] = fresh;
        self.stats.respawns += 1;
        self.obs.event(EventKind::Respawn, 0, idx as u64, 0);

        // Re-place every victim (sorted order: deterministic recovery),
        // re-delivering its last unanswered payload. The replacement
        // re-serves at most that ONE position; decode payloads carry the
        // session's state, so no other warm state is needed.
        let victims: Vec<(u64, u64)> = self
            .placements
            .iter()
            .filter(|(_, p)| p.worker == idx)
            .map(|(&rid, p)| (rid, p.edge))
            .collect();
        for (rid, edge) in victims {
            self.placements.remove(&rid);
            match self.place(rid, edge) {
                Some(w) => {
                    self.stats.failovers += 1;
                    self.obs.event(EventKind::Failover, rid, w as u64, 0);
                    if let Some(frame) = self.inflight.get(&rid).cloned() {
                        self.stats.failover_redelivered += 1;
                        self.deliver(w, edge, frame)?;
                    }
                }
                None => {
                    self.stats.failover_rejected += 1;
                    self.inflight.remove(&rid);
                    self.reject_to_edge(edge, rid, "no capacity to fail over");
                }
            }
        }
        Ok(())
    }

    // ---- drain / rebalance / migration ------------------------------------

    /// Pump a worker until it has answered everything it owes: no
    /// pending frames, nothing served in the last round, replies
    /// forwarded. Migration requires this quiescence (the scheduler's
    /// export guard makes a violation loud).
    fn quiesce_worker(&mut self, w: usize) -> Result<()> {
        for _ in 0..10_000 {
            self.workers[w].scheduler.poll_connections();
            let served = self.workers[w].scheduler.serve_round()?;
            self.pump_workers();
            if served == 0 && self.workers[w].scheduler.pending_frames() == 0 {
                return Ok(());
            }
        }
        anyhow::bail!("worker {w} would not quiesce")
    }

    /// Live-migrate one session: quiesce the source, export its cloud
    /// residue, ship it through the real kind-7 Migrate codec, import on
    /// the target behind the Resume epoch fence. On a typed target
    /// rejection the session is rolled back onto its source — and if
    /// even that fails, it fails TYPED to the edge. Tokens can never
    /// change: the fence's cached reply frame moves byte-for-byte, and
    /// both workers sample from the same (seed, request, pos) keys.
    pub fn migrate_session(
        &mut self,
        rid: u64,
        target: usize,
    ) -> Result<std::result::Result<(), RejectFrame>> {
        anyhow::ensure!(target < self.workers.len(), "no worker {target}");
        let Some(p) = self.placements.get(&rid).copied() else {
            anyhow::bail!("request {rid} is not placed on this pool");
        };
        if p.worker == target {
            return Ok(Ok(()));
        }
        self.quiesce_worker(p.worker)?;
        let ms = self.workers[p.worker].scheduler.export_session(rid)?;
        let mut bytes = wire::encode_migrate_frame(&ms);
        if let Some(bit) = self.migrate_fault.take() {
            // Chaos: damage the handoff frame in flight.
            self.stats.migrate_frame_faults += 1;
            let at = (bit / 8) % bytes.len();
            bytes[at] ^= 1 << (bit % 8);
        }
        let ms = match wire::decode_migrate_frame(&bytes) {
            Ok(decoded) => decoded,
            Err(e) => {
                // The handoff frame was damaged mid-flight (CRC or
                // structural check caught it — typed, never a silent
                // misdecode). The session was already exported from the
                // source, so re-import the ORIGINAL state there: export
                // removed its epoch entry and released its charges, so
                // the same MigrateState re-admits and re-charges —
                // nothing leaks, and the stream continues exactly where
                // it was. If even the rollback is refused, fail TYPED to
                // the edge.
                self.route(p.worker, p.edge);
                return match self.workers[p.worker].scheduler.import_session(p.edge, &ms)? {
                    Ok(_) => {
                        self.stats.migration_rejected += 1;
                        let (a, b) = (p.worker as u64, target as u64);
                        self.obs.event(EventKind::MigrateReject, rid, a, b);
                        Ok(Err(RejectFrame {
                            code: reject::FAILED,
                            request_id: rid,
                            message: format!("migrate frame damaged in handoff: {e}"),
                        }))
                    }
                    Err(rj) => {
                        self.placements.remove(&rid);
                        self.inflight.remove(&rid);
                        self.reject_to_edge(p.edge, rid, &rj.message.clone());
                        Ok(Err(rj))
                    }
                };
            }
        };
        self.route(target, p.edge);
        match self.workers[target].scheduler.import_session(p.edge, &ms)? {
            Ok(_ack) => {
                self.placements.insert(rid, Placement { worker: target, edge: p.edge });
                self.stats.migrations += 1;
                self.obs.event(EventKind::Migrate, rid, p.worker as u64, target as u64);
                if self.workers[p.worker].region.name != self.workers[target].region.name {
                    self.obs.event(EventKind::RegionHop, rid, p.worker as u64, target as u64);
                }
                Ok(Ok(()))
            }
            Err(rj) => {
                self.stats.migration_rejected += 1;
                self.obs.event(EventKind::MigrateReject, rid, p.worker as u64, target as u64);
                // Roll back onto the source: its epoch entry was removed
                // at export, so the same MigrateState re-admits there.
                self.route(p.worker, p.edge);
                match self.workers[p.worker].scheduler.import_session(p.edge, &ms)? {
                    Ok(_) => Ok(Err(rj)),
                    Err(rj2) => {
                        self.placements.remove(&rid);
                        self.inflight.remove(&rid);
                        self.reject_to_edge(p.edge, rid, &rj2.message.clone());
                        Ok(Err(rj2))
                    }
                }
            }
        }
    }

    /// First-class drain: stop placing onto the worker, then move every
    /// resident session off it (live, bit-identical). Returns how many
    /// sessions moved. The worker stays registered and draining — ready
    /// for maintenance or `undrain_worker`.
    pub fn drain_worker(&mut self, idx: usize) -> Result<usize> {
        anyhow::ensure!(idx < self.workers.len(), "no worker {idx}");
        self.workers[idx].draining = true;
        self.stats.drains += 1;
        self.obs.event(EventKind::Drain, 0, idx as u64, 0);
        self.quiesce_worker(idx)?;
        let resident: Vec<u64> = self
            .placements
            .iter()
            .filter(|(_, p)| p.worker == idx)
            .map(|(&rid, _)| rid)
            .collect();
        let mut moved = 0usize;
        for rid in resident {
            let cands = self.candidates(idx);
            match placement::pick(self.cfg.seed, rid, &cands) {
                Some(target) => {
                    if self.migrate_session(rid, target)?.is_ok() {
                        moved += 1;
                    }
                }
                None => {
                    // Nowhere to put it: typed failure, never a silent drop.
                    let p = self.placements.remove(&rid).expect("resident");
                    let _ = self.workers[idx].scheduler.export_session(rid)?;
                    self.inflight.remove(&rid);
                    self.reject_to_edge(p.edge, rid, "drained worker had no target");
                }
            }
        }
        Ok(moved)
    }

    pub fn undrain_worker(&mut self, idx: usize) {
        self.workers[idx].draining = false;
        self.obs.event(EventKind::Undrain, 0, idx as u64, 0);
    }

    /// One hysteresis-gated rebalance step: when the hottest and coldest
    /// workers differ by at least `rebalance_gap` sessions (and the
    /// cooldown has passed), migrate ONE session hot → cold. Bounded
    /// pause per trigger; repeated polls converge the layout.
    pub fn maybe_rebalance(&mut self) -> Result<bool> {
        if self.polls.saturating_sub(self.last_rebalance) < self.cfg.rebalance_cooldown {
            return Ok(false);
        }
        let mut counts = vec![0u64; self.workers.len()];
        for p in self.placements.values() {
            counts[p.worker] += 1;
        }
        let eligible: Vec<usize> =
            (0..self.workers.len()).filter(|&w| !self.workers[w].draining).collect();
        if eligible.len() < 2 {
            return Ok(false);
        }
        let &hot = eligible.iter().max_by_key(|&&w| counts[w]).expect("non-empty");
        let &cold = eligible.iter().min_by_key(|&&w| counts[w]).expect("non-empty");
        if counts[hot] - counts[cold] < self.cfg.rebalance_gap as u64 {
            return Ok(false);
        }
        let Some(rid) =
            self.placements.iter().find(|(_, p)| p.worker == hot).map(|(&rid, _)| rid)
        else {
            return Ok(false);
        };
        self.last_rebalance = self.polls;
        let ok = self.migrate_session(rid, cold)?.is_ok();
        if ok {
            self.stats.rebalances += 1;
            self.obs.event(EventKind::Rebalance, rid, hot as u64, cold as u64);
        }
        Ok(ok)
    }
}
