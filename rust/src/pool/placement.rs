//! Placement: the Eq. 8c admission gate lifted to per-worker KV budgets.
//!
//! The fleet scheduler admits a session while aggregate live-session KV
//! fits ONE worker's `kv_budget_bytes`. With a pool of workers the same
//! constraint becomes a placement problem: a new session should land on
//! the worker where its back-segment KV working set fits with the most
//! headroom (best-fit-decreasing in reverse — most headroom first keeps
//! the pool level, which is what makes a later worker loss survivable).
//!
//! Workers are not interchangeable across regions, though: Eq. 5's
//! deadline is paid on every edge→worker hop, so a worker behind a
//! far/thin link must offer proportionally MORE headroom to win. Each
//! candidate carries a region `weight` (see
//! [`crate::obs::RegionProfile::weight`]) and the score is
//! `headroom × weight`, computed in u128 so an unbounded-budget pool
//! (headroom `u64::MAX / 2`) cannot saturate into a tie that erases
//! the weights.
//!
//! Placement must also be **deterministic and observable**: the pool
//! replays identically under a seed (benches, chaos reproduction), and
//! every decision is logged as a [`PlacementDecision`]. Ties between
//! equally-scored workers are broken by a seeded splitmix hash of
//! (seed, request, worker) — not by map iteration order, which would
//! leak `HashMap` nondeterminism into the fleet layout.

/// One worker eligible to host a session, with its current headroom in
/// whole sessions (budget ÷ per-session KV bytes, minus already-placed)
/// and its region weight (1..=256; 1 = farthest, uniform weights
/// reproduce the region-blind most-headroom behavior exactly).
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub worker: usize,
    pub headroom: u64,
    pub weight: u64,
}

/// An observable record of one placement: which worker won and how much
/// headroom it had when it did.
#[derive(Clone, Copy, Debug)]
pub struct PlacementDecision {
    pub request_id: u64,
    pub worker: usize,
    pub headroom: u64,
}

/// splitmix64 — the standard 64-bit finalizer; good avalanche, no state.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Pick the candidate with the highest `headroom × weight` score; among
/// ties, the one whose seeded (seed, request, worker) hash is largest.
/// Deterministic in the candidate SET (order-independent) and in the
/// seed. A weight can never resurrect a FULL worker: zero headroom is
/// ineligible regardless of region. `None` when no worker has room —
/// the caller owes the session a typed ADMISSION rejection, not a
/// silent drop.
pub fn pick(seed: u64, request_id: u64, candidates: &[Candidate]) -> Option<usize> {
    candidates
        .iter()
        .filter(|c| c.headroom > 0)
        .max_by_key(|c| {
            let score = (c.headroom as u128) * (c.weight.max(1) as u128);
            let salt = (c.worker as u64).wrapping_mul(0xA24B_AED4_963E_E407);
            (score, mix(seed ^ request_id ^ salt))
        })
        .map(|c| c.worker)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cands(hs: &[u64]) -> Vec<Candidate> {
        hs.iter()
            .enumerate()
            .map(|(worker, &headroom)| Candidate { worker, headroom, weight: 1 })
            .collect()
    }

    #[test]
    fn most_headroom_wins() {
        assert_eq!(pick(7, 1, &cands(&[1, 3, 2])), Some(1));
    }

    #[test]
    fn full_workers_are_ineligible() {
        assert_eq!(pick(7, 1, &cands(&[0, 0, 2])), Some(2));
        assert_eq!(pick(7, 1, &cands(&[0, 0, 0])), None);
        assert_eq!(pick(7, 1, &[]), None);
    }

    #[test]
    fn placement_is_deterministic_and_order_independent() {
        let a = cands(&[4, 4, 4, 4]);
        let mut b = a.clone();
        b.reverse();
        for rid in 0..200u64 {
            let w = pick(99, rid, &a);
            assert_eq!(w, pick(99, rid, &b), "rid {rid}: candidate order changed the pick");
            assert_eq!(w, pick(99, rid, &a), "rid {rid}: pick not reproducible");
        }
    }

    #[test]
    fn tie_break_spreads_across_workers_and_follows_the_seed() {
        let even = cands(&[4, 4, 4, 4]);
        let mut hits = [0usize; 4];
        for rid in 0..400u64 {
            hits[pick(5, rid, &even).unwrap()] += 1;
        }
        for (w, &h) in hits.iter().enumerate() {
            assert!(h > 40, "worker {w} starved by the tie-break: {hits:?}");
        }
        let moved = (0..400u64).filter(|&rid| pick(5, rid, &even) != pick(6, rid, &even)).count();
        assert!(moved > 100, "changing the seed barely moved the layout ({moved}/400)");
    }

    #[test]
    fn region_weight_scales_the_headroom_score() {
        // Equal headroom: the heavier (nearer) region wins outright.
        let near_far = vec![
            Candidate { worker: 0, headroom: 4, weight: 58 },
            Candidate { worker: 1, headroom: 4, weight: 251 },
        ];
        for rid in 0..50u64 {
            assert_eq!(pick(9, rid, &near_far), Some(1));
        }
        // Enough extra headroom flips the pick back to the far region.
        let far_has_room = vec![
            Candidate { worker: 0, headroom: 40, weight: 58 },
            Candidate { worker: 1, headroom: 4, weight: 251 },
        ];
        for rid in 0..50u64 {
            assert_eq!(pick(9, rid, &far_has_room), Some(0));
        }
    }

    #[test]
    fn weight_never_resurrects_a_full_worker() {
        let full_but_near = vec![
            Candidate { worker: 0, headroom: 0, weight: 256 },
            Candidate { worker: 1, headroom: 1, weight: 1 },
        ];
        assert_eq!(pick(3, 11, &full_but_near), Some(1));
        let all_full = vec![Candidate { worker: 0, headroom: 0, weight: 256 }];
        assert_eq!(pick(3, 11, &all_full), None);
    }

    #[test]
    fn unbounded_headroom_does_not_saturate_the_weighted_score() {
        // headroom u64::MAX/2 is the "no budget" sentinel; the u128
        // score must still separate the weights instead of clamping
        // both to the same max.
        let unbounded = vec![
            Candidate { worker: 0, headroom: u64::MAX / 2, weight: 58 },
            Candidate { worker: 1, headroom: u64::MAX / 2, weight: 251 },
        ];
        for rid in 0..50u64 {
            assert_eq!(pick(4, rid, &unbounded), Some(1));
        }
    }
}
