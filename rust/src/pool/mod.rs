//! Sharded cloud pool: many fleet workers behind one placement layer.
//!
//! PR 7's fleet made one cloud process serve thousands of edges — and
//! made that process a single point of failure and a hard capacity
//! ceiling. This module shards the cloud across a pool of workers (each
//! a full [`FleetScheduler`](crate::fleet::FleetScheduler) over its own
//! [`CloudServer`](crate::coordinator::CloudServer)) without giving up
//! the robustness contract the repo has defended since PR 6:
//!
//! > A worker crash, drain, or rebalance at any decode step either
//! > continues the exact fault-free token stream or fails typed — never
//! > silent wrong tokens.
//!
//! Three properties make that contract cheap to keep:
//!
//! 1. **The cloud is stateless and sampling is (seed, request, pos)-
//!    keyed** — any worker built from the same deployment spec produces
//!    bit-identical replies for the same payload, so moving a session
//!    between workers can never change its tokens, only its timing.
//! 2. **Decode payloads carry the session's state** — the fleet
//!    scheduler's mid-stream adoption path (built for reconnects) means
//!    a replacement worker needs no warm state to continue a stream.
//! 3. **Replay fences + resume epochs are serializable** — a session's
//!    entire cloud-side residue (last answered position, its cached
//!    reply frame, announced control settings, epoch high-water mark)
//!    fits in a [`MigrateState`](crate::coordinator::protocol::MigrateState)
//!    and ships worker-to-worker as wire frame kind 7.
//!
//! * [`placement`] — the Eq. 8c admission gate lifted to per-worker KV
//!   budgets: sessions go to the worker with most headroom, tie-broken
//!   by a seeded hash so placement is deterministic and observable.
//! * [`pool`] — the [`CloudPool`] itself: edge frame routing, worker
//!   health sweeps, seeded [`FaultPlan`](crate::wire::FaultPlan) worker
//!   kills, failover with the ≤1 re-served position bound, and live
//!   drain/rebalance via export → Migrate frame → import. Placement
//!   prefers a worker already holding a prefill's prefix digest (wire
//!   v7), so shared prompts land where their cached KV lives; a
//!   session's prefix attachment rides the Migrate frame and is
//!   released/re-attached across the handoff.
//!
//! Workers carry a [`RegionProfile`](crate::obs::RegionProfile):
//! placement scores `headroom × region weight`, so a worker behind a
//! far/thin link needs proportionally more free capacity to win a
//! session. Every pool owns an [`obs::Registry`](crate::obs::Registry)
//! (see [`CloudPool::obs`]) that mirrors all pool/fleet/cloud/prefix
//! counters and records control-plane transitions in a bounded event
//! ring.

pub mod placement;
pub mod pool;

pub use placement::{Candidate, PlacementDecision};
pub use pool::{CloudPool, Placement, PoolConfig, PoolStats};
