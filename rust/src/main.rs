//! splitserve — launcher CLI for the adaptive split-computing framework.
//!
//! Subcommands:
//!   doctor    probe PJRT + artifacts
//!   models    list model configurations
//!   plan      solve Eq. (8) for a memory budget
//!   generate  serve one prompt through the split pipeline
//!   serve     run a workload trace over N edge devices (e2e driver)
//!   cloud     run the cloud half as a standalone frame server (socket)
//!   edge      run the edge half against a remote cloud (socket)
//!   pool      sharded cloud pool demo: placement, worker kills, failover
//!   soak      long-horizon virtual-time soak with leak + drift audits
//!   bench-summary  aggregate BENCH_*.json into BENCH_summary.json
//!   sweep     τ x Q̄a payload sweep on a captured hidden block
//!
//! Every serving mode accepts `--metrics PATH`: on exit it writes a JSON
//! snapshot of the obs registry to PATH and a Prometheus text rendering
//! to PATH.prom.

use std::rc::Rc;
use std::time::Duration;

use anyhow::Result;
use splitserve::adapt::AdaptPolicy;
use splitserve::channel::ChannelTrace;
use splitserve::coordinator::{
    build_pipeline, build_serve_loop, DeploymentSpec, EdgeClient, Request, RetryPolicy,
    ServeSpec, Session, SessionAction, TokenControl,
};
use splitserve::fleet::{serve_listener, FleetConfig, FleetServer};
use splitserve::model::ModelConfig;
use splitserve::obs::{self, RegionProfile, Registry, SoakConfig};
use splitserve::planner::{plan, AnalyticAccuracyModel, PlanChoice, PlanInputs};
use splitserve::pool::{CloudPool, PoolConfig};
use splitserve::runtime::Engine;
use splitserve::trace::{generate_trace, ArrivalPattern, WorkloadSpec};
use splitserve::util::cli::Args;
use splitserve::wire::{EdgePort, Loopback, SocketTransport, WireListener, WireTransport};

const USAGE: &str = "\
splitserve — adaptive split computing for LLM inference

USAGE: splitserve <subcommand> [flags]

  doctor                                probe PJRT + artifacts
  models                                list model configurations
  plan      --model sim7b --budget-mb 16 --w-bar 128
            (prints the Eq. 8 PlanChoice as JSON; exits 2 when infeasible)
  generate  --model sim7b --layers 8 --split 4 --prompt 5,6,7 --max-new 12
            [--prefix-cache-mb N]
  serve     --model sim7b --layers 8 --devices 2 --requests 6 --max-batch 8
            [--adapt] [--scenario constant|step|drift|outage]
            [--arrival poisson|flash-crowd|churn|diurnal [--period-s 60]]
            [--prefix-cache-mb N]
            (--adapt turns on the online control plane; --scenario replays
             a time-varying channel trace on every device link; --arrival
             picks the workload shape — diurnal is a sinusoidal day/night
             load curve; --prefix-cache-mb enables the content-addressed
             prefix KV cache on both halves, 0 = off and byte-identical
             to the pre-v7 wire)
  cloud     --listen 127.0.0.1:7433 --model sim7b --layers 8 --split 4 [--once]
            [--max-batch 8 --fleet-budget-mb 64 --fault-seed S]
            [--prefix-cache-mb N]
            (default is fleet mode: every connection served concurrently,
             cross-connection decode batching, DRR fairness, aggregate-KV
             admission (--fleet-budget-mb, typed ADMISSION rejects when
             full); --once serves exactly one connection serially and
             exits — the cross-process smoke path; --fault-seed wraps
             every accepted connection's read side in seeded cloud-side
             fault injection)
  edge      --connect 127.0.0.1:7433 --model sim7b --layers 8 --split 4 \\
            --prompt 5,6,7 --max-new 12 [--retry N --backoff-ms B]
            [--prefix-cache-mb N]
            (addresses may be unix:/path/to.sock for unix domain sockets;
             both halves must be built with the same model/split flags;
             --retry N survives N wire failures per step — reconnect with
             jittered exponential backoff from B ms, resume, retransmit)
  pool      --workers 3 --sessions 6 --kill 1 [--model sim7b --layers 8
            --split 4 --seed 1337 --max-new 8 --prefix-cache-mb N]
            (in-process sharded-cloud demo: places sessions across a pool
             of fleet workers, kills --kill workers mid-stream, and
             asserts every stream recovered bit-identically with zero
             leaked charges, fences, or placements — the CI pool smoke)
  soak      --minutes 120 --workers 4 [--regions local,us-east,eu-west,ap-south
            --sessions 4000 --seed S --tick-ms 100 --restart-every-s 600
            --drain-every-s 870 --chaos-every-s 1130 --prefix-cache-mb 8
            --model sim7b --layers 8 --split 4]
            (virtual-time long-horizon soak: diurnal churn + rolling
             restarts + drains + chaos over a multi-region pool; exits
             non-zero unless BOTH the leak and drift audits are clean)
  bench-summary  [--dir . --out BENCH_summary.json]
            (aggregate every BENCH_*.json in --dir into one summary)
  sweep     (see examples/compression_sweep for the richer version)

Serving modes (generate, serve, cloud, edge, pool, soak) also accept
  --metrics PATH   write a JSON metrics snapshot to PATH and Prometheus
                   text to PATH.prom on exit
";

fn prompt_from(args: &Args) -> Vec<u32> {
    args.str_or("prompt", "5,6,7")
        .split(',')
        .map(|t| t.trim().parse().unwrap_or(1))
        .collect()
}

/// `--prefix-cache-mb N` → bytes. 0 (the default) disables prefix
/// caching entirely: payloads are byte-identical to the pre-v7 wire.
fn prefix_cache_bytes(args: &Args) -> u64 {
    args.usize_or("prefix-cache-mb", 0) as u64 * 1024 * 1024
}

/// `--metrics PATH` → write the registry's JSON snapshot to PATH and its
/// Prometheus text rendering to PATH.prom. No flag, no files.
fn maybe_write_metrics(args: &Args, reg: &Registry) -> Result<()> {
    if let Some(path) = args.flag("metrics") {
        obs::write_metrics(reg, path)?;
        println!("metrics: wrote {path} and {path}.prom");
    }
    Ok(())
}

/// `--regions a,b,c` → profiles (defaults to `base` when absent).
fn regions_from(args: &Args, base: Vec<RegionProfile>) -> Result<Vec<RegionProfile>> {
    match args.flag("regions") {
        None => Ok(base),
        Some(list) => list
            .split(',')
            .map(|n| {
                let n = n.trim();
                RegionProfile::preset(n).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown region '{n}' (try: local, us-east, us-west, eu-west, ap-south)"
                    )
                })
            })
            .collect(),
    }
}

/// Shared result printout of the one-request drivers (`generate`, `edge`).
/// The `tokens:` line is the cross-process smoke test's comparison key.
fn print_generation(res: &splitserve::coordinator::GenerationResult) {
    println!("tokens: {:?}", res.tokens);
    println!(
        "prefill {:.1} ms | step {:.2} ms | up {} B | down {} B | dropped {}",
        res.prefill.total_latency_s() * 1e3,
        res.mean_step_latency_s() * 1e3,
        res.total_uplink_bytes(),
        res.total_downlink_bytes(),
        res.tokens_dropped
    );
}

/// The chosen Eq. 8 configuration as a line of JSON (the `plan`
/// subcommand's machine-readable contract).
fn plan_choice_json(c: &PlanChoice) -> String {
    format!(
        "{{\"split_layer\": {}, \"qw_front\": {}, \"qw_back\": {}, \"qa_front\": {}, \
         \"qa_back\": {}, \"psi\": {}, \"edge_bytes\": {}, \"predicted_drop\": {:.6}}}",
        c.opsc.split_layer,
        c.opsc.qw_front,
        c.opsc.qw_back,
        c.qa.front,
        c.qa.back,
        c.psi,
        c.edge_bytes,
        c.predicted_drop
    )
}

fn model_from(args: &Args) -> Result<ModelConfig> {
    let name = args.str_or("model", "sim7b");
    let mut cfg = ModelConfig::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (try: {:?})", ModelConfig::all_names()))?;
    if let Some(l) = args.flag("layers") {
        cfg.n_layers = l.parse()?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let args = Args::from_env(true);
    match args.subcommand.as_deref() {
        Some("doctor") => {
            println!("PJRT: {}", splitserve::runtime::smoke()?);
            for name in ["sim7b", "sim13b"] {
                let cfg = ModelConfig::by_name(name).unwrap();
                match Engine::load("artifacts", &cfg) {
                    Ok(e) => println!(
                        "artifacts[{name}]: OK ({} executables)",
                        e.class.artifacts.len()
                    ),
                    Err(e) => println!("artifacts[{name}]: MISSING — run `make artifacts` ({e})"),
                }
            }
        }
        Some("models") => {
            for name in ModelConfig::all_names() {
                let c = ModelConfig::by_name(name).unwrap();
                println!(
                    "{:<22} layers={:<3} d={:<4} heads={} ff={} vocab={} W={} P={} params={:.2}M",
                    c.name,
                    c.n_layers,
                    c.d_model,
                    c.n_heads,
                    c.d_ff,
                    c.vocab,
                    c.max_seq,
                    c.prefill_len,
                    c.total_params() as f64 / 1e6
                );
            }
        }
        Some("plan") => {
            let cfg = model_from(&args)?;
            let budget = args.usize_or("budget-mb", 16) as u64 * 1024 * 1024;
            let w_bar = args.usize_or("w-bar", cfg.max_seq);
            let mut inputs = PlanInputs::defaults(cfg.clone(), budget, w_bar);
            inputs.acc_tolerance = args.f64_or("acc-tol", 1.0);
            match plan(&inputs, &AnalyticAccuracyModel) {
                Some(c) => println!("{}", plan_choice_json(&c)),
                None => {
                    // Machine-readable failure: message on stderr, exit
                    // code 2 (never a panic on the infeasible None).
                    eprintln!(
                        "plan: no feasible configuration under {budget} bytes at W={w_bar} \
                         (accuracy tolerance {})",
                        inputs.acc_tolerance
                    );
                    std::process::exit(2);
                }
            }
        }
        Some("generate") => {
            let cfg = model_from(&args)?;
            let split = args.usize_or("split", cfg.n_layers / 2);
            let prompt = prompt_from(&args);
            let max_new = args.usize_or("max-new", 12);
            let engine = Rc::new(Engine::load("artifacts", &cfg)?);
            let mut spec = DeploymentSpec::defaults(cfg, split);
            spec.prefix_cache_bytes = prefix_cache_bytes(&args);
            if let Some(d) = args.flag("deadline-ms") {
                spec.deadline_s = Some(d.parse::<f64>()? / 1e3);
            }
            let mut pipe = build_pipeline(engine, &spec)?;
            let res = pipe.generate(&Request::new(1, prompt, max_new))?;
            print_generation(&res);
            let reg = Registry::new();
            reg.counter("serve_total_tokens").set(res.tokens.len() as u64);
            pipe.cloud.export_metrics(&reg);
            maybe_write_metrics(&args, &reg)?;
        }
        Some("serve") => {
            let cfg = model_from(&args)?;
            let split = args.usize_or("split", cfg.n_layers / 2);
            let devices = args.usize_or("devices", 2);
            let n_requests = args.usize_or("requests", 6);
            let engine = Rc::new(Engine::load("artifacts", &cfg)?);
            let mut spec = ServeSpec::defaults(cfg.clone(), split, devices);
            spec.deployment.link_seed = 100;
            spec.deployment.prefix_cache_bytes = prefix_cache_bytes(&args);
            spec.batcher.max_batch = args.usize_or("max-batch", spec.batcher.max_batch);
            if let Some(d) = args.flag("deadline-ms") {
                spec.deployment.deadline_s = Some(d.parse::<f64>()? / 1e3);
            }
            if let Some(name) = args.flag("scenario") {
                spec.deployment.channel_trace = Some(
                    ChannelTrace::by_name(name).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown scenario '{name}' (try: constant, step, drift, outage)"
                        )
                    })?,
                );
            }
            if args.has("adapt") {
                spec.adapt = Some(AdaptPolicy::default());
            }
            let mut serve = build_serve_loop(engine, &spec)?;
            let arrival = match args.flag("arrival") {
                None | Some("poisson") => ArrivalPattern::Poisson,
                Some("flash-crowd") => ArrivalPattern::FlashCrowd { lead_s: 2.0, window_s: 1.0 },
                Some("churn") => ArrivalPattern::Churn { burst: 4, gap_s: 8.0 },
                Some("diurnal") => ArrivalPattern::Diurnal {
                    period_s: args.usize_or("period-s", 60) as f64,
                    peak_rate: 2.0,
                    trough_rate: 0.25,
                },
                Some(other) => anyhow::bail!(
                    "unknown arrival '{other}' (try: poisson, flash-crowd, churn, diurnal)"
                ),
            };
            let trace = generate_trace(&WorkloadSpec { n_requests, arrival, ..Default::default() });
            // Real end-to-end serving: every token below crossed the
            // simulated link as compressed bytes and was decoded by the
            // shared stateless cloud in a continuous-batching iteration.
            let report = serve.run(trace, |_, _| TokenControl::Continue)?;
            for r in &report.results {
                println!(
                    "req {}: {} tokens, {:.1} ms e2e, {} B up / {} B down",
                    r.request_id,
                    r.tokens.len(),
                    r.total_latency_s() * 1e3,
                    r.total_uplink_bytes(),
                    r.total_downlink_bytes()
                );
            }
            println!(
                "served {} requests, {} tokens in {:.2} s simulated ({} iterations, peak batch {})",
                report.results.len(),
                report.total_tokens,
                report.clock_s,
                report.iterations,
                report.peak_batch
            );
            println!(
                "throughput {:.1} tok/s | mean latency {:.1} ms | p95 {:.1} ms | server busy {:.2} s | cloud calls {}",
                report.throughput_tok_s(),
                report.mean_latency_s() * 1e3,
                report.p95_latency_s() * 1e3,
                report.server_busy_s,
                serve.cloud.tokens_generated()
            );
            if serve.adapt.is_some() {
                println!(
                    "adaptation: {} re-plans | {} reconfigs | {} control bytes | cloud applied {}",
                    report.replans,
                    report.reconfigs,
                    report.control_bytes,
                    serve.cloud.reconfigs_applied()
                );
            }
            let reg = Registry::new();
            serve.export_metrics(&reg, &report);
            maybe_write_metrics(&args, &reg)?;
        }
        Some("cloud") => {
            let cfg = model_from(&args)?;
            let split = args.usize_or("split", cfg.n_layers / 2);
            let listen = args.str_or("listen", "127.0.0.1:7433");
            let engine = Rc::new(Engine::load("artifacts", &cfg)?);
            let mut spec = DeploymentSpec::defaults(cfg, split);
            spec.prefix_cache_bytes = prefix_cache_bytes(&args);
            let cloud = spec.build_cloud_server(engine)?;
            let listener = WireListener::bind(listen)?;
            if args.has("once") {
                // One connection, serial serve, honest exit code (the
                // cross-process smoke tests check it).
                println!("cloud: serving split l={split} back segment on {listen} (--once)");
                let mut conn = listener.accept()?;
                let n = cloud.serve_connection(&mut conn)?;
                println!("cloud: served {n} payloads, exiting (--once)");
                let reg = Registry::new();
                cloud.export_metrics(&reg);
                maybe_write_metrics(&args, &reg)?;
            } else {
                // Fleet mode: accept thread + one scheduler thread serving
                // every connection concurrently with cross-connection
                // batching, DRR fairness, and aggregate-KV admission.
                let mut fleet_cfg = FleetConfig {
                    max_batch: args.usize_or("max-batch", FleetConfig::default().max_batch),
                    ..FleetConfig::default()
                };
                if let Some(mb) = args.flag("fleet-budget-mb") {
                    fleet_cfg.kv_budget_bytes = Some(mb.parse::<u64>()? * 1024 * 1024);
                }
                let fault_seed = match args.flag("fault-seed") {
                    Some(s) => Some(s.parse::<u64>()?),
                    None => None,
                };
                let mut fleet = FleetServer::new(cloud, fleet_cfg);
                println!(
                    "cloud: fleet-serving split l={split} back segment on {listen} \
                     (max batch {}, budget {:?} B{})",
                    fleet_cfg.max_batch,
                    fleet_cfg.kv_budget_bytes,
                    if fault_seed.is_some() { ", fault injection ON" } else { "" }
                );
                let stop = std::sync::atomic::AtomicBool::new(false); // runs until killed
                serve_listener(listener, &mut fleet, fault_seed, &stop)?;
                let reg = Registry::new();
                reg.publish(&fleet.stats());
                fleet.scheduler().cloud().export_metrics(&reg);
                maybe_write_metrics(&args, &reg)?;
            }
        }
        Some("edge") => {
            let cfg = model_from(&args)?;
            let split = args.usize_or("split", cfg.n_layers / 2);
            let connect = args
                .flag("connect")
                .ok_or_else(|| anyhow::anyhow!("edge needs --connect <addr|unix:path>"))?;
            let prompt = prompt_from(&args);
            let max_new = args.usize_or("max-new", 12);
            let engine = Rc::new(Engine::load("artifacts", &cfg)?);
            let mut spec = DeploymentSpec::defaults(cfg, split);
            spec.prefix_cache_bytes = prefix_cache_bytes(&args);
            if let Some(d) = args.flag("deadline-ms") {
                spec.deadline_s = Some(d.parse::<f64>()? / 1e3);
            }
            let edge = spec.build_edge_device(engine)?;
            let transport = SocketTransport::connect_retry(connect, Duration::from_secs(10))?;
            let mut client = EdgeClient::new(edge, transport);
            client.controller = spec.edge_controller();
            let retries = args.usize_or("retry", 0) as u32;
            let req = Request::new(1, prompt, max_new);
            let res = if retries > 0 {
                client.retry = RetryPolicy::new(retries, args.usize_or("backoff-ms", 50) as u64);
                let addr = connect.to_string();
                client.on_reconnect(Box::new(move || {
                    let t = SocketTransport::connect_retry(&addr, Duration::from_secs(10))?;
                    Ok(WireTransport::Socket(t))
                }));
                client.generate_resilient(&req)?
            } else {
                client.generate(&req)?
            };
            print_generation(&res);
            let reg = Registry::new();
            reg.counter("serve_total_tokens").set(res.tokens.len() as u64);
            let edge_stats = client.edge.prefix_cache.borrow().stats;
            reg.publish(&edge_stats);
            maybe_write_metrics(&args, &reg)?;
        }
        Some("pool") => {
            let cfg = model_from(&args)?;
            let split = args.usize_or("split", cfg.n_layers / 2);
            let workers = args.usize_or("workers", 3);
            let sessions = args.usize_or("sessions", 6);
            let kill = args.usize_or("kill", 0);
            let seed = args.usize_or("seed", 0x5EED) as u64;
            let max_new = args.usize_or("max-new", 8);
            let engine = Rc::new(Engine::load("artifacts", &cfg)?);
            let mut spec = DeploymentSpec::defaults(cfg.clone(), split);
            spec.prefix_cache_bytes = prefix_cache_bytes(&args);
            let pool_cfg = PoolConfig { workers, seed, ..PoolConfig::default() };
            let fspec = spec.clone();
            let feng = engine.clone();
            let mut pool =
                CloudPool::new(move || fspec.build_cloud_server(feng.clone()), pool_cfg)?;
            let edge = spec.build_edge_device(engine.clone())?;

            struct PoolTenant {
                session: Session,
                port: EdgePort,
                up: Option<splitserve::channel::TransferOutcome>,
            }
            let requests: Vec<Request> = (0..sessions)
                .map(|i| {
                    let i = i as u32;
                    Request::new(u64::from(i) + 1, vec![3 + i % 97, 50, 9, i % 13 + 1], max_new)
                })
                .collect();
            let mut tenants: Vec<PoolTenant> = requests
                .iter()
                .map(|r| {
                    let (edge_half, pool_half) = Loopback::pair();
                    pool.add_edge(WireTransport::Loopback(pool_half));
                    PoolTenant {
                        session: Session::for_edge(r.clone(), &edge, spec.edge_controller()),
                        port: EdgePort::new(WireTransport::Loopback(edge_half)),
                        up: None,
                    }
                })
                .collect();

            // Drive every session against the pool, killing workers
            // mid-stream on a fixed schedule so the run is reproducible.
            let mut steps = 0u64;
            let mut killed = 0usize;
            while tenants.iter().any(|t| !t.session.is_terminal()) {
                steps += 1;
                anyhow::ensure!(steps < 200_000, "pool demo did not converge");
                for t in tenants.iter_mut() {
                    if t.session.is_terminal() || t.up.is_some() {
                        continue;
                    }
                    if let SessionAction::Transmit(p) = t.session.poll(&edge)? {
                        t.up = Some(t.port.send_payload(&p)?);
                    }
                }
                if killed < kill && steps == 5 + killed as u64 * 7 {
                    let victim = killed % workers;
                    pool.kill_worker(victim)?;
                    println!("pool: killed worker {victim} at step {steps}");
                    killed += 1;
                }
                pool.poll()?;
                for t in tenants.iter_mut() {
                    if t.session.is_terminal() {
                        continue;
                    }
                    if let Some((reply, cloud_s, down)) = t.port.try_recv_reply()? {
                        let up = t.up.take().expect("reply without in-flight payload");
                        t.session.on_reply(&edge, &reply, cloud_s, up, down)?;
                    }
                }
            }

            // Bit-identity: every stream must match the solo single-
            // session oracle, worker kills and all.
            for r in &requests {
                let mut pipe =
                    build_pipeline(engine.clone(), &DeploymentSpec::defaults(cfg.clone(), split))?;
                let want = pipe.generate(r)?;
                let got = tenants
                    .iter()
                    .find(|t| t.session.request_id() == r.id)
                    .expect("tenant exists")
                    .session
                    .tokens()
                    .to_vec();
                anyhow::ensure!(
                    got == want.tokens,
                    "req {} diverged after failover: {got:?} vs {:?}",
                    r.id,
                    want.tokens
                );
            }
            anyhow::ensure!(
                pool.live_sessions() == 0
                    && pool.fence_entries() == 0
                    && pool.placed_sessions() == 0
                    && pool.inflight_frames() == 0,
                "pool leaked state after all sessions finished"
            );
            let s = pool.stats;
            println!(
                "pool: {sessions} sessions over {workers} workers, {killed} kills — \
                 all streams bit-identical to solo, zero leaked state"
            );
            println!(
                "pool stats: placed {} | kills {} | failovers {} | migrations {} | replies {}",
                s.placed, s.kills, s.failovers, s.migrations, s.replies_forwarded
            );
            pool.publish_metrics();
            maybe_write_metrics(&args, pool.obs())?;
        }
        Some("soak") => {
            let cfg = model_from(&args)?;
            let split = args.usize_or("split", cfg.n_layers / 2);
            let engine = Rc::new(Engine::load("artifacts", &cfg)?);
            let mut spec = DeploymentSpec::defaults(cfg, split);
            spec.prefix_cache_bytes = args.usize_or("prefix-cache-mb", 8) as u64 * 1024 * 1024;
            let mut scfg =
                SoakConfig::default().with_horizon_minutes(args.f64_or("minutes", 120.0));
            scfg.workers = args.usize_or("workers", scfg.workers);
            scfg.seed = args.u64_or("seed", scfg.seed);
            scfg.tick_ms = args.u64_or("tick-ms", scfg.tick_ms);
            scfg.max_sessions = args.usize_or("sessions", scfg.max_sessions);
            scfg.restart_every_s = args.f64_or("restart-every-s", scfg.restart_every_s);
            scfg.drain_every_s = args.f64_or("drain-every-s", scfg.drain_every_s);
            scfg.chaos_every_s = args.f64_or("chaos-every-s", scfg.chaos_every_s);
            scfg.regions = regions_from(&args, scfg.regions)?;
            let reg = std::sync::Arc::new(Registry::new());
            let out = splitserve::obs::soak::run(engine, &spec, &scfg, reg.clone())?;
            println!(
                "soak: {:.0} simulated s in {:.1} wall s — {} sessions ({} completed, \
                 {} typed-failed), {} tokens",
                out.sim_s, out.wall_s, out.sessions, out.completed, out.failed_typed, out.tokens
            );
            println!(
                "churn: {} kills | {} drains | {} migrations | {} events",
                out.kills, out.drains, out.migrations, out.events_total
            );
            for (name, p95) in &out.region_p95_ms {
                println!("region {name}: p95 time-to-token {p95} ms");
            }
            println!(
                "audits: leak {} (residue {}) | drift {} ({} stream + {} reconcile checks, \
                 {} violations)",
                if out.leak.clean() { "CLEAN" } else { "DIRTY" },
                out.leak.total(),
                if out.drift_violations == 0 { "CLEAN" } else { "DIRTY" },
                out.drift_stream_checks,
                out.drift_reconcile_checks,
                out.drift_violations
            );
            for d in &out.drift_details {
                eprintln!("drift: {d}");
            }
            maybe_write_metrics(&args, &reg)?;
            anyhow::ensure!(
                out.passed(),
                "soak FAILED: leak residue {} / drift violations {}",
                out.leak.total(),
                out.drift_violations
            );
            println!("soak PASSED: both audits clean");
        }
        Some("bench-summary") => {
            let dir = args.str_or("dir", ".");
            let out_name = args.str_or("out", "BENCH_summary.json");
            let mut benches: std::collections::BTreeMap<String, String> =
                std::collections::BTreeMap::new();
            for entry in std::fs::read_dir(dir)? {
                let entry = entry?;
                let name = entry.file_name().to_string_lossy().into_owned();
                if !name.starts_with("BENCH_") || !name.ends_with(".json") || name == out_name {
                    continue;
                }
                let text = std::fs::read_to_string(entry.path())?;
                // Only well-formed reports aggregate; a truncated file
                // from a crashed bench is reported, not silently merged.
                if splitserve::util::json::Json::parse(&text).is_err() {
                    eprintln!("bench-summary: skipping malformed {name}");
                    continue;
                }
                let key = name.trim_start_matches("BENCH_").trim_end_matches(".json").to_string();
                benches.insert(key, text.trim().to_string());
            }
            let body: Vec<String> =
                benches.iter().map(|(k, v)| format!("  \"{k}\": {v}")).collect();
            let summary = format!(
                "{{\n\"bench_count\": {},\n\"benches\": {{\n{}\n}}\n}}\n",
                benches.len(),
                body.join(",\n")
            );
            let out_path = std::path::Path::new(dir).join(out_name);
            std::fs::write(&out_path, &summary)?;
            println!(
                "bench-summary: aggregated {} reports into {}",
                benches.len(),
                out_path.display()
            );
        }
        Some("sweep") => {
            println!("see `cargo run --release --example compression_sweep` for the full sweep");
        }
        _ => print!("{USAGE}"),
    }
    Ok(())
}
