//! Unified optimization: Eq. (8) configuration search and the Algorithm-2
//! early-exit controller.

pub mod config_search;
pub mod early_exit;

pub use config_search::{plan, AccuracyModel, AnalyticAccuracyModel, PlanChoice, PlanInputs};
pub use early_exit::{EarlyExitController, ExitDecision, LatencyModel, TxSettings};
