//! Unified configuration search, paper Eq. (8).
//!
//! Enumerate split point ℓ_w, weight precisions Q^w = {Qw1, Qw2} and
//! activation precisions Q^a = {Qa1, Qa2} over their discrete sets; keep
//! the candidates that satisfy the accuracy bound (8b) and the edge memory
//! budget (8c) at the fixed maximum token count W̄; return the one
//! maximizing total activation precision Ψ(Q^a) = Σ_k Q_{a,k}.
//!
//! The accuracy constraint is pluggable: the default `AnalyticAccuracyModel`
//! predicts the drop from per-layer precision penalties (calibrated against
//! this repo's own Table-2/3 runs); `eval`-driven models can be swapped in
//! where a real measurement per candidate is affordable.

use crate::memory::{self, ActBits};
use crate::model::ModelConfig;
use crate::quant::OpscConfig;

/// Predicted accuracy drop (percentage points) for a candidate config.
pub trait AccuracyModel {
    fn predicted_drop(&self, cfg: &ModelConfig, opsc: &OpscConfig, qa: &ActBits) -> f64;
}

/// Analytic proxy: each quantized layer contributes a per-bit penalty,
/// with back-segment layers weighted heavier (paper Table 4 observes the
/// final layers are the most precision-sensitive), plus an activation
/// penalty dominated by the narrower of the two segments.
pub struct AnalyticAccuracyModel;

fn weight_penalty(bits: u32) -> f64 {
    match bits {
        0..=2 => 2.5,
        3 => 0.35,
        4 => 0.045,
        5..=8 => 0.008,
        _ => 0.0,
    }
}

fn act_penalty(bits: u32) -> f64 {
    match bits {
        0..=2 => 6.0,
        3 => 1.1,
        4 => 0.25,
        5..=8 => 0.03,
        _ => 0.0,
    }
}

impl AccuracyModel for AnalyticAccuracyModel {
    fn predicted_drop(&self, cfg: &ModelConfig, opsc: &OpscConfig, qa: &ActBits) -> f64 {
        let l = cfg.n_layers as f64;
        let front = opsc.split_layer as f64;
        let back = l - front;
        // back layers ~2x more sensitive (Table 4: back-end method worse)
        let w_drop = front * weight_penalty(opsc.qw_front)
            + 2.0 * back * weight_penalty(opsc.qw_back);
        let a_drop = front / l * act_penalty(qa.front) * l / 8.0
            + 2.0 * back / l * act_penalty(qa.back) * l / 8.0;
        w_drop + a_drop
    }
}

/// Planner inputs: model, budgets and candidate sets.
#[derive(Clone, Debug)]
pub struct PlanInputs {
    pub cfg: ModelConfig,
    /// Edge memory budget M in bytes (Eq. 8c right side).
    pub mem_budget_bytes: u64,
    /// W̄: maximum token count the edge must accommodate.
    pub w_bar: usize,
    /// A_Δ: acceptable accuracy drop in percentage points (Eq. 8b).
    pub acc_tolerance: f64,
    pub split_candidates: Vec<usize>,
    pub qw_candidates: Vec<u32>,
    pub qa_candidates: Vec<u32>,
}

impl PlanInputs {
    pub fn defaults(cfg: ModelConfig, mem_budget_bytes: u64, w_bar: usize) -> PlanInputs {
        let splits = (1..=cfg.n_layers).collect();
        PlanInputs {
            cfg,
            mem_budget_bytes,
            w_bar,
            acc_tolerance: 1.0, // paper default A_Δ = 1%
            split_candidates: splits,
            qw_candidates: vec![4, 8, 16],
            qa_candidates: vec![2, 3, 4, 8, 16],
        }
    }
}

/// A feasible configuration with its scores.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanChoice {
    pub opsc: OpscConfig,
    pub qa: ActBits,
    /// Ψ(Q^a) — the maximized objective.
    pub psi: u64,
    /// Eq. 8c left side at W̄.
    pub edge_bytes: u64,
    pub predicted_drop: f64,
}

/// Solve Eq. (8) by exhaustive enumeration over the candidate sets
/// (the sets are discrete and small — the paper's own solution approach).
/// Ties on Ψ prefer larger split (maximize edge utilization), then lower
/// memory.
pub fn plan(inputs: &PlanInputs, acc: &dyn AccuracyModel) -> Option<PlanChoice> {
    let mut best: Option<PlanChoice> = None;
    for &split in &inputs.split_candidates {
        if split == 0 || split > inputs.cfg.n_layers {
            continue;
        }
        for &qw_front in &inputs.qw_candidates {
            // The cloud keeps the back segment at full precision (paper
            // §2.1: the server maintains a single high-precision model);
            // Qw2 only matters if the edge caches back layers, which this
            // deployment does not. Fixed to 16.
            let opsc = OpscConfig::new(split, qw_front, 16);
            for &qa_front in &inputs.qa_candidates {
                for &qa_back in &inputs.qa_candidates {
                    let qa = ActBits { front: qa_front, back: qa_back };
                    let drop = acc.predicted_drop(&inputs.cfg, &opsc, &qa);
                    if drop > inputs.acc_tolerance {
                        continue; // violates (8b)
                    }
                    let edge_bytes = memory::edge_total_bytes(
                        &inputs.cfg,
                        split,
                        qw_front,
                        inputs.w_bar,
                        &qa,
                    );
                    if edge_bytes > inputs.mem_budget_bytes {
                        continue; // violates (8c)
                    }
                    let psi = qa.psi(inputs.cfg.n_layers, split);
                    let cand = PlanChoice { opsc, qa, psi, edge_bytes, predicted_drop: drop };
                    let better = match &best {
                        None => true,
                        Some(b) => {
                            (cand.psi, cand.opsc.split_layer, std::cmp::Reverse(cand.edge_bytes))
                                > (b.psi, b.opsc.split_layer, std::cmp::Reverse(b.edge_bytes))
                        }
                    };
                    if better {
                        best = Some(cand);
                    }
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(budget_mb: u64) -> PlanInputs {
        PlanInputs::defaults(ModelConfig::sim7b(), budget_mb * 1024 * 1024, 128)
    }

    #[test]
    fn feasible_plan_respects_constraints() {
        let inp = inputs(16);
        let p = plan(&inp, &AnalyticAccuracyModel).expect("feasible");
        assert!(p.edge_bytes <= inp.mem_budget_bytes);
        assert!(p.predicted_drop <= inp.acc_tolerance);
        assert!(p.opsc.split_layer >= 1);
    }

    #[test]
    fn tighter_memory_lowers_psi_or_split() {
        let rich = plan(&inputs(64), &AnalyticAccuracyModel).unwrap();
        let poor = plan(&inputs(2), &AnalyticAccuracyModel).unwrap();
        assert!(
            poor.psi <= rich.psi,
            "poor {:?} rich {:?}",
            poor,
            rich
        );
        assert!(poor.edge_bytes < rich.edge_bytes);
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let p = plan(&inputs(0), &AnalyticAccuracyModel);
        assert!(p.is_none());
    }

    #[test]
    fn impossible_accuracy_returns_none() {
        let mut inp = inputs(64);
        inp.acc_tolerance = -1.0;
        assert!(plan(&inp, &AnalyticAccuracyModel).is_none());
    }

    #[test]
    fn psi_is_maximized_among_feasible() {
        // brute-force check on a reduced candidate set
        let mut inp = inputs(8);
        inp.split_candidates = vec![5, 10, 20];
        inp.qw_candidates = vec![4, 8];
        inp.qa_candidates = vec![3, 4, 8];
        let best = plan(&inp, &AnalyticAccuracyModel).unwrap();
        for &s in &inp.split_candidates {
            for &qw in &inp.qw_candidates {
                for &qf in &inp.qa_candidates {
                    for &qb in &inp.qa_candidates {
                        let qa = ActBits { front: qf, back: qb };
                        let opsc = OpscConfig::new(s, qw, 16);
                        let drop =
                            AnalyticAccuracyModel.predicted_drop(&inp.cfg, &opsc, &qa);
                        let mem = crate::memory::edge_total_bytes(&inp.cfg, s, qw, 128, &qa);
                        if drop <= inp.acc_tolerance && mem <= inp.mem_budget_bytes {
                            assert!(
                                qa.psi(inp.cfg.n_layers, s) <= best.psi,
                                "missed better candidate"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn analytic_model_monotone_in_bits() {
        let cfg = ModelConfig::sim7b();
        let m = AnalyticAccuracyModel;
        let d4 = m.predicted_drop(&cfg, &OpscConfig::new(20, 4, 16), &ActBits::uniform(4));
        let d8 = m.predicted_drop(&cfg, &OpscConfig::new(20, 8, 16), &ActBits::uniform(8));
        let d3 = m.predicted_drop(&cfg, &OpscConfig::new(20, 4, 16), &ActBits::uniform(3));
        assert!(d8 < d4 && d4 < d3);
    }
}
