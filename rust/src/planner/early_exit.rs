//! Early-exit strategy under delay constraints, paper Algorithm 2.
//!
//! At each decode step the controller estimates the total latency
//! L_t = L_c(w) + L_ε(B_io; R*) (Eq. 11) and, when the deadline D would be
//! violated, walks the paper's escalation ladder:
//!
//!   1. recompress the intermediate output harder (TAB-Q at fewer bits),
//!   2. drop the KV-cache transmission (I_kv ← 0, hidden state only),
//!   3. reduce the token budget w (generate less).
//!
//! The controller is pure decision logic over *measured* compute time and
//! *actual* payload sizes — the coordinator feeds it real numbers from the
//! compression pipeline and the link simulator.

use crate::channel::outage::{worst_case_latency, ChannelParams};

/// Latency estimator for Eq. (11): measured local compute + ε-outage
/// worst-case communication at the operating rate.
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    pub channel: ChannelParams,
    pub rate_bps: f64,
}

impl LatencyModel {
    pub fn total_latency_s(&self, compute_s: f64, payload_bytes: u64) -> f64 {
        compute_s + worst_case_latency(&self.channel, payload_bytes * 8, self.rate_bps)
    }
}

/// Current transmission settings of a request (mutated by escalations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TxSettings {
    /// Activation bit budget Q̄a handed to TAB-Q.
    pub qa_bits: u32,
    /// I_kv: whether the KV cache travels with the hidden state.
    pub include_kv: bool,
}

/// Outcome of one early-exit evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ExitDecision {
    /// Latency fits — transmit as configured.
    Proceed { latency_s: f64 },
    /// Escalated settings fit — transmit with these settings.
    Escalate { settings: TxSettings, latency_s: f64 },
    /// Even the cheapest payload misses the deadline — stop generating
    /// (early exit) after `tokens_to_drop` fewer tokens.
    ReduceTokens { tokens_to_drop: usize, latency_s: f64 },
}

/// Payload oracle: the coordinator supplies the *actual* wire size for a
/// given (settings) pair — compression results, not estimates. `None`
/// means the settings cannot serve the current request state at all
/// (e.g. I_kv = 0 past the prefill width, `ProbeOutcome::Infeasible`);
/// the controller skips such rungs instead of comparing magic sentinels.
pub trait PayloadOracle {
    fn payload_bytes(&self, settings: TxSettings) -> Option<u64>;
}

impl<F: Fn(TxSettings) -> Option<u64>> PayloadOracle for F {
    fn payload_bytes(&self, settings: TxSettings) -> Option<u64> {
        self(settings)
    }
}

/// Algorithm 2 controller.
#[derive(Clone, Copy, Debug)]
pub struct EarlyExitController {
    pub deadline_s: f64,
    pub model: LatencyModel,
    /// Minimum activation bits TAB-Q may be pushed to (paper floor: 2).
    pub min_qa_bits: u32,
    /// Seconds of communication latency freed per dropped token (measured
    /// per-token payload share; used to size the token reduction).
    pub per_token_payload_bytes: u64,
}

impl EarlyExitController {
    /// Evaluate one transmission (Alg. 2 lines 8-27). Infeasible rungs
    /// (oracle returns `None`) are skipped; the ladder only ever lands on
    /// settings that can actually serve the request state.
    pub fn decide(
        &self,
        compute_s: f64,
        start: TxSettings,
        payload: &dyn PayloadOracle,
    ) -> ExitDecision {
        let lat = |s: TxSettings| {
            payload.payload_bytes(s).map(|b| self.model.total_latency_s(compute_s, b))
        };
        // Cheapest feasible latency seen on the ladder (sizes the token
        // cut if every rung misses the deadline).
        let mut l_min = f64::INFINITY;
        if let Some(l) = lat(start) {
            if l <= self.deadline_s {
                return ExitDecision::Proceed { latency_s: l };
            }
            l_min = l;
        }
        // Ladder step 1: recompress harder (lines 10-14).
        let mut s = start;
        while s.qa_bits > self.min_qa_bits {
            s.qa_bits -= 1;
            if let Some(l) = lat(s) {
                if l <= self.deadline_s {
                    return ExitDecision::Escalate { settings: s, latency_s: l };
                }
                l_min = l_min.min(l);
            }
        }
        // Ladder step 2: drop the KV transmission (lines 15-18).
        if s.include_kv {
            s.include_kv = false;
            s.qa_bits = start.qa_bits + 1; // re-try from the configured bits
            while s.qa_bits > self.min_qa_bits {
                s.qa_bits -= 1;
                if let Some(l) = lat(s) {
                    if l <= self.deadline_s {
                        return ExitDecision::Escalate { settings: s, latency_s: l };
                    }
                    l_min = l_min.min(l);
                }
            }
        }
        // Ladder step 3: reduce tokens (lines 19-24) — size the cut from
        // the per-token payload share.
        let over_s = (l_min - self.deadline_s).max(0.0);
        let per_token_s = self.model.total_latency_s(0.0, self.per_token_payload_bytes);
        let drop = if per_token_s > 0.0 && over_s.is_finite() {
            (over_s / per_token_s).ceil() as usize
        } else {
            1
        };
        ExitDecision::ReduceTokens { tokens_to_drop: drop.max(1), latency_s: l_min }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> LatencyModel {
        LatencyModel { channel: ChannelParams::default(), rate_bps: 8e6 }
    }

    /// Payload model: KV costs 20x the hidden state; size scales with bits.
    fn oracle(base: u64) -> impl Fn(TxSettings) -> Option<u64> {
        move |s: TxSettings| {
            let per_bits = base * s.qa_bits as u64 / 8;
            if s.include_kv {
                Some(per_bits * 20)
            } else {
                Some(per_bits)
            }
        }
    }

    fn controller(deadline_s: f64) -> EarlyExitController {
        EarlyExitController {
            deadline_s,
            model: model(),
            min_qa_bits: 2,
            per_token_payload_bytes: 256,
        }
    }

    #[test]
    fn generous_deadline_proceeds() {
        let c = controller(10.0);
        let d = c.decide(0.001, TxSettings { qa_bits: 8, include_kv: true }, &oracle(1024));
        assert!(matches!(d, ExitDecision::Proceed { .. }));
    }

    #[test]
    fn moderate_deadline_recompresses_first() {
        // deadline fails at 8 bits with KV but passes at ~3 bits with KV
        let c = controller(0.100);
        let start = TxSettings { qa_bits: 8, include_kv: true };
        let d = c.decide(0.001, start, &oracle(4096));
        match d {
            ExitDecision::Escalate { settings, latency_s } => {
                assert!(settings.qa_bits < 8, "must reduce bits, got {settings:?}");
                assert!(settings.include_kv, "KV should survive mild pressure");
                assert!(latency_s <= c.deadline_s);
            }
            other => panic!("expected Escalate, got {other:?}"),
        }
    }

    #[test]
    fn tight_deadline_drops_kv() {
        let c = controller(0.012);
        let start = TxSettings { qa_bits: 8, include_kv: true };
        let d = c.decide(0.001, start, &oracle(4096));
        match d {
            ExitDecision::Escalate { settings, latency_s } => {
                assert!(!settings.include_kv, "KV must be dropped: {settings:?}");
                assert!(latency_s <= c.deadline_s);
            }
            other => panic!("expected Escalate(no-kv), got {other:?}"),
        }
    }

    #[test]
    fn impossible_deadline_reduces_tokens() {
        let c = controller(1e-7);
        let start = TxSettings { qa_bits: 8, include_kv: true };
        let d = c.decide(0.001, start, &oracle(4096));
        match d {
            ExitDecision::ReduceTokens { tokens_to_drop, .. } => assert!(tokens_to_drop >= 1),
            other => panic!("expected ReduceTokens, got {other:?}"),
        }
    }

    #[test]
    fn decision_latency_is_consistent_with_model() {
        let c = controller(0.100);
        let start = TxSettings { qa_bits: 8, include_kv: true };
        let orc = oracle(4096);
        if let ExitDecision::Escalate { settings, latency_s } = c.decide(0.001, start, &orc) {
            let recomputed = c.model.total_latency_s(0.001, orc(settings).unwrap());
            assert!((recomputed - latency_s).abs() < 1e-12);
        }
    }

    #[test]
    fn infeasible_rungs_are_skipped() {
        // A deadline only the I_kv=0 rung could meet, but that rung is
        // infeasible: the controller must fall through to ReduceTokens
        // without ever selecting the infeasible settings.
        let c = controller(0.012);
        let start = TxSettings { qa_bits: 8, include_kv: true };
        let gated = |s: TxSettings| {
            if s.include_kv {
                Some(4096 * s.qa_bits as u64 / 8 * 20)
            } else {
                None // e.g. seq_len > prefill width: cannot drop KV
            }
        };
        match c.decide(0.001, start, &gated) {
            ExitDecision::ReduceTokens { tokens_to_drop, latency_s } => {
                assert!(tokens_to_drop >= 1);
                assert!(latency_s.is_finite(), "cut must be sized from a feasible rung");
            }
            other => panic!("expected ReduceTokens, got {other:?}"),
        }
        // sanity: with the rung feasible the same deadline escalates to no-KV
        match c.decide(0.001, start, &oracle(4096)) {
            ExitDecision::Escalate { settings, .. } => assert!(!settings.include_kv),
            other => panic!("expected Escalate, got {other:?}"),
        }
    }

    #[test]
    fn ladder_monotone_under_shrinking_deadline() {
        // As the deadline shrinks the controller must never *increase*
        // the payload: Proceed -> Escalate(bits) -> Escalate(no-kv) ->
        // ReduceTokens, in that order.
        let start = TxSettings { qa_bits: 8, include_kv: true };
        let orc = oracle(4096);
        let mut rank_prev = -1i32;
        for deadline in [5.0, 0.2, 0.100, 0.012, 0.004, 1e-6] {
            let c = controller(deadline);
            let rank = match c.decide(0.001, start, &orc) {
                ExitDecision::Proceed { .. } => 0,
                ExitDecision::Escalate { settings, .. } => {
                    if settings.include_kv {
                        1
                    } else {
                        2
                    }
                }
                ExitDecision::ReduceTokens { .. } => 3,
            };
            assert!(rank >= rank_prev, "ladder regressed at deadline {deadline}");
            rank_prev = rank;
        }
    }
}
