//! Cloud-side fleet scheduler: cross-connection batch formation, DRR
//! fairness, replay fencing and aggregate-KV admission over the existing
//! stateless [`CloudServer`].
//!
//! The scheduler owns the `CloudServer` (its runtime is `Rc`-based and
//! deliberately single-threaded) and every connection's *write* half.
//! Frames reach it as raw `Vec<u8>` — pushed by socket reader threads or
//! pulled by the non-blocking poll sweep — and are classified from the
//! frame header plus the payload body's 17-byte `[request_id][pos][flags]`
//! prefix ([`crate::wire::peek_payload_prefix`]): routing, replay fencing
//! and admission never decompress a tensor. Tensor decode happens once,
//! at serve time, for exactly the frames picked into a batch.
//!
//! Fairness is deficit round-robin in *bytes*: each connection with
//! pending decode payloads earns `drr_quantum` bytes of service per
//! round and spends its deficit front-of-queue, so one chatty edge
//! multiplexing many sessions cannot starve a slow single-session
//! tenant. Picked payloads from ALL connections form one
//! [`CloudServer::handle_batch`] call — cross-connection decode stacking,
//! which the per-connection serial loop could never do.
//!
//! Admission extends the Eq. 8c memory gate across tenants: every live
//! session costs the cloud one decompressed back-segment KV working set
//! (2 · n_back_layers · W̄ · kv_width · 4 bytes) when it appears in a
//! batch, so a new session (prefill, or a `Resume` arriving on a fresh
//! connection) is admitted only while aggregate live-session KV fits
//! `kv_budget_bytes`; otherwise it gets the typed in-band
//! [`reject::ADMISSION`] rejection and the connection stays up.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::protocol::{
    reject, CloudReply, MigrateState, RejectFrame, Resume, ResumeAck, SplitPayload,
};
use crate::coordinator::CloudServer;
use crate::wire::{
    self, peek_payload_prefix, FrameKind, PayloadPrefix, PollRecv, Transport, WireError,
    WireTransport,
};

use super::server::Credits;

/// Knobs of the fleet scheduler.
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Max payloads per cross-connection batch (continuous-batching
    /// iteration width).
    pub max_batch: usize,
    /// Per-connection bound on buffered frames (backpressure: a polled
    /// connection at the bound is not polled; a socket reader thread at
    /// the bound blocks before reading more).
    pub queue_depth: usize,
    /// DRR service quantum in bytes per connection per round.
    pub drr_quantum: u64,
    /// Aggregate cloud KV working-memory budget across all live sessions
    /// (None = admission gate off).
    pub kv_budget_bytes: Option<u64>,
    /// Per-connection idle deadline: a connection that delivers no frame
    /// for this long is closed and fully swept (half-open sockets whose
    /// peer silently vanished would otherwise pin Credits and cloud state
    /// behind a blocking reader forever). None = sweep off.
    pub idle_timeout: Option<Duration>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            max_batch: 8,
            queue_depth: 4,
            drr_quantum: 64 * 1024,
            kv_budget_bytes: None,
            idle_timeout: None,
        }
    }
}

/// Counters of everything the scheduler did (tests and the fleet bench
/// assert on these).
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetStats {
    /// Payloads answered with a fresh reply.
    pub payloads_served: u64,
    /// `handle_batch` calls issued.
    pub batches: u64,
    /// Widest batch formed.
    pub peak_batch: usize,
    /// Duplicate payloads answered by replaying the fenced reply frame.
    pub replayed: u64,
    /// Payloads rejected as behind the replay fence (STALE_POS).
    pub stale_rejected: u64,
    /// Sessions refused by the aggregate-KV admission gate.
    pub admission_rejected: u64,
    /// Retransmits dropped because the same (request, pos) was already
    /// queued and will be answered once.
    pub deduped: u64,
    /// Control-plane reconfigurations applied.
    pub reconfigs: u64,
    /// Resume handshakes answered (admitted or fenced).
    pub resumes: u64,
    /// Connections torn down (clean or crashed) and swept.
    pub closed_conns: u64,
    /// Payloads answered with a typed FAILED rejection.
    pub failed: u64,
    /// Connections closed by the idle-deadline sweep (a subset of
    /// `closed_conns`).
    pub idle_swept: u64,
    /// Sessions exported for worker-to-worker migration.
    pub exported: u64,
    /// Migrated sessions imported (admitted) on this worker.
    pub imported: u64,
}

impl crate::obs::MetricSource for FleetStats {
    /// `fleet_*` counters for the obs registry. `peak_batch` is excluded:
    /// it is a high-water mark, not a monotone counter — the pool mirrors
    /// it as the `fleet_peak_batch` gauge instead.
    fn metrics(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("fleet_payloads_served", self.payloads_served),
            ("fleet_batches", self.batches),
            ("fleet_replayed", self.replayed),
            ("fleet_stale_rejected", self.stale_rejected),
            ("fleet_admission_rejected", self.admission_rejected),
            ("fleet_deduped", self.deduped),
            ("fleet_reconfigs", self.reconfigs),
            ("fleet_resumes", self.resumes),
            ("fleet_closed_conns", self.closed_conns),
            ("fleet_failed", self.failed),
            ("fleet_idle_swept", self.idle_swept),
            ("fleet_exported", self.exported),
            ("fleet_imported", self.imported),
        ]
    }
}

/// How a connection's frames reach the scheduler.
enum ConnMode {
    /// In-process transport swept by [`FleetScheduler::poll_connections`];
    /// the transport also carries replies back.
    Polled,
    /// A blocking socket reader thread pushes frames into the server
    /// inbox; the stored transport is the write half (an OS-level clone).
    /// The credits gate bounds the reader (backpressure).
    Threaded(Arc<Credits>),
}

struct ConnState {
    transport: WireTransport,
    mode: ConnMode,
    /// Intake-validated payload frames awaiting batch formation.
    pending: VecDeque<(PayloadPrefix, Vec<u8>)>,
    /// (request → queued pos) for retransmit dedup while still pending.
    pending_pos: HashMap<u64, u64>,
    /// DRR byte deficit.
    deficit: u64,
    /// Replay fence: last answered position + its encoded reply frame,
    /// per request (same contract as `CloudServer::serve_connection`,
    /// hoisted here so a dead connection's fence is sweepable).
    fence: HashMap<u64, (u64, Vec<u8>)>,
    /// Request ids this connection announced to the cloud control plane
    /// (Reconfig/Resume) — retired on close.
    announced: HashSet<u64>,
    /// Last frame arrival (or registration) — the idle-sweep clock.
    last_seen: Instant,
}

impl ConnState {
    fn release_credit(&self, n: usize) {
        if let ConnMode::Threaded(credits) = &self.mode {
            for _ in 0..n {
                credits.release();
            }
        }
    }
}

pub struct FleetScheduler {
    cloud: CloudServer,
    cfg: FleetConfig,
    conns: HashMap<u64, ConnState>,
    /// Round-robin order (rotated each serve round so no connection is
    /// structurally first).
    rr: VecDeque<u64>,
    /// Live sessions (admitted, not yet EOS) → owning connection. The
    /// admission gate charges each one `session_kv_bytes`.
    live: HashMap<u64, u64>,
    /// Cloud KV working set one live session costs (2 · n_back · W̄ ·
    /// kv_width · 4 bytes).
    session_kv_bytes: u64,
    pub stats: FleetStats,
}

impl FleetScheduler {
    pub fn new(cloud: CloudServer, cfg: FleetConfig) -> FleetScheduler {
        let mcfg = &cloud.node.weights.cfg;
        let session_kv_bytes =
            2 * cloud.node.layer_range.len() as u64
                * mcfg.max_seq as u64
                * mcfg.kv_width() as u64
                * 4;
        FleetScheduler {
            cloud,
            cfg,
            conns: HashMap::new(),
            rr: VecDeque::new(),
            live: HashMap::new(),
            session_kv_bytes,
            stats: FleetStats::default(),
        }
    }

    pub fn cloud(&self) -> &CloudServer {
        &self.cloud
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Cloud KV working-set bytes one live session is charged.
    pub fn session_kv_bytes(&self) -> u64 {
        self.session_kv_bytes
    }

    /// Live (admitted, pre-EOS) sessions across all connections.
    pub fn live_sessions(&self) -> usize {
        self.live.len()
    }

    /// Registered connections.
    pub fn connections(&self) -> usize {
        self.conns.len()
    }

    /// Replay-fence entries across all live connections (hygiene
    /// observability: must be swept with their connection).
    pub fn fence_entries(&self) -> usize {
        self.conns.values().map(|c| c.fence.len()).sum()
    }

    /// Payload frames buffered across all connections.
    pub fn pending_frames(&self) -> usize {
        self.conns.values().map(|c| c.pending.len()).sum()
    }

    pub(crate) fn register_polled(&mut self, id: u64, transport: WireTransport) {
        self.insert_conn(id, transport, ConnMode::Polled);
    }

    pub(crate) fn register_threaded(
        &mut self,
        id: u64,
        write_half: WireTransport,
        credits: Arc<Credits>,
    ) {
        self.insert_conn(id, write_half, ConnMode::Threaded(credits));
    }

    fn insert_conn(&mut self, id: u64, transport: WireTransport, mode: ConnMode) {
        self.conns.insert(
            id,
            ConnState {
                transport,
                mode,
                pending: VecDeque::new(),
                pending_pos: HashMap::new(),
                deficit: 0,
                fence: HashMap::new(),
                announced: HashSet::new(),
                last_seen: Instant::now(),
            },
        );
        self.rr.push_back(id);
    }

    /// Tear a connection down and sweep every piece of per-connection
    /// cloud state it accumulated: replay fences and pending frames go
    /// with the `ConnState`, announced control-plane entries are retired
    /// on the cloud, and the sessions it owned are released from the
    /// admission gate (their per-request state lives on the edge — a
    /// reconnecting session re-admits through `Resume`). Unknown ids are
    /// a no-op, so duplicate close events are harmless.
    pub fn close_connection(&mut self, id: u64) {
        let Some(conn) = self.conns.remove(&id) else { return };
        self.rr.retain(|&c| c != id);
        conn.release_credit(conn.pending.len());
        if let ConnMode::Threaded(credits) = &conn.mode {
            credits.kill();
        }
        // For socket connections the stored transport is an OS-level clone
        // of the reader thread's stream: shutting it down both ways makes
        // the blocked read return EOF *now* instead of at its own I/O
        // timeout, so the reader thread exits with the sweep.
        conn.transport.shutdown();
        for rid in &conn.announced {
            self.cloud.retire_request(*rid);
        }
        self.live.retain(|_, owner| *owner != id);
        self.stats.closed_conns += 1;
    }

    /// Non-blocking sweep over the polled connections: move waiting
    /// frames through intake, up to each connection's queue room (the
    /// polled form of backpressure — a full connection is simply not
    /// polled, frames stay buffered in its transport). Connections whose
    /// peer hung up (or whose intake hit a wire error) are swept.
    pub fn poll_connections(&mut self) {
        let ids: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| matches!(c.mode, ConnMode::Polled))
            .map(|(&id, _)| id)
            .collect();
        for id in ids {
            let mut arrived: Vec<Vec<u8>> = Vec::new();
            let mut closed = false;
            {
                let Some(conn) = self.conns.get_mut(&id) else { continue };
                let mut room = self.cfg.queue_depth.saturating_sub(conn.pending.len());
                while room > 0 {
                    match conn.transport.poll_recv() {
                        Ok(PollRecv::Frame(f, _)) => {
                            arrived.push(f);
                            room -= 1;
                        }
                        Ok(PollRecv::Empty) => break,
                        Ok(PollRecv::Closed) | Err(_) => {
                            closed = true;
                            break;
                        }
                    }
                }
            }
            for f in arrived {
                if self.on_frame(id, f).is_err() {
                    closed = true;
                    break;
                }
            }
            if closed {
                self.close_connection(id);
            }
        }
    }

    /// Intake one frame from a connection. Control frames are handled
    /// immediately; payload frames are fenced/admitted off the peeked
    /// prefix and enqueued for batch formation. An `Err` is
    /// connection-fatal (corrupted frame, dead peer on reply write) —
    /// the caller must sweep the connection; per-request failures are
    /// answered in-band and return `Ok`.
    pub fn on_frame(&mut self, conn_id: u64, frame: Vec<u8>) -> Result<()> {
        let Some(conn) = self.conns.get_mut(&conn_id) else {
            return Ok(()); // late frame from an already-swept connection
        };
        conn.last_seen = Instant::now();
        match peek_payload_prefix(&frame) {
            Ok(pfx) => self.intake_payload(conn_id, pfx, frame),
            Err(WireError::WrongKind { got, .. }) => self.intake_control(conn_id, got, frame),
            Err(e) => {
                // Envelope-level damage (CRC, truncation): connection-fatal,
                // exactly like the serial `serve_connection` loop.
                self.release_one(conn_id);
                Err(e.into())
            }
        }
    }

    fn release_one(&mut self, conn_id: u64) {
        if let Some(conn) = self.conns.get(&conn_id) {
            conn.release_credit(1);
        }
    }

    fn intake_control(&mut self, conn_id: u64, kind: FrameKind, frame: Vec<u8>) -> Result<()> {
        self.release_one(conn_id);
        match kind {
            FrameKind::Reconfig => {
                let rc = wire::decode_reconfig_frame(&frame)?;
                self.cloud.apply_reconfig(&rc);
                self.stats.reconfigs += 1;
                let conn = self.conns.get_mut(&conn_id).expect("checked in on_frame");
                conn.announced.insert(rc.request_id);
                Ok(())
            }
            FrameKind::Resume => {
                let rs = wire::decode_resume_frame(&frame)?;
                self.stats.resumes += 1;
                let conn = self.conns.get_mut(&conn_id).expect("checked in on_frame");
                let last_pos = conn.fence.get(&rs.request_id).map(|(p, _)| *p);
                // A session resuming here may have been released when its
                // old connection died — it must fit the aggregate budget
                // again before the cloud re-fences it.
                if !self.has_room(rs.request_id) {
                    self.stats.admission_rejected += 1;
                    let out = wire::encode_error_frame(&self.admission_reject(rs.request_id));
                    return self.send_to(conn_id, &out);
                }
                let out = match self.cloud.admit_resume(&rs, last_pos) {
                    Ok(ack) => {
                        self.live.insert(rs.request_id, conn_id);
                        let conn = self.conns.get_mut(&conn_id).expect("checked in on_frame");
                        conn.announced.insert(rs.request_id);
                        wire::encode_resume_ack_frame(&ack)
                    }
                    Err(rj) => wire::encode_error_frame(&rj),
                };
                self.send_to(conn_id, &out)
            }
            FrameKind::PrefixProbe => {
                let probe = wire::decode_prefix_probe_frame(&frame)?;
                let ack = self.cloud.handle_probe(&probe);
                // A hit pinned a refcount under this request id; announce
                // it so the connection sweep retires (releases) it even if
                // the session never completes here.
                let conn = self.conns.get_mut(&conn_id).expect("checked in on_frame");
                conn.announced.insert(probe.request_id);
                self.send_to(conn_id, &wire::encode_prefix_ack_frame(&ack))
            }
            other => anyhow::bail!("cloud fleet received a {other:?} frame"),
        }
    }

    fn intake_payload(&mut self, conn_id: u64, pfx: PayloadPrefix, frame: Vec<u8>) -> Result<()> {
        let conn = self.conns.get_mut(&conn_id).expect("checked in on_frame");
        if let Some((last, cached)) = conn.fence.get(&pfx.request_id) {
            if pfx.pos == *last {
                let cached = cached.clone();
                self.stats.replayed += 1;
                self.release_one(conn_id);
                return self.send_to(conn_id, &cached);
            }
            if pfx.pos < *last {
                let rj = RejectFrame {
                    code: reject::STALE_POS,
                    request_id: pfx.request_id,
                    message: format!(
                        "position {} is behind the last answered {last}",
                        pfx.pos
                    ),
                };
                self.stats.stale_rejected += 1;
                self.release_one(conn_id);
                return self.send_to(conn_id, &wire::encode_error_frame(&rj));
            }
        }
        if conn.pending_pos.get(&pfx.request_id) == Some(&pfx.pos) {
            // A retransmit of a frame still queued: the queued copy will
            // be answered once; dropping the duplicate keeps the fence's
            // one-reply-per-position contract.
            self.stats.deduped += 1;
            self.release_one(conn_id);
            return Ok(());
        }
        if pfx.is_prefill && !self.has_room(pfx.request_id) {
            self.stats.admission_rejected += 1;
            self.release_one(conn_id);
            let out = wire::encode_error_frame(&self.admission_reject(pfx.request_id));
            return self.send_to(conn_id, &out);
        }
        // Mid-stream decode traffic adopts its session onto this
        // connection (a reconnect without Resume, or in-order migration):
        // the owner binding keeps the close-time release exact.
        self.live.insert(pfx.request_id, conn_id);
        let conn = self.conns.get_mut(&conn_id).expect("checked in on_frame");
        conn.pending_pos.insert(pfx.request_id, pfx.pos);
        conn.pending.push_back((pfx, frame));
        Ok(())
    }

    /// Would admitting `request_id` as a live session keep aggregate KV
    /// inside the budget? Sessions already live (retransmitted prefill,
    /// mid-stream adoption) always fit — they're never double-charged.
    fn has_room(&self, request_id: u64) -> bool {
        if self.live.contains_key(&request_id) {
            return true;
        }
        match self.cfg.kv_budget_bytes {
            Some(budget) => (self.live.len() as u64 + 1) * self.session_kv_bytes <= budget,
            None => true,
        }
    }

    fn admission_reject(&self, request_id: u64) -> RejectFrame {
        RejectFrame {
            code: reject::ADMISSION,
            request_id,
            message: format!(
                "fleet at capacity: {} live sessions x {} KV bytes against budget {:?}",
                self.live.len(),
                self.session_kv_bytes,
                self.cfg.kv_budget_bytes
            ),
        }
    }

    /// One DRR round: pick up to `max_batch` pending payloads across
    /// connections by byte deficit, serve them as ONE cross-connection
    /// `handle_batch` call, write the replies, and advance the fences.
    /// Returns the number of payloads served (0 = nothing pending).
    pub fn serve_round(&mut self) -> Result<usize> {
        let picked = self.form_batch();
        if picked.is_empty() {
            return Ok(0);
        }
        self.stats.batches += 1;
        self.stats.peak_batch = self.stats.peak_batch.max(picked.len());
        self.serve_picked(picked)
    }

    /// Deficit round-robin selection. Each connection with pending work
    /// earns one `drr_quantum` of byte credit per round and dequeues
    /// front-of-queue while its deficit covers the frame; the scan order
    /// rotates so ties don't always favor the same tenant.
    fn form_batch(&mut self) -> Vec<(u64, PayloadPrefix, Vec<u8>)> {
        let mut picked = Vec::new();
        let n = self.rr.len();
        for _ in 0..n {
            let Some(id) = self.rr.pop_front() else { break };
            self.rr.push_back(id);
            let Some(conn) = self.conns.get_mut(&id) else { continue };
            if conn.pending.is_empty() {
                conn.deficit = 0;
                continue;
            }
            conn.deficit = conn.deficit.saturating_add(self.cfg.drr_quantum);
            let mut took = 0usize;
            while picked.len() < self.cfg.max_batch {
                let Some((_, frame)) = conn.pending.front() else { break };
                let cost = frame.len() as u64;
                if cost > conn.deficit {
                    break;
                }
                conn.deficit -= cost;
                let (pfx, frame) = conn.pending.pop_front().expect("front checked");
                if conn.pending_pos.get(&pfx.request_id) == Some(&pfx.pos) {
                    conn.pending_pos.remove(&pfx.request_id);
                }
                picked.push((id, pfx, frame));
                took += 1;
            }
            conn.release_credit(took);
            if conn.pending.is_empty() {
                conn.deficit = 0; // idle connections don't bank credit
            }
            if picked.len() >= self.cfg.max_batch {
                break;
            }
        }
        picked
    }

    /// Strictly decode the picked frames, serve them (batched; falls back
    /// to payload-at-a-time on a poisoned batch so one bad tenant cannot
    /// void the others' work), send replies, advance fences.
    fn serve_picked(&mut self, picked: Vec<(u64, PayloadPrefix, Vec<u8>)>) -> Result<usize> {
        let mut owners: Vec<(u64, PayloadPrefix)> = Vec::with_capacity(picked.len());
        let mut payloads: Vec<SplitPayload> = Vec::with_capacity(picked.len());
        let mut dead: Vec<u64> = Vec::new();
        for (conn_id, pfx, frame) in picked {
            match wire::decode_payload_frame(&frame) {
                Ok(p) => {
                    owners.push((conn_id, pfx));
                    payloads.push(p);
                }
                Err(e) => {
                    // The envelope was valid at intake, so this is a body
                    // that lies behind a good CRC: condemn the request,
                    // keep the connection.
                    self.stats.failed += 1;
                    let rj = RejectFrame {
                        code: reject::FAILED,
                        request_id: pfx.request_id,
                        message: format!("{e}"),
                    };
                    if self.send_to(conn_id, &wire::encode_error_frame(&rj)).is_err() {
                        dead.push(conn_id);
                    }
                }
            }
        }
        let mut served = 0usize;
        if !payloads.is_empty() {
            type Served = std::result::Result<(CloudReply, f64), (u8, String)>;
            let replies: Vec<Served> = match self.cloud.handle_batch(&payloads) {
                Ok((replies, _)) => replies.into_iter().map(Ok).collect(),
                Err(_) => {
                    // One payload poisoned the batch. The cloud is
                    // stateless and sampling is (seed, request, pos)-
                    // keyed, so re-serving individually returns the
                    // identical tokens; only server-side counters see
                    // the retry. The typed reject code survives (a warm
                    // prefix miss must reach the edge as PREFIX, not
                    // FAILED, so it rebuilds as an insert).
                    payloads
                        .iter()
                        .map(|p| {
                            self.cloud
                                .handle(p)
                                .map_err(|e| (CloudServer::reject_code_for(&e), format!("{e:#}")))
                        })
                        .collect()
                }
            };
            for ((conn_id, pfx), outcome) in owners.into_iter().zip(replies) {
                let out = match outcome {
                    Ok((reply, cloud_s)) => {
                        let reply_frame = wire::encode_reply_frame(&reply, cloud_s);
                        served += 1;
                        self.stats.payloads_served += 1;
                        if let Some(conn) = self.conns.get_mut(&conn_id) {
                            if reply.token == 0 {
                                conn.fence.remove(&pfx.request_id);
                                self.live.remove(&pfx.request_id);
                            } else {
                                conn.fence.insert(pfx.request_id, (pfx.pos, reply_frame.clone()));
                            }
                        }
                        reply_frame
                    }
                    Err((code, msg)) => {
                        self.stats.failed += 1;
                        wire::encode_error_frame(&RejectFrame {
                            code,
                            request_id: pfx.request_id,
                            message: msg,
                        })
                    }
                };
                if self.send_to(conn_id, &out).is_err() {
                    dead.push(conn_id);
                }
            }
        }
        for id in dead {
            self.close_connection(id);
        }
        Ok(served)
    }

    /// Close every connection whose last frame is older than the
    /// configured idle deadline (half-open sweep). Returns the swept ids.
    /// A connection with work still queued is NOT idle — its frames
    /// arrived recently by definition — so the sweep can only hit peers
    /// that genuinely stopped talking.
    pub fn sweep_idle(&mut self) -> Vec<u64> {
        let Some(deadline) = self.cfg.idle_timeout else { return Vec::new() };
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.last_seen.elapsed() >= deadline)
            .map(|(&id, _)| id)
            .collect();
        for &id in &stale {
            self.close_connection(id);
            self.stats.idle_swept += 1;
        }
        stale
    }

    /// Extract and REMOVE a session's entire cloud-side state for a
    /// worker-to-worker migration: the replay fence (last answered
    /// position + cached reply frame), the announced control settings,
    /// and the resume-epoch high-water mark. The shipped migration epoch
    /// is that high-water mark + 1, so the import re-enters the target
    /// through the same strictly-increasing fence a reconnecting edge
    /// uses — a duplicated or stale `Migrate` delivery is a typed
    /// STALE_EPOCH rejection, never a second live copy.
    ///
    /// The session must be quiescent (no queued payloads): the pool
    /// drains a worker's pending work before it moves sessions, and this
    /// guard makes a violation loud instead of silently dropping frames.
    pub fn export_session(&mut self, request_id: u64) -> Result<MigrateState> {
        let Some(&owner) = self.live.get(&request_id) else {
            anyhow::bail!("request {request_id} is not live on this worker");
        };
        let conn = self.conns.get_mut(&owner).expect("live owner is registered");
        anyhow::ensure!(
            !conn.pending_pos.contains_key(&request_id),
            "request {request_id} has queued work; quiesce before migrating"
        );
        let fence = conn.fence.remove(&request_id);
        conn.announced.remove(&request_id);
        self.live.remove(&request_id);
        let (control, epoch) = self.cloud.export_control(request_id);
        // The prefix attachment ships as (digest, len) and is RELEASED
        // here — after export this worker holds no refcount for the
        // session (zero-leak). The shared rows themselves stay resident
        // (other sessions may pin them); the target re-attaches by digest.
        let prefix = self.cloud.export_prefix(request_id);
        self.stats.exported += 1;
        Ok(MigrateState {
            request_id,
            epoch: epoch.unwrap_or(0) + 1,
            next_pos: fence.as_ref().map_or(0, |(p, _)| p + 1),
            fence,
            control,
            prefix,
        })
    }

    /// Admit a migrated session onto this worker, bound to `conn_id`.
    /// Runs the same gauntlet a reconnecting edge faces: the per-worker
    /// aggregate-KV admission gate (typed ADMISSION rejection when full),
    /// then the epoch fence via `admit_resume` (typed STALE_EPOCH on a
    /// duplicate or stale delivery). On admit, the shipped fence and
    /// control settings are installed verbatim, so the very next payload
    /// — even a re-served duplicate of the last answered position — gets
    /// the bit-identical cached reply.
    pub fn import_session(
        &mut self,
        conn_id: u64,
        ms: &MigrateState,
    ) -> Result<std::result::Result<ResumeAck, RejectFrame>> {
        anyhow::ensure!(self.conns.contains_key(&conn_id), "unknown connection {conn_id}");
        if !self.has_room(ms.request_id) {
            self.stats.admission_rejected += 1;
            return Ok(Err(self.admission_reject(ms.request_id)));
        }
        // No shipped control = the session never announced settings; the
        // synthetic values only exist to ride the Resume fence and are
        // retired right after admission.
        let (qa_bits, tau, include_kv) = match &ms.control {
            Some(rc) => (rc.qa_bits, rc.tau, rc.include_kv),
            None => (16, 5.0, true),
        };
        let rs = Resume {
            request_id: ms.request_id,
            epoch: ms.epoch,
            next_pos: ms.next_pos,
            qa_bits,
            tau,
            include_kv,
        };
        let ack = match self.cloud.admit_resume(&rs, ms.fence.as_ref().map(|(p, _)| *p)) {
            Ok(ack) => ack,
            Err(rj) => return Ok(Err(rj)),
        };
        match &ms.control {
            Some(rc) => self.cloud.restore_control(rc),
            None => self.cloud.retire_request(ms.request_id),
        }
        // Re-attach the shipped prefix reference when the digest is
        // resident here; a miss is survivable (the session's next warm
        // payload draws a typed PREFIX reject and rebuilds as an insert).
        // Must come after the retire above, which releases attachments.
        if let Some((digest, _len)) = &ms.prefix {
            self.cloud.import_prefix(ms.request_id, digest);
        }
        self.live.insert(ms.request_id, conn_id);
        let conn = self.conns.get_mut(&conn_id).expect("existence checked above");
        conn.announced.insert(ms.request_id);
        if let Some((pos, frame)) = &ms.fence {
            conn.fence.insert(ms.request_id, (*pos, frame.clone()));
        }
        self.stats.imported += 1;
        Ok(Ok(ack))
    }

    fn send_to(&mut self, conn_id: u64, frame: &[u8]) -> Result<()> {
        let Some(conn) = self.conns.get_mut(&conn_id) else {
            return Ok(()); // already swept
        };
        conn.transport.send(frame).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use std::rc::Rc;

    use super::*;
    use crate::adapt::Reconfig;
    use crate::coordinator::DeploymentSpec;
    use crate::model::ModelConfig;
    use crate::runtime::Engine;
    use crate::wire::Loopback;

    fn sched(cfg: FleetConfig) -> FleetScheduler {
        let mut mcfg = ModelConfig::sim7b();
        mcfg.n_layers = 2;
        let eng = Rc::new(Engine::load("artifacts", &mcfg).expect("run `make artifacts`"));
        let spec = DeploymentSpec::defaults(mcfg, 1);
        FleetScheduler::new(spec.build_cloud_server(eng).unwrap(), cfg)
    }

    /// Register a polled loopback connection, keeping our half alive so
    /// the worker's side never reads Closed.
    fn conn(s: &mut FleetScheduler, id: u64) -> WireTransport {
        let (ours, theirs) = Loopback::pair();
        s.register_polled(id, WireTransport::Loopback(theirs));
        WireTransport::Loopback(ours)
    }

    fn migrated(rid: u64, epoch: u32) -> MigrateState {
        MigrateState {
            request_id: rid,
            epoch,
            next_pos: 4,
            fence: Some((3, vec![0xAB; 24])),
            control: Some(Reconfig {
                request_id: rid,
                epoch: 2,
                qa_bits: 8,
                tau: 4.0,
                include_kv: true,
                budget_cap: Reconfig::NO_BUDGET_CAP,
            }),
            prefix: None,
        }
    }

    /// The migration handoff contract: a duplicated delivery is a typed
    /// STALE_EPOCH (never a second live copy), export removes EVERY trace
    /// and bumps the epoch past the local high-water mark, and the state
    /// round-trips A → B → A without tripping A's own fence.
    #[test]
    fn migrate_import_is_epoch_fenced_and_export_round_trips() {
        let mut a = sched(FleetConfig::default());
        let mut b = sched(FleetConfig::default());
        let _ca = conn(&mut a, 1);
        let _cb = conn(&mut b, 1);

        let state = migrated(77, 5);
        let ack = b.import_session(1, &state).unwrap().expect("first import admits");
        assert_eq!(ack.last_pos, Some(3), "ack must echo the shipped fence position");
        assert_eq!(b.live_sessions(), 1);
        assert_eq!(b.fence_entries(), 1);
        assert_eq!(b.cloud().control_entries(), 1);
        assert_eq!(b.stats.imported, 1);

        let rj = b
            .import_session(1, &state)
            .unwrap()
            .expect_err("a duplicated Migrate delivery must be rejected");
        assert_eq!(rj.code, reject::STALE_EPOCH);
        assert_eq!(b.live_sessions(), 1, "duplicate must not double-admit");

        let out = b.export_session(77).unwrap();
        assert_eq!(out.epoch, 6, "export must fence above the local high-water mark");
        assert_eq!(out.next_pos, 4);
        assert_eq!(out.fence.as_ref().unwrap().0, 3);
        assert_eq!(out.control.unwrap().qa_bits, 8);
        assert_eq!(b.live_sessions(), 0, "export leaked the admission charge");
        assert_eq!(b.fence_entries(), 0, "export leaked the replay fence");
        assert_eq!(b.cloud().control_entries(), 0, "export leaked control state");
        assert_eq!(b.cloud().resume_entries(), 0, "export leaked the epoch fence");
        assert_eq!(b.stats.exported, 1);

        a.import_session(1, &out).unwrap().expect("A admits the exported state");
        let back = a.export_session(77).unwrap();
        assert_eq!(back.epoch, 7);
        b.import_session(1, &back).unwrap().expect("B re-admits after a full round trip");
    }

    #[test]
    fn export_demands_a_live_session_and_a_known_connection() {
        let mut a = sched(FleetConfig::default());
        let _c = conn(&mut a, 1);
        assert!(a.export_session(99).is_err(), "unknown session must fail loudly");
        let state = migrated(5, 1);
        assert!(
            a.import_session(42, &state).is_err(),
            "import onto an unregistered connection must fail loudly"
        );
    }

    /// A migrated session faces the same Eq. 8c gate as a reconnecting
    /// edge: with per-worker budget for one session, the second import is
    /// a typed ADMISSION rejection and charges stay exact.
    #[test]
    fn import_respects_the_per_worker_admission_gate() {
        let probe = sched(FleetConfig::default());
        let per_session = probe.session_kv_bytes();
        drop(probe);
        let mut b = sched(FleetConfig {
            kv_budget_bytes: Some(per_session),
            ..FleetConfig::default()
        });
        let _c = conn(&mut b, 1);
        b.import_session(1, &migrated(7, 1)).unwrap().expect("first session fits");
        let rj = b
            .import_session(1, &migrated(8, 1))
            .unwrap()
            .expect_err("second session must be refused");
        assert_eq!(rj.code, reject::ADMISSION);
        assert_eq!(b.live_sessions(), 1);
        assert_eq!(b.stats.admission_rejected, 1);
        assert_eq!(
            b.cloud().resume_entries(),
            1,
            "a refused import must not leave an epoch entry behind"
        );
    }
}
