//! Fleet-scale multi-tenant cloud serving: one server process, thousands
//! of live edge connections.
//!
//! The serial `splitserve cloud` loop served one connection at a time —
//! fine for validating the protocol, useless as a cloud. This module
//! turns the same stateless [`CloudServer`](crate::coordinator::CloudServer)
//! into a fleet endpoint without giving up any of its invariants:
//!
//! - [`server`] — the accept-and-read layer. Socket connections get
//!   blocking reader threads feeding a shared inbox under credit-based
//!   backpressure; in-process transports are polled. Frames cross threads
//!   as opaque bytes — the single scheduler thread is the only place
//!   tensors are ever decoded.
//! - [`scheduler`] — routing from peeked prefixes (request id, position,
//!   flags — never a tensor decode), per-connection replay fences,
//!   deficit-round-robin fairness in bytes, cross-connection decode
//!   batches through `CloudServer::handle_batch`, and an aggregate-KV
//!   admission gate that extends the paper's Eq. 8c memory constraint
//!   across tenants (typed `ADMISSION` rejection, connection stays up).
//!
//! Because cloud sampling is (seed, request, pos)-keyed and the cloud
//! holds no cross-request state, a session's token stream under fleet
//! scheduling is bit-identical to the same session served solo — the
//! fleet tests and bench assert exactly that.

pub mod scheduler;
pub mod server;

pub use scheduler::{FleetConfig, FleetScheduler, FleetStats};
pub use server::{serve_listener, Credits, FleetServer};
