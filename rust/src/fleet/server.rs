//! Fleet accept-and-read layer: one server process, thousands of live
//! edge connections.
//!
//! The cloud runtime is single-threaded by design (`Rc`-based weights,
//! deterministic sampling), so the fleet splits IO from compute:
//!
//! - **Socket connections** each get a blocking reader thread that moves
//!   whole frames (opaque `Vec<u8>` — no decode on the IO thread) into
//!   the server inbox, gated by a bounded [`Credits`] counter so a slow
//!   scheduler exerts backpressure all the way to the socket instead of
//!   buffering unboundedly. Replies go out on an OS-level clone of the
//!   stream owned by the scheduler.
//! - **Polled connections** (in-process transports: `LinkTransport`
//!   halves, `Loopback`s, fault-wrapped sims) are swept non-blockingly by
//!   the scheduler itself — this is how benches drive 10k simulated
//!   devices from one thread.
//!
//! [`FleetServer::poll`] is the single-step event loop: drain the inbox,
//! sweep polled connections, run one DRR batch round. `serve_listener`
//! wraps it for the real `splitserve cloud` process with an accept
//! thread feeding new sockets through a channel.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::CloudServer;
use crate::wire::{FaultPlan, FaultyTransport, SocketTransport, Transport, WireTransport};

use super::scheduler::{FleetConfig, FleetScheduler, FleetStats};

/// Bounded permit counter gating a reader thread's inbox pushes
/// (per-connection backpressure for threaded connections). `kill` wakes
/// and permanently unblocks waiters so reader threads exit when their
/// connection is swept.
pub struct Credits {
    cap: usize,
    held: Mutex<usize>,
    cv: Condvar,
    dead: AtomicBool,
}

impl Credits {
    pub fn new(cap: usize) -> Credits {
        Credits {
            cap: cap.max(1),
            held: Mutex::new(0),
            cv: Condvar::new(),
            dead: AtomicBool::new(false),
        }
    }

    /// Take one permit, blocking while the connection's queue is full.
    /// Returns `false` once the connection is dead — the caller must
    /// stop reading.
    pub fn acquire(&self) -> bool {
        let mut held = self.held.lock().expect("credits poisoned");
        loop {
            if self.dead.load(Ordering::Acquire) {
                return false;
            }
            if *held < self.cap {
                *held += 1;
                return true;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(held, Duration::from_millis(100))
                .expect("credits poisoned");
            held = guard;
        }
    }

    /// Return one permit (frame dequeued, answered at intake, or dropped
    /// with its connection).
    pub fn release(&self) {
        let mut held = self.held.lock().expect("credits poisoned");
        *held = held.saturating_sub(1);
        drop(held);
        self.cv.notify_one();
    }

    /// Mark the connection dead and wake any blocked reader.
    pub fn kill(&self) {
        self.dead.store(true, Ordering::Release);
        self.cv.notify_all();
    }
}

enum InboxEvent {
    /// A whole frame read from connection `id` (undecoded).
    Frame(u64, Vec<u8>),
    /// Connection `id` hit EOF or a read error — sweep it.
    Closed(u64),
}

/// The fleet front-end: owns the inbox, hands connections to the
/// scheduler, and steps the event loop.
pub struct FleetServer {
    scheduler: FleetScheduler,
    inbox_rx: Receiver<InboxEvent>,
    inbox_tx: Sender<InboxEvent>,
    next_conn: u64,
}

impl FleetServer {
    pub fn new(cloud: CloudServer, cfg: FleetConfig) -> FleetServer {
        let (inbox_tx, inbox_rx) = std::sync::mpsc::channel();
        FleetServer {
            scheduler: FleetScheduler::new(cloud, cfg),
            inbox_rx,
            inbox_tx,
            next_conn: 0,
        }
    }

    pub fn scheduler(&self) -> &FleetScheduler {
        &self.scheduler
    }

    pub fn stats(&self) -> FleetStats {
        self.scheduler.stats
    }

    fn next_id(&mut self) -> u64 {
        let id = self.next_conn;
        self.next_conn += 1;
        id
    }

    /// Register an in-process duplex transport (simulated link half,
    /// loopback, or a fault-wrapped sim). The scheduler polls it — no
    /// thread is spawned. Returns the connection id.
    pub fn add_polled(&mut self, transport: WireTransport) -> u64 {
        let id = self.next_id();
        self.scheduler.register_polled(id, transport);
        id
    }

    /// Register an accepted socket connection: spawn a blocking reader
    /// thread over the read half, keep an OS-level clone as the
    /// scheduler-owned write half. With `fault_seed`, the read half is
    /// wrapped in a [`FaultyTransport`] whose plan derives from the seed
    /// and connection id — cloud-side chaos without touching the edge.
    /// (The write half stays clean: reply-side faults are indistinguishable
    /// from downlink loss, which the edge's retry path already covers, and
    /// the two halves live on different threads so they could not share
    /// one plan's RNG anyway.)
    pub fn add_socket(&mut self, socket: SocketTransport, fault_seed: Option<u64>) -> Result<u64> {
        let id = self.next_id();
        let write_half = WireTransport::Socket(
            socket
                .try_clone()
                .context("cloning accepted socket for the write half")?,
        );
        let queue_depth = self.scheduler.config().queue_depth;
        let credits = Arc::new(Credits::new(queue_depth));
        self.scheduler
            .register_threaded(id, write_half, Arc::clone(&credits));

        let mut read_half: WireTransport = match fault_seed {
            Some(seed) => WireTransport::Faulty(FaultyTransport::new(
                WireTransport::Socket(socket),
                FaultPlan::from_seed(seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            )),
            None => WireTransport::Socket(socket),
        };
        let tx = self.inbox_tx.clone();
        std::thread::Builder::new()
            .name(format!("fleet-read-{id}"))
            .spawn(move || {
                loop {
                    match read_half.recv_eof() {
                        Ok(Some((frame, _))) => {
                            if !credits.acquire() {
                                break; // connection swept while we waited
                            }
                            if tx.send(InboxEvent::Frame(id, frame)).is_err() {
                                break; // server gone
                            }
                        }
                        Ok(None) | Err(_) => {
                            // EOF, timeout, or wire damage: the serial
                            // serve_connection treats all of these as
                            // end-of-connection; so does the fleet.
                            let _ = tx.send(InboxEvent::Closed(id));
                            break;
                        }
                    }
                }
            })
            .context("spawning fleet reader thread")?;
        Ok(id)
    }

    /// One event-loop step: drain the inbox (threaded connections), sweep
    /// polled connections, then run one DRR batch round. Returns the
    /// number of payloads served this step — callers use 0 to decide when
    /// to idle-sleep.
    pub fn poll(&mut self) -> Result<usize> {
        loop {
            match self.inbox_rx.try_recv() {
                Ok(InboxEvent::Frame(id, frame)) => {
                    if self.scheduler.on_frame(id, frame).is_err() {
                        self.scheduler.close_connection(id);
                    }
                }
                Ok(InboxEvent::Closed(id)) => self.scheduler.close_connection(id),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    unreachable!("server holds a sender clone")
                }
            }
        }
        self.scheduler.poll_connections();
        // Idle sweep (when configured): a socket whose peer went silent —
        // wedged device, half-open TCP — would otherwise pin its Credits
        // and cloud-side session state forever.
        self.scheduler.sweep_idle();
        self.scheduler.serve_round()
    }

    /// Explicitly tear down a connection (tests use this to simulate
    /// crashes of polled connections).
    pub fn close_connection(&mut self, id: u64) {
        self.scheduler.close_connection(id);
    }
}

/// Run the fleet against a bound listener until `stop` flips: an accept
/// thread feeds new sockets through a channel while the calling thread —
/// which owns the `Rc`-based cloud runtime — loops `poll`, sleeping
/// briefly when there is nothing to serve.
pub fn serve_listener(
    listener: crate::wire::WireListener,
    fleet: &mut FleetServer,
    fault_seed: Option<u64>,
    stop: &AtomicBool,
) -> Result<()> {
    let (conn_tx, conn_rx) = std::sync::mpsc::channel::<SocketTransport>();
    std::thread::Builder::new()
        .name("fleet-accept".into())
        .spawn(move || loop {
            match listener.accept() {
                Ok(t) => {
                    if conn_tx.send(t).is_err() {
                        break;
                    }
                }
                Err(_) => break,
            }
        })
        .context("spawning fleet accept thread")?;

    while !stop.load(Ordering::Relaxed) {
        while let Ok(t) = conn_rx.try_recv() {
            let id = fleet.add_socket(t, fault_seed)?;
            eprintln!("[cloud] fleet connection {id} accepted");
        }
        let served = fleet.poll()?;
        if served == 0 {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    Ok(())
}
