//! Memory accounting: paper Eq. (1)-(3).
//!
//! Byte-exact models of (1) the OPSC weight footprint, (2) the KV-cache
//! growth under per-segment activation precision, and (3) the intermediate
//! output transmitted at the split point. These drive the planner's
//! memory constraint (Eq. 8c) and the Fig. 6 payload accounting.
//!
//! All quantities are computed in BITS internally and reported in bytes
//! (ceil), so mixed bit-widths never lose fractional bytes.

use crate::model::ModelConfig;
use crate::util::bits_to_bytes;

/// Per-segment activation precision Q^a = {Qa1 (front), Qa2 (back)}.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ActBits {
    pub front: u32,
    pub back: u32,
}

impl ActBits {
    pub fn uniform(bits: u32) -> ActBits {
        ActBits { front: bits, back: bits }
    }

    /// Q_{a,k} for 0-indexed layer k under split ℓ (paper's piecewise def).
    pub fn for_layer(&self, k: usize, split: usize) -> u32 {
        if k < split {
            self.front
        } else {
            self.back
        }
    }

    /// Ψ(Q^a) = Σ_k Q_{a,k} — the planner's objective (Eq. 8a).
    pub fn psi(&self, n_layers: usize, split: usize) -> u64 {
        (0..n_layers)
            .map(|k| self.for_layer(k, split) as u64)
            .sum()
    }
}

/// B_w(i; Q): weight bits of one decoder layer at Q-bit precision.
/// Norm vectors stay fp16 (they are never quantized), matching the
/// implementation in quant::opsc.
pub fn layer_weight_bits(cfg: &ModelConfig, bits: u32) -> u64 {
    let d = cfg.d_model as u64;
    let f = cfg.d_ff as u64;
    let matmul_params = 4 * d * d + 2 * d * f + f * d;
    let norm_params = 2 * d;
    matmul_params * bits as u64 + norm_params * 16
}

/// Eq. (1): M(ℓ_w, Q^w) — total weight footprint of the edge-resident
/// front segment at Qw1 plus the (optionally edge-cached) back segment at
/// Qw2. For a pure split deployment the back segment lives on the cloud;
/// pass `back_layers = 0` to account only the edge share.
pub fn opsc_weight_bytes(cfg: &ModelConfig, split: usize, qw_front: u32, qw_back: u32) -> u64 {
    assert!(split <= cfg.n_layers);
    let front: u64 = (0..split).map(|_| layer_weight_bits(cfg, qw_front)).sum();
    let back: u64 = (split..cfg.n_layers).map(|_| layer_weight_bits(cfg, qw_back)).sum();
    bits_to_bytes(front + back)
}

/// Edge-only share of Eq. (1): front segment + embedding table (the edge
/// must embed tokens locally).
pub fn edge_weight_bytes(cfg: &ModelConfig, split: usize, qw_front: u32) -> u64 {
    let front: u64 = (0..split).map(|_| layer_weight_bits(cfg, qw_front)).sum();
    let emb = (cfg.vocab * cfg.d_model) as u64 * 16; // fp16 embedding
    bits_to_bytes(front + emb)
}

/// Eq. (2): B_kv(w, ℓ; Q^a) — incremental KV memory when generating token
/// w with split at ℓ: the new token's K/V for the ℓ edge layers, the
/// buffered K/V of the previous w-1 tokens for the L-ℓ cloud layers, plus
/// the transient hidden state of token w at layer ℓ.
pub fn kv_bits(cfg: &ModelConfig, w_tokens: usize, split: usize, qa: &ActBits) -> u64 {
    let hd = (cfg.n_heads * cfg.head_dim) as u64;
    let t_w = w_tokens as u64 * hd;
    let t_prev = w_tokens.saturating_sub(1) as u64 * hd;
    let mut bits = 0u64;
    for k in 0..split.min(cfg.n_layers) {
        bits += 2 * t_w * qa.for_layer(k, split) as u64;
    }
    for k in split..cfg.n_layers {
        bits += 2 * t_prev * qa.for_layer(k, split) as u64;
    }
    // transient hidden state of token w at the split layer
    let split_bits = qa.for_layer(split.saturating_sub(1), split) as u64;
    bits += hd * split_bits;
    bits
}

pub fn kv_bytes(cfg: &ModelConfig, w_tokens: usize, split: usize, qa: &ActBits) -> u64 {
    bits_to_bytes(kv_bits(cfg, w_tokens, split, qa))
}

/// Eq. (3): B_io — intermediate output size on the wire. With I_kv = 1 the
/// KV cache travels; with I_kv = 0 only the hidden state rows do.
pub fn io_bytes(
    cfg: &ModelConfig,
    w_tokens: usize,
    split: usize,
    include_kv: bool,
    qa: &ActBits,
) -> u64 {
    if include_kv {
        kv_bytes(cfg, w_tokens, split, qa)
    } else {
        let hd = (cfg.n_heads * cfg.head_dim) as u64;
        let split_bits = qa.for_layer(split.saturating_sub(1), split) as u64;
        bits_to_bytes(w_tokens as u64 * hd * split_bits)
    }
}

/// Total edge memory under a full OPSC configuration (Eq. 8c left side):
/// front weights + embedding + KV at the maximum token budget W̄.
pub fn edge_total_bytes(
    cfg: &ModelConfig,
    split: usize,
    qw_front: u32,
    w_bar: usize,
    qa: &ActBits,
) -> u64 {
    edge_weight_bytes(cfg, split, qw_front) + kv_bytes(cfg, w_bar, split, qa)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig::sim7b()
    }

    #[test]
    fn weight_bytes_monotone_in_bits_and_split() {
        let c = cfg();
        let b4 = opsc_weight_bytes(&c, 16, 4, 16);
        let b8 = opsc_weight_bytes(&c, 16, 8, 16);
        let b16 = opsc_weight_bytes(&c, 16, 16, 16);
        assert!(b4 < b8 && b8 < b16);
        // larger front segment at 4 bits = smaller total
        assert!(opsc_weight_bytes(&c, 24, 4, 16) < opsc_weight_bytes(&c, 8, 4, 16));
    }

    #[test]
    fn eq1_manual_check() {
        let c = cfg();
        // all layers at 16 bits: matmul params * 2 bytes + norms * 2 bytes
        let total = opsc_weight_bytes(&c, 0, 4, 16);
        let per_layer = (4 * 128 * 128 + 2 * 128 * 352 + 352 * 128 + 2 * 128) as u64 * 2;
        assert_eq!(total, per_layer * 32);
    }

    #[test]
    fn kv_grows_with_tokens() {
        let c = cfg();
        let qa = ActBits::uniform(8);
        let k10 = kv_bytes(&c, 10, 20, &qa);
        let k50 = kv_bytes(&c, 50, 20, &qa);
        assert!(k50 > k10 * 4);
    }

    #[test]
    fn eq2_manual_check() {
        let c = cfg();
        let qa = ActBits { front: 4, back: 8 };
        let hd = 128u64;
        let w = 10u64;
        let split = 20usize;
        let expect_bits = 2 * w * hd * 4 * 20      // front: T_w at Qa1
            + 2 * (w - 1) * hd * 8 * 12            // back: T_{w-1} at Qa2
            + hd * 4; // transient hidden at split layer (front bits)
        assert_eq!(kv_bits(&c, 10, split, &qa), expect_bits);
    }

    #[test]
    fn io_without_kv_much_smaller() {
        let c = cfg();
        let qa = ActBits::uniform(8);
        let with = io_bytes(&c, 50, 20, true, &qa);
        let without = io_bytes(&c, 50, 20, false, &qa);
        assert!(without < with / 10, "{without} vs {with}");
    }

    #[test]
    fn io_hidden_only_is_tokens_times_width() {
        let c = cfg();
        let qa = ActBits::uniform(8);
        assert_eq!(io_bytes(&c, 3, 20, false, &qa), 3 * 128); // 3*128*8bits/8
    }

    #[test]
    fn psi_counts_per_layer_bits() {
        let qa = ActBits { front: 4, back: 8 };
        assert_eq!(qa.psi(32, 20), 20 * 4 + 12 * 8);
        assert_eq!(ActBits::uniform(4).psi(32, 7), 128);
    }

    #[test]
    fn edge_total_includes_kv_and_embedding() {
        let c = cfg();
        let qa = ActBits::uniform(8);
        let t = edge_total_bytes(&c, 20, 4, 128, &qa);
        assert_eq!(
            t,
            edge_weight_bytes(&c, 20, 4) + kv_bytes(&c, 128, 20, &qa)
        );
        assert!(t > edge_weight_bytes(&c, 20, 4));
    }
}
