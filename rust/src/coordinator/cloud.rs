//! Cloud server: runs the full-precision back segment statelessly — every
//! call carries all the state it needs (paper Fig. 1(c): one server, many
//! heterogeneous edge devices, no per-client residue between calls).

use std::time::Instant;

use anyhow::Result;

use super::protocol::{CloudReply, SplitPayload};
use super::profile::DeviceProfile;
use crate::quant::ScratchPool;
use crate::runtime::NodeRuntime;

pub struct CloudServer {
    /// Back segment (layers split..L) + lm head, full precision.
    pub node: NodeRuntime,
    pub profile: DeviceProfile,
    /// Tokens served (for Fig. 5(b) accounting).
    pub tokens_generated: u64,
    /// Decompression scratch (rANS slot-lookup table, code buffers),
    /// reused across requests and KV layers.
    pub scratch: ScratchPool,
}

fn argmax(v: &[f32]) -> u32 {
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (i, &x) in v.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1 as u32
}

fn entropy(logits: &[f32]) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| {
        let p = e / z;
        if p > 0.0 { -p * p.ln() } else { 0.0 }
    }).sum()
}

impl CloudServer {
    pub fn new(node: NodeRuntime, profile: DeviceProfile) -> CloudServer {
        CloudServer { node, profile, tokens_generated: 0, scratch: ScratchPool::new() }
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.node.weights.cfg
    }

    /// Serve one payload. Returns (reply, scaled_compute_seconds).
    pub fn handle(&mut self, payload: &SplitPayload) -> Result<(CloudReply, f64)> {
        let cfg = self.cfg().clone();
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        let t0 = Instant::now();
        let reply = if payload.is_prefill || payload.kv.is_none() {
            // Prefill, or I_kv = 0 decode (full hidden history): run the
            // back segment prefill-style over all rows.
            let w = payload.hidden.rows;
            anyhow::ensure!(w <= cfg.prefill_len, "hidden block exceeds prefill width");
            let mut h = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            h.resize(cfg.prefill_len * d, 0.0); // zero-pad to static width
            let (h_out, kv_rows) = self.node.prefill(&h)?;
            let logits = self.node.logits_prefill(&h_out)?;
            let row = &logits[payload.pos * cfg.vocab..(payload.pos + 1) * cfg.vocab];
            let token = argmax(row);
            // Reply with the back-layer KV rows for all processed tokens
            // (prefill only — I_kv=0 decode keeps the cloud stateless and
            // the edge will resend history anyway).
            let new_kv_rows = if payload.is_prefill {
                kv_rows
                    .into_iter()
                    .map(|(k, v)| (k[..w * kvw].to_vec(), v[..w * kvw].to_vec()))
                    .collect()
            } else {
                Vec::new()
            };
            CloudReply {
                request_id: payload.request_id,
                token,
                new_kv_rows,
                logits_entropy: entropy(row),
            }
        } else {
            // I_kv = 1 decode: reconstruct the shipped caches, run one
            // decode step, return the new KV row per layer.
            let kv_in = payload
                .kv
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("decode payload without KV"))?;
            let mut caches = kv_in.decompress_with_pool(cfg.max_seq, kvw, &self.scratch)?;
            anyhow::ensure!(
                caches.len() == self.node.layer_range.len(),
                "KV layer count mismatch"
            );
            let h = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            anyhow::ensure!(h.len() == d, "decode hidden must be one row");
            let h_out = self.node.decode(&h, &mut caches, payload.pos)?;
            let logits = self.node.logits_decode(&h_out)?;
            let token = argmax(&logits);
            let pos = payload.pos;
            let new_kv_rows = caches
                .iter()
                .map(|c| {
                    (
                        c.k[pos * kvw..(pos + 1) * kvw].to_vec(),
                        c.v[pos * kvw..(pos + 1) * kvw].to_vec(),
                    )
                })
                .collect();
            CloudReply {
                request_id: payload.request_id,
                token,
                new_kv_rows,
                logits_entropy: entropy(&logits),
            }
        };
        self.tokens_generated += 1;
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());
        Ok((reply, compute_s))
    }
}
