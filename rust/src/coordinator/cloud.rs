//! Cloud server: runs the full-precision back segment statelessly — every
//! call carries all the state it needs (paper Fig. 1(c): one server, many
//! heterogeneous edge devices, no per-client residue between calls).
//!
//! Because no per-request state lives here, `handle` takes `&self`: ONE
//! `CloudServer` instance is shared by every session of the serve loop.
//! Mutable residue is limited to stats (atomic) and the decompression
//! scratch pool (already interior-mutable).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::Result;

use super::profile::DeviceProfile;
use super::protocol::{CloudReply, SplitPayload};
use super::sampling::{self, sample};
use crate::quant::ScratchPool;
use crate::runtime::NodeRuntime;

pub struct CloudServer {
    /// Back segment (layers split..L) + lm head, full precision.
    pub node: NodeRuntime,
    pub profile: DeviceProfile,
    /// Tokens served (for Fig. 5(b) accounting); atomic so `handle` stays
    /// `&self` under many-to-one sharing.
    tokens_generated: AtomicU64,
    /// Decompression scratch (rANS slot-lookup table, code buffers),
    /// reused across requests and KV layers.
    pub scratch: ScratchPool,
}

impl CloudServer {
    pub fn new(node: NodeRuntime, profile: DeviceProfile) -> CloudServer {
        CloudServer {
            node,
            profile,
            tokens_generated: AtomicU64::new(0),
            scratch: ScratchPool::new(),
        }
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.node.weights.cfg
    }

    /// Tokens served over the life of the server (all sessions).
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated.load(Ordering::Relaxed)
    }

    /// Serve one payload. Returns (reply, scaled_compute_seconds).
    pub fn handle(&self, payload: &SplitPayload) -> Result<(CloudReply, f64)> {
        let t0 = Instant::now();
        let reply = self.serve_payload(payload)?;
        self.tokens_generated.fetch_add(1, Ordering::Relaxed);
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());
        Ok((reply, compute_s))
    }

    /// Serve one continuous-batching iteration's worth of payloads
    /// back-to-back on this server (one scratch pool, one pass over the
    /// batch). Per-payload compute is measured individually so the serve
    /// loop's iteration accounting can apply its sub-linear batching model
    /// to real numbers; replies are position-matched to `payloads`.
    pub fn handle_batch(&self, payloads: &[SplitPayload]) -> Result<Vec<(CloudReply, f64)>> {
        payloads.iter().map(|p| self.handle(p)).collect()
    }

    fn serve_payload(&self, payload: &SplitPayload) -> Result<CloudReply> {
        let cfg = self.cfg().clone();
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        let reply = if payload.is_prefill || payload.kv.is_none() {
            // Prefill, or I_kv = 0 decode (full hidden history): run the
            // back segment prefill-style over all rows.
            let w = payload.hidden.rows;
            anyhow::ensure!(w <= cfg.prefill_len, "hidden block exceeds prefill width");
            let mut h = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            h.resize(cfg.prefill_len * d, 0.0); // zero-pad to static width
            let (h_out, kv_rows) = self.node.prefill(&h)?;
            let logits = self.node.logits_prefill(&h_out)?;
            let row = &logits[payload.pos * cfg.vocab..(payload.pos + 1) * cfg.vocab];
            let token = sample(row, payload.sampling, payload.request_id, payload.pos);
            // Reply with the back-layer KV rows for all processed tokens
            // (prefill only — I_kv=0 decode keeps the cloud stateless and
            // the edge will resend history anyway).
            let new_kv_rows = if payload.is_prefill {
                kv_rows
                    .into_iter()
                    .map(|(k, v)| (k[..w * kvw].to_vec(), v[..w * kvw].to_vec()))
                    .collect()
            } else {
                Vec::new()
            };
            CloudReply {
                request_id: payload.request_id,
                token,
                new_kv_rows,
                logits_entropy: sampling::entropy(row),
            }
        } else {
            // I_kv = 1 decode: reconstruct the shipped caches, run one
            // decode step, return the new KV row per layer.
            let kv_in = payload
                .kv
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("decode payload without KV"))?;
            let mut caches = kv_in.decompress_with_pool(cfg.max_seq, kvw, &self.scratch)?;
            anyhow::ensure!(
                caches.len() == self.node.layer_range.len(),
                "KV layer count mismatch"
            );
            let h = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            anyhow::ensure!(h.len() == d, "decode hidden must be one row");
            let h_out = self.node.decode(&h, &mut caches, payload.pos)?;
            let logits = self.node.logits_decode(&h_out)?;
            let token = sample(&logits, payload.sampling, payload.request_id, payload.pos);
            let pos = payload.pos;
            let new_kv_rows = caches
                .iter()
                .map(|c| {
                    (
                        c.k[pos * kvw..(pos + 1) * kvw].to_vec(),
                        c.v[pos * kvw..(pos + 1) * kvw].to_vec(),
                    )
                })
                .collect();
            CloudReply {
                request_id: payload.request_id,
                token,
                new_kv_rows,
                logits_entropy: sampling::entropy(&logits),
            }
        };
        Ok(reply)
    }
}
