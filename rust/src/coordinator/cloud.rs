//! Cloud server: runs the full-precision back segment statelessly — every
//! call carries all the state it needs (paper Fig. 1(c): one server, many
//! heterogeneous edge devices, no per-client residue between calls).
//!
//! Because no per-request state lives here, `handle` takes `&self`: ONE
//! `CloudServer` instance is shared by every session of the serve loop.
//! Mutable residue is limited to stats (atomic) and the decompression
//! scratch pool (already interior-mutable).
//!
//! `handle_batch` is the stacked-decode entry point: the single-token
//! I_kv = 1 payloads of one continuous-batching iteration are stacked
//! into ONE batched engine call (`NodeRuntime::decode_batch` +
//! `logits_decode_batch`), so B concurrent sessions pay a single
//! traversal of the back-segment weight matrices instead of B. Stacking
//! is bit-transparent — per-session attention runs against that
//! session's own reconstructed cache — so token streams are identical to
//! serving each payload alone (pinned by `tests/session_serve.rs`).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::profile::DeviceProfile;
use super::protocol::{
    reject, CloudReply, PrefixAck, PrefixProbe, PrefixRef, RejectFrame, Resume, ResumeAck,
    SplitPayload,
};
use super::sampling::{self, sample};
use crate::adapt::Reconfig;
use crate::obs::{Counter, Registry};
use crate::prefix::{PrefixDigest, PrefixKv, PrefixStore, PrefixStoreStats};
use crate::quant::ScratchPool;
use crate::runtime::{LayerKv, NodeRuntime};
use crate::wire::FrameKind;

/// Typed miss for a warm prefix payload whose digest is not resident (or
/// whose stored shape disagrees with the reference): the edge presented a
/// cache token this server cannot honor — evicted, migrated away, forged,
/// or stale. Wire paths map it to an in-band [`reject::PREFIX`] so the
/// session can rebuild the prefill as a full insert and retransmit; it is
/// never served with silently-wrong state.
#[derive(Debug)]
pub struct PrefixMiss {
    pub request_id: u64,
    pub message: String,
}

impl std::fmt::Display for PrefixMiss {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {}: prefix miss: {}", self.request_id, self.message)
    }
}

impl std::error::Error for PrefixMiss {}

/// How one `handle_batch` call actually spent the server's wall time, so
/// the serve loop can charge its simulated clock without re-modeling work
/// that was already batched for real.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchCompute {
    /// Sum of individually measured payload seconds (prefill, I_kv = 0,
    /// stacking disabled). These ran serially, so the serve loop's
    /// sub-linear batching model may legitimately be applied to them.
    pub solo_s: f64,
    pub solo_n: usize,
    /// Measured wall seconds of the stacked engine call — already
    /// sub-linear for real; charging it through the batching model again
    /// would double-count the stacking gain.
    pub stacked_s: f64,
    pub stacked_n: usize,
}

pub struct CloudServer {
    /// Back segment (layers split..L) + lm head, full precision.
    pub node: NodeRuntime,
    pub profile: DeviceProfile,
    /// Tokens served (for Fig. 5(b) accounting); an obs counter so
    /// `handle` stays `&self` under many-to-one sharing and the value
    /// exports to the metrics registry without extra glue.
    tokens_generated: Counter,
    /// Tokens served through the stacked (B >= 2) decode path.
    tokens_stacked: Counter,
    /// Decompression scratch (rANS slot-lookup table, code buffers),
    /// reused across requests and KV layers.
    pub scratch: ScratchPool,
    /// Stack same-iteration decode payloads into one batched engine call.
    /// Disabled (payload-at-a-time serving) only by the A/B baselines in
    /// `benches/engine.rs`.
    pub stacked: bool,
    /// Control-plane view: the last transmission settings each session
    /// announced via a `Reconfig` frame. The server holds the data plane
    /// to this word — a payload quantized wider than the announced Q̄a
    /// is rejected as a protocol violation. Entries are dropped when a
    /// session's EOS reply is served. Mutex-guarded so `handle` stays
    /// `&self` under many-to-one sharing.
    control: Mutex<HashMap<u64, Reconfig>>,
    /// Reconfigurations applied over the life of the server.
    reconfigs_applied: Counter,
    /// Resumption fence: the highest resume epoch accepted per request.
    /// OUTLIVES connections (unlike `control`) — a delayed duplicate
    /// `Resume` from a dead connection must be rejectable after the live
    /// one reconnected. Entries are dropped when the EOS reply is served.
    resume_epochs: Mutex<HashMap<u64, u32>>,
    /// Content-addressed store of back-segment prefill KV, shared across
    /// every session this server serves (the whole point). Budget 0
    /// (default) disables it and the serving paths reduce to their
    /// pre-prefix behavior. Mutex-guarded so `handle` stays `&self`.
    prefix: Mutex<PrefixStore>,
}

impl CloudServer {
    pub fn new(node: NodeRuntime, profile: DeviceProfile) -> CloudServer {
        CloudServer {
            node,
            profile,
            tokens_generated: Counter::new(),
            tokens_stacked: Counter::new(),
            scratch: ScratchPool::new(),
            stacked: true,
            control: Mutex::new(HashMap::new()),
            reconfigs_applied: Counter::new(),
            resume_epochs: Mutex::new(HashMap::new()),
            prefix: Mutex::new(PrefixStore::new(0)),
        }
    }

    /// Size (bytes) of the content-addressed prefix store. 0 disables
    /// prefix caching on this server. Replaces the store wholesale, so
    /// call it at deployment build time, before sessions attach.
    pub fn set_prefix_budget(&self, budget_bytes: u64) {
        *self.prefix.lock().expect("prefix store poisoned") = PrefixStore::new(budget_bytes);
    }

    fn prefix_store(&self) -> std::sync::MutexGuard<'_, PrefixStore> {
        self.prefix.lock().expect("prefix store poisoned")
    }

    /// Whether `digest` is resident in this server's prefix store
    /// (placement signal for the worker pool; does not bump LRU).
    pub fn prefix_resident(&self, digest: &PrefixDigest) -> bool {
        self.prefix_store().resident(digest)
    }

    /// Bytes the prefix store currently charges against Eq. 8c's cloud
    /// memory term — each shared prefix counted once, no matter how many
    /// sessions attach.
    pub fn prefix_charged_bytes(&self) -> u64 {
        self.prefix_store().charged_bytes()
    }

    /// The prefix store's byte budget (0 = prefix caching disabled).
    /// The leak audit checks `charged ≤ budget` on every worker.
    pub fn prefix_budget_bytes(&self) -> u64 {
        self.prefix_store().budget_bytes()
    }

    /// Outstanding request→prefix attachments (leak audits: must return
    /// to zero once every session has retired).
    pub fn prefix_live_attachments(&self) -> usize {
        self.prefix_store().live_attachments()
    }

    /// Prefix-store counters (hits/misses/inserts/evictions).
    pub fn prefix_stats(&self) -> PrefixStoreStats {
        self.prefix_store().stats
    }

    /// Answer a `PrefixProbe`: attach the request to the digest if it is
    /// resident (pinning it so an acked hit cannot be evicted before the
    /// warm payload lands) and report hit/miss. Misses are not sticky —
    /// the session's insert payload will make the digest resident.
    pub fn handle_probe(&self, probe: &PrefixProbe) -> PrefixAck {
        let hit = self.prefix_store().attach(probe.request_id, &probe.digest);
        PrefixAck { request_id: probe.request_id, digest: probe.digest, hit }
    }

    /// Extract and RELEASE a migrating session's prefix attachment so the
    /// source worker holds no refcount for it after the handoff (zero-leak
    /// invariant). Returns the digest and prefix length to ride the
    /// `Migrate` frame; `None` when the session holds no attachment.
    pub fn export_prefix(&self, request_id: u64) -> Option<(PrefixDigest, u32)> {
        let mut store = self.prefix_store();
        let digest = store.attachment(request_id)?;
        let len = store.get(&digest).map(|kv| kv.prefix_len as u32);
        store.release(request_id);
        len.map(|l| (digest, l))
    }

    /// Re-attach a migrated session's prefix on this (target) server.
    /// Returns residency: a miss is survivable — the session's next warm
    /// payload draws a typed `PREFIX` reject and is rebuilt as an insert.
    pub fn import_prefix(&self, request_id: u64, digest: &PrefixDigest) -> bool {
        self.prefix_store().attach(request_id, digest)
    }

    /// Map a serve error to its in-band reject code: a typed
    /// [`PrefixMiss`] becomes `reject::PREFIX` (the session rebuilds as
    /// an insert and retransmits); everything else stays `FAILED`.
    pub fn reject_code_for(e: &anyhow::Error) -> u8 {
        if e.downcast_ref::<PrefixMiss>().is_some() {
            reject::PREFIX
        } else {
            reject::FAILED
        }
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.node.weights.cfg
    }

    /// Tokens served over the life of the server (all sessions).
    /// Deprecated shim — the value now lives on the obs counters; prefer
    /// [`CloudServer::export_metrics`] for registry-wide exposition.
    pub fn tokens_generated(&self) -> u64 {
        self.tokens_generated.get()
    }

    /// Tokens served through the stacked decode path (observability for
    /// tests and the engine bench). Deprecated shim over the obs counter.
    pub fn tokens_stacked(&self) -> u64 {
        self.tokens_stacked.get()
    }

    /// Control-plane reconfigurations applied over the life of the
    /// server (observability for tests and the adaptation bench).
    /// Deprecated shim over the obs counter.
    pub fn reconfigs_applied(&self) -> u64 {
        self.reconfigs_applied.get()
    }

    /// Mirror this server's counters into an obs registry (`cloud_*`
    /// counters plus the `prefix_store_*` family).
    pub fn export_metrics(&self, reg: &Registry) {
        reg.counter("cloud_tokens_generated").set(self.tokens_generated.get());
        reg.counter("cloud_tokens_stacked").set(self.tokens_stacked.get());
        reg.counter("cloud_reconfigs_applied").set(self.reconfigs_applied.get());
        reg.publish(&self.prefix_stats());
    }

    /// Live control-plane entries (announced sessions not yet retired).
    /// Observability for the fleet connection-state hygiene test: after a
    /// connection's sweep this must not grow across connect/crash cycles.
    pub fn control_entries(&self) -> usize {
        self.control.lock().expect("control plane poisoned").len()
    }

    /// Live resume-fence entries. These OUTLIVE connections by design
    /// (a delayed duplicate `Resume` from a dead connection must stay
    /// rejectable) and are dropped when the EOS reply is served.
    pub fn resume_entries(&self) -> usize {
        self.resume_epochs.lock().expect("resume fence poisoned").len()
    }

    /// Apply a session's announced transmission settings mid-stream.
    /// Stale epochs (≤ the last applied) are ignored, so duplicated or
    /// reordered control frames cannot roll a session's settings back.
    pub fn apply_reconfig(&self, rc: &Reconfig) {
        let mut control = self.control.lock().expect("control plane poisoned");
        if let Some(prev) = control.get(&rc.request_id) {
            if prev.epoch >= rc.epoch {
                return;
            }
        }
        control.insert(rc.request_id, *rc);
        self.reconfigs_applied.inc();
    }

    /// Hold an arriving payload to its session's announced settings: no
    /// transmitted tensor — the hidden block OR the KV caches that
    /// dominate the payload's bytes — may be quantized at or above the
    /// announced Q̄a. TAB-Q spends one bit on the sign, so a compliant
    /// edge's chosen magnitude bits are always ≤ Q̄a − 1 — the strict
    /// `<` catches even a single-rung violation (an edge still
    /// transmitting at Q̄a = 4 after a 4 → 3 downgrade was announced).
    fn check_control(&self, payload: &SplitPayload) -> Result<()> {
        let control = self.control.lock().expect("control plane poisoned");
        let Some(rc) = control.get(&payload.request_id) else {
            return Ok(());
        };
        anyhow::ensure!(
            payload.hidden.chosen_bits < rc.qa_bits,
            "request {}: payload quantized at {} bits exceeds the announced Q̄a = {}",
            payload.request_id,
            payload.hidden.chosen_bits,
            rc.qa_bits
        );
        if let Some(kv) = &payload.kv {
            for (k, v) in &kv.layers {
                anyhow::ensure!(
                    k.chosen_bits < rc.qa_bits && v.chosen_bits < rc.qa_bits,
                    "request {}: KV block quantized at {} bits exceeds the announced Q̄a = {}",
                    payload.request_id,
                    k.chosen_bits.max(v.chosen_bits),
                    rc.qa_bits
                );
            }
        }
        if let Some(ins) = payload.prefix.as_ref().and_then(|pr| pr.insert.as_ref()) {
            anyhow::ensure!(
                ins.chosen_bits < rc.qa_bits,
                "request {}: prefix block quantized at {} bits exceeds the announced Q̄a = {}",
                payload.request_id,
                ins.chosen_bits,
                rc.qa_bits
            );
        }
        Ok(())
    }

    /// Forget a finished session's control-plane entry (EOS served).
    fn retire_control(&self, request_id: u64, reply: &CloudReply) {
        if reply.token == 0 {
            self.retire_request(request_id);
            self.resume_epochs
                .lock()
                .expect("resume fence poisoned")
                .remove(&request_id);
        }
    }

    /// Admit (or reject) a session's reconnection. The resume epoch must
    /// strictly exceed the highest one accepted for this request — a
    /// delayed duplicate from a dead connection can never re-fence a live
    /// session. On admit, the resume's transmission settings are
    /// re-announced to the control plane (epoch 0, so the session's next
    /// genuine `Reconfig` supersedes it), and the ack echoes the accepted
    /// epoch plus the connection's last answered position when known.
    pub fn admit_resume(
        &self,
        rs: &Resume,
        last_pos: Option<u64>,
    ) -> std::result::Result<ResumeAck, RejectFrame> {
        {
            let mut epochs = self.resume_epochs.lock().expect("resume fence poisoned");
            if let Some(&prev) = epochs.get(&rs.request_id) {
                if rs.epoch <= prev {
                    return Err(RejectFrame {
                        code: reject::STALE_EPOCH,
                        request_id: rs.request_id,
                        message: format!(
                            "resume epoch {} is not above the accepted {prev}",
                            rs.epoch
                        ),
                    });
                }
            }
            epochs.insert(rs.request_id, rs.epoch);
        }
        // Force-insert (not `apply_reconfig`): the reconnecting session's
        // settings must land even if an older connection once announced a
        // higher reconfig epoch for this id.
        self.control.lock().expect("control plane poisoned").insert(
            rs.request_id,
            Reconfig {
                request_id: rs.request_id,
                epoch: 0,
                qa_bits: rs.qa_bits,
                tau: rs.tau,
                include_kv: rs.include_kv,
                budget_cap: Reconfig::NO_BUDGET_CAP,
            },
        );
        Ok(ResumeAck { request_id: rs.request_id, epoch: rs.epoch, last_pos })
    }

    /// Extract and REMOVE a session's cloud-side control state for a
    /// worker-to-worker migration: the announced settings (if any) and
    /// the accepted resume-epoch high-water mark (if any). Removal is the
    /// point — after the handoff the source worker must hold nothing for
    /// this session (zero-leak invariant), and an A→B→A round trip must
    /// re-admit on A without tripping its own stale-epoch fence.
    pub fn export_control(&self, request_id: u64) -> (Option<Reconfig>, Option<u32>) {
        let rc = self.control.lock().expect("control plane poisoned").remove(&request_id);
        let epoch = self
            .resume_epochs
            .lock()
            .expect("resume fence poisoned")
            .remove(&request_id);
        (rc, epoch)
    }

    /// Force-install migrated control settings verbatim. No epoch
    /// comparison: `admit_resume` already fenced the migration's epoch,
    /// and the shipped announcement IS the session's current word — the
    /// target has no older announcement to protect. Deliberately does not
    /// bump `reconfigs_applied`: nothing changed from the session's view.
    pub fn restore_control(&self, rc: &Reconfig) {
        self.control.lock().expect("control plane poisoned").insert(rc.request_id, *rc);
    }

    /// Drop a session's control-plane entry unconditionally. Drivers call
    /// this when a session ends for any non-EOS reason (budget
    /// exhaustion, cancellation, error) and `serve_connection` sweeps the
    /// ids its connection announced — otherwise entries would accumulate
    /// on a long-lived server and a later session reusing the request id
    /// would be held to a dead session's announcement. Also the single
    /// choke point through which prefix refcounts drain: EOS, budget
    /// exhaustion, cancellation, connection sweep and worker death all
    /// funnel here, so none of them can leak a pinned prefix.
    pub fn retire_request(&self, request_id: u64) {
        self.control.lock().expect("control plane poisoned").remove(&request_id);
        self.prefix_store().release(request_id);
    }

    /// Serve one payload. Returns (reply, scaled_compute_seconds).
    pub fn handle(&self, payload: &SplitPayload) -> Result<(CloudReply, f64)> {
        let t0 = Instant::now();
        self.check_control(payload)?;
        let reply = self.serve_payload(payload)?;
        self.retire_control(payload.request_id, &reply);
        self.tokens_generated.inc();
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());
        Ok((reply, compute_s))
    }

    /// Serve one encoded frame: strict decode → dispatch on kind.
    /// Payload frames are served (`handle`) and produce an encoded reply
    /// frame; Reconfig frames update the control plane and produce no
    /// reply (`Ok(None)`). The server's compute seconds ride in the reply
    /// frame's timing prefix, so a remote edge keeps the same `StepStats`
    /// shape as the in-process drivers. This is the unit of work of the
    /// cross-process `splitserve cloud` loop.
    pub fn serve_frame(&self, frame_bytes: &[u8]) -> Result<Option<Vec<u8>>> {
        let (kind, _) = crate::wire::decode_frame(frame_bytes)?;
        match kind {
            FrameKind::Reconfig => {
                let rc = crate::wire::decode_reconfig_frame(frame_bytes)?;
                self.apply_reconfig(&rc);
                Ok(None)
            }
            FrameKind::Payload => {
                let payload = crate::wire::decode_payload_frame(frame_bytes)?;
                let (reply, cloud_s) = self.handle(&payload)?;
                Ok(Some(crate::wire::encode_reply_frame(&reply, cloud_s)))
            }
            FrameKind::Resume => {
                let rs = crate::wire::decode_resume_frame(frame_bytes)?;
                Ok(Some(match self.admit_resume(&rs, None) {
                    Ok(ack) => crate::wire::encode_resume_ack_frame(&ack),
                    Err(rj) => crate::wire::encode_error_frame(&rj),
                }))
            }
            FrameKind::PrefixProbe => {
                let probe = crate::wire::decode_prefix_probe_frame(frame_bytes)?;
                let ack = self.handle_probe(&probe);
                Ok(Some(crate::wire::encode_prefix_ack_frame(&ack)))
            }
            FrameKind::Reply
            | FrameKind::ResumeAck
            | FrameKind::Error
            | FrameKind::Migrate
            | FrameKind::PrefixAck => {
                anyhow::bail!("cloud server received a {kind:?} frame")
            }
        }
    }

    /// Blocking frames-in/frames-out loop over one transport connection;
    /// returns the number of payloads served once the peer hangs up
    /// cleanly at a frame boundary. Control (Reconfig) frames are applied
    /// in stream order and answered with nothing; when the connection
    /// ends (cleanly or not) every announcement it made is retired so a
    /// later connection reusing a request id starts from a clean slate.
    pub fn serve_connection(&self, transport: &mut dyn crate::wire::Transport) -> Result<u64> {
        let mut announced: Vec<u64> = Vec::new();
        let result = self.serve_connection_inner(transport, &mut announced);
        for id in announced {
            self.retire_request(id);
        }
        result
    }

    fn serve_connection_inner(
        &self,
        transport: &mut dyn crate::wire::Transport,
        announced: &mut Vec<u64>,
    ) -> Result<u64> {
        let mut served = 0u64;
        // Per-connection replay fence: last answered position and its
        // encoded reply frame, per request. A duplicated payload (same
        // pos) is answered by replaying the cached frame — idempotent,
        // zero recompute; an EARLIER pos is rejected in-band as stale.
        // Positions only move forward within a connection, so the fence
        // is one entry per request, not a history.
        let mut fence: HashMap<u64, (u64, Vec<u8>)> = HashMap::new();
        while let Some((frame_bytes, _)) = transport.recv_eof()? {
            let (kind, _) = crate::wire::decode_frame(&frame_bytes)?;
            match kind {
                FrameKind::Reconfig => {
                    let rc = crate::wire::decode_reconfig_frame(&frame_bytes)?;
                    self.apply_reconfig(&rc);
                    announced.push(rc.request_id);
                }
                FrameKind::Resume => {
                    let rs = crate::wire::decode_resume_frame(&frame_bytes)?;
                    let last_pos = fence.get(&rs.request_id).map(|(p, _)| *p);
                    match self.admit_resume(&rs, last_pos) {
                        Ok(ack) => {
                            announced.push(rs.request_id);
                            transport.send(&crate::wire::encode_resume_ack_frame(&ack))?;
                        }
                        Err(rj) => transport.send(&crate::wire::encode_error_frame(&rj))?,
                    }
                }
                FrameKind::Payload => {
                    let payload = crate::wire::decode_payload_frame(&frame_bytes)?;
                    let id = payload.request_id;
                    let pos = payload.pos as u64;
                    if let Some((last, cached)) = fence.get(&id) {
                        if pos == *last {
                            transport.send(cached)?;
                            continue;
                        }
                        if pos < *last {
                            transport.send(&crate::wire::encode_error_frame(&RejectFrame {
                                code: reject::STALE_POS,
                                request_id: id,
                                message: format!(
                                    "position {pos} is behind the last answered {last}"
                                ),
                            }))?;
                            continue;
                        }
                    }
                    // A payload that fails to serve (control violation,
                    // inconsistent tensors behind a valid CRC) condemns
                    // only its own request: reject in-band and keep the
                    // connection — other sessions multiplexed on it are
                    // healthy.
                    match self.handle(&payload) {
                        Ok((reply, cloud_s)) => {
                            let reply_frame = crate::wire::encode_reply_frame(&reply, cloud_s);
                            transport.send(&reply_frame)?;
                            served += 1;
                            if reply.token == 0 {
                                fence.remove(&id);
                            } else {
                                fence.insert(id, (pos, reply_frame));
                            }
                        }
                        Err(e) => {
                            transport.send(&crate::wire::encode_error_frame(&RejectFrame {
                                code: Self::reject_code_for(&e),
                                request_id: id,
                                message: format!("{e:#}"),
                            }))?;
                        }
                    }
                }
                FrameKind::PrefixProbe => {
                    let probe = crate::wire::decode_prefix_probe_frame(&frame_bytes)?;
                    let ack = self.handle_probe(&probe);
                    // The probe may have pinned a refcount; sweep it with
                    // the connection like any other announcement.
                    announced.push(probe.request_id);
                    transport.send(&crate::wire::encode_prefix_ack_frame(&ack))?;
                }
                FrameKind::Reply
                | FrameKind::ResumeAck
                | FrameKind::Error
                | FrameKind::Migrate
                | FrameKind::PrefixAck => {
                    anyhow::bail!("cloud server received a {kind:?} frame")
                }
            }
        }
        Ok(served)
    }

    /// Serve one continuous-batching iteration's payloads on this server.
    /// Single-token decode payloads that ship their KV (I_kv = 1) are
    /// stacked into one batched engine call; prefill and I_kv = 0
    /// payloads (full-history recompute) are served individually.
    /// Replies are position-matched to `payloads`; a stacked payload's
    /// per-step compute charge is the batch's measured wall time split
    /// evenly. The returned [`BatchCompute`] tells the serve loop which
    /// part of the wall time was measured serially (model-batchable) vs
    /// already batched for real.
    pub fn handle_batch(
        &self,
        payloads: &[SplitPayload],
    ) -> Result<(Vec<(CloudReply, f64)>, BatchCompute)> {
        let mut replies: Vec<Option<(CloudReply, f64)>> = Vec::with_capacity(payloads.len());
        replies.resize_with(payloads.len(), || None);
        let mut compute = BatchCompute::default();
        let mut stacked: Vec<usize> = Vec::new();
        for (i, p) in payloads.iter().enumerate() {
            if self.stacked && !p.is_prefill && p.kv.is_some() {
                stacked.push(i);
            } else {
                let served = self.handle(p)?;
                compute.solo_s += served.1;
                compute.solo_n += 1;
                replies[i] = Some(served);
            }
        }
        match stacked.len() {
            0 => {}
            1 => {
                let served = self.handle(&payloads[stacked[0]])?;
                compute.solo_s += served.1;
                compute.solo_n += 1;
                replies[stacked[0]] = Some(served);
            }
            _ => {
                let (served, wall_s) = self.handle_stacked(payloads, &stacked)?;
                compute.stacked_s += wall_s;
                compute.stacked_n += served.len();
                for (&i, r) in stacked.iter().zip(served) {
                    replies[i] = Some(r);
                }
            }
        }
        let replies = replies.into_iter().map(|r| r.expect("every payload served")).collect();
        Ok((replies, compute))
    }

    /// Decompress one I_kv = 1 decode payload into (per-layer caches,
    /// hidden row) — the shared prologue of the solo and stacked paths.
    fn decode_inputs(&self, payload: &SplitPayload) -> Result<(Vec<LayerKv>, Vec<f32>)> {
        let cfg = self.cfg();
        let kv_in = payload
            .kv
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("decode payload without KV"))?;
        anyhow::ensure!(
            payload.pos < cfg.max_seq,
            "decode position {} exceeds max_seq {}",
            payload.pos,
            cfg.max_seq
        );
        let caches = kv_in.decompress_with_pool(cfg.max_seq, cfg.kv_width(), &self.scratch)?;
        anyhow::ensure!(
            caches.len() == self.node.layer_range.len(),
            "KV layer count mismatch"
        );
        let h = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
        anyhow::ensure!(h.len() == cfg.d_model, "decode hidden must be one row");
        Ok((caches, h))
    }

    /// Sample + assemble the reply for one decoded row — the shared
    /// epilogue of the solo and stacked paths.
    fn decode_reply(
        payload: &SplitPayload,
        caches: &[LayerKv],
        logits_row: &[f32],
        kvw: usize,
    ) -> CloudReply {
        let token = sample(logits_row, payload.sampling, payload.request_id, payload.pos);
        let pos = payload.pos;
        let new_kv_rows = caches
            .iter()
            .map(|c| {
                (
                    c.k[pos * kvw..(pos + 1) * kvw].to_vec(),
                    c.v[pos * kvw..(pos + 1) * kvw].to_vec(),
                )
            })
            .collect();
        CloudReply {
            request_id: payload.request_id,
            pos: payload.pos as u64,
            token,
            new_kv_rows,
            logits_entropy: sampling::entropy(logits_row),
        }
    }

    /// The stacked fast path: decompress each payload's caches, stack the
    /// hidden rows into (B, d), run ONE batched decode + lm-head call,
    /// then sample and slice out the new KV rows per session. Returns the
    /// position-matched replies and the batch's measured wall seconds.
    fn handle_stacked(
        &self,
        payloads: &[SplitPayload],
        stacked: &[usize],
    ) -> Result<(Vec<(CloudReply, f64)>, f64)> {
        let t0 = Instant::now();
        let cfg = self.cfg().clone();
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        let b = stacked.len();
        let mut caches: Vec<Vec<LayerKv>> = Vec::with_capacity(b);
        let mut hs: Vec<f32> = Vec::with_capacity(b * d);
        let mut positions: Vec<usize> = Vec::with_capacity(b);
        for &i in stacked {
            self.check_control(&payloads[i])?;
            let (c, h) = self.decode_inputs(&payloads[i])?;
            hs.extend_from_slice(&h);
            positions.push(payloads[i].pos);
            caches.push(c);
        }
        {
            let mut cache_refs: Vec<&mut [LayerKv]> =
                caches.iter_mut().map(|c| c.as_mut_slice()).collect();
            self.node.decode_batch(&mut hs, &mut cache_refs, &positions)?;
        }
        let logits = self.node.logits_decode_batch(&hs, b)?;
        self.tokens_generated.add(b as u64);
        self.tokens_stacked.add(b as u64);
        let wall_s = self.profile.scale(t0.elapsed().as_secs_f64());
        let per_payload_s = wall_s / b as f64;
        let out: Vec<(CloudReply, f64)> = stacked
            .iter()
            .enumerate()
            .map(|(bi, &i)| {
                let row = &logits[bi * cfg.vocab..(bi + 1) * cfg.vocab];
                (Self::decode_reply(&payloads[i], &caches[bi], row, kvw), per_payload_s)
            })
            .collect();
        for (bi, &i) in stacked.iter().enumerate() {
            self.retire_control(payloads[i].request_id, &out[bi].0);
        }
        Ok((out, wall_s))
    }

    /// Serve a prefill payload that carries a prefix reference.
    ///
    /// * **Insert** (`pr.insert` present): the payload ships TWO
    ///   independently coded blocks — the prefix rows inside the
    ///   reference and the suffix rows in `payload.hidden`. They are
    ///   decompressed, concatenated and served as a normal full prefill;
    ///   then the back segment's prefix KV rows are published into the
    ///   store under the digest (first insert charges the bytes once; a
    ///   racing duplicate deduplicates to a refcount). The reply carries
    ///   all `w` KV rows, exactly like a cold prefill.
    /// * **Warm** (no insert): only the suffix block was transmitted.
    ///   The stored prefix KV is read (typed [`PrefixMiss`] when absent
    ///   or shape-mismatched — forged and stale tokens land here) and
    ///   the back segment runs a suffix-only prefill against it; the
    ///   suffix hidden rows and logits are bit-identical to the insert
    ///   path's rows at the same positions (pinned by
    ///   `suffix_prefill_is_bit_identical_to_whole_block`), so the
    ///   sampled token stream cannot depend on cache temperature. The
    ///   reply carries only the suffix KV rows; the edge already holds
    ///   the prefix rows in its own cache entry.
    fn serve_prefix_prefill(&self, payload: &SplitPayload, pr: &PrefixRef) -> Result<CloudReply> {
        let cfg = self.cfg().clone();
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        anyhow::ensure!(payload.is_prefill, "prefix reference on a non-prefill payload");
        let wp = pr.prefix_len as usize;
        let w_suf = payload.hidden.rows;
        let w = wp + w_suf;
        anyhow::ensure!(wp > 0, "empty prefix reference");
        anyhow::ensure!(w <= cfg.prefill_len, "prefix + suffix exceed prefill width");
        anyhow::ensure!(
            payload.pos >= wp && payload.pos < w,
            "position {} outside the suffix rows [{wp}, {w})",
            payload.pos
        );
        if let Some(ins) = &pr.insert {
            anyhow::ensure!(ins.rows == wp, "prefix block rows disagree with the reference");
            let mut h = self.scratch.with(|s| ins.decompress_with(s))?;
            let h_suf = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            h.extend_from_slice(&h_suf);
            h.resize(cfg.prefill_len * d, 0.0); // zero-pad to static width
            let (h_out, kv_rows) = self.node.prefill(&h)?;
            let logits = self.node.logits_prefill(&h_out)?;
            let row = &logits[payload.pos * cfg.vocab..(payload.pos + 1) * cfg.vocab];
            let token = sample(row, payload.sampling, payload.request_id, payload.pos);
            let prefix_kv = PrefixKv {
                prefix_len: wp,
                kv_width: kvw,
                layers: kv_rows
                    .iter()
                    .map(|(k, v)| (k[..wp * kvw].to_vec(), v[..wp * kvw].to_vec()))
                    .collect(),
            };
            self.prefix_store().insert(payload.request_id, &pr.digest, prefix_kv);
            let new_kv_rows = kv_rows
                .into_iter()
                .map(|(k, v)| (k[..w * kvw].to_vec(), v[..w * kvw].to_vec()))
                .collect();
            Ok(CloudReply {
                request_id: payload.request_id,
                pos: payload.pos as u64,
                token,
                new_kv_rows,
                logits_entropy: sampling::entropy(row),
            })
        } else {
            let prefix_layers: Vec<(Vec<f32>, Vec<f32>)> = {
                let mut store = self.prefix_store();
                // A warm payload normally arrives pre-attached by its
                // probe; attach here too so a (legitimately) probe-less
                // in-process driver still pins and retires cleanly.
                store.attach(payload.request_id, &pr.digest);
                let Some(kv) = store.get(&pr.digest) else {
                    return Err(PrefixMiss {
                        request_id: payload.request_id,
                        message: format!("digest not resident (prefix_len {wp})"),
                    }
                    .into());
                };
                if kv.prefix_len != wp || kv.kv_width != kvw {
                    return Err(PrefixMiss {
                        request_id: payload.request_id,
                        message: format!(
                            "stored shape ({} rows, width {}) disagrees with the reference \
                             ({wp} rows, width {kvw})",
                            kv.prefix_len, kv.kv_width
                        ),
                    }
                    .into());
                }
                kv.layers.clone()
            };
            let mut h_suf = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            h_suf.resize((cfg.prefill_len - wp) * d, 0.0); // zero-pad to static width
            let (h_out, kv_suf) = self.node.prefill_suffix(&h_suf, wp, &prefix_layers)?;
            let logits = self.node.logits_rows(&h_out, cfg.prefill_len - wp)?;
            let local = payload.pos - wp; // suffix-local sample row
            let row = &logits[local * cfg.vocab..(local + 1) * cfg.vocab];
            let token = sample(row, payload.sampling, payload.request_id, payload.pos);
            // Suffix rows only: the edge's cache entry supplies [0, wp).
            let new_kv_rows = kv_suf
                .into_iter()
                .map(|(k, v)| (k[..w_suf * kvw].to_vec(), v[..w_suf * kvw].to_vec()))
                .collect();
            Ok(CloudReply {
                request_id: payload.request_id,
                pos: payload.pos as u64,
                token,
                new_kv_rows,
                logits_entropy: sampling::entropy(row),
            })
        }
    }

    fn serve_payload(&self, payload: &SplitPayload) -> Result<CloudReply> {
        if let Some(pr) = &payload.prefix {
            return self.serve_prefix_prefill(payload, pr);
        }
        let cfg = self.cfg().clone();
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        let reply = if payload.is_prefill || payload.kv.is_none() {
            // Prefill, or I_kv = 0 decode (full hidden history): run the
            // back segment prefill-style over all rows.
            let w = payload.hidden.rows;
            anyhow::ensure!(w <= cfg.prefill_len, "hidden block exceeds prefill width");
            anyhow::ensure!(
                payload.pos < w,
                "position {} exceeds the {w} transmitted rows",
                payload.pos
            );
            let mut h = self.scratch.with(|s| payload.hidden.decompress_with(s))?;
            h.resize(cfg.prefill_len * d, 0.0); // zero-pad to static width
            let (h_out, kv_rows) = self.node.prefill(&h)?;
            let logits = self.node.logits_prefill(&h_out)?;
            let row = &logits[payload.pos * cfg.vocab..(payload.pos + 1) * cfg.vocab];
            let token = sample(row, payload.sampling, payload.request_id, payload.pos);
            // Reply with the back-layer KV rows for all processed tokens
            // (prefill only — I_kv=0 decode keeps the cloud stateless and
            // the edge will resend history anyway).
            let new_kv_rows = if payload.is_prefill {
                kv_rows
                    .into_iter()
                    .map(|(k, v)| (k[..w * kvw].to_vec(), v[..w * kvw].to_vec()))
                    .collect()
            } else {
                Vec::new()
            };
            CloudReply {
                request_id: payload.request_id,
                pos: payload.pos as u64,
                token,
                new_kv_rows,
                logits_entropy: sampling::entropy(row),
            }
        } else {
            // I_kv = 1 decode: reconstruct the shipped caches, run one
            // decode step (in place — the caches live only for this
            // call), return the new KV row per layer.
            let (mut caches, h) = self.decode_inputs(payload)?;
            let h_out = self.node.decode(&h, &mut caches, payload.pos)?;
            let logits = self.node.logits_decode(&h_out)?;
            Self::decode_reply(payload, &caches, &logits, kvw)
        };
        Ok(reply)
    }
}
