//! Continuous (iteration-level) dynamic batcher — the server-side batching
//! policy used by the multi-device simulation. Requests join a FIFO queue;
//! the active set admits up to `max_batch` requests; every iteration serves
//! one token to each active request (Orca-style continuous batching).

#[derive(Clone, Debug, PartialEq)]
pub struct BatchItem {
    pub request_id: u64,
    pub tokens_remaining: usize,
    /// True until the (one-time) prefill cost has been charged.
    pub needs_prefill: bool,
}

#[derive(Clone, Debug)]
pub struct BatcherParams {
    pub max_batch: usize,
    /// Per-token service time at batch size 1.
    pub base_token_s: f64,
    /// Marginal cost of each extra batch member (sub-linear batching:
    /// iteration time = base * (1 + overhead * (b - 1))).
    pub batch_overhead: f64,
    /// One-time prefill service charge on admission.
    pub prefill_s: f64,
    /// Congestion term: extra seconds per iteration per waiting request
    /// (queueing/memory-management pressure — the paper's "nonlinear
    /// growth" under high concurrency).
    pub congestion_s_per_waiter: f64,
}

impl Default for BatcherParams {
    fn default() -> Self {
        BatcherParams {
            max_batch: 8,
            base_token_s: 0.02,
            batch_overhead: 0.12,
            prefill_s: 0.08,
            congestion_s_per_waiter: 0.002,
        }
    }
}

#[derive(Default, Debug)]
pub struct DynamicBatcher {
    pub queue: std::collections::VecDeque<BatchItem>,
    pub active: Vec<BatchItem>,
}

impl DynamicBatcher {
    pub fn submit(&mut self, item: BatchItem) {
        self.queue.push_back(item);
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// Admit queued requests into free active slots; returns the prefill
    /// charge incurred this admission round.
    pub fn admit(&mut self, p: &BatcherParams) -> f64 {
        let mut prefill_cost = 0.0;
        while self.active.len() < p.max_batch {
            let Some(mut item) = self.queue.pop_front() else { break };
            if item.needs_prefill {
                prefill_cost += p.prefill_s;
                item.needs_prefill = false;
            }
            self.active.push(item);
        }
        prefill_cost
    }

    /// Serve one token to every active request. Returns (iteration_seconds,
    /// finished request ids). Iteration time reflects batch size and queue
    /// congestion.
    pub fn iterate(&mut self, p: &BatcherParams) -> (f64, Vec<u64>) {
        if self.active.is_empty() {
            return (0.0, vec![]);
        }
        let b = self.active.len();
        let iter_s = p.base_token_s * (1.0 + p.batch_overhead * (b as f64 - 1.0))
            + p.congestion_s_per_waiter * self.queue.len() as f64;
        let mut finished = Vec::new();
        self.active.retain_mut(|item| {
            item.tokens_remaining -= 1;
            if item.tokens_remaining == 0 {
                finished.push(item.request_id);
                false
            } else {
                true
            }
        });
        (iter_s, finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(id: u64, tokens: usize) -> BatchItem {
        BatchItem { request_id: id, tokens_remaining: tokens, needs_prefill: true }
    }

    #[test]
    fn admits_up_to_max_batch() {
        let p = BatcherParams { max_batch: 2, ..Default::default() };
        let mut b = DynamicBatcher::default();
        for i in 0..5 {
            b.submit(item(i, 3));
        }
        let prefill = b.admit(&p);
        assert_eq!(b.active.len(), 2);
        assert_eq!(b.queue.len(), 3);
        assert!((prefill - 2.0 * p.prefill_s).abs() < 1e-12);
    }

    #[test]
    fn iteration_time_grows_with_batch_and_queue() {
        let p = BatcherParams::default();
        let mut one = DynamicBatcher::default();
        one.submit(item(0, 10));
        one.admit(&p);
        let (t1, _) = one.iterate(&p);

        let mut many = DynamicBatcher::default();
        for i in 0..20 {
            many.submit(item(i, 10));
        }
        many.admit(&p);
        let (t8, _) = many.iterate(&p);
        assert!(t8 > t1, "batched iteration costs more in total ({t8} vs {t1})");
        // but less per token:
        assert!(t8 / 8.0 < t1, "batching must be sub-linear");
    }

    #[test]
    fn finishes_and_frees_slots() {
        let p = BatcherParams { max_batch: 1, ..Default::default() };
        let mut b = DynamicBatcher::default();
        b.submit(item(7, 1));
        b.submit(item(8, 1));
        b.admit(&p);
        let (_, fin) = b.iterate(&p);
        assert_eq!(fin, vec![7]);
        b.admit(&p);
        let (_, fin) = b.iterate(&p);
        assert_eq!(fin, vec![8]);
        assert!(b.is_idle());
    }

    #[test]
    fn prefill_charged_once() {
        let p = BatcherParams { max_batch: 1, ..Default::default() };
        let mut b = DynamicBatcher::default();
        b.submit(item(1, 2));
        assert!(b.admit(&p) > 0.0);
        b.iterate(&p);
        assert_eq!(b.admit(&p), 0.0, "no new admissions, no prefill charge");
    }
}
