//! Token selection for the cloud decode path.
//!
//! The cloud is stateless (paper Fig. 1(c)), so the sampling policy must
//! travel with the payload: `SamplingSpec` is `Copy`, rides on every
//! `SplitPayload`, and the seeded draw is keyed by (seed, request, pos) so
//! the sampled token never depends on how requests are interleaved on the
//! shared server — a hard requirement for the many-to-one serve loop,
//! where decode iterations of different sessions are batched together.

use crate::util::rng::Rng;

/// How the cloud turns a logits row into the next token.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum SamplingSpec {
    /// Deterministic argmax decode (the paper's evaluation setting).
    #[default]
    Greedy,
    /// Seeded temperature/top-k sampling: softmax over the `k` largest
    /// logits at `temperature`, drawn from a (seed, request, pos)-keyed
    /// stream. `temperature <= 0` or `k <= 1` degrades to greedy.
    TopK { k: usize, temperature: f32, seed: u64 },
}

impl SamplingSpec {
    /// Extra wire bytes this spec adds to a payload: greedy is a flag bit
    /// in the payload's fixed header; top-k appends k (u16), temperature
    /// (f32) and seed (u64).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            SamplingSpec::Greedy => 0,
            SamplingSpec::TopK { .. } => 14,
        }
    }
}

/// Index of the largest element (first on ties; 0 for an empty slice).
pub fn argmax(v: &[f32]) -> u32 {
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (i, &x) in v.iter().enumerate() {
        if x > best.0 {
            best = (x, i);
        }
    }
    best.1 as u32
}

/// Shannon entropy (nats) of softmax(logits) — the early-exit confidence
/// signal carried on every `CloudReply`.
pub fn entropy(logits: &[f32]) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter()
        .map(|&e| {
            let p = e / z;
            if p > 0.0 {
                -p * p.ln()
            } else {
                0.0
            }
        })
        .sum()
}

/// Sample one token from a logits row under `spec`. Deterministic in
/// (logits, spec, request_id, pos) — scheduling order cannot change it.
pub fn sample(logits: &[f32], spec: SamplingSpec, request_id: u64, pos: usize) -> u32 {
    match spec {
        SamplingSpec::Greedy => argmax(logits),
        SamplingSpec::TopK { k, temperature, seed } => {
            if temperature <= 0.0 || k <= 1 || logits.len() <= 1 {
                return argmax(logits);
            }
            let k = k.min(logits.len());
            // Short-list the k largest logits in O(V) (ties broken by
            // index so the candidate set is deterministic). One index
            // buffer is the only allocation; the softmax weights are
            // streamed, never materialized.
            // total_cmp: a total order even if a quantization overflow
            // ever produces NaN logits (an Equal-on-NaN comparator would
            // panic std's sort/select as inconsistent).
            let desc = |a: &usize, b: &usize| logits[*b].total_cmp(&logits[*a]).then(a.cmp(b));
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, desc);
                idx.truncate(k);
            }
            idx.sort_unstable_by(desc);
            let m = logits[idx[0]]; // sorted descending: the shortlist max
            let w = |i: usize| (((logits[i] - m) / temperature) as f64).exp();
            let z: f64 = idx.iter().map(|&i| w(i)).sum();
            // Position-keyed stream: one fresh generator per (seed,
            // request, pos) triple, independent of draw order elsewhere.
            let mut rng = Rng::new(
                seed ^ request_id.rotate_left(32)
                    ^ (pos as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let u = rng.f64() * z;
            let mut acc = 0.0f64;
            for &i in &idx {
                acc += w(i);
                if u < acc {
                    return i as u32;
                }
            }
            idx[idx.len() - 1] as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits() -> Vec<f32> {
        vec![0.1, 2.5, -1.0, 2.4, 0.0, 1.9, -3.0, 0.7]
    }

    #[test]
    fn greedy_is_argmax() {
        let l = logits();
        assert_eq!(sample(&l, SamplingSpec::Greedy, 1, 0), argmax(&l));
        assert_eq!(argmax(&l), 1);
    }

    #[test]
    fn zero_temperature_and_k1_degrade_to_greedy() {
        let l = logits();
        let t0 = SamplingSpec::TopK { k: 4, temperature: 0.0, seed: 9 };
        let k1 = SamplingSpec::TopK { k: 1, temperature: 1.0, seed: 9 };
        assert_eq!(sample(&l, t0, 1, 0), argmax(&l));
        assert_eq!(sample(&l, k1, 1, 0), argmax(&l));
    }

    #[test]
    fn topk_stays_within_shortlist() {
        let l = logits();
        let spec = SamplingSpec::TopK { k: 3, temperature: 1.5, seed: 42 };
        // top-3 by logit: indices 1 (2.5), 3 (2.4), 5 (1.9)
        for pos in 0..200 {
            let t = sample(&l, spec, 7, pos);
            assert!([1u32, 3, 5].contains(&t), "token {t} outside top-k");
        }
    }

    #[test]
    fn topk_deterministic_per_key_and_varies_with_pos() {
        let l = logits();
        let spec = SamplingSpec::TopK { k: 3, temperature: 1.5, seed: 42 };
        let a: Vec<u32> = (0..64).map(|p| sample(&l, spec, 7, p)).collect();
        let b: Vec<u32> = (0..64).map(|p| sample(&l, spec, 7, p)).collect();
        assert_eq!(a, b, "same (seed, request, pos) must reproduce");
        let other_req: Vec<u32> = (0..64).map(|p| sample(&l, spec, 8, p)).collect();
        assert_ne!(a, other_req, "request id must decorrelate streams");
        // at this temperature the draw must actually mix over positions
        let mut seen = a.clone();
        seen.sort_unstable();
        seen.dedup();
        assert!(seen.len() > 1, "temperature sampling never varied");
    }

    #[test]
    fn entropy_peaks_on_uniform() {
        let flat = vec![1.0f32; 8];
        let peaked = vec![10.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert!(entropy(&flat) > entropy(&peaked));
        assert!((entropy(&flat) - (8f32).ln()).abs() < 1e-4);
    }
}
