//! L3 coordinator — the paper's system contribution: edge/cloud split
//! serving with OPSC front segments, two-stage intermediate compression on
//! the wire, a stateless cloud, dynamic batching, routing, and the
//! Algorithm-2 early-exit controller on the decode loop.

pub mod batcher;
pub mod builder;
pub mod cloud;
pub mod edge;
pub mod pipeline;
pub mod profile;
pub mod protocol;
pub mod request;
pub mod router;
pub mod sim;

pub use batcher::{BatcherParams, DynamicBatcher};
pub use builder::{build_pipeline, DeploymentSpec};
pub use cloud::CloudServer;
pub use edge::{EdgeDevice, EdgeRequestState};
pub use pipeline::SplitPipeline;
pub use profile::DeviceProfile;
pub use protocol::{CompressedKv, CompressedTensor, CompressionConfig, SplitPayload};
pub use request::{GenerationResult, Request, StepStats};
pub use router::{RouteDecision, Router};
pub use sim::{simulate, Deployment, SimOutcome, SimWorkload};
