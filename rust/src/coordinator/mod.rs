//! L3 coordinator — the paper's system contribution: edge/cloud split
//! serving with OPSC front segments, two-stage intermediate compression on
//! the wire, a stateless cloud, dynamic batching, routing, and the
//! Algorithm-2 early-exit controller on the decode loop.
//!
//! The request path is a sans-IO state machine (`session`) with two
//! drivers: `pipeline` (one blocking session) and `serve_loop` (N
//! interleaved sessions sharing one `CloudServer` with continuous
//! batching). `sim` stays the closed-form fast path for capacity planning.
//! The serve loop optionally carries the online adaptive control plane
//! (`crate::adapt`): link telemetry → Eq. 8 re-planning → per-session
//! `Reconfig` frames applied mid-stream by sessions and the cloud alike.

pub mod batcher;
pub mod builder;
pub mod cloud;
pub mod edge;
pub mod pipeline;
pub mod profile;
pub mod protocol;
pub mod request;
pub mod router;
pub mod sampling;
pub mod serve_loop;
pub mod session;
pub mod sim;
pub mod snapshot;

pub use batcher::{BatcherParams, DynamicBatcher};
pub use builder::{build_pipeline, build_serve_loop, DeploymentSpec, ServeSpec};
pub use cloud::{BatchCompute, CloudServer, PrefixMiss};
pub use edge::{EdgeDevice, EdgeRequestState, PrefixDecision, ProbeOutcome};
pub use pipeline::{EdgeClient, RetryPolicy, SplitPipeline};
pub use profile::DeviceProfile;
pub use protocol::{
    reject, CloudReply, CompressedKv, CompressedTensor, CompressionConfig, MigrateState,
    PrefixAck, PrefixProbe, PrefixRef, RejectFrame, Resume, ResumeAck, SplitPayload,
};
pub use request::{GenerationResult, Request, StepStats};
pub use router::{RouteDecision, Router};
pub use sampling::SamplingSpec;
pub use serve_loop::{EdgeEndpoint, ServeLoop, ServeReport, TokenControl};
pub use session::{Session, SessionAction, SessionPhase};
pub use sim::{simulate, Deployment, SimOutcome, SimWorkload};
pub use snapshot::{SessionSnapshot, StateSnapshot};
