//! Sans-IO session state machine — one generation request as a pure
//! state-transition object with NO knowledge of links, servers or clocks.
//!
//! The session owns the per-request edge state (`EdgeRequestState`) and the
//! Algorithm-2 escalation ladder, and exposes exactly two transitions:
//!
//!   * [`Session::poll`] — advance until the session either needs IO
//!     (`SessionAction::Transmit`: the caller must deliver the payload to a
//!     cloud server), is blocked on IO it already requested
//!     (`SessionAction::Yield`), or is finished (`SessionAction::Finished`).
//!   * [`Session::on_reply`] — feed back the cloud's reply plus the link
//!     outcomes the driver measured; the session records `StepStats` and
//!     becomes pollable again.
//!
//! Because all IO is pushed to the caller, the same state machine serves
//! both drivers: `SplitPipeline::generate` (one session, blocking) and
//! `ServeLoop` (N interleaved sessions, one shared `CloudServer` that
//! stacks same-iteration decode payloads into one batched engine call).
//! Stacking is invisible here — the cloud is stateless and sampling is
//! (seed, request, pos)-keyed, so a session's token stream is identical
//! however its payloads are grouped. Phases:
//!
//! ```text
//! NeedPrefill ──poll──▶ AwaitingReply ──on_reply──▶ ReadyToDecode
//!                  ▲                                     │ poll
//!                  └─────────────────────────────────────┤
//!                                                        ▼
//!                                                 Done / Cancelled
//! ```

use anyhow::Result;

use super::edge::{EdgeDevice, EdgeRequestState, PrefixDecision};
use super::protocol::{CloudReply, SplitPayload};
use super::request::{GenerationResult, Request, StepStats};
use super::snapshot::{SessionSnapshot, StateSnapshot};
use crate::adapt::Reconfig;
use crate::channel::TransferOutcome;
use crate::planner::{EarlyExitController, ExitDecision, TxSettings};
use crate::runtime::LayerKv;

/// Where the session is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionPhase {
    /// Created; the next `poll` runs the edge prefill.
    NeedPrefill,
    /// A payload is in flight; waiting for `on_reply`.
    AwaitingReply,
    /// A reply has been absorbed; the next `poll` commits the token and
    /// runs the next decode step (or finishes).
    ReadyToDecode,
    /// Generation completed (EOS, budget, cache limit, or early exit).
    Done,
    /// Torn down mid-stream by the driver (or failed).
    Cancelled,
}

/// What the driver must do next for this session.
#[derive(Debug)]
pub enum SessionAction {
    /// Deliver this payload to the cloud, then call `on_reply` with the
    /// reply and the measured link outcomes.
    Transmit(SplitPayload),
    /// Nothing to do — a transmission is already in flight.
    Yield,
    /// Terminal; collect the result with `into_result`.
    Finished,
}

/// Bookkeeping for the transmission currently in flight: everything
/// `on_reply` needs to finish the step's `StepStats`.
#[derive(Clone, Copy, Debug)]
struct PendingTx {
    edge_s: f64,
    chosen_bits: u32,
    kv_transmitted: bool,
    is_prefill: bool,
    pos: usize,
}

pub struct Session {
    request: Request,
    phase: SessionPhase,
    /// Current transmission settings (mutated by Algorithm-2 escalations
    /// and by control-plane reconfigurations).
    settings: TxSettings,
    /// TS threshold override installed by the last reconfiguration
    /// (None = the edge device's configured τ).
    tau_override: Option<f32>,
    controller: Option<EarlyExitController>,
    /// Edge-held request state; None until prefill runs.
    state: Option<EdgeRequestState>,
    /// Token produced by the last reply, committed on the next poll.
    next_token: u32,
    /// Decode budget remaining (max_new_tokens countdown).
    budget: usize,
    /// True once a decode step has been served with I_kv = 0: the cloud
    /// returned no KV rows for it, so the edge-held cloud-layer caches
    /// are missing those positions and must never be shipped again.
    cloud_kv_stale: bool,
    /// Resumption epoch: bumped on every reconnect-and-resume of this
    /// session, so the cloud can fence traffic from dead connections.
    /// Survives snapshot/restore.
    resume_epoch: u32,
    /// How the prefill engages the prefix cache (Off / Insert / Warm).
    /// Set by the driver before the first poll (after the probe
    /// handshake, for Warm); only consulted at prefill time, so it is
    /// deliberately NOT snapshotted — a restored mid-stream session has
    /// no prefill left to cache.
    prefix_decision: PrefixDecision,
    pending: Option<PendingTx>,
    result: GenerationResult,
}

impl Session {
    /// New session with explicit initial transmission settings.
    pub fn new(
        request: Request,
        settings: TxSettings,
        controller: Option<EarlyExitController>,
    ) -> Session {
        let result = GenerationResult { request_id: request.id, ..Default::default() };
        let budget = request.max_new_tokens;
        Session {
            request,
            phase: SessionPhase::NeedPrefill,
            settings,
            tau_override: None,
            controller,
            state: None,
            next_token: 0,
            budget,
            cloud_kv_stale: false,
            resume_epoch: 0,
            prefix_decision: PrefixDecision::Off,
            pending: None,
            result,
        }
    }

    /// New session whose initial settings follow the edge device's
    /// configured compression (the `SplitPipeline::generate` defaults).
    pub fn for_edge(
        request: Request,
        edge: &EdgeDevice,
        controller: Option<EarlyExitController>,
    ) -> Session {
        let settings = TxSettings { qa_bits: edge.compression.q_bar, include_kv: true };
        Session::new(request, settings, controller)
    }

    pub fn request_id(&self) -> u64 {
        self.request.id
    }

    pub fn phase(&self) -> SessionPhase {
        self.phase
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self.phase, SessionPhase::Done | SessionPhase::Cancelled)
    }

    pub fn is_cancelled(&self) -> bool {
        self.phase == SessionPhase::Cancelled
    }

    /// Tokens committed so far (for streaming drivers).
    pub fn tokens(&self) -> &[u32] {
        &self.result.tokens
    }

    /// Result accumulated so far (complete once the session is terminal).
    pub fn result(&self) -> &GenerationResult {
        &self.result
    }

    pub fn into_result(self) -> GenerationResult {
        self.result
    }

    /// Edge compute seconds of the transmission currently in flight (for
    /// the serve loop's iteration clock).
    pub fn pending_edge_s(&self) -> Option<f64> {
        self.pending.as_ref().map(|p| p.edge_s)
    }

    /// Transmission settings currently in force.
    pub fn settings(&self) -> TxSettings {
        self.settings
    }

    /// Tokens of prompt + generation held so far (None before prefill).
    pub fn seq_len(&self) -> Option<usize> {
        self.state.as_ref().map(|s| s.seq_len())
    }

    /// Decode-token budget still unspent.
    pub fn remaining_budget(&self) -> usize {
        self.budget
    }

    /// True once the edge-held cloud-KV copy is stale (a step was served
    /// statelessly) — the session can never ship KV again.
    pub fn cloud_kv_stale(&self) -> bool {
        self.cloud_kv_stale
    }

    /// Current resumption epoch (bumped per reconnect-and-resume).
    pub fn resume_epoch(&self) -> u32 {
        self.resume_epoch
    }

    /// Bump and return the resumption epoch — called once per
    /// reconnect-and-resume so the cloud can fence the dead connection's
    /// stragglers.
    pub fn bump_resume_epoch(&mut self) -> u32 {
        self.resume_epoch += 1;
        self.resume_epoch
    }

    /// How the prefill will engage (or engaged) the prefix cache.
    pub fn prefix_decision(&self) -> PrefixDecision {
        self.prefix_decision
    }

    /// Install the driver's prefix decision. Must be called before the
    /// prefill polls; for `Warm` the driver is expected to have completed
    /// the probe handshake (a hit-acked digest), downgrading to `Insert`
    /// on a probe miss.
    pub fn set_prefix_decision(&mut self, decision: PrefixDecision) {
        self.prefix_decision = decision;
    }

    /// Recover from an in-band `PREFIX` reject: the cloud could not
    /// honor the warm cache token (evicted between ack and payload,
    /// migrated away, or stale). Rebuild the in-flight prefill as a full
    /// insert payload — recompressed deterministically from the edge
    /// state, so its bytes equal a cold insert's — and return it for
    /// retransmission. The session stays `AwaitingReply` for the same
    /// position, and the decision is downgraded so the eventual reply is
    /// absorbed as an insert (full KV rows).
    pub fn rebuild_prefill_as_insert(&mut self, edge: &EdgeDevice) -> Result<SplitPayload> {
        let pending = self
            .pending
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("PREFIX reject with nothing in flight"))?;
        anyhow::ensure!(pending.is_prefill, "PREFIX reject on a decode step");
        let Some((digest, prefix_len)) = self.prefix_decision.reference() else {
            anyhow::bail!("PREFIX reject but the session holds no prefix decision");
        };
        let state = self.state.as_ref().expect("reject before prefill");
        let mut payload = edge.rebuild_prefill_as_insert(state, &digest, prefix_len)?;
        payload.sampling = self.request.sampling;
        pending.chosen_bits = payload.hidden.chosen_bits;
        self.prefix_decision = PrefixDecision::Insert { digest, prefix_len };
        Ok(payload)
    }

    /// TS threshold currently in force: the device's configured τ unless
    /// a reconfiguration overrode it (what a `Resume` re-announces).
    pub fn current_tau(&self, edge: &EdgeDevice) -> f32 {
        self.tau_override.unwrap_or(edge.compression.tau)
    }

    /// Position of the transmission currently in flight, if any. An
    /// in-flight step's edge compute already ran and its effects (token
    /// push, history append) already live in the request state, so
    /// recovery after a wire failure retransmits the SAME payload (see
    /// `EdgeClient`) — the session keeps waiting for that position's
    /// reply rather than re-polling.
    pub fn pending_pos(&self) -> Option<usize> {
        self.pending.as_ref().map(|p| p.pos)
    }

    /// Apply a control-plane reconfiguration: new (τ, Q̄a, I_kv) take
    /// effect from the next decode step; a budget cap shrinks (never
    /// grows) the remaining token budget L. No-op on a terminal session.
    /// I_kv = 0 is taken as a preference — `poll` still reverts to KV
    /// shipping whenever the sequence outgrows the prefill width — and
    /// once a session has served a step statelessly its cloud-KV copy is
    /// stale (the cloud returned no rows for it), so an I_kv = 1 upgrade
    /// is refused: the session stays on full-history payloads, which the
    /// controller only ever commits to for horizons the prefill width
    /// can serve end to end.
    pub fn apply_reconfig(&mut self, rc: &Reconfig) {
        if self.is_terminal() {
            return;
        }
        self.settings.qa_bits = rc.qa_bits;
        self.settings.include_kv = rc.include_kv && !self.cloud_kv_stale;
        self.tau_override = Some(rc.tau);
        if rc.budget_cap != Reconfig::NO_BUDGET_CAP {
            self.budget = self.budget.min(rc.budget_cap as usize);
        }
        self.result.reconfigs += 1;
    }

    /// Tear the session down mid-stream. Idempotent; a no-op once Done.
    pub fn cancel(&mut self) {
        if self.phase != SessionPhase::Done {
            self.result.final_settings = Some(self.settings);
            self.pending = None;
            self.phase = SessionPhase::Cancelled;
        }
    }

    fn finish(&mut self) -> SessionAction {
        self.result.final_settings = Some(self.settings);
        self.phase = SessionPhase::Done;
        SessionAction::Finished
    }

    /// Advance the state machine. Errors (e.g. empty prompt) leave the
    /// session Cancelled so loop drivers can drop it cleanly; single-
    /// session drivers may just propagate.
    pub fn poll(&mut self, edge: &EdgeDevice) -> Result<SessionAction> {
        let r = match self.phase {
            SessionPhase::Done | SessionPhase::Cancelled => return Ok(SessionAction::Finished),
            SessionPhase::AwaitingReply => return Ok(SessionAction::Yield),
            SessionPhase::NeedPrefill => self.poll_prefill(edge),
            SessionPhase::ReadyToDecode => self.poll_decode(edge),
        };
        if r.is_err() {
            self.cancel();
        }
        r
    }

    fn poll_prefill(&mut self, edge: &EdgeDevice) -> Result<SessionAction> {
        let (mut payload, state, edge_s) =
            edge.prefill_ex(self.request.id, &self.request.prompt, self.prefix_decision)?;
        payload.sampling = self.request.sampling;
        self.pending = Some(PendingTx {
            edge_s,
            chosen_bits: payload.hidden.chosen_bits,
            kv_transmitted: false,
            is_prefill: true,
            pos: payload.pos,
        });
        self.state = Some(state);
        self.phase = SessionPhase::AwaitingReply;
        Ok(SessionAction::Transmit(payload))
    }

    fn poll_decode(&mut self, edge: &EdgeDevice) -> Result<SessionAction> {
        if self.budget == 0 {
            return Ok(self.finish());
        }
        // Commit the token the last reply produced.
        let token = self.next_token;
        self.result.tokens.push(token);
        self.budget -= 1;
        if token == 0 || self.budget == 0 {
            return Ok(self.finish()); // EOS or budget exhausted
        }
        let max_seq = edge.node.weights.cfg.max_seq;
        {
            let state = self.state.as_ref().expect("decode before prefill");
            if state.seq_len() + 1 >= max_seq {
                return Ok(self.finish()); // static KV cache full
            }
        }
        // An earlier escalation to I_kv = 0 stops being feasible once the
        // sequence outgrows the prefill width (the cloud can no longer
        // recompute from scratch) — revert to shipping KV rather than
        // letting decode_step reject the request; the controller may
        // still re-escalate the bit budget below. If the cloud-KV copy
        // went stale while stateless, reverting would ship caches missing
        // those positions and decode silently wrong tokens — end the
        // request instead (the dropped remainder is reported).
        let prefill_len = edge.node.weights.cfg.prefill_len;
        let next_len = self.state.as_ref().expect("decode before prefill").seq_len() + 1;
        if !self.settings.include_kv && next_len > prefill_len {
            if self.cloud_kv_stale {
                self.result.tokens_dropped = self.budget;
                return Ok(self.finish());
            }
            self.settings.include_kv = true;
        }
        let state = self.state.as_mut().expect("decode before prefill");
        // Edge compute + provisional payload under current settings.
        let (mut payload, edge_s) = edge.decode_step(
            state,
            token,
            self.settings.include_kv,
            Some(self.settings.qa_bits),
            self.tau_override,
        )?;

        // Algorithm 2, folded into the transition: check the deadline,
        // escalate (possibly rebuilding the payload) or exit early.
        if let Some(ctrl) = self.controller {
            let decision = {
                let state_ref: &EdgeRequestState = state;
                let oracle =
                    |s: TxSettings| edge.payload_size_probe(state_ref, s).bytes();
                ctrl.decide(edge_s, self.settings, &oracle)
            };
            match decision {
                ExitDecision::Proceed { .. } => {}
                ExitDecision::Escalate { settings, .. } => {
                    self.settings = settings;
                    payload = edge.rebuild_payload(state, settings, self.tau_override)?;
                }
                ExitDecision::ReduceTokens { tokens_to_drop, .. } => {
                    self.result.tokens_dropped = self.budget.min(tokens_to_drop);
                    return Ok(self.finish()); // early exit: stop generating
                }
            }
        }
        payload.sampling = self.request.sampling;
        self.pending = Some(PendingTx {
            edge_s,
            chosen_bits: payload.hidden.chosen_bits,
            kv_transmitted: self.settings.include_kv,
            is_prefill: false,
            pos: payload.pos,
        });
        self.phase = SessionPhase::AwaitingReply;
        Ok(SessionAction::Transmit(payload))
    }

    /// Feed back the cloud's reply for the in-flight transmission, plus
    /// the uplink/downlink outcomes the driver measured. Ignored (stray
    /// reply) if the session is terminal or nothing is in flight.
    ///
    /// The reply's identity is verified against the in-flight
    /// transmission: a reply for another request, or for a position other
    /// than the one awaiting an answer (a duplicated or stale frame), is
    /// a typed error that leaves the session's state — including the
    /// in-flight transmission — untouched, so the driver can keep waiting
    /// for (or re-request) the right reply. A structurally invalid reply
    /// body (ragged KV rows, out-of-range position) cancels the session:
    /// its step accounting can no longer be trusted.
    pub fn on_reply(
        &mut self,
        edge: &EdgeDevice,
        reply: &CloudReply,
        cloud_s: f64,
        up: TransferOutcome,
        down: TransferOutcome,
    ) -> Result<()> {
        if self.is_terminal() {
            return Ok(());
        }
        let Some(pending) = self.pending else { return Ok(()) };
        anyhow::ensure!(
            reply.request_id == self.request.id,
            "reply for request {} fed to session {}",
            reply.request_id,
            self.request.id
        );
        anyhow::ensure!(
            reply.pos == pending.pos as u64,
            "stale reply: answers position {}, position {} is in flight (request {})",
            reply.pos,
            pending.pos,
            self.request.id
        );
        if pending.is_prefill || pending.kv_transmitted {
            let state = self.state.as_mut().expect("reply before prefill");
            if let Err(e) = edge.absorb_reply(state, pending.pos, &reply.new_kv_rows) {
                self.cancel();
                return Err(e.context("absorbing cloud reply"));
            }
            // The prefill state is now complete on both halves; publish
            // the prefix into the edge cache so the NEXT session sharing
            // it prefills suffix-only (no-op when already resident, when
            // caching is off, or when the reply was warm — a warm reply
            // implies the entry already existed).
            if pending.is_prefill {
                if let Some((digest, prefix_len)) = self.prefix_decision.reference() {
                    edge.learn_prefix(state, &digest, prefix_len);
                }
            }
        } else {
            // Stateless step: the cloud recomputed from the full hidden
            // history and returned no KV rows — the edge-held cloud
            // caches now miss this position for good.
            self.cloud_kv_stale = true;
        }
        self.pending = None;
        let stats = StepStats {
            edge_compute_s: pending.edge_s,
            cloud_compute_s: cloud_s,
            uplink_s: up.latency_s,
            downlink_s: down.latency_s,
            uplink_bytes: up.payload_bytes,
            downlink_bytes: down.payload_bytes,
            outage: up.outage || down.outage,
            chosen_bits: pending.chosen_bits,
            kv_transmitted: pending.kv_transmitted,
        };
        if pending.is_prefill {
            self.result.prefill = stats;
        } else {
            self.result.steps.push(stats);
        }
        self.next_token = reply.token;
        self.phase = SessionPhase::ReadyToDecode;
        Ok(())
    }

    /// Serialize the session at a quiescent point (nothing in flight)
    /// into a [`SessionSnapshot`]. The edge-held request state — KV
    /// caches, hidden history, tokens — is captured as raw f32, so a
    /// restored session continues the stream bit-identically (the
    /// two-stage wire compression is lossy; the snapshot is not). The
    /// edge device supplies the cache geometry (only the used rows are
    /// captured; the zero padding is restored from the config).
    pub fn snapshot(&self, edge: &EdgeDevice) -> Result<SessionSnapshot> {
        anyhow::ensure!(
            self.pending.is_none(),
            "cannot snapshot with a transmission in flight (request {})",
            self.request.id
        );
        let kvw = edge.node.weights.cfg.kv_width();
        let state = self.state.as_ref().map(|s| {
            let rows = s.seq_len();
            let trim = |caches: &[LayerKv]| {
                caches
                    .iter()
                    .map(|c| (c.k[..rows * kvw].to_vec(), c.v[..rows * kvw].to_vec()))
                    .collect()
            };
            StateSnapshot {
                front_kv: trim(&s.front_kv),
                cloud_kv: trim(&s.cloud_kv),
                hidden_history: s.hidden_history.clone(),
                tokens: s.tokens.clone(),
            }
        });
        Ok(SessionSnapshot {
            request: self.request.clone(),
            phase: self.phase,
            settings: self.settings,
            tau_override: self.tau_override,
            next_token: self.next_token,
            budget: self.budget,
            cloud_kv_stale: self.cloud_kv_stale,
            resume_epoch: self.resume_epoch,
            result: self.result.clone(),
            state,
        })
    }

    /// Rebuild a session from a snapshot against the same deployment (the
    /// edge device supplies the cache geometry; the controller is
    /// configuration, not state, so the caller re-supplies it). The
    /// restored session continues exactly where the snapshot left off.
    pub fn restore(
        snap: SessionSnapshot,
        edge: &EdgeDevice,
        controller: Option<EarlyExitController>,
    ) -> Result<Session> {
        anyhow::ensure!(
            snap.phase != SessionPhase::AwaitingReply,
            "snapshot captured mid-flight (request {})",
            snap.request.id
        );
        let cfg = &edge.node.weights.cfg;
        let kvw = cfg.kv_width();
        let max_seq = cfg.max_seq;
        let state = match snap.state {
            None => None,
            Some(st) => {
                let rows = st.tokens.len();
                anyhow::ensure!(rows <= max_seq, "snapshot holds {rows} rows, max_seq {max_seq}");
                anyhow::ensure!(
                    st.hidden_history.len() == rows * cfg.d_model,
                    "snapshot hidden history covers {} floats, expected {}",
                    st.hidden_history.len(),
                    rows * cfg.d_model
                );
                let pad = |trimmed: Vec<(Vec<f32>, Vec<f32>)>| -> Result<Vec<LayerKv>> {
                    trimmed
                        .into_iter()
                        .map(|(k, v)| {
                            anyhow::ensure!(
                                k.len() == rows * kvw && v.len() == rows * kvw,
                                "snapshot KV layer covers {} floats, expected {}",
                                k.len(),
                                rows * kvw
                            );
                            let mut cache = LayerKv::zeros(max_seq, kvw);
                            cache.k[..rows * kvw].copy_from_slice(&k);
                            cache.v[..rows * kvw].copy_from_slice(&v);
                            Ok(cache)
                        })
                        .collect()
                };
                anyhow::ensure!(
                    st.cloud_kv.len() == edge.n_cloud_layers,
                    "snapshot holds {} cloud KV layers, deployment has {}",
                    st.cloud_kv.len(),
                    edge.n_cloud_layers
                );
                let mut hidden_history = Vec::with_capacity(max_seq * cfg.d_model);
                hidden_history.extend_from_slice(&st.hidden_history);
                Some(EdgeRequestState {
                    request_id: snap.request.id,
                    front_kv: pad(st.front_kv)?,
                    cloud_kv: pad(st.cloud_kv)?,
                    hidden_history,
                    tokens: st.tokens,
                })
            }
        };
        Ok(Session {
            request: snap.request,
            phase: snap.phase,
            settings: snap.settings,
            tau_override: snap.tau_override,
            controller,
            state,
            next_token: snap.next_token,
            budget: snap.budget,
            cloud_kv_stale: snap.cloud_kv_stale,
            resume_epoch: snap.resume_epoch,
            pending: None,
            result: snap.result,
        })
    }
}
