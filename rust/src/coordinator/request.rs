//! Request and generation-result types shared across the coordinator.

use super::sampling::SamplingSpec;
use crate::planner::TxSettings;

/// One inference request submitted by a client of an edge device.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// End-to-end deadline per generated token (None = best effort).
    pub deadline_s: Option<f64>,
    /// Arrival time in the workload clock (seconds).
    pub arrival_s: f64,
    /// Decode policy executed by the (stateless) cloud; travels on every
    /// payload of this request.
    pub sampling: SamplingSpec,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            deadline_s: None,
            arrival_s: 0.0,
            sampling: SamplingSpec::Greedy,
        }
    }

    /// Builder-style sampling override.
    pub fn with_sampling(mut self, sampling: SamplingSpec) -> Request {
        self.sampling = sampling;
        self
    }
}

/// Per-step accounting produced by the split pipeline.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub edge_compute_s: f64,
    pub cloud_compute_s: f64,
    pub uplink_s: f64,
    pub downlink_s: f64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub outage: bool,
    /// TAB-Q bits actually used for the hidden-state block.
    pub chosen_bits: u32,
    pub kv_transmitted: bool,
}

impl StepStats {
    pub fn total_latency_s(&self) -> f64 {
        self.edge_compute_s + self.cloud_compute_s + self.uplink_s + self.downlink_s
    }
}

/// Result of generating one request through the split pipeline.
#[derive(Clone, Debug, Default)]
pub struct GenerationResult {
    pub request_id: u64,
    pub tokens: Vec<u32>,
    pub prefill: StepStats,
    pub steps: Vec<StepStats>,
    /// Tokens dropped by the Algorithm-2 early exit (0 = none).
    pub tokens_dropped: usize,
    /// Mid-stream control-plane reconfigurations applied (0 = the static
    /// plan served the whole request).
    pub reconfigs: usize,
    /// Settings in force when generation finished.
    pub final_settings: Option<TxSettings>,
}

impl GenerationResult {
    pub fn total_latency_s(&self) -> f64 {
        self.prefill.total_latency_s()
            + self.steps.iter().map(|s| s.total_latency_s()).sum::<f64>()
    }

    pub fn total_uplink_bytes(&self) -> u64 {
        self.prefill.uplink_bytes + self.steps.iter().map(|s| s.uplink_bytes).sum::<u64>()
    }

    pub fn total_downlink_bytes(&self) -> u64 {
        self.prefill.downlink_bytes + self.steps.iter().map(|s| s.downlink_bytes).sum::<u64>()
    }

    pub fn mean_step_latency_s(&self) -> f64 {
        if self.steps.is_empty() {
            0.0
        } else {
            self.steps.iter().map(|s| s.total_latency_s()).sum::<f64>() / self.steps.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_sums() {
        let mut r = GenerationResult { request_id: 1, ..Default::default() };
        r.prefill = StepStats { uplink_bytes: 100, edge_compute_s: 0.5, ..Default::default() };
        r.steps.push(StepStats { uplink_bytes: 10, cloud_compute_s: 0.25, ..Default::default() });
        r.steps.push(StepStats { uplink_bytes: 20, uplink_s: 0.25, ..Default::default() });
        assert_eq!(r.total_uplink_bytes(), 130);
        assert!((r.total_latency_s() - 1.0).abs() < 1e-12);
        assert!((r.mean_step_latency_s() - 0.25).abs() < 1e-12);
    }
}
