//! Many-to-one serve loop: N edge devices, ONE shared stateless
//! `CloudServer`, continuous (iteration-level) batching over real
//! payloads — the paper's Fig. 1(c) deployment as an executable scheduler
//! rather than the `sim.rs` cost-scalar model.
//!
//! Each admitted request is a sans-IO [`Session`]. Every loop iteration:
//!
//!   1. admits arrived requests through the [`Router`] (Eq. 8c memory
//!      admission, least-outstanding-work placement),
//!   2. polls every active session — each runs its edge front segment and
//!      hands back a compressed `SplitPayload`,
//!   3. streams newly committed tokens to the caller's sink (which may
//!      cancel a session mid-stream),
//!   4. ships the iteration's payloads over each device's wire as
//!      **encoded frames** — the edge port charges the device's `LinkSim`
//!      with the actual frame length, the cloud port strictly decodes the
//!      bytes — and serves the decoded payloads together on the shared
//!      cloud (`handle_batch`, which STACKS the iteration's I_kv = 1
//!      decode payloads into one batched engine call — B sessions, one
//!      weight-matrix traversal),
//!   5. retires finished/cancelled sessions, returning their router slots
//!      (`Router::complete` — capacity really is reclaimed under churn).
//!
//! Token streams are scheduling-independent: the cloud is stateless and
//! sampling is (seed, request, pos)-keyed, so interleaving N sessions
//! produces exactly the tokens each request would get alone through
//! `SplitPipeline::generate`.
//!
//! Clock model: per-request `StepStats` are real (measured compute +
//! simulated link events; a stacked payload is charged its even share of
//! the batch's wall time). The loop additionally keeps an aggregate
//! simulated clock in which the batch's edge/link work overlaps across
//! devices (max, not sum) and the shared server charges serially-measured
//! payloads through the `BatcherParams` sub-linear batching model while
//! the stacked engine call — already batched for real — is charged its
//! measured wall time directly (`BatchCompute` keeps the two apart, so
//! the real stacking gain is never modeled twice). `sim.rs` remains the
//! closed-form fast path for the same accounting and is cross-checked
//! against this loop in the test suite.

use std::collections::VecDeque;

use anyhow::Result;

use super::batcher::BatcherParams;
use super::cloud::CloudServer;
use super::edge::{EdgeDevice, PrefixDecision};
use super::pipeline::is_prefix_reject;
use super::protocol::{PrefixProbe, SplitPayload};
use super::request::{GenerationResult, Request};
use super::router::{RouteDecision, Router};
use super::session::{Session, SessionAction};
use crate::adapt::{AdaptiveController, SessionView};
use crate::channel::{LinkSim, TransferOutcome};
use crate::planner::EarlyExitController;
use crate::wire::{CloudPort, EdgePort, LinkTransport, WireTransport};

/// One edge device and its wire; every session runs on exactly one
/// endpoint (selected by the router at admission). The endpoint holds
/// BOTH halves of its simulated wireless duplex — the serve loop is the
/// single-process driver and pumps the cloud side into the shared server,
/// so every payload still crosses the codec as real frame bytes.
pub struct EdgeEndpoint {
    pub edge: EdgeDevice,
    /// Edge side (sim-charged with actual encoded frame lengths).
    pub port: EdgePort,
    /// Cloud side of the same wire (lossless loopback).
    pub cloud_port: CloudPort,
}

impl EdgeEndpoint {
    /// In-process endpoint over a simulated wireless duplex.
    pub fn over_link(edge: EdgeDevice, link: LinkSim) -> EdgeEndpoint {
        let (edge_half, cloud_half) = LinkTransport::duplex(link);
        EdgeEndpoint {
            edge,
            port: EdgePort::new(WireTransport::Sim(edge_half)),
            cloud_port: CloudPort::new(WireTransport::Loopback(cloud_half)),
        }
    }

    /// The wireless link simulator behind this endpoint's wire.
    pub fn link(&self) -> &LinkSim {
        self.port.link().expect("serve-loop endpoints are sim-backed")
    }
}

/// Verdict of the per-token streaming sink.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenControl {
    Continue,
    /// Tear the session down mid-stream (slot is reclaimed immediately).
    Cancel,
}

/// Aggregate outcome of one `ServeLoop::run`.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Per-request results, completion order (cancelled/failed included —
    /// they carry the tokens committed before teardown).
    pub results: Vec<GenerationResult>,
    /// Simulated-clock arrival→completion latency of each request that
    /// finished naturally (completion order).
    pub latencies_s: Vec<f64>,
    /// Simulated wall clock at the end of the run.
    pub clock_s: f64,
    /// Simulated seconds the shared server spent computing.
    pub server_busy_s: f64,
    pub iterations: u64,
    pub total_tokens: u64,
    pub cancelled: u64,
    pub failed: u64,
    /// Largest number of payloads served in one iteration.
    pub peak_batch: usize,
    /// (request_id, error) for sessions torn down by an edge-side error.
    pub errors: Vec<(u64, String)>,
    /// Adaptation counters: per-session reconfigurations actually applied
    /// mid-stream, device-level Eq. 8 re-plans, and the control-plane
    /// bytes those reconfigurations cost on the wire. All zero when the
    /// control plane is off OR the channel never left the deadband (the
    /// static≡adaptive invariant).
    pub reconfigs: u64,
    pub replans: u64,
    pub control_bytes: u64,
}

impl ServeReport {
    pub fn throughput_tok_s(&self) -> f64 {
        if self.clock_s > 0.0 {
            self.total_tokens as f64 / self.clock_s
        } else {
            0.0
        }
    }

    pub fn mean_latency_s(&self) -> f64 {
        crate::util::mean(&self.latencies_s)
    }

    pub fn p95_latency_s(&self) -> f64 {
        crate::util::percentile(&self.latencies_s, 95.0)
    }
}

/// Tear one session down after an unrecoverable per-session fault,
/// recording the typed cause. The loop keeps serving everyone else — a
/// chaos-injected wire fault or a hostile payload condemns exactly one
/// request, never the batch.
fn fail_session(a: &mut ActiveSession, report: &mut ServeReport, err: anyhow::Error) {
    a.failed = true;
    report.errors.push((a.session.request_id(), format!("{err:#}")));
    a.session.cancel();
}

struct ActiveSession {
    session: Session,
    device: usize,
    /// Whether the router charged a slot (false = cloud-fallback overflow).
    routed: bool,
    /// Tokens charged at admission; released verbatim at completion.
    expected: u64,
    arrival_s: f64,
    /// Tokens already pushed to the streaming sink.
    streamed: usize,
    failed: bool,
    /// Control-plane bookkeeping: reconfigurations applied so far, the
    /// plan the LAST reconfiguration (or the static deployment) set —
    /// distinct from the session's live settings, which Algorithm-2
    /// escalations may move below it — and cooldown counters.
    epoch: u32,
    applied_bits: u32,
    applied_kv: bool,
    decode_steps: u64,
    last_reconfig_step: u64,
}

/// The many-to-one scheduler: drives N concurrent sessions across
/// multiple edge devices and one shared cloud server.
pub struct ServeLoop {
    pub cloud: CloudServer,
    pub edges: Vec<EdgeEndpoint>,
    pub router: Router,
    /// Iteration accounting (max batch width, sub-linear batching model).
    pub params: BatcherParams,
    /// Early-exit controller applied to every session (None = best effort).
    pub controller: Option<EarlyExitController>,
    /// Online control plane (None = execute the static plan forever).
    /// Fed by the per-frame transfer outcomes of step 6; consulted
    /// between decode steps, where its per-session `Reconfig` decisions
    /// are sent over the wire (charged as real control bytes), applied by
    /// the shared cloud, and installed into the session.
    pub adapt: Option<AdaptiveController>,
}

impl ServeLoop {
    pub fn new(
        cloud: CloudServer,
        edges: Vec<EdgeEndpoint>,
        router: Router,
        params: BatcherParams,
    ) -> ServeLoop {
        ServeLoop { cloud, edges, router, params, controller: None, adapt: None }
    }

    /// Mirror a finished run's counters into an obs registry: `serve_*`
    /// counters/gauges from the report, the `serve_latency_us` histogram,
    /// the shared cloud's `cloud_*`/`prefix_store_*` family, and the
    /// per-edge prefix cache totals. This is what `--metrics PATH` on the
    /// serve modes snapshots.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, report: &ServeReport) {
        reg.counter("serve_total_tokens").set(report.total_tokens);
        reg.counter("serve_iterations").set(report.iterations);
        reg.counter("serve_cancelled").set(report.cancelled);
        reg.counter("serve_failed").set(report.failed);
        reg.counter("serve_reconfigs").set(report.reconfigs);
        reg.counter("serve_replans").set(report.replans);
        reg.counter("serve_control_bytes").set(report.control_bytes);
        reg.counter("serve_results").set(report.results.len() as u64);
        reg.gauge("serve_peak_batch").set(report.peak_batch as i64);
        reg.gauge("serve_clock_us").set((report.clock_s * 1e6) as i64);
        reg.gauge("serve_server_busy_us").set((report.server_busy_s * 1e6) as i64);
        let lat = reg.histogram("serve_latency_us");
        for &s in &report.latencies_s {
            lat.record((s * 1e6).max(1.0) as u64);
        }
        self.cloud.export_metrics(reg);
        let mut edge_totals: std::collections::BTreeMap<&'static str, u64> =
            std::collections::BTreeMap::new();
        for ep in &self.edges {
            let stats = ep.edge.prefix_cache.borrow().stats;
            crate::obs::accumulate(&mut edge_totals, &stats);
        }
        reg.publish_totals(&edge_totals);
    }

    fn least_loaded_device(&self) -> usize {
        self.router
            .devices
            .iter()
            .min_by_key(|d| (d.outstanding_tokens, d.device_id))
            .map(|d| d.device_id)
            .unwrap_or(0)
    }

    /// Serve a whole trace to completion, streaming every committed token
    /// through `on_token` (return `TokenControl::Cancel` to tear that
    /// session down mid-stream). Requests are admitted at their
    /// `arrival_s` on the simulated clock.
    pub fn run(
        &mut self,
        requests: Vec<Request>,
        mut on_token: impl FnMut(u64, u32) -> TokenControl,
    ) -> Result<ServeReport> {
        anyhow::ensure!(!self.edges.is_empty(), "serve loop needs at least one edge device");
        // Reject non-finite arrivals up front: a NaN would poison the
        // simulated clock, and before total_cmp the sort below panicked.
        if let Some(bad) = requests.iter().find(|r| !r.arrival_s.is_finite()) {
            anyhow::bail!("request {} has non-finite arrival time {}", bad.id, bad.arrival_s);
        }
        let max_batch = self.params.max_batch.max(1);
        let mut pending = requests;
        pending.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut next = 0usize;
        let mut waiting: VecDeque<Request> = VecDeque::new();
        let mut active: Vec<ActiveSession> = Vec::new();
        let mut report = ServeReport::default();
        let mut clock = 0.0f64;

        loop {
            // 1. arrivals up to the current clock
            while next < pending.len() && pending[next].arrival_s <= clock {
                waiting.push_back(pending[next].clone());
                next += 1;
            }

            // 2. admission: router memory check + iteration width cap.
            let mut admitted_any = false;
            while active.len() < max_batch && !waiting.is_empty() {
                let can_admit = self.router.devices.iter().any(|d| d.can_admit());
                if !can_admit && !active.is_empty() {
                    break; // wait for a completion to free capacity
                }
                let req = waiting.pop_front().expect("non-empty checked");
                let expected = req.max_new_tokens as u64;
                let (device, routed) = match self.router.route(expected) {
                    RouteDecision::ToDevice(d) => (d, true),
                    // No memory headroom anywhere but nothing is running:
                    // serve on the least-loaded device without charging a
                    // slot (the deployment's overflow path) rather than
                    // deadlocking.
                    RouteDecision::CloudFallback => (self.least_loaded_device(), false),
                };
                let arrival_s = req.arrival_s;
                let base_bits = self.edges[device].edge.compression.q_bar;
                // Prefix planning: when the device holds a warm entry for
                // this prompt, probe the shared cloud over THIS session's
                // own wire (real frames) so the store pins the digest
                // before the suffix-only prefill ships. A probe miss — or
                // a wire fault during the handshake — downgrades to an
                // insert, which is always safe (full payload).
                let mut decision = self.edges[device].edge.prefix_decision(&req.prompt);
                if let PrefixDecision::Warm { digest, prefix_len } = decision {
                    let probe =
                        PrefixProbe { request_id: req.id, digest, prefix_len: prefix_len as u32 };
                    let ep = &mut self.edges[device];
                    let acked = ep.port.send_prefix_probe(&probe).and_then(|_| {
                        let (decoded, _) = ep.cloud_port.recv_prefix_probe()?;
                        let ack = self.cloud.handle_probe(&decoded);
                        ep.cloud_port.send_prefix_ack(&ack)?;
                        let (ack, _) = ep.port.recv_prefix_ack()?;
                        Ok(ack)
                    });
                    match acked {
                        Ok(ack) if ack.hit && ack.digest == digest => {}
                        Ok(_) => decision = PrefixDecision::Insert { digest, prefix_len },
                        Err(_) => {
                            ep.port.transport.drain();
                            ep.cloud_port.transport.drain();
                            decision = PrefixDecision::Insert { digest, prefix_len };
                        }
                    }
                }
                let mut session =
                    Session::for_edge(req, &self.edges[device].edge, self.controller);
                session.set_prefix_decision(decision);
                active.push(ActiveSession {
                    session,
                    device,
                    routed,
                    expected,
                    arrival_s,
                    streamed: 0,
                    failed: false,
                    epoch: 0,
                    applied_bits: base_bits,
                    applied_kv: true,
                    decode_steps: 0,
                    last_reconfig_step: 0,
                });
                admitted_any = true;
            }

            // 3. idle handling / termination
            if active.is_empty() {
                if next < pending.len() {
                    clock = clock.max(pending[next].arrival_s); // jump to next arrival
                    continue;
                }
                break; // drained
            }

            // 4. poll every session: edge compute + payload build
            let mut outbox: Vec<(usize, SplitPayload)> = Vec::new();
            for (i, a) in active.iter_mut().enumerate() {
                let edge = &self.edges[a.device].edge;
                match a.session.poll(edge) {
                    Ok(SessionAction::Transmit(payload)) => outbox.push((i, payload)),
                    Ok(SessionAction::Yield) | Ok(SessionAction::Finished) => {}
                    Err(e) => {
                        // poll already cancelled the session; record and
                        // let the retire sweep reclaim the slot.
                        a.failed = true;
                        report.errors.push((a.session.request_id(), e.to_string()));
                    }
                }
            }

            // 5. stream tokens committed by this poll; sink may cancel.
            for a in active.iter_mut() {
                while a.streamed < a.session.tokens().len() {
                    let t = a.session.tokens()[a.streamed];
                    a.streamed += 1;
                    if on_token(a.session.request_id(), t) == TokenControl::Cancel {
                        a.session.cancel();
                        break;
                    }
                }
            }

            // 6. deliver the iteration's batch: per device, the payload
            // is encoded + framed + charged on the uplink by the edge
            // port and strictly decoded from bytes by the cloud port (the
            // shared server computes on what the wire carried); then one
            // shared-server batch call (decode payloads stacked into a
            // single batched engine step), framed reply + downlink charge
            // per session.
            let mut meta: Vec<(usize, TransferOutcome)> = Vec::new();
            let mut payloads: Vec<SplitPayload> = Vec::new();
            for (i, payload) in outbox {
                if active[i].session.is_terminal() {
                    continue; // cancelled between poll and delivery
                }
                let device = active[i].device;
                let ep = &mut self.edges[device];
                // Any wire fault on this exchange condemns only this
                // session: typed error recorded, endpoint queues drained
                // (a partial frame must not desync the NEXT session on
                // this device), telemetry re-anchored (fault-window
                // samples would poison the bandwidth estimate).
                let up = match ep.port.send_payload(&payload) {
                    Ok(up) => up,
                    Err(e) => {
                        ep.port.transport.drain();
                        ep.cloud_port.transport.drain();
                        fail_session(&mut active[i], &mut report, e.context("uplink"));
                        if let Some(ctrl) = self.adapt.as_mut() {
                            ctrl.reanchor(device);
                        }
                        continue;
                    }
                };
                let decoded = match ep.cloud_port.recv_payload() {
                    Ok((d, _)) => d,
                    Err(e) => {
                        ep.port.transport.drain();
                        ep.cloud_port.transport.drain();
                        fail_session(&mut active[i], &mut report, e.context("cloud decode"));
                        if let Some(ctrl) = self.adapt.as_mut() {
                            ctrl.reanchor(device);
                        }
                        continue;
                    }
                };
                // The decoded payload must be the one this session just
                // sent — a duplicated or reordered frame that still
                // decodes is identity-checked here, never served as if it
                // were the in-flight step.
                if decoded.request_id != payload.request_id || decoded.pos != payload.pos {
                    ep.port.transport.drain();
                    ep.cloud_port.transport.drain();
                    fail_session(
                        &mut active[i],
                        &mut report,
                        anyhow::anyhow!(
                            "wire delivered request {} pos {} while request {} pos {} was in flight",
                            decoded.request_id,
                            decoded.pos,
                            payload.request_id,
                            payload.pos
                        ),
                    );
                    if let Some(ctrl) = self.adapt.as_mut() {
                        ctrl.reanchor(device);
                    }
                    continue;
                }
                meta.push((i, up));
                payloads.push(decoded);
            }
            // A payload that decoded cleanly can still fail to serve
            // (control-plane violation, inconsistent tensor dims). The
            // batch call refuses as a whole; fall back to serving each
            // payload alone so the fault is attributed to ITS session and
            // everyone else's step still completes.
            let b = payloads.len();
            let (served, compute): (Vec<std::result::Result<_, anyhow::Error>>, _) =
                match self.cloud.handle_batch(&payloads) {
                    Ok((served, compute)) => (served.into_iter().map(Ok).collect(), compute),
                    Err(_) => {
                        let mut served = Vec::with_capacity(payloads.len());
                        let mut compute = super::cloud::BatchCompute::default();
                        for p in &payloads {
                            match self.cloud.handle(p) {
                                Ok((r, s)) => {
                                    compute.solo_s += s;
                                    compute.solo_n += 1;
                                    served.push(Ok((r, s)));
                                }
                                Err(e) => served.push(Err(e)),
                            }
                        }
                        (served, compute)
                    }
                };
            // Edge/link time overlaps across devices but serializes on one
            // device: sum per device, then max across devices.
            let mut device_busy_s = vec![0.0f64; self.edges.len()];
            for ((i, up), outcome) in meta.into_iter().zip(served) {
                let a = &mut active[i];
                let device = a.device;
                let edge_s = a.session.pending_edge_s().unwrap_or(0.0);
                let (reply, cloud_s, up) = match outcome {
                    Ok((r, s)) => (r, s, up),
                    // Typed PREFIX reject: the cloud refused the warm
                    // cache token. Rebuild the prefill as a full insert
                    // and retransmit on this session's own wire — served
                    // solo, so everyone else's step is untouched. The
                    // retransmission's uplink outcome replaces the warm
                    // attempt's in the step accounting (it is the frame
                    // that actually got answered).
                    Err(e) if is_prefix_reject(&e) => {
                        let rebuilt =
                            match a.session.rebuild_prefill_as_insert(&self.edges[device].edge) {
                                Ok(p) => p,
                                Err(e) => {
                                    fail_session(
                                        a,
                                        &mut report,
                                        e.context("rebuilding prefill as insert"),
                                    );
                                    continue;
                                }
                            };
                        let ep = &mut self.edges[device];
                        let resent = ep.port.send_payload(&rebuilt).and_then(|up2| {
                            let (decoded, _) = ep.cloud_port.recv_payload()?;
                            let (reply, cloud_s) = self.cloud.handle(&decoded)?;
                            Ok((reply, cloud_s, up2))
                        });
                        match resent {
                            Ok(x) => x,
                            Err(e) => {
                                ep.port.transport.drain();
                                ep.cloud_port.transport.drain();
                                fail_session(
                                    a,
                                    &mut report,
                                    e.context("prefix insert retransmission"),
                                );
                                if let Some(ctrl) = self.adapt.as_mut() {
                                    ctrl.reanchor(device);
                                }
                                continue;
                            }
                        }
                    }
                    Err(e) => {
                        fail_session(a, &mut report, e.context("cloud serve"));
                        continue;
                    }
                };
                let ep = &mut self.edges[device];
                let sent = ep.cloud_port.send_reply(&reply, cloud_s);
                let received = sent.and_then(|_| ep.port.recv_reply());
                let (reply, server_s, down) = match received {
                    Ok(x) => x,
                    Err(e) => {
                        ep.port.transport.drain();
                        ep.cloud_port.transport.drain();
                        fail_session(a, &mut report, e.context("downlink"));
                        if let Some(ctrl) = self.adapt.as_mut() {
                            ctrl.reanchor(device);
                        }
                        continue;
                    }
                };
                // Telemetry: both directions of this exchange crossed the
                // device's link — feed the control plane's estimator.
                if let Some(ctrl) = self.adapt.as_mut() {
                    ctrl.observe(device, &up);
                    ctrl.observe(device, &down);
                }
                a.decode_steps += 1;
                // A reply that answers the wrong request/position, or one
                // whose body cannot be absorbed, is a typed per-session
                // failure — never a silently-wrong token.
                if let Err(e) = a.session.on_reply(&ep.edge, &reply, server_s, up, down) {
                    ep.port.transport.drain();
                    ep.cloud_port.transport.drain();
                    fail_session(a, &mut report, e.context("absorbing reply"));
                    if let Some(ctrl) = self.adapt.as_mut() {
                        ctrl.reanchor(device);
                    }
                    continue;
                }
                device_busy_s[device] += edge_s + up.latency_s + down.latency_s;
            }
            let edge_wire_max_s = device_busy_s.iter().fold(0.0f64, |m, &x| m.max(x));

            // 7. retire terminal sessions (free router slots, collect
            // results) BEFORE advancing the clock: their last token was
            // delivered at the end of the previous iteration.
            let mut finished_any = false;
            let mut i = 0;
            while i < active.len() {
                if !active[i].session.is_terminal() {
                    i += 1;
                    continue;
                }
                let a = active.swap_remove(i);
                finished_any = true;
                if a.routed {
                    self.router.complete(a.device, a.expected);
                }
                // Sessions can end without an EOS reply (budget, cancel,
                // error): sweep the cloud's control-plane entry so it
                // cannot outlive the session.
                self.cloud.retire_request(a.session.request_id());
                let cancelled = a.session.is_cancelled();
                let res = a.session.into_result();
                report.total_tokens += res.tokens.len() as u64;
                if a.failed {
                    report.failed += 1;
                } else if cancelled {
                    report.cancelled += 1;
                } else {
                    report.latencies_s.push(clock - a.arrival_s);
                }
                report.results.push(res);
            }

            // 7.5 control plane: between decode steps, the adaptive
            // controller (when installed) re-plans each device against
            // its ESTIMATED link state, then reconciles every surviving
            // session with its device's plan. Emitted reconfigurations
            // are real frames: encoded, charged on the device's uplink
            // (control bytes are accounted), applied by the shared cloud
            // server, and only then installed into the session — the
            // next payload the session builds already honors them, and
            // the cloud will hold it to the announced precision.
            if self.adapt.is_some() {
                let mut control_s = 0.0f64;
                for d in 0..self.edges.len() {
                    self.adapt.as_mut().expect("checked").device_update(d);
                }
                for a in active.iter_mut() {
                    if a.session.is_terminal() {
                        continue;
                    }
                    let Some(seq_len) = a.session.seq_len() else {
                        continue; // prefill still pending: nothing to adapt yet
                    };
                    let cfg = &self.edges[a.device].edge.node.weights.cfg;
                    let view = SessionView {
                        request_id: a.session.request_id(),
                        epoch: a.epoch,
                        seq_len,
                        remaining_budget: a.session.remaining_budget(),
                        prefill_len: cfg.prefill_len,
                        max_seq: cfg.max_seq,
                        applied_bits: a.applied_bits,
                        applied_kv: a.applied_kv,
                        kv_shippable: !a.session.cloud_kv_stale(),
                        steps_since_reconfig: a.decode_steps - a.last_reconfig_step,
                        // The in-process loop drives sessions synchronously:
                        // a Resume handshake can never be in flight here.
                        mid_resume: false,
                    };
                    let ctrl = self.adapt.as_mut().expect("checked");
                    if let Some(rc) = ctrl.reconcile(a.device, &view) {
                        let device = a.device;
                        let ep = &mut self.edges[device];
                        // Control frames cross the same chaotic wire as
                        // payloads: a mangled reconfig condemns only this
                        // session (typed, queues drained, telemetry
                        // re-anchored) — never the whole loop.
                        let exchanged = ep.port.send_reconfig(&rc).and_then(|up| {
                            let (applied, _) = ep.cloud_port.recv_reconfig()?;
                            Ok((up, applied))
                        });
                        let (up, applied) = match exchanged {
                            Ok(x) => x,
                            Err(e) => {
                                ep.port.transport.drain();
                                ep.cloud_port.transport.drain();
                                fail_session(a, &mut report, e.context("reconfig control frame"));
                                if let Some(ctrl) = self.adapt.as_mut() {
                                    ctrl.reanchor(device);
                                }
                                continue;
                            }
                        };
                        self.cloud.apply_reconfig(&applied);
                        a.session.apply_reconfig(&rc);
                        a.epoch = rc.epoch;
                        a.applied_bits = rc.qa_bits;
                        // Read the I_kv actually in force back from the
                        // session — it refuses KV-shipping upgrades once
                        // its cloud-KV copy is stale.
                        a.applied_kv = a.session.settings().include_kv;
                        a.last_reconfig_step = a.decode_steps;
                        control_s += up.latency_s;
                        report.reconfigs += 1;
                        report.control_bytes += up.payload_bytes;
                    }
                }
                clock += control_s;
            }

            // 8. advance the simulated clock by one continuous-batching
            // iteration: overlapped edge/link work + server compute. Only
            // the serially-measured payloads (prefill / I_kv = 0 /
            // stacking disabled) go through the BatcherParams sub-linear
            // model; the stacked engine call was measured already-batched
            // and is charged its real wall time — re-modeling it would
            // double-count the stacking gain.
            if b > 0 {
                let solo_batched_s = if compute.solo_n > 0 {
                    (compute.solo_s / compute.solo_n as f64)
                        * (1.0 + self.params.batch_overhead * (compute.solo_n as f64 - 1.0))
                } else {
                    0.0
                };
                let batched_server_s = solo_batched_s
                    + compute.stacked_s
                    + self.params.congestion_s_per_waiter * waiting.len() as f64;
                clock += edge_wire_max_s + batched_server_s;
                report.server_busy_s += batched_server_s;
                report.iterations += 1;
                report.peak_batch = report.peak_batch.max(b);
            } else if !finished_any && !admitted_any {
                // No transmissions, no completions, no admissions — the
                // loop would spin forever. Cannot happen with a correct
                // session machine; fail loudly instead of hanging.
                anyhow::bail!("serve loop stalled with {} active sessions", active.len());
            }
        }

        report.clock_s = clock;
        if let Some(ctrl) = &self.adapt {
            report.replans = ctrl.replans();
        }
        Ok(report)
    }
}
