//! Device compute profiles.
//!
//! The paper profiles local compute on the real target (Jetson Xavier NX
//! edge, A6000 cloud — footnote 10). Our substrate measures wall-clock on
//! the host CPU PJRT and scales it by a per-device factor, preserving the
//! edge/cloud compute asymmetry the scheduling decisions depend on.

#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    /// Multiplier applied to measured host wall-clock.
    pub compute_scale: f64,
}

impl DeviceProfile {
    /// Jetson-Xavier-NX-like edge device (slower than the host).
    pub fn edge_default() -> DeviceProfile {
        DeviceProfile { name: "edge-jetson-nx".into(), compute_scale: 6.0 }
    }

    /// A6000-like cloud GPU (much faster than the host CPU).
    pub fn cloud_default() -> DeviceProfile {
        DeviceProfile { name: "cloud-a6000".into(), compute_scale: 0.15 }
    }

    pub fn scale(&self, measured_s: f64) -> f64 {
        measured_s * self.compute_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_slower_than_cloud() {
        let e = DeviceProfile::edge_default();
        let c = DeviceProfile::cloud_default();
        assert!(e.scale(1.0) > c.scale(1.0));
    }
}
