//! Edge device: runs the OPSC front segment, owns all per-request state
//! (the paper's stateless-cloud design), compresses intermediate outputs,
//! and talks to the cloud over the simulated wireless link.

use std::time::Instant;

use anyhow::Result;

use super::profile::DeviceProfile;
use super::protocol::{CompressedKv, CompressedTensor, CompressionConfig, SplitPayload};
use super::sampling::SamplingSpec;
use crate::planner::TxSettings;
use crate::quant::ScratchPool;
use crate::runtime::{LayerKv, NodeRuntime};

/// Outcome of probing the wire size a payload WOULD have under some
/// transmission settings. Typed so the early-exit controller can tell
/// "these settings cannot serve this state" (e.g. I_kv = 0 past the
/// prefill width) apart from "the payload is merely huge" — previously a
/// `u64::MAX / 4` sentinel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeOutcome {
    /// Estimated wire bytes under the probed settings.
    Feasible(u64),
    /// The settings cannot serve the current request state at all.
    Infeasible,
}

impl ProbeOutcome {
    /// Estimated bytes, or `None` when infeasible — the shape the
    /// controller's `PayloadOracle` consumes.
    pub fn bytes(self) -> Option<u64> {
        match self {
            ProbeOutcome::Feasible(b) => Some(b),
            ProbeOutcome::Infeasible => None,
        }
    }
}

/// Per-request state held on the edge. The cloud keeps nothing between
/// calls (many-to-one deployment, paper Fig. 1(c)); Eq. (2)'s edge memory
/// model is exactly the contents of this struct.
#[derive(Debug)]
pub struct EdgeRequestState {
    pub request_id: u64,
    /// KV caches of the FRONT layers (produced and consumed locally).
    pub front_kv: Vec<LayerKv>,
    /// KV caches of the CLOUD layers (canonical copy lives here; shipped
    /// when I_kv = 1, refreshed from CloudReply rows).
    pub cloud_kv: Vec<LayerKv>,
    /// Split-layer hidden state of every token so far (w, d) — needed to
    /// serve I_kv = 0 steps, where the cloud recomputes from scratch.
    pub hidden_history: Vec<f32>,
    /// Tokens so far (prompt + generated).
    pub tokens: Vec<u32>,
}

impl EdgeRequestState {
    pub fn seq_len(&self) -> usize {
        self.tokens.len()
    }
}

pub struct EdgeDevice {
    /// Front segment (layers 0..split), OPSC-quantized weights.
    pub node: NodeRuntime,
    pub profile: DeviceProfile,
    pub compression: CompressionConfig,
    /// Number of cloud layers (for KV bookkeeping).
    pub n_cloud_layers: usize,
    /// Fused-compression scratch arenas, reused across decode steps and
    /// shared with the parallel KV-layer workers (zero steady-state
    /// allocation on the compression hot path).
    pub scratch: ScratchPool,
}

impl EdgeDevice {
    pub fn new(
        node: NodeRuntime,
        n_cloud_layers: usize,
        profile: DeviceProfile,
        compression: CompressionConfig,
    ) -> EdgeDevice {
        EdgeDevice { node, profile, compression, n_cloud_layers, scratch: ScratchPool::new() }
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.node.weights.cfg
    }

    /// Compress one tensor through the fused engine on this device's
    /// pooled scratch.
    pub(crate) fn compress_block(
        &self,
        t: &[f32],
        rows: usize,
        cols: usize,
        comp: &CompressionConfig,
    ) -> CompressedTensor {
        self.scratch.with(|s| CompressedTensor::compress_with(s, t, rows, cols, comp))
    }

    /// Prefill the front segment and build the first payload.
    /// Returns (payload, state, scaled_compute_seconds).
    pub fn prefill(&self, request_id: u64, prompt: &[u32]) -> Result<(SplitPayload, EdgeRequestState, f64)> {
        let cfg = self.cfg();
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.len() <= cfg.prefill_len,
            "prompt ({}) exceeds prefill width ({})",
            prompt.len(),
            cfg.prefill_len
        );
        let t0 = Instant::now();
        let x = self.node.weights.embed_padded(prompt, cfg.prefill_len);
        let (h, kv_rows) = self.node.prefill(&x)?;
        let front_kv = self.node.install_prefill_kv(&kv_rows, prompt.len());
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());

        let d = cfg.d_model;
        let w = prompt.len();
        // Sized for the whole request up front: decode appends one row per
        // step, so reserving max_seq rows avoids re-allocating (and
        // re-copying) the history on the decode hot path.
        let mut hidden_history = Vec::with_capacity(cfg.max_seq * d);
        hidden_history.extend_from_slice(&h[..w * d]);
        let hidden = self.compress_block(&hidden_history, w, d, &self.compression);
        let state = EdgeRequestState {
            request_id,
            front_kv,
            cloud_kv: vec![LayerKv::zeros(cfg.max_seq, cfg.kv_width()); self.n_cloud_layers],
            hidden_history,
            tokens: prompt.to_vec(),
        };
        let payload = SplitPayload {
            request_id,
            pos: w - 1,
            hidden,
            kv: None, // nothing to ship yet — the cloud builds its KV in prefill
            is_prefill: true,
            sampling: SamplingSpec::default(),
        };
        Ok((payload, state, compute_s))
    }

    /// One decode step: embed `token`, run the front segment at position
    /// `pos = seq_len`, append to histories, and build the payload under
    /// the given transmission settings. `q_bar_override` / `tau_override`
    /// replace the device's configured Q̄a / τ for this step (the
    /// adaptive control plane reconfigures both mid-stream).
    pub fn decode_step(
        &self,
        state: &mut EdgeRequestState,
        token: u32,
        include_kv: bool,
        q_bar_override: Option<u32>,
        tau_override: Option<f32>,
    ) -> Result<(SplitPayload, f64)> {
        let cfg = self.cfg();
        let pos = state.seq_len();
        anyhow::ensure!(pos < cfg.max_seq, "request exceeded max_seq");
        let t0 = Instant::now();
        let x = self.node.weights.embed(&[token]);
        let h = self.node.decode(&x, &mut state.front_kv, pos)?;
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());

        state.tokens.push(token);
        state.hidden_history.extend_from_slice(&h);

        let mut comp = self.compression;
        if let Some(q) = q_bar_override {
            comp.q_bar = q;
        }
        if let Some(t) = tau_override {
            comp.tau = t;
        }
        let d = cfg.d_model;
        let w = state.seq_len();
        let (hidden, kv) = if include_kv {
            // ship this token's hidden row + the cloud layers' caches
            let hidden = self.compress_block(&h, 1, d, &comp);
            // previous tokens' KV only — the current token's cloud KV is
            // computed by the cloud from the hidden row (Eq. 2 structure)
            let kv = CompressedKv::compress_with_pool(
                &state.cloud_kv,
                w - 1,
                cfg.kv_width(),
                &comp,
                &self.scratch,
            );
            (hidden, Some(kv))
        } else {
            // I_kv = 0: ship the split-layer hidden of ALL tokens; the
            // cloud recomputes its K/V from scratch (needs w <= P).
            anyhow::ensure!(
                w <= cfg.prefill_len,
                "I_kv=0 requires seq_len ({w}) <= prefill width ({})",
                cfg.prefill_len
            );
            let hidden = self.compress_block(&state.hidden_history, w, d, &comp);
            (hidden, None)
        };
        let payload = SplitPayload {
            request_id: state.request_id,
            pos,
            hidden,
            kv,
            is_prefill: false,
            sampling: SamplingSpec::default(),
        };
        Ok((payload, compute_s))
    }

    /// Apply the cloud's reply: install the new KV rows of the cloud
    /// layers at `pos` into the edge-held canonical copy. The row shapes
    /// come off the wire, so they are validated — a hostile or corrupt
    /// reply is a typed error, never a slice panic or silent cache
    /// corruption.
    pub fn absorb_reply(
        &self,
        state: &mut EdgeRequestState,
        pos: usize,
        new_kv_rows: &[(Vec<f32>, Vec<f32>)],
    ) -> Result<()> {
        let kvw = self.cfg().kv_width();
        let max_seq = self.cfg().max_seq;
        anyhow::ensure!(pos < max_seq, "reply position {pos} exceeds max_seq {max_seq}");
        anyhow::ensure!(
            new_kv_rows.len() <= state.cloud_kv.len(),
            "reply carries {} KV layers, edge holds {}",
            new_kv_rows.len(),
            state.cloud_kv.len()
        );
        for (krow, vrow) in new_kv_rows {
            // prefill replies carry several rows, decode replies one
            anyhow::ensure!(
                krow.len() == vrow.len() && !krow.is_empty() && krow.len() % kvw == 0,
                "reply KV rows are ragged ({} k floats, {} v floats, width {kvw})",
                krow.len(),
                vrow.len()
            );
            let n_rows = krow.len() / kvw;
            anyhow::ensure!(
                n_rows <= pos + 1,
                "reply carries {n_rows} KV rows for position {pos}"
            );
        }
        for (cache, (krow, vrow)) in state.cloud_kv.iter_mut().zip(new_kv_rows) {
            let n_rows = krow.len() / kvw;
            let start = pos + 1 - n_rows;
            cache.k[start * kvw..(pos + 1) * kvw].copy_from_slice(krow);
            cache.v[start * kvw..(pos + 1) * kvw].copy_from_slice(vrow);
        }
        Ok(())
    }

    /// Payload-size oracle for the early-exit controller: what WOULD the
    /// wire size be under `settings`, given the current request state?
    /// Uses the memory model for speed (the controller probes several
    /// settings per step); the actual transmitted payload is re-built and
    /// measured exactly.
    pub fn payload_size_probe(
        &self,
        state: &EdgeRequestState,
        settings: TxSettings,
    ) -> ProbeOutcome {
        let cfg = &self.node.weights.cfg;
        let w = state.seq_len();
        let qa = crate::memory::ActBits::uniform(settings.qa_bits);
        let split = self.node.layer_range.end;
        if settings.include_kv {
            ProbeOutcome::Feasible(crate::memory::io_bytes(cfg, w, split, true, &qa))
        } else if w > cfg.prefill_len {
            // I_kv=0 impossible beyond the prefill width.
            ProbeOutcome::Infeasible
        } else {
            ProbeOutcome::Feasible(crate::memory::io_bytes(cfg, w, split, false, &qa))
        }
    }

    /// Rebuild the current step's payload under escalated settings (the
    /// front-segment compute is NOT redone — only compression changes).
    pub fn rebuild_payload(
        &self,
        state: &EdgeRequestState,
        settings: TxSettings,
        tau_override: Option<f32>,
    ) -> anyhow::Result<SplitPayload> {
        let cfg = &self.node.weights.cfg;
        let d = cfg.d_model;
        let w = state.seq_len();
        let pos = w - 1;
        let mut comp = self.compression;
        comp.q_bar = settings.qa_bits;
        if let Some(t) = tau_override {
            comp.tau = t;
        }
        let last_hidden = &state.hidden_history[pos * d..w * d];
        let (hidden, kv) = if settings.include_kv {
            let hidden = self.compress_block(last_hidden, 1, d, &comp);
            let kv = CompressedKv::compress_with_pool(
                &state.cloud_kv,
                pos,
                cfg.kv_width(),
                &comp,
                &self.scratch,
            );
            (hidden, Some(kv))
        } else {
            anyhow::ensure!(w <= cfg.prefill_len, "I_kv=0 beyond prefill width");
            let hidden = self.compress_block(&state.hidden_history, w, d, &comp);
            (hidden, None)
        };
        Ok(SplitPayload {
            request_id: state.request_id,
            pos,
            hidden,
            kv,
            is_prefill: false,
            sampling: SamplingSpec::default(),
        })
    }
}
