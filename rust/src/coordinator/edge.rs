//! Edge device: runs the OPSC front segment, owns all per-request state
//! (the paper's stateless-cloud design), compresses intermediate outputs,
//! and talks to the cloud over the simulated wireless link.
//!
//! The device also owns the edge half of the content-addressed prefix
//! cache (`crate::prefix`): [`EdgeDevice::prefix_decision`] picks the
//! longest cacheable prefix of a prompt, and
//! [`EdgeDevice::prefill_ex`] serves it — suffix-only front compute when
//! the prefix is resident locally, two-block encoding (prefix block +
//! divergent suffix block) on the wire so the cloud can populate its
//! store, and a 36-byte reference instead of the prefix block once both
//! halves are warm.

use std::cell::RefCell;
use std::time::Instant;

use anyhow::Result;

use super::profile::DeviceProfile;
use super::protocol::{
    CompressedKv, CompressedTensor, CompressionConfig, PrefixRef, SplitPayload,
};
use super::sampling::SamplingSpec;
use crate::planner::TxSettings;
use crate::prefix::{
    prefix_candidates, EdgePrefixCache, EdgePrefixEntry, PlanIdentity, PrefixDigest, CHUNK_TOKENS,
};
use crate::quant::ScratchPool;
use crate::runtime::{LayerKv, NodeRuntime};

/// How a prefill should engage the prefix cache. Chosen by
/// [`EdgeDevice::prefix_decision`] before the first payload is built;
/// drivers may downgrade `Warm` to `Insert` when the cloud's probe
/// answers miss (or a warm payload draws a typed `PREFIX` reject).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefixDecision {
    /// No cacheable prefix (short prompt, cache disabled): today's
    /// single-block payload, byte for byte.
    Off,
    /// Ship the prefix as its own compressed block so the cloud can
    /// serve this session AND populate its store for later ones.
    Insert { digest: PrefixDigest, prefix_len: usize },
    /// Both halves hold the prefix: ship the 36-byte reference plus the
    /// divergent suffix block only. Requires a resident edge entry.
    Warm { digest: PrefixDigest, prefix_len: usize },
}

impl PrefixDecision {
    /// The (digest, prefix_len) this decision addresses, if any.
    pub fn reference(&self) -> Option<(PrefixDigest, usize)> {
        match *self {
            PrefixDecision::Off => None,
            PrefixDecision::Insert { digest, prefix_len }
            | PrefixDecision::Warm { digest, prefix_len } => Some((digest, prefix_len)),
        }
    }
}

/// Outcome of probing the wire size a payload WOULD have under some
/// transmission settings. Typed so the early-exit controller can tell
/// "these settings cannot serve this state" (e.g. I_kv = 0 past the
/// prefill width) apart from "the payload is merely huge" — previously a
/// `u64::MAX / 4` sentinel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeOutcome {
    /// Estimated wire bytes under the probed settings.
    Feasible(u64),
    /// The settings cannot serve the current request state at all.
    Infeasible,
}

impl ProbeOutcome {
    /// Estimated bytes, or `None` when infeasible — the shape the
    /// controller's `PayloadOracle` consumes.
    pub fn bytes(self) -> Option<u64> {
        match self {
            ProbeOutcome::Feasible(b) => Some(b),
            ProbeOutcome::Infeasible => None,
        }
    }
}

/// Per-request state held on the edge. The cloud keeps nothing between
/// calls (many-to-one deployment, paper Fig. 1(c)); Eq. (2)'s edge memory
/// model is exactly the contents of this struct.
#[derive(Debug)]
pub struct EdgeRequestState {
    pub request_id: u64,
    /// KV caches of the FRONT layers (produced and consumed locally).
    pub front_kv: Vec<LayerKv>,
    /// KV caches of the CLOUD layers (canonical copy lives here; shipped
    /// when I_kv = 1, refreshed from CloudReply rows).
    pub cloud_kv: Vec<LayerKv>,
    /// Split-layer hidden state of every token so far (w, d) — needed to
    /// serve I_kv = 0 steps, where the cloud recomputes from scratch.
    pub hidden_history: Vec<f32>,
    /// Tokens so far (prompt + generated).
    pub tokens: Vec<u32>,
}

impl EdgeRequestState {
    pub fn seq_len(&self) -> usize {
        self.tokens.len()
    }
}

pub struct EdgeDevice {
    /// Front segment (layers 0..split), OPSC-quantized weights.
    pub node: NodeRuntime,
    pub profile: DeviceProfile,
    pub compression: CompressionConfig,
    /// Number of cloud layers (for KV bookkeeping).
    pub n_cloud_layers: usize,
    /// Fused-compression scratch arenas, reused across decode steps and
    /// shared with the parallel KV-layer workers (zero steady-state
    /// allocation on the compression hot path).
    pub scratch: ScratchPool,
    /// Edge half of the content-addressed prefix cache (budget 0 =
    /// disabled, which keeps every payload byte-identical to the
    /// pre-prefix wire format).
    pub prefix_cache: RefCell<EdgePrefixCache>,
}

impl EdgeDevice {
    pub fn new(
        node: NodeRuntime,
        n_cloud_layers: usize,
        profile: DeviceProfile,
        compression: CompressionConfig,
    ) -> EdgeDevice {
        EdgeDevice {
            node,
            profile,
            compression,
            n_cloud_layers,
            scratch: ScratchPool::new(),
            prefix_cache: RefCell::new(EdgePrefixCache::new(0)),
        }
    }

    /// (Re)size the edge prefix cache. 0 disables it; resizing resets the
    /// cache (entries are cheap to re-learn from the next cold prefill).
    pub fn set_prefix_cache_budget(&self, budget_bytes: u64) {
        *self.prefix_cache.borrow_mut() = EdgePrefixCache::new(budget_bytes);
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.node.weights.cfg
    }

    /// The plan identity scoping this device's prefix digests: any change
    /// to the split point, compression settings, or model shape lands in
    /// a different address space, so stale plans miss instead of aliasing.
    pub fn prefix_plan(&self) -> PlanIdentity {
        let cfg = self.cfg();
        PlanIdentity {
            split_layer: self.node.layer_range.end as u32,
            q_bar: self.compression.q_bar,
            tau_bits: self.compression.tau.to_bits() as u64,
            delta_bits: self.compression.delta.to_bits(),
            use_rans: self.compression.use_rans,
            i_kv: false, // prefill blocks never ship KV; decode mode is orthogonal
            d_model: cfg.d_model as u32,
            n_layers: cfg.n_layers as u32,
            prefill_len: cfg.prefill_len as u32,
        }
    }

    /// Pick this prompt's prefix-cache engagement. Chunk boundaries are
    /// probed **longest-first for residency**: when the longest boundary
    /// misses but a shorter one is already cached, the shorter warm
    /// match wins over a cold insert of the longest (a 2-chunk prompt
    /// sharing its first chunk with a hot prefix reuses that chunk
    /// instead of prefetching both from scratch). Only a fully cold
    /// prompt inserts — at the LONGEST boundary, so the cache learns the
    /// widest reusable prefix. `Off` when nothing is cacheable or the
    /// cache is disabled.
    pub fn prefix_decision(&self, prompt: &[u32]) -> PrefixDecision {
        let mut cache = self.prefix_cache.borrow_mut();
        if !cache.enabled() {
            return PrefixDecision::Off;
        }
        let plan = self.prefix_plan();
        let cands = prefix_candidates(prompt, &plan);
        for &(prefix_len, digest) in cands.iter().rev() {
            if cache.contains(&digest) {
                return PrefixDecision::Warm { digest, prefix_len };
            }
        }
        match cands.last() {
            Some(&(prefix_len, digest)) => PrefixDecision::Insert { digest, prefix_len },
            None => PrefixDecision::Off,
        }
    }

    /// Compress one tensor through the fused engine on this device's
    /// pooled scratch.
    pub(crate) fn compress_block(
        &self,
        t: &[f32],
        rows: usize,
        cols: usize,
        comp: &CompressionConfig,
    ) -> CompressedTensor {
        self.scratch.with(|s| CompressedTensor::compress_with(s, t, rows, cols, comp))
    }

    /// Prefill the front segment and build the first payload, without
    /// engaging the prefix cache — byte-identical to the pre-prefix wire
    /// format. Returns (payload, state, scaled_compute_seconds).
    pub fn prefill(&self, request_id: u64, prompt: &[u32]) -> Result<(SplitPayload, EdgeRequestState, f64)> {
        self.prefill_ex(request_id, prompt, PrefixDecision::Off)
    }

    /// Prefill under a prefix-cache decision (see [`PrefixDecision`]).
    ///
    /// With a resident edge entry (always for `Warm`, opportunistically
    /// for `Insert` after a downgrade) only the divergent suffix rows are
    /// computed and compressed; the front K/V, hidden history and payload
    /// bytes are bit-identical to the full-compute path by the suffix-
    /// prefill kernel's equivalence guarantee, so warm and cold streams
    /// cannot diverge.
    pub fn prefill_ex(
        &self,
        request_id: u64,
        prompt: &[u32],
        decision: PrefixDecision,
    ) -> Result<(SplitPayload, EdgeRequestState, f64)> {
        let cfg = self.cfg();
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            prompt.len() <= cfg.prefill_len,
            "prompt ({}) exceeds prefill width ({})",
            prompt.len(),
            cfg.prefill_len
        );
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        let w = prompt.len();
        if let Some((_, prefix_len)) = decision.reference() {
            anyhow::ensure!(
                prefix_len > 0 && prefix_len < w && prefix_len % CHUNK_TOKENS == 0,
                "prefix length {prefix_len} is not a chunk boundary inside the prompt ({w})"
            );
        }
        let entry = match decision.reference() {
            Some((digest, _)) => self.prefix_cache.borrow_mut().get(&digest),
            None => None,
        };
        if let (PrefixDecision::Warm { .. }, None) = (decision, &entry) {
            anyhow::bail!("warm prefix decision without a resident edge entry");
        }

        let t0 = Instant::now();
        let (hidden_history, front_kv) = match (&entry, decision.reference()) {
            (Some(e), Some((_, wp))) => {
                // Suffix-only front compute against the cached prefix.
                anyhow::ensure!(
                    e.prefix_len == wp,
                    "edge entry covers {} tokens, decision claims {wp}",
                    e.prefix_len
                );
                let x_suffix =
                    self.node.weights.embed_padded(&prompt[wp..], cfg.prefill_len - wp);
                let (h_suf, kv_suf) = self.node.prefill_suffix(&x_suffix, wp, &e.front_kv)?;
                let mut hidden_history = Vec::with_capacity(cfg.max_seq * d);
                hidden_history.extend_from_slice(&e.hidden);
                hidden_history.extend_from_slice(&h_suf[..(w - wp) * d]);
                let front_kv: Vec<LayerKv> = e
                    .front_kv
                    .iter()
                    .zip(&kv_suf)
                    .map(|((pk, pv), (sk, sv))| {
                        let mut k = Vec::with_capacity(cfg.max_seq * kvw);
                        k.extend_from_slice(pk);
                        k.extend_from_slice(&sk[..(w - wp) * kvw]);
                        k.resize(cfg.max_seq * kvw, 0.0);
                        let mut v = Vec::with_capacity(cfg.max_seq * kvw);
                        v.extend_from_slice(pv);
                        v.extend_from_slice(&sv[..(w - wp) * kvw]);
                        v.resize(cfg.max_seq * kvw, 0.0);
                        LayerKv { k, v }
                    })
                    .collect();
                (hidden_history, front_kv)
            }
            _ => {
                // Full-block front compute (cold path, today's behavior).
                let x = self.node.weights.embed_padded(prompt, cfg.prefill_len);
                let (h, kv_rows) = self.node.prefill(&x)?;
                // Sized for the whole request up front: decode appends one
                // row per step, so reserving max_seq rows avoids
                // re-allocating (and re-copying) the history on the decode
                // hot path.
                let mut hidden_history = Vec::with_capacity(cfg.max_seq * d);
                hidden_history.extend_from_slice(&h[..w * d]);
                (hidden_history, self.node.install_prefill_kv(&kv_rows, w))
            }
        };
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());

        // Pre-fill the cloud-KV mirror from the entry's learned back rows
        // on the warm path — the cloud's warm reply carries suffix rows
        // only, so the mirror's prefix must come from here.
        let mut cloud_kv =
            vec![LayerKv::zeros(cfg.max_seq, cfg.kv_width()); self.n_cloud_layers];
        if let (PrefixDecision::Warm { prefix_len, .. }, Some(e)) = (decision, &entry) {
            anyhow::ensure!(
                e.back_kv.len() == self.n_cloud_layers
                    && e.back_kv.iter().all(|(k, v)| {
                        k.len() == prefix_len * kvw && v.len() == prefix_len * kvw
                    }),
                "edge entry's back-segment rows do not cover the cloud layers"
            );
            for (cache, (bk, bv)) in cloud_kv.iter_mut().zip(&e.back_kv) {
                cache.k[..prefix_len * kvw].copy_from_slice(bk);
                cache.v[..prefix_len * kvw].copy_from_slice(bv);
            }
        }

        let (hidden, prefix) = match decision {
            PrefixDecision::Off => {
                (self.compress_block(&hidden_history, w, d, &self.compression), None)
            }
            PrefixDecision::Insert { digest, prefix_len: wp } => {
                // Two-block encode: the prefix travels as its own tensor so
                // the cloud's store entry (and every later warm suffix) is
                // independent of this prompt's divergent tail.
                let prefix_block =
                    self.compress_block(&hidden_history[..wp * d], wp, d, &self.compression);
                let suffix_block = self.compress_block(
                    &hidden_history[wp * d..w * d],
                    w - wp,
                    d,
                    &self.compression,
                );
                let r = PrefixRef { digest, prefix_len: wp as u32, insert: Some(prefix_block) };
                (suffix_block, Some(r))
            }
            PrefixDecision::Warm { digest, prefix_len: wp } => {
                let suffix_block = self.compress_block(
                    &hidden_history[wp * d..w * d],
                    w - wp,
                    d,
                    &self.compression,
                );
                (suffix_block, Some(PrefixRef { digest, prefix_len: wp as u32, insert: None }))
            }
        };
        let state = EdgeRequestState {
            request_id,
            front_kv,
            cloud_kv,
            hidden_history,
            tokens: prompt.to_vec(),
        };
        let payload = SplitPayload {
            request_id,
            pos: w - 1,
            hidden,
            kv: None, // nothing to ship yet — the cloud builds its KV in prefill
            is_prefill: true,
            sampling: SamplingSpec::default(),
            prefix,
        };
        Ok((payload, state, compute_s))
    }

    /// Learn an edge cache entry from a freshly served cold/insert
    /// prefill: front prefix K/V from the local caches, split-layer
    /// hidden prefix from the history, back prefix K/V from the absorbed
    /// cloud reply. Call AFTER `absorb_reply` of the prefill reply.
    /// Idempotent — a resident digest only gets its recency bumped.
    pub fn learn_prefix(&self, state: &EdgeRequestState, digest: &PrefixDigest, prefix_len: usize) {
        let mut cache = self.prefix_cache.borrow_mut();
        if !cache.enabled() || cache.contains(digest) {
            return;
        }
        let cfg = self.cfg();
        let (d, kvw) = (cfg.d_model, cfg.kv_width());
        let wp = prefix_len;
        if wp == 0 || state.seq_len() < wp {
            return;
        }
        let entry = EdgePrefixEntry {
            prefix_len: wp,
            front_kv: state
                .front_kv
                .iter()
                .map(|c| (c.k[..wp * kvw].to_vec(), c.v[..wp * kvw].to_vec()))
                .collect(),
            hidden: state.hidden_history[..wp * d].to_vec(),
            back_kv: state
                .cloud_kv
                .iter()
                .map(|c| (c.k[..wp * kvw].to_vec(), c.v[..wp * kvw].to_vec()))
                .collect(),
        };
        cache.insert(digest, entry);
    }

    /// Rebuild a warm prefill payload as a full insert after the cloud
    /// answered with a typed `PREFIX` reject (store restart, eviction,
    /// forged token): no front compute is redone — the prefix block is
    /// re-compressed from the hidden history, which by determinism equals
    /// the bytes a cold insert would have shipped. The caller re-stamps
    /// the sampling spec before retransmitting.
    pub fn rebuild_prefill_as_insert(
        &self,
        state: &EdgeRequestState,
        digest: &PrefixDigest,
        prefix_len: usize,
    ) -> Result<SplitPayload> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let w = state.seq_len();
        let wp = prefix_len;
        anyhow::ensure!(
            wp > 0 && wp < w && state.hidden_history.len() >= w * d,
            "prefix length {wp} does not split the prompt ({w})"
        );
        let prefix_block =
            self.compress_block(&state.hidden_history[..wp * d], wp, d, &self.compression);
        let suffix_block = self.compress_block(
            &state.hidden_history[wp * d..w * d],
            w - wp,
            d,
            &self.compression,
        );
        Ok(SplitPayload {
            request_id: state.request_id,
            pos: w - 1,
            hidden: suffix_block,
            kv: None,
            is_prefill: true,
            sampling: SamplingSpec::default(),
            prefix: Some(PrefixRef {
                digest: *digest,
                prefix_len: wp as u32,
                insert: Some(prefix_block),
            }),
        })
    }

    /// One decode step: embed `token`, run the front segment at position
    /// `pos = seq_len`, append to histories, and build the payload under
    /// the given transmission settings. `q_bar_override` / `tau_override`
    /// replace the device's configured Q̄a / τ for this step (the
    /// adaptive control plane reconfigures both mid-stream).
    pub fn decode_step(
        &self,
        state: &mut EdgeRequestState,
        token: u32,
        include_kv: bool,
        q_bar_override: Option<u32>,
        tau_override: Option<f32>,
    ) -> Result<(SplitPayload, f64)> {
        let cfg = self.cfg();
        let pos = state.seq_len();
        anyhow::ensure!(pos < cfg.max_seq, "request exceeded max_seq");
        let t0 = Instant::now();
        let x = self.node.weights.embed(&[token]);
        let h = self.node.decode(&x, &mut state.front_kv, pos)?;
        let compute_s = self.profile.scale(t0.elapsed().as_secs_f64());

        state.tokens.push(token);
        state.hidden_history.extend_from_slice(&h);

        let mut comp = self.compression;
        if let Some(q) = q_bar_override {
            comp.q_bar = q;
        }
        if let Some(t) = tau_override {
            comp.tau = t;
        }
        let d = cfg.d_model;
        let w = state.seq_len();
        let (hidden, kv) = if include_kv {
            // ship this token's hidden row + the cloud layers' caches
            let hidden = self.compress_block(&h, 1, d, &comp);
            // previous tokens' KV only — the current token's cloud KV is
            // computed by the cloud from the hidden row (Eq. 2 structure)
            let kv = CompressedKv::compress_with_pool(
                &state.cloud_kv,
                w - 1,
                cfg.kv_width(),
                &comp,
                &self.scratch,
            );
            (hidden, Some(kv))
        } else {
            // I_kv = 0: ship the split-layer hidden of ALL tokens; the
            // cloud recomputes its K/V from scratch (needs w <= P).
            anyhow::ensure!(
                w <= cfg.prefill_len,
                "I_kv=0 requires seq_len ({w}) <= prefill width ({})",
                cfg.prefill_len
            );
            let hidden = self.compress_block(&state.hidden_history, w, d, &comp);
            (hidden, None)
        };
        let payload = SplitPayload {
            request_id: state.request_id,
            pos,
            hidden,
            kv,
            is_prefill: false,
            sampling: SamplingSpec::default(),
            prefix: None, // the prefix only rides prefill payloads
        };
        Ok((payload, compute_s))
    }

    /// Apply the cloud's reply: install the new KV rows of the cloud
    /// layers at `pos` into the edge-held canonical copy. The row shapes
    /// come off the wire, so they are validated — a hostile or corrupt
    /// reply is a typed error, never a slice panic or silent cache
    /// corruption.
    pub fn absorb_reply(
        &self,
        state: &mut EdgeRequestState,
        pos: usize,
        new_kv_rows: &[(Vec<f32>, Vec<f32>)],
    ) -> Result<()> {
        let kvw = self.cfg().kv_width();
        let max_seq = self.cfg().max_seq;
        anyhow::ensure!(pos < max_seq, "reply position {pos} exceeds max_seq {max_seq}");
        anyhow::ensure!(
            new_kv_rows.len() <= state.cloud_kv.len(),
            "reply carries {} KV layers, edge holds {}",
            new_kv_rows.len(),
            state.cloud_kv.len()
        );
        for (krow, vrow) in new_kv_rows {
            // prefill replies carry several rows, decode replies one
            anyhow::ensure!(
                krow.len() == vrow.len() && !krow.is_empty() && krow.len() % kvw == 0,
                "reply KV rows are ragged ({} k floats, {} v floats, width {kvw})",
                krow.len(),
                vrow.len()
            );
            let n_rows = krow.len() / kvw;
            anyhow::ensure!(
                n_rows <= pos + 1,
                "reply carries {n_rows} KV rows for position {pos}"
            );
        }
        for (cache, (krow, vrow)) in state.cloud_kv.iter_mut().zip(new_kv_rows) {
            let n_rows = krow.len() / kvw;
            let start = pos + 1 - n_rows;
            cache.k[start * kvw..(pos + 1) * kvw].copy_from_slice(krow);
            cache.v[start * kvw..(pos + 1) * kvw].copy_from_slice(vrow);
        }
        Ok(())
    }

    /// Payload-size oracle for the early-exit controller: what WOULD the
    /// wire size be under `settings`, given the current request state?
    /// Uses the memory model for speed (the controller probes several
    /// settings per step); the actual transmitted payload is re-built and
    /// measured exactly.
    pub fn payload_size_probe(
        &self,
        state: &EdgeRequestState,
        settings: TxSettings,
    ) -> ProbeOutcome {
        let cfg = &self.node.weights.cfg;
        let w = state.seq_len();
        let qa = crate::memory::ActBits::uniform(settings.qa_bits);
        let split = self.node.layer_range.end;
        if settings.include_kv {
            ProbeOutcome::Feasible(crate::memory::io_bytes(cfg, w, split, true, &qa))
        } else if w > cfg.prefill_len {
            // I_kv=0 impossible beyond the prefill width.
            ProbeOutcome::Infeasible
        } else {
            ProbeOutcome::Feasible(crate::memory::io_bytes(cfg, w, split, false, &qa))
        }
    }

    /// Rebuild the current step's payload under escalated settings (the
    /// front-segment compute is NOT redone — only compression changes).
    pub fn rebuild_payload(
        &self,
        state: &EdgeRequestState,
        settings: TxSettings,
        tau_override: Option<f32>,
    ) -> anyhow::Result<SplitPayload> {
        let cfg = &self.node.weights.cfg;
        let d = cfg.d_model;
        let w = state.seq_len();
        let pos = w - 1;
        let mut comp = self.compression;
        comp.q_bar = settings.qa_bits;
        if let Some(t) = tau_override {
            comp.tau = t;
        }
        let last_hidden = &state.hidden_history[pos * d..w * d];
        let (hidden, kv) = if settings.include_kv {
            let hidden = self.compress_block(last_hidden, 1, d, &comp);
            let kv = CompressedKv::compress_with_pool(
                &state.cloud_kv,
                pos,
                cfg.kv_width(),
                &comp,
                &self.scratch,
            );
            (hidden, Some(kv))
        } else {
            anyhow::ensure!(w <= cfg.prefill_len, "I_kv=0 beyond prefill width");
            let hidden = self.compress_block(&state.hidden_history, w, d, &comp);
            (hidden, None)
        };
        Ok(SplitPayload {
            request_id: state.request_id,
            pos,
            hidden,
            kv,
            is_prefill: false,
            sampling: SamplingSpec::default(),
            prefix: None,
        })
    }
}
