//! Request router: assigns incoming requests to edge devices
//! (least-outstanding-work first, with per-device memory admission via the
//! Eq. 8c budget). The router is the front door of the deployment — the
//! piece a vLLM-style router plays in a homogeneous cluster, adapted to
//! heterogeneous memory-constrained edges.

use crate::memory::ActBits;
use crate::model::ModelConfig;

#[derive(Clone, Debug)]
pub struct DeviceSlot {
    pub device_id: usize,
    /// Eq. 8c memory budget of this device (bytes).
    pub mem_budget_bytes: u64,
    /// Static per-request KV+weights cost under the device's plan.
    pub per_request_bytes: u64,
    pub weight_bytes: u64,
    pub active_requests: usize,
    /// Outstanding decode steps across active requests (load proxy).
    pub outstanding_tokens: u64,
}

impl DeviceSlot {
    pub fn new(
        device_id: usize,
        cfg: &ModelConfig,
        split: usize,
        qw_front: u32,
        qa: &ActBits,
        w_bar: usize,
        mem_budget_bytes: u64,
    ) -> DeviceSlot {
        let weight_bytes = crate::memory::edge_weight_bytes(cfg, split, qw_front);
        let per_request_bytes = crate::memory::kv_bytes(cfg, w_bar, split, qa);
        DeviceSlot {
            device_id,
            mem_budget_bytes,
            per_request_bytes,
            weight_bytes,
            active_requests: 0,
            outstanding_tokens: 0,
        }
    }

    pub fn used_bytes(&self) -> u64 {
        self.weight_bytes + self.active_requests as u64 * self.per_request_bytes
    }

    pub fn can_admit(&self) -> bool {
        self.used_bytes() + self.per_request_bytes <= self.mem_budget_bytes
    }
}

#[derive(Debug, Default)]
pub struct Router {
    pub devices: Vec<DeviceSlot>,
    pub rejected: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteDecision {
    ToDevice(usize),
    /// No device has memory headroom — serve cloud-only.
    CloudFallback,
}

impl Router {
    pub fn new(devices: Vec<DeviceSlot>) -> Router {
        Router { devices, rejected: 0 }
    }

    /// Route one request: least outstanding work among devices that pass
    /// memory admission; cloud fallback if none can take it.
    pub fn route(&mut self, expected_tokens: u64) -> RouteDecision {
        let best = self
            .devices
            .iter_mut()
            .filter(|d| d.can_admit())
            .min_by_key(|d| (d.outstanding_tokens, d.device_id));
        match best {
            Some(d) => {
                d.active_requests += 1;
                d.outstanding_tokens += expected_tokens;
                RouteDecision::ToDevice(d.device_id)
            }
            None => {
                self.rejected += 1;
                RouteDecision::CloudFallback
            }
        }
    }

    /// Mark a request complete on its device.
    pub fn complete(&mut self, device_id: usize, tokens: u64) {
        let d = &mut self.devices[device_id];
        d.active_requests = d.active_requests.saturating_sub(1);
        d.outstanding_tokens = d.outstanding_tokens.saturating_sub(tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(id: usize, budget_mb: u64) -> DeviceSlot {
        let cfg = ModelConfig::sim7b();
        DeviceSlot::new(
            id,
            &cfg,
            20,
            4,
            &ActBits::uniform(8),
            128,
            budget_mb * 1024 * 1024,
        )
    }

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new(vec![slot(0, 64), slot(1, 64)]);
        assert_eq!(r.route(100), RouteDecision::ToDevice(0));
        assert_eq!(r.route(50), RouteDecision::ToDevice(1));
        // device 1 now has less outstanding work
        assert_eq!(r.route(10), RouteDecision::ToDevice(1));
    }

    #[test]
    fn memory_admission_enforced() {
        // tiny budget: weights fit but no request slot
        let s = slot(0, 3);
        assert!(!s.can_admit(), "3 MB cannot hold front weights + KV");
        let mut r = Router::new(vec![s]);
        assert_eq!(r.route(10), RouteDecision::CloudFallback);
        assert_eq!(r.rejected, 1);
    }

    #[test]
    fn complete_frees_capacity() {
        let mut r = Router::new(vec![slot(0, 16)]);
        // fill to capacity
        let mut admitted = 0;
        while let RouteDecision::ToDevice(_) = r.route(10) {
            admitted += 1;
            if admitted > 1000 {
                panic!("no admission limit hit");
            }
        }
        assert!(admitted >= 1);
        assert_eq!(r.route(10), RouteDecision::CloudFallback);
        r.complete(0, 10);
        assert_eq!(r.route(10), RouteDecision::ToDevice(0));
    }

    #[test]
    fn used_bytes_counts_active_requests() {
        let mut s = slot(0, 64);
        let w = s.used_bytes();
        s.active_requests = 2;
        assert_eq!(s.used_bytes(), w + 2 * s.per_request_bytes);
    }
}
