//! Blocking single-request drivers over the sans-IO
//! [`Session`](super::session::Session) state machine, moving **encoded
//! frames** instead of structs:
//!
//!   * [`SplitPipeline`] — one edge device + one in-process cloud server,
//!     joined by a simulated wireless duplex. Every payload is really
//!     encoded, charged on the [`LinkSim`] with its actual frame length,
//!     and strictly decoded at the cloud boundary before serving; the
//!     reply makes the same trip back.
//!   * [`EdgeClient`] — the same edge half talking to a **remote**
//!     `splitserve cloud` process over a socket transport (TCP or unix
//!     domain socket); the server's compute seconds ride in the reply
//!     frame's timing prefix.
//!
//! The generation logic itself (decode loop, Algorithm-2 escalation,
//! `StepStats` accounting) lives in `Session`; these drivers only perform
//! the IO the session asks for, through one shared [`drive_session`]
//! loop. The many-to-one counterpart is
//! [`ServeLoop`](super::serve_loop::ServeLoop).

use anyhow::Result;

use super::cloud::CloudServer;
use super::edge::EdgeDevice;
use super::protocol::{CloudReply, SplitPayload};
use super::request::{GenerationResult, Request};
use super::session::{Session, SessionAction};
use crate::channel::{LinkSim, TransferOutcome};
use crate::planner::EarlyExitController;
use crate::wire::{CloudPort, EdgePort, LinkTransport, SocketTransport, WireTransport};

/// Drive one session to completion through an exchange function that
/// delivers a payload and produces (reply, server compute seconds,
/// uplink outcome, downlink outcome). Both blocking drivers share this
/// loop, so single-process and cross-process generation differ ONLY in
/// how frames move.
pub(crate) fn drive_session(
    edge: &EdgeDevice,
    controller: Option<EarlyExitController>,
    req: &Request,
    mut exchange: impl FnMut(&SplitPayload) -> Result<(CloudReply, f64, TransferOutcome, TransferOutcome)>,
) -> Result<GenerationResult> {
    let mut session = Session::for_edge(req.clone(), edge, controller);
    loop {
        match session.poll(edge)? {
            SessionAction::Transmit(payload) => {
                let (reply, server_s, up, down) = exchange(&payload)?;
                session.on_reply(edge, &reply, server_s, up, down);
            }
            // A single blocking driver never observes Yield: every
            // transmit is answered before the next poll.
            SessionAction::Yield => unreachable!("no in-flight IO in the blocking driver"),
            SessionAction::Finished => return Ok(session.into_result()),
        }
    }
}

pub struct SplitPipeline {
    pub edge: EdgeDevice,
    pub cloud: CloudServer,
    /// Edge side of the simulated wireless wire — charges the `LinkSim`
    /// with actual encoded frame lengths in both directions.
    pub port: EdgePort,
    /// Cloud side of the same wire (lossless loopback; this driver pumps
    /// it so the server computes on what the bytes carried).
    pub cloud_port: CloudPort,
    /// Early-exit controller (None = best-effort, no deadline).
    pub controller: Option<EarlyExitController>,
}

impl SplitPipeline {
    pub fn new(edge: EdgeDevice, cloud: CloudServer, link: LinkSim) -> SplitPipeline {
        let (edge_half, cloud_half) = LinkTransport::duplex(link);
        SplitPipeline {
            edge,
            cloud,
            port: EdgePort::new(WireTransport::Sim(edge_half)),
            cloud_port: CloudPort::new(WireTransport::Loopback(cloud_half)),
            controller: None,
        }
    }

    /// The wireless link simulator behind this pipeline's wire.
    pub fn link(&self) -> &LinkSim {
        self.port.link().expect("SplitPipeline is always sim-backed")
    }

    /// Run a full request to completion. EOS is vocabulary token 0
    /// (synthetic convention). Behavior-identical to driving a fresh
    /// `Session` by hand: poll → transmit → reply, until finished — with
    /// every transmission crossing the codec as real frame bytes.
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let SplitPipeline { edge, cloud, port, cloud_port, controller } = self;
        drive_session(edge, *controller, req, |payload| {
            let up = port.send_payload(payload)?;
            let (decoded, _) = cloud_port.recv_payload()?;
            let (reply, cloud_s) = cloud.handle(&decoded)?;
            cloud_port.send_reply(&reply, cloud_s)?;
            let (reply, server_s, down) = port.recv_reply()?;
            Ok((reply, server_s, up, down))
        })
    }
}

/// Cross-process driver: the edge half of a deployment generating against
/// a remote `splitserve cloud` over a real socket. Link outcomes are
/// measured wall time; the remote server's compute seconds come back in
/// each reply frame, so `StepStats` keeps the same shape as the
/// single-process drivers.
pub struct EdgeClient {
    pub edge: EdgeDevice,
    pub port: EdgePort,
    pub controller: Option<EarlyExitController>,
}

impl EdgeClient {
    pub fn new(edge: EdgeDevice, transport: SocketTransport) -> EdgeClient {
        EdgeClient { edge, port: EdgePort::new(WireTransport::Socket(transport)), controller: None }
    }

    /// Push a control-plane reconfiguration to the remote cloud (frame
    /// kind 3): the server records the announced settings for the
    /// session and holds its subsequent payloads to them. The frame is
    /// one-way — the server sends no reply for control traffic — so the
    /// payload/reply rhythm of `generate` is undisturbed.
    pub fn reconfigure(&mut self, rc: &crate::adapt::Reconfig) -> Result<()> {
        self.port.send_reconfig(rc)?;
        Ok(())
    }

    /// Run a full request to completion against the remote cloud.
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let EdgeClient { edge, port, controller } = self;
        drive_session(edge, *controller, req, |payload| {
            let up = port.send_payload(payload)?;
            let (reply, server_s, mut down) = port.recv_reply()?;
            // The blocking recv's wall time spans the server's whole
            // turnaround; the server's own compute seconds arrive in the
            // timing prefix and are recorded as cloud_compute_s, so they
            // must come OUT of the measured downlink or StepStats would
            // count them twice.
            down.latency_s = (down.latency_s - server_s).max(0.0);
            Ok((reply, server_s, up, down))
        })
    }
}
