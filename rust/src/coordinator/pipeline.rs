//! Blocking single-request drivers over the sans-IO
//! [`Session`](super::session::Session) state machine, moving **encoded
//! frames** instead of structs:
//!
//!   * [`SplitPipeline`] — one edge device + one in-process cloud server,
//!     joined by a simulated wireless duplex. Every payload is really
//!     encoded, charged on the [`LinkSim`] with its actual frame length,
//!     and strictly decoded at the cloud boundary before serving; the
//!     reply makes the same trip back.
//!   * [`EdgeClient`] — the same edge half talking to a **remote**
//!     `splitserve cloud` process over a socket transport (TCP or unix
//!     domain socket); the server's compute seconds ride in the reply
//!     frame's timing prefix.
//!
//! The generation logic itself (decode loop, Algorithm-2 escalation,
//! `StepStats` accounting) lives in `Session`; these drivers only perform
//! the IO the session asks for, through one shared [`drive_prepared`]
//! loop. The many-to-one counterpart is
//! [`ServeLoop`](super::serve_loop::ServeLoop).

use anyhow::Result;

use super::cloud::{CloudServer, PrefixMiss};
use super::edge::{EdgeDevice, PrefixDecision};
use super::protocol::{reject, CloudReply, PrefixProbe, Resume, SplitPayload};
use super::request::{GenerationResult, Request};
use super::session::{Session, SessionAction};
use super::snapshot::SessionSnapshot;
use crate::channel::{LinkSim, TransferOutcome};
use crate::planner::EarlyExitController;
use crate::util::rng::Rng;
use crate::wire::{
    CloudPort, EdgePort, LinkTransport, SocketTransport, WireError, WireTransport,
};

/// Whether a failed exchange is the cloud's typed refusal of a warm
/// prefix token — in-band `reject::PREFIX` on wire paths, a downcastable
/// [`PrefixMiss`] on in-process paths. Drivers answer it by rebuilding
/// the prefill as a full insert and retransmitting; anything else is a
/// genuine failure.
pub(crate) fn is_prefix_reject(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<WireError>(),
        Some(WireError::Rejected { code: reject::PREFIX, .. })
    ) || e.downcast_ref::<PrefixMiss>().is_some()
}

/// Drive a prepared session to completion through an exchange function
/// that delivers a payload and produces (reply, server compute seconds,
/// uplink outcome, downlink outcome). Both blocking drivers share this
/// loop, so single-process and cross-process generation differ ONLY in
/// how frames move. A typed `PREFIX` reject is survived in place: the
/// prefill is rebuilt as a full insert and retransmitted once.
pub(crate) fn drive_prepared(
    session: &mut Session,
    edge: &EdgeDevice,
    mut exchange: impl FnMut(&SplitPayload) -> Result<(CloudReply, f64, TransferOutcome, TransferOutcome)>,
) -> Result<()> {
    loop {
        match session.poll(edge)? {
            SessionAction::Transmit(payload) => {
                let (reply, server_s, up, down) = match exchange(&payload) {
                    Ok(ok) => ok,
                    Err(e) if is_prefix_reject(&e) => {
                        let rebuilt = session.rebuild_prefill_as_insert(edge)?;
                        exchange(&rebuilt)?
                    }
                    Err(e) => return Err(e),
                };
                session.on_reply(edge, &reply, server_s, up, down)?;
            }
            // A single blocking driver never observes Yield: every
            // transmit is answered before the next poll.
            SessionAction::Yield => unreachable!("no in-flight IO in the blocking driver"),
            SessionAction::Finished => return Ok(()),
        }
    }
}


pub struct SplitPipeline {
    pub edge: EdgeDevice,
    pub cloud: CloudServer,
    /// Edge side of the simulated wireless wire — charges the `LinkSim`
    /// with actual encoded frame lengths in both directions.
    pub port: EdgePort,
    /// Cloud side of the same wire (lossless loopback; this driver pumps
    /// it so the server computes on what the bytes carried).
    pub cloud_port: CloudPort,
    /// Early-exit controller (None = best-effort, no deadline).
    pub controller: Option<EarlyExitController>,
}

impl SplitPipeline {
    pub fn new(edge: EdgeDevice, cloud: CloudServer, link: LinkSim) -> SplitPipeline {
        let (edge_half, cloud_half) = LinkTransport::duplex(link);
        SplitPipeline {
            edge,
            cloud,
            port: EdgePort::new(WireTransport::Sim(edge_half)),
            cloud_port: CloudPort::new(WireTransport::Loopback(cloud_half)),
            controller: None,
        }
    }

    /// The wireless link simulator behind this pipeline's wire.
    pub fn link(&self) -> &LinkSim {
        self.port.link().expect("SplitPipeline is always sim-backed")
    }

    /// Run a full request to completion. EOS is vocabulary token 0
    /// (synthetic convention). Behavior-identical to driving a fresh
    /// `Session` by hand: poll → transmit → reply, until finished — with
    /// every transmission crossing the codec as real frame bytes. When
    /// the edge holds a warm prefix entry, a `PrefixProbe`/`PrefixAck`
    /// handshake (also real frames over the same wire) pins the cloud's
    /// copy before the prefill ships suffix-only; a probe miss downgrades
    /// to an insert.
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let SplitPipeline { edge, cloud, port, cloud_port, controller } = self;
        let mut session = Session::for_edge(req.clone(), edge, *controller);
        let mut decision = edge.prefix_decision(&req.prompt);
        if let PrefixDecision::Warm { digest, prefix_len } = decision {
            let probe =
                PrefixProbe { request_id: req.id, digest, prefix_len: prefix_len as u32 };
            port.send_prefix_probe(&probe)?;
            let (decoded, _) = cloud_port.recv_prefix_probe()?;
            let ack = cloud.handle_probe(&decoded);
            cloud_port.send_prefix_ack(&ack)?;
            let (ack, _) = port.recv_prefix_ack()?;
            if !(ack.hit && ack.digest == digest) {
                decision = PrefixDecision::Insert { digest, prefix_len };
            }
        }
        session.set_prefix_decision(decision);
        drive_prepared(&mut session, edge, |payload| {
            let up = port.send_payload(payload)?;
            let (decoded, _) = cloud_port.recv_payload()?;
            let (reply, cloud_s) = cloud.handle(&decoded)?;
            cloud_port.send_reply(&reply, cloud_s)?;
            let (reply, server_s, down) = port.recv_reply()?;
            Ok((reply, server_s, up, down))
        })?;
        Ok(session.into_result())
    }
}

/// Reconnect-and-retry schedule for [`EdgeClient`]: up to `attempts`
/// recovery rounds per in-flight step, with seeded-jitter exponential
/// backoff between them (`base_ms · 2^(k−1)`, capped at `max_ms`, scaled
/// by a uniform [0.5, 1.0) draw so a fleet of edges does not thunder back
/// in lockstep).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Recovery rounds per failed exchange (0 = fail on first error).
    pub attempts: u32,
    /// First backoff delay in milliseconds.
    pub base_ms: u64,
    /// Backoff ceiling in milliseconds.
    pub max_ms: u64,
    /// Jitter seed (mixed with the request id, so retries are
    /// deterministic per session but decorrelated across sessions).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { attempts: 0, base_ms: 50, max_ms: 2_000, seed: 0x8E77 }
    }
}

impl RetryPolicy {
    pub fn new(attempts: u32, base_ms: u64) -> RetryPolicy {
        RetryPolicy { attempts, base_ms, ..RetryPolicy::default() }
    }

    /// Backoff before recovery round `attempt` (1-based), jittered.
    pub fn delay(&self, attempt: u32, rng: &mut Rng) -> std::time::Duration {
        let exp = self.base_ms.saturating_mul(1u64 << (attempt - 1).min(16));
        let capped = exp.min(self.max_ms) as f64;
        std::time::Duration::from_secs_f64(capped * (0.5 + 0.5 * rng.f64()) / 1_000.0)
    }
}

/// Cross-process driver: the edge half of a deployment generating against
/// a remote `splitserve cloud` over a real socket. Link outcomes are
/// measured wall time; the remote server's compute seconds come back in
/// each reply frame, so `StepStats` keeps the same shape as the
/// single-process drivers.
///
/// With a [`RetryPolicy`] and a reconnect closure installed, the client
/// is crash-recovering: a wire failure mid-step triggers reconnect →
/// `Resume` handshake (epoch-fenced) → retransmission of the SAME
/// payload. The in-flight step's edge compute already mutated the request
/// state, so the session is never re-polled — and because sampling is
/// (seed, request, pos)-keyed, the recovered stream is bit-identical to
/// an undisturbed run.
pub struct EdgeClient {
    pub edge: EdgeDevice,
    pub port: EdgePort,
    pub controller: Option<EarlyExitController>,
    /// Reconnect-and-retry schedule for `generate_resilient` / `resume`.
    pub retry: RetryPolicy,
    /// How to re-establish the wire after a failure (e.g. re-dial the
    /// cloud's listen address). None = recover on the existing transport.
    reconnect: Option<Box<dyn FnMut() -> Result<WireTransport>>>,
}

impl EdgeClient {
    pub fn new(edge: EdgeDevice, transport: SocketTransport) -> EdgeClient {
        EdgeClient::over(edge, WireTransport::Socket(transport))
    }

    /// Generic constructor over any wire (chaos tests wrap a faulty
    /// transport; production wraps a socket).
    pub fn over(edge: EdgeDevice, transport: WireTransport) -> EdgeClient {
        EdgeClient {
            edge,
            port: EdgePort::new(transport),
            controller: None,
            retry: RetryPolicy::default(),
            reconnect: None,
        }
    }

    /// Install the reconnect closure used by recovery (returns a fresh
    /// transport to the same cloud).
    pub fn on_reconnect(&mut self, f: Box<dyn FnMut() -> Result<WireTransport>>) {
        self.reconnect = Some(f);
    }

    /// Push a control-plane reconfiguration to the remote cloud (frame
    /// kind 3): the server records the announced settings for the
    /// session and holds its subsequent payloads to them. The frame is
    /// one-way — the server sends no reply for control traffic — so the
    /// payload/reply rhythm of `generate` is undisturbed.
    pub fn reconfigure(&mut self, rc: &crate::adapt::Reconfig) -> Result<()> {
        self.port.send_reconfig(rc)?;
        Ok(())
    }

    /// Plan the session's prefix engagement: when the edge holds a warm
    /// entry, run the probe handshake against the remote cloud and
    /// downgrade to an insert on a miss (or a mis-addressed ack).
    fn plan_prefix(&mut self, req: &Request) -> Result<PrefixDecision> {
        let mut decision = self.edge.prefix_decision(&req.prompt);
        if let PrefixDecision::Warm { digest, prefix_len } = decision {
            let probe =
                PrefixProbe { request_id: req.id, digest, prefix_len: prefix_len as u32 };
            self.port.send_prefix_probe(&probe)?;
            let (ack, _) = self.port.recv_prefix_ack()?;
            if !(ack.hit && ack.digest == digest) {
                decision = PrefixDecision::Insert { digest, prefix_len };
            }
        }
        Ok(decision)
    }

    /// Run a full request to completion against the remote cloud.
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let decision = self.plan_prefix(req)?;
        let EdgeClient { edge, port, controller, .. } = self;
        let mut session = Session::for_edge(req.clone(), edge, *controller);
        session.set_prefix_decision(decision);
        drive_prepared(&mut session, edge, |payload| {
            let up = port.send_payload(payload)?;
            let (reply, server_s, mut down) = port.recv_reply()?;
            // The blocking recv's wall time spans the server's whole
            // turnaround; the server's own compute seconds arrive in the
            // timing prefix and are recorded as cloud_compute_s, so they
            // must come OUT of the measured downlink or StepStats would
            // count them twice.
            down.latency_s = (down.latency_s - server_s).max(0.0);
            Ok((reply, server_s, up, down))
        })?;
        Ok(session.into_result())
    }

    /// Like [`generate`](EdgeClient::generate), but every wire failure is
    /// survived up to the [`RetryPolicy`]: backoff → reconnect → `Resume`
    /// handshake → retransmit the in-flight payload. In-band typed
    /// rejections from the cloud ([`WireError::Rejected`]) are NOT
    /// retried — the cloud answered; the answer was no.
    pub fn generate_resilient(&mut self, req: &Request) -> Result<GenerationResult> {
        let mut session = Session::for_edge(req.clone(), &self.edge, self.controller);
        session.set_prefix_decision(self.plan_prefix(req)?);
        self.drive_resilient(&mut session)?;
        Ok(session.into_result())
    }

    /// Continue a snapshotted session against the (possibly restarted)
    /// cloud: restore, fence the dead connection's stragglers with a
    /// `Resume` handshake, then drive to completion under the same
    /// recovery schedule as `generate_resilient`. Already-delivered
    /// tokens are NOT recomputed — generation picks up at the snapshot's
    /// next position.
    pub fn resume(&mut self, snap: SessionSnapshot) -> Result<GenerationResult> {
        let mut session = Session::restore(snap, &self.edge, self.controller)?;
        self.reestablish(&mut session)?;
        self.drive_resilient(&mut session)?;
        Ok(session.into_result())
    }

    fn drive_resilient(&mut self, session: &mut Session) -> Result<()> {
        let mut rng = Rng::new(self.retry.seed ^ session.request_id().rotate_left(17));
        loop {
            match session.poll(&self.edge)? {
                SessionAction::Transmit(payload) => {
                    let (reply, server_s, up, down) =
                        match self.exchange_with_recovery(session, &payload, &mut rng) {
                            Ok(ok) => ok,
                            // Typed PREFIX reject: the cloud cannot honor
                            // the warm token (evicted, migrated, stale) —
                            // rebuild as a full insert and retransmit.
                            Err(e) if is_prefix_reject(&e) => {
                                let rebuilt = session.rebuild_prefill_as_insert(&self.edge)?;
                                self.exchange_with_recovery(session, &rebuilt, &mut rng)?
                            }
                            Err(e) => return Err(e),
                        };
                    session.on_reply(&self.edge, &reply, server_s, up, down)?;
                }
                SessionAction::Yield => unreachable!("no in-flight IO in the blocking driver"),
                SessionAction::Finished => return Ok(()),
            }
        }
    }

    /// One payload/reply exchange, surviving wire failures up to the
    /// retry budget. Recovery retransmits the SAME payload — never
    /// re-runs the edge step — so a fault can duplicate work on the
    /// stateless cloud but never fork the session's state.
    fn exchange_with_recovery(
        &mut self,
        session: &mut Session,
        payload: &SplitPayload,
        rng: &mut Rng,
    ) -> Result<(CloudReply, f64, TransferOutcome, TransferOutcome)> {
        let mut attempt = 0u32;
        loop {
            let err = match self.try_exchange(payload) {
                Ok(ok) => return Ok(ok),
                Err(e) => e,
            };
            let rejected =
                matches!(err.downcast_ref::<WireError>(), Some(WireError::Rejected { .. }));
            if rejected || attempt >= self.retry.attempts {
                return Err(err.context(format!(
                    "request {} position {:?}: exchange failed after {attempt} recoveries",
                    session.request_id(),
                    session.pending_pos(),
                )));
            }
            attempt += 1;
            std::thread::sleep(self.retry.delay(attempt, rng));
            if let Err(e) = self.reestablish(session) {
                if attempt >= self.retry.attempts {
                    return Err(e.context("re-establishing the cloud connection"));
                }
                // Burn the round and let the next one re-dial again.
                continue;
            }
        }
    }

    fn try_exchange(
        &mut self,
        payload: &SplitPayload,
    ) -> Result<(CloudReply, f64, TransferOutcome, TransferOutcome)> {
        let up = self.port.send_payload(payload)?;
        let mut skipped = 0u32;
        loop {
            // A duplicated or reordered frame can deliver a reply — or an
            // in-band stale-position rejection — for an already-answered
            // position (the cloud's replay fence echoes duplicates and
            // refuses regressions). The fence trails the edge, so neither
            // can refer to the in-flight payload: discard a bounded
            // number of them rather than absorbing a stale answer.
            let (reply, server_s, mut down) = match self.port.recv_reply() {
                Ok(ok) => ok,
                Err(e)
                    if matches!(
                        e.downcast_ref::<WireError>(),
                        Some(WireError::Rejected { code: reject::STALE_POS, .. })
                    ) =>
                {
                    skipped += 1;
                    anyhow::ensure!(
                        skipped <= 8,
                        "request {}: discarded {skipped} stale replies awaiting position {}",
                        payload.request_id,
                        payload.pos
                    );
                    continue;
                }
                Err(e) => return Err(e),
            };
            if reply.request_id != payload.request_id || reply.pos != payload.pos as u64 {
                skipped += 1;
                anyhow::ensure!(
                    skipped <= 8,
                    "request {}: discarded {skipped} stale replies awaiting position {}",
                    payload.request_id,
                    payload.pos
                );
                continue;
            }
            down.latency_s = (down.latency_s - server_s).max(0.0);
            return Ok((reply, server_s, up, down));
        }
    }

    /// Reconnect (when a closure is installed), discard queued
    /// stragglers, and run the `Resume` handshake: the cloud fences the
    /// dead connection's epoch and re-learns the session's announced
    /// transmission settings.
    fn reestablish(&mut self, session: &mut Session) -> Result<()> {
        if let Some(reconnect) = self.reconnect.as_mut() {
            self.port = EdgePort::new(reconnect()?);
        }
        self.port.transport.drain();
        let epoch = session.bump_resume_epoch();
        let settings = session.settings();
        let rs = Resume {
            request_id: session.request_id(),
            epoch,
            next_pos: session.pending_pos().or(session.seq_len()).unwrap_or(0) as u64,
            qa_bits: settings.qa_bits,
            tau: session.current_tau(&self.edge),
            include_kv: settings.include_kv,
        };
        self.port.send_resume(&rs)?;
        let mut skipped = 0u32;
        let ack = loop {
            match self.port.recv_resume_ack() {
                Ok((ack, _)) => break ack,
                // Same-transport recovery can still have stragglers in
                // the pipe ahead of the ack — replies (WrongKind) or
                // stale-position echoes from the replay fence; skip a
                // bounded few. A stale-EPOCH rejection stays fatal: that
                // is the cloud refusing THIS resume.
                Err(e)
                    if skipped < 8
                        && matches!(
                            e.downcast_ref::<WireError>(),
                            Some(WireError::WrongKind { .. })
                                | Some(WireError::Rejected {
                                    code: reject::STALE_POS,
                                    ..
                                })
                        ) =>
                {
                    skipped += 1;
                }
                Err(e) => return Err(e),
            }
        };
        anyhow::ensure!(
            ack.request_id == rs.request_id && ack.epoch == epoch,
            "resume ack mismatch: got request {} epoch {}, want request {} epoch {epoch}",
            ack.request_id,
            ack.epoch,
            rs.request_id
        );
        Ok(())
    }
}
