//! SplitPipeline: one edge device + one cloud server + the wireless link,
//! composed into a blocking single-request driver over the sans-IO
//! [`Session`](super::session::Session) state machine. Every byte on the
//! wire is a real serialized payload, every latency is a measured compute
//! time or a simulated link event.
//!
//! The generation logic itself (decode loop, Algorithm-2 escalation,
//! `StepStats` accounting) lives in `Session`; this driver only performs
//! the IO the session asks for. The many-to-one counterpart that shares
//! one `CloudServer` across interleaved sessions — and stacks their
//! decode steps into batched engine calls — is
//! [`ServeLoop`](super::serve_loop::ServeLoop). Both run on the in-place
//! engine contract: decode mutates the request's KV caches through
//! `&mut LayerKv` and never copies a full cache.

use anyhow::Result;

use super::cloud::CloudServer;
use super::edge::EdgeDevice;
use super::request::{GenerationResult, Request};
use super::session::{Session, SessionAction};
use crate::channel::LinkSim;
use crate::planner::EarlyExitController;

pub struct SplitPipeline {
    pub edge: EdgeDevice,
    pub cloud: CloudServer,
    pub link: LinkSim,
    /// Early-exit controller (None = best-effort, no deadline).
    pub controller: Option<EarlyExitController>,
}

impl SplitPipeline {
    pub fn new(edge: EdgeDevice, cloud: CloudServer, link: LinkSim) -> SplitPipeline {
        SplitPipeline { edge, cloud, link, controller: None }
    }

    /// Run a full request to completion. EOS is vocabulary token 0
    /// (synthetic convention). Behavior-identical to driving a fresh
    /// `Session` by hand: poll → transmit → reply, until finished.
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let mut session = Session::for_edge(req.clone(), &self.edge, self.controller);
        loop {
            match session.poll(&self.edge)? {
                SessionAction::Transmit(payload) => {
                    let up = self.link.transfer(payload.wire_bytes());
                    let (reply, cloud_s) = self.cloud.handle(&payload)?;
                    let down = self.link.transfer(reply.wire_bytes());
                    session.on_reply(&self.edge, &reply, cloud_s, up, down);
                }
                // A single blocking driver never observes Yield: every
                // transmit is answered before the next poll.
                SessionAction::Yield => unreachable!("no in-flight IO in the blocking driver"),
                SessionAction::Finished => return Ok(session.into_result()),
            }
        }
    }
}
