//! SplitPipeline: one edge device + the cloud server + the wireless link +
//! the Algorithm-2 early-exit controller, composed into a full
//! autoregressive generation loop. This is the end-to-end request path —
//! every byte on the wire is a real serialized payload, every latency is a
//! measured compute time or a simulated link event.

use anyhow::Result;

use super::cloud::CloudServer;
use super::edge::{EdgeDevice, EdgeRequestState};
use super::request::{GenerationResult, Request, StepStats};
use crate::channel::LinkSim;
use crate::planner::{EarlyExitController, ExitDecision, TxSettings};

pub struct SplitPipeline {
    pub edge: EdgeDevice,
    pub cloud: CloudServer,
    pub link: LinkSim,
    /// Early-exit controller (None = best-effort, no deadline).
    pub controller: Option<EarlyExitController>,
}

impl SplitPipeline {
    pub fn new(edge: EdgeDevice, cloud: CloudServer, link: LinkSim) -> SplitPipeline {
        SplitPipeline { edge, cloud, link, controller: None }
    }

    /// Run a full request. EOS is vocabulary token 0 (synthetic convention).
    pub fn generate(&mut self, req: &Request) -> Result<GenerationResult> {
        let mut result = GenerationResult { request_id: req.id, ..Default::default() };
        let mut settings = TxSettings {
            qa_bits: self.edge.compression.q_bar,
            include_kv: true,
        };

        // ---- prefill ----
        let (payload, mut state, edge_s) = self.edge.prefill(req.id, &req.prompt)?;
        let up = self.link.transfer(payload.wire_bytes());
        let (reply, cloud_s) = self.cloud.handle(&payload)?;
        let down = self.link.transfer(reply.wire_bytes());
        self.edge.absorb_reply(&mut state, payload.pos, &reply.new_kv_rows);
        result.prefill = StepStats {
            edge_compute_s: edge_s,
            cloud_compute_s: cloud_s,
            uplink_s: up.latency_s,
            downlink_s: down.latency_s,
            uplink_bytes: up.payload_bytes,
            downlink_bytes: down.payload_bytes,
            outage: up.outage || down.outage,
            chosen_bits: payload.hidden.chosen_bits,
            kv_transmitted: false,
        };
        let mut next_token = reply.token;

        // ---- decode loop ----
        let mut budget = req.max_new_tokens;
        while budget > 0 {
            result.tokens.push(next_token);
            budget -= 1;
            if next_token == 0 || budget == 0 {
                break; // EOS or budget exhausted
            }
            if state.seq_len() + 1 >= self.edge.node.weights.cfg.max_seq {
                break; // static KV cache full
            }

            // Edge compute + provisional payload under current settings.
            let (mut payload, edge_s) = self.edge.decode_step(
                &mut state,
                next_token,
                settings.include_kv,
                Some(settings.qa_bits),
            )?;

            // Algorithm 2: check the deadline, escalate if needed.
            if let Some(ctrl) = &self.controller {
                let state_ref = &state;
                let edge_dev = &self.edge;
                let oracle = |s: TxSettings| -> u64 {
                    edge_dev
                        .payload_size_probe(state_ref, s)
                        .unwrap_or(u64::MAX / 4)
                };
                match ctrl.decide(edge_s, settings, &oracle) {
                    ExitDecision::Proceed { .. } => {}
                    ExitDecision::Escalate { settings: s, .. } => {
                        settings = s;
                        payload = self.edge.rebuild_payload(&state, settings)?;
                    }
                    ExitDecision::ReduceTokens { tokens_to_drop, .. } => {
                        result.tokens_dropped = budget.min(tokens_to_drop);
                        result.final_settings = Some(settings);
                        break; // early exit: stop generating
                    }
                }
            }

            let up = self.link.transfer(payload.wire_bytes());
            let (reply, cloud_s) = self.cloud.handle(&payload)?;
            let down = self.link.transfer(reply.wire_bytes());
            if settings.include_kv {
                self.edge.absorb_reply(&mut state, payload.pos, &reply.new_kv_rows);
            }
            result.steps.push(StepStats {
                edge_compute_s: edge_s,
                cloud_compute_s: cloud_s,
                uplink_s: up.latency_s,
                downlink_s: down.latency_s,
                uplink_bytes: up.payload_bytes,
                downlink_bytes: down.payload_bytes,
                outage: up.outage || down.outage,
                chosen_bits: payload.hidden.chosen_bits,
                kv_transmitted: settings.include_kv,
            });
            next_token = reply.token;
        }
        result.final_settings = Some(settings);
        Ok(result)
    }
}

impl EdgeDevice {
    /// Payload-size oracle for the early-exit controller: what WOULD the
    /// wire size be under `settings`, given the current request state?
    /// Uses the memory model for speed (the controller probes several
    /// settings per step); the actual transmitted payload is re-built and
    /// measured exactly.
    pub fn payload_size_probe(
        &self,
        state: &EdgeRequestState,
        settings: TxSettings,
    ) -> Result<u64> {
        let cfg = &self.node.weights.cfg;
        let w = state.seq_len();
        let qa = crate::memory::ActBits::uniform(settings.qa_bits);
        let split = self.node.layer_range.end;
        if settings.include_kv {
            Ok(crate::memory::io_bytes(cfg, w, split, true, &qa))
        } else {
            if w > cfg.prefill_len {
                // I_kv=0 impossible beyond the prefill width — make it
                // unattractive rather than erroring inside the controller.
                return Ok(u64::MAX / 4);
            }
            Ok(crate::memory::io_bytes(cfg, w, split, false, &qa))
        }
    }

    /// Rebuild the current step's payload under escalated settings (the
    /// front-segment compute is NOT redone — only compression changes).
    pub fn rebuild_payload(
        &self,
        state: &EdgeRequestState,
        settings: TxSettings,
    ) -> Result<super::protocol::SplitPayload> {
        let cfg = &self.node.weights.cfg;
        let d = cfg.d_model;
        let w = state.seq_len();
        let pos = w - 1;
        let mut comp = self.compression;
        comp.q_bar = settings.qa_bits;
        let last_hidden = &state.hidden_history[pos * d..w * d];
        let (hidden, kv) = if settings.include_kv {
            let hidden = self.compress_block(last_hidden, 1, d, &comp);
            let kv = super::protocol::CompressedKv::compress_with_pool(
                &state.cloud_kv,
                pos,
                cfg.kv_width(),
                &comp,
                &self.scratch,
            );
            (hidden, Some(kv))
        } else {
            anyhow::ensure!(w <= cfg.prefill_len, "I_kv=0 beyond prefill width");
            let hidden = self.compress_block(&state.hidden_history, w, d, &comp);
            (hidden, None)
        };
        Ok(super::protocol::SplitPayload {
            request_id: state.request_id,
            pos,
            hidden,
            kv,
            is_prefill: false,
        })
    }
}
