//! Versioned byte codec for session durability: everything needed to
//! rehost one in-flight generation request — on the same edge after a
//! crash, or on another process entirely.
//!
//! The snapshot captures the session at a quiescent point (no
//! transmission in flight): the request, the accumulated result, the
//! Algorithm-2 settings, the resumption epoch, and the edge-held request
//! state with its KV caches and hidden history as **raw f32**. Raw
//! matters: the wire's two-stage compression (TS → TAB-Q → rANS) is
//! lossy, so a snapshot that round-tripped state through `CompressedKv`
//! would resume a *different* stream. This codec is exact — a restored
//! session produces bit-identical tokens.
//!
//! Layout (little-endian, strict decode in the `wire::codec` style):
//!
//! ```text
//! [magic   u32]  0x53534E50 ("PNSS" on the wire — "SSNP" big-endian)
//! [version u8 ]  1
//! [body       ]  request | control | result | state (see below)
//! [crc32   u32]  IEEE CRC-32 over version + body
//! ```
//!
//! Like the wire frames, decoding is strict: truncation, corruption,
//! unknown flags and inconsistent dimensions are typed [`WireError`]s,
//! never panics.

use super::request::{GenerationResult, Request, StepStats};
use super::sampling::SamplingSpec;
use super::session::SessionPhase;
use crate::planner::TxSettings;
use crate::wire::codec::Reader;
use crate::wire::frame::{crc32, WireError};

/// Snapshot format version.
pub const SNAPSHOT_VERSION: u8 = 1;
/// "SSNP" — splitserve snapshot.
pub const SNAPSHOT_MAGIC: u32 = 0x5353_4E50;

const FLAG_DEADLINE: u8 = 1;
const FLAG_TOPK: u8 = 1 << 1;

const FLAG_INCLUDE_KV: u8 = 1;
const FLAG_TAU: u8 = 1 << 1;
const FLAG_KV_STALE: u8 = 1 << 2;
const FLAG_STATE: u8 = 1 << 3;
const FLAG_FINAL_SETTINGS: u8 = 1 << 4;
const FLAG_FINAL_KV: u8 = 1 << 5;

const STAT_OUTAGE: u8 = 1;
const STAT_KV: u8 = 1 << 1;

/// Edge-held request state, trimmed to the rows actually used (the
/// restore pads back to the deployment's `max_seq` with zeros).
#[derive(Clone, Debug, PartialEq)]
pub struct StateSnapshot {
    /// Front-layer (k, v) caches, `seq_len * kv_width` floats each.
    pub front_kv: Vec<(Vec<f32>, Vec<f32>)>,
    /// Cloud-layer (k, v) caches, same trim.
    pub cloud_kv: Vec<(Vec<f32>, Vec<f32>)>,
    /// Split-layer hidden state of every token so far (`seq_len * d`).
    pub hidden_history: Vec<f32>,
    /// Tokens so far (prompt + generated).
    pub tokens: Vec<u32>,
}

/// A session at a quiescent point, ready to serialize or restore. Built
/// by [`Session::snapshot`](super::Session::snapshot), consumed by
/// [`Session::restore`](super::Session::restore).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionSnapshot {
    pub request: Request,
    pub phase: SessionPhase,
    pub settings: TxSettings,
    pub tau_override: Option<f32>,
    pub next_token: u32,
    pub budget: usize,
    pub cloud_kv_stale: bool,
    pub resume_epoch: u32,
    pub result: GenerationResult,
    pub state: Option<StateSnapshot>,
}

fn malformed(m: impl Into<String>) -> WireError {
    WireError::Malformed(m.into())
}

fn write_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(r: &mut Reader, n: usize) -> Result<Vec<f32>, WireError> {
    let bytes = r.take(n.checked_mul(4).ok_or_else(|| malformed("f32 count overflow"))?)?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn write_stats(out: &mut Vec<u8>, s: &StepStats) {
    out.extend_from_slice(&s.edge_compute_s.to_le_bytes());
    out.extend_from_slice(&s.cloud_compute_s.to_le_bytes());
    out.extend_from_slice(&s.uplink_s.to_le_bytes());
    out.extend_from_slice(&s.downlink_s.to_le_bytes());
    out.extend_from_slice(&s.uplink_bytes.to_le_bytes());
    out.extend_from_slice(&s.downlink_bytes.to_le_bytes());
    out.extend_from_slice(&s.chosen_bits.to_le_bytes());
    let mut flags = 0u8;
    if s.outage {
        flags |= STAT_OUTAGE;
    }
    if s.kv_transmitted {
        flags |= STAT_KV;
    }
    out.push(flags);
}

fn read_stats(r: &mut Reader) -> Result<StepStats, WireError> {
    let edge_compute_s = r.f64()?;
    let cloud_compute_s = r.f64()?;
    let uplink_s = r.f64()?;
    let downlink_s = r.f64()?;
    let uplink_bytes = r.u64()?;
    let downlink_bytes = r.u64()?;
    let chosen_bits = r.u32()?;
    let flags = r.u8()?;
    if flags & !(STAT_OUTAGE | STAT_KV) != 0 {
        return Err(malformed(format!("unknown step-stat flags {flags:#04x}")));
    }
    Ok(StepStats {
        edge_compute_s,
        cloud_compute_s,
        uplink_s,
        downlink_s,
        uplink_bytes,
        downlink_bytes,
        outage: flags & STAT_OUTAGE != 0,
        chosen_bits,
        kv_transmitted: flags & STAT_KV != 0,
    })
}

fn phase_to_u8(p: SessionPhase) -> u8 {
    match p {
        SessionPhase::NeedPrefill => 0,
        SessionPhase::AwaitingReply => 1,
        SessionPhase::ReadyToDecode => 2,
        SessionPhase::Done => 3,
        SessionPhase::Cancelled => 4,
    }
}

fn phase_from_u8(b: u8) -> Result<SessionPhase, WireError> {
    match b {
        0 => Ok(SessionPhase::NeedPrefill),
        2 => Ok(SessionPhase::ReadyToDecode),
        3 => Ok(SessionPhase::Done),
        4 => Ok(SessionPhase::Cancelled),
        1 => Err(malformed("snapshot captured mid-flight (AwaitingReply)")),
        other => Err(malformed(format!("unknown session phase {other}"))),
    }
}

/// Guard a length field before allocating for it: the bytes must
/// actually be present in the buffer.
fn guard(r: &Reader, items: usize, item_bytes: usize) -> Result<(), WireError> {
    let need = items
        .checked_mul(item_bytes)
        .ok_or_else(|| malformed("snapshot length overflow"))?;
    if r.remaining() < need {
        return Err(WireError::Truncated { need, have: r.remaining() });
    }
    Ok(())
}

impl SessionSnapshot {
    /// Serialize to the versioned, CRC-protected byte form.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&SNAPSHOT_MAGIC.to_le_bytes());
        out.push(SNAPSHOT_VERSION);
        // --- request ---
        let rq = &self.request;
        out.extend_from_slice(&rq.id.to_le_bytes());
        out.extend_from_slice(&(rq.prompt.len() as u32).to_le_bytes());
        for &t in &rq.prompt {
            out.extend_from_slice(&t.to_le_bytes());
        }
        out.extend_from_slice(&(rq.max_new_tokens as u32).to_le_bytes());
        let mut rflags = 0u8;
        if rq.deadline_s.is_some() {
            rflags |= FLAG_DEADLINE;
        }
        if matches!(rq.sampling, SamplingSpec::TopK { .. }) {
            rflags |= FLAG_TOPK;
        }
        out.push(rflags);
        if let Some(d) = rq.deadline_s {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out.extend_from_slice(&rq.arrival_s.to_le_bytes());
        if let SamplingSpec::TopK { k, temperature, seed } = rq.sampling {
            out.extend_from_slice(&(k as u16).to_le_bytes());
            out.extend_from_slice(&temperature.to_le_bytes());
            out.extend_from_slice(&seed.to_le_bytes());
        }
        // --- control ---
        out.push(phase_to_u8(self.phase));
        out.extend_from_slice(&self.settings.qa_bits.to_le_bytes());
        let mut cflags = 0u8;
        if self.settings.include_kv {
            cflags |= FLAG_INCLUDE_KV;
        }
        if self.tau_override.is_some() {
            cflags |= FLAG_TAU;
        }
        if self.cloud_kv_stale {
            cflags |= FLAG_KV_STALE;
        }
        if self.state.is_some() {
            cflags |= FLAG_STATE;
        }
        if let Some(fs) = self.result.final_settings {
            cflags |= FLAG_FINAL_SETTINGS;
            if fs.include_kv {
                cflags |= FLAG_FINAL_KV;
            }
        }
        out.push(cflags);
        if let Some(tau) = self.tau_override {
            out.extend_from_slice(&tau.to_le_bytes());
        }
        out.extend_from_slice(&self.next_token.to_le_bytes());
        out.extend_from_slice(&(self.budget as u32).to_le_bytes());
        out.extend_from_slice(&self.resume_epoch.to_le_bytes());
        // --- result ---
        let rs = &self.result;
        out.extend_from_slice(&(rs.tokens.len() as u32).to_le_bytes());
        for &t in &rs.tokens {
            out.extend_from_slice(&t.to_le_bytes());
        }
        write_stats(&mut out, &rs.prefill);
        out.extend_from_slice(&(rs.steps.len() as u32).to_le_bytes());
        for s in &rs.steps {
            write_stats(&mut out, s);
        }
        out.extend_from_slice(&(rs.tokens_dropped as u32).to_le_bytes());
        out.extend_from_slice(&(rs.reconfigs as u32).to_le_bytes());
        if let Some(fs) = rs.final_settings {
            out.extend_from_slice(&fs.qa_bits.to_le_bytes());
        }
        // --- state ---
        if let Some(st) = &self.state {
            let rows = st.tokens.len();
            let kv_floats = st.front_kv.first().or(st.cloud_kv.first()).map_or(0, |l| l.0.len());
            debug_assert!(rows == 0 || kv_floats % rows == 0, "ragged snapshot KV");
            out.extend_from_slice(&(st.front_kv.len() as u16).to_le_bytes());
            out.extend_from_slice(&(st.cloud_kv.len() as u16).to_le_bytes());
            out.extend_from_slice(&(rows as u32).to_le_bytes());
            out.extend_from_slice(&(kv_floats as u32).to_le_bytes());
            out.extend_from_slice(&(st.hidden_history.len() as u32).to_le_bytes());
            for &t in &st.tokens {
                out.extend_from_slice(&t.to_le_bytes());
            }
            write_f32s(&mut out, &st.hidden_history);
            for (k, v) in st.front_kv.iter().chain(&st.cloud_kv) {
                debug_assert!(k.len() == kv_floats && v.len() == kv_floats);
                write_f32s(&mut out, k);
                write_f32s(&mut out, v);
            }
        }
        let crc = crc32(&out[4..]);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Strict decode: magic, version, CRC, structure, full consumption.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionSnapshot, WireError> {
        if bytes.len() < 9 {
            return Err(WireError::Truncated { need: 9, have: bytes.len() });
        }
        let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        if magic != SNAPSHOT_MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        if bytes[4] != SNAPSHOT_VERSION {
            return Err(WireError::BadVersion(bytes[4]));
        }
        let want = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        let got = crc32(&bytes[4..bytes.len() - 4]);
        if want != got {
            return Err(WireError::Crc { want, got });
        }
        let mut r = Reader::new(&bytes[5..bytes.len() - 4]);
        // --- request ---
        let id = r.u64()?;
        let prompt_len = r.u32()? as usize;
        guard(&r, prompt_len, 4)?;
        let mut prompt = Vec::with_capacity(prompt_len);
        for _ in 0..prompt_len {
            prompt.push(r.u32()?);
        }
        let max_new_tokens = r.u32()? as usize;
        let rflags = r.u8()?;
        if rflags & !(FLAG_DEADLINE | FLAG_TOPK) != 0 {
            return Err(malformed(format!("unknown request flags {rflags:#04x}")));
        }
        let deadline_s = if rflags & FLAG_DEADLINE != 0 { Some(r.f64()?) } else { None };
        let arrival_s = r.f64()?;
        let sampling = if rflags & FLAG_TOPK != 0 {
            let k = r.u16()? as usize;
            let temperature = r.f32()?;
            let seed = r.u64()?;
            SamplingSpec::TopK { k, temperature, seed }
        } else {
            SamplingSpec::Greedy
        };
        let request =
            Request { id, prompt, max_new_tokens, deadline_s, arrival_s, sampling };
        // --- control ---
        let phase = phase_from_u8(r.u8()?)?;
        let qa_bits = r.u32()?;
        let cflags = r.u8()?;
        let known = FLAG_INCLUDE_KV
            | FLAG_TAU
            | FLAG_KV_STALE
            | FLAG_STATE
            | FLAG_FINAL_SETTINGS
            | FLAG_FINAL_KV;
        if cflags & !known != 0 {
            return Err(malformed(format!("unknown control flags {cflags:#04x}")));
        }
        let settings = TxSettings { qa_bits, include_kv: cflags & FLAG_INCLUDE_KV != 0 };
        let tau_override = if cflags & FLAG_TAU != 0 { Some(r.f32()?) } else { None };
        let next_token = r.u32()?;
        let budget = r.u32()? as usize;
        let resume_epoch = r.u32()?;
        // --- result ---
        let n_tokens = r.u32()? as usize;
        guard(&r, n_tokens, 4)?;
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            tokens.push(r.u32()?);
        }
        let prefill = read_stats(&mut r)?;
        let n_steps = r.u32()? as usize;
        guard(&r, n_steps, 53)?;
        let mut steps = Vec::with_capacity(n_steps);
        for _ in 0..n_steps {
            steps.push(read_stats(&mut r)?);
        }
        let tokens_dropped = r.u32()? as usize;
        let reconfigs = r.u32()? as usize;
        let final_settings = if cflags & FLAG_FINAL_SETTINGS != 0 {
            Some(TxSettings { qa_bits: r.u32()?, include_kv: cflags & FLAG_FINAL_KV != 0 })
        } else {
            None
        };
        let result = GenerationResult {
            request_id: id,
            tokens,
            prefill,
            steps,
            tokens_dropped,
            reconfigs,
            final_settings,
        };
        // --- state ---
        let state = if cflags & FLAG_STATE != 0 {
            let n_front = r.u16()? as usize;
            let n_cloud = r.u16()? as usize;
            let rows = r.u32()? as usize;
            let kv_floats = r.u32()? as usize;
            let hidden_len = r.u32()? as usize;
            if rows > 0 && kv_floats % rows != 0 {
                return Err(malformed(format!(
                    "KV layer of {kv_floats} floats is not a multiple of {rows} rows"
                )));
            }
            guard(&r, rows, 4)?;
            let mut st_tokens = Vec::with_capacity(rows);
            for _ in 0..rows {
                st_tokens.push(r.u32()?);
            }
            guard(&r, hidden_len, 4)?;
            let hidden_history = read_f32s(&mut r, hidden_len)?;
            let n_layers = n_front
                .checked_add(n_cloud)
                .ok_or_else(|| malformed("layer count overflow"))?;
            guard(&r, n_layers.max(1), kv_floats.saturating_mul(8))?;
            let mut read_layers = |n: usize| -> Result<Vec<(Vec<f32>, Vec<f32>)>, WireError> {
                let mut layers = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = read_f32s(&mut r, kv_floats)?;
                    let v = read_f32s(&mut r, kv_floats)?;
                    layers.push((k, v));
                }
                Ok(layers)
            };
            let front_kv = read_layers(n_front)?;
            let cloud_kv = read_layers(n_cloud)?;
            Some(StateSnapshot { front_kv, cloud_kv, hidden_history, tokens: st_tokens })
        } else {
            None
        };
        r.done()?;
        Ok(SessionSnapshot {
            request,
            phase,
            settings,
            tau_override,
            next_token,
            budget,
            cloud_kv_stale: cflags & FLAG_KV_STALE != 0,
            resume_epoch,
            result,
            state,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> SessionSnapshot {
        SessionSnapshot {
            request: Request {
                id: 42,
                prompt: vec![3, 1, 4, 1, 5],
                max_new_tokens: 9,
                deadline_s: Some(0.75),
                arrival_s: 1.5,
                sampling: SamplingSpec::TopK { k: 8, temperature: 0.9, seed: 77 },
            },
            phase: SessionPhase::ReadyToDecode,
            settings: TxSettings { qa_bits: 4, include_kv: true },
            tau_override: Some(10.0),
            next_token: 17,
            budget: 6,
            cloud_kv_stale: false,
            resume_epoch: 2,
            result: GenerationResult {
                request_id: 42,
                tokens: vec![17, 23],
                prefill: StepStats {
                    edge_compute_s: 0.01,
                    uplink_bytes: 1200,
                    chosen_bits: 4,
                    ..Default::default()
                },
                steps: vec![StepStats {
                    cloud_compute_s: 0.02,
                    downlink_bytes: 300,
                    outage: true,
                    kv_transmitted: true,
                    chosen_bits: 3,
                    ..Default::default()
                }],
                tokens_dropped: 1,
                reconfigs: 2,
                final_settings: Some(TxSettings { qa_bits: 3, include_kv: false }),
            },
            state: Some(StateSnapshot {
                front_kv: vec![(vec![0.5; 14], vec![-0.5; 14]); 2],
                cloud_kv: vec![(vec![1.25; 14], vec![2.5; 14]); 3],
                hidden_history: (0..28).map(|i| i as f32 * 0.125).collect(),
                tokens: vec![3, 1, 4, 1, 5, 17, 23],
            }),
        }
    }

    #[test]
    fn roundtrip_is_exact() {
        let snap = sample_snapshot();
        let bytes = snap.to_bytes();
        let back = SessionSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(format!("{snap:?}"), format!("{back:?}"));
        assert_eq!(snap.state, back.state);
    }

    #[test]
    fn minimal_snapshot_roundtrips() {
        let mut snap = sample_snapshot();
        snap.state = None;
        snap.tau_override = None;
        snap.request.deadline_s = None;
        snap.request.sampling = SamplingSpec::Greedy;
        snap.result.final_settings = None;
        snap.phase = SessionPhase::NeedPrefill;
        let back = SessionSnapshot::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(format!("{snap:?}"), format!("{back:?}"));
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = sample_snapshot().to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                SessionSnapshot::from_bytes(&bytes[..cut]).is_err(),
                "truncation to {cut} must fail"
            );
        }
    }

    #[test]
    fn corruption_is_rejected() {
        let bytes = sample_snapshot().to_bytes();
        // flip a bit in every 7th byte (full sweep is slow at f32 scale)
        for byte in (4..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x10;
            assert!(
                SessionSnapshot::from_bytes(&bad).is_err(),
                "flip at byte {byte} must be detected"
            );
        }
    }

    #[test]
    fn mid_flight_phase_is_rejected() {
        // re-encode with a poisoned phase instead of hunting offsets
        let mut snap = sample_snapshot();
        snap.phase = SessionPhase::AwaitingReply;
        let bytes = snap.to_bytes();
        assert!(matches!(
            SessionSnapshot::from_bytes(&bytes),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn wrong_magic_and_version_are_typed() {
        let bytes = sample_snapshot().to_bytes();
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(SessionSnapshot::from_bytes(&bad), Err(WireError::BadMagic(_))));
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(matches!(
            SessionSnapshot::from_bytes(&bad),
            Err(WireError::BadVersion(99))
        ));
    }
}
