//! Deployment builder: wires a complete split deployment (quantized edge
//! front + full-precision cloud back + link + controller) from a handful
//! of knobs. This is the function examples, benches and the CLI all use —
//! one construction path, no copy-pasted setup.

use std::rc::Rc;

use anyhow::Result;

use super::cloud::CloudServer;
use super::edge::EdgeDevice;
use super::pipeline::SplitPipeline;
use super::profile::DeviceProfile;
use super::protocol::CompressionConfig;
use crate::channel::{optimize_rate, ChannelParams, LinkSim};
use crate::model::{ModelConfig, ModelWeights};
use crate::planner::{EarlyExitController, LatencyModel};
use crate::quant::{apply_opsc, OpscConfig};
use crate::runtime::{Engine, NodeRuntime};

#[derive(Clone, Debug)]
pub struct DeploymentSpec {
    pub model: ModelConfig,
    pub opsc: OpscConfig,
    pub compression: CompressionConfig,
    pub channel: ChannelParams,
    /// None → optimize via Eq. (13).
    pub rate_bps: Option<f64>,
    pub weight_seed: u64,
    pub link_seed: u64,
    /// Per-token deadline (enables the Algorithm-2 controller).
    pub deadline_s: Option<f64>,
    pub edge_profile: DeviceProfile,
    pub cloud_profile: DeviceProfile,
}

impl DeploymentSpec {
    pub fn defaults(model: ModelConfig, split: usize) -> DeploymentSpec {
        DeploymentSpec {
            model,
            opsc: OpscConfig::new(split, 4, 16),
            compression: CompressionConfig::default(),
            channel: ChannelParams::default(),
            rate_bps: None,
            weight_seed: 42,
            link_seed: 7,
            deadline_s: None,
            edge_profile: DeviceProfile::edge_default(),
            cloud_profile: DeviceProfile::cloud_default(),
        }
    }
}

/// Build the full pipeline. The engine can be shared across deployments
/// (pass the same Rc) — executables are compiled once per shape class.
pub fn build_pipeline(engine: Rc<Engine>, spec: &DeploymentSpec) -> Result<SplitPipeline> {
    let cfg = &spec.model;
    let split = spec.opsc.split_layer;
    anyhow::ensure!(
        split >= 1 && split <= cfg.n_layers,
        "split must keep at least one layer on the edge"
    );
    // split == n_layers is legal: the cloud runs only the lm head
    // (full-edge deployment, the Fig. 5 offload-maximizing regime).

    // Edge: front segment, OPSC-quantized.
    let mut edge_weights = ModelWeights::synthetic(cfg, spec.weight_seed);
    apply_opsc(&mut edge_weights, &spec.opsc);
    let edge_node = NodeRuntime::new(engine.clone(), Rc::new(edge_weights), 0..split, false)?;

    // Cloud: back segment, untouched full precision (paper §2.1: the
    // server maintains a single high-precision model).
    let cloud_weights = Rc::new(ModelWeights::synthetic(cfg, spec.weight_seed));
    let cloud_node = NodeRuntime::new(engine, cloud_weights, split..cfg.n_layers, true)?;

    let rate = spec
        .rate_bps
        .unwrap_or_else(|| optimize_rate(&spec.channel, 1e5, 4.0 * spec.channel.capacity_bps()));
    let link = LinkSim::new(spec.channel, rate, spec.link_seed);

    let edge = EdgeDevice::new(
        edge_node,
        cfg.n_layers - split,
        spec.edge_profile.clone(),
        spec.compression,
    );
    let cloud = CloudServer::new(cloud_node, spec.cloud_profile.clone());
    let mut pipeline = SplitPipeline::new(edge, cloud, link);
    if let Some(d) = spec.deadline_s {
        let hd = cfg.kv_width() as u64;
        pipeline.controller = Some(EarlyExitController {
            deadline_s: d,
            model: LatencyModel { channel: spec.channel, rate_bps: rate },
            min_qa_bits: 2,
            per_token_payload_bytes: hd * spec.compression.q_bar as u64 / 8,
        });
    }
    Ok(pipeline)
}
