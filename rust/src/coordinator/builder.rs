//! Deployment builder: wires a complete split deployment (quantized edge
//! front + full-precision cloud back + link + controller) from a handful
//! of knobs. This is the function examples, benches and the CLI all use —
//! one construction path, no copy-pasted setup.
//!
//! Two entry points share every construction detail:
//!   * [`build_pipeline`] — one edge + one cloud (the blocking
//!     single-session driver),
//!   * [`build_serve_loop`] — N edges + ONE shared cloud + router, the
//!     many-to-one continuous-batching deployment of Fig. 1(c).

use std::rc::Rc;

use anyhow::Result;

use super::batcher::BatcherParams;
use super::cloud::CloudServer;
use super::edge::EdgeDevice;
use super::pipeline::SplitPipeline;
use super::profile::DeviceProfile;
use super::protocol::CompressionConfig;
use super::router::{DeviceSlot, Router};
use super::serve_loop::{EdgeEndpoint, ServeLoop};
use crate::adapt::{expected_goodput_bps, AdaptPolicy, AdaptiveController, MemoryGauge};
use crate::channel::{optimize_rate, ChannelParams, ChannelTrace, LinkSim};
use crate::memory::ActBits;
use crate::model::{ModelConfig, ModelWeights};
use crate::planner::{EarlyExitController, LatencyModel};
use crate::quant::{apply_opsc, OpscConfig};
use crate::runtime::{Engine, NodeRuntime};

#[derive(Clone, Debug)]
pub struct DeploymentSpec {
    pub model: ModelConfig,
    pub opsc: OpscConfig,
    pub compression: CompressionConfig,
    pub channel: ChannelParams,
    /// Time-varying channel scenario replayed by every link of the
    /// deployment (None = stationary nominal channel).
    pub channel_trace: Option<ChannelTrace>,
    /// None → optimize via Eq. (13).
    pub rate_bps: Option<f64>,
    pub weight_seed: u64,
    pub link_seed: u64,
    /// Per-token deadline (enables the Algorithm-2 controller).
    pub deadline_s: Option<f64>,
    pub edge_profile: DeviceProfile,
    pub cloud_profile: DeviceProfile,
    /// Content-addressed prefix KV cache budget in BYTES, applied to
    /// both halves (edge front-segment cache and cloud back-segment
    /// store). 0 disables prefix caching entirely — every payload is
    /// byte-identical to the pre-v7 wire.
    pub prefix_cache_bytes: u64,
}

impl DeploymentSpec {
    pub fn defaults(model: ModelConfig, split: usize) -> DeploymentSpec {
        DeploymentSpec {
            model,
            opsc: OpscConfig::new(split, 4, 16),
            compression: CompressionConfig::default(),
            channel: ChannelParams::default(),
            channel_trace: None,
            rate_bps: None,
            weight_seed: 42,
            link_seed: 7,
            deadline_s: None,
            edge_profile: DeviceProfile::edge_default(),
            cloud_profile: DeviceProfile::cloud_default(),
            prefix_cache_bytes: 0,
        }
    }

    /// Builder-style: enable the prefix KV cache with a byte budget
    /// shared by the edge cache and the cloud store.
    pub fn with_prefix_cache(mut self, budget_bytes: u64) -> DeploymentSpec {
        self.prefix_cache_bytes = budget_bytes;
        self
    }

    fn check_split(&self) -> Result<usize> {
        let split = self.opsc.split_layer;
        anyhow::ensure!(
            split >= 1 && split <= self.model.n_layers,
            "split must keep at least one layer on the edge"
        );
        // split == n_layers is legal: the cloud runs only the lm head
        // (full-edge deployment, the Fig. 5 offload-maximizing regime).
        Ok(split)
    }

    fn operating_rate(&self) -> f64 {
        self.rate_bps
            .unwrap_or_else(|| optimize_rate(&self.channel, 1e5, 4.0 * self.channel.capacity_bps()))
    }

    fn controller(&self, rate: f64) -> Option<EarlyExitController> {
        self.deadline_s.map(|d| {
            let hd = self.model.kv_width() as u64;
            EarlyExitController {
                deadline_s: d,
                model: LatencyModel { channel: self.channel, rate_bps: rate },
                min_qa_bits: 2,
                per_token_payload_bytes: hd * self.compression.q_bar as u64 / 8,
            }
        })
    }

    /// Synthesize + OPSC-quantize the edge weight set ONCE; every edge
    /// device of a deployment shares the same Rc (devices are identical
    /// by construction — same seed, same quantizer), so an N-device serve
    /// loop pays one weight build instead of N.
    fn edge_weights(&self) -> Rc<ModelWeights> {
        let mut edge_weights = ModelWeights::synthetic(&self.model, self.weight_seed);
        apply_opsc(&mut edge_weights, &self.opsc);
        Rc::new(edge_weights)
    }

    /// Build one OPSC-quantized edge front segment (its own device
    /// buffers over the shared weight set).
    fn build_edge(
        &self,
        engine: Rc<Engine>,
        split: usize,
        weights: Rc<ModelWeights>,
    ) -> Result<EdgeDevice> {
        let edge_node = NodeRuntime::new(engine, weights, 0..split, false)?;
        let edge = EdgeDevice::new(
            edge_node,
            self.model.n_layers - split,
            self.edge_profile.clone(),
            self.compression,
        );
        if self.prefix_cache_bytes > 0 {
            edge.set_prefix_cache_budget(self.prefix_cache_bytes);
        }
        Ok(edge)
    }

    /// Build the full-precision cloud back segment (paper §2.1: the
    /// server maintains a single high-precision model).
    fn build_cloud(&self, engine: Rc<Engine>, split: usize) -> Result<CloudServer> {
        let cloud_weights = Rc::new(ModelWeights::synthetic(&self.model, self.weight_seed));
        let cloud_node = NodeRuntime::new(engine, cloud_weights, split..self.model.n_layers, true)?;
        let cloud = CloudServer::new(cloud_node, self.cloud_profile.clone());
        if self.prefix_cache_bytes > 0 {
            cloud.set_prefix_budget(self.prefix_cache_bytes);
        }
        Ok(cloud)
    }

    /// Build just the edge half of this deployment — the piece a
    /// cross-process `splitserve edge` runs. Both processes construct
    /// from the same spec (same seeds, same quantizer), so the split
    /// model they jointly form is identical to the single-process one.
    pub fn build_edge_device(&self, engine: Rc<Engine>) -> Result<EdgeDevice> {
        let split = self.check_split()?;
        self.build_edge(engine, split, self.edge_weights())
    }

    /// Build just the cloud half of this deployment — the piece a
    /// cross-process `splitserve cloud` serves behind a socket.
    pub fn build_cloud_server(&self, engine: Rc<Engine>) -> Result<CloudServer> {
        let split = self.check_split()?;
        self.build_cloud(engine, split)
    }

    /// The Algorithm-2 controller this spec implies (None without a
    /// deadline), for drivers built from the halves above.
    pub fn edge_controller(&self) -> Option<EarlyExitController> {
        self.controller(self.operating_rate())
    }

    /// One seeded link of this deployment (per-device fading stream:
    /// `link_seed + device`), with the spec's channel trace attached.
    fn build_link(&self, rate: f64, device: u64) -> LinkSim {
        let mut link = LinkSim::new(self.channel, rate, self.link_seed.wrapping_add(device));
        if let Some(trace) = self.channel_trace {
            link.set_trace(trace);
        }
        link
    }
}

/// Build the single-session pipeline. The engine can be shared across
/// deployments (pass the same Rc) — executables are compiled once per
/// shape class.
pub fn build_pipeline(engine: Rc<Engine>, spec: &DeploymentSpec) -> Result<SplitPipeline> {
    let split = spec.check_split()?;
    let edge = spec.build_edge(engine.clone(), split, spec.edge_weights())?;
    let cloud = spec.build_cloud(engine, split)?;
    let rate = spec.operating_rate();
    let link = spec.build_link(rate, 0);
    let mut pipeline = SplitPipeline::new(edge, cloud, link);
    pipeline.controller = spec.controller(rate);
    Ok(pipeline)
}

/// Knobs for the many-to-one deployment on top of a `DeploymentSpec`.
#[derive(Clone, Debug)]
pub struct ServeSpec {
    pub deployment: DeploymentSpec,
    pub n_devices: usize,
    /// Eq. 8c memory budget per edge device (router admission).
    pub mem_budget_bytes: u64,
    /// Iteration accounting: max batch width + sub-linear batching model.
    pub batcher: BatcherParams,
    /// Online adaptive control plane (None = the static plan runs
    /// forever, the pre-adaptation behavior).
    pub adapt: Option<AdaptPolicy>,
}

impl ServeSpec {
    pub fn defaults(model: ModelConfig, split: usize, n_devices: usize) -> ServeSpec {
        ServeSpec {
            deployment: DeploymentSpec::defaults(model, split),
            n_devices,
            mem_budget_bytes: 64 * 1024 * 1024,
            batcher: BatcherParams::default(),
            adapt: None,
        }
    }

    /// Builder-style: enable the adaptive control plane with a policy.
    pub fn with_adapt(mut self, policy: AdaptPolicy) -> ServeSpec {
        self.adapt = Some(policy);
        self
    }
}

/// Build the many-to-one serve loop: `n_devices` edge endpoints (each with
/// its own device buffers, scratch pools and link fading stream, seeded
/// `link_seed + device`, over ONE shared OPSC weight set) sharing ONE
/// stateless `CloudServer`, fronted by a `Router` with per-device memory
/// admission.
pub fn build_serve_loop(engine: Rc<Engine>, spec: &ServeSpec) -> Result<ServeLoop> {
    let dep = &spec.deployment;
    anyhow::ensure!(spec.n_devices >= 1, "serve loop needs at least one edge device");
    let split = dep.check_split()?;
    let rate = dep.operating_rate();
    let cloud = dep.build_cloud(engine.clone(), split)?;
    let edge_weights = dep.edge_weights();
    let mut edges = Vec::with_capacity(spec.n_devices);
    for d in 0..spec.n_devices {
        let edge = dep.build_edge(engine.clone(), split, edge_weights.clone())?;
        let link = dep.build_link(rate, d as u64);
        edges.push(EdgeEndpoint::over_link(edge, link));
    }
    let qa = ActBits::uniform(dep.compression.q_bar);
    let slots: Vec<DeviceSlot> = (0..spec.n_devices)
        .map(|d| {
            DeviceSlot::new(
                d,
                &dep.model,
                split,
                dep.opsc.qw_front,
                &qa,
                dep.model.max_seq,
                spec.mem_budget_bytes,
            )
        })
        .collect();
    let router = Router::new(slots);
    let mut serve = ServeLoop::new(cloud, edges, router, spec.batcher.clone());
    serve.controller = dep.controller(rate);
    if let Some(policy) = spec.adapt.clone() {
        // The controller plans against the NOMINAL channel's expected
        // goodput at the operating rate; its estimators start there too,
        // so a constant channel never leaves the deadband.
        let nominal = expected_goodput_bps(&dep.channel, rate);
        let gauge = MemoryGauge::new(
            dep.model.clone(),
            split,
            dep.opsc.qw_front,
            spec.mem_budget_bytes,
        );
        serve.adapt = Some(AdaptiveController::new(
            policy,
            gauge,
            dep.compression.q_bar,
            dep.compression.tau,
            nominal,
            spec.n_devices,
        ));
    }
    Ok(serve)
}
