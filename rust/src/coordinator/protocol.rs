//! Edge→cloud wire protocol: the paper's two-stage intermediate-output
//! compression (TS → TAB-Q → rANS) applied to real tensors, with bit-exact
//! payload accounting and lossless-outlier reconstruction (Eq. 7).
//!
//! A `SplitPayload` is what one transmission carries:
//!   * the compressed hidden-state block at the split layer, always;
//!   * optionally (I_kv = 1) the compressed KV caches of the CLOUD layers —
//!     the paper's stateless-cloud design keeps all per-request state on
//!     the edge (Eq. 2's memory model), shipping the cloud share each step.
//!
//! # Wire format v3 — real frames, not arithmetic
//!
//! Since wire format v3, this layout is no longer a size-accounting
//! convention: `wire::codec` encodes and strictly decodes every struct
//! below as actual bytes, every transmission crosses the edge↔cloud
//! boundary inside a CRC-protected versioned frame (`wire::frame`), and
//! `encoded.len() == wire_bytes()` is asserted at every encode in debug
//! builds and in the test suite. One `CompressedTensor` serializes as:
//!
//! ```text
//! [rows u16][cols u16][bits u8][flags u8]            -- 6-byte header
//! [scale f32, zero f32] x rows                        -- per-token params
//! [sign bitset: ceil(rows*cols/8) bytes]              -- 1 bit/element
//! [coded stream: tag u8 + representation]             -- TAB-Q codes
//!   tag 0 (raw packing):  [bits u32][n u32][packed]
//!   tag 1 (rANS):         [len u32][rANS stream]
//! [CSR outliers: rows/cols u16 header, row_ptr u32 x (rows+1),
//!  (col_idx u16, value f32) x nnz]                    -- lossless T_above
//! ```
//!
//! A `CompressedKv` is a `[n_layers u16][used_rows u16]` header plus the
//! per-layer (k, v) tensor pairs; `SplitPayload` and `CloudReply` add
//! small fixed headers (see `wire::codec` for the byte-level layouts and
//! `wire::frame` for the `[magic][version][kind][len][body][crc32]`
//! envelope every message travels in). v3 differs from v2 in exactly one
//! accounted byte sequence: the rANS branch carries an explicit u32
//! length prefix, because a rANS stream cannot delimit itself inside a
//! larger frame body. The tensor layout itself is unchanged from v2
//! (64-bit-state interleaved rANS, strict truncation-detecting decode,
//! no retained uncompressed codes). Wire format v4 leaves every layout
//! below untouched and adds one frame kind: the control-plane
//! `adapt::Reconfig` (kind 3), the adaptive control plane's mid-stream
//! actuation message. Wire format v5 stamps every `CloudReply` with the
//! position it answers (duplicate/stale replies become typed rejections)
//! and adds the session-recovery frames: `Resume` (kind 4),
//! `ResumeAck` (kind 5) and the in-band typed `Error` (kind 6). Wire
//! format v6 adds `Migrate` (kind 7): a worker-to-worker frame carrying
//! one session's cloud-side state ([`MigrateState`]) for live migration
//! inside a cloud pool.
//!
//! Compression runs on the fused engine (`quant::fused`): single-pass
//! TS+stats, streaming adaptive bit search, scratch-reused rANS tables.
//! The unfused composition survives as [`CompressedTensor::compress_reference`],
//! the property-test oracle and A/B bench baseline.

use anyhow::Result;

use crate::quant::fused::{self, compress_fused, CompressionScratch, ScratchPool};
use crate::quant::rans::CodedStream;
use crate::quant::tabq::tabq_adaptive;
use crate::quant::ts::{threshold_split, SparseOutliers};
use crate::quant::{aiq, QuantParams};

/// Compression settings for one transmission.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionConfig {
    /// TS threshold τ (|t| >= τ goes to the lossless CSR side).
    pub tau: f32,
    /// TAB-Q bit budget Q̄a (sign included).
    pub q_bar: u32,
    /// TAB-Q distortion tolerance Δ.
    pub delta: f64,
    /// Entropy-code the TAB-Q stream with rANS (else raw bit-packing).
    pub use_rans: bool,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        // Paper defaults: τ = 5, Δ = 0.2, Q̄a = 4.
        CompressionConfig { tau: 5.0, q_bar: 4, delta: 0.2, use_rans: true }
    }
}

/// One compressed (rows x cols) tensor: lossless outliers + quantized bulk.
/// Carries exactly the wire contents — the TAB-Q code vector exists only
/// transiently in [`CompressionScratch`] during compression.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedTensor {
    pub rows: usize,
    pub cols: usize,
    pub above: SparseOutliers,
    /// Per-token (row) scale/zero of the quantized bulk.
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    /// Sign bitset, row-major, 1 = negative (len = ceil(rows*cols/8)).
    pub signs: Vec<u8>,
    /// Entropy-coded TAB-Q magnitude codes.
    pub coded: CodedStream,
    /// Bits actually chosen by TAB-Q's adaptive search.
    pub chosen_bits: u32,
}

impl CompressedTensor {
    /// Compress on the fused engine with a process-wide pooled scratch.
    pub fn compress(t: &[f32], rows: usize, cols: usize, c: &CompressionConfig) -> CompressedTensor {
        fused::global_pool().with(|s| Self::compress_with(s, t, rows, cols, c))
    }

    /// Compress on the fused engine with caller-owned scratch (the
    /// allocation-free hot path used by `EdgeDevice` / the KV workers).
    pub fn compress_with(
        scratch: &mut CompressionScratch,
        t: &[f32],
        rows: usize,
        cols: usize,
        c: &CompressionConfig,
    ) -> CompressedTensor {
        let out = compress_fused(scratch, t, rows, cols, c.tau, c.q_bar, c.delta, c.use_rans);
        CompressedTensor {
            rows,
            cols,
            above: out.above,
            scales: out.scales,
            zeros: out.zeros,
            signs: out.signs,
            coded: out.coded,
            chosen_bits: out.bits,
        }
    }

    /// The unfused reference composition (`threshold_split` →
    /// `tabq_adaptive` → `CodedStream::best`). Kept as the equivalence
    /// oracle for property tests and the "before" baseline in
    /// `benches/hot_paths.rs`; the serving path never calls it.
    pub fn compress_reference(
        t: &[f32],
        rows: usize,
        cols: usize,
        c: &CompressionConfig,
    ) -> CompressedTensor {
        let (above, below_dense) = threshold_split(t, rows, cols, c.tau);
        let ad = tabq_adaptive(&below_dense, rows, cols, c.q_bar, c.delta);
        let coded = if c.use_rans {
            CodedStream::best(&ad.block.codes, ad.block.bits)
        } else {
            CodedStream::Raw {
                bits: ad.block.bits,
                n: ad.block.codes.len(),
                bytes: crate::quant::aiq::pack_codes(&ad.block.codes, ad.block.bits),
            }
        };
        CompressedTensor {
            rows,
            cols,
            above,
            scales: ad.block.scales,
            zeros: ad.block.zeros,
            signs: ad.block.signs,
            coded,
            chosen_bits: ad.block.bits,
        }
    }

    /// Bit-exact wire size: coded TAB-Q stream + signs/scales/zeros + CSR.
    pub fn wire_bytes(&self) -> u64 {
        let n = (self.rows * self.cols) as u64;
        self.coded.wire_bytes()
            + crate::util::bits_to_bytes(n) // sign bits
            + (self.rows as u64) * 8 // per-token scale+zero
            + self.above.payload_bytes()
            + 6 // header: rows u16, cols u16, bits u8, flags u8
    }

    /// Dequantize decoded codes + restore signs, then add the lossless
    /// outliers (Eq. 7).
    fn reconstruct(&self, codes: &[u16]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            codes.len() == self.rows * self.cols,
            "code stream length {} != {}x{}",
            codes.len(),
            self.rows,
            self.cols
        );
        let mut out = vec![0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let p = QuantParams { scale: self.scales[r], zero: self.zeros[r], bits: self.chosen_bits };
            let base = r * self.cols;
            for c in 0..self.cols {
                let i = base + c;
                let mag = aiq::dequantize_one(codes[i], &p);
                let neg = self.signs[i / 8] >> (i % 8) & 1 == 1;
                out[i] = if neg { -mag } else { mag };
            }
        }
        self.above.add_into(&mut out);
        Ok(out)
    }

    /// Cloud-side reconstruction (Eq. 7): dequantized bulk + outliers.
    pub fn decompress(&self) -> Result<Vec<f32>> {
        let codes = self.coded.decode()?;
        self.reconstruct(&codes)
    }

    /// Scratch-reusing reconstruction: the decoded code buffer and the
    /// rANS slot-lookup table live in `scratch` across calls.
    pub fn decompress_with(&self, scratch: &mut CompressionScratch) -> Result<Vec<f32>> {
        let (dec, dec_codes) = scratch.decode_parts();
        self.coded.decode_with(dec, dec_codes)?;
        self.reconstruct(dec_codes)
    }

    /// Max per-element reconstruction error of the bulk (half quantum per
    /// token row); outliers are lossless.
    pub fn worst_bulk_error(&self) -> f32 {
        self.scales.iter().fold(0f32, |m, &s| m.max(s * 0.5))
    }
}

/// Compressed KV caches for a contiguous layer range (cloud layers).
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedKv {
    /// One (k, v) pair per layer; each covers only the used rows [0, w).
    pub layers: Vec<(CompressedTensor, CompressedTensor)>,
    pub used_rows: usize,
}

impl CompressedKv {
    /// Compress every cloud layer's (k, v) pair. Layers are independent, so
    /// they are fanned out over scoped worker threads (each with a pooled
    /// scratch arena); output is deterministic regardless of worker count.
    pub fn compress(
        kv: &[crate::runtime::LayerKv],
        used_rows: usize,
        kv_width: usize,
        c: &CompressionConfig,
    ) -> CompressedKv {
        Self::compress_with_pool(kv, used_rows, kv_width, c, fused::global_pool())
    }

    /// Pool-explicit variant used by `EdgeDevice` (its pool persists across
    /// decode steps, so the per-layer workers never cold-allocate).
    pub fn compress_with_pool(
        kv: &[crate::runtime::LayerKv],
        used_rows: usize,
        kv_width: usize,
        c: &CompressionConfig,
        pool: &ScratchPool,
    ) -> CompressedKv {
        let n = kv.len();
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(n);
        let compress_layer = |s: &mut CompressionScratch, cache: &crate::runtime::LayerKv| {
            let kslice = &cache.k[..used_rows * kv_width];
            let vslice = &cache.v[..used_rows * kv_width];
            (
                CompressedTensor::compress_with(s, kslice, used_rows, kv_width, c),
                CompressedTensor::compress_with(s, vslice, used_rows, kv_width, c),
            )
        };
        // shared by reference so each spawned worker copies the &, not the
        // closure itself
        let compress_layer = &compress_layer;
        let layers: Vec<(CompressedTensor, CompressedTensor)> = if workers <= 1 {
            pool.with(|s| kv.iter().map(|cache| compress_layer(&mut *s, cache)).collect())
        } else {
            let mut slots: Vec<Option<(CompressedTensor, CompressedTensor)>> =
                (0..n).map(|_| None).collect();
            let chunk = n.div_ceil(workers);
            std::thread::scope(|scope| {
                for (slot_chunk, kv_chunk) in slots.chunks_mut(chunk).zip(kv.chunks(chunk)) {
                    scope.spawn(move || {
                        let mut s = pool.take();
                        for (slot, cache) in slot_chunk.iter_mut().zip(kv_chunk) {
                            *slot = Some(compress_layer(&mut s, cache));
                        }
                        pool.put(s);
                    });
                }
            });
            slots.into_iter().map(|s| s.expect("kv worker filled its slot")).collect()
        };
        CompressedKv { layers, used_rows }
    }

    pub fn wire_bytes(&self) -> u64 {
        self.layers.iter().map(|(k, v)| k.wire_bytes() + v.wire_bytes()).sum::<u64>() + 4
    }

    /// Reconstruct into full-width (max_seq) zero-padded caches.
    pub fn decompress(&self, max_seq: usize, kv_width: usize) -> Result<Vec<crate::runtime::LayerKv>> {
        self.decompress_with_pool(max_seq, kv_width, fused::global_pool())
    }

    /// Scratch-reusing reconstruction (cloud hot path: one arena serves
    /// every layer of the request). Each cache buffer is the decompressed
    /// tensor itself, zero-extended to full width — no zeroed max_seq
    /// cache is allocated just to be overwritten.
    pub fn decompress_with_pool(
        &self,
        max_seq: usize,
        kv_width: usize,
        pool: &ScratchPool,
    ) -> Result<Vec<crate::runtime::LayerKv>> {
        let used = self.used_rows * kv_width;
        let total = max_seq * kv_width;
        anyhow::ensure!(used <= total, "used rows {} exceed cache width {max_seq}", self.used_rows);
        pool.with(|s| {
            self.layers
                .iter()
                .map(|(kc, vc)| {
                    let mut k = kc.decompress_with(s)?;
                    anyhow::ensure!(k.len() == used, "kv tensor covers {} != {used}", k.len());
                    k.resize(total, 0.0);
                    let mut v = vc.decompress_with(s)?;
                    anyhow::ensure!(v.len() == used, "kv tensor covers {} != {used}", v.len());
                    v.resize(total, 0.0);
                    Ok(crate::runtime::LayerKv { k, v })
                })
                .collect()
        })
    }
}

/// Prefix-cache reference riding a prefill `SplitPayload` (wire v7).
///
/// On a **warm** transmission (`insert == None`) this is the headline
/// wire saving: the 32-byte content digest + prefix length stand in for
/// the prefix's share of the compressed hidden block — the cloud
/// reconstructs the prefix from its [`prefix::PrefixStore`]
/// (crate::prefix) and the payload's `hidden` tensor covers only the
/// divergent suffix rows `[prefix_len, w)`. On an **insert**
/// transmission the prefix rows travel once as their own compressed
/// block (`insert`) so the cloud can serve the session *and* populate
/// the store for every later session sharing the prefix.
///
/// A warm reference to a digest the cloud does not hold (forged token,
/// store restart, eviction race) is answered with a typed in-band
/// [`reject::PREFIX`] — never silent wrong tokens.
#[derive(Clone, Debug, PartialEq)]
pub struct PrefixRef {
    pub digest: crate::prefix::PrefixDigest,
    /// Prompt positions `[0, prefix_len)` covered by the digest.
    pub prefix_len: u32,
    /// Compressed split-layer hidden rows of the prefix (insert only).
    pub insert: Option<CompressedTensor>,
}

impl PrefixRef {
    /// digest 32 + prefix_len u32 (+ the insert tensor when present; its
    /// presence is a payload flag bit, not extra header bytes).
    pub fn wire_bytes(&self) -> u64 {
        36 + self.insert.as_ref().map_or(0, |t| t.wire_bytes())
    }
}

/// What one edge→cloud transmission carries (paper Eq. 3).
#[derive(Clone, Debug, PartialEq)]
pub struct SplitPayload {
    pub request_id: u64,
    /// Position of the last token in `hidden` (the token being decoded, or
    /// prompt_len-1 for prefill).
    pub pos: usize,
    /// Compressed hidden-state rows at the split layer. With a warm
    /// `prefix` reference these are the divergent suffix rows only.
    pub hidden: CompressedTensor,
    /// I_kv = 1: the cloud layers' KV caches travel too (stateless cloud).
    pub kv: Option<CompressedKv>,
    /// Prefill (true) or single-token decode (false).
    pub is_prefill: bool,
    /// Decode policy for the stateless cloud (Session stamps it from the
    /// Request; direct edge-API callers get greedy).
    pub sampling: super::sampling::SamplingSpec,
    /// Prefix-cache reference (wire v7, prefill only). `None` keeps the
    /// pre-prefix layout byte-for-byte.
    pub prefix: Option<PrefixRef>,
}

impl SplitPayload {
    pub fn wire_bytes(&self) -> u64 {
        // 17-byte fixed header (request id, pos, flags — greedy decode is
        // a flag bit) + the sampling spec's own bytes when it carries
        // top-k parameters + the optional prefix reference.
        17 + self.sampling.wire_bytes()
            + self.prefix.as_ref().map_or(0, |p| p.wire_bytes())
            + self.hidden.wire_bytes()
            + self.kv.as_ref().map_or(0, |k| k.wire_bytes())
    }
}

/// Edge→cloud prefix-cache probe (frame kind 8, wire v7): "is this
/// (digest, prefix_len) resident?". A hit attaches the probing request to
/// the entry (refcount++), pinning it until the request retires — the ack
/// is a *promise* the warm payload can rely on, not a racy snapshot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixProbe {
    pub request_id: u64,
    pub digest: crate::prefix::PrefixDigest,
    pub prefix_len: u32,
}

impl PrefixProbe {
    /// request id u64 + digest 32 + prefix_len u32.
    pub fn wire_bytes(&self) -> u64 {
        44
    }
}

/// Cloud→edge answer to a [`PrefixProbe`] (frame kind 9, wire v7).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefixAck {
    pub request_id: u64,
    /// Echo of the probed digest (cross-field mismatch is a typed error).
    pub digest: crate::prefix::PrefixDigest,
    /// Resident (and now pinned for this request) or not.
    pub hit: bool,
}

impl PrefixAck {
    /// request id u64 + digest 32 + flags u8 (bit 0 = hit).
    pub fn wire_bytes(&self) -> u64 {
        41
    }
}

/// Cloud→edge reply: the sampled token, and in stateless mode the new KV
/// rows of the cloud layers so the edge can keep the canonical state.
#[derive(Clone, Debug, PartialEq)]
pub struct CloudReply {
    pub request_id: u64,
    /// Position this reply answers (the payload's `pos`, echoed back).
    /// New in wire v5: the stamp is what lets a session reject a
    /// duplicated or stale reply as a typed error instead of silently
    /// absorbing the wrong token.
    pub pos: u64,
    pub token: u32,
    /// (k_row, v_row) per cloud layer for the newly processed position(s);
    /// raw f32 (small: one row per layer per step).
    pub new_kv_rows: Vec<(Vec<f32>, Vec<f32>)>,
    pub logits_entropy: f32,
}

impl CloudReply {
    /// Bit-exact wire size of the reply body (`wire::codec` layout):
    /// request id u64 + pos u64 + token u32 + entropy f32 + layer count
    /// u16 + row length u32 = 30 fixed bytes, plus the raw f32 KV rows.
    /// The frame's 8-byte server-compute timing prefix is transport
    /// metadata and counted in `wire::REPLY_OVERHEAD`, not here.
    pub fn wire_bytes(&self) -> u64 {
        let rows: u64 = self
            .new_kv_rows
            .iter()
            .map(|(k, v)| 4 * (k.len() + v.len()) as u64)
            .sum();
        30 + rows
    }
}

/// Edge→cloud session resumption (frame kind 4, new in wire v5): after a
/// reconnect — or against a restarted cloud — the edge re-announces the
/// session so the stateless cloud can fence stale traffic and continue
/// the stream bit-identically. The settings mirror what a `Reconfig`
/// would have announced; `serve_connection` re-registers them because a
/// connection teardown sweeps its announced control state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Resume {
    pub request_id: u64,
    /// Resumption epoch: strictly increases across reconnects of the same
    /// session. The cloud rejects `Resume`s at or below the highest epoch
    /// it has seen, so a delayed duplicate from a dead connection can
    /// never re-fence a live session.
    pub epoch: u32,
    /// Next position the edge will transmit; the cloud fences every
    /// earlier position on this connection as a replay.
    pub next_pos: u64,
    /// Transmission settings to re-announce (Q̄a of the session's current
    /// plan — validated 2..=16 like a `Reconfig`).
    pub qa_bits: u32,
    /// TS threshold τ to re-announce.
    pub tau: f32,
    /// I_kv of the session's current plan.
    pub include_kv: bool,
}

impl Resume {
    /// request id u64 + epoch u32 + next_pos u64 + tau f32 + qa_bits u8 +
    /// flags u8.
    pub fn wire_bytes(&self) -> u64 {
        26
    }
}

/// Cloud→edge acknowledgement of a [`Resume`] (frame kind 5, wire v5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResumeAck {
    pub request_id: u64,
    /// The epoch the cloud accepted (echo of the resume's).
    pub epoch: u32,
    /// Last position this connection already answered, when the cloud has
    /// one cached — the edge can sanity-check it against its own stream.
    /// `None` on a fresh connection (e.g. after a cloud restart).
    pub last_pos: Option<u64>,
}

impl ResumeAck {
    /// request id u64 + epoch u32 + last_pos u64 + flags u8.
    pub fn wire_bytes(&self) -> u64 {
        21
    }
}

/// In-band typed rejection codes carried by an `Error` frame (kind 6).
pub mod reject {
    /// The frame's epoch is at or below one the cloud already accepted.
    pub const STALE_EPOCH: u8 = 1;
    /// The payload's position was already answered on this connection
    /// (and its reply is no longer replayable).
    pub const STALE_POS: u8 = 2;
    /// The request failed on the cloud (the message carries the cause).
    pub const FAILED: u8 = 3;
    /// Fleet admission refused a new session: serving it would push the
    /// cloud's aggregate KV working memory past the budget (the Eq. 8c
    /// gate extended across all tenants of one server).
    pub const ADMISSION: u8 = 4;
    /// A warm payload referenced a prefix digest the cloud does not hold
    /// (forged or stale cache token, store restart, eviction). The edge
    /// falls back to a full insert payload — the stream continues
    /// bit-identically, it just pays the cold wire cost.
    pub const PREFIX: u8 = 5;
}

/// Cloud→edge in-band typed rejection (frame kind 6, wire v5): the
/// connection stays up — the error frame IS the typed error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RejectFrame {
    /// One of the [`reject`] codes.
    pub code: u8,
    pub request_id: u64,
    /// Human-readable cause (UTF-8, length-prefixed on the wire).
    pub message: String,
}

impl RejectFrame {
    /// code u8 + request id u64 + message length u16 + UTF-8 bytes.
    pub fn wire_bytes(&self) -> u64 {
        11 + self.message.len() as u64
    }
}

/// Worker→worker live-migration of one session's cloud-side state (frame
/// kind 7, new in wire v6). The cloud is stateless about KV — every
/// payload carries the back-segment caches (or the cloud rebuilt them
/// from shipped `CompressedKv` rows) — so a session's *entire* residue on
/// a worker is: the replay fence (last answered position + the cached
/// encoded reply frame, byte-identical on replay), the announced
/// control-plane settings, and its resume-epoch high-water mark. The
/// heavy per-request state already lives on the edge (`SessionSnapshot`,
/// PR 6); migration ships only what the TARGET worker needs to continue
/// the stream bit-identically and fence retransmissions of the last
/// position.
///
/// Import runs through the same epoch-fenced admission as a PR 6
/// `Resume`: `epoch` must strictly exceed the target's high-water mark
/// for the session, so a duplicated or stale `Migrate` delivery during
/// the handoff is rejected typed (`STALE_EPOCH`), never double-applied.
#[derive(Clone, Debug, PartialEq)]
pub struct MigrateState {
    pub request_id: u64,
    /// Migration epoch: the source's accepted resume-epoch high-water
    /// mark + 1. Strictly increases across migrations/resumes of the same
    /// session, exactly like a reconnecting edge's `Resume.epoch`.
    pub epoch: u32,
    /// Next position the session will transmit (the fence position + 1,
    /// or 0 for a session migrated before its first reply).
    pub next_pos: u64,
    /// The replay fence being shipped: last answered position and the
    /// cached *encoded reply frame* (a complete kind-2 frame, CRC and
    /// all — replayed byte-identically if the edge retransmits).
    pub fence: Option<(u64, Vec<u8>)>,
    /// The session's announced control-plane settings, verbatim (so a
    /// later `Reconfig` with a higher epoch still applies on the target).
    pub control: Option<crate::adapt::Reconfig>,
    /// The session's prefix-cache attachment (wire v7): the digest it
    /// holds a refcount on, plus the prefix length. Export releases the
    /// refcount on the source worker; import re-attaches on the target
    /// if the digest is resident there (a miss is benign — the prefix
    /// only matters at prefill, which has already happened).
    pub prefix: Option<(crate::prefix::PrefixDigest, u32)>,
}

impl MigrateState {
    /// request id u64 + epoch u32 + next_pos u64 + flags u8, then
    /// optionally [fence pos u64 + frame len u32 + frame bytes], the
    /// 22-byte `Reconfig` body, and the 36-byte prefix attachment.
    pub fn wire_bytes(&self) -> u64 {
        let fence = self.fence.as_ref().map_or(0, |(_, f)| 12 + f.len() as u64);
        let control = if self.control.is_some() { 22 } else { 0 };
        let prefix = if self.prefix.is_some() { 36 } else { 0 };
        21 + fence + control + prefix
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;
    use crate::util::rng::Rng;

    fn heavy_block(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.heavy_tailed(1.0, 0.001, 150.0)).collect()
    }

    #[test]
    fn fused_compress_matches_reference_oracle() {
        // The acceptance gate: bit-identical wire contents AND identical
        // reconstruction between the fused engine and the unfused oracle.
        run_cases(60, 0xE0, |_, rng| {
            let rows = 1 + rng.below(20);
            let cols = 8 + rng.below(160);
            let t = heavy_block(rng, rows, cols);
            let c = CompressionConfig {
                tau: [0.0f32, 1.0, 5.0, 10.0][rng.below(4)],
                q_bar: 2 + rng.below(8) as u32,
                delta: [0.0, 0.2, 1.0][rng.below(3)],
                use_rans: rng.below(2) == 0,
            };
            let fused = CompressedTensor::compress(&t, rows, cols, &c);
            let oracle = CompressedTensor::compress_reference(&t, rows, cols, &c);
            assert_eq!(fused, oracle, "wire contents must be bit-identical");
            assert_eq!(fused.wire_bytes(), oracle.wire_bytes());
            let a = fused.decompress().unwrap();
            let b = oracle.decompress().unwrap();
            assert_eq!(a, b, "reconstructions must be identical");
            // scratch-reusing decompress agrees too
            let mut s = crate::quant::CompressionScratch::default();
            assert_eq!(fused.decompress_with(&mut s).unwrap(), a);
        });
    }

    #[test]
    fn compress_roundtrip_outliers_lossless_bulk_bounded() {
        run_cases(40, 0xE1, |_, rng| {
            let rows = 1 + rng.below(16);
            let cols = 16 + rng.below(128);
            let t = heavy_block(rng, rows, cols);
            let c = CompressionConfig::default();
            let packet = CompressedTensor::compress(&t, rows, cols, &c);
            let back = packet.decompress().unwrap();
            for (i, (a, b)) in t.iter().zip(&back).enumerate() {
                if a.abs() >= c.tau {
                    assert_eq!(a, b, "outlier {i} must be lossless");
                } else {
                    let row = i / cols;
                    let bound = packet.scales[row] * 0.5 + 1e-4;
                    assert!((a - b).abs() <= bound, "bulk err {} > {bound}", (a - b).abs());
                }
            }
        });
    }

    #[test]
    fn wire_bytes_beat_dense_f32() {
        let mut rng = Rng::new(0xE2);
        let rows = 16;
        let cols = 128;
        let t = heavy_block(&mut rng, rows, cols);
        let packet = CompressedTensor::compress(&t, rows, cols, &CompressionConfig::default());
        let dense = (rows * cols * 4) as u64;
        assert!(
            packet.wire_bytes() < dense / 3,
            "compressed {} vs dense {dense}",
            packet.wire_bytes()
        );
    }

    #[test]
    fn lower_qbar_smaller_payload() {
        let mut rng = Rng::new(0xE3);
        let t = heavy_block(&mut rng, 32, 128);
        let mk = |q_bar: u32| {
            CompressedTensor::compress(
                &t,
                32,
                128,
                &CompressionConfig { q_bar, delta: 0.0, ..Default::default() },
            )
            .wire_bytes()
        };
        assert!(mk(2) < mk(4));
        assert!(mk(4) < mk(8));
    }

    #[test]
    fn kv_roundtrip_padded() {
        let mut rng = Rng::new(0xE4);
        let kvw = 64;
        let max_seq = 32;
        let used = 10;
        let mut caches = vec![crate::runtime::LayerKv::zeros(max_seq, kvw); 3];
        for c in &mut caches {
            for i in 0..used * kvw {
                c.k[i] = rng.normal_f32(0.0, 1.0);
                c.v[i] = rng.normal_f32(0.0, 1.0);
            }
        }
        let cfg = CompressionConfig { q_bar: 8, ..Default::default() };
        let ck = CompressedKv::compress(&caches, used, kvw, &cfg);
        let back = ck.decompress(max_seq, kvw).unwrap();
        assert_eq!(back.len(), 3);
        for (orig, rec) in caches.iter().zip(&back) {
            for i in 0..used * kvw {
                assert!((orig.k[i] - rec.k[i]).abs() < 0.05, "k row err");
            }
            // padding stays zero
            assert!(rec.k[used * kvw..].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn parallel_kv_compress_is_deterministic() {
        // worker-thread fan-out must produce exactly what the serial pooled
        // path produces, layer for layer
        let mut rng = Rng::new(0xE7);
        let kvw = 96;
        let used = 24;
        let mut caches = vec![crate::runtime::LayerKv::zeros(64, kvw); 7];
        for c in &mut caches {
            for i in 0..used * kvw {
                c.k[i] = rng.heavy_tailed(1.0, 0.01, 80.0);
                c.v[i] = rng.heavy_tailed(1.0, 0.01, 80.0);
            }
        }
        let cfg = CompressionConfig::default();
        let par = CompressedKv::compress(&caches, used, kvw, &cfg);
        // serial oracle: per-layer reference compress
        for (i, (k, v)) in par.layers.iter().enumerate() {
            let kq = CompressedTensor::compress_reference(
                &caches[i].k[..used * kvw],
                used,
                kvw,
                &cfg,
            );
            let vq = CompressedTensor::compress_reference(
                &caches[i].v[..used * kvw],
                used,
                kvw,
                &cfg,
            );
            assert_eq!(k, &kq, "layer {i} k");
            assert_eq!(v, &vq, "layer {i} v");
        }
    }

    #[test]
    fn payload_with_kv_much_larger_than_hidden_only() {
        // the Fig. 6 phenomenon: KV dominates the wire
        let mut rng = Rng::new(0xE5);
        let kvw = 128;
        let used = 50;
        let d = 128;
        let hidden: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cfg = CompressionConfig::default();
        let h = CompressedTensor::compress(&hidden, 1, d, &cfg);
        let mut caches = vec![crate::runtime::LayerKv::zeros(128, kvw); 12];
        for c in &mut caches {
            for i in 0..used * kvw {
                c.k[i] = rng.normal_f32(0.0, 1.0);
                c.v[i] = rng.normal_f32(0.0, 1.0);
            }
        }
        let kv = CompressedKv::compress(&caches, used, kvw, &cfg);
        assert!(kv.wire_bytes() > 20 * h.wire_bytes());
    }

    #[test]
    fn adaptive_bits_reported() {
        let mut rng = Rng::new(0xE6);
        let t = heavy_block(&mut rng, 8, 64);
        let packet = CompressedTensor::compress(
            &t,
            8,
            64,
            &CompressionConfig { q_bar: 8, delta: 1e9, ..Default::default() },
        );
        assert_eq!(packet.chosen_bits, 1, "huge tolerance must reach min bits");
    }
}
