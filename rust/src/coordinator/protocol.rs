//! Edge→cloud wire protocol: the paper's two-stage intermediate-output
//! compression (TS → TAB-Q → rANS) applied to real tensors, with bit-exact
//! payload accounting and lossless-outlier reconstruction (Eq. 7).
//!
//! A `SplitPayload` is what one transmission carries:
//!   * the compressed hidden-state block at the split layer, always;
//!   * optionally (I_kv = 1) the compressed KV caches of the CLOUD layers —
//!     the paper's stateless-cloud design keeps all per-request state on
//!     the edge (Eq. 2's memory model), shipping the cloud share each step.

use anyhow::Result;

use crate::quant::rans::CodedStream;
use crate::quant::tabq::{tabq_adaptive, TabqBlock};
use crate::quant::ts::{threshold_split, SparseOutliers};

/// Compression settings for one transmission.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressionConfig {
    /// TS threshold τ (|t| >= τ goes to the lossless CSR side).
    pub tau: f32,
    /// TAB-Q bit budget Q̄a (sign included).
    pub q_bar: u32,
    /// TAB-Q distortion tolerance Δ.
    pub delta: f64,
    /// Entropy-code the TAB-Q stream with rANS (else raw bit-packing).
    pub use_rans: bool,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        // Paper defaults: τ = 5, Δ = 0.2, Q̄a = 4.
        CompressionConfig { tau: 5.0, q_bar: 4, delta: 0.2, use_rans: true }
    }
}

/// One compressed (rows x cols) tensor: lossless outliers + quantized bulk.
#[derive(Clone, Debug)]
pub struct CompressedTensor {
    pub rows: usize,
    pub cols: usize,
    pub above: SparseOutliers,
    pub below: TabqBlock,
    pub coded: CodedStream,
    /// Bits actually chosen by TAB-Q's adaptive search.
    pub chosen_bits: u32,
}

impl CompressedTensor {
    pub fn compress(t: &[f32], rows: usize, cols: usize, c: &CompressionConfig) -> CompressedTensor {
        let (above, below_dense) = threshold_split(t, rows, cols, c.tau);
        let ad = tabq_adaptive(&below_dense, rows, cols, c.q_bar, c.delta);
        let coded = if c.use_rans {
            CodedStream::best(&ad.block.codes, ad.block.bits)
        } else {
            CodedStream::Raw {
                bits: ad.block.bits,
                n: ad.block.codes.len(),
                bytes: crate::quant::aiq::pack_codes(&ad.block.codes, ad.block.bits),
            }
        };
        let chosen_bits = ad.block.bits;
        CompressedTensor { rows, cols, above, below: ad.block, coded, chosen_bits }
    }

    /// Bit-exact wire size: coded TAB-Q stream + signs/scales/zeros + CSR.
    pub fn wire_bytes(&self) -> u64 {
        let n = (self.rows * self.cols) as u64;
        self.coded.wire_bytes()
            + crate::util::bits_to_bytes(n) // sign bits
            + (self.rows as u64) * 8 // per-token scale+zero
            + self.above.payload_bytes()
            + 6 // header: rows u16, cols u16, bits u8, flags u8
    }

    /// Cloud-side reconstruction (Eq. 7): dequantized bulk + outliers.
    pub fn decompress(&self) -> Result<Vec<f32>> {
        let codes = self.coded.decode()?;
        anyhow::ensure!(codes == self.below.codes, "code stream corrupted");
        let mut out = self.below.dequantize();
        self.above.add_into(&mut out);
        Ok(out)
    }

    /// Max per-element reconstruction error of the bulk (half quantum per
    /// token row); outliers are lossless.
    pub fn worst_bulk_error(&self) -> f32 {
        self.below.scales.iter().fold(0f32, |m, &s| m.max(s * 0.5))
    }
}

/// Compressed KV caches for a contiguous layer range (cloud layers).
#[derive(Clone, Debug)]
pub struct CompressedKv {
    /// One (k, v) pair per layer; each covers only the used rows [0, w).
    pub layers: Vec<(CompressedTensor, CompressedTensor)>,
    pub used_rows: usize,
}

impl CompressedKv {
    pub fn compress(
        kv: &[crate::runtime::LayerKv],
        used_rows: usize,
        kv_width: usize,
        c: &CompressionConfig,
    ) -> CompressedKv {
        let layers = kv
            .iter()
            .map(|cache| {
                let kslice = &cache.k[..used_rows * kv_width];
                let vslice = &cache.v[..used_rows * kv_width];
                (
                    CompressedTensor::compress(kslice, used_rows, kv_width, c),
                    CompressedTensor::compress(vslice, used_rows, kv_width, c),
                )
            })
            .collect();
        CompressedKv { layers, used_rows }
    }

    pub fn wire_bytes(&self) -> u64 {
        self.layers.iter().map(|(k, v)| k.wire_bytes() + v.wire_bytes()).sum::<u64>() + 4
    }

    /// Reconstruct into full-width (max_seq) zero-padded caches.
    pub fn decompress(&self, max_seq: usize, kv_width: usize) -> Result<Vec<crate::runtime::LayerKv>> {
        self.layers
            .iter()
            .map(|(kc, vc)| {
                let mut cache = crate::runtime::LayerKv::zeros(max_seq, kv_width);
                let k = kc.decompress()?;
                let v = vc.decompress()?;
                cache.k[..self.used_rows * kv_width].copy_from_slice(&k);
                cache.v[..self.used_rows * kv_width].copy_from_slice(&v);
                Ok(cache)
            })
            .collect()
    }
}

/// What one edge→cloud transmission carries (paper Eq. 3).
#[derive(Clone, Debug)]
pub struct SplitPayload {
    pub request_id: u64,
    /// Position of the last token in `hidden` (the token being decoded, or
    /// prompt_len-1 for prefill).
    pub pos: usize,
    /// Compressed hidden-state rows at the split layer.
    pub hidden: CompressedTensor,
    /// I_kv = 1: the cloud layers' KV caches travel too (stateless cloud).
    pub kv: Option<CompressedKv>,
    /// Prefill (true) or single-token decode (false).
    pub is_prefill: bool,
}

impl SplitPayload {
    pub fn wire_bytes(&self) -> u64 {
        17 + self.hidden.wire_bytes() + self.kv.as_ref().map_or(0, |k| k.wire_bytes())
    }
}

/// Cloud→edge reply: the sampled token, and in stateless mode the new KV
/// rows of the cloud layers so the edge can keep the canonical state.
#[derive(Clone, Debug)]
pub struct CloudReply {
    pub request_id: u64,
    pub token: u32,
    /// (k_row, v_row) per cloud layer for the newly processed position(s);
    /// raw f32 (small: one row per layer per step).
    pub new_kv_rows: Vec<(Vec<f32>, Vec<f32>)>,
    pub logits_entropy: f32,
}

impl CloudReply {
    pub fn wire_bytes(&self) -> u64 {
        let rows: u64 = self
            .new_kv_rows
            .iter()
            .map(|(k, v)| 4 * (k.len() + v.len()) as u64)
            .sum();
        12 + rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;
    use crate::util::rng::Rng;

    fn heavy_block(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.heavy_tailed(1.0, 0.001, 150.0)).collect()
    }

    #[test]
    fn compress_roundtrip_outliers_lossless_bulk_bounded() {
        run_cases(40, 0xE1, |_, rng| {
            let rows = 1 + rng.below(16);
            let cols = 16 + rng.below(128);
            let t = heavy_block(rng, rows, cols);
            let c = CompressionConfig::default();
            let packet = CompressedTensor::compress(&t, rows, cols, &c);
            let back = packet.decompress().unwrap();
            for (i, (a, b)) in t.iter().zip(&back).enumerate() {
                if a.abs() >= c.tau {
                    assert_eq!(a, b, "outlier {i} must be lossless");
                } else {
                    let row = i / cols;
                    let bound = packet.below.scales[row] * 0.5 + 1e-4;
                    assert!((a - b).abs() <= bound, "bulk err {} > {bound}", (a - b).abs());
                }
            }
        });
    }

    #[test]
    fn wire_bytes_beat_dense_f32() {
        let mut rng = Rng::new(0xE2);
        let rows = 16;
        let cols = 128;
        let t = heavy_block(&mut rng, rows, cols);
        let packet = CompressedTensor::compress(&t, rows, cols, &CompressionConfig::default());
        let dense = (rows * cols * 4) as u64;
        assert!(
            packet.wire_bytes() < dense / 3,
            "compressed {} vs dense {dense}",
            packet.wire_bytes()
        );
    }

    #[test]
    fn lower_qbar_smaller_payload() {
        let mut rng = Rng::new(0xE3);
        let t = heavy_block(&mut rng, 32, 128);
        let mk = |q_bar: u32| {
            CompressedTensor::compress(
                &t,
                32,
                128,
                &CompressionConfig { q_bar, delta: 0.0, ..Default::default() },
            )
            .wire_bytes()
        };
        assert!(mk(2) < mk(4));
        assert!(mk(4) < mk(8));
    }

    #[test]
    fn kv_roundtrip_padded() {
        let mut rng = Rng::new(0xE4);
        let kvw = 64;
        let max_seq = 32;
        let used = 10;
        let mut caches = vec![crate::runtime::LayerKv::zeros(max_seq, kvw); 3];
        for c in &mut caches {
            for i in 0..used * kvw {
                c.k[i] = rng.normal_f32(0.0, 1.0);
                c.v[i] = rng.normal_f32(0.0, 1.0);
            }
        }
        let cfg = CompressionConfig { q_bar: 8, ..Default::default() };
        let ck = CompressedKv::compress(&caches, used, kvw, &cfg);
        let back = ck.decompress(max_seq, kvw).unwrap();
        assert_eq!(back.len(), 3);
        for (orig, rec) in caches.iter().zip(&back) {
            for i in 0..used * kvw {
                assert!((orig.k[i] - rec.k[i]).abs() < 0.05, "k row err");
            }
            // padding stays zero
            assert!(rec.k[used * kvw..].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn payload_with_kv_much_larger_than_hidden_only() {
        // the Fig. 6 phenomenon: KV dominates the wire
        let mut rng = Rng::new(0xE5);
        let kvw = 128;
        let used = 50;
        let d = 128;
        let hidden: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cfg = CompressionConfig::default();
        let h = CompressedTensor::compress(&hidden, 1, d, &cfg);
        let mut caches = vec![crate::runtime::LayerKv::zeros(128, kvw); 12];
        for c in &mut caches {
            for i in 0..used * kvw {
                c.k[i] = rng.normal_f32(0.0, 1.0);
                c.v[i] = rng.normal_f32(0.0, 1.0);
            }
        }
        let kv = CompressedKv::compress(&caches, used, kvw, &cfg);
        assert!(kv.wire_bytes() > 20 * h.wire_bytes());
    }

    #[test]
    fn adaptive_bits_reported() {
        let mut rng = Rng::new(0xE6);
        let t = heavy_block(&mut rng, 8, 64);
        let packet = CompressedTensor::compress(
            &t,
            8,
            64,
            &CompressionConfig { q_bar: 8, delta: 1e9, ..Default::default() },
        );
        assert_eq!(packet.chosen_bits, 1, "huge tolerance must reach min bits");
    }
}
