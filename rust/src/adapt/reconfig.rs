//! The control plane's actuation message: a per-session mid-stream
//! reconfiguration of the transmission plan.
//!
//! A `Reconfig` travels the same wire as the data plane (frame kind 3,
//! wire format v4; see `wire::codec` for the byte layout) so control
//! traffic is charged real bytes on the link, ordered with the payload
//! stream, and visible to the cloud: the stateless server records the
//! announced settings per request and holds subsequent payloads to them
//! (a payload quantized wider than the announced Q̄a is a protocol
//! error, not a silent fidelity mismatch).

/// One session's new transmission plan, effective from the next decode
/// step: (τ, Q̄a, I_kv, remaining-sequence budget L).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Reconfig {
    pub request_id: u64,
    /// Monotone per-session reconfiguration counter; the cloud ignores
    /// stale (≤ last applied) epochs, so duplicated or reordered control
    /// frames cannot roll settings back.
    pub epoch: u32,
    /// TAB-Q activation bit budget Q̄a (sign included).
    pub qa_bits: u32,
    /// TS outlier threshold τ.
    pub tau: f32,
    /// I_kv: whether the KV cache travels with each decode step.
    pub include_kv: bool,
    /// Cap on the session's REMAINING token budget L
    /// ([`Reconfig::NO_BUDGET_CAP`] = leave the budget unchanged).
    pub budget_cap: u32,
}

impl Reconfig {
    /// Sentinel: the reconfiguration does not touch the token budget.
    pub const NO_BUDGET_CAP: u32 = u32::MAX;

    /// Bit-exact wire size of the frame body (`wire::codec` layout):
    /// request id u64 + epoch u32 + budget cap u32 + τ f32 + Q̄a u8 +
    /// flags u8.
    pub fn wire_bytes(&self) -> u64 {
        22
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_is_fixed() {
        let rc = Reconfig {
            request_id: 7,
            epoch: 1,
            qa_bits: 3,
            tau: 5.0,
            include_kv: false,
            budget_cap: Reconfig::NO_BUDGET_CAP,
        };
        assert_eq!(rc.wire_bytes(), 22);
    }
}
