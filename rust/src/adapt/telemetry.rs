//! Control-plane telemetry: link goodput estimation from per-frame
//! transfer outcomes, and live memory headroom over the Eq. (1)-(3)
//! byte models.

use crate::channel::outage::{attempts_for_epsilon, outage_probability};
use crate::channel::{ChannelParams, TransferOutcome};
use crate::memory::{self, ActBits};
use crate::model::ModelConfig;

/// Expected steady-state goodput (bytes/s) of the ε-outage link at
/// `rate_bps`: the raw byte rate divided by the mean attempt count of the
/// truncated-geometric retransmission process,
/// E[attempts] = (1 − P_o^n) / (1 − P_o) with n = n_ε. This is the
/// goodput the offline plan implicitly assumed — the reference the
/// controller's deadband is centered on.
pub fn expected_goodput_bps(p: &ChannelParams, rate_bps: f64) -> f64 {
    let po = outage_probability(p, rate_bps);
    let n = attempts_for_epsilon(p, rate_bps) as f64;
    let mean_attempts = if po <= 0.0 {
        1.0
    } else if po >= 1.0 {
        n
    } else {
        (1.0 - po.powf(n)) / (1.0 - po)
    };
    (rate_bps / 8.0) / mean_attempts.max(1.0)
}

/// EWMA goodput estimator over per-frame [`TransferOutcome`]s.
///
/// The estimate is a **ratio of exponentially decayed sums** (bytes over
/// seconds), not an average of per-frame rates: averaging `bytes/latency`
/// samples converges to `(R/8)·E[1/attempts]`, which overstates the
/// goodput the link actually delivers (Jensen); the decayed-sum ratio
/// converges to `(R/8)/E[attempts]` — exactly [`expected_goodput_bps`]
/// under a stationary channel, so the deadband sits on an unbiased
/// center. Seeded with a 0.25-second prior at the reference goodput so a
/// cold estimator reads "nominal", not zero — small enough that ~25-35
/// observed frames outweigh it entirely (collapse detection is bounded
/// by the α decay, not by the prior), large enough that the first few
/// frames cannot whipsaw the estimate.
#[derive(Clone, Debug)]
pub struct BandwidthEstimator {
    alpha: f64,
    ewma_bytes: f64,
    ewma_secs: f64,
    /// EWMA of the per-frame outage indicator.
    outage_rate: f64,
    samples: u64,
}

impl BandwidthEstimator {
    /// `alpha` is the EWMA smoothing factor per observed frame;
    /// `reference_goodput_bps` seeds the prior (bytes/s).
    pub fn new(alpha: f64, reference_goodput_bps: f64) -> BandwidthEstimator {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0, 1)");
        assert!(reference_goodput_bps > 0.0);
        BandwidthEstimator {
            alpha,
            ewma_bytes: reference_goodput_bps * 0.25,
            ewma_secs: 0.25,
            outage_rate: 0.0,
            samples: 0,
        }
    }

    /// Fold one frame's transfer accounting into the estimate. Frames
    /// with zero airtime (loopback halves, zero-byte frames) carry no
    /// bandwidth signal and are skipped.
    pub fn observe(&mut self, o: &TransferOutcome) {
        if o.payload_bytes == 0 || o.latency_s <= 0.0 {
            return;
        }
        let a = self.alpha;
        self.ewma_bytes = (1.0 - a) * self.ewma_bytes + a * o.payload_bytes as f64;
        self.ewma_secs = (1.0 - a) * self.ewma_secs + a * o.latency_s;
        self.outage_rate = (1.0 - a) * self.outage_rate + a * (o.outage as u8 as f64);
        self.samples += 1;
    }

    /// Smoothed goodput estimate (bytes/s).
    pub fn goodput_bps(&self) -> f64 {
        if self.ewma_secs <= 0.0 {
            0.0
        } else {
            self.ewma_bytes / self.ewma_secs
        }
    }

    /// Smoothed per-frame outage rate in [0, 1].
    pub fn outage_rate(&self) -> f64 {
        self.outage_rate
    }

    /// Frames observed (warmup gating).
    pub fn samples(&self) -> u64 {
        self.samples
    }

    /// Reset the estimator to its cold-start prior at
    /// `reference_goodput_bps` — the same state `new` seeds. Called after
    /// a detected wire fault: the fault window's latency samples measure
    /// the fault, not the channel, and must not steer Eq. 8 re-planning.
    pub fn re_anchor(&mut self, reference_goodput_bps: f64) {
        assert!(reference_goodput_bps > 0.0);
        self.ewma_bytes = reference_goodput_bps * 0.25;
        self.ewma_secs = 0.25;
        self.outage_rate = 0.0;
        self.samples = 0;
    }

    /// Relative deviation of the estimate from `reference` (bytes/s):
    /// 0.0 means on-plan, -0.5 means half the planned goodput.
    pub fn deviation_from(&self, reference: f64) -> f64 {
        if reference <= 0.0 {
            0.0
        } else {
            self.goodput_bps() / reference - 1.0
        }
    }
}

/// Live edge-memory accounting over the paper's Eq. (1)-(3) models: the
/// planner's Eq. (8c) constraint as a queryable gauge, used by the
/// controller to size the remaining-sequence budget L a reconfiguration
/// can afford.
#[derive(Clone, Debug)]
pub struct MemoryGauge {
    pub cfg: ModelConfig,
    pub split: usize,
    pub qw_front: u32,
    pub mem_budget_bytes: u64,
}

impl MemoryGauge {
    pub fn new(cfg: ModelConfig, split: usize, qw_front: u32, mem_budget_bytes: u64) -> MemoryGauge {
        MemoryGauge { cfg, split, qw_front, mem_budget_bytes }
    }

    /// Eq. (8c) left side at `w` tokens under activation precision `qa`.
    pub fn edge_bytes(&self, w: usize, qa: &ActBits) -> u64 {
        memory::edge_total_bytes(&self.cfg, self.split, self.qw_front, w, qa)
    }

    /// Does a `w`-token sequence at `qa` fit the budget?
    pub fn fits(&self, w: usize, qa: &ActBits) -> bool {
        self.edge_bytes(w, qa) <= self.mem_budget_bytes
    }

    /// Bytes left under the budget at `w` tokens (0 when over).
    pub fn headroom_bytes(&self, w: usize, qa: &ActBits) -> u64 {
        self.mem_budget_bytes.saturating_sub(self.edge_bytes(w, qa))
    }

    /// Largest token count (≤ `hi`) the budget can hold at `qa` — the
    /// memory-feasible sequence length L. 0 when even one token does not
    /// fit (the weights alone bust the budget).
    pub fn max_tokens(&self, qa: &ActBits, hi: usize) -> usize {
        let hi = hi.max(1);
        if !self.fits(1, qa) {
            return 0;
        }
        if self.fits(hi, qa) {
            return hi;
        }
        // KV growth is monotone in w (Eq. 2): bisect.
        let (mut lo, mut hi) = (1usize, hi);
        while lo + 1 < hi {
            let mid = lo + (hi - lo) / 2;
            if self.fits(mid, qa) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(bytes: u64, latency_s: f64, outage: bool) -> TransferOutcome {
        TransferOutcome { latency_s, attempts: 1, outage, payload_bytes: bytes }
    }

    #[test]
    fn estimator_converges_to_observed_rate() {
        let mut e = BandwidthEstimator::new(0.1, 1e6);
        for _ in 0..400 {
            e.observe(&outcome(5000, 5000.0 / 2e6, false)); // 2 MB/s
        }
        let g = e.goodput_bps();
        assert!((g / 2e6 - 1.0).abs() < 0.05, "estimate {g} should approach 2 MB/s");
        assert!(e.deviation_from(1e6) > 0.9);
    }

    #[test]
    fn estimator_reads_reference_when_cold() {
        let e = BandwidthEstimator::new(0.1, 1.5e6);
        assert!((e.goodput_bps() / 1.5e6 - 1.0).abs() < 1e-12);
        assert_eq!(e.samples(), 0);
        assert_eq!(e.deviation_from(1.5e6), 0.0);
    }

    #[test]
    fn estimator_ignores_zero_airtime_frames() {
        let mut e = BandwidthEstimator::new(0.2, 1e6);
        e.observe(&outcome(0, 0.0, false));
        e.observe(&outcome(1000, 0.0, false)); // lossless loopback
        assert_eq!(e.samples(), 0);
        assert!((e.goodput_bps() / 1e6 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn estimator_is_harmonic_not_arithmetic() {
        // Two frames, same size: one at 4 MB/s, one at 1 MB/s. The true
        // delivered goodput is total bytes / total time = 1.6 MB/s, NOT
        // the 2.5 MB/s a per-frame-rate average would report.
        let mut e = BandwidthEstimator::new(0.05, 1.6e6);
        for _ in 0..400 {
            e.observe(&outcome(4000, 4000.0 / 4e6, false));
            e.observe(&outcome(4000, 4000.0 / 1e6, false));
        }
        let g = e.goodput_bps();
        assert!((g / 1.6e6 - 1.0).abs() < 0.1, "harmonic estimate, got {g}");
    }

    #[test]
    fn expected_goodput_matches_link_sim_long_run() {
        use crate::channel::LinkSim;
        let p = ChannelParams::default();
        let rate = 15e6;
        let expect = expected_goodput_bps(&p, rate);
        let mut link = LinkSim::new(p, rate, 99);
        for _ in 0..30_000 {
            link.transfer(1500);
        }
        let emp = link.mean_goodput();
        assert!(
            (emp / expect - 1.0).abs() < 0.05,
            "empirical {emp} vs model {expect}"
        );
    }

    #[test]
    fn gauge_max_tokens_monotone_in_bits() {
        let cfg = ModelConfig::sim7b();
        let g = MemoryGauge::new(cfg.clone(), 16, 4, 8 * 1024 * 1024);
        let l8 = g.max_tokens(&ActBits::uniform(8), cfg.max_seq);
        let l4 = g.max_tokens(&ActBits::uniform(4), cfg.max_seq);
        assert!(l4 >= l8, "narrower KV must afford at least as many tokens");
        assert!(g.fits(l8.max(1), &ActBits::uniform(8)) || l8 == 0);
    }

    #[test]
    fn gauge_max_tokens_zero_when_weights_do_not_fit() {
        let cfg = ModelConfig::sim7b();
        let g = MemoryGauge::new(cfg.clone(), 16, 4, 1024); // 1 KB budget
        assert_eq!(g.max_tokens(&ActBits::uniform(4), cfg.max_seq), 0);
        assert_eq!(g.headroom_bytes(1, &ActBits::uniform(4)), 0);
    }

    #[test]
    fn gauge_max_tokens_is_the_boundary() {
        let cfg = ModelConfig::sim7b();
        let qa = ActBits::uniform(8);
        // budget exactly between w=20 and w=21
        let g0 = MemoryGauge::new(cfg.clone(), 16, 4, 0);
        let at20 = g0.edge_bytes(20, &qa);
        let at21 = g0.edge_bytes(21, &qa);
        assert!(at21 > at20);
        let g = MemoryGauge::new(cfg.clone(), 16, 4, at20);
        assert_eq!(g.max_tokens(&qa, cfg.max_seq), 20);
    }
}
