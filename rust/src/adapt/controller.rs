//! The decision half of the control plane: re-planning against the
//! estimated link state, with hysteresis, cooldown and a minimum-
//! improvement threshold so a noisy estimate can never make the plan
//! flap.
//!
//! Decision structure (per serve-loop iteration):
//!
//!   1. **Device level** — each edge device's [`BandwidthEstimator`]
//!      tracks the goodput its link actually delivers. When the estimate
//!      deviates from the goodput the device's current plan was chosen
//!      against by more than the deadband (and the estimator is warmed
//!      up), the controller re-solves the configuration problem: it
//!      filters the Q̄a candidate set down to the rungs whose predicted
//!      per-step wire time fits the static plan's nominal step budget at
//!      the *estimated* goodput, then re-invokes
//!      [`planner::plan`](crate::planner::plan) (Eq. 8: accuracy bound +
//!      memory budget, split and weight precision pinned to what is
//!      physically deployed) over that set — first with the KV cache on
//!      the wire, then without it (I_kv = 0), mirroring Algorithm 2's
//!      escalation ladder at the plan level. If nothing is feasible the
//!      device enters the degraded regime, where sessions shed remaining
//!      token budget instead.
//!   2. **Session level** — [`AdaptiveController::reconcile`] compares a
//!      session's currently applied plan against its device's target and
//!      emits a [`Reconfig`] only when something actually changes, the
//!      per-session cooldown has elapsed, and the session can serve the
//!      target (I_kv = 0 is only possible while the remaining horizon
//!      fits the prefill width). The remaining-sequence budget L is
//!      additionally capped to what the Eq. (8c) gauge says the edge can
//!      hold at the new precision.
//!
//! Upgrades (wider bits than the current plan) must clear the budget
//! with an extra `min_rel_gain` margin — the hysteresis that keeps a
//! borderline channel from oscillating between adjacent rungs. Every
//! re-plan re-anchors the device's reference goodput, so the deadband is
//! always measured against the state the current plan was chosen for.

use crate::memory::{self, ActBits};
use crate::planner::{self, AnalyticAccuracyModel, PlanInputs};

use super::reconfig::Reconfig;
use super::telemetry::{BandwidthEstimator, MemoryGauge};
use crate::channel::TransferOutcome;

/// Tunables of the online control plane.
#[derive(Clone, Debug)]
pub struct AdaptPolicy {
    /// EWMA smoothing factor of the per-frame goodput estimator.
    pub ewma_alpha: f64,
    /// Relative goodput deviation (vs the current plan's reference) that
    /// triggers a re-plan. Must sit above the estimator's own noise band
    /// under a stationary channel (the constant-channel invariant):
    /// attempts at the ε-outage operating point bound the upward
    /// excursion by E[attempts] − 1 ≈ 0.33, and simulated seeded runs
    /// put the downward excursion under ~0.54 — 0.6 clears both, while
    /// the bench scenarios (SNR ×0.1 ⇒ goodput ×0.075) overshoot it by
    /// an order of magnitude.
    pub deadband: f64,
    /// Frames the estimator must absorb before any decision.
    pub warmup_samples: u64,
    /// Decode steps a session must wait between reconfigurations.
    pub cooldown_steps: u64,
    /// Hysteresis margin: an upgrade must fit the step budget with this
    /// much headroom to spare (downgrades only need to fit).
    pub min_rel_gain: f64,
    /// Slack multiplier on the nominal per-step wire-time budget.
    pub slack: f64,
    /// Candidate Q̄a bit-widths the re-plan searches (Eq. 8 candidate
    /// set; the smallest doubles as the degraded-regime floor).
    pub qa_candidates: Vec<u32>,
    /// Accuracy tolerance A_Δ (Eq. 8b) for re-planning.
    pub acc_tolerance: f64,
}

impl Default for AdaptPolicy {
    fn default() -> Self {
        AdaptPolicy {
            ewma_alpha: 0.1,
            deadband: 0.6,
            warmup_samples: 8,
            cooldown_steps: 3,
            min_rel_gain: 0.15,
            slack: 1.25,
            qa_candidates: vec![2, 3, 4, 6, 8],
            acc_tolerance: 1.0,
        }
    }
}

/// A device's current transmission plan target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DevicePlan {
    /// Q̄a the device's sessions should transmit at.
    pub bits: u32,
    /// Preferred I_kv (sessions revert to KV shipping when I_kv = 0 is
    /// infeasible for their horizon).
    pub include_kv: bool,
    /// No rung fits the estimated link at all: sessions shed remaining
    /// token budget (Algorithm 2's last resort, at plan level).
    pub degraded: bool,
}

/// What the controller needs to know about one session to reconcile it
/// with its device's plan. All fields are copies — the view borrows
/// nothing.
#[derive(Clone, Copy, Debug)]
pub struct SessionView {
    pub request_id: u64,
    /// Reconfigurations already applied to this session.
    pub epoch: u32,
    pub seq_len: usize,
    pub remaining_budget: usize,
    pub prefill_len: usize,
    pub max_seq: usize,
    /// Plan currently applied to the session (what the last Reconfig —
    /// or the static deployment — set; Algorithm-2's per-step
    /// escalations below this are the session's own business).
    pub applied_bits: u32,
    pub applied_kv: bool,
    /// False once the session's edge-held cloud-KV copy went stale (a
    /// step was served with I_kv = 0): KV shipping can never resume, so
    /// the controller must not keep asking for it.
    pub kv_shippable: bool,
    /// Decode steps since this session's last reconfiguration.
    pub steps_since_reconfig: u64,
    /// The session is inside a `Resume` handshake (crash recovery or a
    /// live migration between workers) whose announced settings are not
    /// settled yet. Reconfiguring now would race the handshake — the
    /// cloud's force-installed resume announcement and the new Reconfig
    /// could land in either order — so a due change is a typed
    /// [`ReconcileDecision::Defer`], never an actuation and never an
    /// abort of the session.
    pub mid_resume: bool,
}

/// Outcome of a session-level reconcile pass
/// ([`AdaptiveController::reconcile_checked`]).
#[derive(Clone, Debug, PartialEq)]
pub enum ReconcileDecision {
    /// Apply this reconfiguration now.
    Actuate(Reconfig),
    /// A change is due, but the session is mid-`Resume`: actuating would
    /// race the handshake. Typed hold-off — re-reconcile next iteration;
    /// the session keeps serving under its applied plan meanwhile.
    Defer,
    /// Nothing to change.
    Hold,
}

#[derive(Clone, Debug)]
struct DeviceState {
    estimator: BandwidthEstimator,
    /// Goodput the device's current plan was chosen against (deadband
    /// anchor; re-anchored at every re-plan).
    planned_goodput: f64,
    plan: DevicePlan,
}

/// The online controller: one per serve loop, tracking every device.
#[derive(Clone, Debug)]
pub struct AdaptiveController {
    pub policy: AdaptPolicy,
    /// Eq. (1)-(3) memory accounting for the deployed configuration.
    pub gauge: MemoryGauge,
    /// Static plan's Q̄a (the deployment's compression.q_bar).
    base_bits: u32,
    /// Static plan's TS threshold τ.
    base_tau: f32,
    /// Expected goodput of the nominal channel at the operating rate —
    /// the denominator of the per-step wire-time budget.
    nominal_goodput: f64,
    devices: Vec<DeviceState>,
    replans: u64,
    reconfigs: u64,
    defers: u64,
}

impl AdaptiveController {
    pub fn new(
        policy: AdaptPolicy,
        gauge: MemoryGauge,
        base_bits: u32,
        base_tau: f32,
        nominal_goodput_bps: f64,
        n_devices: usize,
    ) -> AdaptiveController {
        assert!(n_devices >= 1);
        assert!(nominal_goodput_bps > 0.0);
        assert!(!policy.qa_candidates.is_empty(), "need at least one Q̄a candidate");
        // The data plane's legal Q̄a range (quant::fused asserts 2..=16):
        // an out-of-range rung would panic the edge compressor mid-stream
        // instead of being a planning-time error here.
        assert!(
            (2..=16).contains(&base_bits)
                && policy.qa_candidates.iter().all(|b| (2..=16).contains(b)),
            "Q̄a candidates and the base plan must lie in 2..=16"
        );
        let base_plan = DevicePlan { bits: base_bits, include_kv: true, degraded: false };
        let devices = (0..n_devices)
            .map(|_| DeviceState {
                estimator: BandwidthEstimator::new(policy.ewma_alpha, nominal_goodput_bps),
                planned_goodput: nominal_goodput_bps,
                plan: base_plan,
            })
            .collect();
        AdaptiveController {
            policy,
            gauge,
            base_bits,
            base_tau,
            nominal_goodput: nominal_goodput_bps,
            devices,
            replans: 0,
            reconfigs: 0,
            defers: 0,
        }
    }

    /// Fold one frame's transfer accounting into a device's estimator.
    pub fn observe(&mut self, device: usize, outcome: &TransferOutcome) {
        self.devices[device].estimator.observe(outcome);
    }

    /// Reset a device's estimator to the cold prior at its CURRENT
    /// plan's goodput anchor. Called by the serve loop after a wire fault
    /// on that device: the fault window's samples measure the fault, not
    /// the channel, and feeding them forward would trigger a spurious
    /// Eq. 8 downgrade for every healthy session sharing the device.
    pub fn reanchor(&mut self, device: usize) {
        let d = &mut self.devices[device];
        d.estimator.re_anchor(d.planned_goodput);
    }

    /// Device plans re-solved over the run (Eq. 8 invocations).
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Per-session reconfigurations emitted over the run.
    pub fn reconfigs(&self) -> u64 {
        self.reconfigs
    }

    /// Due changes deferred because the session was mid-`Resume`.
    pub fn defers(&self) -> u64 {
        self.defers
    }

    /// A device's current plan target.
    pub fn device_plan(&self, device: usize) -> DevicePlan {
        self.devices[device].plan
    }

    /// A device's current goodput estimate (bytes/s).
    pub fn estimated_goodput(&self, device: usize) -> f64 {
        self.devices[device].estimator.goodput_bps()
    }

    /// Predicted per-step wire seconds of one decode transmission at the
    /// widest I_kv-feasible probe width, under `goodput`.
    fn step_wire_s(&self, bits: u32, include_kv: bool, goodput: f64) -> f64 {
        let cfg = &self.gauge.cfg;
        let w = cfg.prefill_len;
        let qa = ActBits::uniform(bits);
        let bytes = memory::io_bytes(cfg, w, self.gauge.split, include_kv, &qa);
        bytes as f64 / goodput.max(1e-9)
    }

    /// Re-invoke the Eq. (8) search with the deployed split and weight
    /// precision pinned and a single Q̄a candidate: feasible iff the
    /// accuracy bound (8b) and the memory budget (8c) both hold at the
    /// uniform precision.
    fn plan_feasible(&self, bits: u32) -> bool {
        let mut inputs = PlanInputs::defaults(
            self.gauge.cfg.clone(),
            self.gauge.mem_budget_bytes,
            self.gauge.cfg.max_seq,
        );
        inputs.acc_tolerance = self.policy.acc_tolerance;
        inputs.split_candidates = vec![self.gauge.split];
        inputs.qw_candidates = vec![self.gauge.qw_front];
        inputs.qa_candidates = vec![bits];
        planner::plan(&inputs, &AnalyticAccuracyModel).is_some()
    }

    /// Solve for a new device plan at the estimated goodput.
    fn replan(&self, g_est: f64, current: &DevicePlan) -> DevicePlan {
        // The step budget the static plan implicitly promised: its own
        // per-step wire time under the nominal channel, with slack.
        let budget_s = self.step_wire_s(self.base_bits, true, self.nominal_goodput)
            * self.policy.slack;
        let fits_link = |bits: u32, include_kv: bool| {
            let margin =
                if bits > current.bits { 1.0 - self.policy.min_rel_gain } else { 1.0 };
            self.step_wire_s(bits, include_kv, g_est) <= budget_s * margin
        };
        // The candidate ladder is capped AT the deployed static plan: the
        // static Q̄a is the nominal-channel optimum, so anything wider
        // busts the nominal step budget by construction, and a transient
        // goodput over-estimate must never strand a device above it
        // (upgrades stop at base_bits; downgrades go as deep as the
        // candidate set allows). The baseline itself is always a
        // candidate, and it is EXEMPT from Eq. 8 re-judgment: the
        // offline planner (or the operator) already chose it, and the
        // control plane must always be able to fall back to it — a
        // deployment whose static Q̄a the analytic accuracy model happens
        // to reject would otherwise never recover to its own plan.
        let mut candidates: Vec<u32> = self
            .policy
            .qa_candidates
            .iter()
            .copied()
            .filter(|&b| b <= self.base_bits)
            .collect();
        if !candidates.contains(&self.base_bits) {
            candidates.push(self.base_bits);
        }
        candidates.sort_unstable();
        let feasible = |b: u32| b == self.base_bits || self.plan_feasible(b);
        // Ladder rung 1: keep the KV cache on the wire, recompress harder
        // (or, when the link recovered, wider again — capped at the
        // static plan).
        for &b in candidates.iter().rev() {
            if fits_link(b, true) && feasible(b) {
                return DevicePlan { bits: b, include_kv: true, degraded: false };
            }
        }
        // Ladder rung 2: drop the KV transmission (I_kv = 0).
        for &b in candidates.iter().rev() {
            if fits_link(b, false) && feasible(b) {
                return DevicePlan { bits: b, include_kv: false, degraded: false };
            }
        }
        // Ladder rung 3: nothing fits — cheapest settings, and sessions
        // shed remaining budget (reconcile applies the cut).
        DevicePlan { bits: candidates[0], include_kv: false, degraded: true }
    }

    /// Device-level trigger: re-plan when the goodput estimate has left
    /// the deadband around the current plan's reference. Call once per
    /// device per serve iteration.
    pub fn device_update(&mut self, device: usize) {
        let (g_est, samples, planned, current) = {
            let d = &self.devices[device];
            (d.estimator.goodput_bps(), d.estimator.samples(), d.planned_goodput, d.plan)
        };
        if samples < self.policy.warmup_samples || planned <= 0.0 {
            return;
        }
        let deviation = g_est / planned - 1.0;
        // Strand guard: the deadband is centered on the *current plan's*
        // anchor, so a device whose anchor was dragged down by poisoned
        // fault-storm telemetry (retry latencies measure the storm, not
        // the channel) could sit parked below the static plan while the
        // recovered link would carry it fine — the +33% recovery
        // deviation never clears a 0.6 deadband. A device below the
        // static fallback therefore also re-plans whenever the estimate
        // supports the static plan with the full upgrade margin; the
        // ladder then restores exactly the deployed baseline.
        let below_base =
            current.bits < self.base_bits || !current.include_kv || current.degraded;
        let base_fits_now = below_base && {
            let budget_s = self.step_wire_s(self.base_bits, true, self.nominal_goodput)
                * self.policy.slack;
            self.step_wire_s(self.base_bits, true, g_est)
                <= budget_s * (1.0 - self.policy.min_rel_gain)
        };
        if deviation.abs() <= self.policy.deadband && !base_fits_now {
            return;
        }
        let new_plan = self.replan(g_est, &current);
        self.replans += 1;
        let d = &mut self.devices[device];
        d.planned_goodput = g_est;
        d.plan = new_plan;
    }

    /// Session-level actuation: emit a [`Reconfig`] when the session's
    /// applied plan differs from its device's target (respecting the
    /// cooldown, per-session I_kv feasibility, and the Eq. 8c budget for
    /// the remaining horizon). `None` = nothing to change — including a
    /// change deferred because the session is mid-`Resume` (use
    /// [`reconcile_checked`](Self::reconcile_checked) to distinguish the
    /// typed defer from a genuine hold).
    pub fn reconcile(&mut self, device: usize, view: &SessionView) -> Option<Reconfig> {
        match self.reconcile_checked(device, view) {
            ReconcileDecision::Actuate(rc) => Some(rc),
            ReconcileDecision::Defer | ReconcileDecision::Hold => None,
        }
    }

    /// [`reconcile`](Self::reconcile) with the mid-`Resume` race made
    /// typed: a due change for a session whose Resume handshake is still
    /// settling is returned as [`ReconcileDecision::Defer`] — the session
    /// is never reconfigured under the handshake and never aborted, it
    /// simply keeps its applied plan until the next pass.
    pub fn reconcile_checked(&mut self, device: usize, view: &SessionView) -> ReconcileDecision {
        match self.compute_reconfig(device, view) {
            None => ReconcileDecision::Hold,
            Some(_) if view.mid_resume => {
                self.defers += 1;
                ReconcileDecision::Defer
            }
            Some(rc) => {
                self.reconfigs += 1;
                ReconcileDecision::Actuate(rc)
            }
        }
    }

    /// The pure decision: what `Reconfig`, if any, would reconcile this
    /// session with its device's plan. No counters, no gating on the
    /// session's handshake state.
    fn compute_reconfig(&self, device: usize, view: &SessionView) -> Option<Reconfig> {
        let plan = self.devices[device].plan;
        if view.remaining_budget == 0 || view.steps_since_reconfig < self.policy.cooldown_steps
        {
            return None;
        }
        let w_live = (view.seq_len + view.remaining_budget).min(view.max_seq);
        // Per-session I_kv feasibility: going stateless needs the WHOLE
        // remaining horizon to fit the prefill width; going back to KV
        // shipping needs a non-stale edge-held cloud cache.
        let mut include_kv = plan.include_kv;
        if !include_kv && w_live > view.prefill_len {
            include_kv = true;
        }
        if include_kv && !view.kv_shippable {
            include_kv = false;
        }
        let mut budget_cap = Reconfig::NO_BUDGET_CAP;
        if plan.degraded && view.remaining_budget >= 2 {
            // Algorithm 2's last rung at plan level: halve what remains.
            budget_cap = (view.remaining_budget as u32).div_ceil(2);
        }
        // Remaining-sequence budget L the edge memory can hold at the new
        // precision (Eq. 8c via the gauge). No headroom AT ALL at the new
        // precision (l_mem ≤ current length) means the session may not
        // grow another token: cap L to zero, ending it cleanly.
        let qa = ActBits::uniform(plan.bits);
        let l_mem = self.gauge.max_tokens(&qa, view.max_seq);
        if l_mem > view.seq_len {
            let rem_mem = (l_mem - view.seq_len) as u32;
            if (rem_mem as usize) < view.remaining_budget {
                budget_cap = budget_cap.min(rem_mem);
            }
        } else {
            budget_cap = 0;
        }
        // A stale-KV session is pinned to stateless serving; if its
        // horizon outgrows the prefill width, cap L to the steps the
        // cloud can still recompute (rather than letting the session be
        // force-ended at the boundary).
        if !include_kv && w_live > view.prefill_len {
            budget_cap =
                budget_cap.min(view.prefill_len.saturating_sub(view.seq_len) as u32);
        }
        if plan.bits == view.applied_bits
            && include_kv == view.applied_kv
            && budget_cap == Reconfig::NO_BUDGET_CAP
        {
            return None; // minimum improvement: no change worth a frame
        }
        Some(Reconfig {
            request_id: view.request_id,
            epoch: view.epoch + 1,
            qa_bits: plan.bits,
            // Under pressure, also harden the TS threshold: fewer lossless
            // outliers on the wire while the bulk is coarse anyway.
            tau: if plan.bits < self.base_bits { self.base_tau * 2.0 } else { self.base_tau },
            include_kv,
            budget_cap,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn small_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 4;
        cfg
    }

    fn controller(n_devices: usize) -> AdaptiveController {
        let cfg = small_cfg();
        let gauge = MemoryGauge::new(cfg, 2, 4, 64 * 1024 * 1024);
        AdaptiveController::new(AdaptPolicy::default(), gauge, 4, 5.0, 2e6, n_devices)
    }

    fn feed(ctrl: &mut AdaptiveController, device: usize, goodput: f64, frames: usize) {
        for _ in 0..frames {
            ctrl.observe(
                device,
                &TransferOutcome {
                    latency_s: 4000.0 / goodput,
                    attempts: 1,
                    outage: false,
                    payload_bytes: 4000,
                },
            );
        }
    }

    fn view(epoch: u32, steps: u64) -> SessionView {
        SessionView {
            request_id: 9,
            epoch,
            seq_len: 8,
            remaining_budget: 10,
            prefill_len: 64,
            max_seq: 128,
            applied_bits: 4,
            applied_kv: true,
            kv_shippable: true,
            steps_since_reconfig: steps,
            mid_resume: false,
        }
    }

    #[test]
    fn on_plan_goodput_never_replans() {
        let mut c = controller(1);
        feed(&mut c, 0, 2e6, 100);
        for _ in 0..50 {
            c.device_update(0);
        }
        assert_eq!(c.replans(), 0);
        assert_eq!(c.device_plan(0), DevicePlan { bits: 4, include_kv: true, degraded: false });
        assert!(c.reconcile(0, &view(0, 100)).is_none(), "no drift, no reconfig");
    }

    #[test]
    fn mild_fluctuation_stays_inside_deadband() {
        let mut c = controller(1);
        // ±30% swings: inside the 55% deadband, so the plan must hold.
        for round in 0..20 {
            let g = if round % 2 == 0 { 2.6e6 } else { 1.4e6 };
            feed(&mut c, 0, g, 5);
            c.device_update(0);
        }
        assert_eq!(c.replans(), 0, "deadband must absorb ±30% noise");
    }

    #[test]
    fn collapse_triggers_downgrade_and_recovery_restores_base() {
        let mut c = controller(1);
        feed(&mut c, 0, 2e6 / 15.0, 60); // deep degradation
        c.device_update(0);
        assert_eq!(c.replans(), 1);
        let down = c.device_plan(0);
        assert!(
            !down.include_kv || down.bits < 4,
            "degraded link must shed bytes: {down:?}"
        );
        let rc = c.reconcile(0, &view(0, 10)).expect("plan changed, reconfig due");
        assert_eq!(rc.epoch, 1);
        assert_eq!(rc.qa_bits, down.bits);
        assert!(rc.tau >= 5.0);
        // cooldown: a just-reconfigured session is left alone
        assert!(c.reconcile(0, &view(1, 0)).is_none());
        // recovery: estimator climbs back to nominal → re-plan restores
        // the static configuration, and never overshoots above it.
        feed(&mut c, 0, 2e6, 120);
        c.device_update(0);
        assert_eq!(c.replans(), 2);
        assert_eq!(
            c.device_plan(0),
            DevicePlan { bits: 4, include_kv: true, degraded: false },
            "recovery must converge back to the static plan"
        );
        let mut v = view(1, 10);
        v.applied_bits = down.bits;
        v.applied_kv = down.include_kv;
        let rc = c.reconcile(0, &v).expect("restore reconfig");
        assert_eq!(rc.qa_bits, 4);
        assert!(rc.include_kv);
        assert_eq!(rc.epoch, 2);
        assert_eq!(rc.budget_cap, Reconfig::NO_BUDGET_CAP);
        // converged: the applied plan now matches — silence.
        let mut v = view(2, 10);
        v.applied_bits = 4;
        v.applied_kv = true;
        assert!(c.reconcile(0, &v).is_none(), "converged controller must not flap");
    }

    #[test]
    fn accuracy_bound_blocks_two_bit_rung() {
        // For the 4-layer config the Eq. 8b analytic model rejects
        // uniform 2-bit activations (drop ≈ 4.6 > 1.0): even under heavy
        // degradation the re-plan may not choose 2 bits as a non-degraded
        // plan — it either finds an accuracy-feasible rung or degrades.
        let c = controller(1);
        assert!(!c.plan_feasible(2));
        assert!(c.plan_feasible(3) && c.plan_feasible(4) && c.plan_feasible(8));
        let plan = c.replan(2e6 / 15.0, &DevicePlan { bits: 4, include_kv: true, degraded: false });
        assert!(plan.degraded || plan.bits >= 3, "2-bit rung violates Eq. 8b: {plan:?}");
    }

    #[test]
    fn total_collapse_enters_degraded_regime_and_sheds_budget() {
        let mut c = controller(1);
        feed(&mut c, 0, 2e6 / 200.0, 80);
        c.device_update(0);
        let plan = c.device_plan(0);
        assert!(plan.degraded, "nothing fits a 200x collapse: {plan:?}");
        let rc = c.reconcile(0, &view(0, 10)).expect("degraded reconfig");
        assert!(rc.budget_cap != Reconfig::NO_BUDGET_CAP, "degraded regime must cap L");
        assert!(rc.budget_cap >= 1 && (rc.budget_cap as usize) < 10);
    }

    #[test]
    fn session_without_prefill_headroom_keeps_kv() {
        let mut c = controller(1);
        feed(&mut c, 0, 2e6 / 15.0, 60);
        c.device_update(0);
        let plan = c.device_plan(0);
        assert!(!plan.include_kv, "15x degradation should prefer I_kv = 0: {plan:?}");
        // horizon beyond the prefill width: I_kv = 0 infeasible for this
        // session, so the emitted reconfig must keep KV shipping. Pin a
        // bits mismatch so a reconfig is due regardless.
        let mut v = view(0, 10);
        v.seq_len = 60;
        v.remaining_budget = 20; // w_live = 80 > prefill 64
        v.applied_bits = 8;
        let rc = c.reconcile(0, &v).expect("bits differ, reconfig due");
        assert!(rc.include_kv, "must keep KV when the horizon outgrows prefill");
        assert_eq!(rc.qa_bits, plan.bits);
    }

    #[test]
    fn stale_kv_session_is_never_asked_to_ship_again() {
        // Device plan is back at the static {4 bits, KV on}, but the
        // session served stateless steps: the controller may restore the
        // bit width, must NOT restore KV shipping, and must then go
        // silent instead of re-asking every cooldown.
        let mut c = controller(1);
        let mut v = view(3, 10);
        v.applied_bits = 2;
        v.applied_kv = false;
        v.kv_shippable = false;
        let rc = c.reconcile(0, &v).expect("bit restore due");
        assert_eq!(rc.qa_bits, 4);
        assert!(!rc.include_kv, "stale cloud-KV copy must never ship again");
        let mut v2 = v;
        v2.applied_bits = 4; // the restore applied
        assert!(c.reconcile(0, &v2).is_none(), "reconciled stale session must be left alone");
    }

    #[test]
    fn per_device_isolation() {
        let mut c = controller(2);
        feed(&mut c, 0, 2e6 / 15.0, 60);
        feed(&mut c, 1, 2e6, 60);
        c.device_update(0);
        c.device_update(1);
        assert_ne!(c.device_plan(0), c.device_plan(1), "only device 0 degraded");
        assert_eq!(c.device_plan(1), DevicePlan { bits: 4, include_kv: true, degraded: false });
    }

    #[test]
    fn mid_resume_change_is_a_typed_defer_not_an_abort() {
        let mut c = controller(1);
        feed(&mut c, 0, 2e6 / 15.0, 60);
        c.device_update(0);
        // a change IS due for this session...
        let mut v = view(0, 10);
        v.mid_resume = true;
        assert_eq!(
            c.reconcile_checked(0, &v),
            ReconcileDecision::Defer,
            "a due change mid-Resume must be a typed defer"
        );
        assert_eq!(c.reconfigs(), 0, "a deferred change must not count as emitted");
        assert_eq!(c.defers(), 1);
        // the legacy entry point stays quiet instead of racing the
        // handshake — and nothing about the session was aborted
        assert!(c.reconcile(0, &v).is_none());
        // ...and the moment the handshake settles, the same view actuates
        v.mid_resume = false;
        match c.reconcile_checked(0, &v) {
            ReconcileDecision::Actuate(rc) => assert_eq!(rc.request_id, v.request_id),
            other => panic!("settled session must actuate, got {other:?}"),
        }
        assert_eq!(c.reconfigs(), 1);
    }

    #[test]
    fn mid_resume_with_nothing_due_is_a_plain_hold() {
        let mut c = controller(1);
        let mut v = view(0, 100);
        v.mid_resume = true;
        assert_eq!(c.reconcile_checked(0, &v), ReconcileDecision::Hold);
        assert_eq!(c.defers(), 0, "holds are not defers");
    }

    #[test]
    fn poisoned_telemetry_never_strands_below_the_static_fallback() {
        // Adversarial estimator: a fault storm's retry latencies look like
        // a goodput collapse, then flap wildly. Pin two things: (1) the
        // plan ladder never leaves the candidate range and never exceeds
        // the static plan, whatever garbage arrives; (2) after the storm,
        // reanchor + nominal traffic converge the device EXACTLY back to
        // the static fallback plan — recovery can't strand a device on a
        // storm-era downgrade.
        let mut c = controller(1);
        let static_plan = DevicePlan { bits: 4, include_kv: true, degraded: false };
        let mut rng_state = 0x5EEDu64;
        for round in 0..40 {
            // xorshift garbage goodputs across 4 orders of magnitude
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            let g = 2e2 + (rng_state % 10_000) as f64 * 2e3;
            feed(&mut c, 0, g, 5);
            // storm frames also carry outage markers and retry counts
            c.observe(
                0,
                &TransferOutcome {
                    latency_s: 0.5,
                    attempts: 6,
                    outage: true,
                    payload_bytes: 100,
                },
            );
            c.device_update(0);
            let p = c.device_plan(0);
            assert!(
                p.bits <= 4 && (2..=16).contains(&p.bits),
                "round {round}: poisoned plan {p:?} left the legal ladder"
            );
        }
        // storm over: the serve loop reanchors the device, traffic is
        // nominal again
        c.reanchor(0);
        feed(&mut c, 0, 2e6, 120);
        c.device_update(0);
        assert_eq!(
            c.device_plan(0),
            static_plan,
            "recovery must converge to the static fallback, not strand below it"
        );
        // and a session still carrying a storm-era downgrade is restored
        let mut v = view(0, 10);
        v.applied_bits = 2;
        v.applied_kv = false;
        let rc = c.reconcile(0, &v).expect("restore due after recovery");
        assert_eq!(rc.qa_bits, 4);
        assert!(rc.include_kv);
    }
}
