//! Online adaptive control plane — the loop the paper's title promises.
//!
//! The Eq. (8) configuration search in `planner::config_search` runs once,
//! offline, against an *assumed* link; the serve loop then executes that
//! static plan. This module closes the loop at runtime:
//!
//!   * **telemetry** — a [`BandwidthEstimator`] distills the per-frame
//!     [`TransferOutcome`](crate::channel::TransferOutcome)s the wire
//!     layer already measures into a smoothed goodput estimate, and a
//!     [`MemoryGauge`] wraps the Eq. (1)-(3) byte models into live
//!     headroom queries;
//!   * **decision** — an [`AdaptiveController`] watches each device's
//!     estimate, and when it deviates from the goodput the current plan
//!     was chosen against (beyond a deadband, after a warmup, outside a
//!     cooldown) it **re-invokes [`planner::plan`](crate::planner::plan)**
//!     with the link-feasible candidate set, walking the same ladder the
//!     paper's Algorithm 2 walks per-step — recompress harder, drop the
//!     KV transmission, shrink the remaining token budget L — but at the
//!     plan level, across whole sessions;
//!   * **actuation** — decisions are emitted as per-session [`Reconfig`]
//!     messages (wire frame kind 3, format v4), applied to the session's
//!     transmission settings on the edge and announced to the cloud so
//!     the stateless server can hold the data plane to the control
//!     plane's word mid-stream (including in cross-process serving).
//!
//! Two invariants anchor the design (pinned in `tests/adapt_serve.rs`):
//! under a constant channel the controller never fires and the adaptive
//! run is bit-identical to the static one, and every drift scenario run
//! is seed-reproducible end to end (the channel trace is keyed on the
//! link's own simulated clock, never on wall time).

pub mod controller;
pub mod reconfig;
pub mod telemetry;

pub use controller::{
    AdaptPolicy, AdaptiveController, DevicePlan, ReconcileDecision, SessionView,
};
pub use reconfig::Reconfig;
pub use telemetry::{expected_goodput_bps, BandwidthEstimator, MemoryGauge};
