//! Metrics registry: counters, gauges, and log-linear histograms.
//!
//! Design constraints, in order:
//!
//! 1. **Zero allocation on the hot path.** Registration (name lookup,
//!    bucket array allocation) happens once, up front; after that a
//!    handle is a plain `Arc` and every `inc`/`add`/`record` is a single
//!    relaxed atomic RMW. Nothing on the record path touches a `String`,
//!    a lock, or the allocator.
//! 2. **Deterministic.** A counter is a commutative sum and a histogram
//!    is a vector of commutative bucket sums, so the final state depends
//!    only on the *multiset* of recorded values — not on thread
//!    interleaving. That is what lets the soak harness assert exact
//!    equality between a concurrent run and a single-threaded replay.
//! 3. **Snapshot-diffable.** [`Registry::snapshot`] captures every
//!    metric into a plain-data [`Snapshot`](super::Snapshot) that forms
//!    a group under `diff`/`merge` (`a.diff(b).merge(b) == a`), which is
//!    the algebra the leak and drift audits are written against.
//!
//! Histograms are log-linear (HDR-style): values below 8 get exact unit
//! buckets; above that, every power-of-two octave is split into 8 linear
//! sub-buckets, bounding the relative quantile error at 12.5% while
//! covering the full `u64` range in [`BUCKETS`] slots.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::events::{Event, EventKind, EventRing};
use super::snapshot::{HistSnapshot, Snapshot};

/// Monotone event counter. `set` exists for *mirror publication* — a
/// subsystem that still owns a legacy stat struct republishes absolute
/// values into the registry — and must not be mixed with `inc`/`add` on
/// the same metric.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Mirror an externally-maintained absolute value.
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (headroom bytes, live sessions, ...).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Sub-buckets per power-of-two octave (log-linear resolution).
pub const SUB_BUCKETS: usize = 8;

/// Total bucket count covering the full `u64` domain: 8 exact unit
/// buckets for v < 8, then 8 sub-buckets for each octave m in 3..=63.
pub const BUCKETS: usize = 8 + 61 * SUB_BUCKETS;

/// Bucket index for a recorded value. Values below 8 map exactly; above
/// that the octave is `msb(v)` and the sub-bucket is the next 3 bits.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 8 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= 3
    let sub = ((v >> (msb - 3)) & 7) as usize;
    8 + (msb - 3) * SUB_BUCKETS + sub
}

/// Inclusive lower bound of a bucket.
pub fn bucket_lower(i: usize) -> u64 {
    if i < 8 {
        return i as u64;
    }
    let oct = (i - 8) / SUB_BUCKETS;
    let sub = ((i - 8) % SUB_BUCKETS) as u64;
    let m = (oct + 3) as u32;
    (1u64 << m) + sub * (1u64 << (m - 3))
}

/// Exclusive upper bound of a bucket (saturating at `u64::MAX`).
pub fn bucket_upper(i: usize) -> u64 {
    if i + 1 < BUCKETS {
        bucket_lower(i + 1)
    } else {
        u64::MAX
    }
}

/// Log-linear histogram over `u64` values (latencies in microseconds,
/// byte counts, ...). Bucket counts are relaxed atomics: recording is
/// one RMW, and the final distribution is interleaving-independent.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets = (0..BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram { buckets, sum: AtomicU64::new(0) }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Sparse `(bucket index, count)` pairs for non-empty buckets.
    pub fn sparse(&self) -> Vec<(u32, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i as u32, n))
            })
            .collect()
    }

    /// Quantile estimate (q in [0, 1]); relative error bounded by the
    /// bucket half-width (6.25% above 8, exact below).
    pub fn quantile(&self, q: f64) -> u64 {
        HistSnapshot { count: self.count(), sum: self.sum(), buckets: self.sparse(), label: None }
            .quantile(q)
    }

    pub fn to_snapshot(&self, label: Option<(String, String)>) -> HistSnapshot {
        HistSnapshot { count: self.count(), sum: self.sum(), buckets: self.sparse(), label }
    }
}

/// A subsystem whose legacy stat struct can be republished into the
/// registry under stable metric names. This is the thin-wrapper layer
/// the ad-hoc `PoolStats`/`FleetStats`/prefix counters sit behind: the
/// structs keep their fields (callers don't break), but the registry is
/// the one schema every path reports through.
pub trait MetricSource {
    /// `(metric name, absolute value)` pairs. Names must be stable —
    /// they are the exposition schema.
    fn metrics(&self) -> Vec<(&'static str, u64)>;
}

/// Fold a [`MetricSource`] into a running total map (used to aggregate
/// one schema across pool workers).
pub fn accumulate(into: &mut BTreeMap<&'static str, u64>, src: &impl MetricSource) {
    for (k, v) in src.metrics() {
        *into.entry(k).or_insert(0) += v;
    }
}

/// The metrics registry: named counters, gauges, histograms, plus the
/// bounded structured event ring and a virtual-time source the soak
/// driver advances.
#[derive(Debug)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, (Option<(String, String)>, Arc<Histogram>)>>,
    events: EventRing,
    now_ms: AtomicU64,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry::with_event_capacity(4096)
    }

    pub fn with_event_capacity(cap: usize) -> Registry {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
            events: EventRing::new(cap),
            now_ms: AtomicU64::new(0),
        }
    }

    /// Get-or-register a counter. Allocates only on first use of a name;
    /// hold the returned handle for hot-path recording.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.counters.lock().unwrap();
        if let Some(c) = m.get(name) {
            return c.clone();
        }
        let c = Arc::new(Counter::new());
        m.insert(name.to_string(), c.clone());
        c
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.gauges.lock().unwrap();
        if let Some(g) = m.get(name) {
            return g.clone();
        }
        let g = Arc::new(Gauge::new());
        m.insert(name.to_string(), g.clone());
        g
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histogram_entry(name.to_string(), None)
    }

    /// Histogram carrying one `key="value"` label (per-region latency
    /// series). The label rides into exposition; the map key is the
    /// rendered `name{key="value"}` form, so distinct label values are
    /// distinct series.
    pub fn histogram_labeled(&self, name: &str, key: &str, value: &str) -> Arc<Histogram> {
        let rendered = format!("{name}{{{key}=\"{value}\"}}");
        self.histogram_entry(rendered, Some((key.to_string(), value.to_string())))
    }

    fn histogram_entry(&self, key: String, label: Option<(String, String)>) -> Arc<Histogram> {
        let mut m = self.hists.lock().unwrap();
        if let Some((_, h)) = m.get(&key) {
            return h.clone();
        }
        let h = Arc::new(Histogram::new());
        m.insert(key, (label, h.clone()));
        h
    }

    /// Republish a legacy stat struct's counters (mirror semantics).
    pub fn publish(&self, src: &impl MetricSource) {
        for (k, v) in src.metrics() {
            self.counter(k).set(v);
        }
    }

    pub fn publish_totals(&self, totals: &BTreeMap<&'static str, u64>) {
        for (k, v) in totals {
            self.counter(k).set(*v);
        }
    }

    /// Virtual "now" in milliseconds; the soak driver owns this clock,
    /// real-time paths may leave it at zero.
    pub fn set_time_ms(&self, t: u64) {
        self.now_ms.store(t, Ordering::Relaxed);
    }

    pub fn time_ms(&self) -> u64 {
        self.now_ms.load(Ordering::Relaxed)
    }

    /// Push a structured event stamped with the registry's virtual time.
    pub fn event(&self, kind: EventKind, request_id: u64, a: u64, b: u64) {
        self.events.push(kind, self.time_ms(), request_id, a, b);
    }

    pub fn events(&self) -> Vec<Event> {
        self.events.recent()
    }

    pub fn events_dropped(&self) -> u64 {
        self.events.dropped()
    }

    /// Total events ever pushed (retained + overwritten).
    pub fn events_total(&self) -> u64 {
        self.events.total()
    }

    /// Capture every metric into plain diffable data. Zero-valued
    /// counters/gauges and empty histograms are dropped so the snapshot
    /// is canonical (required for the diff/merge group laws).
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        for (k, c) in self.counters.lock().unwrap().iter() {
            let v = c.get();
            if v != 0 {
                snap.counters.insert(k.clone(), v);
            }
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            let v = g.get();
            if v != 0 {
                snap.gauges.insert(k.clone(), v);
            }
        }
        for (k, (label, h)) in self.hists.lock().unwrap().iter() {
            let hs = h.to_snapshot(label.clone());
            if hs.count != 0 {
                snap.hists.insert(k.clone(), hs);
            }
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_below_eight_and_log_linear_above() {
        // Exact unit buckets below 8.
        for v in 0..8u64 {
            let i = bucket_index(v);
            assert_eq!(bucket_lower(i), v);
            assert_eq!(bucket_upper(i), v + 1);
        }
        // Every value lands inside its bucket's [lower, upper) span.
        for &v in &[8u64, 9, 15, 16, 17, 100, 1023, 1024, 1 << 20, (1 << 40) + 12345, u64::MAX] {
            let i = bucket_index(v);
            assert!(i < BUCKETS, "index {i} out of range for {v}");
            assert!(bucket_lower(i) <= v, "v={v} below bucket {i} lower {}", bucket_lower(i));
            assert!(v < bucket_upper(i) || bucket_upper(i) == u64::MAX, "v={v} above bucket {i}");
        }
        // Octave boundaries: lower(8 + 8k) == 2^(3+k).
        for k in 0..10usize {
            assert_eq!(bucket_lower(8 + SUB_BUCKETS * k), 1u64 << (3 + k));
        }
        // Relative width within an octave is 1/8 of the octave base.
        let i = bucket_index(1 << 20);
        assert_eq!(bucket_upper(i) - bucket_lower(i), (1 << 20) / 8);
    }

    #[test]
    fn bucket_index_is_monotone() {
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < (1u64 << 40) {
            let i = bucket_index(v);
            assert!(i >= prev, "index regressed at v={v}");
            prev = i;
            v = v.saturating_mul(3) / 2 + 1;
        }
    }

    #[test]
    fn quantiles_respect_the_log_linear_error_bound() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for &(q, exact) in &[(0.50, 5000u64), (0.95, 9500), (0.99, 9900)] {
            let est = h.quantile(q);
            let err = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(err <= 0.125, "q={q}: est {est} vs exact {exact} (err {err:.4})");
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.sum(), 10_000 * 10_001 / 2);
    }

    #[test]
    fn concurrent_recording_is_deterministic() {
        // The same multiset of values, recorded across 8 scoped threads
        // in whatever interleaving the scheduler picks, must produce a
        // snapshot EQUAL to the single-threaded reference.
        let reg = Registry::new();
        let c = reg.counter("ops");
        let h = reg.histogram("latency_us");
        std::thread::scope(|s| {
            for t in 0..8u64 {
                let c = c.clone();
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000u64 {
                        c.inc();
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        let reference = Registry::new();
        let rc = reference.counter("ops");
        let rh = reference.histogram("latency_us");
        for v in 0..8000u64 {
            rc.inc();
            rh.record(v);
        }
        assert_eq!(reg.snapshot(), reference.snapshot());
    }

    #[test]
    fn handles_are_shared_per_name() {
        let reg = Registry::new();
        reg.counter("x").add(3);
        reg.counter("x").add(4);
        assert_eq!(reg.counter("x").get(), 7);
        reg.gauge("g").set(-2);
        assert_eq!(reg.gauge("g").get(), -2);
        reg.histogram("h").record(5);
        assert_eq!(reg.histogram("h").count(), 1);
    }
}
