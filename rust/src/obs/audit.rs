//! Leak and drift audits over registry snapshots and pool ledgers.
//!
//! The paper's Eq. 8c admission story is only trustworthy if, after
//! hours of churn, every charge it took is provably given back. Two
//! audit passes make that checkable:
//!
//! * **Leak audit** ([`LeakReport`]): after every edge has closed and
//!   every session retired — through whatever mix of normal EOS,
//!   drain, rebalance, kill/recover, and migration the run saw — the
//!   pool must hold ZERO live admission charges, replay fences, control
//!   entries, resume fences, placements, in-flight replay buffers,
//!   queued frames, and prefix attachments. Resident *unpinned* prefix
//!   rows are cache, not leak: the LRU owns them, so charged bytes are
//!   audited against the store budget rather than against zero.
//!
//! * **Drift audit** ([`DriftAudit`]): during the run, (a) completed
//!   token streams are spot-checked bit-for-bit against a fault-free
//!   solo replay, (b) the registry's mirrored gauges are reconciled
//!   against the live pool getters they claim to mirror, and (c) every
//!   worker's headroom accounting is reconciled: live KV charged on a
//!   worker must never exceed its Eq. 8c budget.
//!
//! Both audits are the soak pass criterion: a soak run that streams
//! millions of tokens but leaks one fence, or serves one silently
//! different token, fails.

use crate::obs::Registry;
use crate::pool::CloudPool;

/// Outstanding-state census of a pool that should be empty. Every field
/// is a leak when non-zero (see module docs for the prefix-bytes rule).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LeakReport {
    /// Live Eq. 8c admission charges summed across workers.
    pub live_sessions: u64,
    /// Replay fences summed across workers.
    pub fence_entries: u64,
    /// Reconfig control entries summed across workers.
    pub control_entries: u64,
    /// Resume epoch fences summed across workers.
    pub resume_entries: u64,
    /// Pool placement ledger entries.
    pub placed_sessions: u64,
    /// Pool-level in-flight replay buffers.
    pub inflight_frames: u64,
    /// Frames still queued inside worker schedulers.
    pub pending_frames: u64,
    /// Pinned prefix refcounts summed across workers.
    pub prefix_attachments: u64,
    /// Bytes the prefix stores charge BEYOND their configured budgets
    /// (resident-under-budget rows are cache, not leak).
    pub prefix_over_budget_bytes: u64,
}

impl LeakReport {
    /// Census the pool now. Call after closing every edge.
    pub fn audit(pool: &CloudPool) -> LeakReport {
        let mut pending_frames = 0u64;
        for i in 0..pool.worker_count() {
            pending_frames += pool.worker(i).pending_frames() as u64;
        }
        LeakReport {
            live_sessions: pool.live_sessions() as u64,
            fence_entries: pool.fence_entries() as u64,
            control_entries: pool.control_entries() as u64,
            resume_entries: pool.resume_entries() as u64,
            placed_sessions: pool.placed_sessions() as u64,
            inflight_frames: pool.inflight_frames() as u64,
            pending_frames,
            prefix_attachments: pool.prefix_attachments() as u64,
            prefix_over_budget_bytes: pool
                .prefix_charged_bytes()
                .saturating_sub(pool.prefix_budget_bytes()),
        }
    }

    pub fn clean(&self) -> bool {
        *self == LeakReport::default()
    }

    /// Total outstanding entries (the "leak count" the bench reports).
    pub fn total(&self) -> u64 {
        self.live_sessions
            + self.fence_entries
            + self.control_entries
            + self.resume_entries
            + self.placed_sessions
            + self.inflight_frames
            + self.pending_frames
            + self.prefix_attachments
            + self.prefix_over_budget_bytes
    }

    /// Publish the census as registry gauges (`leak_*` schema).
    pub fn publish(&self, reg: &Registry) {
        reg.gauge("leak_live_sessions").set(self.live_sessions as i64);
        reg.gauge("leak_fence_entries").set(self.fence_entries as i64);
        reg.gauge("leak_control_entries").set(self.control_entries as i64);
        reg.gauge("leak_resume_entries").set(self.resume_entries as i64);
        reg.gauge("leak_placed_sessions").set(self.placed_sessions as i64);
        reg.gauge("leak_inflight_frames").set(self.inflight_frames as i64);
        reg.gauge("leak_pending_frames").set(self.pending_frames as i64);
        reg.gauge("leak_prefix_attachments").set(self.prefix_attachments as i64);
        reg.gauge("leak_prefix_over_budget_bytes").set(self.prefix_over_budget_bytes as i64);
    }
}

/// Accumulating drift auditor. Feed it spot-check comparisons and
/// reconciliation passes during the run; `clean()` is the pass bit.
#[derive(Debug, Default)]
pub struct DriftAudit {
    pub stream_checks: u64,
    pub reconcile_checks: u64,
    pub violations: u64,
    /// First few violation descriptions (bounded; this is evidence, not
    /// a log).
    pub details: Vec<String>,
}

impl DriftAudit {
    pub fn new() -> DriftAudit {
        DriftAudit::default()
    }

    fn violation(&mut self, detail: String) {
        self.violations += 1;
        if self.details.len() < 16 {
            self.details.push(detail);
        }
    }

    /// Bit-identity spot check: a live stream against its fault-free
    /// replay. Any mismatch — position, value, or length — is drift.
    pub fn check_stream(&mut self, request_id: u64, got: &[u32], want: &[u32]) {
        self.stream_checks += 1;
        if got != want {
            let shared = got.len().min(want.len());
            let pos = got.iter().zip(want).position(|(g, w)| g != w).unwrap_or(shared);
            self.violation(format!(
                "req {request_id}: stream drift at position {pos} (got {} tokens, want {})",
                got.len(),
                want.len()
            ));
        }
    }

    /// Reconcile the registry's mirrored pool gauges/counters against
    /// the live getters, and every worker's headroom accounting against
    /// its Eq. 8c budget. Call after `pool.publish_metrics()`.
    pub fn reconcile(&mut self, reg: &Registry, pool: &CloudPool) {
        self.reconcile_checks += 1;
        let pairs: [(&str, u64); 6] = [
            ("pool_live_sessions", pool.live_sessions() as u64),
            ("pool_fence_entries", pool.fence_entries() as u64),
            ("pool_placed_sessions", pool.placed_sessions() as u64),
            ("pool_inflight_frames", pool.inflight_frames() as u64),
            ("pool_prefix_charged_bytes", pool.prefix_charged_bytes()),
            ("pool_prefix_attachments", pool.prefix_attachments() as u64),
        ];
        for (name, want) in pairs {
            let got = reg.gauge(name).get();
            if got != want as i64 {
                self.violation(format!("gauge {name}={got} disagrees with live getter {want}"));
            }
        }
        let counters: [(&str, u64); 4] = [
            ("pool_placed", pool.stats.placed),
            ("pool_kills", pool.stats.kills),
            ("pool_failovers", pool.stats.failovers),
            ("pool_migrations", pool.stats.migrations),
        ];
        for (name, want) in counters {
            let got = reg.counter(name).get();
            if got != want {
                self.violation(format!("counter {name}={got} disagrees with PoolStats {want}"));
            }
        }
        // Headroom accounting: charged KV on a worker never exceeds its
        // budget (the admission gate's whole promise).
        for i in 0..pool.worker_count() {
            let w = pool.worker(i);
            if let Some(budget) = w.config().kv_budget_bytes {
                let charged = w.live_sessions() as u64 * w.session_kv_bytes();
                if charged > budget {
                    self.violation(format!(
                        "worker {i}: {charged} KV bytes charged over budget {budget}"
                    ));
                }
            }
        }
    }

    pub fn clean(&self) -> bool {
        self.violations == 0
    }
}
