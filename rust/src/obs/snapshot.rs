//! Snapshot algebra + exposition (JSON and Prometheus text).
//!
//! A [`Snapshot`] is plain data — counters, gauges, sparse histogram
//! buckets — closed under two operations:
//!
//! * `a.diff(b)`: element-wise wrapping subtraction ("what happened
//!   between b and a"), and
//! * `d.merge(b)`: element-wise wrapping addition.
//!
//! Entries that land on zero are dropped, so snapshots are canonical and
//! `a.diff(b).merge(b) == a` holds exactly (pinned in tests below). The
//! audits lean on this: a leak audit is "the diff of the post-retire
//! snapshot against baseline has no outstanding gauge entries", and a
//! soak phase report is just a diff.
//!
//! Exposition is intentionally boring: `to_json` uses the same JSON
//! dialect `util::json` parses back, and `to_prometheus` emits the text
//! format with names sanitized to `[a-zA-Z0-9_:]` and label values
//! escaped per the spec (`\\`, `\"`, `\n`).

use std::collections::BTreeMap;

use super::registry::{bucket_lower, bucket_upper};

/// Plain-data capture of one histogram: total count, sum of recorded
/// values, and sparse non-zero `(bucket index, count)` pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: Vec<(u32, u64)>,
    /// Optional `key="value"` label carried into exposition.
    pub label: Option<(String, String)>,
}

impl HistSnapshot {
    /// Quantile estimate: walk the cumulative sparse buckets to the
    /// target rank and return the bucket midpoint (exact for unit
    /// buckets below 8).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for &(i, n) in &self.buckets {
            cum += n;
            if cum >= target {
                let lo = bucket_lower(i as usize);
                let hi = bucket_upper(i as usize);
                return lo + (hi - lo - 1) / 2;
            }
        }
        let last = self.buckets.last().map(|&(i, _)| i as usize).unwrap_or(0);
        bucket_lower(last)
    }

    fn wrapping_combine(&self, other: &HistSnapshot, sub: bool) -> HistSnapshot {
        let mut buckets: BTreeMap<u32, u64> = self.buckets.iter().copied().collect();
        for &(i, n) in &other.buckets {
            let e = buckets.entry(i).or_insert(0);
            *e = if sub { e.wrapping_sub(n) } else { e.wrapping_add(n) };
        }
        let buckets: Vec<(u32, u64)> = buckets.into_iter().filter(|&(_, n)| n != 0).collect();
        HistSnapshot {
            count: if sub {
                self.count.wrapping_sub(other.count)
            } else {
                self.count.wrapping_add(other.count)
            },
            sum: if sub {
                self.sum.wrapping_sub(other.sum)
            } else {
                self.sum.wrapping_add(other.sum)
            },
            buckets,
            label: self.label.clone().or_else(|| other.label.clone()),
        }
    }

    fn is_zero(&self) -> bool {
        self.count == 0 && self.sum == 0 && self.buckets.is_empty()
    }
}

/// Point-in-time capture of a whole registry. See the module docs for
/// the diff/merge algebra.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    pub fn hist(&self, key: &str) -> Option<&HistSnapshot> {
        self.hists.get(key)
    }

    /// `self - earlier`, element-wise wrapping, zero entries dropped.
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        self.combine(earlier, true)
    }

    /// `self + other`, element-wise wrapping, zero entries dropped.
    pub fn merge(&self, other: &Snapshot) -> Snapshot {
        self.combine(other, false)
    }

    fn combine(&self, other: &Snapshot, sub: bool) -> Snapshot {
        let mut out = Snapshot::default();
        let keys = |a: &BTreeMap<String, u64>, b: &BTreeMap<String, u64>| -> Vec<String> {
            a.keys().chain(b.keys()).cloned().collect()
        };
        for k in keys(&self.counters, &other.counters) {
            let a = self.counter(&k);
            let b = other.counter(&k);
            let v = if sub { a.wrapping_sub(b) } else { a.wrapping_add(b) };
            if v != 0 {
                out.counters.insert(k, v);
            }
        }
        let gkeys: Vec<String> = self.gauges.keys().chain(other.gauges.keys()).cloned().collect();
        for k in gkeys {
            let a = self.gauge(&k);
            let b = other.gauge(&k);
            let v = if sub { a.wrapping_sub(b) } else { a.wrapping_add(b) };
            if v != 0 {
                out.gauges.insert(k, v);
            }
        }
        let empty = HistSnapshot::default();
        let hkeys: Vec<String> = self.hists.keys().chain(other.hists.keys()).cloned().collect();
        for k in hkeys {
            if out.hists.contains_key(&k) {
                continue;
            }
            let a = self.hists.get(&k).unwrap_or(&empty);
            let b = other.hists.get(&k).unwrap_or(&empty);
            let h = a.wrapping_combine(b, sub);
            if !h.is_zero() {
                out.hists.insert(k, h);
            }
        }
        out
    }

    /// JSON exposition (round-trips through `util::json::Json::parse`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"counters\": {");
        push_map(&mut s, self.counters.iter().map(|(k, v)| (k.as_str(), v.to_string())));
        s.push_str("},\n  \"gauges\": {");
        push_map(&mut s, self.gauges.iter().map(|(k, v)| (k.as_str(), v.to_string())));
        s.push_str("},\n  \"histograms\": {");
        let hists: Vec<(&str, String)> = self
            .hists
            .iter()
            .map(|(k, h)| {
                let buckets: Vec<String> =
                    h.buckets.iter().map(|&(i, n)| format!("[{i},{n}]")).collect();
                let body = format!(
                    "{{\"count\": {}, \"sum\": {}, \"p50\": {}, \"p95\": {}, \"p99\": {}, \
                     \"buckets\": [{}]}}",
                    h.count,
                    h.sum,
                    h.quantile(0.50),
                    h.quantile(0.95),
                    h.quantile(0.99),
                    buckets.join(",")
                );
                (k.as_str(), body)
            })
            .collect();
        push_map(&mut s, hists.iter().map(|(k, v)| (*k, v.clone())));
        s.push_str("}\n}\n");
        s
    }

    /// Prometheus text exposition. Histogram `le` bounds are the
    /// exclusive log-linear bucket uppers rendered as inclusive edges —
    /// within the documented 12.5% bucket resolution.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            let name = sanitize_metric_name(k);
            s.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (k, v) in &self.gauges {
            let name = sanitize_metric_name(k);
            s.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for (k, h) in &self.hists {
            // The map key may be the rendered `name{key="value"}` form;
            // recover the bare name, then re-emit the label escaped.
            let bare = k.split('{').next().unwrap_or(k);
            let name = sanitize_metric_name(bare);
            let label = h
                .label
                .as_ref()
                .map(|(lk, lv)| {
                    format!("{}=\"{}\",", sanitize_metric_name(lk), escape_label_value(lv))
                })
                .unwrap_or_default();
            let bare_label = match label.trim_end_matches(',') {
                "" => String::new(),
                l => format!("{{{l}}}"),
            };
            s.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for &(i, n) in &h.buckets {
                cum += n;
                let le = bucket_upper(i as usize);
                s.push_str(&format!("{name}_bucket{{{label}le=\"{le}\"}} {cum}\n"));
            }
            s.push_str(&format!("{name}_bucket{{{label}le=\"+Inf\"}} {}\n", h.count));
            s.push_str(&format!("{name}_sum{bare_label} {}\n", h.sum));
            s.push_str(&format!("{name}_count{bare_label} {}\n", h.count));
        }
        s
    }
}

fn push_map<'a>(s: &mut String, entries: impl Iterator<Item = (&'a str, String)>) {
    let mut first = true;
    for (k, v) in entries {
        if !first {
            s.push(',');
        }
        first = false;
        s.push_str(&format!("\n    \"{}\": {v}", escape_json(k)));
    }
    if !first {
        s.push_str("\n  ");
    }
}

/// Escape a string for embedding in a JSON string literal.
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Prometheus metric names admit `[a-zA-Z0-9_:]`; anything else becomes
/// `_`, and a leading digit gets a `_` prefix.
pub fn sanitize_metric_name(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for (i, c) in s.chars().enumerate() {
        let ok = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        if i == 0 && c.is_ascii_digit() {
            out.push('_');
        }
        out.push(if ok { c } else { '_' });
    }
    out
}

/// Prometheus label-value escaping: backslash, double-quote, newline.
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::registry::Registry;
    use super::*;

    fn sample() -> (Registry, Snapshot, Snapshot) {
        let reg = Registry::new();
        reg.counter("a").add(5);
        reg.gauge("g").set(3);
        reg.histogram("h").record(100);
        let early = reg.snapshot();
        reg.counter("a").add(2);
        reg.counter("b").inc();
        reg.gauge("g").set(-1);
        reg.histogram("h").record(100);
        reg.histogram("h").record(9000);
        let late = reg.snapshot();
        (reg, early, late)
    }

    #[test]
    fn diff_merge_round_trips() {
        let (_reg, early, late) = sample();
        assert_eq!(late.diff(&early).merge(&early), late);
        assert_eq!(early.diff(&late).merge(&late), early);
        // Self-diff is the empty (canonical) snapshot.
        assert_eq!(late.diff(&late), Snapshot::default());
        // The delta itself reads correctly.
        let d = late.diff(&early);
        assert_eq!(d.counter("a"), 2);
        assert_eq!(d.counter("b"), 1);
        assert_eq!(d.gauge("g"), -4);
        assert_eq!(d.hist("h").unwrap().count, 2);
    }

    #[test]
    fn merge_is_commutative_on_disjoint_and_shared_keys() {
        let (_reg, early, late) = sample();
        let d = late.diff(&early);
        assert_eq!(d.merge(&early), early.merge(&d));
    }

    #[test]
    fn json_round_trips_through_the_in_tree_parser() {
        let (_reg, _early, late) = sample();
        let doc = crate::util::json::Json::parse(&late.to_json()).expect("valid json");
        assert_eq!(doc.get("counters").unwrap().get("a").unwrap().as_usize(), Some(7));
        let h = doc.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(3));
        assert!(h.get("p50").unwrap().as_f64().is_some());
    }

    #[test]
    fn prometheus_text_escapes_and_sanitizes() {
        let reg = Registry::new();
        reg.counter("weird-name.count").inc();
        reg.histogram_labeled("lat_us", "region", "eu\"west\\x\n1").record(7);
        let text = reg.snapshot().to_prometheus();
        assert!(text.contains("# TYPE weird_name_count counter"), "{text}");
        assert!(text.contains("weird_name_count 1"), "{text}");
        // Label value: quote, backslash, newline all escaped.
        assert!(text.contains(r#"region="eu\"west\\x\n1""#), "{text}");
        assert!(text.contains("lat_us_bucket{"), "{text}");
        assert!(text.contains("le=\"+Inf\"} 1"), "{text}");
        assert_eq!(escape_label_value("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
        assert_eq!(sanitize_metric_name("9lives-of.cats"), "_9lives_of_cats");
    }

    #[test]
    fn quantiles_from_sparse_snapshots_match_the_histogram() {
        let reg = Registry::new();
        let h = reg.histogram("h");
        for v in 1..=1000u64 {
            h.record(v);
        }
        let hs = reg.snapshot().hists.get("h").unwrap().clone();
        for &q in &[0.5, 0.95, 0.99] {
            assert_eq!(hs.quantile(q), h.quantile(q));
        }
    }
}
