//! Bounded structured event ring.
//!
//! Control-plane transitions (admission, reconfig, migrate, failover,
//! prefix attach/release, region hops, ...) are recorded as fixed-size
//! `Copy` events into a ring of fixed capacity: pushing never allocates
//! after construction, and when the ring is full the oldest event is
//! overwritten (the `dropped` counter keeps the loss honest). The ring
//! is a flight recorder, not a ledger — the audits read the *registry*,
//! the ring explains what the registry's numbers came from.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// What happened. Variants map 1:1 onto the control-plane transitions
/// of the pool/fleet/prefix layers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A session was placed on a worker (`a` = worker).
    Admission,
    /// Placement found no headroom anywhere (typed reject to the edge).
    AdmissionReject,
    /// A plan reconfig was applied to a live session.
    Reconfig,
    /// An epoch-fenced resume was admitted.
    Resume,
    /// Live migration src→dst (`a` = source worker, `b` = target).
    Migrate,
    /// Migration refused or rolled back (`a` = source, `b` = target).
    MigrateReject,
    /// A killed worker's session was re-placed (`a` = new worker).
    Failover,
    /// A worker was killed (`a` = worker).
    Kill,
    /// A worker slot was respawned (`a` = worker).
    Respawn,
    /// A worker entered drain (`a` = worker, `b` = sessions moved).
    Drain,
    /// A worker left drain (`a` = worker).
    Undrain,
    /// Auto-rebalance moved one session (`a` = hot worker, `b` = cold).
    Rebalance,
    /// A prefix digest gained an attachment (`a` = worker).
    PrefixAttach,
    /// A prefix attachment was released (`a` = worker).
    PrefixRelease,
    /// A migration crossed a region boundary (`a` = src worker,
    /// `b` = dst worker).
    RegionHop,
    /// An edge connection was closed and swept.
    EdgeClosed,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admission => "admission",
            EventKind::AdmissionReject => "admission_reject",
            EventKind::Reconfig => "reconfig",
            EventKind::Resume => "resume",
            EventKind::Migrate => "migrate",
            EventKind::MigrateReject => "migrate_reject",
            EventKind::Failover => "failover",
            EventKind::Kill => "kill",
            EventKind::Respawn => "respawn",
            EventKind::Drain => "drain",
            EventKind::Undrain => "undrain",
            EventKind::Rebalance => "rebalance",
            EventKind::PrefixAttach => "prefix_attach",
            EventKind::PrefixRelease => "prefix_release",
            EventKind::RegionHop => "region_hop",
            EventKind::EdgeClosed => "edge_closed",
        }
    }
}

/// One recorded transition. `a`/`b` are kind-specific operands (worker
/// indices, counts) documented on [`EventKind`]; `at_ms` is the
/// registry's virtual clock at push time (0 outside the soak driver).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    pub seq: u64,
    pub at_ms: u64,
    pub kind: EventKind,
    pub request_id: u64,
    pub a: u64,
    pub b: u64,
}

/// Fixed-capacity overwrite-oldest ring. The mutex guards a pre-sized
/// `VecDeque` of `Copy` events — a push is a lock, a bounds check, and
/// a struct copy; no allocation once warm.
#[derive(Debug)]
pub struct EventRing {
    cap: usize,
    inner: Mutex<VecDeque<Event>>,
    seq: AtomicU64,
    dropped: AtomicU64,
}

impl EventRing {
    pub fn new(cap: usize) -> EventRing {
        let cap = cap.max(1);
        EventRing {
            cap,
            inner: Mutex::new(VecDeque::with_capacity(cap)),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn push(&self, kind: EventKind, at_ms: u64, request_id: u64, a: u64, b: u64) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.inner.lock().unwrap();
        if ring.len() == self.cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(Event { seq, at_ms, kind, request_id, a, b });
    }

    /// Oldest-first copy of the retained window.
    pub fn recent(&self) -> Vec<Event> {
        self.inner.lock().unwrap().iter().copied().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total events ever pushed (retained + dropped).
    pub fn total(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// JSON-lines rendering of the retained window (one object per
    /// event), used by the `--metrics` dump.
    pub fn to_json_lines(&self) -> String {
        let mut s = String::new();
        for e in self.recent() {
            s.push_str(&format!(
                "{{\"seq\": {}, \"at_ms\": {}, \"kind\": \"{}\", \"request_id\": {}, \
                 \"a\": {}, \"b\": {}}}\n",
                e.seq,
                e.at_ms,
                e.kind.name(),
                e.request_id,
                e.a,
                e.b
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_overwrites_oldest() {
        let ring = EventRing::new(4);
        for i in 0..10u64 {
            ring.push(EventKind::Admission, i, i, 0, 0);
        }
        let events = ring.recent();
        assert_eq!(events.len(), 4);
        assert_eq!(ring.dropped(), 6);
        assert_eq!(ring.total(), 10);
        assert_eq!(events.first().unwrap().seq, 6, "oldest retained must be seq 6");
        assert_eq!(events.last().unwrap().seq, 9);
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn json_lines_parse_per_line() {
        let ring = EventRing::new(8);
        ring.push(EventKind::Migrate, 42, 7, 1, 2);
        for line in ring.to_json_lines().lines() {
            let v = crate::util::json::Json::parse(line).expect("each event line is json");
            assert_eq!(v.get("kind").unwrap().as_str(), Some("migrate"));
            assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        }
    }
}
