//! Per-region worker asymmetry: RTT and goodput profiles.
//!
//! A sharded cloud is rarely one rack. Workers live in regions with
//! different edge→worker round-trip times and sustained goodput, and
//! the paper's latency constraint (Eq. 5's deadline) is paid on every
//! hop — so placement scoring must weigh *where* a worker is, not just
//! how much KV headroom it has. [`RegionProfile::weight`] folds a
//! profile into a deterministic integer multiplier for the placement
//! score: a worker in a slow region needs proportionally more headroom
//! to win a placement over a near one, and among equal regions the
//! original most-headroom + seeded-tie-break behavior is unchanged.
//!
//! The soak driver also uses the profile as a *virtual-latency model*:
//! [`RegionProfile::reply_delay_s`] is the simulated extra time a reply
//! of a given size spends on the region's link, which is what produces
//! the per-region time-to-token spread `BENCH_soak.json` reports.

/// RTT/goodput profile of the link between the edge population and one
/// worker's region.
#[derive(Clone, Debug, PartialEq)]
pub struct RegionProfile {
    pub name: String,
    /// Round-trip time, seconds.
    pub rtt_s: f64,
    /// Sustained goodput, bits per second.
    pub goodput_bps: f64,
}

impl RegionProfile {
    pub fn new(name: &str, rtt_s: f64, goodput_bps: f64) -> RegionProfile {
        RegionProfile {
            name: name.to_string(),
            rtt_s: rtt_s.max(0.0),
            goodput_bps: goodput_bps.max(1.0),
        }
    }

    /// The same-rack default every worker gets unless told otherwise.
    /// Its weight is the reference point: a pool with uniform regions
    /// places exactly as the region-blind pool did.
    pub fn local() -> RegionProfile {
        RegionProfile::new("local", 0.0005, 2.5e9)
    }

    /// Named presets for the CLI (`--regions us-east,eu-west,...`).
    pub fn preset(name: &str) -> Option<RegionProfile> {
        match name {
            "local" => Some(RegionProfile::local()),
            "us-east" => Some(RegionProfile::new("us-east", 0.012, 1.25e9)),
            "us-west" => Some(RegionProfile::new("us-west", 0.035, 1.0e9)),
            "eu-west" => Some(RegionProfile::new("eu-west", 0.048, 6.0e8)),
            "ap-south" => Some(RegionProfile::new("ap-south", 0.085, 3.0e8)),
            _ => None,
        }
    }

    /// Deterministic integer placement weight in [1, 256]. Pure
    /// function of the profile (fixed f64 arithmetic, rounded once), so
    /// pool layouts stay seed-reproducible. Reference scales: 25 ms RTT
    /// halves the weight; goodput saturates above a few Mb/s so the
    /// term only punishes genuinely thin links.
    pub fn weight(&self) -> u64 {
        let f_rtt = 0.025 / (0.025 + self.rtt_s);
        let f_bw = self.goodput_bps / (self.goodput_bps + 2.0e6);
        ((256.0 * f_rtt * f_bw).round() as u64).max(1)
    }

    /// Simulated one-way reply delay for `bytes` on this region's link:
    /// RTT plus serialization at goodput. Used by the soak driver's
    /// virtual clock — never by real transports.
    pub fn reply_delay_s(&self, bytes: u64) -> f64 {
        self.rtt_s + (bytes as f64 * 8.0) / self.goodput_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_order_by_distance() {
        let w = |n: &str| RegionProfile::preset(n).unwrap().weight();
        assert!(w("local") > w("us-east"), "{} vs {}", w("local"), w("us-east"));
        assert!(w("us-east") > w("us-west"));
        assert!(w("us-west") > w("eu-west"));
        assert!(w("eu-west") > w("ap-south"));
        assert!(w("ap-south") >= 1);
        assert!(w("local") <= 256);
    }

    #[test]
    fn weight_is_deterministic() {
        let a = RegionProfile::new("x", 0.033, 7.5e8);
        let b = RegionProfile::new("x", 0.033, 7.5e8);
        assert_eq!(a.weight(), b.weight());
    }

    #[test]
    fn reply_delay_scales_with_bytes_and_rtt() {
        let near = RegionProfile::preset("us-east").unwrap();
        let far = RegionProfile::preset("ap-south").unwrap();
        assert!(far.reply_delay_s(4096) > near.reply_delay_s(4096));
        assert!(near.reply_delay_s(1 << 20) > near.reply_delay_s(1 << 10));
    }
}
