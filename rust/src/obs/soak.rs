//! Long-horizon soak harness: hours of simulated churn over a
//! multi-region pool, passing only if the leak AND drift audits are
//! clean.
//!
//! The driver runs on **virtual time**: a tick advances the registry
//! clock by `tick_ms` simulated milliseconds, admits the diurnal
//! trace's arrivals that came due, runs the maintenance cadences
//! (rolling worker restarts, drain/undrain, armed chaos faults), and
//! pumps every live session one payload/reply step. Idle troughs are
//! jumped over, so a 2-simulated-hour scenario finishes in bounded
//! wall time regardless of how quiet the night side of the diurnal
//! curve is.
//!
//! Per-region latency asymmetry: each worker's [`RegionProfile`] both
//! biases placement (`headroom × weight`) and contributes a simulated
//! reply delay (`rtt + bytes/goodput`) to that token's recorded
//! latency, so `soak_token_latency_ms{region=...}` histograms show the
//! spread a real multi-region deployment would.
//!
//! Pass criteria (checked by [`SoakOutcome::passed`]):
//!
//! * **Leak audit** — after every session retires (EOS, typed reject,
//!   kill-recover, drain, migration), the pool holds zero admission
//!   charges, fences, placements, replay buffers, queued frames and
//!   prefix refcounts, and no store is charged beyond its budget.
//! * **Drift audit** — spot-checked completed streams are bit-identical
//!   to their fault-free solo replays, registry mirrors reconcile with
//!   the live ledgers, and no worker's KV charge ever exceeds its
//!   Eq. 8c budget.

use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::channel::TransferOutcome;
use crate::coordinator::{
    build_pipeline, DeploymentSpec, EdgeDevice, PrefixDecision, PrefixProbe, Request, Session,
    SessionAction,
};
use crate::fleet::{FleetConfig, FleetScheduler};
use crate::obs::{DriftAudit, Histogram, LeakReport, RegionProfile, Registry};
use crate::pool::{CloudPool, PoolConfig};
use crate::prefix::CHUNK_TOKENS;
use crate::runtime::Engine;
use crate::trace::{generate_trace, ArrivalPattern, WorkloadSpec};
use crate::util::rng::Rng;
use crate::wire::{EdgePort, FaultPlan, Loopback, WireTransport};

/// Knobs of one soak scenario. Every field is simulated time or a
/// seed — the run is deterministic end to end.
#[derive(Clone, Debug)]
pub struct SoakConfig {
    /// Simulated horizon in seconds (arrivals beyond it are dropped).
    pub horizon_s: f64,
    /// Simulated milliseconds per driver tick.
    pub tick_ms: u64,
    pub workers: usize,
    /// Region profiles, cycled over the worker slots.
    pub regions: Vec<RegionProfile>,
    pub seed: u64,
    /// Diurnal arrival curve (requests/s at peak and trough, period).
    pub period_s: f64,
    pub peak_rate: f64,
    pub trough_rate: f64,
    /// Hard cap on trace length (memory bound).
    pub max_sessions: usize,
    pub max_new: usize,
    /// Fraction of prompts rewritten to share one hot 16-token prefix.
    pub prefix_share: f64,
    /// Per-worker Eq. 8c budget, in whole sessions (None = gate off —
    /// but then the heaviest region wins every placement, so keep it
    /// finite when regions differ).
    pub sessions_per_worker: Option<u64>,
    /// Rolling worker-restart cadence, simulated seconds (0 = off).
    pub restart_every_s: f64,
    /// Drain + undrain cadence, simulated seconds (0 = off).
    pub drain_every_s: f64,
    /// Chaos cadence: alternates an armed seeded worker kill and a
    /// one-shot migrate-frame bit flip (0 = off).
    pub chaos_every_s: f64,
    /// Bit-identity spot check every Nth completed session...
    pub drift_check_every: u64,
    /// ...up to this many solo replays (compute bound).
    pub max_drift_replays: u64,
    /// Registry-vs-ledger reconciliation cadence, simulated seconds.
    pub reconcile_every_s: f64,
}

impl Default for SoakConfig {
    fn default() -> SoakConfig {
        SoakConfig {
            horizon_s: 7200.0,
            tick_ms: 100,
            workers: 4,
            regions: vec![
                RegionProfile::local(),
                RegionProfile::preset("us-east").expect("preset"),
                RegionProfile::preset("eu-west").expect("preset"),
                RegionProfile::preset("ap-south").expect("preset"),
            ],
            seed: 0x50AC,
            period_s: 3600.0,
            peak_rate: 1.0,
            trough_rate: 0.15,
            max_sessions: 4000,
            max_new: 6,
            prefix_share: 0.35,
            sessions_per_worker: Some(8),
            restart_every_s: 600.0,
            drain_every_s: 870.0,
            chaos_every_s: 1130.0,
            drift_check_every: 7,
            max_drift_replays: 32,
            reconcile_every_s: 30.0,
        }
    }
}

impl SoakConfig {
    /// Scale the horizon (CI smoke runs ~10 simulated minutes).
    pub fn with_horizon_minutes(mut self, minutes: f64) -> SoakConfig {
        self.horizon_s = (minutes * 60.0).max(60.0);
        self
    }
}

/// What the run did, and whether it passed.
#[derive(Clone, Debug)]
pub struct SoakOutcome {
    pub sim_s: f64,
    pub wall_s: f64,
    pub sessions: u64,
    pub completed: u64,
    /// Sessions that ended in a TYPED rejection (admission pressure,
    /// failover without capacity, chaos) — expected under load, never a
    /// pass/fail criterion by itself.
    pub failed_typed: u64,
    pub tokens: u64,
    pub kills: u64,
    pub drains: u64,
    pub migrations: u64,
    pub leak: LeakReport,
    pub drift_stream_checks: u64,
    pub drift_reconcile_checks: u64,
    pub drift_violations: u64,
    pub drift_details: Vec<String>,
    /// Per-region p95 time-to-token, simulated ms (regions that served
    /// no tokens are omitted).
    pub region_p95_ms: Vec<(String, u64)>,
    pub events_total: u64,
}

impl SoakOutcome {
    /// The soak pass bit: both audits clean.
    pub fn passed(&self) -> bool {
        self.leak.clean() && self.drift_violations == 0
    }
}

struct Tenant {
    req: Request,
    session: Session,
    port: EdgePort,
    edge_id: u64,
    up: Option<TransferOutcome>,
    sent_at_ms: u64,
    /// Last observed owning worker (refreshed every tick; replies are
    /// attributed to the region that actually served them).
    worker: usize,
}

enum Admit {
    Tenant(Box<Tenant>),
    Rejected,
}

/// Open an edge connection for one request and run the prefix probe
/// handshake when the edge cache claims a warm hit. A typed rejection
/// at the probe (no headroom anywhere) rejects the session.
fn admit(
    pool: &mut CloudPool,
    edge: &EdgeDevice,
    spec: &DeploymentSpec,
    req: &Request,
) -> Result<Admit> {
    let (edge_half, pool_half) = Loopback::pair();
    let edge_id = pool.add_edge(WireTransport::Loopback(pool_half));
    let mut port = EdgePort::new(WireTransport::Loopback(edge_half));
    let mut session = Session::for_edge(req.clone(), edge, spec.edge_controller());
    let mut decision = edge.prefix_decision(&req.prompt);
    if let PrefixDecision::Warm { digest, prefix_len } = decision {
        let probe =
            PrefixProbe { request_id: req.id, digest, prefix_len: prefix_len as u32 };
        port.send_prefix_probe(&probe)?;
        pool.poll()?;
        match port.recv_prefix_ack() {
            Ok((ack, _)) if ack.hit && ack.digest == digest => {}
            Ok(_) => decision = PrefixDecision::Insert { digest, prefix_len },
            Err(_) => {
                // Typed in-band rejection: the pool had no headroom.
                pool.close_edge(edge_id);
                return Ok(Admit::Rejected);
            }
        }
    }
    session.set_prefix_decision(decision);
    Ok(Admit::Tenant(Box::new(Tenant {
        req: req.clone(),
        session,
        port,
        edge_id,
        up: None,
        sent_at_ms: 0,
        worker: 0,
    })))
}

/// Run one soak scenario to completion. All metrics, events, and audit
/// gauges land on `reg` (which the pool shares); the returned outcome
/// summarizes them.
pub fn run(
    eng: Rc<Engine>,
    spec: &DeploymentSpec,
    cfg: &SoakConfig,
    reg: Arc<Registry>,
) -> Result<SoakOutcome> {
    anyhow::ensure!(cfg.workers >= 1, "soak needs at least one worker");
    anyhow::ensure!(!cfg.regions.is_empty(), "soak needs at least one region profile");
    let wall0 = Instant::now();

    // Per-worker Eq. 8c budget, converted from sessions to bytes using
    // a throwaway scheduler's per-session KV figure.
    let kv_budget_bytes = match cfg.sessions_per_worker {
        Some(n) => {
            let probe =
                FleetScheduler::new(spec.build_cloud_server(eng.clone())?, FleetConfig::default());
            Some(n.max(1) * probe.session_kv_bytes().max(1))
        }
        None => None,
    };

    let fspec = spec.clone();
    let feng = eng.clone();
    let mut pool = CloudPool::new(
        move || fspec.build_cloud_server(feng.clone()),
        PoolConfig {
            workers: cfg.workers,
            fleet: FleetConfig { kv_budget_bytes, ..FleetConfig::default() },
            seed: cfg.seed,
            auto_rebalance: true,
            ..PoolConfig::default()
        },
    )?;
    pool.attach_obs(reg.clone());
    for w in 0..cfg.workers {
        pool.set_worker_region(w, cfg.regions[w % cfg.regions.len()].clone());
    }
    let regions: Vec<RegionProfile> =
        (0..cfg.workers).map(|w| pool.worker_region(w).clone()).collect();
    let region_hist: Vec<Arc<Histogram>> = regions
        .iter()
        .map(|r| reg.histogram_labeled("soak_token_latency_ms", "region", &r.name))
        .collect();

    // Diurnal trace, truncated to the horizon; a seeded fraction of
    // prompts is rewritten to share one hot chunk-aligned prefix.
    let mut reqs = generate_trace(&WorkloadSpec {
        n_requests: cfg.max_sessions,
        arrival_rate: cfg.peak_rate.max(0.001),
        arrival: ArrivalPattern::Diurnal {
            period_s: cfg.period_s,
            peak_rate: cfg.peak_rate,
            trough_rate: cfg.trough_rate.min(cfg.peak_rate),
        },
        prompt_len_min: 4,
        prompt_len_max: 24,
        output_len_min: 2,
        output_len_max: cfg.max_new.max(2),
        vocab: spec.model.vocab.clamp(32, 512),
        seed: cfg.seed,
    });
    reqs.retain(|r| r.arrival_s < cfg.horizon_s);
    let mut share_rng = Rng::new(cfg.seed ^ 0x5AAE);
    let hot: Vec<u32> = (0..CHUNK_TOKENS as u32).map(|i| 10 + i).collect();
    for r in reqs.iter_mut() {
        if share_rng.f64() < cfg.prefix_share {
            let mut p = hot.clone();
            p.extend(r.prompt.iter().copied().take(8));
            if p.len() <= CHUNK_TOKENS {
                p.push(7);
            }
            r.prompt = p;
        }
    }
    let sessions = reqs.len() as u64;

    let edge = spec.build_edge_device(eng.clone())?;
    // Fault-free solo oracle for the drift spot checks, prefix cache
    // off: warm streams must be bit-identical to COLD replays.
    let mut oracle_spec = spec.clone();
    oracle_spec.prefix_cache_bytes = 0;
    let mut oracle = build_pipeline(eng.clone(), &oracle_spec)?;

    let mut drift = DriftAudit::new();
    let mut active: Vec<Tenant> = Vec::new();
    let mut next = 0usize;
    let mut now_ms = 0u64;
    let mut completed = 0u64;
    let mut failed_typed = 0u64;
    let mut tokens = 0u64;
    let mut next_restart_s = cfg.restart_every_s;
    let mut next_drain_s = cfg.drain_every_s;
    let mut next_chaos_s = cfg.chaos_every_s;
    let mut next_reconcile_s = cfg.reconcile_every_s.max(1.0);
    let mut rr_kill = 0usize;
    let mut rr_drain = 0usize;
    let mut chaos_n = 0u64;
    let mut steps = 0u64;

    while next < reqs.len() || !active.is_empty() {
        steps += 1;
        anyhow::ensure!(steps < 100_000_000, "soak driver did not converge");
        // Jump the virtual clock across idle troughs.
        if active.is_empty() && next < reqs.len() {
            let due_ms = (reqs[next].arrival_s * 1000.0) as u64;
            now_ms = now_ms.max(due_ms);
        }
        now_ms += cfg.tick_ms.max(1);
        reg.set_time_ms(now_ms);
        let now_s = now_ms as f64 / 1000.0;

        // Admissions due this tick.
        while next < reqs.len() && reqs[next].arrival_s * 1000.0 <= now_ms as f64 {
            let req = reqs[next].clone();
            next += 1;
            match admit(&mut pool, &edge, spec, &req)? {
                Admit::Tenant(t) => active.push(*t),
                Admit::Rejected => {
                    failed_typed += 1;
                    reg.counter("soak_sessions_rejected").inc();
                }
            }
        }

        // Maintenance cadences, on simulated time.
        if cfg.restart_every_s > 0.0 && now_s >= next_restart_s {
            next_restart_s += cfg.restart_every_s;
            pool.kill_worker(rr_kill % cfg.workers)?;
            rr_kill += 1;
        }
        if cfg.chaos_every_s > 0.0 && now_s >= next_chaos_s {
            next_chaos_s += cfg.chaos_every_s;
            chaos_n += 1;
            if chaos_n % 2 == 1 {
                let w = (rr_kill + 1) % cfg.workers;
                pool.arm_worker_fault(w, FaultPlan::disconnect(cfg.seed ^ chaos_n, 2));
            } else {
                pool.arm_migrate_fault(chaos_n as usize * 13 + 5);
            }
        }
        if cfg.drain_every_s > 0.0 && cfg.workers > 1 && now_s >= next_drain_s {
            next_drain_s += cfg.drain_every_s;
            let w = rr_drain % cfg.workers;
            rr_drain += 1;
            pool.drain_worker(w)?;
            pool.undrain_worker(w);
        }

        // One payload per idle session, one pool step, then absorb
        // whatever replied.
        for t in active.iter_mut() {
            if t.session.is_terminal() || t.up.is_some() {
                continue;
            }
            if let SessionAction::Transmit(p) = t.session.poll(&edge)? {
                t.up = Some(t.port.send_payload(&p)?);
                t.sent_at_ms = now_ms;
            }
        }
        pool.poll()?;
        for t in active.iter_mut() {
            if let Some(p) = pool.placement_of(t.req.id) {
                t.worker = p.worker;
            }
        }

        let mut i = 0usize;
        while i < active.len() {
            // None = still running; Some(failed) = session over.
            let done: Option<bool> = {
                let t = &mut active[i];
                if t.session.is_terminal() {
                    Some(false)
                } else {
                    match t.port.try_recv_reply() {
                        Ok(Some((reply, cloud_s, down))) => {
                            let up = t.up.take().expect("reply without an in-flight payload");
                            let wire_bytes = up.payload_bytes + down.payload_bytes;
                            match t.session.on_reply(&edge, &reply, cloud_s, up, down) {
                                Ok(()) => {
                                    let delay = regions[t.worker].reply_delay_s(wire_bytes);
                                    let ms = now_ms.saturating_sub(t.sent_at_ms)
                                        + (delay * 1000.0) as u64;
                                    region_hist[t.worker].record(ms.max(1));
                                    t.session.is_terminal().then_some(false)
                                }
                                Err(_) => Some(true),
                            }
                        }
                        Ok(None) => None,
                        // Typed in-band rejection (admission pressure,
                        // failover without capacity, chaos fallout).
                        Err(_) => Some(true),
                    }
                }
            };
            match done {
                None => i += 1,
                Some(failed) => {
                    let t = active.swap_remove(i);
                    pool.close_edge(t.edge_id);
                    if failed {
                        failed_typed += 1;
                        reg.counter("soak_sessions_failed").inc();
                    } else {
                        completed += 1;
                        let n = t.session.tokens().len() as u64;
                        tokens += n;
                        reg.counter("soak_sessions_completed").inc();
                        reg.counter("soak_tokens_total").add(n);
                        if completed % cfg.drift_check_every.max(1) == 0
                            && drift.stream_checks < cfg.max_drift_replays
                        {
                            let want = oracle.generate(&t.req)?;
                            drift.check_stream(t.req.id, t.session.tokens(), &want.tokens);
                        }
                    }
                }
            }
        }

        if now_s >= next_reconcile_s {
            next_reconcile_s += cfg.reconcile_every_s.max(1.0);
            pool.publish_metrics();
            drift.reconcile(&reg, &pool);
        }
    }

    // Settle: flush any straggler frames, then run both audits.
    for _ in 0..8 {
        pool.poll()?;
    }
    pool.publish_metrics();
    drift.reconcile(&reg, &pool);
    let leak = LeakReport::audit(&pool);
    leak.publish(&reg);
    reg.gauge("soak_sim_ms").set(now_ms as i64);

    let mut region_p95_ms: Vec<(String, u64)> = Vec::new();
    for (w, r) in regions.iter().enumerate() {
        if region_p95_ms.iter().any(|(n, _)| n == &r.name) {
            continue;
        }
        if region_hist[w].count() > 0 {
            region_p95_ms.push((r.name.clone(), region_hist[w].quantile(0.95)));
        }
    }

    Ok(SoakOutcome {
        sim_s: now_ms as f64 / 1000.0,
        wall_s: wall0.elapsed().as_secs_f64(),
        sessions,
        completed,
        failed_typed,
        tokens,
        kills: pool.stats.kills,
        drains: pool.stats.drains,
        migrations: pool.stats.migrations,
        leak,
        drift_stream_checks: drift.stream_checks,
        drift_reconcile_checks: drift.reconcile_checks,
        drift_violations: drift.violations,
        drift_details: drift.details.clone(),
        region_p95_ms,
        events_total: reg.events_total(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let cfg = SoakConfig::default();
        assert!(cfg.horizon_s >= 7200.0, "the default scenario is the 2-simulated-hour soak");
        assert!(cfg.trough_rate <= cfg.peak_rate);
        assert_eq!(cfg.regions.len(), 4);
        let short = cfg.with_horizon_minutes(10.0);
        assert_eq!(short.horizon_s, 600.0);
    }
}
