//! Observability: metrics registry, structured events, audits, soak.
//!
//! Nine PRs in, the repo had grown one ad-hoc counter struct per layer —
//! `CloudServer` atomics, `ServeReport` fields, `FleetStats`,
//! `PoolStats`, two prefix-cache stat blocks — each with its own
//! getters, none comparable to the others, none exposable without
//! bespoke glue. This module is the one schema they all report through:
//!
//! * [`registry`] — counters, gauges, and log-linear histograms behind
//!   a [`Registry`]. Registration allocates once; the record path is a
//!   single relaxed atomic RMW (no locks, no strings, no allocator),
//!   and the final state is interleaving-independent, so concurrent
//!   runs snapshot bit-identically to a serial replay.
//! * [`snapshot`] — plain-data [`Snapshot`]s forming a group under
//!   `diff`/`merge` (`a.diff(b).merge(b) == a`), with JSON and
//!   Prometheus text exposition (`--metrics PATH` writes both).
//! * [`events`] — a bounded overwrite-oldest [`EventRing`] of `Copy`
//!   control-plane transitions (admission, reconfig, migrate, failover,
//!   prefix attach/release, region hops): a flight recorder explaining
//!   where the registry's numbers came from.
//! * [`region`] — [`RegionProfile`] RTT/goodput asymmetry. Placement
//!   scores `headroom × region weight`; the soak driver uses the same
//!   profile as a virtual-latency model.
//! * [`audit`] — the two soak pass criteria. [`LeakReport`]: after all
//!   sessions retire, every admission charge, fence, placement,
//!   refcount, and replay buffer must net to zero. [`DriftAudit`]:
//!   streams spot-check bit-identical against fault-free replays, and
//!   the registry's mirrors reconcile against the live ledgers.
//! * [`soak`] — the long-horizon virtual-time driver: hours of
//!   simulated diurnal churn, rolling worker restarts, drains and
//!   chaos faults over a multi-region pool, passing only if both
//!   audits come back clean.

pub mod audit;
pub mod events;
pub mod region;
pub mod registry;
pub mod snapshot;
pub mod soak;

pub use audit::{DriftAudit, LeakReport};
pub use events::{Event, EventKind, EventRing};
pub use region::RegionProfile;
pub use registry::{accumulate, Counter, Gauge, Histogram, MetricSource, Registry};
pub use snapshot::{HistSnapshot, Snapshot};
pub use soak::{SoakConfig, SoakOutcome};

use anyhow::Result;

/// Write a registry snapshot to `path` (JSON: counters, gauges,
/// histograms, recent events) and its Prometheus text rendering next to
/// it at `path.prom`. This is what the `--metrics PATH` flag on every
/// CLI mode calls on exit.
pub fn write_metrics(reg: &Registry, path: &str) -> Result<()> {
    let snap = reg.snapshot();
    let mut json = snap.to_json();
    // Splice the event window in as a JSON array field (the snapshot
    // itself is pure metrics; events are the flight recorder).
    let events: Vec<String> = reg
        .events()
        .iter()
        .map(|e| {
            format!(
                "{{\"seq\": {}, \"at_ms\": {}, \"kind\": \"{}\", \"request_id\": {}, \
                 \"a\": {}, \"b\": {}}}",
                e.seq,
                e.at_ms,
                e.kind.name(),
                e.request_id,
                e.a,
                e.b
            )
        })
        .collect();
    let tail = format!(
        ", \"events_dropped\": {}, \"events\": [{}]}}",
        reg.events_dropped(),
        events.join(", ")
    );
    // snapshot JSON ends with its closing '}' (plus trailing newline) —
    // pop both and splice our tail in as extra top-level fields.
    while json.ends_with(char::is_whitespace) {
        json.pop();
    }
    json.pop();
    json.push_str(&tail);
    std::fs::write(path, &json)?;
    std::fs::write(format!("{path}.prom"), snap.to_prometheus())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_metrics_emits_parseable_json_and_prom_text() {
        let reg = Registry::new();
        reg.counter("demo_total").add(3);
        reg.gauge("demo_level").set(-2);
        reg.histogram("demo_us").record(140);
        reg.event(EventKind::Admission, 9, 1, 0);
        let dir = std::env::temp_dir().join("splitserve_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.json");
        let path = path.to_str().unwrap();
        write_metrics(&reg, path).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let v = crate::util::json::Json::parse(&text).expect("metrics json parses");
        let events = v.get("events").and_then(|e| e.as_arr().map(|a| a.len()));
        assert_eq!(events, Some(1));
        let prom = std::fs::read_to_string(format!("{path}.prom")).unwrap();
        assert!(prom.contains("demo_total 3"), "{prom}");
        assert!(prom.contains("demo_level -2"), "{prom}");
        assert!(prom.contains("demo_us_count 1"), "{prom}");
    }
}
