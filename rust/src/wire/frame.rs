//! Versioned byte frame around every edge↔cloud message.
//!
//! ```text
//! [magic   u32]  0x53504C57 ("SPLW", little-endian "WLPS" on the wire)
//! [version u8 ]  7 (wire format v7: v6 layouts + the PrefixProbe /
//!                PrefixAck prefix-cache handshake and digest-bearing
//!                payloads)
//! [kind    u8 ]  1 = SplitPayload, 2 = CloudReply, 3 = Reconfig,
//!                4 = Resume, 5 = ResumeAck, 6 = Error, 7 = Migrate,
//!                8 = PrefixProbe, 9 = PrefixAck
//! [len     u32]  body length in bytes
//! [body       ]  len bytes (see `wire::codec` for the per-kind layout)
//! [crc32   u32]  IEEE CRC-32 over version, kind, len and body
//! ```
//!
//! The frame is the unit every [`Transport`](super::Transport) moves, so
//! `FRAME_OVERHEAD` (10-byte preamble + 4-byte CRC trailer) is exactly
//! the fixed cost the link simulator charges on top of a message's
//! `wire_bytes()`. Decoding is strict: wrong magic/version/kind, a length
//! field that disagrees with the delivered bytes, or any corruption of
//! the covered region (a single bit flip anywhere past the magic) is
//! reported as a typed [`WireError`] — never a panic, never a silent
//! misdecode.

use std::fmt;

/// Frame preamble: magic + version + kind + len.
pub const HEADER_BYTES: usize = 10;
/// Fixed per-frame cost: preamble + CRC-32 trailer.
pub const FRAME_OVERHEAD: u64 = HEADER_BYTES as u64 + 4;
/// "SPLW" — splitserve wire.
pub const MAGIC: u32 = 0x53504C57;
/// Upper bound on a frame body. Real payloads are a few KB–MB (hidden
/// block + compressed KV); the cap exists so a corrupted or hostile
/// length field is rejected as a typed error BEFORE the receiver
/// allocates or blocks reading gigabytes it will only throw away at the
/// CRC check.
pub const MAX_BODY_BYTES: usize = 256 << 20;
/// Wire format v7: the v6 layouts (position-stamped replies, the
/// `Resume`/`ResumeAck` recovery handshake, in-band `Error` rejections,
/// the worker-to-worker `Migrate` frame) plus the content-addressed
/// prefix cache: `PrefixProbe`/`PrefixAck` frames and an optional
/// 36-byte prefix reference on `SplitPayload` so a session whose prompt
/// prefix is resident ships a digest instead of re-transmitting
/// compressed prefill state (see `wire::codec` and `prefix`).
pub const VERSION: u8 = 7;

/// What a frame's body contains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// An edge→cloud `SplitPayload`.
    Payload = 1,
    /// A cloud→edge `CloudReply` (prefixed by the server compute seconds).
    Reply = 2,
    /// A control-plane `adapt::Reconfig`: a session's new transmission
    /// settings, announced mid-stream. Carries no reply of its own.
    Reconfig = 3,
    /// Edge→cloud session resumption after a reconnect (or cloud
    /// restart): re-announces the session's id, epoch, next expected
    /// position and transmission settings so the stateless cloud can
    /// fence stale traffic and continue the stream bit-identically.
    Resume = 4,
    /// Cloud→edge acknowledgement of a `Resume`: echoes the session id
    /// and epoch and reports the last position this connection will
    /// fence against.
    ResumeAck = 5,
    /// Cloud→edge in-band typed rejection (stale epoch, replayed
    /// position, unknown session). The connection keeps serving — the
    /// error frame *is* the typed error, not a torn socket.
    Error = 6,
    /// Worker→worker live-migration of a session's cloud-side state:
    /// the replay fence (last answered position + its cached reply
    /// frame), the announced control-plane settings, and a strictly
    /// increasing migration epoch so duplicate or stale deliveries
    /// during the handoff are fenced off exactly like a stale `Resume`.
    Migrate = 7,
    /// Edge→cloud prefix-cache probe: "is this (digest, prefix_len)
    /// resident?". A hit pins the entry for the probing request so it
    /// cannot be evicted between the ack and the warm payload.
    PrefixProbe = 8,
    /// Cloud→edge answer to a `PrefixProbe`: echoes request id + digest
    /// and reports hit/miss. A miss tells the edge to fall back to the
    /// full insert payload.
    PrefixAck = 9,
}

impl FrameKind {
    fn from_u8(b: u8) -> Result<FrameKind, WireError> {
        match b {
            1 => Ok(FrameKind::Payload),
            2 => Ok(FrameKind::Reply),
            3 => Ok(FrameKind::Reconfig),
            4 => Ok(FrameKind::Resume),
            5 => Ok(FrameKind::ResumeAck),
            6 => Ok(FrameKind::Error),
            7 => Ok(FrameKind::Migrate),
            8 => Ok(FrameKind::PrefixProbe),
            9 => Ok(FrameKind::PrefixAck),
            other => Err(WireError::BadKind(other)),
        }
    }
}

/// Typed decode failures. Everything a hostile or truncated byte stream
/// can do to the decoder maps onto one of these variants.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// A field extends past the end of the buffer.
    Truncated { need: usize, have: usize },
    /// The 4-byte magic does not open the frame.
    BadMagic(u32),
    /// Unknown wire-format version.
    BadVersion(u8),
    /// Unknown frame kind.
    BadKind(u8),
    /// The frame arrived as a different kind than the decoder expected.
    WrongKind { want: FrameKind, got: FrameKind },
    /// The header's length field disagrees with the delivered bytes.
    Length { declared: usize, actual: usize },
    /// The header declares a body beyond [`MAX_BODY_BYTES`] — rejected
    /// before anything is allocated or read.
    TooLarge { declared: usize, max: usize },
    /// CRC-32 over version/kind/len/body failed.
    Crc { want: u32, got: u32 },
    /// Structurally invalid body (bad tag, inconsistent dims, ...).
    Malformed(String),
    /// The peer stalled past the transport's read/write deadline.
    Timeout,
    /// The peer rejected the frame in-band with a typed `Error` frame
    /// (stale epoch, replayed position, unknown session, ...).
    Rejected { code: u8, request_id: u64, message: String },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "wire: truncated (need {need} bytes, have {have})")
            }
            WireError::BadMagic(m) => write!(f, "wire: bad magic {m:#010x}"),
            WireError::BadVersion(v) => write!(f, "wire: unsupported version {v}"),
            WireError::BadKind(k) => write!(f, "wire: unknown frame kind {k}"),
            WireError::WrongKind { want, got } => {
                write!(f, "wire: expected {want:?} frame, got {got:?}")
            }
            WireError::Length { declared, actual } => {
                write!(f, "wire: frame declares {declared} body bytes but carries {actual}")
            }
            WireError::TooLarge { declared, max } => {
                write!(f, "wire: declared body of {declared} bytes exceeds the {max}-byte cap")
            }
            WireError::Crc { want, got } => {
                write!(f, "wire: crc mismatch (header {want:#010x}, computed {got:#010x})")
            }
            WireError::Malformed(m) => write!(f, "wire: malformed body: {m}"),
            WireError::Timeout => write!(f, "wire: peer stalled past the transport deadline"),
            WireError::Rejected { code, request_id, message } => {
                write!(f, "wire: peer rejected request {request_id} (code {code}): {message}")
            }
        }
    }
}

impl std::error::Error for WireError {}

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 (the zlib/Ethernet polynomial).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Wrap `body` in a v3 frame of the given kind. The sender enforces the
/// same body cap the receiver does — an oversized body fails loudly here
/// instead of encoding a frame every decoder will reject (and a body
/// past u32 would corrupt the length field).
pub fn encode_frame(kind: FrameKind, body: &[u8]) -> Vec<u8> {
    assert!(
        body.len() <= MAX_BODY_BYTES,
        "frame body of {} bytes exceeds the {MAX_BODY_BYTES}-byte cap",
        body.len()
    );
    let mut out = Vec::with_capacity(HEADER_BYTES + body.len() + 4);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(kind as u8);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    let crc = crc32(&out[4..]);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse just the preamble (socket reads need the body length before the
/// body exists in memory). Checks magic, version and kind.
pub fn peek_header(header: &[u8; HEADER_BYTES]) -> Result<(FrameKind, usize), WireError> {
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    if header[4] != VERSION {
        return Err(WireError::BadVersion(header[4]));
    }
    let kind = FrameKind::from_u8(header[5])?;
    let len = u32::from_le_bytes(header[6..10].try_into().unwrap()) as usize;
    if len > MAX_BODY_BYTES {
        return Err(WireError::TooLarge { declared: len, max: MAX_BODY_BYTES });
    }
    Ok((kind, len))
}

/// Strict decode of one complete frame: returns the kind and a view of
/// the body. Rejects truncation, trailing bytes, and any corruption of
/// the CRC-covered region.
pub fn decode_frame(frame: &[u8]) -> Result<(FrameKind, &[u8]), WireError> {
    if frame.len() < HEADER_BYTES + 4 {
        return Err(WireError::Truncated { need: HEADER_BYTES + 4, have: frame.len() });
    }
    let header: &[u8; HEADER_BYTES] = frame[..HEADER_BYTES].try_into().unwrap();
    let (kind, len) = peek_header(header)?;
    let actual = frame.len() - HEADER_BYTES - 4;
    if actual != len {
        return Err(WireError::Length { declared: len, actual });
    }
    let covered = &frame[4..HEADER_BYTES + len];
    let got = crc32(covered);
    let want = u32::from_le_bytes(frame[HEADER_BYTES + len..].try_into().unwrap());
    if want != got {
        return Err(WireError::Crc { want, got });
    }
    Ok((kind, &frame[HEADER_BYTES..HEADER_BYTES + len]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // canonical IEEE CRC-32 test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_roundtrip_and_overhead() {
        for body in [&b""[..], &b"x"[..], &[7u8; 1000][..]] {
            let f = encode_frame(FrameKind::Payload, body);
            assert_eq!(f.len() as u64, body.len() as u64 + FRAME_OVERHEAD);
            let (kind, back) = decode_frame(&f).unwrap();
            assert_eq!(kind, FrameKind::Payload);
            assert_eq!(back, body);
        }
        let f = encode_frame(FrameKind::Reply, b"abc");
        assert_eq!(decode_frame(&f).unwrap().0, FrameKind::Reply);
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        let f = encode_frame(FrameKind::Payload, b"hello wire");
        for byte in 0..f.len() {
            for bit in 0..8 {
                let mut bad = f.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    decode_frame(&bad).is_err(),
                    "flip at byte {byte} bit {bit} must be detected"
                );
            }
        }
    }

    #[test]
    fn every_truncation_is_rejected() {
        let f = encode_frame(FrameKind::Reply, &[9u8; 64]);
        for cut in 0..f.len() {
            assert!(decode_frame(&f[..cut]).is_err(), "truncation to {cut} must fail");
        }
        // trailing garbage too
        let mut padded = f.clone();
        padded.push(0);
        assert!(decode_frame(&padded).is_err());
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        // a hostile/corrupt length field must be a typed error, not a
        // multi-GiB allocation followed by a blocking read
        let mut header = [0u8; HEADER_BYTES];
        header[0..4].copy_from_slice(&MAGIC.to_le_bytes());
        header[4] = VERSION;
        header[5] = FrameKind::Payload as u8;
        header[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(peek_header(&header), Err(WireError::TooLarge { .. })));
        // just over the cap: rejected; at the cap: length is accepted
        header[6..10].copy_from_slice(&((MAX_BODY_BYTES as u32) + 1).to_le_bytes());
        assert!(matches!(peek_header(&header), Err(WireError::TooLarge { .. })));
        header[6..10].copy_from_slice(&(MAX_BODY_BYTES as u32).to_le_bytes());
        assert!(peek_header(&header).is_ok());
    }

    #[test]
    fn unknown_kind_with_valid_crc_is_a_typed_error() {
        // Forward compatibility: a WELL-FORMED frame of a future kind
        // (valid magic, version, length and CRC) must decode to a typed
        // `BadKind` — never a panic, never a misparse. (The bit-flip
        // suite only covers kinds that also break the CRC.)
        // kind byte 13 is unclaimed (v7 claims 1..=9; keep this probe off
        // any value a future frame kind is likely to take next).
        let body = b"frame from the future";
        let mut f = Vec::new();
        f.extend_from_slice(&MAGIC.to_le_bytes());
        f.push(VERSION);
        f.push(13); // unknown kind byte
        f.extend_from_slice(&(body.len() as u32).to_le_bytes());
        f.extend_from_slice(body);
        let crc = crc32(&f[4..]);
        f.extend_from_slice(&crc.to_le_bytes());
        assert!(matches!(decode_frame(&f), Err(WireError::BadKind(13))));
    }

    #[test]
    fn typed_errors_name_the_failure() {
        let f = encode_frame(FrameKind::Payload, b"body");
        let mut bad_magic = f.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(decode_frame(&bad_magic), Err(WireError::BadMagic(_))));
        let mut bad_version = f.clone();
        bad_version[4] = 99;
        assert!(matches!(decode_frame(&bad_version), Err(WireError::BadVersion(99))));
        let mut bad_kind = f.clone();
        bad_kind[5] = 42;
        assert!(matches!(decode_frame(&bad_kind), Err(WireError::BadKind(42))));
        let mut bad_len = f.clone();
        bad_len[6] ^= 1;
        assert!(matches!(decode_frame(&bad_len), Err(WireError::Length { .. })));
        let mut bad_body = f.clone();
        bad_body[HEADER_BYTES] ^= 1;
        assert!(matches!(decode_frame(&bad_body), Err(WireError::Crc { .. })));
    }
}
