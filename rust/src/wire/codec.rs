//! Byte codec for the edge↔cloud protocol structs — the single
//! implementation of the layout documented in `coordinator::protocol`.
//!
//! Every encoder is paired with a strict decoder, and the load-bearing
//! invariant is enforced at every encode (debug builds) and in the test
//! suite: **the encoded body length equals the struct's `wire_bytes()`**,
//! so the byte accounting the paper's figures rest on is an assertion,
//! not an estimate. The full frame adds [`PAYLOAD_OVERHEAD`] /
//! [`REPLY_OVERHEAD`] fixed bytes on top.
//!
//! # Body layouts (wire format v4, little-endian throughout)
//!
//! `CompressedTensor`:
//! ```text
//! [rows u16][cols u16][bits u8][flags u8]          6-byte header
//! [scale f32, zero f32] x rows                     per-token params
//! [sign bitset: ceil(rows*cols/8) bytes]           1 bit/element
//! [tag u8]                                         0 = raw, 1 = rANS
//!   tag 0: [bits u32][n u32][packed codes]         8-byte raw header
//!   tag 1: [len u32][rANS stream]                  explicit length: the
//!                                                  stream is not
//!                                                  self-delimiting
//! [CSR: rows u16, cols u16, row_ptr u32 x (rows+1),
//!  (col_idx u16, value f32) x nnz]                 lossless T_above
//! ```
//!
//! `CompressedKv`: `[n_layers u16][used_rows u16]` + (k, v) tensor pairs.
//!
//! `SplitPayload`: `[request_id u64][pos u64][flags u8]` (17 bytes; flags
//! bit0 = prefill, bit1 = KV present, bit2 = top-k sampling), then for
//! top-k `[k u16][temperature f32][seed u64]` (14 bytes), then the hidden
//! tensor, then the KV block when present.
//!
//! `CloudReply` (the frame body is prefixed by `[server_s f64]`, the
//! server's measured compute seconds — transport metadata outside
//! `wire_bytes()`): `[request_id u64][pos u64][token u32][entropy f32]
//! [n_layers u16][row_len u32]` + per layer `row_len` f32 k-row then
//! `row_len` f32 v-row. The `pos` stamp is new in v5: it echoes the
//! payload position the reply answers, so duplicated or stale replies
//! are typed rejections at the session instead of silent double-applies.
//!
//! `Reconfig` (frame kind 3, new in v4 — the control plane's mid-stream
//! actuation message): `[request_id u64][epoch u32][budget_cap u32]
//! [tau f32][qa_bits u8][flags u8]` (22 bytes; flags bit0 = I_kv).
//!
//! The v5 session-recovery frames:
//!
//! `Resume` (kind 4): `[request_id u64][epoch u32][next_pos u64][tau f32]
//! [qa_bits u8][flags u8]` (26 bytes; flags bit0 = I_kv).
//!
//! `ResumeAck` (kind 5): `[request_id u64][epoch u32][last_pos u64]
//! [flags u8]` (21 bytes; flags bit0 = last_pos present).
//!
//! `Error` (kind 6): `[code u8][request_id u64][len u16][UTF-8 message]`
//! (11 + len bytes) — the cloud's in-band typed rejection.
//!
//! The v6 pool frame:
//!
//! `Migrate` (kind 7): `[request_id u64][epoch u32][next_pos u64][flags u8]`
//! (21 bytes; flags bit0 = fence present, bit1 = control present), then when
//! bit0 `[fence_pos u64][frame_len u32][cached reply frame bytes]` — the
//! embedded frame is a complete kind-2 reply frame and is re-validated on
//! decode (envelope, CRC, matching request/pos) — then when bit1 the 22-byte
//! `Reconfig` body verbatim. v7 adds flags bit2 = prefix attachment
//! present: `[digest 32 bytes][prefix_len u32]` appended after the
//! control body, so a migrating session's prefix-store refcount moves
//! with it.
//!
//! The v7 prefix-cache messages:
//!
//! A prefill `SplitPayload` may carry a prefix-cache reference (flags
//! bit3): `[digest 32 bytes][prefix_len u32]` placed immediately after
//! the flags byte — a fixed offset, so the pool can peek the digest for
//! residency-preferring placement without decoding tensors. Flags bit4
//! (insert; requires bit3) appends the prefix's own compressed hidden
//! block right after the 36-byte reference, ahead of the sampling spec.
//! With bit3 and no bit4 (warm), the payload's `hidden` tensor covers
//! only the divergent suffix rows.
//!
//! `PrefixProbe` (kind 8): `[request_id u64][digest 32][prefix_len u32]`
//! (44 bytes) — "is this prefix resident?"; a hit pins the entry for
//! this request.
//!
//! `PrefixAck` (kind 9): `[request_id u64][digest 32][flags u8]`
//! (41 bytes; flags bit0 = hit) — the digest is echoed so a cross-field
//! mismatch is a typed error, not a misapplied answer.

use crate::adapt::Reconfig;
use crate::coordinator::protocol::{
    CloudReply, CompressedKv, CompressedTensor, MigrateState, PrefixAck, PrefixProbe, PrefixRef,
    RejectFrame, Resume, ResumeAck, SplitPayload,
};
use crate::coordinator::sampling::SamplingSpec;
use crate::prefix::PrefixDigest;
use crate::quant::rans::CodedStream;
use crate::quant::ts::SparseOutliers;
use crate::util::bits_to_bytes;

use super::frame::{self, FrameKind, WireError, FRAME_OVERHEAD};

/// Fixed bytes a payload frame adds on top of `SplitPayload::wire_bytes()`.
pub const PAYLOAD_OVERHEAD: u64 = FRAME_OVERHEAD;
/// Fixed bytes a reply frame adds on top of `CloudReply::wire_bytes()`
/// (frame + the 8-byte server-compute-seconds timing prefix).
pub const REPLY_OVERHEAD: u64 = FRAME_OVERHEAD + 8;
/// Fixed bytes a reconfig frame adds on top of `Reconfig::wire_bytes()`.
pub const RECONFIG_OVERHEAD: u64 = FRAME_OVERHEAD;
/// Fixed bytes a migrate frame adds on top of `MigrateState::wire_bytes()`.
pub const MIGRATE_OVERHEAD: u64 = FRAME_OVERHEAD;
/// Fixed bytes a prefix probe/ack frame adds on top of its `wire_bytes()`.
pub const PREFIX_OVERHEAD: u64 = FRAME_OVERHEAD;

const FLAG_PREFILL: u8 = 1;
const FLAG_KV: u8 = 1 << 1;
const FLAG_TOPK: u8 = 1 << 2;
/// Payload flag (v7): a 36-byte prefix-cache reference follows the flags
/// byte (digest 32 + prefix_len u32) — fixed offset, peekable.
const FLAG_PREFIX: u8 = 1 << 3;
/// Payload flag (v7): the prefix reference carries its own compressed
/// hidden block (a cold insert populating the store). Requires
/// [`FLAG_PREFIX`].
const FLAG_PREFIX_INSERT: u8 = 1 << 4;

/// Reconfig body flag: I_kv (ship the KV cache with each decode step).
const RC_FLAG_KV: u8 = 1;

/// Resume body flag: I_kv of the re-announced settings.
const RS_FLAG_KV: u8 = 1;
/// ResumeAck body flag: the `last_pos` field is meaningful.
const RA_FLAG_LAST_POS: u8 = 1;
/// Migrate body flag: a replay fence (pos + cached reply frame) is shipped.
const MG_FLAG_FENCE: u8 = 1;
/// Migrate body flag: announced control-plane settings are shipped.
const MG_FLAG_CONTROL: u8 = 1 << 1;
/// Migrate body flag (v7): a prefix-store attachment (digest 32 +
/// prefix_len u32) is shipped.
const MG_FLAG_PREFIX: u8 = 1 << 2;
/// PrefixAck body flag: the probed digest is resident (and now pinned).
const PA_FLAG_HIT: u8 = 1;

fn malformed(m: impl Into<String>) -> WireError {
    WireError::Malformed(m.into())
}

/// Bounds-checked little-endian cursor over a frame body. Crate-visible:
/// the session-snapshot codec (`coordinator::snapshot`) reuses it for the
/// same strict, typed decoding discipline.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, at: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.remaining() {
            return Err(WireError::Truncated { need: self.at + n, have: self.buf.len() });
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub(crate) fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Strict-consumption check: a well-formed body leaves nothing behind.
    pub(crate) fn done(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(malformed(format!("{} unread trailing bytes", self.remaining())));
        }
        Ok(())
    }
}

fn write_tensor(out: &mut Vec<u8>, t: &CompressedTensor) {
    // Release-mode asserts: a value the header cannot represent must fail
    // loudly HERE, not wrap into a CRC-valid frame that misdecodes at the
    // peer. All are impossible by construction (rows <= max_seq, cols =
    // model widths < 65536 — ts.rs asserts the latter at compression).
    assert!(t.rows <= u16::MAX as usize && t.cols <= u16::MAX as usize);
    assert!(t.chosen_bits <= u8::MAX as u32);
    debug_assert_eq!(t.signs.len() as u64, bits_to_bytes((t.rows * t.cols) as u64));
    out.extend_from_slice(&(t.rows as u16).to_le_bytes());
    out.extend_from_slice(&(t.cols as u16).to_le_bytes());
    out.push(t.chosen_bits as u8);
    out.push(0u8); // flags: reserved
    for (s, z) in t.scales.iter().zip(&t.zeros) {
        out.extend_from_slice(&s.to_le_bytes());
        out.extend_from_slice(&z.to_le_bytes());
    }
    out.extend_from_slice(&t.signs);
    match &t.coded {
        CodedStream::Raw { bits, n, bytes } => {
            out.push(0u8);
            out.extend_from_slice(&bits.to_le_bytes());
            out.extend_from_slice(&(*n as u32).to_le_bytes());
            out.extend_from_slice(bytes);
        }
        CodedStream::Rans(b) => {
            out.push(1u8);
            out.extend_from_slice(&(b.len() as u32).to_le_bytes());
            out.extend_from_slice(b);
        }
    }
    let a = &t.above;
    debug_assert_eq!((a.rows, a.cols), (t.rows, t.cols));
    out.extend_from_slice(&(a.rows as u16).to_le_bytes());
    out.extend_from_slice(&(a.cols as u16).to_le_bytes());
    for &p in &a.row_ptr {
        out.extend_from_slice(&p.to_le_bytes());
    }
    for (c, v) in a.col_idx.iter().zip(&a.values) {
        out.extend_from_slice(&c.to_le_bytes());
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn read_tensor(r: &mut Reader) -> Result<CompressedTensor, WireError> {
    let rows = r.u16()? as usize;
    let cols = r.u16()? as usize;
    let chosen_bits = r.u8()? as u32;
    if chosen_bits > 16 {
        // Anything wider than the u16 code space is hostile or corrupt;
        // reject it here instead of letting dequantization shift by an
        // out-of-range width downstream.
        return Err(malformed(format!("tensor bit width {chosen_bits} exceeds u16 codes")));
    }
    let _flags = r.u8()?;
    let mut scales = Vec::with_capacity(rows);
    let mut zeros = Vec::with_capacity(rows);
    for _ in 0..rows {
        scales.push(r.f32()?);
        zeros.push(r.f32()?);
    }
    let n = rows * cols;
    let signs = r.take(bits_to_bytes(n as u64) as usize)?.to_vec();
    let coded = match r.u8()? {
        0 => {
            let bits = r.u32()?;
            if bits > 16 {
                return Err(malformed(format!("raw code width {bits} exceeds u16 codes")));
            }
            let cn = r.u32()? as usize;
            let packed = r.take(bits_to_bytes(cn as u64 * bits as u64) as usize)?;
            CodedStream::Raw { bits, n: cn, bytes: packed.to_vec() }
        }
        1 => {
            let len = r.u32()? as usize;
            CodedStream::Rans(r.take(len)?.to_vec())
        }
        tag => return Err(malformed(format!("unknown coded-stream tag {tag}"))),
    };
    // CSR outliers
    let a_rows = r.u16()? as usize;
    let a_cols = r.u16()? as usize;
    if (a_rows, a_cols) != (rows, cols) {
        return Err(malformed(format!(
            "outlier block is {a_rows}x{a_cols}, tensor is {rows}x{cols}"
        )));
    }
    let mut row_ptr = Vec::with_capacity(rows + 1);
    for _ in 0..=rows {
        row_ptr.push(r.u32()?);
    }
    if row_ptr[0] != 0 || row_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(malformed("CSR row_ptr not monotone from 0"));
    }
    let nnz = *row_ptr.last().unwrap() as usize;
    if r.remaining() < nnz * 6 {
        return Err(WireError::Truncated { need: r.at + nnz * 6, have: r.buf.len() });
    }
    let mut col_idx = Vec::with_capacity(nnz);
    let mut values = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        let c = r.u16()?;
        if c as usize >= cols {
            return Err(malformed(format!("outlier column {c} out of range (cols {cols})")));
        }
        col_idx.push(c);
        values.push(r.f32()?);
    }
    Ok(CompressedTensor {
        rows,
        cols,
        above: SparseOutliers { rows, cols, row_ptr, col_idx, values },
        scales,
        zeros,
        signs,
        coded,
        chosen_bits,
    })
}

fn write_kv(out: &mut Vec<u8>, kv: &CompressedKv) {
    assert!(kv.layers.len() <= u16::MAX as usize, "layer count overflows the wire header");
    assert!(kv.used_rows <= u16::MAX as usize, "used_rows overflows the wire header");
    out.extend_from_slice(&(kv.layers.len() as u16).to_le_bytes());
    out.extend_from_slice(&(kv.used_rows as u16).to_le_bytes());
    for (k, v) in &kv.layers {
        write_tensor(out, k);
        write_tensor(out, v);
    }
}

fn read_kv(r: &mut Reader) -> Result<CompressedKv, WireError> {
    let n_layers = r.u16()? as usize;
    let used_rows = r.u16()? as usize;
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let k = read_tensor(r)?;
        let v = read_tensor(r)?;
        layers.push((k, v));
    }
    Ok(CompressedKv { layers, used_rows })
}

fn write_payload(out: &mut Vec<u8>, p: &SplitPayload) {
    out.extend_from_slice(&p.request_id.to_le_bytes());
    out.extend_from_slice(&(p.pos as u64).to_le_bytes());
    let mut flags = 0u8;
    if p.is_prefill {
        flags |= FLAG_PREFILL;
    }
    if p.kv.is_some() {
        flags |= FLAG_KV;
    }
    if matches!(p.sampling, SamplingSpec::TopK { .. }) {
        flags |= FLAG_TOPK;
    }
    if let Some(pr) = &p.prefix {
        debug_assert!(p.is_prefill, "a prefix reference only makes sense on prefill");
        flags |= FLAG_PREFIX;
        if pr.insert.is_some() {
            flags |= FLAG_PREFIX_INSERT;
        }
    }
    out.push(flags);
    if let Some(pr) = &p.prefix {
        out.extend_from_slice(&pr.digest.0);
        out.extend_from_slice(&pr.prefix_len.to_le_bytes());
        if let Some(t) = &pr.insert {
            write_tensor(out, t);
        }
    }
    if let SamplingSpec::TopK { k, temperature, seed } = p.sampling {
        assert!(k <= u16::MAX as usize, "top-k shortlist exceeds the wire's u16");
        out.extend_from_slice(&(k as u16).to_le_bytes());
        out.extend_from_slice(&temperature.to_le_bytes());
        out.extend_from_slice(&seed.to_le_bytes());
    }
    write_tensor(out, &p.hidden);
    if let Some(kv) = &p.kv {
        write_kv(out, kv);
    }
}

fn read_payload(r: &mut Reader) -> Result<SplitPayload, WireError> {
    let request_id = r.u64()?;
    let pos = r.u64()? as usize;
    let flags = r.u8()?;
    if flags & !(FLAG_PREFILL | FLAG_KV | FLAG_TOPK | FLAG_PREFIX | FLAG_PREFIX_INSERT) != 0 {
        return Err(malformed(format!("unknown payload flags {flags:#04x}")));
    }
    if flags & FLAG_PREFIX_INSERT != 0 && flags & FLAG_PREFIX == 0 {
        return Err(malformed("prefix-insert flag without a prefix reference"));
    }
    if flags & FLAG_PREFIX != 0 && flags & FLAG_PREFILL == 0 {
        return Err(malformed("prefix reference on a non-prefill payload"));
    }
    let prefix = if flags & FLAG_PREFIX != 0 {
        let digest = PrefixDigest(r.take(32)?.try_into().unwrap());
        let prefix_len = r.u32()?;
        if prefix_len == 0 {
            return Err(malformed("prefix reference with zero prefix_len"));
        }
        let insert =
            if flags & FLAG_PREFIX_INSERT != 0 { Some(read_tensor(r)?) } else { None };
        Some(PrefixRef { digest, prefix_len, insert })
    } else {
        None
    };
    let sampling = if flags & FLAG_TOPK != 0 {
        let k = r.u16()? as usize;
        let temperature = r.f32()?;
        let seed = r.u64()?;
        SamplingSpec::TopK { k, temperature, seed }
    } else {
        SamplingSpec::Greedy
    };
    let hidden = read_tensor(r)?;
    let kv = if flags & FLAG_KV != 0 { Some(read_kv(r)?) } else { None };
    Ok(SplitPayload {
        request_id,
        pos,
        hidden,
        kv,
        is_prefill: flags & FLAG_PREFILL != 0,
        sampling,
        prefix,
    })
}

fn write_reply(out: &mut Vec<u8>, reply: &CloudReply, server_s: f64) {
    out.extend_from_slice(&server_s.to_le_bytes());
    out.extend_from_slice(&reply.request_id.to_le_bytes());
    out.extend_from_slice(&reply.pos.to_le_bytes());
    out.extend_from_slice(&reply.token.to_le_bytes());
    out.extend_from_slice(&reply.logits_entropy.to_le_bytes());
    assert!(reply.new_kv_rows.len() <= u16::MAX as usize, "reply layer count overflows u16");
    out.extend_from_slice(&(reply.new_kv_rows.len() as u16).to_le_bytes());
    let row_len = reply.new_kv_rows.first().map_or(0, |(k, _)| k.len());
    out.extend_from_slice(&(row_len as u32).to_le_bytes());
    for (k, v) in &reply.new_kv_rows {
        debug_assert!(k.len() == row_len && v.len() == row_len, "ragged KV reply rows");
        for &x in k {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn read_reply(r: &mut Reader) -> Result<(CloudReply, f64), WireError> {
    let server_s = r.f64()?;
    let request_id = r.u64()?;
    let pos = r.u64()?;
    let token = r.u32()?;
    let logits_entropy = r.f32()?;
    let n_layers = r.u16()? as usize;
    let row_len = r.u32()? as usize;
    let rows_bytes = n_layers.saturating_mul(row_len).saturating_mul(8);
    if r.remaining() < rows_bytes {
        return Err(WireError::Truncated {
            need: r.at.saturating_add(rows_bytes),
            have: r.buf.len(),
        });
    }
    let mut new_kv_rows = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let mut k = Vec::with_capacity(row_len);
        for _ in 0..row_len {
            k.push(r.f32()?);
        }
        let mut v = Vec::with_capacity(row_len);
        for _ in 0..row_len {
            v.push(r.f32()?);
        }
        new_kv_rows.push((k, v));
    }
    Ok((CloudReply { request_id, pos, token, new_kv_rows, logits_entropy }, server_s))
}

/// Encode one payload as a complete frame. The body length is asserted
/// equal to `wire_bytes()` — the accounting IS the encoding.
pub fn encode_payload_frame(p: &SplitPayload) -> Vec<u8> {
    let mut body = Vec::with_capacity(p.wire_bytes() as usize);
    write_payload(&mut body, p);
    debug_assert_eq!(
        body.len() as u64,
        p.wire_bytes(),
        "payload body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::Payload, &body)
}

/// Strict decode of a payload frame (kind, CRC, structure, consumption).
pub fn decode_payload_frame(bytes: &[u8]) -> Result<SplitPayload, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::Payload {
        return Err(WireError::WrongKind { want: FrameKind::Payload, got: kind });
    }
    let mut r = Reader::new(body);
    let p = read_payload(&mut r)?;
    r.done()?;
    Ok(p)
}

/// Encode one reply (plus the server's measured compute seconds) as a
/// complete frame. Body length = `wire_bytes()` + 8 (the timing prefix).
pub fn encode_reply_frame(reply: &CloudReply, server_s: f64) -> Vec<u8> {
    let mut body = Vec::with_capacity(reply.wire_bytes() as usize + 8);
    write_reply(&mut body, reply, server_s);
    debug_assert_eq!(
        body.len() as u64,
        reply.wire_bytes() + 8,
        "reply body must encode to exactly wire_bytes() + timing prefix"
    );
    frame::encode_frame(FrameKind::Reply, &body)
}

/// Strict decode of a reply frame; returns the reply and the server's
/// compute seconds from the timing prefix.
pub fn decode_reply_frame(bytes: &[u8]) -> Result<(CloudReply, f64), WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::Reply {
        return Err(WireError::WrongKind { want: FrameKind::Reply, got: kind });
    }
    let mut r = Reader::new(body);
    let out = read_reply(&mut r)?;
    r.done()?;
    Ok(out)
}

fn write_reconfig(out: &mut Vec<u8>, rc: &Reconfig) {
    // 2..=16 is the data plane's legal Q̄a range (quant::fused asserts
    // it at compression) — an out-of-range announcement fails loudly at
    // the sender instead of panicking a session's compressor later.
    assert!(
        (2..=16).contains(&rc.qa_bits),
        "reconfig Q̄a of {} bits is outside the legal 2..=16 range",
        rc.qa_bits
    );
    out.extend_from_slice(&rc.request_id.to_le_bytes());
    out.extend_from_slice(&rc.epoch.to_le_bytes());
    out.extend_from_slice(&rc.budget_cap.to_le_bytes());
    out.extend_from_slice(&rc.tau.to_le_bytes());
    out.push(rc.qa_bits as u8);
    out.push(if rc.include_kv { RC_FLAG_KV } else { 0 });
}

fn read_reconfig(r: &mut Reader) -> Result<Reconfig, WireError> {
    let request_id = r.u64()?;
    let epoch = r.u32()?;
    let budget_cap = r.u32()?;
    let tau = r.f32()?;
    let qa_bits = r.u8()? as u32;
    if !(2..=16).contains(&qa_bits) {
        return Err(malformed(format!("reconfig Q̄a of {qa_bits} bits out of range")));
    }
    if !tau.is_finite() || tau < 0.0 {
        return Err(malformed(format!("reconfig τ = {tau} is not a valid threshold")));
    }
    let flags = r.u8()?;
    if flags & !RC_FLAG_KV != 0 {
        return Err(malformed(format!("unknown reconfig flags {flags:#04x}")));
    }
    Ok(Reconfig {
        request_id,
        epoch,
        qa_bits,
        tau,
        include_kv: flags & RC_FLAG_KV != 0,
        budget_cap,
    })
}

/// Encode one control-plane reconfiguration as a complete frame. Body
/// length is asserted equal to `wire_bytes()` — control traffic is
/// byte-accounted exactly like the data plane.
pub fn encode_reconfig_frame(rc: &Reconfig) -> Vec<u8> {
    let mut body = Vec::with_capacity(rc.wire_bytes() as usize);
    write_reconfig(&mut body, rc);
    debug_assert_eq!(
        body.len() as u64,
        rc.wire_bytes(),
        "reconfig body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::Reconfig, &body)
}

/// Strict decode of a reconfig frame (kind, CRC, structure, consumption).
pub fn decode_reconfig_frame(bytes: &[u8]) -> Result<Reconfig, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::Reconfig {
        return Err(WireError::WrongKind { want: FrameKind::Reconfig, got: kind });
    }
    let mut r = Reader::new(body);
    let rc = read_reconfig(&mut r)?;
    r.done()?;
    Ok(rc)
}

fn write_resume(out: &mut Vec<u8>, rs: &Resume) {
    // Same legal range a Reconfig announcement enforces: fail loudly at
    // the sender, not in the peer's compressor.
    assert!(
        (2..=16).contains(&rs.qa_bits),
        "resume Q̄a of {} bits is outside the legal 2..=16 range",
        rs.qa_bits
    );
    out.extend_from_slice(&rs.request_id.to_le_bytes());
    out.extend_from_slice(&rs.epoch.to_le_bytes());
    out.extend_from_slice(&rs.next_pos.to_le_bytes());
    out.extend_from_slice(&rs.tau.to_le_bytes());
    out.push(rs.qa_bits as u8);
    out.push(if rs.include_kv { RS_FLAG_KV } else { 0 });
}

fn read_resume(r: &mut Reader) -> Result<Resume, WireError> {
    let request_id = r.u64()?;
    let epoch = r.u32()?;
    let next_pos = r.u64()?;
    let tau = r.f32()?;
    let qa_bits = r.u8()? as u32;
    if !(2..=16).contains(&qa_bits) {
        return Err(malformed(format!("resume Q̄a of {qa_bits} bits out of range")));
    }
    if !tau.is_finite() || tau < 0.0 {
        return Err(malformed(format!("resume τ = {tau} is not a valid threshold")));
    }
    let flags = r.u8()?;
    if flags & !RS_FLAG_KV != 0 {
        return Err(malformed(format!("unknown resume flags {flags:#04x}")));
    }
    Ok(Resume { request_id, epoch, next_pos, qa_bits, tau, include_kv: flags & RS_FLAG_KV != 0 })
}

/// Encode one session-resumption announcement as a complete frame.
pub fn encode_resume_frame(rs: &Resume) -> Vec<u8> {
    let mut body = Vec::with_capacity(rs.wire_bytes() as usize);
    write_resume(&mut body, rs);
    debug_assert_eq!(
        body.len() as u64,
        rs.wire_bytes(),
        "resume body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::Resume, &body)
}

/// Strict decode of a resume frame (kind, CRC, structure, consumption).
pub fn decode_resume_frame(bytes: &[u8]) -> Result<Resume, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::Resume {
        return Err(WireError::WrongKind { want: FrameKind::Resume, got: kind });
    }
    let mut r = Reader::new(body);
    let rs = read_resume(&mut r)?;
    r.done()?;
    Ok(rs)
}

fn write_resume_ack(out: &mut Vec<u8>, ack: &ResumeAck) {
    out.extend_from_slice(&ack.request_id.to_le_bytes());
    out.extend_from_slice(&ack.epoch.to_le_bytes());
    out.extend_from_slice(&ack.last_pos.unwrap_or(0).to_le_bytes());
    out.push(if ack.last_pos.is_some() { RA_FLAG_LAST_POS } else { 0 });
}

fn read_resume_ack(r: &mut Reader) -> Result<ResumeAck, WireError> {
    let request_id = r.u64()?;
    let epoch = r.u32()?;
    let last_pos = r.u64()?;
    let flags = r.u8()?;
    if flags & !RA_FLAG_LAST_POS != 0 {
        return Err(malformed(format!("unknown resume-ack flags {flags:#04x}")));
    }
    let last_pos = (flags & RA_FLAG_LAST_POS != 0).then_some(last_pos);
    Ok(ResumeAck { request_id, epoch, last_pos })
}

/// Encode one resume acknowledgement as a complete frame.
pub fn encode_resume_ack_frame(ack: &ResumeAck) -> Vec<u8> {
    let mut body = Vec::with_capacity(ack.wire_bytes() as usize);
    write_resume_ack(&mut body, ack);
    debug_assert_eq!(
        body.len() as u64,
        ack.wire_bytes(),
        "resume-ack body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::ResumeAck, &body)
}

/// Strict decode of a resume-ack frame (kind, CRC, structure, consumption).
pub fn decode_resume_ack_frame(bytes: &[u8]) -> Result<ResumeAck, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::ResumeAck {
        return Err(WireError::WrongKind { want: FrameKind::ResumeAck, got: kind });
    }
    let mut r = Reader::new(body);
    let ack = read_resume_ack(&mut r)?;
    r.done()?;
    Ok(ack)
}

fn write_reject(out: &mut Vec<u8>, e: &RejectFrame) {
    assert!(e.message.len() <= u16::MAX as usize, "error message overflows the wire's u16");
    out.push(e.code);
    out.extend_from_slice(&e.request_id.to_le_bytes());
    out.extend_from_slice(&(e.message.len() as u16).to_le_bytes());
    out.extend_from_slice(e.message.as_bytes());
}

fn read_reject(r: &mut Reader) -> Result<RejectFrame, WireError> {
    let code = r.u8()?;
    let request_id = r.u64()?;
    let len = r.u16()? as usize;
    let message = std::str::from_utf8(r.take(len)?)
        .map_err(|_| malformed("error message is not UTF-8"))?
        .to_string();
    Ok(RejectFrame { code, request_id, message })
}

/// Encode one in-band typed rejection as a complete frame.
pub fn encode_error_frame(e: &RejectFrame) -> Vec<u8> {
    let mut body = Vec::with_capacity(e.wire_bytes() as usize);
    write_reject(&mut body, e);
    debug_assert_eq!(
        body.len() as u64,
        e.wire_bytes(),
        "error body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::Error, &body)
}

/// Strict decode of an error frame (kind, CRC, structure, consumption).
pub fn decode_error_frame(bytes: &[u8]) -> Result<RejectFrame, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::Error {
        return Err(WireError::WrongKind { want: FrameKind::Error, got: kind });
    }
    let mut r = Reader::new(body);
    let e = read_reject(&mut r)?;
    r.done()?;
    Ok(e)
}

fn write_prefix_probe(out: &mut Vec<u8>, p: &PrefixProbe) {
    out.extend_from_slice(&p.request_id.to_le_bytes());
    out.extend_from_slice(&p.digest.0);
    out.extend_from_slice(&p.prefix_len.to_le_bytes());
}

fn read_prefix_probe(r: &mut Reader) -> Result<PrefixProbe, WireError> {
    let request_id = r.u64()?;
    let digest = PrefixDigest(r.take(32)?.try_into().unwrap());
    let prefix_len = r.u32()?;
    if prefix_len == 0 {
        return Err(malformed("prefix probe with zero prefix_len"));
    }
    Ok(PrefixProbe { request_id, digest, prefix_len })
}

/// Encode one prefix-cache probe as a complete frame.
pub fn encode_prefix_probe_frame(p: &PrefixProbe) -> Vec<u8> {
    let mut body = Vec::with_capacity(p.wire_bytes() as usize);
    write_prefix_probe(&mut body, p);
    debug_assert_eq!(
        body.len() as u64,
        p.wire_bytes(),
        "prefix-probe body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::PrefixProbe, &body)
}

/// Strict decode of a prefix-probe frame (kind, CRC, structure,
/// consumption).
pub fn decode_prefix_probe_frame(bytes: &[u8]) -> Result<PrefixProbe, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::PrefixProbe {
        return Err(WireError::WrongKind { want: FrameKind::PrefixProbe, got: kind });
    }
    let mut r = Reader::new(body);
    let p = read_prefix_probe(&mut r)?;
    r.done()?;
    Ok(p)
}

fn write_prefix_ack(out: &mut Vec<u8>, a: &PrefixAck) {
    out.extend_from_slice(&a.request_id.to_le_bytes());
    out.extend_from_slice(&a.digest.0);
    out.push(if a.hit { PA_FLAG_HIT } else { 0 });
}

fn read_prefix_ack(r: &mut Reader) -> Result<PrefixAck, WireError> {
    let request_id = r.u64()?;
    let digest = PrefixDigest(r.take(32)?.try_into().unwrap());
    let flags = r.u8()?;
    if flags & !PA_FLAG_HIT != 0 {
        return Err(malformed(format!("unknown prefix-ack flags {flags:#04x}")));
    }
    Ok(PrefixAck { request_id, digest, hit: flags & PA_FLAG_HIT != 0 })
}

/// Encode one prefix-cache probe answer as a complete frame.
pub fn encode_prefix_ack_frame(a: &PrefixAck) -> Vec<u8> {
    let mut body = Vec::with_capacity(a.wire_bytes() as usize);
    write_prefix_ack(&mut body, a);
    debug_assert_eq!(
        body.len() as u64,
        a.wire_bytes(),
        "prefix-ack body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::PrefixAck, &body)
}

/// Strict decode of a prefix-ack frame (kind, CRC, structure,
/// consumption).
pub fn decode_prefix_ack_frame(bytes: &[u8]) -> Result<PrefixAck, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::PrefixAck {
        return Err(WireError::WrongKind { want: FrameKind::PrefixAck, got: kind });
    }
    let mut r = Reader::new(body);
    let a = read_prefix_ack(&mut r)?;
    r.done()?;
    Ok(a)
}

fn write_migrate(out: &mut Vec<u8>, ms: &MigrateState) {
    out.extend_from_slice(&ms.request_id.to_le_bytes());
    out.extend_from_slice(&ms.epoch.to_le_bytes());
    out.extend_from_slice(&ms.next_pos.to_le_bytes());
    let mut flags = 0u8;
    if ms.fence.is_some() {
        flags |= MG_FLAG_FENCE;
    }
    if ms.control.is_some() {
        flags |= MG_FLAG_CONTROL;
    }
    if ms.prefix.is_some() {
        flags |= MG_FLAG_PREFIX;
    }
    out.push(flags);
    if let Some((pos, frame)) = &ms.fence {
        assert!(frame.len() <= u32::MAX as usize, "fenced reply frame overflows the wire's u32");
        out.extend_from_slice(&pos.to_le_bytes());
        out.extend_from_slice(&(frame.len() as u32).to_le_bytes());
        out.extend_from_slice(frame);
    }
    if let Some(rc) = &ms.control {
        write_reconfig(out, rc);
    }
    if let Some((digest, prefix_len)) = &ms.prefix {
        out.extend_from_slice(&digest.0);
        out.extend_from_slice(&prefix_len.to_le_bytes());
    }
}

fn read_migrate(r: &mut Reader) -> Result<MigrateState, WireError> {
    let request_id = r.u64()?;
    let epoch = r.u32()?;
    let next_pos = r.u64()?;
    let flags = r.u8()?;
    if flags & !(MG_FLAG_FENCE | MG_FLAG_CONTROL | MG_FLAG_PREFIX) != 0 {
        return Err(malformed(format!("unknown migrate flags {flags:#04x}")));
    }
    let fence = if flags & MG_FLAG_FENCE != 0 {
        let pos = r.u64()?;
        let len = r.u32()? as usize;
        let frame = r.take(len)?.to_vec();
        // The cached frame is replayed verbatim to the edge on a duplicate
        // position, so a migrate that ships garbage here would turn into a
        // silent wrong answer later. Validate the whole embedded frame NOW:
        // envelope, CRC, structure, and that it fences this very session at
        // this very position.
        let (reply, _server_s) = decode_reply_frame(&frame)?;
        if reply.request_id != request_id {
            return Err(malformed(format!(
                "fenced reply is for request {}, migrate is for {request_id}",
                reply.request_id
            )));
        }
        if reply.pos != pos {
            return Err(malformed(format!(
                "fenced reply answers pos {}, fence claims {pos}",
                reply.pos
            )));
        }
        if next_pos != pos + 1 {
            return Err(malformed(format!(
                "migrate next_pos {next_pos} disagrees with fence pos {pos}"
            )));
        }
        Some((pos, frame))
    } else {
        None
    };
    let control = if flags & MG_FLAG_CONTROL != 0 {
        let rc = read_reconfig(r)?;
        if rc.request_id != request_id {
            return Err(malformed(format!(
                "migrated control is for request {}, migrate is for {request_id}",
                rc.request_id
            )));
        }
        Some(rc)
    } else {
        None
    };
    let prefix = if flags & MG_FLAG_PREFIX != 0 {
        let digest = PrefixDigest(r.take(32)?.try_into().unwrap());
        let prefix_len = r.u32()?;
        if prefix_len == 0 {
            return Err(malformed("migrated prefix attachment with zero prefix_len"));
        }
        Some((digest, prefix_len))
    } else {
        None
    };
    Ok(MigrateState { request_id, epoch, next_pos, fence, control, prefix })
}

/// Encode one worker-to-worker session migration as a complete frame.
/// Body length is asserted equal to `wire_bytes()` — the handoff is
/// byte-accounted exactly like the data plane.
pub fn encode_migrate_frame(ms: &MigrateState) -> Vec<u8> {
    let mut body = Vec::with_capacity(ms.wire_bytes() as usize);
    write_migrate(&mut body, ms);
    debug_assert_eq!(
        body.len() as u64,
        ms.wire_bytes(),
        "migrate body must encode to exactly wire_bytes()"
    );
    frame::encode_frame(FrameKind::Migrate, &body)
}

/// Strict decode of a migrate frame (kind, CRC, structure, consumption),
/// including full re-validation of the embedded replay-fence reply frame.
pub fn decode_migrate_frame(bytes: &[u8]) -> Result<MigrateState, WireError> {
    let (kind, body) = frame::decode_frame(bytes)?;
    if kind != FrameKind::Migrate {
        return Err(WireError::WrongKind { want: FrameKind::Migrate, got: kind });
    }
    let mut r = Reader::new(body);
    let ms = read_migrate(&mut r)?;
    r.done()?;
    Ok(ms)
}

/// The peekable fixed prefix of an encoded reply frame's body — what the
/// pool needs to route a worker's answer back to its edge and retire
/// finished streams (EOS = token 0) without decoding the KV rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplyMeta {
    pub request_id: u64,
    pub pos: u64,
    pub token: u32,
}

/// Peek the `[request_id][pos][token]` fields of an encoded *reply frame*
/// (they sit behind the 8-byte server-compute-seconds prefix). The frame
/// envelope is fully validated — corrupted replies must never be routed.
pub fn peek_reply_meta(frame_bytes: &[u8]) -> Result<ReplyMeta, WireError> {
    let (kind, body) = frame::decode_frame(frame_bytes)?;
    if kind != FrameKind::Reply {
        return Err(WireError::WrongKind { want: FrameKind::Reply, got: kind });
    }
    if body.len() < 28 {
        return Err(WireError::Truncated { need: 28, have: body.len() });
    }
    let request_id = u64::from_le_bytes(body[8..16].try_into().unwrap());
    let pos = u64::from_le_bytes(body[16..24].try_into().unwrap());
    let token = u32::from_le_bytes(body[24..28].try_into().unwrap());
    Ok(ReplyMeta { request_id, pos, token })
}

/// The peekable fixed prefix of an encoded payload frame's body —
/// everything the fleet scheduler needs to route, replay-fence and admit
/// a payload WITHOUT decompressing its tensors (the tensors are only
/// decoded when the payload is actually served in a batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PayloadPrefix {
    pub request_id: u64,
    pub pos: u64,
    pub is_prefill: bool,
    pub has_kv: bool,
    /// The payload's prefix-cache reference (digest, prefix_len), when it
    /// carries one (wire v7). It sits at a fixed offset right after the
    /// flags byte precisely so this peek can read it — the pool prefers
    /// placing a prefix-bearing prefill on a worker already holding the
    /// digest.
    pub prefix: Option<(PrefixDigest, u32)>,
    /// The reference carries the prefix's own compressed block (a cold
    /// insert) rather than relying on store residency.
    pub prefix_insert: bool,
}

/// Peek the `[request_id u64][pos u64][flags u8]` prefix of an encoded
/// *payload frame* — plus the fixed-offset 36-byte prefix-cache reference
/// when flags bit3 says one is present. The frame envelope (magic,
/// version, kind, length, CRC-32) is fully validated — a corrupted frame
/// must never be routed by garbage — but the tensor payload behind the
/// prefix is not decoded.
pub fn peek_payload_prefix(frame_bytes: &[u8]) -> Result<PayloadPrefix, WireError> {
    let (kind, body) = frame::decode_frame(frame_bytes)?;
    if kind != FrameKind::Payload {
        return Err(WireError::WrongKind { want: FrameKind::Payload, got: kind });
    }
    if body.len() < 17 {
        return Err(WireError::Truncated { need: 17, have: body.len() });
    }
    let request_id = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let pos = u64::from_le_bytes(body[8..16].try_into().unwrap());
    let flags = body[16];
    if flags & !(FLAG_PREFILL | FLAG_KV | FLAG_TOPK | FLAG_PREFIX | FLAG_PREFIX_INSERT) != 0 {
        return Err(WireError::Malformed("unknown payload flags".into()));
    }
    if flags & FLAG_PREFIX_INSERT != 0 && flags & FLAG_PREFIX == 0 {
        return Err(WireError::Malformed("prefix-insert flag without a prefix reference".into()));
    }
    let prefix = if flags & FLAG_PREFIX != 0 {
        if body.len() < 53 {
            return Err(WireError::Truncated { need: 53, have: body.len() });
        }
        let digest = PrefixDigest(body[17..49].try_into().unwrap());
        let prefix_len = u32::from_le_bytes(body[49..53].try_into().unwrap());
        Some((digest, prefix_len))
    } else {
        None
    };
    Ok(PayloadPrefix {
        request_id,
        pos,
        is_prefill: flags & FLAG_PREFILL != 0,
        has_kv: flags & FLAG_KV != 0,
        prefix,
        prefix_insert: flags & FLAG_PREFIX_INSERT != 0,
    })
}
