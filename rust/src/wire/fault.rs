//! Seeded fault injection for any [`Transport`]: the chaos harness's
//! workhorse. A [`FaultyTransport`] wraps a transport and, driven by a
//! deterministic [`FaultPlan`], injects the misbehaviors a real lossy
//! link or flaky peer produces — bit corruption, truncation, frame
//! duplication, reordering, recv stalls, and mid-frame disconnects.
//!
//! Everything is seeded (`util::rng::Rng`), so a failing chaos case
//! replays exactly from its seed. Faults are injected at the frame
//! boundary the peer actually observes: a corrupted frame arrives
//! CRC-broken, a truncated frame arrives short, a disconnect may leave a
//! partial frame in flight — precisely the byte streams the strict
//! decoder must turn into typed errors, never silent misdecodes.

use anyhow::Result;

use crate::channel::TransferOutcome;
use crate::util::rng::Rng;

use super::frame::WireError;
use super::transport::{Transport, WireTransport};

/// Per-frame fault probabilities plus a deterministic disconnect point.
/// All rates are probabilities in `[0, 1]` evaluated independently per
/// frame; `disconnect_after` kills the transport after that many
/// send/recv operations (a send in flight is torn mid-frame).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the plan's private RNG stream.
    pub seed: u64,
    /// Flip one random bit somewhere in a sent frame.
    pub corrupt_rate: f64,
    /// Deliver only a strict prefix of a sent frame.
    pub truncate_rate: f64,
    /// Deliver a sent frame twice.
    pub duplicate_rate: f64,
    /// Hold a sent frame back and deliver it after the next one.
    pub reorder_rate: f64,
    /// A recv stalls past the deadline (typed [`WireError::Timeout`]).
    pub stall_rate: f64,
    /// Kill the transport after this many send/recv operations; a send
    /// that crosses the boundary delivers a partial frame first.
    pub disconnect_after: Option<u64>,
}

impl FaultPlan {
    /// No faults at all: the decorated transport behaves losslessly.
    pub fn clean(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            corrupt_rate: 0.0,
            truncate_rate: 0.0,
            duplicate_rate: 0.0,
            reorder_rate: 0.0,
            stall_rate: 0.0,
            disconnect_after: None,
        }
    }

    /// A random mixed-fault plan for property sweeps: each class gets an
    /// independently drawn (possibly zero) rate, and roughly a third of
    /// the seeds also schedule a disconnect.
    pub fn from_seed(seed: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA_17);
        let mut rate = |p_active: f64, max: f64| {
            if rng.f64() < p_active {
                rng.f64() * max
            } else {
                0.0
            }
        };
        let corrupt_rate = rate(0.4, 0.3);
        let truncate_rate = rate(0.4, 0.3);
        let duplicate_rate = rate(0.4, 0.3);
        let reorder_rate = rate(0.3, 0.2);
        let stall_rate = rate(0.3, 0.2);
        let disconnect_after =
            if rng.f64() < 0.35 { Some(1 + rng.below(24) as u64) } else { None };
        FaultPlan {
            seed,
            corrupt_rate,
            truncate_rate,
            duplicate_rate,
            reorder_rate,
            stall_rate,
            disconnect_after,
        }
    }

    /// Single-class plan: bit corruption only.
    pub fn corrupt(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { corrupt_rate: rate, ..FaultPlan::clean(seed) }
    }

    /// Single-class plan: frame truncation only.
    pub fn truncate(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { truncate_rate: rate, ..FaultPlan::clean(seed) }
    }

    /// Single-class plan: frame duplication only.
    pub fn duplicate(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { duplicate_rate: rate, ..FaultPlan::clean(seed) }
    }

    /// Single-class plan: frame reordering only.
    pub fn reorder(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { reorder_rate: rate, ..FaultPlan::clean(seed) }
    }

    /// Single-class plan: recv stalls only.
    pub fn stall(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { stall_rate: rate, ..FaultPlan::clean(seed) }
    }

    /// Single-class plan: deterministic disconnect after `ops` operations.
    pub fn disconnect(seed: u64, ops: u64) -> FaultPlan {
        FaultPlan { disconnect_after: Some(ops), ..FaultPlan::clean(seed) }
    }
}

/// One seeded plan deriving **correlated** fault windows across a whole
/// set of connections — the coordinated-failure mode independent
/// per-connection plans cannot express (a backhaul cut or cell outage
/// takes 30% of a fleet down in the *same* window, not 30% of frames
/// spread uniformly over time).
///
/// Cohort membership and each member's exact failure op are both pure
/// functions of `(seed, conn_id)`, so any party holding the plan — the
/// storm driver, the assertion at the other end, a replaying debugger —
/// derives the identical outage without coordination.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CorrelatedOutage {
    /// Seed of the whole correlated plan.
    pub seed: u64,
    /// Fraction of connections in the outage cohort, in `[0, 1]`.
    pub fraction: f64,
    /// First transport op of the shared outage window.
    pub window_start: u64,
    /// Window width in transport ops: every cohort member's link dies at
    /// an op in `[window_start, window_start + window_ops)`.
    pub window_ops: u64,
}

impl CorrelatedOutage {
    pub fn new(seed: u64, fraction: f64, window_start: u64, window_ops: u64) -> CorrelatedOutage {
        assert!((0.0..=1.0).contains(&fraction), "cohort fraction must be a probability");
        assert!(window_ops >= 1, "the outage window must span at least one op");
        CorrelatedOutage { seed, fraction, window_start, window_ops }
    }

    fn conn_rng(&self, conn_id: u64) -> Rng {
        // Per-connection stream: decorrelate ids without decorrelating
        // the plan (same (seed, conn) ⇒ same draws, always).
        Rng::new(self.seed ^ conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Is this connection in the outage cohort?
    pub fn hits(&self, conn_id: u64) -> bool {
        self.conn_rng(conn_id).f64() < self.fraction
    }

    /// The per-connection [`FaultPlan`] this correlated plan implies:
    /// cohort members disconnect at a seeded op inside the shared window,
    /// everyone else runs clean.
    pub fn plan_for(&self, conn_id: u64) -> FaultPlan {
        let mut rng = self.conn_rng(conn_id);
        if rng.f64() >= self.fraction {
            return FaultPlan::clean(self.seed ^ conn_id);
        }
        let at = self.window_start + rng.below(self.window_ops as usize) as u64;
        FaultPlan::disconnect(self.seed ^ conn_id, at)
    }
}

/// Counts of the faults actually injected — the chaos harness asserts
/// both determinism (same seed ⇒ same counts) and coverage (the sweep
/// really exercised every class).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultLog {
    pub corrupted: u64,
    pub truncated: u64,
    pub duplicated: u64,
    pub reordered: u64,
    pub stalled: u64,
    pub disconnected: bool,
}

impl FaultLog {
    /// Total injected fault events.
    pub fn total(&self) -> u64 {
        self.corrupted
            + self.truncated
            + self.duplicated
            + self.reordered
            + self.stalled
            + u64::from(self.disconnected)
    }
}

/// A [`Transport`] decorator that injects the plan's faults into the
/// frames crossing it. Wraps any [`WireTransport`] (boxed, so the enum
/// can hold it as a variant without recursing).
pub struct FaultyTransport {
    inner: Box<WireTransport>,
    plan: FaultPlan,
    rng: Rng,
    /// Reorder buffer: a held-back frame awaiting the next send.
    held: Option<Vec<u8>>,
    ops: u64,
    dead: bool,
    /// What was actually injected, for determinism/coverage assertions.
    pub log: FaultLog,
}

impl FaultyTransport {
    pub fn new(inner: WireTransport, plan: FaultPlan) -> FaultyTransport {
        FaultyTransport {
            inner: Box::new(inner),
            plan,
            rng: Rng::new(plan.seed ^ 0xC4A0_5),
            held: None,
            ops: 0,
            dead: false,
            log: FaultLog::default(),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The wrapped transport. The fleet sweep's shutdown path needs to
    /// reach an OS socket hiding behind fault injection.
    pub fn inner(&self) -> &WireTransport {
        &self.inner
    }

    /// The transport hit its scheduled disconnect (every further op errors).
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Drain undelivered frames from the wrapped transport (see
    /// [`WireTransport::drain`]).
    pub fn drain(&mut self) -> usize {
        self.inner.drain()
    }

    /// Non-blocking receive under fault injection. A stall roll delays
    /// the observation (`Empty`) without losing the frame; the disconnect
    /// budget is only charged when a frame is actually taken (idle polls
    /// must not kill the transport), and a frame consumed on the dying op
    /// is torn away — exactly a mid-delivery disconnect.
    pub fn poll_recv(&mut self) -> Result<super::transport::PollRecv> {
        use super::transport::PollRecv;
        if self.dead {
            return Ok(PollRecv::Closed);
        }
        if self.roll(self.plan.stall_rate) {
            self.log.stalled += 1;
            return Ok(PollRecv::Empty);
        }
        match self.inner.poll_recv()? {
            PollRecv::Frame(f, o) => {
                if self.count_op() {
                    return Ok(PollRecv::Closed);
                }
                Ok(PollRecv::Frame(f, o))
            }
            other => Ok(other),
        }
    }

    fn roll(&mut self, rate: f64) -> bool {
        rate > 0.0 && self.rng.f64() < rate
    }

    /// One more op against the disconnect budget; true = the transport
    /// dies ON this op.
    fn count_op(&mut self) -> bool {
        if self.dead {
            return false;
        }
        self.ops += 1;
        match self.plan.disconnect_after {
            Some(n) if self.ops > n => {
                self.dead = true;
                self.log.disconnected = true;
                true
            }
            _ => false,
        }
    }

    fn dead_err() -> anyhow::Error {
        anyhow::anyhow!("fault: transport disconnected by plan")
    }
}

impl Transport for FaultyTransport {
    fn send(&mut self, frame: &[u8]) -> Result<TransferOutcome> {
        if self.dead {
            return Err(Self::dead_err());
        }
        if self.count_op() {
            // Mid-frame disconnect: a partial prefix escapes, then the
            // connection is gone.
            if frame.len() > 1 {
                let cut = 1 + self.rng.below(frame.len() - 1);
                let _ = self.inner.send(&frame[..cut]);
            }
            return Err(Self::dead_err());
        }
        let mut out = frame.to_vec();
        if self.roll(self.plan.corrupt_rate) {
            let bit = self.rng.below(out.len() * 8);
            out[bit / 8] ^= 1 << (bit % 8);
            self.log.corrupted += 1;
        }
        if self.roll(self.plan.truncate_rate) && out.len() > 1 {
            out.truncate(1 + self.rng.below(out.len() - 1));
            self.log.truncated += 1;
        }
        if self.roll(self.plan.reorder_rate) && self.held.is_none() {
            // Hold this frame back; it rides behind the next send.
            self.log.reordered += 1;
            self.held = Some(out);
            // The caller is told the frame left (that is the fault).
            return Ok(TransferOutcome {
                latency_s: 0.0,
                attempts: 1,
                outage: false,
                payload_bytes: frame.len() as u64,
            });
        }
        let outcome = self.inner.send(&out)?;
        if self.roll(self.plan.duplicate_rate) {
            self.log.duplicated += 1;
            self.inner.send(&out)?;
        }
        if let Some(late) = self.held.take() {
            self.inner.send(&late)?;
        }
        Ok(outcome)
    }

    fn recv(&mut self) -> Result<(Vec<u8>, TransferOutcome)> {
        if self.dead || self.count_op() {
            return Err(Self::dead_err());
        }
        if self.roll(self.plan.stall_rate) {
            // A stalled peer surfaces as the transport deadline expiring —
            // the typed error, without actually sleeping the test.
            self.log.stalled += 1;
            return Err(WireError::Timeout.into());
        }
        self.inner.recv()
    }

    fn recv_eof(&mut self) -> Result<Option<(Vec<u8>, TransferOutcome)>> {
        if self.dead || self.count_op() {
            return Err(Self::dead_err());
        }
        if self.roll(self.plan.stall_rate) {
            self.log.stalled += 1;
            return Err(WireError::Timeout.into());
        }
        self.inner.recv_eof()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::frame::{self, FrameKind};
    use crate::wire::transport::Loopback;

    fn faulty_pair(plan: FaultPlan) -> (FaultyTransport, Loopback) {
        let (a, b) = Loopback::pair();
        (FaultyTransport::new(WireTransport::Loopback(a), plan), b)
    }

    #[test]
    fn clean_plan_is_transparent() {
        let (mut tx, mut rx) = faulty_pair(FaultPlan::clean(1));
        let f = frame::encode_frame(FrameKind::Payload, b"hello");
        for _ in 0..50 {
            tx.send(&f).unwrap();
            let (got, _) = rx.recv().unwrap();
            assert_eq!(got, f);
        }
        assert_eq!(tx.log, FaultLog::default());
    }

    #[test]
    fn corruption_is_always_caught_by_the_frame_crc() {
        let (mut tx, mut rx) = faulty_pair(FaultPlan::corrupt(7, 1.0));
        let f = frame::encode_frame(FrameKind::Payload, &[5u8; 200]);
        for _ in 0..30 {
            tx.send(&f).unwrap();
            let (got, _) = rx.recv().unwrap();
            assert!(frame::decode_frame(&got).is_err(), "flipped bit must be typed");
        }
        assert_eq!(tx.log.corrupted, 30);
    }

    #[test]
    fn truncation_is_always_caught() {
        let (mut tx, mut rx) = faulty_pair(FaultPlan::truncate(9, 1.0));
        let f = frame::encode_frame(FrameKind::Reply, &[1u8; 64]);
        for _ in 0..30 {
            tx.send(&f).unwrap();
            let (got, _) = rx.recv().unwrap();
            assert!(got.len() < f.len());
            assert!(frame::decode_frame(&got).is_err());
        }
    }

    #[test]
    fn duplication_delivers_the_frame_twice() {
        let (mut tx, mut rx) = faulty_pair(FaultPlan::duplicate(11, 1.0));
        let f = frame::encode_frame(FrameKind::Payload, b"dup");
        tx.send(&f).unwrap();
        assert_eq!(rx.recv().unwrap().0, f);
        assert_eq!(rx.recv().unwrap().0, f, "duplicate must follow");
        assert_eq!(tx.log.duplicated, 1);
    }

    #[test]
    fn reordering_swaps_consecutive_frames() {
        let (mut tx, mut rx) = faulty_pair(FaultPlan::reorder(13, 1.0));
        let a = frame::encode_frame(FrameKind::Payload, b"first");
        let b = frame::encode_frame(FrameKind::Payload, b"second");
        tx.send(&a).unwrap();
        tx.send(&b).unwrap();
        assert_eq!(rx.recv().unwrap().0, b, "second frame overtakes");
        assert_eq!(rx.recv().unwrap().0, a, "held frame follows");
        assert!(tx.log.reordered >= 1);
    }

    #[test]
    fn stall_is_a_typed_timeout() {
        let (mut tx, _rx) = faulty_pair(FaultPlan::stall(17, 1.0));
        let err = tx.recv().unwrap_err();
        assert_eq!(err.downcast_ref::<WireError>(), Some(&WireError::Timeout));
        assert_eq!(tx.log.stalled, 1);
    }

    #[test]
    fn disconnect_kills_the_transport_mid_frame() {
        let (mut tx, mut rx) = faulty_pair(FaultPlan::disconnect(19, 2));
        let f = frame::encode_frame(FrameKind::Payload, &[3u8; 100]);
        tx.send(&f).unwrap();
        tx.send(&f).unwrap();
        // third op crosses the budget: dies, possibly leaking a partial
        assert!(tx.send(&f).is_err());
        assert!(tx.is_dead());
        assert!(tx.send(&f).is_err(), "dead transport stays dead");
        assert!(tx.recv().is_err());
        // the two clean frames arrived; anything after is partial garbage
        assert_eq!(rx.recv().unwrap().0, f);
        assert_eq!(rx.recv().unwrap().0, f);
        if let Ok(Some((partial, _))) = rx.recv_eof() {
            assert!(frame::decode_frame(&partial).is_err(), "partial frame must be typed");
        }
    }

    #[test]
    fn same_seed_same_faults() {
        let plan = FaultPlan::from_seed(0xABCD);
        let run = || {
            let (mut tx, mut rx) = faulty_pair(plan);
            let f = frame::encode_frame(FrameKind::Payload, &[8u8; 128]);
            let mut delivered = Vec::new();
            for _ in 0..40 {
                if tx.send(&f).is_err() {
                    break;
                }
                while let Some(got) = rx.try_recv() {
                    delivered.push(got);
                }
            }
            (tx.log, delivered)
        };
        let (log_a, frames_a) = run();
        let (log_b, frames_b) = run();
        assert_eq!(log_a, log_b, "fault log must be deterministic");
        assert_eq!(frames_a, frames_b, "delivered byte streams must be identical");
        assert!(log_a.total() > 0, "a from_seed plan at this seed must inject something");
    }

    #[test]
    fn sweep_covers_every_fault_class() {
        // ensure FaultPlan::from_seed actually exercises each class over
        // a modest seed range — the property sweep depends on it
        let mut agg = FaultLog::default();
        for seed in 0..64u64 {
            let (mut tx, mut rx) = faulty_pair(FaultPlan::from_seed(seed));
            let f = frame::encode_frame(FrameKind::Payload, &[2u8; 96]);
            for _ in 0..20 {
                if tx.send(&f).is_err() {
                    break;
                }
                while rx.try_recv().is_some() {}
                // feed the faulty side so its recv path (stall rolls)
                // never blocks on an empty queue
                rx.send(&f).unwrap();
                if tx.recv_eof().is_err() && tx.is_dead() {
                    break;
                }
            }
            agg.corrupted += tx.log.corrupted;
            agg.truncated += tx.log.truncated;
            agg.duplicated += tx.log.duplicated;
            agg.reordered += tx.log.reordered;
            agg.stalled += tx.log.stalled;
            agg.disconnected |= tx.log.disconnected;
        }
        assert!(agg.corrupted > 0, "sweep must corrupt");
        assert!(agg.truncated > 0, "sweep must truncate");
        assert!(agg.duplicated > 0, "sweep must duplicate");
        assert!(agg.reordered > 0, "sweep must reorder");
        assert!(agg.stalled > 0, "sweep must stall");
        assert!(agg.disconnected, "sweep must disconnect");
    }

    #[test]
    fn correlated_outage_is_deterministic() {
        let plan = CorrelatedOutage::new(0xC0DE, 0.3, 40, 16);
        for conn in 0..200u64 {
            assert_eq!(plan.hits(conn), plan.hits(conn));
            assert_eq!(plan.plan_for(conn), plan.plan_for(conn));
            // membership and the derived plan must agree
            assert_eq!(plan.hits(conn), plan.plan_for(conn).disconnect_after.is_some());
        }
    }

    #[test]
    fn correlated_outage_cohort_matches_the_fraction() {
        let plan = CorrelatedOutage::new(7, 0.3, 100, 32);
        let hit = (0..2000u64).filter(|&c| plan.hits(c)).count();
        let frac = hit as f64 / 2000.0;
        assert!(
            (0.25..=0.35).contains(&frac),
            "cohort fraction {frac} strays from the requested 0.3"
        );
    }

    #[test]
    fn correlated_outage_confines_failures_to_the_window() {
        let plan = CorrelatedOutage::new(99, 0.5, 100, 32);
        let mut in_cohort = 0;
        for conn in 0..500u64 {
            let fp = plan.plan_for(conn);
            match fp.disconnect_after {
                Some(at) => {
                    in_cohort += 1;
                    assert!(
                        (100..132).contains(&at),
                        "conn {conn} dies at op {at}, outside the [100, 132) window"
                    );
                    // cohort members fail by disconnect ONLY — no
                    // uncorrelated frame-level noise rides along
                    assert_eq!(fp.corrupt_rate, 0.0);
                    assert_eq!(fp.stall_rate, 0.0);
                }
                None => assert_eq!(fp, FaultPlan::clean(plan.seed ^ conn)),
            }
        }
        assert!(in_cohort > 150, "half the fleet should be in the cohort");
    }

    #[test]
    fn correlated_outage_different_seeds_differ() {
        let a = CorrelatedOutage::new(1, 0.3, 50, 16);
        let b = CorrelatedOutage::new(2, 0.3, 50, 16);
        let cohort = |p: &CorrelatedOutage| (0..300u64).filter(|&c| p.hits(c)).collect::<Vec<_>>();
        assert_ne!(cohort(&a), cohort(&b), "seeds must decorrelate the cohorts");
    }

    #[test]
    fn correlated_outage_drives_a_faulty_transport_down_in_window() {
        let plan = CorrelatedOutage::new(0xFEED, 1.0, 3, 4);
        let fp = plan.plan_for(42);
        let at = fp.disconnect_after.expect("fraction 1.0 puts everyone in the cohort");
        let (mut tx, _rx) = faulty_pair(fp);
        let f = frame::encode_frame(FrameKind::Payload, b"storm");
        let mut ok = 0u64;
        loop {
            if tx.send(&f).is_err() {
                break;
            }
            ok += 1;
            assert!(ok < 64, "transport must die at its scheduled op");
        }
        assert_eq!(ok, at, "link survives exactly its scheduled ops then dies");
        assert!(tx.is_dead());
    }
}
