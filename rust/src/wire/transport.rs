//! Frame movers: the [`Transport`] trait and its three implementations —
//! the seeded wireless link simulator (charged with **actual encoded
//! frame lengths**), a lossless in-memory loopback, and a real TCP / unix
//! domain socket transport — plus the typed [`EdgePort`] / [`CloudPort`]
//! endpoints every driver (blocking pipeline, serve loop, cross-process
//! edge client) goes through. This is the single home of the
//! uplink/downlink transfer-charging logic that used to be duplicated
//! between `coordinator::pipeline` and `coordinator::serve_loop`.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::channel::{LinkSim, TransferOutcome};
use crate::coordinator::protocol::{CloudReply, SplitPayload};

use super::codec;
use super::frame::{self, WireError, HEADER_BYTES};

/// Moves whole frames between the edge and cloud halves of a deployment.
/// Sans-IO-friendly: implementations either simulate the link (charging
/// latency per byte actually framed), shuttle buffers in memory, or do
/// real socket IO — the drivers cannot tell the difference.
pub trait Transport {
    /// Deliver one encoded frame to the peer; returns the transfer
    /// accounting (simulated link events, or measured wall time).
    fn send(&mut self, frame: &[u8]) -> Result<TransferOutcome>;

    /// Next frame from the peer, with its transfer accounting. Errors on
    /// timeout, truncation mid-frame, or a closed peer.
    fn recv(&mut self) -> Result<(Vec<u8>, TransferOutcome)>;

    /// Like [`recv`](Transport::recv), but a clean peer shutdown at a
    /// frame boundary yields `Ok(None)` (the cloud serve loop's exit).
    fn recv_eof(&mut self) -> Result<Option<(Vec<u8>, TransferOutcome)>> {
        self.recv().map(Some)
    }
}

fn lossless(bytes: u64) -> TransferOutcome {
    TransferOutcome { latency_s: 0.0, attempts: 1, outage: false, payload_bytes: bytes }
}

/// Result of a non-blocking receive sweep ([`WireTransport::poll_recv`]):
/// the fleet scheduler polls thousands of in-process connections from one
/// thread, so "no frame yet" must be distinguishable from "peer gone".
#[derive(Debug)]
pub enum PollRecv {
    /// One whole frame was waiting, with its transfer accounting.
    Frame(Vec<u8>, TransferOutcome),
    /// Nothing queued right now; poll again later.
    Empty,
    /// The peer hung up (clean close or transport death).
    Closed,
}

/// Lossless, zero-latency in-memory transport half. [`Loopback::pair`]
/// yields two connected halves; frames sent on one side arrive on the
/// other in order. Channel-backed, so the two halves may live on
/// different threads.
pub struct Loopback {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    /// recv deadline — a protocol bug fails loudly instead of hanging.
    pub timeout: Duration,
}

impl Loopback {
    pub fn pair() -> (Loopback, Loopback) {
        let (atx, brx) = channel();
        let (btx, arx) = channel();
        let timeout = Duration::from_secs(30);
        (Loopback { tx: atx, rx: arx, timeout }, Loopback { tx: btx, rx: brx, timeout })
    }

    /// Non-blocking receive: the next queued frame, if one is already
    /// waiting. Used by queue draining and the fault-injection tests.
    pub fn try_recv(&mut self) -> Option<Vec<u8>> {
        self.rx.try_recv().ok()
    }

    /// Non-blocking receive that distinguishes an empty queue from a
    /// closed peer (the fleet scheduler's connection sweep).
    pub fn poll_recv(&mut self) -> PollRecv {
        match self.rx.try_recv() {
            Ok(f) => {
                let o = lossless(f.len() as u64);
                PollRecv::Frame(f, o)
            }
            Err(std::sync::mpsc::TryRecvError::Empty) => PollRecv::Empty,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => PollRecv::Closed,
        }
    }

    /// Discard every frame already queued; returns how many were
    /// dropped. Resynchronization point after a protocol desync (e.g. a
    /// duplicated or reordered frame was detected): the stale backlog is
    /// thrown away instead of being misapplied.
    pub fn drain(&mut self) -> usize {
        let mut n = 0;
        while self.try_recv().is_some() {
            n += 1;
        }
        n
    }
}

impl Transport for Loopback {
    fn send(&mut self, frame: &[u8]) -> Result<TransferOutcome> {
        self.tx
            .send(frame.to_vec())
            .map_err(|_| anyhow::anyhow!("loopback: peer closed"))?;
        Ok(lossless(frame.len() as u64))
    }

    fn recv(&mut self) -> Result<(Vec<u8>, TransferOutcome)> {
        self.recv_eof()?.ok_or_else(|| anyhow::anyhow!("loopback: peer closed"))
    }

    fn recv_eof(&mut self) -> Result<Option<(Vec<u8>, TransferOutcome)>> {
        match self.rx.recv_timeout(self.timeout) {
            Ok(f) => {
                let o = lossless(f.len() as u64);
                Ok(Some((f, o)))
            }
            Err(RecvTimeoutError::Disconnected) => Ok(None),
            Err(RecvTimeoutError::Timeout) => {
                anyhow::bail!("loopback: no frame within {:?} (protocol stall)", self.timeout)
            }
        }
    }
}

/// The edge half of a simulated wireless duplex: a lossless loopback
/// whose transfers are charged through a seeded [`LinkSim`] with the
/// **actual encoded frame length** in each direction. One `LinkSim`
/// serves both directions (exactly as the drivers always charged it);
/// the cloud half is a plain free loopback so nothing is double-charged.
pub struct LinkTransport {
    pub link: LinkSim,
    io: Loopback,
}

impl LinkTransport {
    /// Build the duplex: (edge half, cloud half).
    pub fn duplex(link: LinkSim) -> (LinkTransport, Loopback) {
        let (edge_io, cloud_io) = Loopback::pair();
        (LinkTransport { link, io: edge_io }, cloud_io)
    }

    /// Discard queued inbound frames (see [`Loopback::drain`]). The
    /// dropped frames are not charged to the link — they were already
    /// charged when sent.
    pub fn drain(&mut self) -> usize {
        self.io.drain()
    }

    /// Non-blocking receive; a frame that arrives is charged through the
    /// link like any other transfer (the charge rides the frame, not the
    /// empty polls).
    pub fn poll_recv(&mut self) -> PollRecv {
        match self.io.poll_recv() {
            PollRecv::Frame(f, _) => {
                let out = self.link.transfer(f.len() as u64);
                PollRecv::Frame(f, out)
            }
            other => other,
        }
    }
}

impl Transport for LinkTransport {
    fn send(&mut self, frame: &[u8]) -> Result<TransferOutcome> {
        let out = self.link.transfer(frame.len() as u64);
        self.io.send(frame)?;
        Ok(out)
    }

    fn recv(&mut self) -> Result<(Vec<u8>, TransferOutcome)> {
        let (f, _) = self.io.recv()?;
        let out = self.link.transfer(f.len() as u64);
        Ok((f, out))
    }
}

enum SocketStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Read for SocketStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.read(buf),
            SocketStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for SocketStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            SocketStream::Tcp(s) => s.write(buf),
            SocketStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            SocketStream::Tcp(s) => s.flush(),
            SocketStream::Unix(s) => s.flush(),
        }
    }
}

/// Real byte transport over TCP (`host:port`) or a unix domain socket
/// (`unix:/path/to.sock`). Outcomes report measured wall time; frames are
/// length-delimited by their own header, so one `recv` reads exactly one
/// frame.
///
/// Attribution caveat: `send` measures only the local buffered write
/// (near-zero once the kernel accepts the frame), so over a real socket
/// most of a round trip's transit time is observed by the blocking
/// `recv` — per-step uplink/downlink SPLITS are approximate
/// cross-process (the totals are right; `EdgeClient` additionally
/// subtracts the server's self-reported compute time from the recv
/// wall time). A byte-accurate split would need application-level acks.
pub struct SocketTransport {
    stream: SocketStream,
}

/// Default socket read/write deadline. A peer that stalls mid-frame past
/// this surfaces as a typed [`WireError::Timeout`] instead of hanging
/// `recv` forever (mirrors [`Loopback`]'s 30 s protocol-stall guard).
pub const SOCKET_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Map a socket IO failure to its typed form: a deadline expiry becomes
/// [`WireError::Timeout`]; everything else stays an IO error.
fn map_io(e: std::io::Error) -> anyhow::Error {
    use std::io::ErrorKind;
    if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
        WireError::Timeout.into()
    } else {
        e.into()
    }
}

impl SocketTransport {
    /// Connect once. `unix:`-prefixed addresses use a unix domain socket,
    /// anything else is `host:port` TCP. Read/write deadlines default to
    /// [`SOCKET_IO_TIMEOUT`].
    pub fn connect(addr: &str) -> Result<SocketTransport> {
        let stream = if let Some(path) = addr.strip_prefix("unix:") {
            SocketStream::Unix(UnixStream::connect(path)?)
        } else {
            let s = TcpStream::connect(addr)?;
            let _ = s.set_nodelay(true);
            SocketStream::Tcp(s)
        };
        let t = SocketTransport { stream };
        t.set_io_timeout(Some(SOCKET_IO_TIMEOUT))?;
        Ok(t)
    }

    /// Adjust both read and write deadlines (`None` = block forever).
    /// Stalls past the deadline surface as [`WireError::Timeout`].
    pub fn set_io_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        match &self.stream {
            SocketStream::Tcp(s) => {
                s.set_read_timeout(timeout)?;
                s.set_write_timeout(timeout)?;
            }
            SocketStream::Unix(s) => {
                s.set_read_timeout(timeout)?;
                s.set_write_timeout(timeout)?;
            }
        }
        Ok(())
    }

    /// Clone the underlying OS socket so reads and writes can live on
    /// different threads (the fleet server's reader-thread / scheduler
    /// split: one half blocks in `recv_eof`, the other writes replies).
    /// Both halves refer to the same connection; closing either end of
    /// the peer tears down both.
    pub fn try_clone(&self) -> Result<SocketTransport> {
        let stream = match &self.stream {
            SocketStream::Tcp(s) => SocketStream::Tcp(s.try_clone()?),
            SocketStream::Unix(s) => SocketStream::Unix(s.try_clone()?),
        };
        Ok(SocketTransport { stream })
    }

    /// Shut the OS socket down in both directions. Every clone of the
    /// stream sees it immediately: a reader thread blocked in `recv_eof`
    /// on another clone returns EOF *now* instead of at its own I/O
    /// deadline — the teeth of the fleet server's idle/half-open sweep.
    pub fn shutdown(&self) {
        let _ = match &self.stream {
            SocketStream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            SocketStream::Unix(s) => s.shutdown(std::net::Shutdown::Both),
        };
    }

    /// Connect with retries. Only errors that mean "the peer is still
    /// binding" are retried (connection refused; unix socket file not
    /// created yet); a bad address or missing directory fails instantly
    /// instead of burning the whole budget on a typo.
    pub fn connect_retry(addr: &str, budget: Duration) -> Result<SocketTransport> {
        use std::io::ErrorKind;
        let t0 = Instant::now();
        loop {
            match Self::connect(addr) {
                Ok(t) => return Ok(t),
                Err(e) => {
                    let transient = e
                        .downcast_ref::<std::io::Error>()
                        .is_some_and(|io| {
                            matches!(io.kind(), ErrorKind::ConnectionRefused | ErrorKind::NotFound)
                        });
                    if !transient || t0.elapsed() >= budget {
                        return Err(
                            e.context(format!("connecting to {addr} (waited {:?})", t0.elapsed()))
                        );
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
            }
        }
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, frame: &[u8]) -> Result<TransferOutcome> {
        let t0 = Instant::now();
        self.stream.write_all(frame).map_err(map_io)?;
        self.stream.flush().map_err(map_io)?;
        Ok(TransferOutcome {
            latency_s: t0.elapsed().as_secs_f64(),
            attempts: 1,
            outage: false,
            payload_bytes: frame.len() as u64,
        })
    }

    fn recv(&mut self) -> Result<(Vec<u8>, TransferOutcome)> {
        self.recv_eof()?
            .ok_or_else(|| anyhow::anyhow!("socket: connection closed by peer"))
    }

    fn recv_eof(&mut self) -> Result<Option<(Vec<u8>, TransferOutcome)>> {
        let t0 = Instant::now();
        let mut header = [0u8; HEADER_BYTES];
        let mut got = 0usize;
        while got < header.len() {
            let n = self.stream.read(&mut header[got..]).map_err(map_io)?;
            if n == 0 {
                if got == 0 {
                    return Ok(None); // clean close at a frame boundary
                }
                anyhow::bail!(WireError::Truncated { need: HEADER_BYTES, have: got });
            }
            got += n;
        }
        // Validate the preamble before trusting its length field.
        let (_kind, body_len) = frame::peek_header(&header)?;
        let mut frame_bytes = vec![0u8; HEADER_BYTES + body_len + 4];
        frame_bytes[..HEADER_BYTES].copy_from_slice(&header);
        self.stream.read_exact(&mut frame_bytes[HEADER_BYTES..]).map_err(map_io)?;
        let out = TransferOutcome {
            latency_s: t0.elapsed().as_secs_f64(),
            attempts: 1,
            outage: false,
            payload_bytes: frame_bytes.len() as u64,
        };
        Ok(Some((frame_bytes, out)))
    }
}

/// Frame-listener counterpart of [`SocketTransport::connect`].
pub enum WireListener {
    Tcp(TcpListener),
    Unix(UnixListener),
}

impl WireListener {
    pub fn bind(addr: &str) -> Result<WireListener> {
        if let Some(path) = addr.strip_prefix("unix:") {
            Self::clear_stale_socket(path)?;
            Ok(WireListener::Unix(UnixListener::bind(path)?))
        } else {
            Ok(WireListener::Tcp(TcpListener::bind(addr)?))
        }
    }

    /// Remove a leftover socket file from a dead server — and ONLY that.
    /// A non-socket file at the path is refused (never deleted), and a
    /// socket another server is still accepting on is reported as
    /// address-in-use instead of being yanked out from under it.
    fn clear_stale_socket(path: &str) -> Result<()> {
        use std::os::unix::fs::FileTypeExt;
        match std::fs::metadata(path) {
            Err(_) => Ok(()), // nothing there: bind will create it
            Ok(meta) if !meta.file_type().is_socket() => {
                anyhow::bail!("refusing to bind over non-socket file {path}")
            }
            Ok(_) => {
                if UnixStream::connect(path).is_ok() {
                    anyhow::bail!("socket {path} is in use by a live server");
                }
                std::fs::remove_file(path)?; // stale: no one is accepting
                Ok(())
            }
        }
    }

    /// Block for one connection.
    pub fn accept(&self) -> Result<SocketTransport> {
        let stream = match self {
            WireListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                SocketStream::Tcp(s)
            }
            WireListener::Unix(l) => {
                let (s, _) = l.accept()?;
                SocketStream::Unix(s)
            }
        };
        Ok(SocketTransport { stream })
    }
}

/// Concrete transport storage for endpoints (enum dispatch keeps the
/// `LinkSim` reachable for stats without downcasting).
pub enum WireTransport {
    /// Simulated wireless duplex (edge half).
    Sim(LinkTransport),
    /// Lossless in-memory loopback half.
    Loopback(Loopback),
    /// Real socket.
    Socket(SocketTransport),
    /// Any of the above wrapped in seeded fault injection (chaos tests).
    Faulty(super::fault::FaultyTransport),
}

impl WireTransport {
    /// The link simulator behind this transport, when it is sim-backed.
    pub fn link(&self) -> Option<&LinkSim> {
        match self {
            WireTransport::Sim(t) => Some(&t.link),
            _ => None,
        }
    }

    /// Discard inbound frames already queued (loopback-backed transports
    /// only; a socket has no non-blocking queue to drain — returns 0).
    /// Resynchronization point after a detected protocol desync.
    pub fn drain(&mut self) -> usize {
        match self {
            WireTransport::Sim(t) => t.drain(),
            WireTransport::Loopback(t) => t.drain(),
            WireTransport::Socket(_) => 0,
            WireTransport::Faulty(t) => t.drain(),
        }
    }

    /// Tear the underlying OS connection down, if there is one. Loopback
    /// and sim transports close by drop (their channel halves disconnect);
    /// a socket needs an explicit `shutdown` so clones held by a blocked
    /// reader thread unblock immediately. Fault-wrapped transports
    /// delegate to whatever they wrap.
    pub fn shutdown(&self) {
        match self {
            WireTransport::Socket(t) => t.shutdown(),
            WireTransport::Faulty(t) => {
                if let WireTransport::Socket(inner) = t.inner() {
                    inner.shutdown();
                }
            }
            WireTransport::Sim(_) | WireTransport::Loopback(_) => {}
        }
    }

    /// Non-blocking receive for the fleet scheduler's single-thread sweep
    /// over in-process connections. Sockets have no queue to poll —
    /// they are served by a blocking reader thread instead — so polling
    /// one is a driver bug and errors loudly.
    pub fn poll_recv(&mut self) -> Result<PollRecv> {
        match self {
            WireTransport::Sim(t) => Ok(t.poll_recv()),
            WireTransport::Loopback(t) => Ok(t.poll_recv()),
            WireTransport::Socket(_) => {
                anyhow::bail!("socket transports are read by a blocking reader thread, not polled")
            }
            WireTransport::Faulty(t) => t.poll_recv(),
        }
    }
}

impl Transport for WireTransport {
    fn send(&mut self, frame: &[u8]) -> Result<TransferOutcome> {
        match self {
            WireTransport::Sim(t) => t.send(frame),
            WireTransport::Loopback(t) => t.send(frame),
            WireTransport::Socket(t) => t.send(frame),
            WireTransport::Faulty(t) => t.send(frame),
        }
    }

    fn recv(&mut self) -> Result<(Vec<u8>, TransferOutcome)> {
        match self {
            WireTransport::Sim(t) => t.recv(),
            WireTransport::Loopback(t) => t.recv(),
            WireTransport::Socket(t) => t.recv(),
            WireTransport::Faulty(t) => t.recv(),
        }
    }

    fn recv_eof(&mut self) -> Result<Option<(Vec<u8>, TransferOutcome)>> {
        match self {
            WireTransport::Sim(t) => t.recv_eof(),
            WireTransport::Loopback(t) => t.recv_eof(),
            WireTransport::Socket(t) => t.recv_eof(),
            WireTransport::Faulty(t) => t.recv_eof(),
        }
    }
}

/// Edge side of the wire: typed payload-out / reply-in over any
/// transport. Every driver's uplink/downlink charging goes through here.
pub struct EdgePort {
    pub transport: WireTransport,
}

impl EdgePort {
    pub fn new(transport: WireTransport) -> EdgePort {
        EdgePort { transport }
    }

    pub fn link(&self) -> Option<&LinkSim> {
        self.transport.link()
    }

    /// Encode, frame and transmit one payload; the returned outcome is
    /// charged with the actual encoded frame length.
    pub fn send_payload(&mut self, p: &SplitPayload) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_payload_frame(p);
        self.transport.send(&frame_bytes)
    }

    /// Encode, frame and transmit one control-plane reconfiguration.
    /// Control traffic rides the same wire as the data plane, so it is
    /// charged real bytes (and real link events) like any frame.
    pub fn send_reconfig(&mut self, rc: &crate::adapt::Reconfig) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_reconfig_frame(rc);
        self.transport.send(&frame_bytes)
    }

    /// Receive and strictly decode the next reply frame. Returns the
    /// reply, the server's compute seconds (from the frame's timing
    /// prefix), and the downlink outcome. An in-band `Error` frame from
    /// the cloud surfaces as a typed [`WireError::Rejected`].
    pub fn recv_reply(&mut self) -> Result<(CloudReply, f64, TransferOutcome)> {
        let (frame_bytes, down) = self.transport.recv()?;
        if let Some(rej) = in_band_rejection(&frame_bytes) {
            return Err(rej.into());
        }
        let (reply, server_s) = codec::decode_reply_frame(&frame_bytes)?;
        Ok((reply, server_s, down))
    }

    /// Non-blocking counterpart of [`recv_reply`](EdgePort::recv_reply)
    /// for interleaved drivers (the fleet bench runs hundreds of sessions
    /// on one thread): `Ok(None)` when no frame is queued yet, a typed
    /// [`WireError::Rejected`] for an in-band `Error` frame, and a closed
    /// peer surfaces as an error (the driver's reconnect path).
    pub fn try_recv_reply(&mut self) -> Result<Option<(CloudReply, f64, TransferOutcome)>> {
        match self.transport.poll_recv()? {
            PollRecv::Empty => Ok(None),
            PollRecv::Closed => anyhow::bail!("edge port: peer closed"),
            PollRecv::Frame(frame_bytes, down) => {
                if let Some(rej) = in_band_rejection(&frame_bytes) {
                    return Err(rej.into());
                }
                let (reply, server_s) = codec::decode_reply_frame(&frame_bytes)?;
                Ok(Some((reply, server_s, down)))
            }
        }
    }

    /// Encode, frame and transmit one session-resumption announcement.
    pub fn send_resume(
        &mut self,
        rs: &crate::coordinator::protocol::Resume,
    ) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_resume_frame(rs);
        self.transport.send(&frame_bytes)
    }

    /// Receive and strictly decode the cloud's resume acknowledgement.
    /// An in-band `Error` frame surfaces as [`WireError::Rejected`].
    pub fn recv_resume_ack(
        &mut self,
    ) -> Result<(crate::coordinator::protocol::ResumeAck, TransferOutcome)> {
        let (frame_bytes, down) = self.transport.recv()?;
        if let Some(rej) = in_band_rejection(&frame_bytes) {
            return Err(rej.into());
        }
        let ack = codec::decode_resume_ack_frame(&frame_bytes)?;
        Ok((ack, down))
    }

    /// Encode, frame and transmit one prefix-cache probe. Probe traffic
    /// rides the same wire as the data plane and is charged real bytes.
    pub fn send_prefix_probe(
        &mut self,
        p: &crate::coordinator::protocol::PrefixProbe,
    ) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_prefix_probe_frame(p);
        self.transport.send(&frame_bytes)
    }

    /// Receive and strictly decode the cloud's prefix-probe answer.
    /// An in-band `Error` frame surfaces as [`WireError::Rejected`].
    pub fn recv_prefix_ack(
        &mut self,
    ) -> Result<(crate::coordinator::protocol::PrefixAck, TransferOutcome)> {
        let (frame_bytes, down) = self.transport.recv()?;
        if let Some(rej) = in_band_rejection(&frame_bytes) {
            return Err(rej.into());
        }
        let ack = codec::decode_prefix_ack_frame(&frame_bytes)?;
        Ok((ack, down))
    }
}

/// Decode an in-band `Error` frame into its typed rejection, if the
/// bytes are one. Any other frame (or garbage) returns `None` and is
/// left for the caller's strict decoder to classify.
fn in_band_rejection(frame_bytes: &[u8]) -> Option<WireError> {
    match frame::decode_frame(frame_bytes) {
        Ok((frame::FrameKind::Error, _)) => {
            let e = codec::decode_error_frame(frame_bytes).ok()?;
            Some(WireError::Rejected {
                code: e.code,
                request_id: e.request_id,
                message: e.message,
            })
        }
        _ => None,
    }
}

/// Cloud side of the wire: typed payload-in / reply-out.
pub struct CloudPort {
    pub transport: WireTransport,
}

impl CloudPort {
    pub fn new(transport: WireTransport) -> CloudPort {
        CloudPort { transport }
    }

    /// Receive and strictly decode the next payload frame.
    pub fn recv_payload(&mut self) -> Result<(SplitPayload, TransferOutcome)> {
        let (frame_bytes, out) = self.transport.recv()?;
        let p = codec::decode_payload_frame(&frame_bytes)?;
        Ok((p, out))
    }

    /// Receive and strictly decode the next reconfig (control) frame.
    pub fn recv_reconfig(&mut self) -> Result<(crate::adapt::Reconfig, TransferOutcome)> {
        let (frame_bytes, out) = self.transport.recv()?;
        let rc = codec::decode_reconfig_frame(&frame_bytes)?;
        Ok((rc, out))
    }

    /// Encode, frame and transmit one reply (+ server compute seconds).
    pub fn send_reply(&mut self, reply: &CloudReply, server_s: f64) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_reply_frame(reply, server_s);
        self.transport.send(&frame_bytes)
    }

    /// Encode, frame and transmit one resume acknowledgement.
    pub fn send_resume_ack(
        &mut self,
        ack: &crate::coordinator::protocol::ResumeAck,
    ) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_resume_ack_frame(ack);
        self.transport.send(&frame_bytes)
    }

    /// Encode, frame and transmit one in-band typed rejection.
    pub fn send_error(
        &mut self,
        e: &crate::coordinator::protocol::RejectFrame,
    ) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_error_frame(e);
        self.transport.send(&frame_bytes)
    }

    /// Receive and strictly decode the next prefix-cache probe frame.
    pub fn recv_prefix_probe(
        &mut self,
    ) -> Result<(crate::coordinator::protocol::PrefixProbe, TransferOutcome)> {
        let (frame_bytes, out) = self.transport.recv()?;
        let p = codec::decode_prefix_probe_frame(&frame_bytes)?;
        Ok((p, out))
    }

    /// Encode, frame and transmit one prefix-probe answer.
    pub fn send_prefix_ack(
        &mut self,
        ack: &crate::coordinator::protocol::PrefixAck,
    ) -> Result<TransferOutcome> {
        let frame_bytes = codec::encode_prefix_ack_frame(ack);
        self.transport.send(&frame_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChannelParams;

    #[test]
    fn loopback_moves_frames_in_order() {
        let (mut a, mut b) = Loopback::pair();
        a.send(b"one").unwrap();
        a.send(b"two").unwrap();
        assert_eq!(b.recv().unwrap().0, b"one");
        let (f, o) = b.recv().unwrap();
        assert_eq!(f, b"two");
        assert_eq!(o.payload_bytes, 3);
        assert_eq!(o.latency_s, 0.0);
        assert!(!o.outage);
    }

    #[test]
    fn loopback_reports_clean_close() {
        let (a, mut b) = Loopback::pair();
        drop(a);
        assert!(b.recv_eof().unwrap().is_none());
        assert!(b.recv().is_err());
    }

    #[test]
    fn link_transport_charges_actual_frame_lengths() {
        let link = LinkSim::new(ChannelParams::default(), 8e6, 7);
        let (mut edge, mut cloud) = LinkTransport::duplex(link);
        let up = edge.send(&[1u8; 1000]).unwrap();
        assert_eq!(up.payload_bytes, 1000);
        assert!(up.latency_s > 0.0, "simulated airtime must be charged");
        let (f, free) = cloud.recv().unwrap();
        assert_eq!(f.len(), 1000);
        assert_eq!(free.latency_s, 0.0, "cloud half must not double-charge");
        cloud.send(&[2u8; 64]).unwrap();
        let (f, down) = edge.recv().unwrap();
        assert_eq!(f.len(), 64);
        assert_eq!(down.payload_bytes, 64);
        assert!(down.latency_s > 0.0);
        assert_eq!(edge.link.total_bytes, 1064, "one LinkSim charges both directions");
    }

    #[test]
    fn socket_transport_roundtrip_over_uds() {
        let path = std::env::temp_dir().join(format!("splitserve-wire-test-{}.sock", std::process::id()));
        let addr = format!("unix:{}", path.display());
        let listener = WireListener::bind(&addr).unwrap();
        let frame_bytes = frame::encode_frame(frame::FrameKind::Payload, &[9u8; 300]);
        let sent = frame_bytes.clone();
        let handle = std::thread::spawn(move || {
            let mut server = listener.accept().unwrap();
            let (got, _) = server.recv().unwrap();
            server.send(&got).unwrap(); // echo
            // clean shutdown: drop closes the socket
            got
        });
        let mut client = SocketTransport::connect_retry(&addr, Duration::from_secs(5)).unwrap();
        client.send(&sent).unwrap();
        let (echoed, out) = client.recv().unwrap();
        assert_eq!(echoed, sent);
        assert_eq!(out.payload_bytes, sent.len() as u64);
        assert!(client.recv_eof().unwrap().is_none(), "server hangup is a clean EOF");
        assert_eq!(handle.join().unwrap(), sent);
        let _ = std::fs::remove_file(&path);
    }
}
