//! The real wire: versioned byte frames, a strict codec for the
//! edge↔cloud protocol structs, and pluggable frame transports.
//!
//! Before this module existed, `SplitPayload`/`CloudReply` crossed the
//! edge↔cloud boundary as in-memory structs and the link simulator was
//! charged with a *computed* `wire_bytes()` size. Now every transmission
//! is encoded to bytes ([`codec`]), wrapped in a CRC-protected versioned
//! frame ([`frame`]), moved by a [`Transport`] (simulated link, in-memory
//! loopback, or a real TCP/unix socket), and strictly decoded on the
//! other side — the bit-exact accounting is an **assertion**
//! (`encoded == wire_bytes()` at every encode in debug builds and in the
//! test suite), and the same deployment runs single-process or as real
//! `splitserve cloud` / `splitserve edge` processes over a socket.

pub mod codec;
pub mod fault;
pub mod frame;
pub mod transport;

pub use codec::{
    decode_error_frame, decode_migrate_frame, decode_payload_frame, decode_prefix_ack_frame,
    decode_prefix_probe_frame, decode_reconfig_frame, decode_reply_frame,
    decode_resume_ack_frame, decode_resume_frame,
    encode_error_frame, encode_migrate_frame, encode_payload_frame, encode_prefix_ack_frame,
    encode_prefix_probe_frame, encode_reconfig_frame, encode_reply_frame,
    encode_resume_ack_frame, encode_resume_frame, peek_payload_prefix, peek_reply_meta,
    PayloadPrefix, ReplyMeta, MIGRATE_OVERHEAD, PAYLOAD_OVERHEAD, PREFIX_OVERHEAD,
    RECONFIG_OVERHEAD, REPLY_OVERHEAD,
};
pub use fault::{CorrelatedOutage, FaultPlan, FaultyTransport};
pub use frame::{crc32, decode_frame, encode_frame, FrameKind, WireError, FRAME_OVERHEAD};
pub use transport::{
    CloudPort, EdgePort, LinkTransport, Loopback, PollRecv, SocketTransport, Transport,
    WireListener, WireTransport,
};
