//! Synthetic-corpus perplexity (WikiText2 / C4 analogs).
//!
//! The corpus is a seeded Markov chain over the synthetic vocabulary:
//! Zipfian unigram mass + a sparse bigram structure, which gives the
//! reference model a predictable-but-not-trivial stream. Perplexity deltas
//! under weight quantization exercise the same distortion pathway the
//! paper's Table 4 measures; absolute values are not comparable.

use anyhow::Result;

use super::runtime::EvalRuntime;
use crate::util::rng::{zipf_cdf, Rng};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corpus {
    /// "WikiText2-sim": stronger bigram structure (lower entropy).
    Wiki,
    /// "C4-sim": noisier mixture (higher entropy).
    C4,
}

/// Generate `n_tokens` of synthetic corpus. Deterministic per (corpus, seed).
pub fn generate_corpus(corpus: Corpus, vocab: usize, n_tokens: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed ^ 0xC04F ^ (corpus as u64) << 17);
    let cdf = zipf_cdf(vocab - 1, 1.2);
    let (p_bigram, n_successors) = match corpus {
        Corpus::Wiki => (0.75, 3),
        Corpus::C4 => (0.45, 6),
    };
    // sparse bigram table: each token has a few preferred successors
    let successors: Vec<Vec<u32>> = (0..vocab)
        .map(|t| {
            let mut r = rng.child(t as u64);
            (0..n_successors).map(|_| r.zipf(&cdf) as u32 + 1).collect()
        })
        .collect();
    let mut out = Vec::with_capacity(n_tokens);
    let mut prev = rng.zipf(&cdf) as u32 + 1;
    for _ in 0..n_tokens {
        let next = if rng.f64() < p_bigram {
            let s = &successors[prev as usize];
            s[rng.below(s.len())]
        } else {
            rng.zipf(&cdf) as u32 + 1
        };
        out.push(next);
        prev = next;
    }
    out
}

/// Model-coupled corpus: windows sampled FROM the full-precision
/// reference at a given temperature. An untrained synthetic model has no
/// predictive power over independent text (its corpus-perplexity is
/// ~vocab-size, flat under quantization); text the reference itself
/// speaks gives it genuinely low perplexity, and any weight distortion
/// (Table 4's quantized segments) raises it monotonically — the same
/// distortion pathway the paper measures. Wiki-sim uses a lower sampling
/// temperature than C4-sim, mirroring WikiText2's lower perplexity.
pub fn model_corpus(
    reference: &EvalRuntime,
    corpus: Corpus,
    n_windows: usize,
    seed: u64,
) -> Result<Vec<Vec<u32>>> {
    let cfg = reference.cfg();
    let temp = match corpus {
        Corpus::Wiki => 0.7,
        Corpus::C4 => 1.0,
    };
    let mut rng = Rng::new(seed ^ 0x9_C04F ^ ((corpus as u64) << 21));
    let cdf = zipf_cdf(cfg.vocab - 1, 1.1);
    let w = cfg.prefill_len;
    let seed_len = 4;
    (0..n_windows)
        .map(|_| {
            let mut window: Vec<u32> =
                (0..seed_len).map(|_| rng.zipf(&cdf) as u32 + 1).collect();
            let cont = reference.rollout(&window, w - seed_len, temp, &mut rng)?;
            window.extend(cont);
            Ok(window)
        })
        .collect()
}

/// Perplexity over pre-built windows.
pub fn perplexity_windows(model: &EvalRuntime, windows: &[Vec<u32>]) -> Result<f64> {
    anyhow::ensure!(!windows.is_empty());
    let mut total = 0f64;
    for w in windows {
        total += model.window_nll(w)?;
    }
    Ok((total / windows.len() as f64).exp())
}

/// Perplexity of `model` on a flat token stream, evaluated over
/// non-overlapping prefill-width windows (stride = window).
pub fn perplexity(model: &EvalRuntime, tokens: &[u32]) -> Result<f64> {
    let w = model.cfg().prefill_len;
    anyhow::ensure!(tokens.len() >= w, "corpus shorter than one window");
    let mut total_nll = 0f64;
    let mut n_windows = 0usize;
    for chunk in tokens.chunks_exact(w) {
        total_nll += model.window_nll(chunk)?;
        n_windows += 1;
    }
    Ok((total_nll / n_windows as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic_and_in_vocab() {
        let a = generate_corpus(Corpus::Wiki, 512, 1000, 3);
        let b = generate_corpus(Corpus::Wiki, 512, 1000, 3);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (1..512).contains(&(t as usize))));
        let c = generate_corpus(Corpus::C4, 512, 1000, 3);
        assert_ne!(a, c);
    }

    #[test]
    fn wiki_more_predictable_than_c4() {
        // bigram repeat rate is higher for Wiki (structure proxy)
        let repeat_rate = |toks: &[u32]| {
            let mut seen = std::collections::HashSet::new();
            let mut repeats = 0usize;
            for w in toks.windows(2) {
                if !seen.insert((w[0], w[1])) {
                    repeats += 1;
                }
            }
            repeats as f64 / toks.len() as f64
        };
        let wiki = generate_corpus(Corpus::Wiki, 512, 20_000, 5);
        let c4 = generate_corpus(Corpus::C4, 512, 20_000, 5);
        assert!(repeat_rate(&wiki) > repeat_rate(&c4));
    }
}
