//! EvalRuntime: a monolithic (full-stack) model instance with a pluggable
//! activation treatment at the residual-stream boundaries — the measuring
//! instrument behind Tables 2-6 and Fig. 4.
//!
//! Treatments:
//!   * `EveryLayer(mode)` — baseline methods quantize activations at every
//!     layer boundary (SmoothQuant/OmniQuant per-tensor, Atom per-token);
//!   * `SplitCompression` — "Ours": the TS + TAB-Q round-trip applied at
//!     the split layer ONLY (everything else full precision), exactly what
//!     the wire does in the serving pipeline;
//!   * `ClampAll{limit}` — the Fig. 4(a) probe: clamp |h| <= limit.

use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::protocol::{CompressedTensor, CompressionConfig};
use crate::model::{ModelConfig, ModelWeights};
use crate::quant::baselines::ActQuantMode;
use crate::runtime::{Engine, NodeRuntime};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum ActTreatment {
    None,
    EveryLayer(ActQuantMode),
    SplitCompression { split: usize, compression: CompressionConfig },
    ClampAll { limit: f32 },
}

pub struct EvalRuntime {
    pub node: NodeRuntime,
    pub treatment: ActTreatment,
}

fn log_softmax_at(logits: &[f32], vocab: usize, pos: usize, token: u32) -> f64 {
    let row = &logits[pos * vocab..(pos + 1) * vocab];
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let z: f64 = row.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (row[token as usize] as f64 - m) - z.ln()
}

impl EvalRuntime {
    /// Build over (possibly pre-quantized) weights, full layer stack.
    pub fn new(
        engine: Rc<Engine>,
        weights: Rc<ModelWeights>,
        treatment: ActTreatment,
    ) -> Result<EvalRuntime> {
        let n = weights.cfg.n_layers;
        let node = NodeRuntime::new(engine, weights, 0..n, true)?;
        Ok(EvalRuntime { node, treatment })
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.node.weights.cfg
    }

    fn hook(&self) -> impl FnMut(usize, &mut Vec<f32>) + '_ {
        let cfg = self.cfg().clone();
        let treatment = self.treatment;
        move |li: usize, h: &mut Vec<f32>| match treatment {
            ActTreatment::None => {}
            ActTreatment::EveryLayer(mode) => {
                let rows = h.len() / cfg.d_model;
                mode.apply(h, rows, cfg.d_model);
            }
            ActTreatment::SplitCompression { split, compression } => {
                // the hook runs AFTER layer li; the split-layer output is
                // what crosses the wire
                if li + 1 == split {
                    let rows = h.len() / cfg.d_model;
                    let packet = CompressedTensor::compress(h, rows, cfg.d_model, &compression);
                    *h = packet.decompress().expect("self-roundtrip");
                }
            }
            ActTreatment::ClampAll { limit } => {
                for v in h.iter_mut() {
                    *v = v.clamp(-limit, limit);
                }
            }
        }
    }

    /// Logits at every prefill position for (padded) `tokens`.
    pub fn logits_all(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        anyhow::ensure!(tokens.len() <= cfg.prefill_len, "sequence exceeds prefill width");
        let x = self.node.weights.embed_padded(tokens, cfg.prefill_len);
        let mut hook = self.hook();
        let (h, _) = self.node.prefill_with(&x, &mut hook)?;
        self.node.logits_prefill(&h)
    }

    /// Length-normalized log-likelihood of `cont` given `context`
    /// (the standard zero-shot multiple-choice scoring rule).
    pub fn choice_logprob(&self, context: &[u32], cont: &[u32]) -> Result<f64> {
        let cfg = self.cfg();
        let mut seq = context.to_vec();
        seq.extend_from_slice(cont);
        let logits = self.logits_all(&seq)?;
        let mut lp = 0f64;
        for (i, &tok) in cont.iter().enumerate() {
            let pos = context.len() + i - 1; // logits[pos] predicts token pos+1
            lp += log_softmax_at(&logits, cfg.vocab, pos, tok);
        }
        Ok(lp / cont.len() as f64)
    }

    /// Mean negative log-likelihood of a token window (for perplexity).
    pub fn window_nll(&self, window: &[u32]) -> Result<f64> {
        let cfg = self.cfg();
        let logits = self.logits_all(window)?;
        let mut nll = 0f64;
        for pos in 0..window.len() - 1 {
            nll -= log_softmax_at(&logits, cfg.vocab, pos, window[pos + 1]);
        }
        Ok(nll / (window.len() - 1) as f64)
    }

    /// Temperature rollout used to BUILD suites (always run on the FP
    /// reference instance; treatment is applied like everywhere else,
    /// which for the reference is `None`).
    pub fn rollout(&self, context: &[u32], len: usize, temp: f64, rng: &mut Rng) -> Result<Vec<u32>> {
        let cfg = self.cfg();
        let mut seq = context.to_vec();
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            anyhow::ensure!(seq.len() < cfg.prefill_len, "rollout exceeds prefill width");
            let logits = self.logits_all(&seq)?;
            let pos = seq.len() - 1;
            let row = &logits[pos * cfg.vocab..(pos + 1) * cfg.vocab];
            let tok = if temp <= 0.0 {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as u32
            } else {
                // softmax sample at temperature
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
                let ws: Vec<f64> =
                    row.iter().map(|&x| (((x as f64) - m) / temp).exp()).collect();
                let z: f64 = ws.iter().sum();
                let mut u = rng.f64() * z;
                let mut pick = 0usize;
                for (i, w) in ws.iter().enumerate() {
                    u -= w;
                    if u <= 0.0 {
                        pick = i;
                        break;
                    }
                }
                pick as u32
            };
            // avoid EOS=0 inside suite continuations
            let tok = if tok == 0 { 1 } else { tok };
            out.push(tok);
            seq.push(tok);
        }
        Ok(out)
    }

    /// Capture the hidden state right after `layer` for `tokens`
    /// (Fig. 4(b) magnitude-distribution probe).
    pub fn capture_hidden(&self, tokens: &[u32], layer: usize) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        let x = self.node.weights.embed_padded(tokens, cfg.prefill_len);
        let mut captured: Vec<f32> = Vec::new();
        let used = tokens.len() * cfg.d_model;
        let mut base_hook = self.hook();
        let mut hook = |li: usize, h: &mut Vec<f32>| {
            base_hook(li, h);
            if li == layer {
                captured = h[..used].to_vec();
            }
        };
        let _ = self.node.prefill_with(&x, &mut hook)?;
        anyhow::ensure!(!captured.is_empty(), "layer {layer} not in range");
        Ok(captured)
    }
}
