//! Synthetic zero-shot multiple-choice suites (HellaSwag / PIQA / ARC-e/c /
//! BoolQ / Winogrande analogs — DESIGN.md §1 substitution).
//!
//! Construction: the *correct* continuation of each item is a temperature
//! rollout from the full-precision reference model, so a faithful model
//! ranks it high but not always first (temperature sets the noise floor);
//! distractors are either random token strings ("easy") or rollouts from a
//! perturbed context ("hard" — plausible under the model but conditioned
//! wrong). Quantization that distorts the scoring pipeline degrades the
//! ranking, which is precisely the relative signal Tables 2/3/5/6 compare.
//! Absolute accuracies are NOT comparable to the real benchmarks.

use anyhow::Result;

use super::runtime::EvalRuntime;
use crate::util::rng::{zipf_cdf, Rng};

#[derive(Clone, Debug)]
pub struct McItem {
    pub context: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub correct: usize,
}

#[derive(Clone, Debug)]
pub struct McSuite {
    pub name: String,
    pub items: Vec<McItem>,
}

#[derive(Clone, Copy, Debug)]
pub struct SuiteSpec {
    pub name: &'static str,
    pub n_items: usize,
    pub ctx_len: usize,
    pub cont_len: usize,
    pub n_choices: usize,
    /// Rollout temperature for the correct continuation (noise floor).
    pub temp: f64,
    /// Hard distractors = perturbed-context rollouts; easy = random.
    pub hard_distractors: bool,
}

/// The six paper-benchmark analogs. Context/continuation lengths must fit
/// the prefill width (ctx + cont <= P = 64).
pub fn paper_suites(n_items: usize) -> Vec<SuiteSpec> {
    // Temperatures/distractor hardness tuned so the FP reference lands in
    // the paper's accuracy neighborhoods (easy suites high, ARC-c-analog
    // hardest) with room to degrade under quantization.
    vec![
        SuiteSpec { name: "HS-sim", n_items, ctx_len: 24, cont_len: 8, n_choices: 4, temp: 0.7, hard_distractors: false },
        SuiteSpec { name: "PIQA-sim", n_items, ctx_len: 16, cont_len: 10, n_choices: 2, temp: 0.7, hard_distractors: true },
        SuiteSpec { name: "ARC-e-sim", n_items, ctx_len: 20, cont_len: 6, n_choices: 4, temp: 0.6, hard_distractors: false },
        SuiteSpec { name: "ARC-c-sim", n_items, ctx_len: 20, cont_len: 6, n_choices: 4, temp: 0.9, hard_distractors: true },
        SuiteSpec { name: "BoolQ-sim", n_items, ctx_len: 28, cont_len: 4, n_choices: 2, temp: 0.7, hard_distractors: false },
        SuiteSpec { name: "Wino-sim", n_items, ctx_len: 18, cont_len: 5, n_choices: 2, temp: 0.65, hard_distractors: true },
    ]
}

/// Build one suite against the full-precision reference model.
pub fn build_suite(reference: &EvalRuntime, spec: &SuiteSpec, seed: u64) -> Result<McSuite> {
    let cfg = reference.cfg();
    assert!(spec.ctx_len + spec.cont_len <= cfg.prefill_len);
    let mut rng = Rng::new(seed ^ 0x5017e5);
    let cdf = zipf_cdf(cfg.vocab - 1, 1.1);
    let mut items = Vec::with_capacity(spec.n_items);
    for _ in 0..spec.n_items {
        // contexts drawn zipf-distributed (skip token 0 = EOS)
        let context: Vec<u32> = (0..spec.ctx_len).map(|_| rng.zipf(&cdf) as u32 + 1).collect();
        let correct_cont = reference.rollout(&context, spec.cont_len, spec.temp, &mut rng)?;
        let mut choices = vec![correct_cont];
        for _ in 1..spec.n_choices {
            let d = if spec.hard_distractors {
                // perturb most of the context, roll out — locally plausible
                // model text conditioned on the wrong premise
                let mut pctx = context.clone();
                for _ in 0..(5 * spec.ctx_len / 6).max(1) {
                    let i = rng.below(pctx.len());
                    pctx[i] = rng.zipf(&cdf) as u32 + 1;
                }
                reference.rollout(&pctx, spec.cont_len, spec.temp, &mut rng)?
            } else {
                (0..spec.cont_len).map(|_| rng.zipf(&cdf) as u32 + 1).collect()
            };
            choices.push(d);
        }
        // shuffle so "correct" isn't always index 0
        let correct_pos = rng.below(spec.n_choices);
        choices.swap(0, correct_pos);
        items.push(McItem { context, choices, correct: correct_pos });
    }
    Ok(McSuite { name: spec.name.to_string(), items })
}

/// Accuracy (%) of a scorer on a suite: argmax over length-normalized
/// choice log-likelihoods.
pub fn evaluate(suite: &McSuite, scorer: &EvalRuntime) -> Result<f64> {
    let mut hits = 0usize;
    for item in &suite.items {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, cont) in item.choices.iter().enumerate() {
            let lp = scorer.choice_logprob(&item.context, cont)?;
            if lp > best.0 {
                best = (lp, ci);
            }
        }
        if best.1 == item.correct {
            hits += 1;
        }
    }
    Ok(100.0 * hits as f64 / suite.items.len() as f64)
}
