//! Evaluation harness: synthetic zero-shot suites, corpus perplexity and
//! the treatment-pluggable EvalRuntime (Tables 2-6, Fig. 4).

pub mod perplexity;
pub mod runtime;
pub mod tasks;

pub use perplexity::{generate_corpus, model_corpus, perplexity, perplexity_windows, Corpus};
pub use runtime::{ActTreatment, EvalRuntime};
pub use tasks::{build_suite, evaluate, paper_suites, McItem, McSuite, SuiteSpec};

use anyhow::Result;

use crate::quant::baselines::CalibStats;
use crate::util::rng::{zipf_cdf, Rng};

/// Run real calibration: feed a few synthetic prompts through the
/// full-precision reference and record per-channel absolute maxima of
/// every layer input (what SmoothQuant smooths against and Atom picks
/// outlier channels from).
pub fn calibrate(reference: &EvalRuntime, n_prompts: usize, seed: u64) -> Result<CalibStats> {
    let cfg = reference.cfg().clone();
    let d = cfg.d_model;
    let n_layers = cfg.n_layers;
    let mut absmax = vec![vec![1e-6f32; d]; n_layers];
    let mut rng = Rng::new(seed ^ 0xCA11B);
    let cdf = zipf_cdf(cfg.vocab - 1, 1.1);
    for _ in 0..n_prompts {
        let prompt: Vec<u32> = (0..cfg.prefill_len / 2)
            .map(|_| rng.zipf(&cdf) as u32 + 1)
            .collect();
        let x = reference.node.weights.embed_padded(&prompt, cfg.prefill_len);
        let used = prompt.len();
        let mut hook = |li: usize, h: &mut Vec<f32>| {
            // the output of layer li is the input of layer li+1
            if li + 1 < n_layers {
                let am = &mut absmax[li + 1];
                for r in 0..used {
                    for c in 0..d {
                        am[c] = am[c].max(h[r * d + c].abs());
                    }
                }
            }
        };
        let _ = reference.node.prefill_with(&x, &mut hook)?;
        // layer 0's input is the embedding itself
        for r in 0..used {
            for c in 0..d {
                absmax[0][c] = absmax[0][c].max(x[r * d + c].abs());
            }
        }
    }
    Ok(CalibStats { input_absmax: absmax })
}
