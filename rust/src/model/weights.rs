//! Synthetic model weights (substitution for pretrained Llama checkpoints).
//!
//! Weights are generated deterministically from a seed with 1/sqrt(d)
//! scaling so activations stay well-conditioned through 32-48 layers.
//! A small set of "outlier channels" in the down-projections gets a large
//! magnitude boost — this reproduces the activation-outlier profile the
//! paper exploits (Fig. 4(b): ~0.0005% of intermediate values are huge and
//! accuracy-critical), so TS/TAB-Q face a realistic value distribution.
//!
//! Quantization baselines mutate copies of these tensors in place
//! (fake-quant); the runtime uploads whatever values are present here.

use super::config::ModelConfig;
use crate::util::rng::Rng;

/// One decoder layer's tensors, row-major, shapes fixed by `ModelConfig`.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Vec<f32>,     // (d, d)
    pub wk: Vec<f32>,     // (d, d)
    pub wv: Vec<f32>,     // (d, d)
    pub wo: Vec<f32>,     // (d, d)
    pub w_gate: Vec<f32>, // (d, f)
    pub w_up: Vec<f32>,   // (d, f)
    pub w_down: Vec<f32>, // (f, d)
    pub g1: Vec<f32>,     // (d,)
    pub g2: Vec<f32>,     // (d,)
}

impl LayerWeights {
    /// Tensors in the artifact argument order (matches python
    /// model.LAYER_WEIGHT_NAMES — runtime feeds these verbatim).
    pub fn ordered(&self) -> [(&'static str, &[f32]); 9] {
        [
            ("wq", &self.wq),
            ("wk", &self.wk),
            ("wv", &self.wv),
            ("wo", &self.wo),
            ("w_gate", &self.w_gate),
            ("w_up", &self.w_up),
            ("w_down", &self.w_down),
            ("g1", &self.g1),
            ("g2", &self.g2),
        ]
    }

    /// Mutable views of the 7 matmul tensors (quantizers skip the norms,
    /// as every method in the paper's comparison does).
    pub fn matmul_tensors_mut(&mut self) -> [(&'static str, &mut Vec<f32>); 7] {
        [
            ("wq", &mut self.wq),
            ("wk", &mut self.wk),
            ("wv", &mut self.wv),
            ("wo", &mut self.wo),
            ("w_gate", &mut self.w_gate),
            ("w_up", &mut self.w_up),
            ("w_down", &mut self.w_down),
        ]
    }

    pub fn param_count(&self) -> usize {
        self.wq.len()
            + self.wk.len()
            + self.wv.len()
            + self.wo.len()
            + self.w_gate.len()
            + self.w_up.len()
            + self.w_down.len()
            + self.g1.len()
            + self.g2.len()
    }
}

/// Full model: embedding + decoder stack + final norm/head.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub cfg: ModelConfig,
    pub embedding: Vec<f32>, // (vocab, d)
    pub layers: Vec<LayerWeights>,
    pub gf: Vec<f32>,    // (d,)
    pub w_out: Vec<f32>, // (d, vocab)
}

/// Fraction of w_down output channels boosted to create activation outliers.
const OUTLIER_CHANNEL_FRAC: f64 = 0.008;
/// Magnitude boost of outlier channels (tuned so a handful of mid-stack
/// intermediate values exceed 100 while >99.9% stay below 10, mirroring
/// paper Fig. 4(b)'s "0.0005% of values exceed 100" profile).
const OUTLIER_BOOST: f32 = 60.0;
/// Late-layer weight outliers (see the comment at the spike site):
/// magnitude ramps from SPIKE_BASE to SPIKE_BASE+SPIKE_SLOPE across the
/// final 30% of the stack — large enough to dominate a 4-bit group's
/// range, small enough to evade the outlier-row protection threshold.
const SPIKE_BASE: f32 = 7.0;
const SPIKE_SLOPE: f32 = 12.0;

impl ModelWeights {
    /// Deterministic synthetic init. Same (cfg, seed) → identical weights.
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let root = Rng::new(seed ^ 0x5eed_c0de);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let v = cfg.vocab;
        let std_d = 1.0 / (d as f32).sqrt();
        let std_f = 1.0 / (f as f32).sqrt();
        // GPT-2-style residual-update damping: output projections scaled
        // by 1/sqrt(2L) so each layer's Jacobian stays near identity and
        // early-injected noise grows mildly instead of exploding — the
        // perturbation dynamics of a trained network, which Table 4's
        // back>front sensitivity ordering depends on.
        let update_scale = 1.0 / (2.0 * cfg.n_layers as f32).sqrt() * 1.4;

        let mut emb_rng = root.child(1_000_000);
        let mut embedding = vec![0.0f32; v * d];
        emb_rng.fill_normal(&mut embedding, 1.0);
        // Persistent residual-stream outlier features: a few embedding
        // channels carry |values| > 100 for a subset of tokens. They ride
        // the residual through every layer (the paper's Fig. 4(b)
        // intermediate-output outliers) WITHOUT creating a high-gain
        // weight path that would amplify noise — matching how a chunk of
        // real LLM outlier dims are persistent token features.
        {
            // Outlier channels fire for EVERY token (as in real LLMs,
            // where a fixed set of dims carries large values at all
            // positions) with a heavy-tailed magnitude: typically 25-70,
            // exceeding 100 for a few % of tokens — so >99.9% of all
            // intermediate values stay small while every token row holds
            // at least one value far above the TS threshold.
            let n_ch = (d / 64).max(1);
            let chans = emb_rng.choose_k(d, n_ch);
            for &ch in &chans {
                for t in 0..v {
                    let sign = if emb_rng.f64() < 0.5 { -1.0 } else { 1.0 };
                    embedding[t * d + ch] =
                        sign * (25.0 + emb_rng.normal().abs() as f32 * 45.0);
                }
            }
        }

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let mut r = root.child(li as u64);
            let gen = |n: usize, std: f32, rr: &mut Rng| {
                let mut t = vec![0.0f32; n];
                rr.fill_normal(&mut t, std);
                t
            };
            let mut lw = LayerWeights {
                wq: gen(d * d, std_d, &mut r),
                wk: gen(d * d, std_d, &mut r),
                wv: gen(d * d, std_d, &mut r),
                wo: gen(d * d, std_d * update_scale, &mut r),
                w_gate: gen(d * f, std_d, &mut r),
                w_up: gen(d * f, std_d, &mut r),
                w_down: gen(f * d, std_f * update_scale, &mut r),
                g1: vec![1.0; d],
                g2: vec![1.0; d],
            };
            // Outlier channels: boost a few w_down output columns so the
            // residual stream develops rare huge values (heavier boost in
            // mid-stack layers, where the paper observes them).
            let n_out = ((d as f64) * OUTLIER_CHANNEL_FRAC).ceil() as usize;
            let mid_boost = if li >= cfg.n_layers / 4 { OUTLIER_BOOST } else { 4.0 };
            for ch in r.choose_k(d, n_out) {
                // Sparse boost: only a few rows of the column, so the
                // outlier fires for specific token patterns rather than
                // uniformly (matching the "0.0005% of values" profile).
                for row in r.choose_k(f, 1) {
                    lw.w_down[row * d + ch] *= mid_boost;
                }
            }
            // Late-layer weight outliers: the FINAL ~30% of layers get
            // rare large entries (x10..x30) that low-bit group-wise
            // quantization cannot represent without wrecking their group
            // — the trained-LLM sensitivity profile behind paper Table 4
            // (back-end quant hurts most) and behind OPSC's design choice
            // of keeping the back segment at full precision on the cloud.
            let frac = (li as f32 + 1.0) / cfg.n_layers as f32;
            let spike = if frac > 0.7 {
                SPIKE_BASE + SPIKE_SLOPE * (frac - 0.7) / 0.3
            } else {
                1.0
            };
            {
                let dims: [(usize, usize); 7] =
                    [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)];
                for ((_, t), (rows, cols)) in lw.matmul_tensors_mut().into_iter().zip(dims) {
                    let k = (cols / 2).max(1);
                    for _ in 0..k {
                        let rr = r.below(rows);
                        let cc = r.below(cols);
                        t[rr * cols + cc] *= spike;
                    }
                }
            }
            layers.push(lw);
        }

        let mut head_rng = root.child(2_000_000);
        let mut w_out = vec![0.0f32; d * v];
        head_rng.fill_normal(&mut w_out, std_d);

        ModelWeights {
            cfg: cfg.clone(),
            embedding,
            layers,
            gf: vec![1.0; d],
            w_out,
        }
    }

    /// Token embedding: row gather (this is why no XLA artifact is needed).
    /// Returns (len(tokens), d) row-major.
    pub fn embed(&self, tokens: &[u32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let mut out = vec![0.0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = (t as usize).min(self.cfg.vocab - 1);
            out[i * d..(i + 1) * d].copy_from_slice(&self.embedding[t * d..(t + 1) * d]);
        }
        out
    }

    /// Embed padded to `width` rows (prefill artifacts have static width).
    pub fn embed_padded(&self, tokens: &[u32], width: usize) -> Vec<f32> {
        assert!(tokens.len() <= width, "prompt longer than prefill width");
        let d = self.cfg.d_model;
        let mut out = self.embed(tokens);
        out.resize(width * d, 0.0);
        out
    }

    pub fn total_params(&self) -> usize {
        self.embedding.len()
            + self.layers.iter().map(|l| l.param_count()).sum::<usize>()
            + self.gf.len()
            + self.w_out.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let cfg = ModelConfig::sim7b();
        let a = ModelWeights::synthetic(&cfg, 7);
        let b = ModelWeights::synthetic(&cfg, 7);
        assert_eq!(a.layers[0].wq, b.layers[0].wq);
        assert_eq!(a.layers[31].w_down, b.layers[31].w_down);
        let c = ModelWeights::synthetic(&cfg, 8);
        assert_ne!(a.layers[0].wq, c.layers[0].wq);
    }

    #[test]
    fn param_count_matches_config() {
        let cfg = ModelConfig::sim7b();
        let w = ModelWeights::synthetic(&cfg, 1);
        assert_eq!(w.total_params(), cfg.total_params());
    }

    #[test]
    fn embed_gathers_rows() {
        let cfg = ModelConfig::sim7b();
        let w = ModelWeights::synthetic(&cfg, 1);
        let e = w.embed(&[3, 3, 5]);
        let d = cfg.d_model;
        assert_eq!(e.len(), 3 * d);
        assert_eq!(e[..d], e[d..2 * d]);
        assert_ne!(e[..d], e[2 * d..3 * d]);
    }

    #[test]
    fn embed_padded_zero_fills() {
        let cfg = ModelConfig::sim7b();
        let w = ModelWeights::synthetic(&cfg, 1);
        let e = w.embed_padded(&[1, 2], 5);
        let d = cfg.d_model;
        assert_eq!(e.len(), 5 * d);
        assert!(e[2 * d..].iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn embed_padded_rejects_long_prompt() {
        let cfg = ModelConfig::sim7b();
        let w = ModelWeights::synthetic(&cfg, 1);
        w.embed_padded(&[0; 100], 10);
    }

    #[test]
    fn outlier_channels_present() {
        let cfg = ModelConfig::sim7b();
        let w = ModelWeights::synthetic(&cfg, 1);
        // mid-stack w_down should contain values far beyond the base std
        let l = &w.layers[20];
        let base = 1.0 / (cfg.d_ff as f32).sqrt();
        let max = l.w_down.iter().fold(0f32, |m, &x| m.max(x.abs()));
        assert!(max > 8.0 * base, "max={max} base={base}");
    }
}
