//! Model definitions: shape-class configs and synthetic weights.

pub mod config;
pub mod weights;

pub use config::{ModelConfig, ShapeClass};
pub use weights::{LayerWeights, ModelWeights};
