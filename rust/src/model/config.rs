//! Model configurations.
//!
//! A `ModelConfig` names a *shape class* (tensor dims, which select the AOT
//! artifact set) plus a layer count. Several architectures share one shape
//! class and differ only in depth — the Rust layer loop is the only place
//! depth appears, so Table-6's cross-model sweep needs no extra artifacts.
//!
//! `sim7b`/`sim13b` mirror Llama-2 7B/13B in layer count (32/40) so the
//! paper's split-point sweeps (ℓ ∈ 1..L) are faithful; widths are scaled
//! down for CPU-PJRT speed (substitution documented in DESIGN.md §1).

/// Shape class: selects which artifact directory the runtime loads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShapeClass {
    Sim7b,
    Sim13b,
}

impl ShapeClass {
    pub fn dir_name(&self) -> &'static str {
        match self {
            ShapeClass::Sim7b => "sim7b",
            ShapeClass::Sim13b => "sim13b",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub shape_class: ShapeClass,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    /// W̄: static KV-cache length (max tokens incl. prompt).
    pub max_seq: usize,
    /// P: static prefill width; prompts are padded to P.
    pub prefill_len: usize,
}

impl ModelConfig {
    pub fn kv_width(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Parameter count of one decoder layer (matches python model.py).
    pub fn params_per_layer(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        4 * d * d       // wq wk wv wo
            + 2 * d * f // w_gate w_up
            + f * d     // w_down
            + 2 * d // g1 g2
    }

    /// Parameters outside the decoder stack (embedding + final norm + head).
    pub fn nonlayer_params(&self) -> usize {
        self.vocab * self.d_model      // embedding
            + self.d_model             // gf
            + self.d_model * self.vocab // w_out
    }

    pub fn total_params(&self) -> usize {
        self.n_layers * self.params_per_layer() + self.nonlayer_params()
    }

    fn sim7b_shapes(name: &str, n_layers: usize) -> ModelConfig {
        ModelConfig {
            name: name.to_string(),
            shape_class: ShapeClass::Sim7b,
            n_layers,
            d_model: 128,
            n_heads: 4,
            head_dim: 32,
            d_ff: 352,
            vocab: 512,
            max_seq: 128,
            prefill_len: 64,
        }
    }

    /// Llama-2-7B analog: 32 decoder layers (paper's primary model).
    pub fn sim7b() -> ModelConfig {
        Self::sim7b_shapes("sim7b", 32)
    }

    /// Llama-2-13B analog: 40 decoder layers.
    pub fn sim13b() -> ModelConfig {
        ModelConfig {
            name: "sim13b".to_string(),
            shape_class: ShapeClass::Sim13b,
            n_layers: 40,
            d_model: 160,
            n_heads: 5,
            head_dim: 32,
            d_ff: 432,
            vocab: 512,
            max_seq: 128,
            prefill_len: 64,
        }
    }

    /// Table-6 cross-architecture analogs (share the sim7b shape class;
    /// depth mirrors the real architecture's decoder-layer count).
    pub fn sim_qwen14b() -> ModelConfig {
        Self::sim7b_shapes("sim-qwen2.5-14b", 48)
    }

    pub fn sim_nemo12b() -> ModelConfig {
        Self::sim7b_shapes("sim-mistral-nemo-12b", 40)
    }

    pub fn sim_llama8b() -> ModelConfig {
        Self::sim7b_shapes("sim-llama-3.1-8b", 32)
    }

    pub fn sim_phi4() -> ModelConfig {
        Self::sim7b_shapes("sim-phi-4", 40)
    }

    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "sim7b" => Some(Self::sim7b()),
            "sim13b" => Some(Self::sim13b()),
            "sim-qwen2.5-14b" | "qwen14b" => Some(Self::sim_qwen14b()),
            "sim-mistral-nemo-12b" | "nemo12b" => Some(Self::sim_nemo12b()),
            "sim-llama-3.1-8b" | "llama8b" => Some(Self::sim_llama8b()),
            "sim-phi-4" | "phi4" => Some(Self::sim_phi4()),
            _ => None,
        }
    }

    pub fn all_names() -> &'static [&'static str] {
        &["sim7b", "sim13b", "qwen14b", "nemo12b", "llama8b", "phi4"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_mirror_paper() {
        assert_eq!(ModelConfig::sim7b().n_layers, 32);
        assert_eq!(ModelConfig::sim13b().n_layers, 40);
        assert_eq!(ModelConfig::sim_qwen14b().n_layers, 48);
    }

    #[test]
    fn d_model_is_heads_times_dim() {
        for name in ModelConfig::all_names() {
            let c = ModelConfig::by_name(name).unwrap();
            assert_eq!(c.d_model, c.n_heads * c.head_dim, "{name}");
            assert!(c.max_seq >= c.prefill_len);
        }
    }

    #[test]
    fn param_count_matches_manual() {
        let c = ModelConfig::sim7b();
        let d = 128;
        let f = 352;
        let expect = 4 * d * d + 2 * d * f + f * d + 2 * d;
        assert_eq!(c.params_per_layer(), expect);
        assert_eq!(
            c.total_params(),
            32 * expect + 512 * d + d + d * 512
        );
    }

    #[test]
    fn by_name_round_trips() {
        assert!(ModelConfig::by_name("sim7b").is_some());
        assert!(ModelConfig::by_name("nope").is_none());
    }
}
