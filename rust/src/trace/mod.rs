//! Workload generation: synthetic request traces (prompt token streams,
//! Poisson arrivals, output-length distributions) shared by the e2e
//! examples and the Fig. 5 scalability bench.

use crate::coordinator::Request;
use crate::util::rng::{zipf_cdf, Rng};

/// Shape of the arrival process. `Poisson` is the steady-state default;
/// the other two are the chaos/stress shapes the robustness suite and
/// `benches/chaos.rs` drive the serve loop with.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum ArrivalPattern {
    /// Memoryless arrivals at `arrival_rate` (the paper's workload).
    #[default]
    Poisson,
    /// A quiet lead-in, then everyone at once: all requests land
    /// uniformly inside `window_s` seconds starting at `lead_s`.
    FlashCrowd { lead_s: f64, window_s: f64 },
    /// Sessions joining and leaving in waves: Poisson bursts of
    /// `burst` requests separated by `gap_s` seconds of silence.
    Churn { burst: usize, gap_s: f64 },
    /// Diurnal load curve: an inhomogeneous Poisson process whose rate
    /// swings sinusoidally between `trough_rate` and `peak_rate` over
    /// `period_s` (t=0 is the trough; the peak sits at `period_s / 2`).
    /// Sampled by thinning, so it degrades exactly to `Poisson` when
    /// trough == peak. This is the fleet's day/night shape: a server
    /// provisioned for the trough must admit/reject its way through the
    /// peak instead of falling over.
    Diurnal { period_s: f64, peak_rate: f64, trough_rate: f64 },
}

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    /// Poisson arrival rate (requests/s) across the whole trace.
    pub arrival_rate: f64,
    /// Arrival process shape (rate still governs intra-burst spacing).
    pub arrival: ArrivalPattern,
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    pub output_len_min: usize,
    pub output_len_max: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_requests: 16,
            arrival_rate: 0.5,
            arrival: ArrivalPattern::Poisson,
            prompt_len_min: 4,
            prompt_len_max: 24,
            output_len_min: 4,
            output_len_max: 24,
            vocab: 512,
            seed: 11,
        }
    }
}

/// Generate a request trace (sorted by arrival time). Arrival times are
/// guaranteed finite — the serve loop rejects anything else.
pub fn generate_trace(spec: &WorkloadSpec) -> Vec<Request> {
    assert!(
        spec.arrival_rate.is_finite() && spec.arrival_rate > 0.0,
        "arrival_rate must be a positive finite rate (got {})",
        spec.arrival_rate
    );
    let mut rng = Rng::new(spec.seed ^ 0x77ACE);
    let cdf = zipf_cdf(spec.vocab - 1, 1.1);
    let mut t = 0.0f64;
    let mut out: Vec<Request> = (0..spec.n_requests)
        .map(|i| {
            t = match spec.arrival {
                ArrivalPattern::Poisson => t + rng.exponential(spec.arrival_rate),
                ArrivalPattern::FlashCrowd { lead_s, window_s } => {
                    // Uniform inside the crowd window; sorted afterwards
                    // by the caller's contract (monotone t not needed —
                    // the trace is re-sorted below).
                    lead_s.max(0.0) + window_s.max(0.0) * rng.f64()
                }
                ArrivalPattern::Churn { burst, gap_s } => {
                    let wave = i / burst.max(1);
                    wave as f64 * gap_s.max(0.0) + rng.exponential(spec.arrival_rate)
                }
                ArrivalPattern::Diurnal { period_s, peak_rate, trough_rate } => {
                    // Thinning (Lewis–Shedler): draw homogeneous
                    // candidates at the envelope rate, accept each with
                    // probability rate(t)/peak.
                    assert!(
                        period_s > 0.0
                            && peak_rate > 0.0
                            && (0.0..=peak_rate).contains(&trough_rate),
                        "diurnal needs period_s > 0 and 0 <= trough_rate <= peak_rate"
                    );
                    loop {
                        t += rng.exponential(peak_rate);
                        let phase = (std::f64::consts::TAU * t / period_s).cos();
                        let rate = trough_rate + (peak_rate - trough_rate) * 0.5 * (1.0 - phase);
                        if rng.f64() < rate / peak_rate {
                            break t;
                        }
                    }
                }
            };
            let plen = rng.range(spec.prompt_len_min as i64, spec.prompt_len_max as i64) as usize;
            let olen = rng.range(spec.output_len_min as i64, spec.output_len_max as i64) as usize;
            let prompt: Vec<u32> = (0..plen).map(|_| rng.zipf(&cdf) as u32 + 1).collect();
            let mut r = Request::new(i as u64, prompt, olen);
            debug_assert!(t.is_finite(), "trace produced a non-finite arrival");
            r.arrival_s = t;
            r
        })
        .collect();
    // FlashCrowd draws are independent (not accumulated), so restore the
    // sorted-by-arrival contract explicitly.
    out.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_deterministic_and_bounded() {
        let spec = WorkloadSpec::default();
        let a = generate_trace(&spec);
        let b = generate_trace(&spec);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        for r in &a {
            assert!((spec.prompt_len_min..=spec.prompt_len_max).contains(&r.prompt.len()));
            assert!((spec.output_len_min..=spec.output_len_max).contains(&r.max_new_tokens));
            assert!(r.prompt.iter().all(|&t| t != 0 && (t as usize) < spec.vocab));
        }
    }

    #[test]
    fn arrivals_increasing() {
        let a = generate_trace(&WorkloadSpec::default());
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn flash_crowd_lands_inside_the_window() {
        let spec = WorkloadSpec {
            n_requests: 32,
            arrival: ArrivalPattern::FlashCrowd { lead_s: 5.0, window_s: 1.0 },
            ..Default::default()
        };
        let a = generate_trace(&spec);
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s, "sorted contract");
        }
        assert!(a.iter().all(|r| (5.0..=6.0).contains(&r.arrival_s)));
        // determinism still holds under the re-sort
        let b = generate_trace(&spec);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
    }

    #[test]
    fn churn_arrives_in_separated_waves() {
        let a = generate_trace(&WorkloadSpec {
            n_requests: 30,
            arrival: ArrivalPattern::Churn { burst: 10, gap_s: 1000.0 },
            ..Default::default()
        });
        let wave = |t: f64| (t / 1000.0) as usize;
        for (i, r) in a.iter().enumerate() {
            assert_eq!(wave(r.arrival_s), i / 10, "request {i} in the wrong wave");
        }
    }

    #[test]
    fn diurnal_deterministic_and_denser_at_the_peak() {
        let spec = WorkloadSpec {
            n_requests: 600,
            arrival: ArrivalPattern::Diurnal {
                period_s: 100.0,
                peak_rate: 8.0,
                trough_rate: 0.5,
            },
            ..Default::default()
        };
        let a = generate_trace(&spec);
        let b = generate_trace(&spec);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt, y.prompt);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s, "sorted contract");
        }
        // Fold arrivals onto one period: the peak half-cycle (quarter to
        // three-quarters, centered on period/2) must be much denser than
        // the trough half-cycle.
        let (mut peak, mut trough) = (0usize, 0usize);
        for r in &a {
            let ph = (r.arrival_s / 100.0).fract();
            if (0.25..0.75).contains(&ph) {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak > 2 * trough,
            "diurnal density: peak half {peak} vs trough half {trough}"
        );
    }

    #[test]
    fn diurnal_with_flat_rates_degrades_to_poisson_density() {
        // trough == peak: thinning accepts every candidate, so the trace
        // is a homogeneous Poisson process at that rate.
        let a = generate_trace(&WorkloadSpec {
            n_requests: 2000,
            arrival: ArrivalPattern::Diurnal {
                period_s: 50.0,
                peak_rate: 2.0,
                trough_rate: 2.0,
            },
            ..Default::default()
        });
        let span = a.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((rate - 2.0).abs() < 0.25, "rate={rate}");
    }

    #[test]
    fn arrival_rate_roughly_respected() {
        let spec = WorkloadSpec { n_requests: 2000, arrival_rate: 2.0, ..Default::default() };
        let a = generate_trace(&spec);
        let span = a.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((rate - 2.0).abs() < 0.25, "rate={rate}");
    }
}
