//! Workload generation: synthetic request traces (prompt token streams,
//! Poisson arrivals, output-length distributions) shared by the e2e
//! examples and the Fig. 5 scalability bench.

use crate::coordinator::Request;
use crate::util::rng::{zipf_cdf, Rng};

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    /// Poisson arrival rate (requests/s) across the whole trace.
    pub arrival_rate: f64,
    pub prompt_len_min: usize,
    pub prompt_len_max: usize,
    pub output_len_min: usize,
    pub output_len_max: usize,
    pub vocab: usize,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_requests: 16,
            arrival_rate: 0.5,
            prompt_len_min: 4,
            prompt_len_max: 24,
            output_len_min: 4,
            output_len_max: 24,
            vocab: 512,
            seed: 11,
        }
    }
}

/// Generate a request trace (sorted by arrival time). Arrival times are
/// guaranteed finite — the serve loop rejects anything else.
pub fn generate_trace(spec: &WorkloadSpec) -> Vec<Request> {
    assert!(
        spec.arrival_rate.is_finite() && spec.arrival_rate > 0.0,
        "arrival_rate must be a positive finite rate (got {})",
        spec.arrival_rate
    );
    let mut rng = Rng::new(spec.seed ^ 0x77ACE);
    let cdf = zipf_cdf(spec.vocab - 1, 1.1);
    let mut t = 0.0f64;
    (0..spec.n_requests)
        .map(|i| {
            t += rng.exponential(spec.arrival_rate);
            let plen = rng.range(spec.prompt_len_min as i64, spec.prompt_len_max as i64) as usize;
            let olen = rng.range(spec.output_len_min as i64, spec.output_len_max as i64) as usize;
            let prompt: Vec<u32> = (0..plen).map(|_| rng.zipf(&cdf) as u32 + 1).collect();
            let mut r = Request::new(i as u64, prompt, olen);
            debug_assert!(t.is_finite(), "trace produced a non-finite arrival");
            r.arrival_s = t;
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_deterministic_and_bounded() {
        let spec = WorkloadSpec::default();
        let a = generate_trace(&spec);
        let b = generate_trace(&spec);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        for r in &a {
            assert!((spec.prompt_len_min..=spec.prompt_len_max).contains(&r.prompt.len()));
            assert!((spec.output_len_min..=spec.output_len_max).contains(&r.max_new_tokens));
            assert!(r.prompt.iter().all(|&t| t != 0 && (t as usize) < spec.vocab));
        }
    }

    #[test]
    fn arrivals_increasing() {
        let a = generate_trace(&WorkloadSpec::default());
        for w in a.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn arrival_rate_roughly_respected() {
        let spec = WorkloadSpec { n_requests: 2000, arrival_rate: 2.0, ..Default::default() };
        let a = generate_trace(&spec);
        let span = a.last().unwrap().arrival_s;
        let rate = 2000.0 / span;
        assert!((rate - 2.0).abs() < 0.25, "rate={rate}");
    }
}
