//! OmniQuant (E2) — Shao et al., 2023 — mechanism re-implementation.
//!
//! Core idea preserved: *learnable weight clipping* — instead of quantizing
//! to the full [min, max] range, each channel's clip ratio is optimized to
//! minimize quantization MSE, trading outlier representation for finer
//! resolution of the bulk. The original learns clip parameters by gradient
//! descent on block outputs; we grid-search the per-channel clip ratio
//! minimizing weight-space MSE (calibration-only, no backprop), which is
//! the same mechanism at the granularity our substrate supports
//! (DESIGN.md §3.4).

use crate::model::ModelWeights;

use super::super::aiq;
use super::{ActQuantMode, CalibStats, QuantMethod};

pub struct OmniQuant {
    pub weight_bits: u32,
    pub act_bits: u32,
    /// Clip ratios searched per channel.
    pub grid: Vec<f32>,
}

impl OmniQuant {
    pub fn new(weight_bits: u32, act_bits: u32) -> Self {
        OmniQuant {
            weight_bits,
            act_bits,
            grid: vec![1.0, 0.95, 0.9, 0.85, 0.8, 0.7, 0.6, 0.5],
        }
    }
}

/// Fake-quant one column with a clipped range; returns squared error.
fn fq_column_clipped(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    c: usize,
    clip: f32,
    bits: u32,
    write: bool,
) -> f64 {
    let (mut tmin, mut tmax) = (f32::INFINITY, f32::NEG_INFINITY);
    for r in 0..rows {
        let x = w[r * cols + c];
        tmin = tmin.min(x);
        tmax = tmax.max(x);
    }
    let p = aiq::params_for_range(tmin * clip, tmax * clip, bits);
    let mut se = 0f64;
    for r in 0..rows {
        let x = w[r * cols + c];
        let xq = aiq::dequantize_one(aiq::quantize_one(x.clamp(tmin * clip, tmax * clip), &p), &p);
        se += ((x - xq) as f64).powi(2);
        if write {
            w[r * cols + c] = xq;
        }
    }
    se
}

/// Grid-search the best clip ratio per output channel, then fake-quant.
pub fn learned_clip_fq(w: &mut [f32], rows: usize, cols: usize, grid: &[f32], bits: u32) {
    for c in 0..cols {
        let mut best = (f64::INFINITY, 1.0f32);
        for &clip in grid {
            let se = fq_column_clipped(w, rows, cols, c, clip, bits, false);
            if se < best.0 {
                best = (se, clip);
            }
        }
        fq_column_clipped(w, rows, cols, c, best.1, bits, true);
    }
}

impl QuantMethod for OmniQuant {
    fn name(&self) -> &'static str {
        "OmniQuant"
    }

    fn quantize_weights(&self, w: &mut ModelWeights, _stats: &CalibStats) {
        let d = w.cfg.d_model;
        let f = w.cfg.d_ff;
        let dims: [(usize, usize); 7] =
            [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)];
        for lw in &mut w.layers {
            for ((_, t), (rows, cols)) in lw.matmul_tensors_mut().into_iter().zip(dims) {
                learned_clip_fq(t, rows, cols, &self.grid, self.weight_bits);
            }
        }
    }

    fn act_mode(&self) -> ActQuantMode {
        ActQuantMode::PerTensor { bits: self.act_bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    #[test]
    fn clipping_beats_full_range_on_outlier_columns() {
        // column with one extreme outlier: clipped quantization must have
        // lower MSE than clip=1.0 (full range). At 4 bits the break-even
        // clip is c* = o² / (o² + n·s²/12-ish); with 1024 bulk values the
        // optimum sits well below 1.0.
        let rows = 1024;
        let mut rng = Rng::new(2);
        let mut w = vec![0f32; rows];
        rng.fill_normal(&mut w, 0.1);
        w[0] = 5.0; // outlier ~50x the bulk scale
        let orig = w.clone();

        let mut clipped = w.clone();
        learned_clip_fq(&mut clipped, rows, 1, &[1.0, 0.7, 0.5, 0.3], 4);
        let mut full = w.clone();
        fq_column_clipped(&mut full, rows, 1, 0, 1.0, 4, true);

        let mse = |q: &[f32]| -> f64 {
            q.iter().zip(&orig).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(mse(&clipped) < mse(&full), "{} vs {}", mse(&clipped), mse(&full));
    }

    #[test]
    fn grid_includes_identity_so_never_worse() {
        let rows = 64;
        let mut rng = Rng::new(3);
        let mut w = vec![0f32; rows * 4];
        rng.fill_normal(&mut w, 1.0);
        let orig = w.clone();
        let grid = [1.0f32, 0.9, 0.8];
        let mut learned = w.clone();
        learned_clip_fq(&mut learned, rows, 4, &grid, 4);
        let mut naive = w;
        for c in 0..4 {
            fq_column_clipped(&mut naive, rows, 4, c, 1.0, 4, true);
        }
        let mse = |q: &[f32]| -> f64 {
            q.iter().zip(&orig).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(mse(&learned) <= mse(&naive) + 1e-9);
    }

    #[test]
    fn quantizes_whole_model() {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let mut w = ModelWeights::synthetic(&cfg, 4);
        let orig = w.clone();
        let st = CalibStats::from_weights(&w);
        OmniQuant::new(4, 4).quantize_weights(&mut w, &st);
        for li in 0..2 {
            assert_ne!(w.layers[li].w_up, orig.layers[li].w_up);
        }
    }
}
