//! Atom (E3) — Zhao et al., MLSys 2024 — mechanism re-implementation.
//!
//! Core ideas preserved: (i) *group-wise* low-bit weight quantization
//! (each contiguous group along the input dim gets its own scale/zero),
//! (ii) *outlier channels* identified from calibration are kept at 8 bits,
//! (iii) activations are quantized *per token*. This is the strongest of
//! the three baselines in the paper (and here), and also the compression
//! framework OPSC builds on (paper footnote 7).

use crate::model::ModelWeights;

use super::super::aiq;
use super::{ActQuantMode, CalibStats, QuantMethod};

pub struct Atom {
    pub weight_bits: u32,
    pub act_bits: u32,
    pub group_size: usize,
    /// Fraction of input channels kept at 8-bit precision.
    pub outlier_frac: f32,
}

impl Atom {
    pub fn new(weight_bits: u32, act_bits: u32) -> Self {
        Atom { weight_bits, act_bits, group_size: 32, outlier_frac: 0.03 }
    }
}

/// Group-wise fake-quant along rows (input channels) of a (rows x cols)
/// matrix; rows listed in `outliers` get 8-bit precision instead.
pub fn groupwise_fq(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    group: usize,
    bits: u32,
    outliers: &[bool],
) {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(outliers.len(), rows);
    for c in 0..cols {
        let mut g0 = 0;
        while g0 < rows {
            let g1 = (g0 + group).min(rows);
            // split the group into outlier and normal rows, quantized
            // separately (8-bit vs `bits`)
            for &is_out in &[false, true] {
                let (mut tmin, mut tmax) = (f32::INFINITY, f32::NEG_INFINITY);
                let mut any = false;
                for r in g0..g1 {
                    if outliers[r] == is_out {
                        let x = w[r * cols + c];
                        tmin = tmin.min(x);
                        tmax = tmax.max(x);
                        any = true;
                    }
                }
                if !any {
                    continue;
                }
                let b = if is_out { 8 } else { bits };
                let p = aiq::params_for_range(tmin, tmax, b);
                for r in g0..g1 {
                    if outliers[r] == is_out {
                        let x = &mut w[r * cols + c];
                        *x = aiq::dequantize_one(aiq::quantize_one(*x, &p), &p);
                    }
                }
            }
            g0 = g1;
        }
    }
}

/// Weight-derived outlier mask: input channels (rows) whose absolute
/// maximum is far above the median get 8-bit treatment. Used when no
/// activation calibration applies (e.g. FFN-internal dims) and by OPSC,
/// which builds on Atom's scheme (paper footnote 7).
pub fn weight_outlier_mask(w: &[f32], rows: usize, cols: usize, ratio: f32) -> Vec<bool> {
    assert_eq!(w.len(), rows * cols);
    let mut absmax = vec![0f32; rows];
    for (r, am) in absmax.iter_mut().enumerate() {
        for c in 0..cols {
            *am = am.max(w[r * cols + c].abs());
        }
    }
    let mut sorted = absmax.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[rows / 2].max(1e-8);
    absmax.iter().map(|&m| m > ratio * median).collect()
}

/// Pick the top-k activation channels as outliers from calibration stats.
pub fn outlier_mask(absmax: &[f32], frac: f32) -> Vec<bool> {
    let n = absmax.len();
    let k = ((n as f32 * frac).ceil() as usize).min(n);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| absmax[b].partial_cmp(&absmax[a]).unwrap());
    let mut mask = vec![false; n];
    for &i in idx.iter().take(k) {
        mask[i] = true;
    }
    mask
}

impl QuantMethod for Atom {
    fn name(&self) -> &'static str {
        "Atom"
    }

    fn quantize_weights(&self, w: &mut ModelWeights, stats: &CalibStats) {
        let d = w.cfg.d_model;
        let f = w.cfg.d_ff;
        for (li, lw) in w.layers.iter_mut().enumerate() {
            let am = &stats.input_absmax[li.min(stats.input_absmax.len() - 1)];
            let mask_d = outlier_mask(am, self.outlier_frac);
            let g = self.group_size;
            let b = self.weight_bits;
            groupwise_fq(&mut lw.wq, d, d, g, b, &mask_d);
            groupwise_fq(&mut lw.wk, d, d, g, b, &mask_d);
            groupwise_fq(&mut lw.wv, d, d, g, b, &mask_d);
            groupwise_fq(&mut lw.wo, d, d, g, b, &mask_d);
            groupwise_fq(&mut lw.w_gate, d, f, g, b, &mask_d);
            groupwise_fq(&mut lw.w_up, d, f, g, b, &mask_d);
            // w_down's input is the FFN hidden dim — no activation
            // calibration there; Atom detects its outlier rows from the
            // weights themselves (the boosted channels that create the
            // model's large activations).
            let mask_f = weight_outlier_mask(&lw.w_down, f, d, 40.0);
            groupwise_fq(&mut lw.w_down, f, d, g, b, &mask_f);
        }
    }

    fn act_mode(&self) -> ActQuantMode {
        // ~3% of channels ride the high-precision outlier path
        ActQuantMode::PerToken { bits: self.act_bits, keep_top: 4 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::util::rng::Rng;

    #[test]
    fn outlier_mask_selects_top_channels() {
        let absmax = vec![1.0, 50.0, 2.0, 100.0];
        let m = outlier_mask(&absmax, 0.5);
        assert_eq!(m, vec![false, true, false, true]);
    }

    #[test]
    fn outlier_rows_get_higher_precision() {
        let rows = 64;
        let cols = 8;
        let mut rng = Rng::new(6);
        let mut w = vec![0f32; rows * cols];
        rng.fill_normal(&mut w, 0.5);
        let orig = w.clone();
        let mut mask = vec![false; rows];
        mask[5] = true;
        groupwise_fq(&mut w, rows, cols, 16, 3, &mask);
        // row 5 (8-bit) must be much closer than its 3-bit group-mates
        let err = |r: usize| -> f64 {
            (0..cols).map(|c| ((w[r * cols + c] - orig[r * cols + c]) as f64).abs()).sum()
        };
        let e5 = err(5);
        let e_others: f64 = (0..16).filter(|&r| r != 5).map(err).sum::<f64>() / 15.0;
        assert!(e5 < e_others / 4.0, "outlier {e5} vs avg {e_others}");
    }

    #[test]
    fn groupwise_beats_per_tensor_on_heterogeneous_rows() {
        // rows alternate tiny/huge scale in different groups
        let rows = 64;
        let cols = 4;
        let mut w = vec![0f32; rows * cols];
        let mut rng = Rng::new(7);
        for r in 0..rows {
            let s = if r < 32 { 0.01 } else { 10.0 };
            for c in 0..cols {
                w[r * cols + c] = rng.normal_f32(0.0, s);
            }
        }
        let orig = w.clone();
        let mask = vec![false; rows];
        let mut grouped = w.clone();
        groupwise_fq(&mut grouped, rows, cols, 32, 4, &mask);
        let mut per_tensor = w;
        aiq::fake_quant(&mut per_tensor, 4);
        // the small-scale rows are where group-wise scales pay off:
        // per-tensor uses the huge-row range there and wipes them out
        let mse_small = |q: &[f32]| -> f64 {
            (0..32 * cols).map(|i| ((q[i] - orig[i]) as f64).powi(2)).sum()
        };
        assert!(
            mse_small(&grouped) < mse_small(&per_tensor) / 100.0,
            "{} vs {}",
            mse_small(&grouped),
            mse_small(&per_tensor)
        );
    }

    #[test]
    fn full_model_quantization_runs() {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let mut w = ModelWeights::synthetic(&cfg, 8);
        let orig = w.clone();
        let st = CalibStats::from_weights(&w);
        Atom::new(4, 4).quantize_weights(&mut w, &st);
        assert_ne!(w.layers[0].wq, orig.layers[0].wq);
        assert_ne!(w.layers[1].w_down, orig.layers[1].w_down);
    }

    #[test]
    fn act_mode_is_per_token() {
        assert_eq!(
            Atom::new(4, 4).act_mode(),
            ActQuantMode::PerToken { bits: 4, keep_top: 4 }
        );
    }
}
