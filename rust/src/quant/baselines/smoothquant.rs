//! SmoothQuant (E1) — Xiao et al., ICML 2023 — mechanism re-implementation.
//!
//! Core idea preserved: activation outliers are migrated into the weights
//! via per-channel smoothing factors s_j = max|X_j|^alpha / max|W_j|^(1-alpha),
//! then both sides are uniformly quantized. Quantizing W·diag(s) instead of
//! W (and X·diag(1/s) instead of X) is what buys accuracy at W8A8 and loses
//! it at aggressive W4A4/A3 — exactly the regime Table 3 probes.
//!
//! Simplification (DESIGN.md §3.4): smoothing + fake-quant is applied in the
//! smoothed basis and mapped back (W ← diag(1/s)·FQ(diag(s)·W)), and
//! activation quantization is per-tensor at the residual stream, because the
//! per-projection inputs live inside the AOT'd layer artifact.

use crate::model::ModelWeights;

use super::super::aiq;
use super::{ActQuantMode, CalibStats, QuantMethod};

pub struct SmoothQuant {
    pub alpha: f32,
    pub weight_bits: u32,
    pub act_bits: u32,
}

impl SmoothQuant {
    pub fn new(weight_bits: u32, act_bits: u32) -> Self {
        SmoothQuant { alpha: 0.5, weight_bits, act_bits }
    }
}

/// Smooth + fake-quant one (rows x cols) matrix whose *rows* are input
/// channels: W'[j,:] = s_j * W[j,:], fake-quant per-tensor, then divide back.
fn smooth_fq(w: &mut [f32], rows: usize, cols: usize, act_absmax: &[f32], alpha: f32, bits: u32) {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(act_absmax.len(), rows);
    // per-input-channel weight absmax
    let mut w_absmax = vec![1e-8f32; rows];
    for (r, wa) in w_absmax.iter_mut().enumerate() {
        for c in 0..cols {
            *wa = wa.max(w[r * cols + c].abs());
        }
    }
    let s: Vec<f32> = (0..rows)
        .map(|r| {
            let a = act_absmax[r].max(1e-6).powf(alpha);
            let b = w_absmax[r].powf(1.0 - alpha);
            (a / b).clamp(1e-4, 1e4)
        })
        .collect();
    for r in 0..rows {
        for c in 0..cols {
            w[r * cols + c] *= s[r];
        }
    }
    aiq::fake_quant(w, bits);
    for r in 0..rows {
        for c in 0..cols {
            w[r * cols + c] /= s[r];
        }
    }
}

impl QuantMethod for SmoothQuant {
    fn name(&self) -> &'static str {
        "SmoothQuant"
    }

    fn quantize_weights(&self, w: &mut ModelWeights, stats: &CalibStats) {
        let d = w.cfg.d_model;
        let f = w.cfg.d_ff;
        for (li, lw) in w.layers.iter_mut().enumerate() {
            let am = &stats.input_absmax[li.min(stats.input_absmax.len() - 1)];
            // projections fed by the (normed) residual stream: rows = d
            smooth_fq(&mut lw.wq, d, d, am, self.alpha, self.weight_bits);
            smooth_fq(&mut lw.wk, d, d, am, self.alpha, self.weight_bits);
            smooth_fq(&mut lw.wv, d, d, am, self.alpha, self.weight_bits);
            smooth_fq(&mut lw.w_gate, d, f, am, self.alpha, self.weight_bits);
            smooth_fq(&mut lw.w_up, d, f, am, self.alpha, self.weight_bits);
            // wo and w_down see internal activations we have no calibration
            // for; SmoothQuant leaves those per-tensor quantized.
            aiq::fake_quant(&mut lw.wo, self.weight_bits);
            aiq::fake_quant(&mut lw.w_down, self.weight_bits);
        }
    }

    fn act_mode(&self) -> ActQuantMode {
        ActQuantMode::PerTensor { bits: self.act_bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn model() -> ModelWeights {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        ModelWeights::synthetic(&cfg, 5)
    }

    #[test]
    fn smoothing_helps_under_skewed_activations() {
        // SmoothQuant's claim is about the *joint* W+A quantization error
        // of y = x @ W when x has outlier channels: migrate the outlier
        // into W, quantize both, and the matmul output error drops.
        let d = 64;
        let cols = 32;
        let n_rows = 16;
        let mut rng = crate::util::rng::Rng::new(1);
        let mut w = vec![0f32; d * cols];
        rng.fill_normal(&mut w, 0.1);
        let mut x = vec![0f32; n_rows * d];
        rng.fill_normal(&mut x, 1.0);
        for r in 0..n_rows {
            x[r * d + 3] *= 500.0; // huge activation channel
        }
        let act_absmax: Vec<f32> = (0..d)
            .map(|c| (0..n_rows).fold(0f32, |m, r| m.max(x[r * d + c].abs())))
            .collect();
        let matmul = |x: &[f32], w: &[f32]| -> Vec<f32> {
            let mut y = vec![0f32; n_rows * cols];
            for r in 0..n_rows {
                for k in 0..d {
                    let xv = x[r * d + k];
                    for c in 0..cols {
                        y[r * cols + c] += xv * w[k * cols + c];
                    }
                }
            }
            y
        };
        let y_ref = matmul(&x, &w);

        // naive: quantize x per-tensor @ 8b, w per-tensor @ 8b
        let mut xq = x.clone();
        aiq::fake_quant(&mut xq, 8);
        let mut wq = w.clone();
        aiq::fake_quant(&mut wq, 8);
        let y_naive = matmul(&xq, &wq);

        // smoothed: x/s and s*w, both quantized @ 8b
        let alpha = 0.5f32;
        let mut w_absmax = vec![1e-8f32; d];
        for (r, wa) in w_absmax.iter_mut().enumerate() {
            for c in 0..cols {
                *wa = wa.max(w[r * cols + c].abs());
            }
        }
        let s: Vec<f32> = (0..d)
            .map(|r| {
                (act_absmax[r].max(1e-6).powf(alpha) / w_absmax[r].powf(1.0 - alpha))
                    .clamp(1e-4, 1e4)
            })
            .collect();
        let mut xs = x.clone();
        for r in 0..n_rows {
            for k in 0..d {
                xs[r * d + k] /= s[k];
            }
        }
        let mut ws = w.clone();
        for r in 0..d {
            for c in 0..cols {
                ws[r * cols + c] *= s[r];
            }
        }
        aiq::fake_quant(&mut xs, 8);
        aiq::fake_quant(&mut ws, 8);
        let y_smooth = matmul(&xs, &ws);

        let mse = |y: &[f32]| -> f64 {
            y.iter().zip(&y_ref).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        assert!(
            mse(&y_smooth) < mse(&y_naive) / 2.0,
            "{} vs {}",
            mse(&y_smooth),
            mse(&y_naive)
        );
    }

    #[test]
    fn quantize_weights_changes_all_matmuls() {
        let mut w = model();
        let orig = w.clone();
        let st = CalibStats::from_weights(&w);
        SmoothQuant::new(4, 4).quantize_weights(&mut w, &st);
        assert_ne!(w.layers[0].wq, orig.layers[0].wq);
        assert_ne!(w.layers[0].w_down, orig.layers[0].w_down);
        assert_eq!(w.layers[0].g1, orig.layers[0].g1); // norms untouched
    }

    #[test]
    fn act_mode_is_per_tensor() {
        assert_eq!(
            SmoothQuant::new(4, 3).act_mode(),
            ActQuantMode::PerTensor { bits: 3 }
        );
    }
}
