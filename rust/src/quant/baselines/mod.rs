//! Baseline LLM quantization methods re-implemented for the paper's
//! comparison (Table 2/3): SmoothQuant (E1), OmniQuant (E2), Atom (E3).
//!
//! Each baseline transforms + fake-quantizes the model weights in place
//! and declares its activation-quantization mode, which the eval pipeline
//! applies to the residual stream at layer boundaries. See DESIGN.md §3.4
//! for what is preserved vs simplified relative to the original systems.

pub mod atom;
pub mod omniquant;
pub mod smoothquant;

pub use atom::Atom;
pub use omniquant::OmniQuant;
pub use smoothquant::SmoothQuant;

use crate::model::ModelWeights;

/// How a method quantizes activations on the request path. The pipeline
/// applies this to the hidden state between decoder layers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActQuantMode {
    /// Full-precision activations.
    None,
    /// One (scale, zero) per tensor — SmoothQuant/OmniQuant style.
    PerTensor { bits: u32 },
    /// One (scale, zero) per token row, with the `keep_top` largest
    /// magnitudes per row kept at full precision — Atom's runtime outlier
    /// handling (its activation outliers ride a high-precision path).
    /// keep_top = 0 degrades to naive per-token quant.
    PerToken { bits: u32, keep_top: usize },
}

impl ActQuantMode {
    /// Fake-quant a (rows x cols) activation block in place.
    pub fn apply(&self, h: &mut [f32], rows: usize, cols: usize) {
        match *self {
            ActQuantMode::None => {}
            ActQuantMode::PerTensor { bits } => super::aiq::fake_quant(h, bits),
            ActQuantMode::PerToken { bits, keep_top } => {
                assert_eq!(h.len(), rows * cols);
                let mut saved: Vec<(usize, f32)> = Vec::with_capacity(keep_top);
                for r in 0..rows {
                    let row = &mut h[r * cols..(r + 1) * cols];
                    saved.clear();
                    if keep_top > 0 {
                        // select the keep_top largest |values|, zero them
                        // out of the quantized bulk (they travel at full
                        // precision on Atom's outlier path)
                        let mut idx: Vec<usize> = (0..cols).collect();
                        idx.sort_by(|&a, &b| row[b].abs().partial_cmp(&row[a].abs()).unwrap());
                        for &i in idx.iter().take(keep_top) {
                            saved.push((i, row[i]));
                            row[i] = 0.0;
                        }
                    }
                    super::aiq::fake_quant(row, bits);
                    for &(i, v) in &saved {
                        row[i] = v;
                    }
                }
            }
        }
    }
}

/// Per-layer calibration statistics collected on a handful of prompts with
/// the FP model: per-channel absolute maxima of each layer's input
/// (residual stream), used by SmoothQuant's smoothing factors and Atom's
/// outlier-channel selection.
#[derive(Clone, Debug)]
pub struct CalibStats {
    /// [layer][channel] -> max |x| observed at the layer input.
    pub input_absmax: Vec<Vec<f32>>,
}

impl CalibStats {
    /// Synthetic fallback: derive plausible stats from the weights alone
    /// (used by unit tests and when no pipeline is available for a real
    /// calibration run).
    pub fn from_weights(w: &ModelWeights) -> CalibStats {
        let d = w.cfg.d_model;
        let input_absmax = w
            .layers
            .iter()
            .map(|lw| {
                // activation scale proxy: column norms of the previous
                // layer's down-projection (what feeds the residual stream)
                let f = w.cfg.d_ff;
                let mut m = vec![0f32; d];
                for (ch, mi) in m.iter_mut().enumerate() {
                    for r in 0..f {
                        *mi = mi.max(lw.w_down[r * d + ch].abs());
                    }
                    *mi *= 3.0; // ~ activation magnitude at unit input
                }
                m
            })
            .collect();
        CalibStats { input_absmax }
    }
}

/// Common interface of the three baselines + OPSC ("Ours") so the bench
/// harnesses can sweep methods uniformly.
pub trait QuantMethod {
    fn name(&self) -> &'static str;
    /// Transform + fake-quantize weights in place.
    fn quantize_weights(&self, w: &mut ModelWeights, stats: &CalibStats);
    /// Activation treatment on the request path.
    fn act_mode(&self) -> ActQuantMode;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    #[test]
    fn per_token_mode_isolates_rows() {
        let cols = 16;
        let mut h = vec![0f32; 2 * cols];
        for c in 0..cols {
            h[c] = 0.001 * c as f32;
            h[cols + c] = 100.0 * c as f32;
        }
        let orig = h.clone();
        ActQuantMode::PerToken { bits: 4, keep_top: 0 }.apply(&mut h, 2, cols);
        let err0: f32 = (0..cols).map(|c| (h[c] - orig[c]).abs()).sum();
        assert!(err0 < 0.01, "row-0 err {err0}");
    }

    #[test]
    fn none_mode_is_identity() {
        let mut h = vec![1.0f32, -2.0, 3.0];
        let orig = h.clone();
        ActQuantMode::None.apply(&mut h, 1, 3);
        assert_eq!(h, orig);
    }

    #[test]
    fn calib_from_weights_shapes() {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 3;
        let w = ModelWeights::synthetic(&cfg, 1);
        let st = CalibStats::from_weights(&w);
        assert_eq!(st.input_absmax.len(), 3);
        assert_eq!(st.input_absmax[0].len(), cfg.d_model);
        assert!(st.input_absmax[0].iter().all(|&x| x > 0.0));
    }
}
