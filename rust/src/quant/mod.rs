//! Quantization and compression: the paper's OPSC + TS + TAB-Q stack,
//! the rANS entropy coder, and the baseline methods (Table 2/3).

pub mod aiq;
pub mod baselines;
pub mod fused;
pub mod opsc;
pub mod rans;
pub mod tabq;
pub mod ts;

pub use aiq::{fake_quant, fake_quant_per_channel, qmax, QuantParams};
pub use fused::{compress_fused, CompressionScratch, FusedOutput, ScratchPool};
pub use opsc::{apply_opsc, apply_segment_quant, apply_segment_quant_naive, OpscConfig};
pub use tabq::{tabq_adaptive, tabq_fixed, TabqBlock};
pub use ts::{recombine, threshold_split, SparseOutliers};
