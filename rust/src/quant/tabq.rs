//! TAB-Q: token-wise adaptive bit integer quantization (paper Algorithm 1).
//!
//! The intermediate activations `T` (w tokens x n features, already stripped
//! of outliers by threshold splitting) are quantized *token-wise*: each row
//! gets its own (scale, zero) so relative importance disparities between
//! tokens survive quantization. The sign is carried separately (1 bit/elem)
//! and the magnitude is quantized at `Q` bits.
//!
//! The adaptive part: start from the bit budget `q_bar - 1` (one bit
//! reserved for the sign, Alg. 1 line 4), then keep reducing `Q` while the
//! code-domain distortion
//!
//!   delta = mean | round(T0_codes / 2^(Qbar - Q)) - T_codes |
//!
//! stays within the tolerance `Delta`; return the *last acceptable* level.
//! (Alg. 1 as printed returns the first violating tensor; returning the
//! last acceptable one is the only reading consistent with the stated goal
//! "terminating as soon as delta surpasses Delta ... avoids excessive
//! distortion" — documented deviation.)

use super::aiq::{self, QuantParams};

/// A token-wise quantized activation block, ready for entropy coding.
#[derive(Clone, Debug)]
pub struct TabqBlock {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    /// Quantized magnitudes, row-major, values in [0, qmax(bits)].
    pub codes: Vec<u16>,
    /// Per-token scale/zero (len = rows).
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    /// Sign bitset, row-major, 1 = negative (len = ceil(rows*cols/8)).
    pub signs: Vec<u8>,
}

impl TabqBlock {
    /// Bit-exact wire size: packed codes + sign bits + per-token params.
    pub fn payload_bytes(&self) -> u64 {
        let n = (self.rows * self.cols) as u64;
        let code_bits = n * self.bits as u64;
        let sign_bits = n;
        crate::util::bits_to_bytes(code_bits)
            + crate::util::bits_to_bytes(sign_bits)
            + (self.rows as u64) * 8 // f32 scale + f32 zero per token
            + 4 // header: rows u16, cols u16 (bits ride in the header byte)
    }

    /// Dequantize back to dense f32 (Eq. 7 applied per token, sign restored).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let p = QuantParams { scale: self.scales[r], zero: self.zeros[r], bits: self.bits };
            for c in 0..self.cols {
                let i = r * self.cols + c;
                let mag = aiq::dequantize_one(self.codes[i], &p);
                let neg = self.signs[i / 8] >> (i % 8) & 1 == 1;
                out[i] = if neg { -mag } else { mag };
            }
        }
        out
    }

    /// Serialize codes as packed bits (pre-entropy-coding wire format).
    pub fn packed_codes(&self) -> Vec<u8> {
        aiq::pack_codes(&self.codes, self.bits)
    }
}

/// Precomputed magnitude decomposition shared across the adaptive search:
/// |t|, the sign bitset, and per-row (min, max) of |t| are independent of
/// the candidate bit width, so the bit-reduction loop never rescans `t`.
struct MagStats {
    rows: usize,
    cols: usize,
    mags: Vec<f32>,
    signs: Vec<u8>,
    row_ranges: Vec<(f32, f32)>,
}

impl MagStats {
    fn compute(t: &[f32], rows: usize, cols: usize) -> MagStats {
        assert_eq!(t.len(), rows * cols);
        let mut mags = vec![0f32; rows * cols];
        let mut signs = vec![0u8; (rows * cols).div_ceil(8)];
        let mut row_ranges = Vec::with_capacity(rows);
        for r in 0..rows {
            let (mut mmin, mut mmax) = (f32::INFINITY, f32::NEG_INFINITY);
            for c in 0..cols {
                let i = r * cols + c;
                let x = t[i];
                let m = x.abs();
                mags[i] = m;
                mmin = mmin.min(m);
                mmax = mmax.max(m);
                if x < 0.0 {
                    signs[i / 8] |= 1 << (i % 8);
                }
            }
            row_ranges.push((mmin, mmax));
        }
        MagStats { rows, cols, mags, signs, row_ranges }
    }

    /// One AIQ pass at `bits` over the precomputed magnitudes.
    fn quantize(&self, bits: u32) -> TabqBlock {
        assert!((1..=15).contains(&bits), "magnitude bits must leave room for sign");
        let (rows, cols) = (self.rows, self.cols);
        let qmax_f = aiq::qmax(bits) as f32;
        let mut codes = vec![0u16; rows * cols];
        let mut scales = vec![0f32; rows];
        let mut zeros = vec![0f32; rows];
        for r in 0..rows {
            let (mmin, mmax) = self.row_ranges[r];
            let p = aiq::params_for_range(mmin, mmax, bits);
            scales[r] = p.scale;
            zeros[r] = p.zero;
            let inv_s = 1.0 / p.scale;
            let z = p.zero;
            let base = r * cols;
            for c in 0..cols {
                // inlined quantize_one: mags are pre-|.|'d, params fixed
                let q = (self.mags[base + c] * inv_s + z).round();
                codes[base + c] = q.clamp(0.0, qmax_f) as u16;
            }
        }
        TabqBlock { rows, cols, bits, codes, scales, zeros, signs: self.signs.clone() }
    }
}

/// Fixed-bit token-wise quantization (Alg. 1 lines 1-5, one AIQ pass).
pub fn tabq_fixed(t: &[f32], rows: usize, cols: usize, bits: u32) -> TabqBlock {
    MagStats::compute(t, rows, cols).quantize(bits)
}

/// Result of the adaptive search: chosen block + the distortion trace.
#[derive(Clone, Debug)]
pub struct TabqAdaptive {
    pub block: TabqBlock,
    /// (bits, delta) evaluated during the search, in visit order.
    pub trace: Vec<(u32, f64)>,
}

/// Paper Algorithm 1: adaptively reduce the magnitude bit width from
/// `q_bar - 1` down to `min_bits` while the code-domain distortion delta
/// stays within `delta_tol`. Returns the last acceptable quantization.
///
/// `q_bar` is the total activation bit budget (sign included), matching the
/// paper's Q̄a; e.g. q_bar = 4 starts the magnitude search at 3 bits.
pub fn tabq_adaptive(
    t: &[f32],
    rows: usize,
    cols: usize,
    q_bar: u32,
    delta_tol: f64,
) -> TabqAdaptive {
    assert!((2..=16).contains(&q_bar), "q_bar must be in 2..=16");
    let min_bits = 1;
    let start_bits = (q_bar - 1).max(min_bits); // line 4: one bit for the sign
    // magnitudes / signs / row ranges are bit-width independent — compute
    // them once for the whole search (the §Perf hot-path optimization)
    let stats = MagStats::compute(t, rows, cols);
    let t0 = stats.quantize(start_bits);
    let mut trace = Vec::new();
    let mut best = t0.clone();
    let mut bits = start_bits;
    while bits > min_bits {
        bits -= 1;
        let cand = stats.quantize(bits);
        let shift = start_bits - bits;
        let n = (rows * cols) as f64;
        // delta = mean | round(T0 / 2^shift) - T | in code units (line 9).
        let mut acc = 0f64;
        for (a, b) in t0.codes.iter().zip(&cand.codes) {
            let rescaled = ((*a as f64) / f64::from(1u32 << shift)).round();
            acc += (rescaled - *b as f64).abs();
        }
        let delta = acc / n;
        trace.push((bits, delta));
        if delta > delta_tol {
            break; // lines 10-13: tolerance exceeded — keep last acceptable
        }
        best = cand;
    }
    TabqAdaptive { block: best, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;

    fn rand_acts(rng: &mut crate::util::rng::Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
        (0..rows * cols).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    #[test]
    fn fixed_roundtrip_error_bounded() {
        run_cases(100, 0xB1, |_, rng| {
            let rows = 1 + rng.below(16);
            let cols = 8 + rng.below(120);
            let bits = 3 + rng.below(6) as u32;
            let t = rand_acts(rng, rows, cols, 2.0);
            let blk = tabq_fixed(&t, rows, cols, bits);
            let back = blk.dequantize();
            for r in 0..rows {
                let s = blk.scales[r];
                for c in 0..cols {
                    let i = r * cols + c;
                    assert!(
                        (back[i] - t[i]).abs() <= s * 0.5 + 1e-4,
                        "row {r} err {} scale {s}",
                        (back[i] - t[i]).abs()
                    );
                }
            }
        });
    }

    #[test]
    fn signs_restored_exactly() {
        run_cases(50, 0xB2, |_, rng| {
            let t = rand_acts(rng, 4, 64, 1.0);
            let blk = tabq_fixed(&t, 4, 64, 4);
            let back = blk.dequantize();
            for (a, b) in t.iter().zip(&back) {
                // sign must match wherever the dequantized magnitude is nonzero
                if b.abs() > 1e-9 {
                    assert_eq!(a.signum(), b.signum(), "a={a} b={b}");
                }
            }
        });
    }

    #[test]
    fn per_token_scales_isolate_rows() {
        // row 0 tiny, row 1 huge: row 0's quant error must stay tiny.
        let cols = 32;
        let mut t = vec![0f32; 2 * cols];
        for c in 0..cols {
            t[c] = 0.001 * (c as f32 / cols as f32);
            t[cols + c] = 500.0 * (c as f32 / cols as f32);
        }
        let blk = tabq_fixed(&t, 2, cols, 4);
        let back = blk.dequantize();
        let err0: f32 = (0..cols).map(|c| (back[c] - t[c]).abs()).sum();
        assert!(err0 < 0.01, "row-0 err {err0}");
    }

    #[test]
    fn adaptive_respects_tolerance_trace() {
        run_cases(40, 0xB3, |_, rng| {
            let t = rand_acts(rng, 8, 64, 3.0);
            let ad = tabq_adaptive(&t, 8, 64, 8, 0.2);
            // every trace entry except possibly the last is within tolerance
            for (i, (_, d)) in ad.trace.iter().enumerate() {
                if i + 1 < ad.trace.len() {
                    assert!(*d <= 0.2, "non-final delta {d} out of tolerance");
                }
            }
            // chosen bits is never below 1 and never above q_bar-1
            assert!((1..=7).contains(&ad.block.bits));
        });
    }

    #[test]
    fn adaptive_zero_tolerance_keeps_start_bits() {
        let mut rng = crate::util::rng::Rng::new(5);
        let t = rand_acts(&mut rng, 8, 64, 3.0);
        let ad = tabq_adaptive(&t, 8, 64, 8, 0.0);
        assert_eq!(ad.block.bits, 7, "delta=0 must reject the first reduction");
    }

    #[test]
    fn adaptive_huge_tolerance_reaches_min_bits() {
        let mut rng = crate::util::rng::Rng::new(6);
        let t = rand_acts(&mut rng, 8, 64, 3.0);
        let ad = tabq_adaptive(&t, 8, 64, 8, 1e9);
        assert_eq!(ad.block.bits, 1);
    }

    #[test]
    fn payload_smaller_at_fewer_bits() {
        let mut rng = crate::util::rng::Rng::new(7);
        let t = rand_acts(&mut rng, 16, 128, 1.0);
        let b8 = tabq_fixed(&t, 16, 128, 8);
        let b3 = tabq_fixed(&t, 16, 128, 3);
        assert!(b3.payload_bytes() < b8.payload_bytes());
        // and both far below f32 dense
        assert!(b8.payload_bytes() < (16 * 128 * 4) as u64);
    }

    #[test]
    fn constant_rows_roundtrip_exactly() {
        let t = vec![[-1.5f32; 32], [2.0f32; 32]].concat();
        let blk = tabq_fixed(&t, 2, 32, 4);
        let back = blk.dequantize();
        for (a, b) in t.iter().zip(&back) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
