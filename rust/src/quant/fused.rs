//! Fused, zero-allocation TS + TAB-Q + rANS compression engine.
//!
//! This is the per-token hot path of the split protocol: every decode step
//! compresses the hidden row AND every cloud layer's (k, v) pair through
//! TS → TAB-Q → rANS. The composable reference path
//! (`ts::threshold_split` → `tabq::tabq_adaptive` → `rans::CodedStream`)
//! re-allocates and re-scans at each stage boundary; this module collapses
//! the stages:
//!
//!   1. **Single pass** over the input emits the CSR outliers, the
//!      magnitude buffer, the sign bitset and the per-row |t| ranges at
//!      once — no dense `below` copy is ever materialized (the reference
//!      path cloned the whole tensor just to zero the outlier slots).
//!   2. The **adaptive bit search** evaluates each candidate width
//!      *streaming*: the candidate's codes are computed element-by-element
//!      and compared against the start-width codes on the fly, so no
//!      candidate `TabqBlock` (codes + scales + cloned signs) is ever
//!      allocated. Only the chosen width is materialized, once.
//!   3. The entropy stage reuses the scratch histogram / frequency /
//!      renorm-word buffers (`rans::RansEncScratch`) and decides
//!      raw-vs-rANS from the histogram instead of encoding both.
//!
//! All intermediate buffers live in a [`CompressionScratch`] that callers
//! (EdgeDevice / CloudServer / the bench harness) reuse across decode steps
//! and KV layers via a [`ScratchPool`].
//!
//! The output is **bit-identical** to the reference path — enforced by
//! property tests here and in `coordinator::protocol` — because every
//! floating-point expression mirrors the reference implementation
//! operation-for-operation, in the same order.

use std::sync::{Mutex, OnceLock};

use super::aiq;
use super::rans::{CodedStream, RansDecScratch, RansEncScratch};
use super::ts::SparseOutliers;

/// Reusable working memory for one compression (or decompression) stream.
/// Holds every intermediate the fused engine needs: magnitude buffer,
/// per-row ranges, start-width and chosen-width code buffers, the rANS
/// encoder tables and the decoder's slot-lookup table.
#[derive(Default, Debug)]
pub struct CompressionScratch {
    mags: Vec<f32>,
    row_ranges: Vec<(f32, f32)>,
    codes0: Vec<u16>,
    codes: Vec<u16>,
    /// rANS encoder scratch (histogram, freqs, cum, renorm words).
    pub enc: RansEncScratch,
    /// rANS decoder scratch (freqs, cum, slot lookup).
    pub dec: RansDecScratch,
    /// Decode-side code buffer (decompression path).
    pub dec_codes: Vec<u16>,
}

impl CompressionScratch {
    /// Simultaneous mutable views of the decoder-side buffers (rANS
    /// tables + code buffer) for the decompression path.
    pub fn decode_parts(&mut self) -> (&mut RansDecScratch, &mut Vec<u16>) {
        (&mut self.dec, &mut self.dec_codes)
    }
}

/// Everything the wire needs from one fused compression: the lossless CSR
/// outliers, the chosen TAB-Q parameters, and the entropy-coded stream.
/// Note there is NO retained uncompressed code vector — the codes live only
/// in scratch and leave this module entropy-coded.
#[derive(Clone, Debug, PartialEq)]
pub struct FusedOutput {
    pub above: SparseOutliers,
    pub bits: u32,
    pub scales: Vec<f32>,
    pub zeros: Vec<f32>,
    pub signs: Vec<u8>,
    pub coded: CodedStream,
}

/// One TAB-Q quantization pass at `bits` over the precomputed magnitudes,
/// writing codes into a scratch buffer and per-row params into the output
/// vectors. Mirrors `tabq::MagStats::quantize` expression-for-expression.
fn quantize_rows(
    mags: &[f32],
    rows: usize,
    cols: usize,
    row_ranges: &[(f32, f32)],
    bits: u32,
    codes: &mut Vec<u16>,
    scales: &mut Vec<f32>,
    zeros: &mut Vec<f32>,
) {
    let qmax_f = aiq::qmax(bits) as f32;
    codes.clear();
    codes.resize(rows * cols, 0);
    scales.clear();
    scales.reserve(rows);
    zeros.clear();
    zeros.reserve(rows);
    for r in 0..rows {
        let (mmin, mmax) = row_ranges[r];
        let p = aiq::params_for_range(mmin, mmax, bits);
        scales.push(p.scale);
        zeros.push(p.zero);
        let inv_s = 1.0 / p.scale;
        let z = p.zero;
        let base = r * cols;
        for c in 0..cols {
            let q = (mags[base + c] * inv_s + z).round();
            codes[base + c] = q.clamp(0.0, qmax_f) as u16;
        }
    }
}

/// Fused TS + adaptive TAB-Q + entropy coding of a (rows x cols) row-major
/// tensor. Bit-identical to the reference composition
/// `threshold_split` → `tabq_adaptive` → `CodedStream::best`, without any
/// intermediate allocation beyond the wire-owned output buffers.
pub fn compress_fused(
    scratch: &mut CompressionScratch,
    t: &[f32],
    rows: usize,
    cols: usize,
    tau: f32,
    q_bar: u32,
    delta_tol: f64,
    use_rans: bool,
) -> FusedOutput {
    assert_eq!(t.len(), rows * cols);
    assert!(cols < u16::MAX as usize, "col_idx is u16");
    assert!(tau >= 0.0);
    assert!((2..=16).contains(&q_bar), "q_bar must be in 2..=16");
    let n = rows * cols;
    let CompressionScratch { mags, row_ranges, codes0, codes, enc, .. } = scratch;

    // ---- pass 1: threshold split + magnitude stats, fused ----
    // The reference path copies `t`, zeroes the outlier slots, then rescans
    // the copy for |t|, signs and per-row ranges. Here one scan emits all
    // of it; an outlier contributes a 0.0 magnitude to its row's range,
    // exactly as the zeroed slot did in the dense copy.
    mags.clear();
    mags.resize(n, 0.0);
    row_ranges.clear();
    let mut signs = vec![0u8; n.div_ceil(8)];
    let mut row_ptr = Vec::with_capacity(rows + 1);
    let mut col_idx: Vec<u16> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    row_ptr.push(0u32);
    for r in 0..rows {
        let (mut mmin, mut mmax) = (f32::INFINITY, f32::NEG_INFINITY);
        let base = r * cols;
        for c in 0..cols {
            let x = t[base + c];
            let a = x.abs();
            if a >= tau {
                col_idx.push(c as u16);
                values.push(x);
                // mags[base + c] stays 0.0; sign bit stays 0
                mmin = mmin.min(0.0);
                mmax = mmax.max(0.0);
            } else {
                mags[base + c] = a;
                mmin = mmin.min(a);
                mmax = mmax.max(a);
                if x < 0.0 {
                    let i = base + c;
                    signs[i / 8] |= 1 << (i % 8);
                }
            }
        }
        row_ptr.push(col_idx.len() as u32);
        row_ranges.push((mmin, mmax));
    }
    let above = SparseOutliers { rows, cols, row_ptr, col_idx, values };

    // ---- pass 2: quantize at the start width (Alg. 1 line 4) ----
    let min_bits = 1u32;
    let start_bits = (q_bar - 1).max(min_bits);
    let mut scales = Vec::new();
    let mut zeros = Vec::new();
    quantize_rows(mags, rows, cols, row_ranges, start_bits, codes0, &mut scales, &mut zeros);

    // ---- adaptive search: streaming candidate evaluation ----
    // delta = mean | round(T0 / 2^shift) - T_cand | in code units (Alg. 1
    // line 9); candidates are folded into the delta accumulation without
    // being stored. Accumulation order matches the reference (flat index).
    let nf = n as f64;
    let mut chosen = start_bits;
    let mut bits = start_bits;
    while bits > min_bits {
        bits -= 1;
        let div = f64::from(1u32 << (start_bits - bits));
        let qmax_f = aiq::qmax(bits) as f32;
        let mut acc = 0f64;
        for r in 0..rows {
            let (mmin, mmax) = row_ranges[r];
            let p = aiq::params_for_range(mmin, mmax, bits);
            let inv_s = 1.0 / p.scale;
            let z = p.zero;
            let base = r * cols;
            for c in 0..cols {
                let q = (mags[base + c] * inv_s + z).round();
                let cand = q.clamp(0.0, qmax_f) as u16;
                let rescaled = ((codes0[base + c] as f64) / div).round();
                acc += (rescaled - cand as f64).abs();
            }
        }
        let delta = acc / nf;
        if delta > delta_tol {
            break; // keep the last acceptable width
        }
        chosen = bits;
    }

    // ---- materialize the chosen width once ----
    let final_codes: &[u16] = if chosen == start_bits {
        codes0
    } else {
        quantize_rows(mags, rows, cols, row_ranges, chosen, codes, &mut scales, &mut zeros);
        codes
    };

    // ---- entropy stage: histogram-driven raw-vs-rANS, scratch tables ----
    let coded = if use_rans {
        CodedStream::best_with(enc, final_codes, chosen)
    } else {
        CodedStream::Raw {
            bits: chosen,
            n: final_codes.len(),
            bytes: aiq::pack_codes(final_codes, chosen),
        }
    };

    FusedOutput { above, bits: chosen, scales, zeros, signs, coded }
}

/// A small thread-safe pool of [`CompressionScratch`] arenas. Owned by
/// `EdgeDevice` / `CloudServer` so scratch survives across decode steps,
/// and shared by the scoped worker threads of the parallel KV encoder
/// (each worker takes one arena, returns it when its layers are done).
#[derive(Default, Debug)]
pub struct ScratchPool {
    pool: Mutex<Vec<Box<CompressionScratch>>>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Pop a pooled arena, or allocate a fresh one if the pool is empty
    /// (or its lock is poisoned — scratch is disposable by design).
    pub fn take(&self) -> Box<CompressionScratch> {
        self.pool
            .lock()
            .ok()
            .and_then(|mut v| v.pop())
            .unwrap_or_default()
    }

    /// Return an arena to the pool for the next step/layer.
    pub fn put(&self, s: Box<CompressionScratch>) {
        if let Ok(mut v) = self.pool.lock() {
            // bound the pool so a one-off wide fan-out can't pin memory
            if v.len() < 64 {
                v.push(s);
            }
        }
    }

    /// Run `f` with a pooled arena.
    pub fn with<R>(&self, f: impl FnOnce(&mut CompressionScratch) -> R) -> R {
        let mut s = self.take();
        let r = f(&mut s);
        self.put(s);
        r
    }
}

/// Process-wide pool backing the allocation-free convenience APIs
/// (`CompressedTensor::compress` and friends) so benches and one-off
/// callers get scratch reuse without threading a pool through.
pub fn global_pool() -> &'static ScratchPool {
    static POOL: OnceLock<ScratchPool> = OnceLock::new();
    POOL.get_or_init(ScratchPool::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rans::CodedStream;
    use crate::quant::{tabq_adaptive, threshold_split};
    use crate::util::prop::run_cases;
    use crate::util::rng::Rng;

    /// The unfused reference composition the engine must match bit-for-bit.
    fn reference(
        t: &[f32],
        rows: usize,
        cols: usize,
        tau: f32,
        q_bar: u32,
        delta: f64,
        use_rans: bool,
    ) -> FusedOutput {
        let (above, below) = threshold_split(t, rows, cols, tau);
        let ad = tabq_adaptive(&below, rows, cols, q_bar, delta);
        let coded = if use_rans {
            CodedStream::best(&ad.block.codes, ad.block.bits)
        } else {
            CodedStream::Raw {
                bits: ad.block.bits,
                n: ad.block.codes.len(),
                bytes: crate::quant::aiq::pack_codes(&ad.block.codes, ad.block.bits),
            }
        };
        FusedOutput {
            above,
            bits: ad.block.bits,
            scales: ad.block.scales,
            zeros: ad.block.zeros,
            signs: ad.block.signs,
            coded,
        }
    }

    #[test]
    fn fused_matches_reference_bitwise() {
        run_cases(80, 0xF1, |_, rng| {
            let rows = 1 + rng.below(20);
            let cols = 8 + rng.below(150);
            let tau = [0.0f32, 1.0, 5.0, 10.0][rng.below(4)];
            let q_bar = 2 + rng.below(8) as u32;
            let delta = [0.0, 0.2, 1.0, 1e9][rng.below(4)];
            let use_rans = rng.below(2) == 0;
            let t: Vec<f32> = (0..rows * cols)
                .map(|_| rng.heavy_tailed(1.0, 0.005, 120.0))
                .collect();
            let mut scratch = CompressionScratch::default();
            let fused = compress_fused(&mut scratch, &t, rows, cols, tau, q_bar, delta, use_rans);
            let want = reference(&t, rows, cols, tau, q_bar, delta, use_rans);
            assert_eq!(fused, want, "rows={rows} cols={cols} tau={tau} q_bar={q_bar}");
        });
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // one arena across wildly different shapes must not leak state
        let mut rng = Rng::new(0xF2);
        let mut scratch = CompressionScratch::default();
        for _ in 0..20 {
            let rows = 1 + rng.below(12);
            let cols = 4 + rng.below(200);
            let t: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let a = compress_fused(&mut scratch, &t, rows, cols, 5.0, 4, 0.2, true);
            let b = reference(&t, rows, cols, 5.0, 4, 0.2, true);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn all_outliers_and_no_outliers_edge_cases() {
        let t = vec![1.0f32, -2.0, 0.5, -0.25, 3.5, 0.0];
        let mut scratch = CompressionScratch::default();
        for tau in [0.0f32, 100.0] {
            let fused = compress_fused(&mut scratch, &t, 2, 3, tau, 4, 0.2, true);
            let want = reference(&t, 2, 3, tau, 4, 0.2, true);
            assert_eq!(fused, want, "tau={tau}");
        }
    }

    #[test]
    fn pool_round_trips_arenas() {
        let pool = ScratchPool::new();
        let a = pool.take();
        pool.put(a);
        let n = pool.with(|s| {
            let t = vec![0.5f32; 64];
            compress_fused(s, &t, 4, 16, 5.0, 4, 0.2, true).above.nnz()
        });
        assert_eq!(n, 0);
        assert!(global_pool().pool.lock().unwrap().len() <= 64);
    }
}
