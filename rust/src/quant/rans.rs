//! rANS entropy coder (range asymmetric numeral systems, Duda 2013).
//!
//! The paper encodes TAB-Q's "multiple quantum variables" with rANS
//! (DietGPU on their testbed). This is a from-scratch **2-way interleaved**
//! rANS with 64-bit states, 32-bit renormalization and a 12-bit quantized
//! frequency table, used to entropy-code the TAB-Q code stream before
//! transmission. Two alternating states keep the decoder's dependency
//! chain short (the DietGPU/ryg-rans trick) and the 32-bit renorm amortizes
//! the per-symbol branch 4x vs. the byte-renorm coder this replaced.
//!
//! Wire format v2 (self-describing):
//!   [n_symbols: u32][alphabet: u16][freqs: alphabet x u16]
//!   [state0: u64][state1: u64][renorm words: u32 ...]
//! Symbols are encoded in reverse with state `i & 1` serving symbol `i`, so
//! decoding streams forward alternating states. Decode is strict: the word
//! tail must be u32-aligned, fully consumed, and both states must return to
//! `RANS64_L` — which makes trailing-byte truncation and most corruptions
//! detectable (the old byte-renorm coder silently accepted a truncated
//! tail whenever the last symbols needed no refill).
//!
//! Frequency tables that cannot be normalized (more than 4096 distinct
//! symbols) are reported as `Err` instead of panicking; `CodedStream::best`
//! falls back to raw bit-packing in that case.

const SCALE_BITS: u32 = 12;
const M: u32 = 1 << SCALE_BITS; // 4096
/// Lower renormalization bound of the 64-bit states.
const RANS64_L: u64 = 1 << 31;
/// Fixed header bytes: n_symbols u32 + alphabet u16.
const HEADER: usize = 6;

/// Reusable encoder-side buffers: histogram, normalized frequency table,
/// cumulative table, and the renorm word stash. Owned by
/// `quant::fused::CompressionScratch` so repeated encodes (decode steps, KV
/// layers) never re-allocate.
#[derive(Default, Debug)]
pub struct RansEncScratch {
    hist: Vec<u64>,
    freqs: Vec<u16>,
    cum: Vec<u32>,
    words: Vec<u32>,
}

impl RansEncScratch {
    fn histogram(&mut self, symbols: &[u16], alphabet: usize) {
        self.hist.clear();
        self.hist.resize(alphabet, 0);
        for &s in symbols {
            self.hist[s as usize] += 1;
        }
    }

    fn build_cum(&mut self, alphabet: usize) {
        self.cum.clear();
        self.cum.resize(alphabet + 1, 0);
        for i in 0..alphabet {
            self.cum[i + 1] = self.cum[i] + self.freqs[i] as u32;
        }
    }
}

/// Reusable decoder-side buffers, including the M-entry slot→symbol lookup
/// table (the single largest per-decode allocation before this existed).
#[derive(Default, Debug)]
pub struct RansDecScratch {
    freqs: Vec<u16>,
    cum: Vec<u32>,
    lookup: Vec<u16>,
}

/// Quantize a histogram to sum exactly M with every present symbol >= 1.
/// Errors (instead of the former panic) when more than M distinct symbols
/// are present — no table summing to M can represent them all.
fn normalize_freqs(hist: &[u64], freqs: &mut Vec<u16>) -> anyhow::Result<()> {
    let total: u64 = hist.iter().sum();
    anyhow::ensure!(total > 0, "rans: empty histogram");
    let n = hist.len();
    let present = hist.iter().filter(|&&h| h > 0).count();
    anyhow::ensure!(
        present as u64 <= M as u64,
        "rans: {present} distinct symbols exceed the {M}-slot table"
    );
    freqs.clear();
    freqs.resize(n, 0);
    let mut assigned: u32 = 0;
    for i in 0..n {
        if hist[i] == 0 {
            continue;
        }
        let f = ((hist[i] as u128 * M as u128) / total as u128) as u32;
        let f = f.max(1).min(M - 1);
        freqs[i] = f as u16;
        assigned += f;
    }
    // Fix the rounding drift by adjusting the largest buckets.
    while assigned != M {
        if assigned < M {
            // give to the most frequent symbol
            let i = (0..n).filter(|&i| freqs[i] > 0).max_by_key(|&i| hist[i]).unwrap();
            freqs[i] += 1;
            assigned += 1;
        } else {
            // take from the largest freq that can spare it; with
            // present <= M this always exists, but never panic on it
            let i = (0..n)
                .filter(|&i| freqs[i] > 1)
                .max_by_key(|&i| freqs[i])
                .ok_or_else(|| anyhow::anyhow!("rans: cannot normalize frequency table"))?;
            freqs[i] -= 1;
            assigned -= 1;
        }
    }
    Ok(())
}

/// Estimated wire size (bytes) of the rANS stream for a histogram already
/// normalized into `freqs`: exact header cost plus the Shannon cross-entropy
/// of the stream under the quantized table. Used by `CodedStream::best` to
/// pick raw-vs-rANS WITHOUT encoding both — the estimate is deterministic,
/// so the fused engine and the reference oracle always make the same choice.
fn estimated_rans_bytes(hist: &[u64], freqs: &[u16]) -> u64 {
    let mut bits = 0f64;
    for (&h, &f) in hist.iter().zip(freqs) {
        if h > 0 {
            bits += h as f64 * (M as f64 / f as f64).log2();
        }
    }
    let payload = (bits / 8.0).ceil() as u64;
    // The two flushed u64 states carry ~8 bytes of payload between them.
    (HEADER as u64) + 2 * hist.len() as u64 + 16 + payload.saturating_sub(8)
}

/// Interleaved encode of `symbols` given a valid freqs/cum table.
/// Appends [state0][state1][reversed renorm words] to `out`.
fn encode_body(out: &mut Vec<u8>, symbols: &[u16], freqs: &[u16], cum: &[u32], words: &mut Vec<u32>) {
    words.clear();
    let mut x0: u64 = RANS64_L;
    let mut x1: u64 = RANS64_L;
    for i in (0..symbols.len()).rev() {
        let s = symbols[i] as usize;
        let f = freqs[s] as u64;
        debug_assert!(f > 0, "symbol {s} has zero frequency");
        let x = if i & 1 == 0 { &mut x0 } else { &mut x1 };
        // single 32-bit renorm suffices for u64 states with f <= M = 2^12
        let x_max = ((RANS64_L >> SCALE_BITS) << 32) * f;
        while *x >= x_max {
            words.push(*x as u32);
            *x >>= 32;
        }
        *x = ((*x / f) << SCALE_BITS) + (*x % f) + cum[s] as u64;
    }
    out.extend_from_slice(&x0.to_le_bytes());
    out.extend_from_slice(&x1.to_le_bytes());
    for w in words.iter().rev() {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

/// Encode a u16 symbol stream (wire format v2). Empty input yields a
/// minimal header. Errors when the alphabet cannot be normalized.
pub fn encode_u16(symbols: &[u16]) -> anyhow::Result<Vec<u8>> {
    let mut scratch = RansEncScratch::default();
    encode_u16_with(&mut scratch, symbols)
}

/// Serialize the full stream (header + freq table + states + words) for a
/// scratch whose freq table is already normalized. THE single writer of the
/// v2 wire layout — both `encode_u16_with` and `CodedStream::best_with` go
/// through here, so the fused-vs-reference bit-identity can't drift.
fn write_stream(scratch: &mut RansEncScratch, symbols: &[u16], alphabet: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(symbols.len() / 2 + HEADER + 2 * alphabet + 16);
    out.extend_from_slice(&(symbols.len() as u32).to_le_bytes());
    out.extend_from_slice(&(alphabet as u16).to_le_bytes());
    scratch.build_cum(alphabet);
    for &f in &scratch.freqs[..alphabet] {
        out.extend_from_slice(&f.to_le_bytes());
    }
    let (freqs, cum) = (&scratch.freqs[..alphabet], &scratch.cum[..alphabet + 1]);
    encode_body(&mut out, symbols, freqs, cum, &mut scratch.words);
    out
}

/// Scratch-reusing variant of [`encode_u16`]: identical bytes, no
/// per-call table/word allocations.
pub fn encode_u16_with(scratch: &mut RansEncScratch, symbols: &[u16]) -> anyhow::Result<Vec<u8>> {
    let alphabet = symbols.iter().map(|&s| s as usize + 1).max().unwrap_or(1);
    anyhow::ensure!(alphabet <= u16::MAX as usize, "rans: symbol {} overflows the u16 alphabet header", alphabet - 1);
    if symbols.is_empty() {
        let mut out = Vec::with_capacity(HEADER);
        out.extend_from_slice(&0u32.to_le_bytes());
        out.extend_from_slice(&(alphabet as u16).to_le_bytes());
        return Ok(out);
    }
    scratch.histogram(symbols, alphabet);
    normalize_freqs(&scratch.hist, &mut scratch.freqs)?;
    Ok(write_stream(scratch, symbols, alphabet))
}

fn take2(b: &[u8], at: usize) -> anyhow::Result<[u8; 2]> {
    b.get(at..at + 2)
        .map(|s| s.try_into().unwrap())
        .ok_or_else(|| anyhow::anyhow!("rans: truncated stream at byte {at}"))
}

fn take4(b: &[u8], at: usize) -> anyhow::Result<[u8; 4]> {
    b.get(at..at + 4)
        .map(|s| s.try_into().unwrap())
        .ok_or_else(|| anyhow::anyhow!("rans: truncated stream at byte {at}"))
}

fn take8(b: &[u8], at: usize) -> anyhow::Result<[u8; 8]> {
    b.get(at..at + 8)
        .map(|s| s.try_into().unwrap())
        .ok_or_else(|| anyhow::anyhow!("rans: truncated stream at byte {at}"))
}

/// Decode a stream produced by `encode_u16`.
pub fn decode_u16(bytes: &[u8]) -> anyhow::Result<Vec<u16>> {
    let mut scratch = RansDecScratch::default();
    let mut out = Vec::new();
    decode_u16_with(&mut scratch, bytes, &mut out)?;
    Ok(out)
}

/// Scratch-reusing decode into `out` (cleared first). The slot-lookup
/// table, frequency table and cumulative table live in `scratch` and are
/// reused across decode steps / KV layers.
pub fn decode_u16_with(
    scratch: &mut RansDecScratch,
    bytes: &[u8],
    out: &mut Vec<u16>,
) -> anyhow::Result<()> {
    use anyhow::{bail, ensure};
    out.clear();
    let n_symbols = u32::from_le_bytes(take4(bytes, 0)?) as usize;
    let alphabet = u16::from_le_bytes(take2(bytes, 4)?) as usize;
    if n_symbols == 0 {
        ensure!(bytes.len() == HEADER, "rans: trailing bytes after empty stream");
        return Ok(());
    }
    if alphabet == 0 {
        bail!("rans: zero alphabet with nonzero symbol count");
    }
    scratch.freqs.clear();
    scratch.freqs.resize(alphabet, 0);
    let mut at = HEADER;
    for i in 0..alphabet {
        scratch.freqs[i] = u16::from_le_bytes(take2(bytes, at)?);
        at += 2;
    }
    scratch.cum.clear();
    scratch.cum.resize(alphabet + 1, 0);
    let mut acc: u64 = 0; // u64: a corrupt table must not overflow-panic
    for i in 0..alphabet {
        scratch.cum[i] = acc as u32;
        acc += scratch.freqs[i] as u64;
        ensure!(acc <= M as u64, "rans: corrupt frequency table (sum exceeds {M})");
    }
    ensure!(acc == M as u64, "rans: corrupt frequency table (sum {acc} != {M})");
    scratch.cum[alphabet] = M;
    // slot -> symbol lookup
    scratch.lookup.clear();
    scratch.lookup.resize(M as usize, 0);
    for s in 0..alphabet {
        for slot in scratch.cum[s]..scratch.cum[s + 1] {
            scratch.lookup[slot as usize] = s as u16;
        }
    }
    let mut x0 = u64::from_le_bytes(take8(bytes, at)?);
    at += 8;
    let mut x1 = u64::from_le_bytes(take8(bytes, at)?);
    at += 8;
    // The renorm tail is a whole number of u32 words; a truncated stream
    // breaks the alignment and is rejected up front.
    ensure!(
        (bytes.len() - at) % 4 == 0,
        "rans: truncated stream (renorm tail not word-aligned)"
    );
    out.reserve(n_symbols);
    for i in 0..n_symbols {
        let x = if i & 1 == 0 { &mut x0 } else { &mut x1 };
        let slot = (*x as u32) & (M - 1);
        let s = scratch.lookup[slot as usize];
        let f = scratch.freqs[s as usize] as u64;
        // lookup guarantees cum[s] <= slot, so the subtraction is safe
        *x = f * (*x >> SCALE_BITS) + slot as u64 - scratch.cum[s as usize] as u64;
        if *x < RANS64_L {
            let Ok(w) = take4(bytes, at) else {
                bail!("rans: stream exhausted mid-decode");
            };
            at += 4;
            *x = (*x << 32) | u32::from_le_bytes(w) as u64;
            ensure!(*x >= RANS64_L, "rans: corrupt stream (state underflow)");
        }
        out.push(s);
    }
    ensure!(at == bytes.len(), "rans: {} unread trailing bytes", bytes.len() - at);
    ensure!(
        x0 == RANS64_L && x1 == RANS64_L,
        "rans: final state mismatch (corrupt or truncated stream)"
    );
    Ok(())
}

/// Entropy-coded-or-raw wrapper: `best` picks the representation the
/// histogram entropy estimate says is smaller (deterministic, but may
/// mispick by a few bytes near a tie — the price of not encoding both).
/// This is what the edge protocol actually puts on the wire for TAB-Q codes.
#[derive(Clone, Debug, PartialEq)]
pub enum CodedStream {
    /// Bit-packed at `bits` per code (header tag 0).
    Raw { bits: u32, n: usize, bytes: Vec<u8> },
    /// rANS-coded (header tag 1).
    Rans(Vec<u8>),
}

impl CodedStream {
    /// Choose raw-vs-rANS from the histogram (entropy estimate) and encode
    /// only the winner — the old implementation fully encoded BOTH and
    /// compared lengths. Alphabets the table cannot represent fall back to
    /// raw packing instead of panicking.
    pub fn best(codes: &[u16], bits: u32) -> CodedStream {
        let mut scratch = RansEncScratch::default();
        Self::best_with(&mut scratch, codes, bits)
    }

    /// Scratch-reusing variant of [`best`](CodedStream::best); produces
    /// byte-identical output (the decision rule and encoder are shared).
    pub fn best_with(scratch: &mut RansEncScratch, codes: &[u16], bits: u32) -> CodedStream {
        let n = codes.len();
        let raw = || CodedStream::Raw { bits, n, bytes: super::aiq::pack_codes(codes, bits) };
        if n == 0 {
            return raw();
        }
        let alphabet = codes.iter().map(|&s| s as usize + 1).max().unwrap();
        if alphabet > u16::MAX as usize {
            return raw();
        }
        scratch.histogram(codes, alphabet);
        if normalize_freqs(&scratch.hist, &mut scratch.freqs).is_err() {
            return raw(); // > M distinct symbols: un-normalizable
        }
        // wire cost: Raw = tag + (bits,n) header + packed; Rans = tag +
        // length prefix + stream (the byte codec writes an explicit u32
        // length before the rANS stream — it is not self-delimiting
        // inside a larger frame body; see `wire::codec`)
        let raw_wire = 1 + 8 + crate::util::bits_to_bytes(n as u64 * bits as u64);
        let rans_wire = 1 + 4 + estimated_rans_bytes(&scratch.hist, &scratch.freqs);
        if rans_wire >= raw_wire {
            return raw();
        }
        CodedStream::Rans(write_stream(scratch, codes, alphabet))
    }

    /// Bit-exact wire size: tag byte + representation header + stream.
    /// The rANS branch counts the u32 length prefix the byte codec writes
    /// (the stream cannot delimit itself inside a frame body).
    pub fn wire_bytes(&self) -> u64 {
        1 + match self {
            CodedStream::Raw { bytes, .. } => 8 + bytes.len() as u64,
            CodedStream::Rans(b) => 4 + b.len() as u64,
        }
    }

    pub fn decode(&self) -> anyhow::Result<Vec<u16>> {
        match self {
            CodedStream::Raw { bits, n, bytes } => Ok(super::aiq::unpack_codes(bytes, *bits, *n)),
            CodedStream::Rans(b) => decode_u16(b),
        }
    }

    /// Scratch-reusing decode into `out` (cleared first).
    pub fn decode_with(&self, scratch: &mut RansDecScratch, out: &mut Vec<u16>) -> anyhow::Result<()> {
        match self {
            CodedStream::Raw { bits, n, bytes } => {
                super::aiq::unpack_codes_into(bytes, *bits, *n, out);
                Ok(())
            }
            CodedStream::Rans(b) => decode_u16_with(scratch, b, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random_streams() {
        run_cases(100, 0xD1, |_, rng| {
            let alphabet = 1 + rng.below(255);
            let n = rng.below(2000);
            let syms: Vec<u16> = (0..n).map(|_| rng.below(alphabet) as u16).collect();
            let enc = encode_u16(&syms).unwrap();
            let dec = decode_u16(&enc).unwrap();
            assert_eq!(dec, syms);
        });
    }

    #[test]
    fn roundtrip_skewed_streams() {
        run_cases(50, 0xD2, |_, rng| {
            // geometric-ish distribution — the shape TAB-Q codes have
            let n = 500 + rng.below(2000);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let mut v = 0u16;
                    while rng.f64() < 0.55 && v < 15 {
                        v += 1;
                    }
                    v
                })
                .collect();
            let enc = encode_u16(&syms).unwrap();
            assert_eq!(decode_u16(&enc).unwrap(), syms);
        });
    }

    #[test]
    fn roundtrip_tiny_and_odd_lengths() {
        // exercise the 2-way interleave edge cases: 1-3 symbols, only one
        // state carrying payload
        for n in 1..=5usize {
            let syms: Vec<u16> = (0..n).map(|i| (i % 3) as u16).collect();
            let enc = encode_u16(&syms).unwrap();
            assert_eq!(decode_u16(&enc).unwrap(), syms, "n={n}");
        }
    }

    #[test]
    fn compresses_skewed_below_raw_packing() {
        let mut rng = Rng::new(3);
        let n = 8192;
        // 90% zeros, rest spread over 4-bit range
        let syms: Vec<u16> = (0..n)
            .map(|_| if rng.f64() < 0.9 { 0 } else { rng.below(15) as u16 + 1 })
            .collect();
        let enc = encode_u16(&syms).unwrap();
        let raw_bytes = (n * 4usize).div_ceil(8); // 4-bit packing
        assert!(
            enc.len() < raw_bytes,
            "rans {} vs raw {raw_bytes}",
            enc.len()
        );
    }

    #[test]
    fn single_symbol_stream() {
        let syms = vec![7u16; 1000];
        let enc = encode_u16(&syms).unwrap();
        assert_eq!(decode_u16(&enc).unwrap(), syms);
        // near-zero entropy: tiny payload (header dominates)
        assert!(enc.len() < 64, "len={}", enc.len());
    }

    #[test]
    fn empty_stream() {
        let enc = encode_u16(&[]).unwrap();
        assert_eq!(decode_u16(&enc).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn truncation_detected_reliably() {
        // dropping the trailing byte breaks either the fixed-size header /
        // state fields or the u32 word alignment — always an error now
        let enc = encode_u16(&[1, 2, 3, 4, 5]).unwrap();
        assert!(decode_u16(&enc[..enc.len() - 1]).is_err(), "1-byte truncation must fail");
        assert!(decode_u16(&enc[..4]).is_err());
        run_cases(30, 0xD4, |_, rng| {
            let n = 1 + rng.below(500);
            let syms: Vec<u16> = (0..n).map(|_| rng.below(12) as u16).collect();
            let enc = encode_u16(&syms).unwrap();
            for cut in 1..=4usize.min(enc.len() - 1) {
                assert!(
                    decode_u16(&enc[..enc.len() - cut]).is_err(),
                    "{cut}-byte truncation must fail (n={n})"
                );
            }
        });
    }

    #[test]
    fn corrupt_stream_errors_not_panics() {
        let enc = encode_u16(&[1, 2, 3, 4, 5]).unwrap();
        let mut bad = enc.clone();
        if bad.len() > 8 {
            bad[6] ^= 0xFF; // corrupt freq table
            assert!(decode_u16(&bad).is_err(), "corrupt freq table must error");
        }
        // appended garbage is also rejected (strict consumption)
        let mut padded = enc.clone();
        padded.extend_from_slice(&[0, 0, 0, 0]);
        assert!(decode_u16(&padded).is_err(), "trailing words must be rejected");
    }

    #[test]
    fn oversized_alphabet_errors_and_best_falls_back_to_raw() {
        // > 4096 distinct symbols cannot be normalized into the 12-bit table
        let syms: Vec<u16> = (0..5000u16).collect();
        assert!(encode_u16(&syms).is_err(), "un-normalizable alphabet must error");
        let c = CodedStream::best(&syms, 13);
        assert!(matches!(c, CodedStream::Raw { .. }), "best must fall back to raw");
        assert_eq!(c.decode().unwrap(), syms);
    }

    #[test]
    fn coded_stream_picks_smaller() {
        let mut rng = Rng::new(4);
        // uniform 8-bit codes, short stream: raw should win (header overhead)
        let uniform: Vec<u16> = (0..64).map(|_| rng.below(250) as u16).collect();
        let c = CodedStream::best(&uniform, 8);
        assert!(matches!(c, CodedStream::Raw { .. }));
        assert_eq!(c.decode().unwrap(), uniform);
        // highly skewed long stream: rans should win
        let skewed: Vec<u16> = (0..8192)
            .map(|_| if rng.f64() < 0.95 { 0u16 } else { 3 })
            .collect();
        let c = CodedStream::best(&skewed, 8);
        assert!(matches!(c, CodedStream::Rans(_)));
        assert_eq!(c.decode().unwrap(), skewed);
    }

    #[test]
    fn best_with_scratch_is_byte_identical() {
        run_cases(40, 0xD5, |_, rng| {
            let n = rng.below(3000);
            let syms: Vec<u16> = (0..n).map(|_| rng.below(16) as u16).collect();
            let a = CodedStream::best(&syms, 4);
            let mut scratch = RansEncScratch::default();
            let b = CodedStream::best_with(&mut scratch, &syms, 4);
            let c = CodedStream::best_with(&mut scratch, &syms, 4); // reuse
            assert_eq!(a, b);
            assert_eq!(b, c);
            let mut dec = RansDecScratch::default();
            let mut out = Vec::new();
            a.decode_with(&mut dec, &mut out).unwrap();
            assert_eq!(out, syms);
        });
    }

    #[test]
    fn normalize_freqs_sums_to_m() {
        let hist = vec![1u64, 100, 10_000, 0, 3];
        let mut f = Vec::new();
        normalize_freqs(&hist, &mut f).unwrap();
        assert_eq!(f.iter().map(|&x| x as u32).sum::<u32>(), M);
        assert!(f[0] >= 1 && f[4] >= 1 && f[3] == 0);
    }
}
