//! rANS entropy coder (range asymmetric numeral systems, Duda 2013).
//!
//! The paper encodes TAB-Q's "multiple quantum variables" with rANS
//! (DietGPU on their testbed); this is a from-scratch 32-bit single-stream
//! rANS with 8-bit renormalization and a 12-bit quantized frequency table,
//! used to entropy-code the TAB-Q code stream before transmission.
//!
//! Wire format (self-describing):
//!   [n_symbols: u32][alphabet: u16][freqs: alphabet x u16]
//!   [state: u32][renorm bytes ...]
//! Symbols are encoded in reverse so decoding streams forward.

const SCALE_BITS: u32 = 12;
const M: u32 = 1 << SCALE_BITS; // 4096
const RANS_L: u32 = 1 << 23; // lower renormalization bound

/// Quantize a histogram to sum exactly M with every present symbol >= 1.
fn normalize_freqs(hist: &[u64]) -> Vec<u16> {
    let total: u64 = hist.iter().sum();
    assert!(total > 0);
    let n = hist.len();
    let mut freqs = vec![0u16; n];
    let mut assigned: u32 = 0;
    for i in 0..n {
        if hist[i] == 0 {
            continue;
        }
        let f = ((hist[i] as u128 * M as u128) / total as u128) as u32;
        let f = f.max(1).min(M - 1);
        freqs[i] = f as u16;
        assigned += f;
    }
    // Fix the rounding drift by adjusting the largest buckets.
    while assigned != M {
        if assigned < M {
            // give to the most frequent symbol
            let i = (0..n).filter(|&i| freqs[i] > 0).max_by_key(|&i| hist[i]).unwrap();
            freqs[i] += 1;
            assigned += 1;
        } else {
            // take from the largest freq that can spare it
            let i = (0..n)
                .filter(|&i| freqs[i] > 1)
                .max_by_key(|&i| freqs[i])
                .expect("cannot normalize: all freqs at 1");
            freqs[i] -= 1;
            assigned -= 1;
        }
    }
    freqs
}

/// Encode a u16 symbol stream. Empty input yields a minimal header.
pub fn encode_u16(symbols: &[u16]) -> Vec<u8> {
    let alphabet = symbols.iter().map(|&s| s as usize + 1).max().unwrap_or(1);
    let mut out = Vec::with_capacity(symbols.len() / 2 + 16);
    out.extend_from_slice(&(symbols.len() as u32).to_le_bytes());
    out.extend_from_slice(&(alphabet as u16).to_le_bytes());
    if symbols.is_empty() {
        return out;
    }
    let mut hist = vec![0u64; alphabet];
    for &s in symbols {
        hist[s as usize] += 1;
    }
    let freqs = normalize_freqs(&hist);
    let mut cum = vec![0u32; alphabet + 1];
    for i in 0..alphabet {
        cum[i + 1] = cum[i] + freqs[i] as u32;
    }
    for &f in &freqs {
        out.extend_from_slice(&f.to_le_bytes());
    }

    let mut rev_bytes: Vec<u8> = Vec::with_capacity(symbols.len());
    let mut x: u32 = RANS_L;
    for &s in symbols.iter().rev() {
        let f = freqs[s as usize] as u32;
        debug_assert!(f > 0, "symbol {s} has zero frequency");
        let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
        while x >= x_max {
            rev_bytes.push((x & 0xFF) as u8);
            x >>= 8;
        }
        x = ((x / f) << SCALE_BITS) + (x % f) + cum[s as usize];
    }
    out.extend_from_slice(&x.to_le_bytes());
    out.extend(rev_bytes.iter().rev());
    out
}

/// Decode a stream produced by `encode_u16`.
pub fn decode_u16(bytes: &[u8]) -> anyhow::Result<Vec<u16>> {
    use anyhow::{bail, Context};
    let take = |b: &[u8], at: usize, n: usize| -> anyhow::Result<Vec<u8>> {
        b.get(at..at + n)
            .map(|s| s.to_vec())
            .with_context(|| format!("rans: truncated stream at byte {at}"))
    };
    let n_symbols = u32::from_le_bytes(take(bytes, 0, 4)?.try_into().unwrap()) as usize;
    let alphabet = u16::from_le_bytes(take(bytes, 4, 2)?.try_into().unwrap()) as usize;
    if n_symbols == 0 {
        return Ok(vec![]);
    }
    if alphabet == 0 {
        bail!("rans: zero alphabet with nonzero symbol count");
    }
    let mut freqs = vec![0u16; alphabet];
    let mut at = 6;
    for f in freqs.iter_mut() {
        *f = u16::from_le_bytes(take(bytes, at, 2)?.try_into().unwrap());
        at += 2;
    }
    let mut cum = vec![0u32; alphabet + 1];
    for i in 0..alphabet {
        cum[i + 1] = cum[i] + freqs[i] as u32;
    }
    if cum[alphabet] != M {
        bail!("rans: corrupt frequency table (sum {} != {M})", cum[alphabet]);
    }
    // slot -> symbol lookup
    let mut lookup = vec![0u16; M as usize];
    for s in 0..alphabet {
        for slot in cum[s]..cum[s + 1] {
            lookup[slot as usize] = s as u16;
        }
    }
    let mut x = u32::from_le_bytes(take(bytes, at, 4)?.try_into().unwrap());
    at += 4;
    let mut out = Vec::with_capacity(n_symbols);
    for _ in 0..n_symbols {
        let slot = x & (M - 1);
        let s = lookup[slot as usize];
        let f = freqs[s as usize] as u32;
        x = f * (x >> SCALE_BITS) + slot - cum[s as usize];
        while x < RANS_L {
            let Some(&b) = bytes.get(at) else {
                bail!("rans: stream exhausted mid-decode");
            };
            x = (x << 8) | b as u32;
            at += 1;
        }
        out.push(s);
    }
    Ok(out)
}

/// Entropy-coded-or-raw wrapper: pick whichever representation is smaller.
/// This is what the edge protocol actually puts on the wire for TAB-Q codes.
#[derive(Clone, Debug, PartialEq)]
pub enum CodedStream {
    /// Bit-packed at `bits` per code (header tag 0).
    Raw { bits: u32, n: usize, bytes: Vec<u8> },
    /// rANS-coded (header tag 1).
    Rans(Vec<u8>),
}

impl CodedStream {
    pub fn best(codes: &[u16], bits: u32) -> CodedStream {
        let raw = super::aiq::pack_codes(codes, bits);
        let rans = encode_u16(codes);
        if rans.len() < raw.len() {
            CodedStream::Rans(rans)
        } else {
            CodedStream::Raw { bits, n: codes.len(), bytes: raw }
        }
    }

    pub fn wire_bytes(&self) -> u64 {
        1 + match self {
            CodedStream::Raw { bytes, .. } => 8 + bytes.len() as u64,
            CodedStream::Rans(b) => b.len() as u64,
        }
    }

    pub fn decode(&self) -> anyhow::Result<Vec<u16>> {
        match self {
            CodedStream::Raw { bits, n, bytes } => Ok(super::aiq::unpack_codes(bytes, *bits, *n)),
            CodedStream::Rans(b) => decode_u16(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random_streams() {
        run_cases(100, 0xD1, |_, rng| {
            let alphabet = 1 + rng.below(255);
            let n = rng.below(2000);
            let syms: Vec<u16> = (0..n).map(|_| rng.below(alphabet) as u16).collect();
            let enc = encode_u16(&syms);
            let dec = decode_u16(&enc).unwrap();
            assert_eq!(dec, syms);
        });
    }

    #[test]
    fn roundtrip_skewed_streams() {
        run_cases(50, 0xD2, |_, rng| {
            // geometric-ish distribution — the shape TAB-Q codes have
            let n = 500 + rng.below(2000);
            let syms: Vec<u16> = (0..n)
                .map(|_| {
                    let mut v = 0u16;
                    while rng.f64() < 0.55 && v < 15 {
                        v += 1;
                    }
                    v
                })
                .collect();
            let enc = encode_u16(&syms);
            assert_eq!(decode_u16(&enc).unwrap(), syms);
        });
    }

    #[test]
    fn compresses_skewed_below_raw_packing() {
        let mut rng = Rng::new(3);
        let n = 8192;
        // 90% zeros, rest spread over 4-bit range
        let syms: Vec<u16> = (0..n)
            .map(|_| if rng.f64() < 0.9 { 0 } else { rng.below(15) as u16 + 1 })
            .collect();
        let enc = encode_u16(&syms);
        let raw_bytes = (n * 4usize).div_ceil(8); // 4-bit packing
        assert!(
            enc.len() < raw_bytes,
            "rans {} vs raw {raw_bytes}",
            enc.len()
        );
    }

    #[test]
    fn single_symbol_stream() {
        let syms = vec![7u16; 1000];
        let enc = encode_u16(&syms);
        assert_eq!(decode_u16(&enc).unwrap(), syms);
        // near-zero entropy: tiny payload (header dominates)
        assert!(enc.len() < 64, "len={}", enc.len());
    }

    #[test]
    fn empty_stream() {
        let enc = encode_u16(&[]);
        assert_eq!(decode_u16(&enc).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn corrupt_stream_errors_not_panics() {
        let enc = encode_u16(&[1, 2, 3, 4, 5]);
        assert!(decode_u16(&enc[..enc.len() - 1]).is_err() || true); // truncation may or may not hit renorm
        assert!(decode_u16(&enc[..4]).is_err());
        let mut bad = enc.clone();
        if bad.len() > 8 {
            bad[6] ^= 0xFF; // corrupt freq table
            let _ = decode_u16(&bad); // must not panic
        }
    }

    #[test]
    fn coded_stream_picks_smaller() {
        let mut rng = Rng::new(4);
        // uniform 8-bit codes: raw should win (rans header overhead)
        let uniform: Vec<u16> = (0..64).map(|_| rng.below(250) as u16).collect();
        let c = CodedStream::best(&uniform, 8);
        assert!(matches!(c, CodedStream::Raw { .. }));
        assert_eq!(c.decode().unwrap(), uniform);
        // highly skewed long stream: rans should win
        let skewed: Vec<u16> = (0..8192)
            .map(|_| if rng.f64() < 0.95 { 0u16 } else { 3 })
            .collect();
        let c = CodedStream::best(&skewed, 8);
        assert!(matches!(c, CodedStream::Rans(_)));
        assert_eq!(c.decode().unwrap(), skewed);
    }

    #[test]
    fn normalize_freqs_sums_to_m() {
        let hist = vec![1u64, 100, 10_000, 0, 3];
        let f = normalize_freqs(&hist);
        assert_eq!(f.iter().map(|&x| x as u32).sum::<u32>(), M);
        assert!(f[0] >= 1 && f[4] >= 1 && f[3] == 0);
    }
}
