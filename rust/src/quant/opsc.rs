//! OPSC: one-point split compression (paper §2.1, Eq. 1).
//!
//! The model is partitioned at a single split point ℓ_w into a front
//! segment (layers 1..=ℓ_w, resident on the edge device) and a back
//! segment (the rest, resident on the cloud). Each segment gets its own
//! weight precision Q^w = {Qw1, Qw2}; per-output-channel AIQ fake-quant is
//! applied host-side before the weights are uploaded to PJRT, so one
//! artifact set serves every (ℓ_w, Q^w) without re-lowering.
//!
//! `bits = 16` means "keep full precision" (the cloud typically runs the
//! back segment unquantized; fp32 here stands in for the paper's fp16).

use crate::model::{ModelConfig, ModelWeights};

use super::baselines::atom::{groupwise_fq, weight_outlier_mask};

/// A complete OPSC configuration: split point + per-segment weight bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OpscConfig {
    /// ℓ_w: number of layers in the (edge-resident) front segment.
    pub split_layer: usize,
    /// Qw1: weight bits for layers 1..=split_layer.
    pub qw_front: u32,
    /// Qw2: weight bits for layers split_layer+1..=L.
    pub qw_back: u32,
}

impl OpscConfig {
    pub fn new(split_layer: usize, qw_front: u32, qw_back: u32) -> Self {
        OpscConfig { split_layer, qw_front, qw_back }
    }

    /// Weight bits for 0-indexed layer `li` under this config.
    pub fn bits_for_layer(&self, li: usize) -> u32 {
        if li < self.split_layer {
            self.qw_front
        } else {
            self.qw_back
        }
    }
}

/// OPSC builds on Atom's quantization scheme (paper footnote 7):
/// group-wise low-bit quantization with weight-derived outlier rows kept
/// at 8 bits — plain per-channel quant would destroy the outlier columns
/// that carry the model's accuracy-critical activations.
fn quant_layer_weights(lw: &mut crate::model::LayerWeights, cfg: &ModelConfig, bits: u32) {
    if bits >= 16 {
        return;
    }
    let d = cfg.d_model;
    let f = cfg.d_ff;
    let group = 32;
    let dims: [(usize, usize); 7] =
        [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)];
    for ((_, w), (rows, cols)) in lw.matmul_tensors_mut().into_iter().zip(dims) {
        let mask = weight_outlier_mask(w, rows, cols, 40.0);
        groupwise_fq(w, rows, cols, group, bits, &mask);
    }
}

/// Apply OPSC fake-quant to a full model in place (norms untouched, as in
/// every method of the paper's comparison).
pub fn apply_opsc(weights: &mut ModelWeights, opsc: &OpscConfig) {
    let cfg = weights.cfg.clone();
    assert!(opsc.split_layer <= cfg.n_layers, "split beyond model depth");
    for (li, lw) in weights.layers.iter_mut().enumerate() {
        quant_layer_weights(lw, &cfg, opsc.bits_for_layer(li));
    }
}

/// Quantize only a contiguous layer range [start, end) at `bits` — the
/// "front-end method" / "back-end method" sweeps of paper Table 4.
pub fn apply_segment_quant(weights: &mut ModelWeights, start: usize, end: usize, bits: u32) {
    let cfg = weights.cfg.clone();
    assert!(start <= end && end <= cfg.n_layers);
    for lw in &mut weights.layers[start..end] {
        quant_layer_weights(lw, &cfg, bits);
    }
}

/// Same sweep with PLAIN per-channel quantization (no group-wise scales,
/// no outlier protection) — the raw segment-sensitivity probe behind
/// paper Table 4. The protected Atom-style scheme (above) masks most of
/// the late-layer weight-outlier damage; the probe must not.
pub fn apply_segment_quant_naive(weights: &mut ModelWeights, start: usize, end: usize, bits: u32) {
    let cfg = weights.cfg.clone();
    assert!(start <= end && end <= cfg.n_layers);
    if bits >= 16 {
        return;
    }
    let d = cfg.d_model;
    let f = cfg.d_ff;
    let dims: [(usize, usize); 7] = [(d, d), (d, d), (d, d), (d, d), (d, f), (d, f), (f, d)];
    for lw in &mut weights.layers[start..end] {
        for ((_, w), (rows, cols)) in lw.matmul_tensors_mut().into_iter().zip(dims) {
            super::aiq::fake_quant_per_channel(w, rows, cols, bits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn small_model() -> ModelWeights {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 4;
        ModelWeights::synthetic(&cfg, 3)
    }

    #[test]
    fn front_back_precisions_differ() {
        let mut w = small_model();
        let orig = w.clone();
        apply_opsc(&mut w, &OpscConfig::new(2, 4, 16));
        // front layers changed (4-bit fake-quant), back layers untouched
        assert_ne!(w.layers[0].wq, orig.layers[0].wq);
        assert_ne!(w.layers[1].wq, orig.layers[1].wq);
        assert_eq!(w.layers[2].wq, orig.layers[2].wq);
        assert_eq!(w.layers[3].wq, orig.layers[3].wq);
        // norms never quantized
        assert_eq!(w.layers[0].g1, orig.layers[0].g1);
    }

    #[test]
    fn bits_for_layer_boundary() {
        let c = OpscConfig::new(20, 4, 8);
        assert_eq!(c.bits_for_layer(0), 4);
        assert_eq!(c.bits_for_layer(19), 4);
        assert_eq!(c.bits_for_layer(20), 8);
    }

    #[test]
    fn quant_error_shrinks_with_bits() {
        let w0 = small_model();
        let err_at = |bits: u32| -> f64 {
            let mut w = w0.clone();
            apply_opsc(&mut w, &OpscConfig::new(4, bits, bits));
            w.layers[0]
                .wq
                .iter()
                .zip(&w0.layers[0].wq)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum()
        };
        let e3 = err_at(3);
        let e4 = err_at(4);
        let e8 = err_at(8);
        assert!(e3 > e4 && e4 > e8, "e3={e3} e4={e4} e8={e8}");
        assert!(err_at(16) == 0.0);
    }

    #[test]
    fn segment_quant_targets_range() {
        let mut w = small_model();
        let orig = w.clone();
        apply_segment_quant(&mut w, 1, 3, 4);
        assert_eq!(w.layers[0].wq, orig.layers[0].wq);
        assert_ne!(w.layers[1].wq, orig.layers[1].wq);
        assert_ne!(w.layers[2].wq, orig.layers[2].wq);
        assert_eq!(w.layers[3].wq, orig.layers[3].wq);
    }

    #[test]
    #[should_panic]
    fn split_beyond_depth_rejected() {
        let mut w = small_model();
        apply_opsc(&mut w, &OpscConfig::new(99, 4, 4));
    }
}
