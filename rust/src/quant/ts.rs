//! Threshold splitting (TS), paper Eq. (4) + CSR encoding.
//!
//! MHA accuracy hinges on a tiny fraction of huge activations (Fig. 4:
//! ~0.0005% of values exceed 100 yet clamping them collapses accuracy).
//! TS partitions the intermediate output `T` into `T_above` (|t| >= tau,
//! kept lossless in CSR) and `T_below` (the rest, handed to TAB-Q).
//!
//! CSR layout follows the classic format: `row_ptr` (rows+1), `col_idx`
//! (u16 — feature dims are < 65536), `values` (f32, lossless). The wire
//! size therefore scales with sparsity, which is what makes transmitting
//! the outliers nearly free at tau >= ~5 (paper Fig. 7).

/// Sparse outlier tensor in CSR form.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseOutliers {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u16>,
    pub values: Vec<f32>,
}

impl SparseOutliers {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Bit-exact wire size: row_ptr + (col_idx, value) pairs + header.
    pub fn payload_bytes(&self) -> u64 {
        4 * (self.rows as u64 + 1)      // row_ptr u32
            + 2 * self.nnz() as u64     // col_idx u16
            + 4 * self.nnz() as u64     // values f32 (lossless)
            + 4 // header: rows u16, cols u16
    }

    /// Scatter the outliers back into a dense row-major buffer (Eq. 7's
    /// `+ T_above` term on the cloud side).
    pub fn add_into(&self, dense: &mut [f32]) {
        assert_eq!(dense.len(), self.rows * self.cols);
        for r in 0..self.rows {
            for i in self.row_ptr[r] as usize..self.row_ptr[r + 1] as usize {
                dense[r * self.cols + self.col_idx[i] as usize] += self.values[i];
            }
        }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        self.add_into(&mut out);
        out
    }
}

/// Paper Eq. (4): split `t` (rows x cols, row-major) at threshold `tau`.
/// Returns (T_above as CSR, T_below dense with outlier slots zeroed).
pub fn threshold_split(t: &[f32], rows: usize, cols: usize, tau: f32) -> (SparseOutliers, Vec<f32>) {
    assert_eq!(t.len(), rows * cols);
    assert!(cols < u16::MAX as usize, "col_idx is u16");
    assert!(tau >= 0.0);
    let mut row_ptr = Vec::with_capacity(rows + 1);
    let mut col_idx = Vec::new();
    let mut values = Vec::new();
    let mut below = t.to_vec();
    row_ptr.push(0u32);
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            if t[i].abs() >= tau {
                col_idx.push(c as u16);
                values.push(t[i]);
                below[i] = 0.0;
            }
        }
        row_ptr.push(col_idx.len() as u32);
    }
    (SparseOutliers { rows, cols, row_ptr, col_idx, values }, below)
}

/// Reconstruction (paper Eq. 7): dense below-part + outliers.
pub fn recombine(below: &[f32], above: &SparseOutliers) -> Vec<f32> {
    let mut out = below.to_vec();
    above.add_into(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;

    #[test]
    fn split_recombine_is_identity() {
        run_cases(100, 0xC1, |_, rng| {
            let rows = 1 + rng.below(16);
            let cols = 1 + rng.below(200);
            let tau = [0.5f32, 1.0, 5.0, 10.0][rng.below(4)];
            let t: Vec<f32> = (0..rows * cols)
                .map(|_| rng.heavy_tailed(1.0, 0.01, 30.0))
                .collect();
            let (above, below) = threshold_split(&t, rows, cols, tau);
            let back = recombine(&below, &above);
            assert_eq!(back, t, "lossless split+recombine");
        });
    }

    #[test]
    fn partition_is_exact() {
        run_cases(100, 0xC2, |_, rng| {
            let rows = 1 + rng.below(8);
            let cols = 1 + rng.below(100);
            let tau = 2.0f32;
            let t: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let (above, below) = threshold_split(&t, rows, cols, tau);
            // below strictly under tau in magnitude
            assert!(below.iter().all(|x| x.abs() < tau));
            // above holds exactly the elements >= tau
            let dense_above = above.to_dense();
            for i in 0..t.len() {
                if t[i].abs() >= tau {
                    assert_eq!(dense_above[i], t[i]);
                    assert_eq!(below[i], 0.0);
                } else {
                    assert_eq!(dense_above[i], 0.0);
                }
            }
        });
    }

    #[test]
    fn higher_tau_fewer_outliers_smaller_payload() {
        let mut rng = crate::util::rng::Rng::new(9);
        let t: Vec<f32> = (0..32 * 128).map(|_| rng.heavy_tailed(1.0, 0.02, 50.0)).collect();
        let (a1, _) = threshold_split(&t, 32, 128, 1.0);
        let (a5, _) = threshold_split(&t, 32, 128, 5.0);
        let (a10, _) = threshold_split(&t, 32, 128, 10.0);
        assert!(a1.nnz() > a5.nnz());
        assert!(a5.nnz() >= a10.nnz());
        assert!(a1.payload_bytes() > a5.payload_bytes());
    }

    #[test]
    fn csr_row_ptr_wellformed() {
        run_cases(50, 0xC3, |_, rng| {
            let rows = 1 + rng.below(10);
            let cols = 1 + rng.below(50);
            let t: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let (a, _) = threshold_split(&t, rows, cols, 1.5);
            assert_eq!(a.row_ptr.len(), rows + 1);
            assert_eq!(a.row_ptr[0], 0);
            assert_eq!(*a.row_ptr.last().unwrap() as usize, a.nnz());
            for w in a.row_ptr.windows(2) {
                assert!(w[0] <= w[1]);
            }
            // col indices sorted within each row
            for r in 0..rows {
                let s = &a.col_idx[a.row_ptr[r] as usize..a.row_ptr[r + 1] as usize];
                for p in s.windows(2) {
                    assert!(p[0] < p[1]);
                }
            }
        });
    }

    #[test]
    fn tau_zero_moves_everything_above() {
        let t = vec![1.0f32, -2.0, 0.5, 0.0];
        let (above, below) = threshold_split(&t, 2, 2, 0.0);
        assert_eq!(above.nnz(), 4);
        assert!(below.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn empty_outliers_payload_is_header_only() {
        let t = vec![0.1f32; 8];
        let (above, _) = threshold_split(&t, 2, 4, 100.0);
        assert_eq!(above.nnz(), 0);
        assert_eq!(above.payload_bytes(), 4 * 3 + 4); // row_ptr + header
    }
}
