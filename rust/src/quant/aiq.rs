//! Asymmetric integer quantization (AIQ), paper Eq. (5)-(7).
//!
//! `q = round(t/s + z)` with `s = (Tmax-Tmin)/Qmax`, dequantized as
//! `(q - z) * s` (Eq. 7). `Qmax = 2^(Q-1) - 1` per Eq. (6).
//!
//! Deviation from the paper as written (mirrored in python ref.py): Eq. (6)'s
//! integer zero-point `z = ceil(Tmin/s)` pushes codes outside `[0, Qmax]`
//! whenever `Tmin > 0`, so any clamped implementation distorts the top of
//! the range by up to `Tmin/s` quanta. We use the exact float zero-point
//! `z = -Tmin/s`, which maps `[Tmin, Tmax]` onto `[0, Qmax]` and preserves
//! both Eq. (7) and the s/2 rounding bound.
//!
//! Also here: bit-packing of code streams (payload accounting is bit-exact)
//! and per-channel fake-quant used by OPSC and the weight-quant baselines.

/// Paper Eq. (6): Q_max = 2^(Q-1) - 1. Valid for 1 <= bits <= 16;
/// bits = 1 is special-cased to 1 (two levels) — the paper's formula
/// degenerates to 0 there, but Fig. 6's Q̄a = 2 sweep (1 sign + 1
/// magnitude bit) needs a usable 1-bit quantizer.
#[inline]
pub fn qmax(bits: u32) -> u32 {
    debug_assert!((1..=16).contains(&bits));
    if bits == 1 {
        1
    } else {
        (1u32 << (bits - 1)) - 1
    }
}

/// Per-tensor AIQ parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero: f32,
    pub bits: u32,
}

/// Compute (scale, zero) for a min/max range at `bits`.
#[inline]
pub fn params_for_range(tmin: f32, tmax: f32, bits: u32) -> QuantParams {
    let qm = qmax(bits) as f32;
    let mut s = (tmax - tmin) / qm;
    if !(s > 0.0) {
        s = 1.0; // degenerate (constant) tensor: exact roundtrip via zero
    }
    QuantParams { scale: s, zero: -tmin / s, bits }
}

#[inline]
pub fn quantize_one(t: f32, p: &QuantParams) -> u16 {
    let qm = qmax(p.bits) as f32;
    let q = (t / p.scale + p.zero).round();
    q.clamp(0.0, qm) as u16
}

#[inline]
pub fn dequantize_one(q: u16, p: &QuantParams) -> f32 {
    (q as f32 - p.zero) * p.scale
}

/// Quantize a whole tensor with one (scale, zero) pair.
pub fn quantize(t: &[f32], bits: u32) -> (Vec<u16>, QuantParams) {
    let (mut tmin, mut tmax) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in t {
        tmin = tmin.min(x);
        tmax = tmax.max(x);
    }
    if t.is_empty() {
        return (vec![], QuantParams { scale: 1.0, zero: 0.0, bits });
    }
    let p = params_for_range(tmin, tmax, bits);
    (t.iter().map(|&x| quantize_one(x, &p)).collect(), p)
}

pub fn dequantize(q: &[u16], p: &QuantParams) -> Vec<f32> {
    q.iter().map(|&c| dequantize_one(c, p)).collect()
}

/// In-place fake-quant (quantize-dequantize) of a tensor at `bits`.
/// `bits >= 16` is treated as full precision (no-op), matching how the
/// paper treats FP16 segments.
pub fn fake_quant(t: &mut [f32], bits: u32) {
    if bits >= 16 || t.is_empty() {
        return;
    }
    let (codes, p) = quantize(t, bits);
    for (x, c) in t.iter_mut().zip(codes) {
        *x = dequantize_one(c, &p);
    }
}

/// Per-output-channel fake-quant of a (rows x cols) row-major matrix:
/// every column gets its own (scale, zero). This is the weight-quant
/// granularity OPSC uses (see quant::opsc).
pub fn fake_quant_per_channel(w: &mut [f32], rows: usize, cols: usize, bits: u32) {
    assert_eq!(w.len(), rows * cols);
    if bits >= 16 {
        return;
    }
    for c in 0..cols {
        let (mut tmin, mut tmax) = (f32::INFINITY, f32::NEG_INFINITY);
        for r in 0..rows {
            let x = w[r * cols + c];
            tmin = tmin.min(x);
            tmax = tmax.max(x);
        }
        let p = params_for_range(tmin, tmax, bits);
        for r in 0..rows {
            let x = &mut w[r * cols + c];
            *x = dequantize_one(quantize_one(*x, &p), &p);
        }
    }
}

/// Pack a code stream at `bits` per code into bytes, LSB-first.
pub fn pack_codes(codes: &[u16], bits: u32) -> Vec<u8> {
    assert!((1..=16).contains(&bits));
    let total_bits = codes.len() as u64 * bits as u64;
    let mut out = vec![0u8; total_bits.div_ceil(8) as usize];
    let mut bitpos = 0u64;
    for &c in codes {
        debug_assert!(bits == 16 || (c as u32) < (1u32 << bits), "code {c} overflows {bits} bits");
        let mut v = c as u32;
        let mut left = bits;
        while left > 0 {
            let byte = (bitpos / 8) as usize;
            let off = (bitpos % 8) as u32;
            let take = (8 - off).min(left);
            out[byte] |= ((v & ((1u32 << take) - 1)) as u8) << off;
            v >>= take;
            left -= take;
            bitpos += take as u64;
        }
    }
    out
}

/// Inverse of `pack_codes`.
pub fn unpack_codes(bytes: &[u8], bits: u32, n: usize) -> Vec<u16> {
    let mut out = Vec::with_capacity(n);
    unpack_codes_into(bytes, bits, n, &mut out);
    out
}

/// Scratch-reusing inverse of `pack_codes`: decode into `out` (cleared
/// first), so repeated decodes share one buffer.
pub fn unpack_codes_into(bytes: &[u8], bits: u32, n: usize, out: &mut Vec<u16>) {
    assert!((1..=16).contains(&bits));
    out.clear();
    out.reserve(n);
    let mut bitpos = 0u64;
    for _ in 0..n {
        let mut v = 0u32;
        let mut got = 0u32;
        while got < bits {
            let byte = (bitpos / 8) as usize;
            let off = (bitpos % 8) as u32;
            let take = (8 - off).min(bits - got);
            let chunk = ((bytes[byte] >> off) as u32) & ((1u32 << take) - 1);
            v |= chunk << got;
            got += take;
            bitpos += take as u64;
        }
        out.push(v as u16);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::run_cases;

    #[test]
    fn qmax_matches_eq6() {
        assert_eq!(qmax(2), 1);
        assert_eq!(qmax(3), 3);
        assert_eq!(qmax(4), 7);
        assert_eq!(qmax(8), 127);
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        run_cases(200, 0xA1, |_, rng| {
            let bits = 2 + (rng.below(7) as u32); // 2..8
            let n = 1 + rng.below(256);
            let scale = [0.01, 1.0, 50.0][rng.below(3)];
            let t: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, scale)).collect();
            let (q, p) = quantize(&t, bits);
            let back = dequantize(&q, &p);
            for (a, b) in t.iter().zip(&back) {
                assert!(
                    (a - b).abs() <= p.scale * 0.5 + 1e-4 * scale,
                    "err {} scale {}",
                    (a - b).abs(),
                    p.scale
                );
            }
        });
    }

    #[test]
    fn codes_within_budget() {
        run_cases(100, 0xA2, |_, rng| {
            let bits = 2 + (rng.below(7) as u32);
            let t: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let (q, _) = quantize(&t, bits);
            assert!(q.iter().all(|&c| (c as u32) <= qmax(bits)));
        });
    }

    #[test]
    fn constant_tensor_exact() {
        let t = vec![2.5f32; 32];
        let (q, p) = quantize(&t, 4);
        let back = dequantize(&q, &p);
        for b in back {
            assert!((b - 2.5).abs() < 1e-6);
        }
    }

    #[test]
    fn fake_quant_16_bits_is_noop() {
        let t0: Vec<f32> = (0..16).map(|i| i as f32 * 0.37).collect();
        let mut t = t0.clone();
        fake_quant(&mut t, 16);
        assert_eq!(t, t0);
    }

    #[test]
    fn per_channel_beats_per_tensor_on_skewed_columns() {
        // col 0 in [0, 1e-2], col 1 in [0, 100]: per-channel must be
        // dramatically more accurate on col 0.
        let rows = 64;
        let mut w = vec![0f32; rows * 2];
        let mut w2 = w.clone();
        for r in 0..rows {
            let a = (r as f32 / rows as f32) * 1e-2;
            let b = (r as f32 / rows as f32) * 100.0;
            w[r * 2] = a;
            w[r * 2 + 1] = b;
            w2[r * 2] = a;
            w2[r * 2 + 1] = b;
        }
        let orig = w.clone();
        fake_quant_per_channel(&mut w, rows, 2, 4);
        fake_quant(&mut w2, 4);
        let err = |x: &[f32]| -> f32 {
            (0..rows).map(|r| (x[r * 2] - orig[r * 2]).abs()).sum()
        };
        assert!(err(&w) < err(&w2) / 5.0, "{} vs {}", err(&w), err(&w2));
    }

    #[test]
    fn pack_unpack_roundtrip() {
        run_cases(200, 0xA3, |_, rng| {
            let bits = 1 + (rng.below(16) as u32);
            let n = rng.below(300);
            let codes: Vec<u16> = (0..n)
                .map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u16)
                .collect();
            let bytes = pack_codes(&codes, bits);
            assert_eq!(bytes.len() as u64, (n as u64 * bits as u64).div_ceil(8));
            assert_eq!(unpack_codes(&bytes, bits, n), codes);
        });
    }

    #[test]
    fn empty_tensor_ok() {
        let (q, p) = quantize(&[], 4);
        assert!(q.is_empty());
        assert!(dequantize(&q, &p).is_empty());
        assert!(pack_codes(&[], 4).is_empty());
    }
}
