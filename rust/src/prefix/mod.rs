//! Content-addressed prefix KV cache spanning both halves of the split.
//!
//! At production scale most traffic shares long common prefixes (system
//! prompts, few-shot templates), yet without this module every session
//! recomputes front-segment prefill and re-ships compressed prefill
//! state over the measured-byte wire. The prefix cache removes both
//! costs:
//!
//! * **Addressing** ([`digest`]) — a chunked rolling hash over prompt
//!   token IDs, scoped by the *plan identity* (split point, Q̄a, τ,
//!   I_kv, model shape) so a plan mismatch is a natural miss.
//! * **Edge half** ([`edge_cache`]) — per-device LRU of front-segment
//!   prefill KV + split-layer hidden rows + learned back-segment rows;
//!   a warm prompt computes and compresses only its divergent suffix.
//! * **Cloud half** ([`store`]) — a refcounted, LRU, byte-budgeted store
//!   of back-segment prefill KV keyed by the same digest. The first
//!   insert charges the bytes once (Eq. 8c extended to shared state);
//!   later sessions attach a refcount; eviction touches only
//!   refcount-0 entries and releases the charge.
//!
//! On the wire (v7) a session whose prefix is resident on both halves
//! ships a 32-byte cache token (`PrefixProbe`/`PrefixAck` handshake +
//! a digest-bearing payload) instead of re-transmitting compressed
//! prefill state; a miss or plan mismatch falls back to the full insert
//! payload, and a forged or stale token is a typed in-band `PREFIX`
//! reject — never silent wrong tokens. The core invariant, pinned by
//! `tests/prefix.rs` across solo, stacked, fleet and pool serving:
//! **cached-prefix token streams are bit-identical to cold ones**, at
//! every divergence point.

pub mod digest;
pub mod edge_cache;
pub mod store;

pub use digest::{prefix_candidates, PlanIdentity, PrefixDigest, PrefixHasher, CHUNK_TOKENS};
pub use edge_cache::{EdgeCacheStats, EdgePrefixCache, EdgePrefixEntry};
pub use store::{PrefixKv, PrefixStore, PrefixStoreStats};
