//! Edge-side prefix cache: everything a device needs to serve a warm
//! prompt without recomputing or re-shipping its shared prefix.
//!
//! One entry per [`PrefixDigest`] holds three artifacts of the prefix's
//! original cold prefill, all for positions `[0, prefix_len)`:
//!
//! * `front_kv` — the front segment's per-layer K/V rows, so the edge can
//!   run a suffix-only front prefill (`NodeRuntime::prefill_suffix`)
//!   instead of recomputing the whole padded block;
//! * `hidden` — the split-layer hidden rows, needed to rebuild the full
//!   hidden history (I_kv = 0 decode re-ships it) and to reconstruct a
//!   cold insert payload when the cloud's store turns out not to hold the
//!   prefix after all (restart, eviction — the typed `PREFIX` reject
//!   path);
//! * `back_kv` — the back segment's prefix K/V rows, learned from the
//!   cold reply, so the edge can pre-fill its cloud-KV mirror on warm
//!   paths where the cloud replies with suffix rows only.
//!
//! Entries are immutable and shared (`Rc`), LRU-evicted under a byte
//! budget. Bit-identity note: all three artifacts are deterministic
//! functions of (tokens, plan), so an entry learned from any cold run
//! equals what every other cold run of the same prefix would produce.

use std::collections::HashMap;
use std::rc::Rc;

use super::digest::PrefixDigest;

/// Cached per-prefix edge state (see module docs).
#[derive(Debug)]
pub struct EdgePrefixEntry {
    pub prefix_len: usize,
    /// Per front layer: (rotary-embedded K rows, raw V rows), each
    /// `prefix_len * kv_width` floats.
    pub front_kv: Vec<(Vec<f32>, Vec<f32>)>,
    /// Split-layer hidden rows, `prefix_len * d_model` floats.
    pub hidden: Vec<f32>,
    /// Per back layer: prefix K/V rows learned from the cold reply.
    pub back_kv: Vec<(Vec<f32>, Vec<f32>)>,
}

impl EdgePrefixEntry {
    pub fn bytes(&self) -> u64 {
        let kv: usize = self
            .front_kv
            .iter()
            .chain(self.back_kv.iter())
            .map(|(k, v)| k.len() + v.len())
            .sum();
        ((kv + self.hidden.len()) * 4) as u64
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EdgeCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub rejected_over_budget: u64,
}

impl crate::obs::MetricSource for EdgeCacheStats {
    /// `edge_prefix_*` counters for the obs registry.
    fn metrics(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("edge_prefix_hits", self.hits),
            ("edge_prefix_misses", self.misses),
            ("edge_prefix_inserts", self.inserts),
            ("edge_prefix_evictions", self.evictions),
            ("edge_prefix_rejected_over_budget", self.rejected_over_budget),
        ]
    }
}

struct Slot {
    entry: Rc<EdgePrefixEntry>,
    last_used: u64,
    bytes: u64,
}

/// Byte-budgeted LRU over [`EdgePrefixEntry`]. Budget 0 disables it.
pub struct EdgePrefixCache {
    budget_bytes: u64,
    used_bytes: u64,
    clock: u64,
    entries: HashMap<PrefixDigest, Slot>,
    pub stats: EdgeCacheStats,
}

impl EdgePrefixCache {
    pub fn new(budget_bytes: u64) -> EdgePrefixCache {
        EdgePrefixCache {
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            stats: EdgeCacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, digest: &PrefixDigest) -> bool {
        self.entries.contains_key(digest)
    }

    /// Fetch an entry, bumping recency. A clone of the `Rc` is returned
    /// so the caller can keep using it across later inserts/evictions.
    pub fn get(&mut self, digest: &PrefixDigest) -> Option<Rc<EdgePrefixEntry>> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(digest) {
            Some(slot) => {
                slot.last_used = clock;
                self.stats.hits += 1;
                Some(Rc::clone(&slot.entry))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert (idempotent per digest — entries for one digest are
    /// bit-identical by construction, so a re-insert only bumps recency).
    /// Returns whether the digest is resident afterwards.
    pub fn insert(&mut self, digest: &PrefixDigest, entry: EdgePrefixEntry) -> bool {
        if !self.enabled() {
            return false;
        }
        self.clock += 1;
        if let Some(slot) = self.entries.get_mut(digest) {
            slot.last_used = self.clock;
            return true;
        }
        let bytes = entry.bytes();
        if bytes > self.budget_bytes {
            self.stats.rejected_over_budget += 1;
            return false;
        }
        while self.used_bytes + bytes > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(d, _)| *d)
                .expect("used_bytes > 0 implies an entry exists");
            let s = self.entries.remove(&victim).expect("victim resident");
            self.used_bytes -= s.bytes;
            self.stats.evictions += 1;
        }
        self.entries.insert(
            *digest,
            Slot { entry: Rc::new(entry), last_used: self.clock, bytes },
        );
        self.used_bytes += bytes;
        self.stats.inserts += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn digest(b: u8) -> PrefixDigest {
        PrefixDigest([b; 32])
    }

    fn entry(prefix_len: usize) -> EdgePrefixEntry {
        EdgePrefixEntry {
            prefix_len,
            front_kv: vec![(vec![0.5; prefix_len * 4], vec![0.25; prefix_len * 4])],
            hidden: vec![1.0; prefix_len * 8],
            back_kv: vec![(vec![0.1; prefix_len * 4], vec![0.2; prefix_len * 4]); 2],
        }
    }

    #[test]
    fn lru_eviction_respects_the_byte_budget() {
        let per = entry(16).bytes();
        let mut c = EdgePrefixCache::new(2 * per);
        assert!(c.insert(&digest(1), entry(16)));
        assert!(c.insert(&digest(2), entry(16)));
        assert!(c.get(&digest(1)).is_some()); // 1 is now more recent than 2
        assert!(c.insert(&digest(3), entry(16)));
        assert!(!c.contains(&digest(2)), "LRU entry evicted");
        assert!(c.contains(&digest(1)));
        assert!(c.contains(&digest(3)));
        assert_eq!(c.used_bytes(), 2 * per);
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn disabled_cache_stores_nothing() {
        let mut c = EdgePrefixCache::new(0);
        assert!(!c.insert(&digest(1), entry(16)));
        assert!(c.get(&digest(1)).is_none());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn rc_entries_survive_eviction_for_live_borrowers() {
        let per = entry(16).bytes();
        let mut c = EdgePrefixCache::new(per);
        c.insert(&digest(1), entry(16));
        let held = c.get(&digest(1)).unwrap();
        c.insert(&digest(2), entry(16)); // evicts 1
        assert!(!c.contains(&digest(1)));
        assert_eq!(held.prefix_len, 16, "borrowed entry stays valid");
    }
}
