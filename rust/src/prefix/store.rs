//! Cloud-side content-addressed store of back-segment prefill KV.
//!
//! One entry per [`PrefixDigest`]: the back segment's per-layer K/V rows
//! for the prefix positions `[0, prefix_len)`. Entries are **immutable
//! once inserted** — a warm prefill reads the shared rows into a fresh
//! per-session cache and every later decode writes only suffix positions,
//! so copy-on-write at the divergence point holds by construction (shared
//! rows are never behind a `&mut`).
//!
//! Accounting follows Eq. 8c's spirit for shared state: the **first
//! insert charges the entry's bytes once**; every later session that
//! attaches to the same digest adds a refcount but zero bytes. Eviction
//! is LRU over `refcount == 0` entries only (a pinned prefix can never be
//! yanked out from under a session that was promised a hit), and releases
//! the charge. Attachments are keyed by request id and released through
//! the cloud's central retire sweep, so EOS, cancellation, connection
//! close and worker death all drain refcounts through one code path.

use std::collections::HashMap;

use super::digest::PrefixDigest;

/// Back-segment prefill KV rows for one prefix: per back layer, the
/// rotary-embedded K rows and raw V rows for positions `[0, prefix_len)`,
/// each `prefix_len * kv_width` floats.
#[derive(Clone, Debug, PartialEq)]
pub struct PrefixKv {
    pub prefix_len: usize,
    pub kv_width: usize,
    pub layers: Vec<(Vec<f32>, Vec<f32>)>,
}

impl PrefixKv {
    /// Bytes this entry charges against the store budget.
    pub fn bytes(&self) -> u64 {
        (self.layers.len() * 2 * self.prefix_len * self.kv_width * 4) as u64
    }
}

/// Counters surfaced in benches and leak audits.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStoreStats {
    /// Probes/attaches that found the digest resident.
    pub hits: u64,
    /// Probes/attaches that missed.
    pub misses: u64,
    /// First-time inserts (each charged its bytes once).
    pub inserts: u64,
    /// Re-inserts of an already-resident digest (deduplicated: no bytes).
    pub dedup_inserts: u64,
    /// LRU evictions of refcount-0 entries (each released its charge).
    pub evictions: u64,
    /// Inserts rejected because the entry cannot fit even after evicting
    /// every unpinned entry.
    pub rejected_over_budget: u64,
}

impl crate::obs::MetricSource for PrefixStoreStats {
    /// `prefix_store_*` counters for the obs registry.
    fn metrics(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("prefix_store_hits", self.hits),
            ("prefix_store_misses", self.misses),
            ("prefix_store_inserts", self.inserts),
            ("prefix_store_dedup_inserts", self.dedup_inserts),
            ("prefix_store_evictions", self.evictions),
            ("prefix_store_rejected_over_budget", self.rejected_over_budget),
        ]
    }
}

struct Entry {
    kv: PrefixKv,
    refcount: usize,
    last_used: u64,
    bytes: u64,
}

/// Refcounted, LRU-evicted, byte-budgeted store. Budget 0 disables it:
/// every probe misses and every insert is dropped, which reduces the
/// serving paths to their pre-prefix behavior.
pub struct PrefixStore {
    budget_bytes: u64,
    charged_bytes: u64,
    clock: u64,
    entries: HashMap<PrefixDigest, Entry>,
    /// Live attachment per request id (a request attaches to at most one
    /// prefix). Release is idempotent and keyed here so the retire sweep
    /// never double-decrements.
    by_request: HashMap<u64, PrefixDigest>,
    pub stats: PrefixStoreStats,
}

impl PrefixStore {
    pub fn new(budget_bytes: u64) -> PrefixStore {
        PrefixStore {
            budget_bytes,
            charged_bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            by_request: HashMap::new(),
            stats: PrefixStoreStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Bytes currently charged for resident entries (shared prefixes are
    /// charged once, regardless of how many sessions attach).
    pub fn charged_bytes(&self) -> u64 {
        self.charged_bytes
    }

    pub fn resident(&self, digest: &PrefixDigest) -> bool {
        self.entries.contains_key(digest)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total refcount across entries plus outstanding request
    /// attachments must agree; exposed for the leak audits.
    pub fn live_attachments(&self) -> usize {
        self.by_request.len()
    }

    pub fn refcount(&self, digest: &PrefixDigest) -> usize {
        self.entries.get(digest).map_or(0, |e| e.refcount)
    }

    fn touch(clock: &mut u64, e: &mut Entry) {
        *clock += 1;
        e.last_used = *clock;
    }

    /// Probe + attach in one step: if the digest is resident, pin it for
    /// `request_id` (refcount++) and return true; otherwise record a miss.
    /// Attaching at probe time (not at payload time) closes the window
    /// where an acked hit could be evicted before the warm payload lands.
    /// Idempotent per (request, digest); re-attaching a request to a
    /// *different* digest releases the old attachment first.
    pub fn attach(&mut self, request_id: u64, digest: &PrefixDigest) -> bool {
        if let Some(prev) = self.by_request.get(&request_id).copied() {
            if prev == *digest {
                let resident = self.entries.contains_key(digest);
                if resident {
                    self.stats.hits += 1;
                } else {
                    self.stats.misses += 1;
                }
                return resident;
            }
            self.release(request_id);
        }
        match self.entries.get_mut(digest) {
            Some(e) => {
                e.refcount += 1;
                Self::touch(&mut self.clock, e);
                self.by_request.insert(request_id, *digest);
                self.stats.hits += 1;
                true
            }
            None => {
                self.stats.misses += 1;
                false
            }
        }
    }

    /// Drop the attachment held by `request_id`, if any. Idempotent.
    pub fn release(&mut self, request_id: u64) {
        if let Some(digest) = self.by_request.remove(&request_id) {
            if let Some(e) = self.entries.get_mut(&digest) {
                debug_assert!(e.refcount > 0, "refcount underflow on release");
                e.refcount = e.refcount.saturating_sub(1);
            }
        }
    }

    /// The digest `request_id` is attached to, if any (exported with a
    /// session's `Migrate` state).
    pub fn attachment(&self, request_id: u64) -> Option<PrefixDigest> {
        self.by_request.get(&request_id).copied()
    }

    /// Read the shared rows for a resident digest (bumps LRU recency).
    pub fn get(&mut self, digest: &PrefixDigest) -> Option<&PrefixKv> {
        let clock = &mut self.clock;
        self.entries.get_mut(digest).map(|e| {
            Self::touch(clock, e);
            &e.kv
        })
    }

    /// Insert a prefix entry and attach `request_id` to it. The first
    /// insert charges `kv.bytes()` once (evicting LRU refcount-0 entries
    /// to make room); inserting an already-resident digest deduplicates —
    /// the stored rows are kept (inserts for one digest are bit-identical
    /// by construction) and only a refcount is added. Returns whether the
    /// digest is resident afterwards: false means the store is disabled
    /// or the entry cannot fit even after evicting everything unpinned —
    /// the session is still served, just not cached.
    pub fn insert(&mut self, request_id: u64, digest: &PrefixDigest, kv: PrefixKv) -> bool {
        if !self.enabled() {
            return false;
        }
        if self.entries.contains_key(digest) {
            self.stats.dedup_inserts += 1;
            self.attach(request_id, digest);
            return true;
        }
        let bytes = kv.bytes();
        if !self.make_room(bytes) {
            self.stats.rejected_over_budget += 1;
            return false;
        }
        self.clock += 1;
        self.entries.insert(
            *digest,
            Entry { kv, refcount: 0, last_used: self.clock, bytes },
        );
        self.charged_bytes += bytes;
        self.stats.inserts += 1;
        self.attach(request_id, digest);
        true
    }

    /// Evict LRU refcount-0 entries until `need` more bytes fit. Pinned
    /// entries are untouchable; returns false if the budget cannot be met.
    fn make_room(&mut self, need: u64) -> bool {
        if need > self.budget_bytes {
            return false;
        }
        while self.charged_bytes + need > self.budget_bytes {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.refcount == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(d, _)| *d);
            match victim {
                Some(d) => {
                    let e = self.entries.remove(&d).expect("victim resident");
                    self.charged_bytes -= e.bytes;
                    self.stats.evictions += 1;
                }
                None => return false, // everything left is pinned
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn digest(b: u8) -> PrefixDigest {
        PrefixDigest([b; 32])
    }

    fn kv(prefix_len: usize) -> PrefixKv {
        PrefixKv {
            prefix_len,
            kv_width: 4,
            layers: vec![(vec![1.0; prefix_len * 4], vec![2.0; prefix_len * 4]); 2],
        }
    }

    #[test]
    fn shared_prefix_is_charged_once() {
        let mut s = PrefixStore::new(1 << 20);
        let d = digest(1);
        assert!(s.insert(100, &d, kv(16)));
        let one = s.charged_bytes();
        assert!(one > 0);
        // 9 more sessions attach: bytes flat, refcount grows
        for rid in 101..110u64 {
            assert!(s.attach(rid, &d), "resident digest must hit");
        }
        assert_eq!(s.charged_bytes(), one, "shared prefix charged once");
        assert_eq!(s.refcount(&d), 10);
        // dedup re-insert adds no bytes either
        assert!(s.insert(110, &d, kv(16)));
        assert_eq!(s.charged_bytes(), one);
        assert_eq!(s.stats.dedup_inserts, 1);
    }

    #[test]
    fn release_is_idempotent_and_keyed_by_request() {
        let mut s = PrefixStore::new(1 << 20);
        let d = digest(2);
        s.insert(7, &d, kv(16));
        s.attach(8, &d);
        assert_eq!(s.refcount(&d), 2);
        s.release(7);
        s.release(7); // double release must not underflow
        assert_eq!(s.refcount(&d), 1);
        s.release(8);
        assert_eq!(s.refcount(&d), 0);
        assert_eq!(s.live_attachments(), 0);
        // entry stays resident (warm for future sessions) until evicted
        assert!(s.resident(&d));
    }

    #[test]
    fn lru_evicts_only_unpinned_and_releases_the_charge() {
        // budget fits exactly two entries of kv(16)
        let per = kv(16).bytes();
        let mut s = PrefixStore::new(2 * per);
        s.insert(1, &digest(1), kv(16));
        s.insert(2, &digest(2), kv(16));
        // both pinned: a third insert cannot fit and is rejected
        assert!(!s.insert(3, &digest(3), kv(16)));
        assert_eq!(s.stats.rejected_over_budget, 1);
        // unpin the older entry; now the third insert evicts it (LRU)
        s.release(1);
        assert!(s.insert(3, &digest(3), kv(16)));
        assert!(!s.resident(&digest(1)), "LRU refcount-0 entry evicted");
        assert!(s.resident(&digest(2)));
        assert_eq!(s.charged_bytes(), 2 * per, "charge released and re-charged");
        assert_eq!(s.stats.evictions, 1);
    }

    #[test]
    fn disabled_store_misses_and_refuses_inserts() {
        let mut s = PrefixStore::new(0);
        let d = digest(9);
        assert!(!s.insert(1, &d, kv(16)));
        assert!(!s.attach(2, &d));
        assert_eq!(s.charged_bytes(), 0);
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn churn_leaks_nothing() {
        let per = kv(16).bytes();
        let mut s = PrefixStore::new(4 * per);
        for cycle in 0..1000u64 {
            let d = digest((cycle % 6) as u8);
            let rid = 10_000 + cycle;
            if !s.attach(rid, &d) {
                s.insert(rid, &d, kv(16));
            }
            s.release(rid);
        }
        assert_eq!(s.live_attachments(), 0, "no leaked attachments");
        for b in 0..6u8 {
            assert_eq!(s.refcount(&digest(b)), 0, "no leaked refcounts");
        }
        assert!(s.charged_bytes() <= 4 * per, "charge within budget");
        let resident: u64 =
            (0..6u8).filter(|b| s.resident(&digest(*b))).count() as u64 * per;
        assert_eq!(s.charged_bytes(), resident, "charge equals resident bytes");
    }
}
