//! Content addressing for shared prompt prefixes.
//!
//! A prefix is identified by a 256-bit digest over (a) the prompt tokens
//! it covers, absorbed in fixed-size chunks by a rolling sponge, and (b)
//! the *plan identity* — split point, activation bit-width Q̄a, sparsity
//! threshold τ, the KV-vs-hidden decode mode I_kv, and the model's shape
//! class. Folding the plan in means a plan mismatch is a natural cache
//! miss instead of a correctness hazard: front-segment KV computed under
//! one OPSC configuration can never be addressed by a session running
//! another.
//!
//! Chunking makes the address space *prefix-closed*: every multiple of
//! [`CHUNK_TOKENS`] up to `prompt.len() - 1` yields a candidate digest,
//! and because the sponge is rolling, all candidates for one prompt are
//! produced in a single O(len) pass ([`prefix_candidates`]). The last
//! token is never part of a cacheable prefix — the sample position
//! `w - 1` must always be computed, so the divergent suffix is non-empty
//! by construction.

use std::fmt;

/// Tokens per digest chunk. Prefix lengths are multiples of this, which
/// bounds the candidate count per prompt and makes near-miss prefixes
/// (shared template + one diverging token) still hit on the longest
/// common chunk boundary.
pub const CHUNK_TOKENS: usize = 16;

/// 256-bit content address of (plan identity, token prefix).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixDigest(pub [u8; 32]);

impl fmt::Debug for PrefixDigest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // First 8 bytes are enough to tell entries apart in logs.
        write!(
            f,
            "PrefixDigest({:02x}{:02x}{:02x}{:02x}{:02x}{:02x}{:02x}{:02x}…)",
            self.0[0], self.0[1], self.0[2], self.0[3], self.0[4], self.0[5], self.0[6], self.0[7]
        )
    }
}

/// Everything that must match for cached prefix state to be reusable.
/// Two sessions whose plans differ in any field hash to different
/// digests, so they can never alias each other's cache entries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanIdentity {
    /// Split layer: number of layers in the edge front segment.
    pub split_layer: u32,
    /// Activation quantization bit-width Q̄a (TAB-Q budget).
    pub q_bar: u32,
    /// Top-κ sparsity threshold τ, bit-cast so float identity is exact.
    pub tau_bits: u64,
    /// TAB-Q outlier fraction Δ, bit-cast.
    pub delta_bits: u64,
    /// Whether the entropy-coding stage (rANS) is enabled.
    pub use_rans: bool,
    /// Decode transmission mode I_kv (1 = re-ship compressed cloud KV).
    pub i_kv: bool,
    /// Model shape identity: d_model, layer count, prefill block length.
    pub d_model: u32,
    pub n_layers: u32,
    pub prefill_len: u32,
}

/// Rolling 4-lane sponge over 64-bit words (splitmix64 finalizer per
/// absorb, cross-lane feed). Not cryptographic — the threat model is
/// accidental collision across millions of live prefixes, where 256 bits
/// of well-mixed state is overwhelming margin; a *forged* token is caught
/// behind this by the typed `PREFIX` reject, not by digest secrecy.
#[derive(Clone)]
pub struct PrefixHasher {
    lanes: [u64; 4],
    absorbed: u64,
}

#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl PrefixHasher {
    /// Start a sponge seeded with the plan identity: the plan is absorbed
    /// first, so every downstream chunk digest is plan-scoped.
    pub fn new(plan: &PlanIdentity) -> PrefixHasher {
        let mut h = PrefixHasher {
            lanes: [
                0x243F_6A88_85A3_08D3, // pi
                0x1319_8A2E_0370_7344,
                0xA409_3822_299F_31D0,
                0x082E_FA98_EC4E_6C89,
            ],
            absorbed: 0,
        };
        h.absorb(plan.split_layer as u64);
        h.absorb(plan.q_bar as u64);
        h.absorb(plan.tau_bits);
        h.absorb(plan.delta_bits);
        h.absorb(plan.use_rans as u64);
        h.absorb(plan.i_kv as u64);
        h.absorb(plan.d_model as u64);
        h.absorb(plan.n_layers as u64);
        h.absorb(plan.prefill_len as u64);
        h
    }

    #[inline]
    fn absorb(&mut self, word: u64) {
        self.absorbed = self.absorbed.wrapping_add(1);
        let lane = (self.absorbed % 4) as usize;
        let mixed = splitmix64(word ^ self.lanes[lane] ^ self.absorbed);
        self.lanes[lane] = self.lanes[lane].rotate_left(23) ^ mixed;
        // cross-lane feed so no lane is independent of any input word
        self.lanes[(lane + 1) % 4] =
            self.lanes[(lane + 1) % 4].wrapping_add(mixed.rotate_left(17));
    }

    /// Absorb one chunk of prompt tokens (callers pass exactly
    /// [`CHUNK_TOKENS`]; the length is absorbed too, so unequal-length
    /// prefixes can never collide by concatenation).
    pub fn absorb_chunk(&mut self, tokens: &[u32]) {
        self.absorb(tokens.len() as u64);
        for &t in tokens {
            self.absorb(t as u64);
        }
    }

    /// Snapshot the current digest (the sponge keeps rolling afterwards).
    pub fn digest(&self) -> PrefixDigest {
        let mut out = [0u8; 32];
        for (i, &lane) in self.lanes.iter().enumerate() {
            // finalize each lane against the absorb count so a snapshot
            // differs from the raw running state
            let fin = splitmix64(lane ^ self.absorbed.wrapping_mul(0x2545_F491_4F6C_DD1D));
            out[i * 8..(i + 1) * 8].copy_from_slice(&fin.to_le_bytes());
        }
        PrefixDigest(out)
    }
}

/// All cacheable (prefix_len, digest) candidates for a prompt under one
/// plan, ascending by length: one per [`CHUNK_TOKENS`] boundary up to
/// `prompt.len() - 1`. Empty when the prompt is too short to leave both a
/// full chunk and a non-empty suffix.
pub fn prefix_candidates(prompt: &[u32], plan: &PlanIdentity) -> Vec<(usize, PrefixDigest)> {
    if prompt.len() <= CHUNK_TOKENS {
        return Vec::new();
    }
    let max_prefix = ((prompt.len() - 1) / CHUNK_TOKENS) * CHUNK_TOKENS;
    let mut h = PrefixHasher::new(plan);
    let mut out = Vec::with_capacity(max_prefix / CHUNK_TOKENS);
    let mut covered = 0usize;
    while covered + CHUNK_TOKENS <= max_prefix {
        h.absorb_chunk(&prompt[covered..covered + CHUNK_TOKENS]);
        covered += CHUNK_TOKENS;
        out.push((covered, h.digest()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> PlanIdentity {
        PlanIdentity {
            split_layer: 2,
            q_bar: 4,
            tau_bits: 5.0f64.to_bits(),
            delta_bits: 0.2f64.to_bits(),
            use_rans: true,
            i_kv: true,
            d_model: 256,
            n_layers: 4,
            prefill_len: 64,
        }
    }

    #[test]
    fn candidates_cover_chunk_boundaries_and_spare_the_last_token() {
        let p = plan();
        let prompt: Vec<u32> = (0..40).collect();
        let c = prefix_candidates(&prompt, &p);
        // 40 tokens: prefixes of 16 and 32 are cacheable (48 > 39).
        assert_eq!(c.iter().map(|(l, _)| *l).collect::<Vec<_>>(), vec![16, 32]);
        // exactly at a boundary the last token still forces a suffix:
        let prompt: Vec<u32> = (0..32).collect();
        let c = prefix_candidates(&prompt, &p);
        assert_eq!(c.iter().map(|(l, _)| *l).collect::<Vec<_>>(), vec![16]);
        // too short: nothing cacheable
        assert!(prefix_candidates(&prompt[..16], &p).is_empty());
        assert!(prefix_candidates(&[], &p).is_empty());
    }

    #[test]
    fn digests_are_deterministic_and_prefix_scoped() {
        let p = plan();
        let a: Vec<u32> = (0..64).map(|i| i * 7 % 512).collect();
        let c1 = prefix_candidates(&a, &p);
        let c2 = prefix_candidates(&a, &p);
        assert_eq!(
            c1.iter().map(|(l, d)| (*l, d.0)).collect::<Vec<_>>(),
            c2.iter().map(|(l, d)| (*l, d.0)).collect::<Vec<_>>()
        );
        // a prompt sharing the first 32 tokens shares those digests...
        let mut b = a.clone();
        for t in b.iter_mut().skip(32) {
            *t += 1;
        }
        let cb = prefix_candidates(&b, &p);
        assert_eq!(c1[0].1, cb[0].1);
        assert_eq!(c1[1].1, cb[1].1);
        // ...and diverges from the first differing chunk on
        assert_ne!(c1[2].1, cb[2].1);
    }

    #[test]
    fn plan_identity_scopes_the_address_space() {
        let prompt: Vec<u32> = (0..48).collect();
        let base = plan();
        let c0 = prefix_candidates(&prompt, &base);
        for tweak in [
            PlanIdentity { split_layer: 3, ..base },
            PlanIdentity { q_bar: 8, ..base },
            PlanIdentity { tau_bits: 7.0f64.to_bits(), ..base },
            PlanIdentity { i_kv: false, ..base },
            PlanIdentity { use_rans: false, ..base },
            PlanIdentity { d_model: 128, ..base },
            PlanIdentity { prefill_len: 128, ..base },
        ] {
            let c = prefix_candidates(&prompt, &tweak);
            for ((l0, d0), (l1, d1)) in c0.iter().zip(c.iter()) {
                assert_eq!(l0, l1);
                assert_ne!(d0.0, d1.0, "plan tweak must change every digest");
            }
        }
    }

    #[test]
    fn different_lengths_never_collide_by_concatenation() {
        let p = plan();
        // prompt whose tokens are all zero: the classic length-extension
        // collision shape
        let prompt = vec![0u32; 64];
        let c = prefix_candidates(&prompt, &p);
        for i in 0..c.len() {
            for j in (i + 1)..c.len() {
                assert_ne!(c[i].1, c[j].1, "lengths {} vs {}", c[i].0, c[j].0);
            }
        }
    }
}
