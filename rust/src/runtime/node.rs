//! NodeRuntime: one logical inference node (the edge's front segment or the
//! cloud's back segment) executing its layer range through the shared PJRT
//! engine.
//!
//! Weights are uploaded to device-resident buffers ONCE at construction
//! (possibly after OPSC/baseline fake-quant). The per-step contract is
//! **in-place and borrowed**: KV caches are owned by the coordinator's KV
//! manager and passed in as `&mut LayerKv` — decode writes exactly one
//! (k, v) row at `pos` and never clones, uploads, or returns a cache.
//! Per-step activations live in a reusable [`EngineScratch`] arena (the
//! `quant::fused::CompressionScratch` pattern), so the decode hot path
//! performs zero full-cache copies and zero steady-state allocation.
//!
//! [`NodeRuntime::decode_batch`] is the stacked many-session entry point:
//! B concurrent sessions' hidden rows are stacked into one (B, d) block so
//! every weight matrix is traversed once per step instead of B times; the
//! per-session attention still runs against each session's own cache. The
//! pre-PR copy-semantics path survives as [`NodeRuntime::decode_copyful`]
//! (the perf baseline and equivalence oracle of `benches/engine.rs`).

use std::cell::RefCell;
use std::ops::Range;
use std::rc::Rc;

use anyhow::Result;

use super::{Buffer, Engine};
use crate::model::ModelWeights;

/// Per-layer KV cache: static (W, H*D) buffers plus the current fill level.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl LayerKv {
    pub fn zeros(max_seq: usize, kv_width: usize) -> LayerKv {
        LayerKv { k: vec![0.0; max_seq * kv_width], v: vec![0.0; max_seq * kv_width] }
    }

    /// Build a full-width cache whose prefix holds the given prefill rows:
    /// one allocation per buffer, prefix copied once, tail zero-filled —
    /// no zero-the-world-then-overwrite pass.
    pub fn from_prefill_rows(
        k_rows: &[f32],
        v_rows: &[f32],
        max_seq: usize,
        kv_width: usize,
    ) -> LayerKv {
        let total = max_seq * kv_width;
        debug_assert!(k_rows.len() <= total && v_rows.len() <= total);
        let mut k = Vec::with_capacity(total);
        k.extend_from_slice(k_rows);
        k.resize(total, 0.0);
        let mut v = Vec::with_capacity(total);
        v.extend_from_slice(v_rows);
        v.resize(total, 0.0);
        LayerKv { k, v }
    }
}

/// Per-step coordinates of a stacked decode call, one entry per session:
/// `positions[b]` is session b's write/attend position, and `cos`/`sin`
/// hold the (B, D/2) RoPE rows gathered for those positions (row b
/// belongs to `positions[b]`).
pub struct DecodeStep<'a> {
    pub positions: &'a [usize],
    pub cos: &'a [f32],
    pub sin: &'a [f32],
}

/// Reusable working memory for the in-place execution engine: every
/// per-step activation (normed hidden, Q/K/V, attention output, FFN
/// gate/up, projection, attention scores) lives here and is recycled
/// across layers, steps and stacked sessions. After the first step at a
/// given batch width, the engine allocates nothing.
#[derive(Default, Debug)]
pub struct EngineScratch {
    pub h_norm: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub attn: Vec<f32>,
    pub proj: Vec<f32>,
    pub gate: Vec<f32>,
    pub up: Vec<f32>,
    pub scores: Vec<f32>,
}

/// Host-computed RoPE tables (cos, sin), each (max_seq, D/2) row-major.
/// Computed on the host because xla_extension 0.5.1 miscompiles in-graph
/// pow/cos (see python/compile/model.py) — the tables are artifact INPUTS.
#[derive(Clone, Debug)]
pub struct RopeTables {
    pub cos: Vec<f32>,
    pub sin: Vec<f32>,
    pub half_dim: usize,
}

impl RopeTables {
    pub fn new(max_seq: usize, head_dim: usize, theta: f64) -> RopeTables {
        let half = head_dim / 2;
        // The inverse frequencies depend only on the dimension index;
        // hoisting them out of the position loop drops the transcendental
        // count from max_seq * half pow() calls to half.
        let inv_freq: Vec<f64> = (0..half)
            .map(|i| 1.0 / theta.powf((2 * i) as f64 / head_dim as f64))
            .collect();
        let mut cos = vec![0f32; max_seq * half];
        let mut sin = vec![0f32; max_seq * half];
        for p in 0..max_seq {
            for (i, &f) in inv_freq.iter().enumerate() {
                let ang = p as f64 * f;
                cos[p * half + i] = ang.cos() as f32;
                sin[p * half + i] = ang.sin() as f32;
            }
        }
        RopeTables { cos, sin, half_dim: half }
    }

    pub fn rows(&self, start: usize, n: usize) -> (&[f32], &[f32]) {
        let h = self.half_dim;
        (&self.cos[start * h..(start + n) * h], &self.sin[start * h..(start + n) * h])
    }
}

pub struct NodeRuntime {
    pub engine: Rc<Engine>,
    /// 0-indexed layers this node executes.
    pub layer_range: Range<usize>,
    /// Device-resident weight buffers, artifact argument order, one vec per
    /// layer in `layer_range`.
    weight_bufs: Vec<Vec<Buffer>>,
    /// Final norm + head (only the node that finishes the stack needs it).
    head_bufs: Option<(Buffer, Buffer)>,
    /// Host-side weights (embedding lookups, re-quantization experiments).
    pub weights: Rc<ModelWeights>,
    rope: RopeTables,
    /// Per-node activation arena, shared by prefill/decode/lm-head calls.
    scratch: RefCell<EngineScratch>,
    /// Gathered (B, D/2) RoPE rows for the current stacked step (its own
    /// cell so it can be borrowed alongside `scratch`).
    rope_gather: RefCell<(Vec<f32>, Vec<f32>)>,
    /// Route `decode` through the retained pre-PR copy-semantics path
    /// (clone caches → upload → artifact call → copy back). Kept as the
    /// perf baseline and equivalence oracle for `benches/engine.rs`.
    pub copyful_decode: bool,
}

impl NodeRuntime {
    pub fn new(
        engine: Rc<Engine>,
        weights: Rc<ModelWeights>,
        layer_range: Range<usize>,
        with_head: bool,
    ) -> Result<NodeRuntime> {
        let cfg = &weights.cfg;
        assert!(layer_range.end <= cfg.n_layers);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let dims: [(usize, &[usize]); 9] = [
            (0, &[d, d]),
            (1, &[d, d]),
            (2, &[d, d]),
            (3, &[d, d]),
            (4, &[d, f]),
            (5, &[d, f]),
            (6, &[f, d]),
            (7, &[d]),
            (8, &[d]),
        ];
        let mut weight_bufs = Vec::with_capacity(layer_range.len());
        for li in layer_range.clone() {
            let lw = &weights.layers[li];
            let ordered = lw.ordered();
            let mut bufs = Vec::with_capacity(9);
            for (i, shape) in dims.iter() {
                bufs.push(engine.upload(ordered[*i].1, shape)?);
            }
            weight_bufs.push(bufs);
        }
        let head_bufs = if with_head {
            Some((
                engine.upload(&weights.gf, &[d])?,
                engine.upload(&weights.w_out, &[d, cfg.vocab])?,
            ))
        } else {
            None
        };
        let rope = RopeTables::new(cfg.max_seq, cfg.head_dim, 10000.0);
        Ok(NodeRuntime {
            engine,
            layer_range,
            weight_bufs,
            head_bufs,
            weights,
            rope,
            scratch: RefCell::new(EngineScratch::default()),
            rope_gather: RefCell::new((Vec::new(), Vec::new())),
            copyful_decode: false,
        })
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.weights.cfg
    }

    /// Prefill: run `x` (P, d) through this node's layers. Returns the
    /// output hidden state and the K/V rows (P, H*D) per layer, to be
    /// installed into the request's KV caches.
    pub fn prefill(&self, x: &[f32]) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>)> {
        self.prefill_with(x, &mut |_, _| {})
    }

    /// Prefill with a per-layer hook: `hook(global_layer_index, h)` runs on
    /// the hidden state AFTER each layer (the residual-stream boundary).
    /// This is how the eval harness applies activation fake-quant, Fig. 4
    /// clamping, and split-point compression round-trips.
    pub fn prefill_with(
        &self,
        x: &[f32],
        hook: &mut dyn FnMut(usize, &mut Vec<f32>),
    ) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>)> {
        let cfg = self.cfg();
        let p = cfg.prefill_len;
        let d = cfg.d_model;
        assert_eq!(x.len(), p * d);
        let mut h = x.to_vec();
        let (cos, sin) = self.rope.rows(0, p);
        let mut kvs = Vec::with_capacity(self.layer_range.len());
        let mut scratch = self.scratch.borrow_mut();
        for (i, bufs) in self.weight_bufs.iter().enumerate() {
            let (k_rows, v_rows) =
                self.engine.layer_prefill_inplace(&mut scratch, &mut h, p, cos, sin, bufs)?;
            hook(self.layer_range.start + i, &mut h);
            kvs.push((k_rows, v_rows));
        }
        Ok((h, kvs))
    }

    /// Suffix-only prefill (the prefix cache's warm path): run the rows
    /// `[start, start + n)` of a logical prefill block through this
    /// node's layers, with each layer's first `start` K/V rows supplied
    /// from `prefix_kv` (layer-ordered, each row block `start * kv_width`
    /// floats — exactly what a whole-block [`prefill`](Self::prefill)
    /// returned for those rows). `x` holds only the suffix rows (n, d).
    ///
    /// Because every non-attention op is per-row and the suffix attention
    /// kernel replays the whole-block kernel's arithmetic exactly, the
    /// returned hidden rows and suffix K/V rows are **bit-identical** to
    /// rows `[start, start + n)` of a whole-block prefill whose first
    /// `start` rows matched the cached prefix.
    pub fn prefill_suffix(
        &self,
        x: &[f32],
        start: usize,
        prefix_kv: &[(Vec<f32>, Vec<f32>)],
    ) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>)> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let kvw = cfg.kv_width();
        anyhow::ensure!(x.len() % d == 0, "suffix block must be (n, {d})");
        let n = x.len() / d;
        anyhow::ensure!(n > 0, "suffix prefill needs at least one row");
        anyhow::ensure!(
            start > 0 && start + n <= cfg.prefill_len,
            "suffix rows [{start}, {}) must sit inside the prefill block of {}",
            start + n,
            cfg.prefill_len
        );
        anyhow::ensure!(
            prefix_kv.len() == self.layer_range.len(),
            "one cached prefix K/V pair per layer ({} != {})",
            prefix_kv.len(),
            self.layer_range.len()
        );
        let mut h = x.to_vec();
        let (cos, sin) = self.rope.rows(start, n);
        let mut kvs = Vec::with_capacity(self.layer_range.len());
        let mut scratch = self.scratch.borrow_mut();
        for (bufs, (pk, pv)) in self.weight_bufs.iter().zip(prefix_kv.iter()) {
            anyhow::ensure!(
                pk.len() == start * kvw && pv.len() == start * kvw,
                "cached prefix K/V must cover exactly ({start}, {kvw}) rows"
            );
            let (k_rows, v_rows) = self.engine.layer_prefill_suffix_inplace(
                &mut scratch,
                &mut h,
                n,
                start,
                cos,
                sin,
                pk,
                pv,
                bufs,
            )?;
            kvs.push((k_rows, v_rows));
        }
        Ok((h, kvs))
    }

    /// One decode step at `pos` through this node's layers. `kv` must hold
    /// one LayerKv per layer in `layer_range`; each cache is mutated in
    /// place — exactly one new (k, v) row is written at `pos`, nothing is
    /// cloned or round-tripped.
    pub fn decode(&self, x: &[f32], kv: &mut [LayerKv], pos: usize) -> Result<Vec<f32>> {
        if self.copyful_decode {
            return self.decode_copyful(x, kv, pos);
        }
        let cfg = self.cfg();
        let d = cfg.d_model;
        assert_eq!(x.len(), d);
        assert!(pos < cfg.max_seq, "position {pos} beyond static cache {}", cfg.max_seq);
        let mut h = x.to_vec();
        let mut sessions: [&mut [LayerKv]; 1] = [kv];
        self.decode_batch(&mut h, &mut sessions, &[pos])?;
        Ok(h)
    }

    /// Stacked decode: one step for B independent sessions at once.
    /// `hs` holds the B hidden rows stacked into (B, d) and is transformed
    /// in place; `kvs[b]` is session b's per-layer cache slice (mutated in
    /// place at `positions[b]`). Each weight matrix is traversed once for
    /// the whole stack; attention runs per session against its own cache,
    /// so row b is bit-identical to a solo `decode` of session b.
    pub fn decode_batch(
        &self,
        hs: &mut [f32],
        kvs: &mut [&mut [LayerKv]],
        positions: &[usize],
    ) -> Result<()> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let b = positions.len();
        anyhow::ensure!(hs.len() == b * d, "stacked hidden must be ({b}, {d})");
        anyhow::ensure!(kvs.len() == b, "one KV-cache set per stacked session");
        for (sess, &pos) in kvs.iter().zip(positions.iter()) {
            anyhow::ensure!(
                sess.len() == self.layer_range.len(),
                "one KV cache per layer per session"
            );
            let w = cfg.max_seq;
            anyhow::ensure!(pos < w, "position {pos} beyond static cache {w}");
        }
        // Gather the per-session RoPE rows once for the whole step (row b
        // of the gathered block belongs to positions[b]).
        let mut rg = self.rope_gather.borrow_mut();
        let (cos_g, sin_g) = &mut *rg;
        cos_g.clear();
        sin_g.clear();
        for &pos in positions {
            let (c, s) = self.rope.rows(pos, 1);
            cos_g.extend_from_slice(c);
            sin_g.extend_from_slice(s);
        }
        let step = DecodeStep { positions, cos: cos_g.as_slice(), sin: sin_g.as_slice() };
        let mut scratch = self.scratch.borrow_mut();
        for (li, bufs) in self.weight_bufs.iter().enumerate() {
            self.engine.layer_decode_batch(&mut scratch, hs, kvs, li, &step, bufs)?;
        }
        Ok(())
    }

    /// The pre-PR decode path, copy semantics preserved: caches are cloned
    /// and round-tripped through the buffer API on every layer. This is
    /// the before/after baseline of `benches/engine.rs` and the oracle of
    /// the in-place equivalence tests — the serving path never calls it.
    pub fn decode_copyful(&self, x: &[f32], kv: &mut [LayerKv], pos: usize) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let w = cfg.max_seq;
        let kvw = cfg.kv_width();
        assert_eq!(x.len(), d);
        assert_eq!(kv.len(), self.layer_range.len(), "one KV cache per layer");
        assert!(pos < w, "position {pos} beyond static cache {w}");
        let pos_buf = self.engine.upload_i32(&[pos as i32], &[1])?;
        let (cr, sr) = self.rope.rows(pos, 1);
        let cos_buf = self.engine.upload(cr, &[1, self.rope.half_dim])?;
        let sin_buf = self.engine.upload(sr, &[1, self.rope.half_dim])?;
        let mut h = x.to_vec();
        for (bufs, cache) in self.weight_bufs.iter().zip(kv.iter_mut()) {
            let hx = self.engine.upload(&h, &[1, d])?;
            let kc = self.engine.upload(&cache.k, &[w, kvw])?;
            let vc = self.engine.upload(&cache.v, &[w, kvw])?;
            let mut args: Vec<&Buffer> = vec![&hx, &kc, &vc, &pos_buf, &cos_buf, &sin_buf];
            args.extend(bufs.iter());
            let mut out = self.engine.run("layer_decode", &args)?;
            cache.v = out.pop().expect("v_cache");
            cache.k = out.pop().expect("k_cache");
            h = out.pop().expect("y");
        }
        Ok(h)
    }

    /// Final norm + vocab projection for a full prefill block (P, d).
    pub fn logits_prefill(&self, h: &[f32]) -> Result<Vec<f32>> {
        let p = self.cfg().prefill_len;
        self.logits_rows(h, p)
    }

    /// Final norm + vocab projection for one decode token (1, d).
    pub fn logits_decode(&self, h: &[f32]) -> Result<Vec<f32>> {
        self.logits_rows(h, 1)
    }

    /// Final norm + vocab projection for a stacked decode block (B, d) —
    /// one weight traversal for the whole batch. Row b of the returned
    /// (B, vocab) block is bit-identical to `logits_decode` of row b.
    pub fn logits_decode_batch(&self, hs: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.logits_rows(hs, rows)
    }

    /// Final norm + vocab projection for an arbitrary (rows, d) block —
    /// the suffix-prefill path samples at a suffix-local row, so it needs
    /// logits over a block narrower than `prefill_len`. Row-generic and
    /// bit-identical per row to the fixed-width entry points above.
    pub fn logits_rows(&self, h: &[f32], rows: usize) -> Result<Vec<f32>> {
        let (gf, w_out) = self.head_bufs.as_ref().expect("node has no lm head");
        let mut scratch = self.scratch.borrow_mut();
        let mut out = Vec::new();
        self.engine.lm_head_into(&mut scratch, h, rows, gf, w_out, &mut out)?;
        Ok(out)
    }

    /// Fresh zeroed KV caches for this node's layer range.
    pub fn fresh_kv(&self) -> Vec<LayerKv> {
        let cfg = self.cfg();
        (0..self.layer_range.len())
            .map(|_| LayerKv::zeros(cfg.max_seq, cfg.kv_width()))
            .collect()
    }

    /// Install prefill K/V rows (P, H*D) into full-width caches — a single
    /// allocation per buffer (prefix copy + zero tail), not a zeroed
    /// max_seq-wide cache that is then overwritten.
    pub fn install_prefill_kv(&self, rows: &[(Vec<f32>, Vec<f32>)], prompt_len: usize) -> Vec<LayerKv> {
        let cfg = self.cfg();
        let kvw = cfg.kv_width();
        rows.iter()
            .map(|(k_rows, v_rows)| {
                LayerKv::from_prefill_rows(
                    &k_rows[..prompt_len * kvw],
                    &v_rows[..prompt_len * kvw],
                    cfg.max_seq,
                    kvw,
                )
            })
            .collect()
    }
}
