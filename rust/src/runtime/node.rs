//! NodeRuntime: one logical inference node (the edge's front segment or the
//! cloud's back segment) executing its layer range through the shared PJRT
//! engine.
//!
//! Weights are uploaded to device-resident buffers ONCE at construction
//! (possibly after OPSC/baseline fake-quant); per-step uploads are only the
//! small dynamic tensors (hidden state, KV caches, position). KV caches are
//! owned by the coordinator's KV manager and passed in per call — that is
//! what lets the cloud resume a request mid-stack (split computing) and
//! what the I_kv switch transmits or re-computes.

use std::ops::Range;
use std::rc::Rc;

use anyhow::Result;

use super::{Buffer, Engine};
use crate::model::ModelWeights;

/// Per-layer KV cache: static (W, H*D) buffers plus the current fill level.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerKv {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl LayerKv {
    pub fn zeros(max_seq: usize, kv_width: usize) -> LayerKv {
        LayerKv { k: vec![0.0; max_seq * kv_width], v: vec![0.0; max_seq * kv_width] }
    }
}

/// Host-computed RoPE tables (cos, sin), each (max_seq, D/2) row-major.
/// Computed on the host because xla_extension 0.5.1 miscompiles in-graph
/// pow/cos (see python/compile/model.py) — the tables are artifact INPUTS.
#[derive(Clone, Debug)]
pub struct RopeTables {
    pub cos: Vec<f32>,
    pub sin: Vec<f32>,
    pub half_dim: usize,
}

impl RopeTables {
    pub fn new(max_seq: usize, head_dim: usize, theta: f64) -> RopeTables {
        let half = head_dim / 2;
        let mut cos = vec![0f32; max_seq * half];
        let mut sin = vec![0f32; max_seq * half];
        for p in 0..max_seq {
            for i in 0..half {
                let inv_freq = 1.0 / theta.powf((2 * i) as f64 / head_dim as f64);
                let ang = p as f64 * inv_freq;
                cos[p * half + i] = ang.cos() as f32;
                sin[p * half + i] = ang.sin() as f32;
            }
        }
        RopeTables { cos, sin, half_dim: half }
    }

    pub fn rows(&self, start: usize, n: usize) -> (&[f32], &[f32]) {
        let h = self.half_dim;
        (&self.cos[start * h..(start + n) * h], &self.sin[start * h..(start + n) * h])
    }
}

pub struct NodeRuntime {
    pub engine: Rc<Engine>,
    /// 0-indexed layers this node executes.
    pub layer_range: Range<usize>,
    /// Device-resident weight buffers, artifact argument order, one vec per
    /// layer in `layer_range`.
    weight_bufs: Vec<Vec<Buffer>>,
    /// Final norm + head (only the node that finishes the stack needs it).
    head_bufs: Option<(Buffer, Buffer)>,
    /// Host-side weights (embedding lookups, re-quantization experiments).
    pub weights: Rc<ModelWeights>,
    rope: RopeTables,
    /// Device-resident prefill-width RoPE tables (uploaded once).
    rope_prefill_bufs: (Buffer, Buffer),
}

impl NodeRuntime {
    pub fn new(
        engine: Rc<Engine>,
        weights: Rc<ModelWeights>,
        layer_range: Range<usize>,
        with_head: bool,
    ) -> Result<NodeRuntime> {
        let cfg = &weights.cfg;
        assert!(layer_range.end <= cfg.n_layers);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let dims: [(usize, &[usize]); 9] = [
            (0, &[d, d]),
            (1, &[d, d]),
            (2, &[d, d]),
            (3, &[d, d]),
            (4, &[d, f]),
            (5, &[d, f]),
            (6, &[f, d]),
            (7, &[d]),
            (8, &[d]),
        ];
        let mut weight_bufs = Vec::with_capacity(layer_range.len());
        for li in layer_range.clone() {
            let lw = &weights.layers[li];
            let ordered = lw.ordered();
            let mut bufs = Vec::with_capacity(9);
            for (i, shape) in dims.iter() {
                bufs.push(engine.upload(ordered[*i].1, shape)?);
            }
            weight_bufs.push(bufs);
        }
        let head_bufs = if with_head {
            Some((
                engine.upload(&weights.gf, &[d])?,
                engine.upload(&weights.w_out, &[d, cfg.vocab])?,
            ))
        } else {
            None
        };
        let rope = RopeTables::new(cfg.max_seq, cfg.head_dim, 10000.0);
        let p = cfg.prefill_len;
        let (cp, sp) = rope.rows(0, p);
        let rope_prefill_bufs = (
            engine.upload(cp, &[p, rope.half_dim])?,
            engine.upload(sp, &[p, rope.half_dim])?,
        );
        Ok(NodeRuntime {
            engine,
            layer_range,
            weight_bufs,
            head_bufs,
            weights,
            rope,
            rope_prefill_bufs,
        })
    }

    fn cfg(&self) -> &crate::model::ModelConfig {
        &self.weights.cfg
    }

    /// Prefill: run `x` (P, d) through this node's layers. Returns the
    /// output hidden state and the K/V rows (P, H*D) per layer, to be
    /// installed into the request's KV caches.
    pub fn prefill(&self, x: &[f32]) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>)> {
        self.prefill_with(x, &mut |_, _| {})
    }

    /// Prefill with a per-layer hook: `hook(global_layer_index, h)` runs on
    /// the hidden state AFTER each layer (the residual-stream boundary).
    /// This is how the eval harness applies activation fake-quant, Fig. 4
    /// clamping, and split-point compression round-trips.
    pub fn prefill_with(
        &self,
        x: &[f32],
        hook: &mut dyn FnMut(usize, &mut Vec<f32>),
    ) -> Result<(Vec<f32>, Vec<(Vec<f32>, Vec<f32>)>)> {
        let cfg = self.cfg();
        let p = cfg.prefill_len;
        let d = cfg.d_model;
        assert_eq!(x.len(), p * d);
        let mut h = x.to_vec();
        let mut kvs = Vec::with_capacity(self.layer_range.len());
        for (i, bufs) in self.weight_bufs.iter().enumerate() {
            let hx = self.engine.upload(&h, &[p, d])?;
            let mut args: Vec<&Buffer> =
                vec![&hx, &self.rope_prefill_bufs.0, &self.rope_prefill_bufs.1];
            args.extend(bufs.iter());
            let mut out = self.engine.run("layer_prefill", &args)?;
            let v_rows = out.pop().expect("v");
            let k_rows = out.pop().expect("k");
            h = out.pop().expect("y");
            hook(self.layer_range.start + i, &mut h);
            kvs.push((k_rows, v_rows));
        }
        Ok((h, kvs))
    }

    /// One decode step at `pos` through this node's layers. `kv` must hold
    /// one LayerKv per layer in `layer_range` and is updated in place with
    /// the new token's K/V rows.
    pub fn decode(&self, x: &[f32], kv: &mut [LayerKv], pos: usize) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        let d = cfg.d_model;
        let w = cfg.max_seq;
        let kvw = cfg.kv_width();
        assert_eq!(x.len(), d);
        assert_eq!(kv.len(), self.layer_range.len(), "one KV cache per layer");
        assert!(pos < w, "position {pos} beyond static cache {w}");
        let pos_buf = self.engine.upload_i32(&[pos as i32], &[1])?;
        let (cr, sr) = self.rope.rows(pos, 1);
        let cos_buf = self.engine.upload(cr, &[1, self.rope.half_dim])?;
        let sin_buf = self.engine.upload(sr, &[1, self.rope.half_dim])?;
        let mut h = x.to_vec();
        for (bufs, cache) in self.weight_bufs.iter().zip(kv.iter_mut()) {
            let hx = self.engine.upload(&h, &[1, d])?;
            let kc = self.engine.upload(&cache.k, &[w, kvw])?;
            let vc = self.engine.upload(&cache.v, &[w, kvw])?;
            let mut args: Vec<&Buffer> =
                vec![&hx, &kc, &vc, &pos_buf, &cos_buf, &sin_buf];
            args.extend(bufs.iter());
            let mut out = self.engine.run("layer_decode", &args)?;
            cache.v = out.pop().expect("v_cache");
            cache.k = out.pop().expect("k_cache");
            h = out.pop().expect("y");
        }
        Ok(h)
    }

    /// Final norm + vocab projection for a full prefill block (P, d).
    pub fn logits_prefill(&self, h: &[f32]) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        let (gf, w_out) = self.head_bufs.as_ref().expect("node has no lm head");
        let hx = self.engine.upload(h, &[cfg.prefill_len, cfg.d_model])?;
        let mut out = self.engine.run("lm_head_prefill", &[&hx, gf, w_out])?;
        Ok(out.pop().expect("logits"))
    }

    /// Final norm + vocab projection for one decode token (1, d).
    pub fn logits_decode(&self, h: &[f32]) -> Result<Vec<f32>> {
        let cfg = self.cfg();
        let (gf, w_out) = self.head_bufs.as_ref().expect("node has no lm head");
        let hx = self.engine.upload(h, &[1, cfg.d_model])?;
        let mut out = self.engine.run("lm_head_decode", &[&hx, gf, w_out])?;
        Ok(out.pop().expect("logits"))
    }

    /// Fresh zeroed KV caches for this node's layer range.
    pub fn fresh_kv(&self) -> Vec<LayerKv> {
        let cfg = self.cfg();
        (0..self.layer_range.len())
            .map(|_| LayerKv::zeros(cfg.max_seq, cfg.kv_width()))
            .collect()
    }

    /// Install prefill K/V rows (P, H*D) into zeroed full caches.
    pub fn install_prefill_kv(&self, rows: &[(Vec<f32>, Vec<f32>)], prompt_len: usize) -> Vec<LayerKv> {
        let cfg = self.cfg();
        let kvw = cfg.kv_width();
        rows.iter()
            .map(|(k_rows, v_rows)| {
                let mut c = LayerKv::zeros(cfg.max_seq, kvw);
                c.k[..prompt_len * kvw].copy_from_slice(&k_rows[..prompt_len * kvw]);
                c.v[..prompt_len * kvw].copy_from_slice(&v_rows[..prompt_len * kvw]);
                c
            })
            .collect()
    }
}
