//! Pure-Rust reference engine: executes the per-layer decoder math on the
//! host, mirroring the jnp oracles in `python/compile/kernels/ref.py`
//! (RMSNorm → rotary QKV → causal / cached attention → SwiGLU FFN).
//!
//! This is the default engine (no `pjrt` feature): it needs no artifacts,
//! no `xla` bindings and no `make artifacts` step, which keeps the whole
//! test and bench suite runnable offline. The API is a drop-in for the
//! PJRT engine — `NodeRuntime` cannot tell them apart.
//!
//! Two execution surfaces share one set of kernels:
//!
//!   * **In-place, borrowed-buffer entry points**
//!     ([`Engine::layer_prefill_inplace`], [`Engine::layer_decode_batch`],
//!     [`Engine::lm_head_into`]) — the serving hot path. KV caches are
//!     mutated through `&mut LayerKv` (decode writes exactly one row at
//!     `pos`), activations live in a caller-owned [`EngineScratch`], and
//!     the stacked decode entry runs B sessions through each weight
//!     matrix in a single traversal.
//!   * **The artifact-style `upload`/[`Engine::run`] surface** — the
//!     pre-PR copy semantics (full caches cloned in, fresh caches
//!     returned), kept for PJRT API parity and as the perf baseline /
//!     equivalence oracle driven by `benches/engine.rs`.
//!
//! The dense kernel is a cache-blocked, row-tiled `matmul_into`
//! parallelized with `std::thread::scope` (the `CompressedKv::compress`
//! fan-out pattern). Accumulation order over the inner dimension is
//! identical in every path — serial, row-parallel, column-parallel, any
//! batch width — so stacked decode is bit-identical to sequential decode
//! and results do not depend on the worker count.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::{bail, ensure, Result};

use super::manifest::ShapeClassManifest;
use super::node::{DecodeStep, EngineScratch, LayerKv};
use crate::model::ModelConfig;

/// Host tensor standing in for a device-resident PJRT buffer.
#[derive(Clone, Debug)]
pub enum Buffer {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Buffer {
    fn f32(&self) -> Result<(&[f32], &[usize])> {
        match self {
            Buffer::F32 { data, dims } => Ok((data, dims)),
            Buffer::I32 { .. } => bail!("expected f32 buffer, got i32"),
        }
    }

    fn i32(&self) -> Result<(&[i32], &[usize])> {
        match self {
            Buffer::I32 { data, dims } => Ok((data, dims)),
            Buffer::F32 { .. } => bail!("expected i32 buffer, got f32"),
        }
    }
}

pub struct Engine {
    /// Synthetic shape-class manifest (no artifacts on disk in reference
    /// mode); `artifacts` is empty, which `splitserve doctor` reports.
    pub class: ShapeClassManifest,
    /// Elements copied through `upload`/`upload_i32` over the engine's
    /// lifetime. The in-place decode path never uploads, so
    /// `benches/engine.rs` asserts this counter is FLAT across decode
    /// steps — the "zero full-KV-cache copies" acceptance gate.
    uploaded_elems: AtomicU64,
}

const EPS: f32 = 1e-5;

/// One decoder layer's weight slices in artifact argument order.
struct LayerW<'a> {
    wq: &'a [f32],
    wk: &'a [f32],
    wv: &'a [f32],
    wo: &'a [f32],
    wg: &'a [f32],
    wu: &'a [f32],
    wd: &'a [f32],
    g1: &'a [f32],
    g2: &'a [f32],
    d: usize,
    f: usize,
}

impl<'a> LayerW<'a> {
    /// Shared destructuring over any 9-buffer weight view (`get(i)` is
    /// the i-th buffer) — the single place the artifact weight order is
    /// spelled out.
    fn build(get: impl Fn(usize) -> &'a Buffer) -> Result<LayerW<'a>> {
        let (wq, wqd) = get(0).f32()?;
        let (wk, _) = get(1).f32()?;
        let (wv, _) = get(2).f32()?;
        let (wo, _) = get(3).f32()?;
        let (wg, wgd) = get(4).f32()?;
        let (wu, _) = get(5).f32()?;
        let (wd, _) = get(6).f32()?;
        let (g1, _) = get(7).f32()?;
        let (g2, _) = get(8).f32()?;
        Ok(LayerW { wq, wk, wv, wo, wg, wu, wd, g1, g2, d: wqd[1], f: wgd[1] })
    }

    fn from_bufs(w: &'a [Buffer]) -> Result<LayerW<'a>> {
        ensure!(w.len() == 9, "layer weights want 9 buffers, got {}", w.len());
        Self::build(|i| &w[i])
    }

    fn from_args(args: &[&'a Buffer]) -> Result<LayerW<'a>> {
        ensure!(args.len() == 9, "layer weights want 9 buffers, got {}", args.len());
        Self::build(|i| args[i])
    }
}

impl Engine {
    /// Construct the reference engine for `cfg`'s shape class. The
    /// `artifacts_dir` argument is accepted for API parity with the PJRT
    /// engine and ignored — the reference engine needs no artifacts.
    pub fn load(_artifacts_dir: &str, cfg: &ModelConfig) -> Result<Engine> {
        Ok(Engine {
            class: ShapeClassManifest {
                name: cfg.shape_class.dir_name().to_string(),
                d_model: cfg.d_model,
                n_heads: cfg.n_heads,
                head_dim: cfg.head_dim,
                d_ff: cfg.d_ff,
                vocab: cfg.vocab,
                max_seq: cfg.max_seq,
                prefill_len: cfg.prefill_len,
                artifacts: BTreeMap::new(),
                golden: BTreeMap::new(),
            },
            uploaded_elems: AtomicU64::new(0),
        })
    }

    /// Host tensor "upload" (clone; the PJRT engine copies to device).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        ensure!(dims.iter().product::<usize>() == data.len(), "upload shape mismatch");
        self.uploaded_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(Buffer::F32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        ensure!(dims.iter().product::<usize>() == data.len(), "upload shape mismatch");
        self.uploaded_elems.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(Buffer::I32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Elements cloned through the upload surface so far (copy-counting
    /// probe for the zero-copy decode assertion).
    pub fn uploaded_elems(&self) -> u64 {
        self.uploaded_elems.load(Ordering::Relaxed)
    }

    /// In-place single-layer prefill: transforms `h` (rows, d) in place
    /// and returns this layer's rotary-embedded K and raw V rows. All
    /// intermediates live in `s`; nothing but the returned rows is
    /// allocated after warmup.
    pub fn layer_prefill_inplace(
        &self,
        s: &mut EngineScratch,
        h: &mut [f32],
        rows: usize,
        cos: &[f32],
        sin: &[f32],
        w: &[Buffer],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let lw = LayerW::from_bufs(w)?;
        ensure!(rows > 0 && h.len() == rows * lw.d, "prefill hidden must be ({rows}, {})", lw.d);
        let half = cos.len() / rows;
        let head_dim = 2 * half;
        ensure!(
            head_dim > 0 && lw.d % head_dim == 0,
            "d_model {} not divisible by head_dim {head_dim}",
            lw.d
        );
        Ok(layer_forward_prefill(s, h, rows, cos, sin, &lw))
    }

    /// In-place single-layer prefill over the *suffix* rows
    /// `[start, start + rows)` of a block whose first `start` rows were
    /// prefilled earlier and whose per-layer K/V rows are supplied from a
    /// prefix cache (`prefix_k` rotary-embedded, `prefix_v` raw — exactly
    /// what [`layer_prefill_inplace`](Engine::layer_prefill_inplace)
    /// returned for those rows). Every non-attention op in the layer is
    /// strictly per-row and attention is strictly causal per query row,
    /// so the suffix rows this computes are **bit-identical** to the same
    /// rows of a whole-block prefill — the invariant the prefix cache's
    /// warm ≡ cold guarantee rests on, pinned by
    /// `suffix_prefill_is_bit_identical_to_whole_block` below.
    ///
    /// `cos`/`sin` must be the rope rows for the *global* positions
    /// `[start, start + rows)`. Returns this layer's suffix K/V rows.
    #[allow(clippy::too_many_arguments)]
    pub fn layer_prefill_suffix_inplace(
        &self,
        s: &mut EngineScratch,
        h: &mut [f32],
        rows: usize,
        start: usize,
        cos: &[f32],
        sin: &[f32],
        prefix_k: &[f32],
        prefix_v: &[f32],
        w: &[Buffer],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let lw = LayerW::from_bufs(w)?;
        ensure!(rows > 0 && h.len() == rows * lw.d, "suffix hidden must be ({rows}, {})", lw.d);
        let half = cos.len() / rows;
        let head_dim = 2 * half;
        ensure!(
            head_dim > 0 && lw.d % head_dim == 0,
            "d_model {} not divisible by head_dim {head_dim}",
            lw.d
        );
        ensure!(
            prefix_k.len() == start * lw.d && prefix_v.len() == start * lw.d,
            "prefix K/V must cover exactly ({start}, {}) rows",
            lw.d
        );
        Ok(layer_forward_prefill_suffix(s, h, rows, start, cos, sin, prefix_k, prefix_v, &lw))
    }

    /// In-place, stacked single-layer decode over B independent sessions:
    /// `hs` is the (B, d) residual block, `kvs[b][layer]` the cache this
    /// call mutates (one new row at `step.positions[b]`; never cloned or
    /// returned). Per-row math is identical to a B = 1 call, so stacking
    /// is bit-transparent.
    pub fn layer_decode_batch(
        &self,
        s: &mut EngineScratch,
        hs: &mut [f32],
        kvs: &mut [&mut [LayerKv]],
        layer: usize,
        step: &DecodeStep<'_>,
        w: &[Buffer],
    ) -> Result<()> {
        let lw = LayerW::from_bufs(w)?;
        let b = step.positions.len();
        ensure!(b > 0 && hs.len() == b * lw.d, "stacked hidden must be ({b}, {})", lw.d);
        ensure!(kvs.len() == b, "one KV-cache set per stacked session");
        ensure!(step.cos.len() == step.sin.len(), "rope row mismatch");
        layer_forward_decode(s, hs, kvs, layer, step, &lw)
    }

    /// Final norm + vocab projection of a (rows, d) block into `out`
    /// (cleared and refilled; reusable across calls).
    pub fn lm_head_into(
        &self,
        s: &mut EngineScratch,
        h: &[f32],
        rows: usize,
        gf: &Buffer,
        w_out: &Buffer,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let (gf, _) = gf.f32()?;
        let (wo, wod) = w_out.f32()?;
        let d = gf.len();
        let vocab = wod[1];
        ensure!(h.len() == rows * d, "lm head input must be ({rows}, {d})");
        lm_head_forward(s, h, rows, gf, wo, vocab, out);
        Ok(())
    }

    /// Execute an "artifact" by name. Same entrypoints and argument order
    /// as the AOT modules (python/compile/model.py) — and the same COPY
    /// semantics: `layer_decode` clones the caches it is given and
    /// returns fresh ones. The serving path uses the in-place entry
    /// points above; this surface remains for PJRT parity and as the
    /// pre-PR baseline in `benches/engine.rs`.
    pub fn run(&self, name: &str, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        match name {
            "layer_prefill" => self.layer_prefill(args),
            "layer_decode" => self.layer_decode(args),
            "lm_head_prefill" | "lm_head_decode" => self.lm_head(args),
            other => bail!("reference engine: unknown artifact '{other}'"),
        }
    }

    /// x(P,d), cos(P,D/2), sin(P,D/2), wq wk wv wo(d,d), w_gate w_up(d,f),
    /// w_down(f,d), g1(d), g2(d) → [y(P,d), k_rows(P,d), v_rows(P,d)].
    fn layer_prefill(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        ensure!(args.len() == 12, "layer_prefill wants 12 args, got {}", args.len());
        let (x, xd) = args[0].f32()?;
        let (cos, cd) = args[1].f32()?;
        let (sin, _) = args[2].f32()?;
        let rows = xd[0];
        let half = cd[1];
        let head_dim = 2 * half;
        let lw = LayerW::from_args(&args[3..])?;
        ensure!(xd[1] == lw.d, "hidden width mismatch");
        ensure!(lw.d % head_dim == 0, "d_model {} not divisible by head_dim {head_dim}", lw.d);
        let mut y = x.to_vec();
        let mut s = EngineScratch::default();
        let (k, v) = layer_forward_prefill(&mut s, &mut y, rows, cos, sin, &lw);
        Ok(vec![y, k, v])
    }

    /// x(1,d), k_cache(W,kvw), v_cache(W,kvw), pos i32[1], cos(1,D/2),
    /// sin(1,D/2), 9 weights → [y(1,d), k_cache', v_cache'].
    fn layer_decode(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        ensure!(args.len() == 15, "layer_decode wants 15 args, got {}", args.len());
        let (x, xd) = args[0].f32()?;
        let (kc, kcd) = args[1].f32()?;
        let (vc, _) = args[2].f32()?;
        let (pos, _) = args[3].i32()?;
        let (cos, _) = args[4].f32()?;
        let (sin, _) = args[5].f32()?;
        let d = xd[1];
        let (cache_w, kvw) = (kcd[0], kcd[1]);
        ensure!(kvw == d, "reference engine assumes kv_width == d_model");
        let pos = pos[0] as usize;
        ensure!(pos < cache_w, "decode position {pos} beyond cache {cache_w}");
        let lw = LayerW::from_args(&args[6..])?;
        ensure!(lw.d == d, "hidden width mismatch");
        // Copy semantics preserved: clone the caches in, return fresh ones.
        let mut cache = LayerKv { k: kc.to_vec(), v: vc.to_vec() };
        let mut h = x.to_vec();
        let mut s = EngineScratch::default();
        let positions = [pos];
        let step = DecodeStep { positions: &positions, cos, sin };
        {
            let mut sess: [&mut [LayerKv]; 1] = [std::slice::from_mut(&mut cache)];
            layer_forward_decode(&mut s, &mut h, &mut sess, 0, &step, &lw)?;
        }
        Ok(vec![h, cache.k, cache.v])
    }

    /// x(w,d), gf(d), w_out(d,vocab) → [logits(w,vocab)].
    fn lm_head(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        ensure!(args.len() == 3, "lm_head wants 3 args, got {}", args.len());
        let (x, xd) = args[0].f32()?;
        let (gf, _) = args[1].f32()?;
        let (w_out, wod) = args[2].f32()?;
        let (rows, d) = (xd[0], xd[1]);
        ensure!(gf.len() == d, "final norm width mismatch");
        let vocab = wod[1];
        let mut s = EngineScratch::default();
        let mut out = Vec::new();
        lm_head_forward(&mut s, x, rows, gf, w_out, vocab, &mut out);
        Ok(vec![out])
    }
}

// ---------------------------------------------------------------------------
// Layer cores (shared by the in-place and artifact-style surfaces)
// ---------------------------------------------------------------------------

/// One decoder layer over a (rows, d) block, residual stream transformed
/// in place. Returns owned copies of the rotary-embedded K rows and raw V
/// rows (the prefill outputs installed into a request's caches).
fn layer_forward_prefill(
    s: &mut EngineScratch,
    h: &mut [f32],
    rows: usize,
    cos: &[f32],
    sin: &[f32],
    lw: &LayerW<'_>,
) -> (Vec<f32>, Vec<f32>) {
    let d = lw.d;
    let half = cos.len() / rows;
    let head_dim = 2 * half;
    let heads = d / head_dim;
    rms_norm_into(h, rows, d, lw.g1, &mut s.h_norm);
    resize_buf(&mut s.q, rows * d);
    matmul_into(&mut s.q, &s.h_norm, lw.wq, rows, d, d);
    resize_buf(&mut s.k, rows * d);
    matmul_into(&mut s.k, &s.h_norm, lw.wk, rows, d, d);
    resize_buf(&mut s.v, rows * d);
    matmul_into(&mut s.v, &s.h_norm, lw.wv, rows, d, d);
    apply_rope(&mut s.q, rows, heads, head_dim, cos, sin);
    apply_rope(&mut s.k, rows, heads, head_dim, cos, sin);
    attention_prefill(s, rows, heads, head_dim);
    resize_buf(&mut s.proj, rows * d);
    matmul_into(&mut s.proj, &s.attn, lw.wo, rows, d, d);
    add_assign(h, &s.proj);
    let k_rows = s.k.clone();
    let v_rows = s.v.clone();
    ffn_inplace(s, h, rows, lw);
    (k_rows, v_rows)
}

/// One decoder layer over the suffix rows `[start, start + rows)` with
/// the first `start` rows' K/V supplied from a prefix cache. The residual
/// stream `h` holds only the suffix rows and is transformed in place;
/// returns the suffix K/V rows. Arithmetic is ordered identically to
/// [`layer_forward_prefill`] row for row — rms-norm, the Q/K/V/O/FFN
/// matmuls and rope are per-row, and
/// [`attention_prefill_with_prefix`] replays the exact ascending-j
/// summation of [`attention_prefill`] — so the results match a
/// whole-block prefill bit for bit.
#[allow(clippy::too_many_arguments)]
fn layer_forward_prefill_suffix(
    s: &mut EngineScratch,
    h: &mut [f32],
    rows: usize,
    start: usize,
    cos: &[f32],
    sin: &[f32],
    prefix_k: &[f32],
    prefix_v: &[f32],
    lw: &LayerW<'_>,
) -> (Vec<f32>, Vec<f32>) {
    let d = lw.d;
    let half = cos.len() / rows;
    let head_dim = 2 * half;
    let heads = d / head_dim;
    rms_norm_into(h, rows, d, lw.g1, &mut s.h_norm);
    resize_buf(&mut s.q, rows * d);
    matmul_into(&mut s.q, &s.h_norm, lw.wq, rows, d, d);
    resize_buf(&mut s.k, rows * d);
    matmul_into(&mut s.k, &s.h_norm, lw.wk, rows, d, d);
    resize_buf(&mut s.v, rows * d);
    matmul_into(&mut s.v, &s.h_norm, lw.wv, rows, d, d);
    apply_rope(&mut s.q, rows, heads, head_dim, cos, sin);
    apply_rope(&mut s.k, rows, heads, head_dim, cos, sin);
    attention_prefill_with_prefix(s, start, rows, heads, head_dim, prefix_k, prefix_v);
    resize_buf(&mut s.proj, rows * d);
    matmul_into(&mut s.proj, &s.attn, lw.wo, rows, d, d);
    add_assign(h, &s.proj);
    let k_rows = s.k.clone();
    let v_rows = s.v.clone();
    ffn_inplace(s, h, rows, lw);
    (k_rows, v_rows)
}

/// One decoder layer, one decode step, B stacked sessions; `hs` (B, d)
/// transformed in place, each session's cache gaining exactly one (k, v)
/// row at its position. Zero allocation after scratch warmup.
fn layer_forward_decode(
    s: &mut EngineScratch,
    hs: &mut [f32],
    kvs: &mut [&mut [LayerKv]],
    layer: usize,
    step: &DecodeStep<'_>,
    lw: &LayerW<'_>,
) -> Result<()> {
    let d = lw.d;
    let b = step.positions.len();
    let half = step.cos.len() / b;
    let head_dim = 2 * half;
    ensure!(head_dim > 0 && d % head_dim == 0, "d_model {d} not divisible by head_dim {head_dim}");
    let heads = d / head_dim;
    let kvw = d; // reference engine assumes kv_width == d_model
    rms_norm_into(hs, b, d, lw.g1, &mut s.h_norm);
    resize_buf(&mut s.q, b * d);
    matmul_into(&mut s.q, &s.h_norm, lw.wq, b, d, d);
    resize_buf(&mut s.k, b * d);
    matmul_into(&mut s.k, &s.h_norm, lw.wk, b, d, d);
    resize_buf(&mut s.v, b * d);
    matmul_into(&mut s.v, &s.h_norm, lw.wv, b, d, d);
    apply_rope(&mut s.q, b, heads, head_dim, step.cos, step.sin);
    apply_rope(&mut s.k, b, heads, head_dim, step.cos, step.sin);
    resize_buf(&mut s.attn, b * d);
    for (bi, (sess, &pos)) in kvs.iter_mut().zip(step.positions.iter()).enumerate() {
        let cache = &mut sess[layer];
        let cache_w = cache.k.len() / kvw;
        ensure!(pos < cache_w, "decode position {pos} beyond cache {cache_w}");
        cache.k[pos * kvw..(pos + 1) * kvw].copy_from_slice(&s.k[bi * d..(bi + 1) * d]);
        cache.v[pos * kvw..(pos + 1) * kvw].copy_from_slice(&s.v[bi * d..(bi + 1) * d]);
        attention_decode_row(
            &mut s.attn[bi * d..(bi + 1) * d],
            &s.q[bi * d..(bi + 1) * d],
            cache,
            pos,
            heads,
            head_dim,
            &mut s.scores,
        );
    }
    resize_buf(&mut s.proj, b * d);
    matmul_into(&mut s.proj, &s.attn, lw.wo, b, d, d);
    add_assign(hs, &s.proj);
    ffn_inplace(s, hs, b, lw);
    Ok(())
}

/// Final RMSNorm + vocab projection into `out`.
fn lm_head_forward(
    s: &mut EngineScratch,
    h: &[f32],
    rows: usize,
    gf: &[f32],
    w_out: &[f32],
    vocab: usize,
    out: &mut Vec<f32>,
) {
    let d = gf.len();
    rms_norm_into(h, rows, d, gf, &mut s.h_norm);
    out.clear();
    out.resize(rows * vocab, 0.0);
    matmul_into(out, &s.h_norm, w_out, rows, d, vocab);
}

/// SwiGLU FFN with pre-norm, accumulated into the residual stream:
/// h += (silu(rms(h, g2) @ wg) * (rms(h, g2) @ wu)) @ wd.
fn ffn_inplace(s: &mut EngineScratch, h: &mut [f32], rows: usize, lw: &LayerW<'_>) {
    let (d, f) = (lw.d, lw.f);
    rms_norm_into(h, rows, d, lw.g2, &mut s.h_norm);
    resize_buf(&mut s.gate, rows * f);
    matmul_into(&mut s.gate, &s.h_norm, lw.wg, rows, d, f);
    resize_buf(&mut s.up, rows * f);
    matmul_into(&mut s.up, &s.h_norm, lw.wu, rows, d, f);
    for (g, u) in s.gate.iter_mut().zip(&s.up) {
        *g = silu(*g) * u;
    }
    resize_buf(&mut s.proj, rows * d);
    matmul_into(&mut s.proj, &s.gate, lw.wd, rows, f, d);
    add_assign(h, &s.proj);
}

// ---------------------------------------------------------------------------
// Dense kernels
// ---------------------------------------------------------------------------

/// Size a scratch buffer without re-zeroing it at steady state: every
/// consumer (matmul_into, attention_decode_row) initializes its output
/// before accumulating, so the memset would be pure overhead on the hot
/// path once the buffer has its final size.
fn resize_buf(v: &mut Vec<f32>, n: usize) {
    if v.len() != n {
        v.clear();
        v.resize(n, 0.0);
    }
}

/// RMSNorm over the last axis into `out`: x / sqrt(mean(x^2) + eps) * gamma.
fn rms_norm_into(x: &[f32], rows: usize, d: usize, gamma: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(rows * d);
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let var: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        out.extend(row.iter().zip(gamma).map(|(v, g)| v * inv * g));
    }
}

/// Inner-dimension block size: keeps the streamed rows of `b` hot in L1
/// across the unrolled accumulation.
const K_BLOCK: usize = 64;
/// Minimum m*k*n before scoped worker threads beat their spawn cost.
const PAR_WORK_MIN: usize = 1 << 21;
/// Worker cap (matmuls this size stop scaling past a few cores).
const MAX_WORKERS: usize = 8;

fn matmul_workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(MAX_WORKERS)
    })
}

/// Row-major (m,k) @ (k,n) → `out` (m,n), overwritten. Cache-blocked over
/// k and tiled across scoped worker threads (rows for m > 1, column
/// ranges for the single-row decode/lm-head shape) when the FLOP count
/// justifies the spawn cost. Every path accumulates each output element
/// over k in ascending order, so serial, parallel, and any batch width
/// produce bit-identical results — the invariant the stacked-decode
/// equivalence tests pin.
fn matmul_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let workers = if m * k * n >= PAR_WORK_MIN { matmul_workers() } else { 1 };
    if workers <= 1 {
        matmul_serial(out, a, b, k, n);
    } else if m == 1 {
        // One output row: split its columns into contiguous chunks.
        let chunk = n.div_ceil(workers);
        std::thread::scope(|scope| {
            for (ti, ochunk) in out.chunks_mut(chunk).enumerate() {
                scope.spawn(move || matmul_cols_serial(ochunk, a, b, k, n, ti * chunk));
            }
        });
    } else {
        // Row tiles: each worker owns a contiguous band of output rows.
        let rows_per = m.div_ceil(workers);
        std::thread::scope(|scope| {
            for (ochunk, achunk) in out.chunks_mut(rows_per * n).zip(a.chunks(rows_per * k)) {
                scope.spawn(move || matmul_serial(ochunk, achunk, b, k, n));
            }
        });
    }
}

/// Serial (m,k) @ (k,n) over full-width rows, k-blocked.
fn matmul_serial(out: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
    let m = out.len() / n;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        orow.fill(0.0);
        for k0 in (0..k).step_by(K_BLOCK) {
            let kend = (k0 + K_BLOCK).min(k);
            for (kk, &aik) in arow[k0..kend].iter().enumerate() {
                let brow = &b[(k0 + kk) * n..(k0 + kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        }
    }
}

/// Serial single-row matmul restricted to columns [j0, j0 + orow.len()).
fn matmul_cols_serial(orow: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize, j0: usize) {
    orow.fill(0.0);
    let w = orow.len();
    for k0 in (0..k).step_by(K_BLOCK) {
        let kend = (k0 + K_BLOCK).min(k);
        for (kk, &aik) in a[k0..kend].iter().enumerate() {
            let bseg = &b[(k0 + kk) * n + j0..(k0 + kk) * n + j0 + w];
            for (o, &bv) in orow.iter_mut().zip(bseg) {
                *o += aik * bv;
            }
        }
    }
}

fn add_assign(a: &mut [f32], b: &[f32]) {
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Rotate-half rotary embedding in place. x: (w, H, D); cos/sin: (w, D/2).
fn apply_rope(x: &mut [f32], w: usize, heads: usize, head_dim: usize, cos: &[f32], sin: &[f32]) {
    let half = head_dim / 2;
    for t in 0..w {
        let (ct, st) = (&cos[t * half..(t + 1) * half], &sin[t * half..(t + 1) * half]);
        for h in 0..heads {
            let base = (t * heads + h) * head_dim;
            for i in 0..half {
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                x[base + i] = x1 * ct[i] - x2 * st[i];
                x[base + half + i] = x2 * ct[i] + x1 * st[i];
            }
        }
    }
}

/// Causal multi-head attention over the scratch arena: reads `s.q`,
/// `s.k`, `s.v` (each (w, H*D)) and fills `s.attn`; `s.scores` is the
/// per-query score buffer.
fn attention_prefill(s: &mut EngineScratch, w: usize, heads: usize, head_dim: usize) {
    let EngineScratch { q, k, v, attn, scores, .. } = s;
    let kvw = heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    attn.clear();
    attn.resize(w * kvw, 0.0);
    scores.clear();
    scores.resize(w, 0.0);
    for h in 0..heads {
        let off = h * head_dim;
        for i in 0..w {
            let qi = &q[i * kvw + off..i * kvw + off + head_dim];
            let mut smax = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate().take(i + 1) {
                let kj = &k[j * kvw + off..j * kvw + off + head_dim];
                let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                *sc = dot * scale;
                smax = smax.max(*sc);
            }
            let mut z = 0f32;
            for sc in scores.iter_mut().take(i + 1) {
                *sc = (*sc - smax).exp();
                z += *sc;
            }
            let orow = &mut attn[i * kvw + off..i * kvw + off + head_dim];
            for (j, &p) in scores.iter().enumerate().take(i + 1) {
                let vj = &v[j * kvw + off..j * kvw + off + head_dim];
                let pw = p / z;
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += pw * vv;
                }
            }
        }
    }
}

/// Causal multi-head attention for suffix query rows `[start, start+rows)`
/// where K/V rows `j < start` come from a prefix cache and rows
/// `j >= start` from the scratch arena (`s.k`/`s.v`, suffix-local).
/// Replays [`attention_prefill`]'s exact per-query arithmetic — ascending-j
/// dot/scale/running-smax, then ascending exp/z, then ascending weighted-V
/// accumulation — only the *source* of each K/V row differs, so every
/// output row is bit-identical to the whole-block kernel's. Fills
/// `s.attn` with the (rows, H*D) suffix attention output.
fn attention_prefill_with_prefix(
    s: &mut EngineScratch,
    start: usize,
    rows: usize,
    heads: usize,
    head_dim: usize,
    prefix_k: &[f32],
    prefix_v: &[f32],
) {
    let EngineScratch { q, k, v, attn, scores, .. } = s;
    let kvw = heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    attn.clear();
    attn.resize(rows * kvw, 0.0);
    scores.clear();
    scores.resize(start + rows, 0.0);
    for h in 0..heads {
        let off = h * head_dim;
        for i in 0..rows {
            let gi = start + i; // global query position
            let qi = &q[i * kvw + off..i * kvw + off + head_dim];
            let mut smax = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate().take(gi + 1) {
                // K/V row `j` of the logical whole block: prefix cache
                // below `start`, scratch (suffix-local) at or above it.
                let kj = if j < start {
                    &prefix_k[j * kvw + off..j * kvw + off + head_dim]
                } else {
                    &k[(j - start) * kvw + off..(j - start) * kvw + off + head_dim]
                };
                let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                *sc = dot * scale;
                smax = smax.max(*sc);
            }
            let mut z = 0f32;
            for sc in scores.iter_mut().take(gi + 1) {
                *sc = (*sc - smax).exp();
                z += *sc;
            }
            let orow = &mut attn[i * kvw + off..i * kvw + off + head_dim];
            for (j, &p) in scores.iter().enumerate().take(gi + 1) {
                let vj = if j < start {
                    &prefix_v[j * kvw + off..j * kvw + off + head_dim]
                } else {
                    &v[(j - start) * kvw + off..(j - start) * kvw + off + head_dim]
                };
                let pw = p / z;
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += pw * vv;
                }
            }
        }
    }
}

/// Single-token attention for one session against its own cache; rows
/// beyond `pos` are masked. `q_row`: (H*D); writes `out_row`: (H*D).
fn attention_decode_row(
    out_row: &mut [f32],
    q_row: &[f32],
    cache: &LayerKv,
    pos: usize,
    heads: usize,
    head_dim: usize,
    scores: &mut Vec<f32>,
) {
    let kvw = heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    out_row.fill(0.0);
    scores.clear();
    scores.resize(pos + 1, 0.0);
    for h in 0..heads {
        let off = h * head_dim;
        let qh = &q_row[off..off + head_dim];
        let mut smax = f32::NEG_INFINITY;
        for (j, sc) in scores.iter_mut().enumerate() {
            let kj = &cache.k[j * kvw + off..j * kvw + off + head_dim];
            let dot: f32 = qh.iter().zip(kj).map(|(a, b)| a * b).sum();
            *sc = dot * scale;
            smax = smax.max(*sc);
        }
        let mut z = 0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - smax).exp();
            z += *sc;
        }
        let orow = &mut out_row[off..off + head_dim];
        for (j, &p) in scores.iter().enumerate() {
            let vj = &cache.v[j * kvw + off..j * kvw + off + head_dim];
            let pw = p / z;
            for (o, &vv) in orow.iter_mut().zip(vj) {
                *o += pw * vv;
            }
        }
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::runtime::{LayerKv, NodeRuntime, RopeTables};
    use crate::util::prop::run_cases;
    use crate::util::rng::Rng;
    use std::rc::Rc;

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        let mut worst = 0f32;
        for (g, w) in got.iter().zip(want) {
            worst = worst.max((g - w).abs());
        }
        assert!(worst <= tol, "{what}: max abs err {worst} > {tol}");
    }

    #[test]
    fn decode_reproduces_prefill_rows() {
        // The serving-critical invariant: decode(t) with caches from
        // prefill rows 0..t must equal prefill row t.
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
        let weights = Rc::new(ModelWeights::synthetic(&cfg, 42));
        let node = NodeRuntime::new(engine, weights.clone(), 0..2, true).unwrap();

        let tokens: Vec<u32> = (0..10u32).map(|i| (i * 37) % 512).collect();
        let x = weights.embed_padded(&tokens, cfg.prefill_len);
        let (h_pre, kv_rows) = node.prefill(&x).unwrap();

        let t = 6usize;
        let kvw = cfg.kv_width();
        let mut kv: Vec<LayerKv> = kv_rows
            .iter()
            .map(|(k_rows, v_rows)| {
                let mut c = LayerKv::zeros(cfg.max_seq, kvw);
                c.k[..t * kvw].copy_from_slice(&k_rows[..t * kvw]);
                c.v[..t * kvw].copy_from_slice(&v_rows[..t * kvw]);
                c
            })
            .collect();
        let xt = weights.embed(&tokens[t..t + 1]);
        let h_dec = node.decode(&xt, &mut kv, t).unwrap();
        let d = cfg.d_model;
        assert_close(&h_dec, &h_pre[t * d..(t + 1) * d], 5e-3, "decode vs prefill row");
    }

    #[test]
    fn split_across_two_nodes_matches_single_node() {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
        let weights = Rc::new(ModelWeights::synthetic(&cfg, 43));
        let full = NodeRuntime::new(engine.clone(), weights.clone(), 0..2, true).unwrap();
        let front = NodeRuntime::new(engine.clone(), weights.clone(), 0..1, false).unwrap();
        let back = NodeRuntime::new(engine.clone(), weights.clone(), 1..2, true).unwrap();

        let tokens: Vec<u32> = vec![5, 99, 210, 340];
        let x = weights.embed_padded(&tokens, cfg.prefill_len);
        let (h_full, _) = full.prefill(&x).unwrap();
        let (h_mid, _) = front.prefill(&x).unwrap();
        let (h_split, _) = back.prefill(&h_mid).unwrap();
        assert_close(&h_split, &h_full, 1e-4, "split prefill == full prefill");
    }

    #[test]
    fn attention_weights_sum_to_one_effectively() {
        // constant V must pass through attention unchanged
        let (heads, head_dim, w) = (2usize, 4usize, 5usize);
        let kvw = heads * head_dim;
        let mut s = EngineScratch {
            q: (0..w * kvw).map(|i| (i % 7) as f32 * 0.1).collect(),
            k: (0..w * kvw).map(|i| (i % 5) as f32 * 0.2).collect(),
            v: vec![3.5f32; w * kvw],
            ..Default::default()
        };
        attention_prefill(&mut s, w, heads, head_dim);
        for &o in &s.attn {
            assert!((o - 3.5).abs() < 1e-5, "attention must be a convex combination");
        }
    }

    #[test]
    fn rope_preserves_pair_norm() {
        let (heads, head_dim, w) = (1usize, 8usize, 3usize);
        let half = head_dim / 2;
        let mut x: Vec<f32> = (0..w * heads * head_dim).map(|i| (i as f32).sin()).collect();
        let orig = x.clone();
        let cos: Vec<f32> = (0..w * half).map(|i| ((i as f32) * 0.3).cos()).collect();
        let sin: Vec<f32> = (0..w * half).map(|i| ((i as f32) * 0.3).sin()).collect();
        apply_rope(&mut x, w, heads, head_dim, &cos, &sin);
        for t in 0..w {
            for i in 0..half {
                let b = t * head_dim;
                let n0 = orig[b + i].hypot(orig[b + half + i]);
                let n1 = x[b + i].hypot(x[b + half + i]);
                assert!((n0 - n1).abs() < 1e-5, "rotation must preserve norms");
            }
        }
    }

    #[test]
    fn matmul_parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(0xA11);
        // m == 1: the column-split decode/lm-head shape, above PAR_WORK_MIN.
        let (k, n) = (256usize, 8192usize);
        let a: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut par = vec![0f32; n];
        matmul_into(&mut par, &a, &b, 1, k, n);
        let mut ser = vec![0f32; n];
        matmul_serial(&mut ser, &a, &b, k, n);
        assert_eq!(par, ser, "column-parallel must be bit-identical to serial");
        // m > 1: the row-split prefill shape.
        let (m, k2, n2) = (64usize, 256usize, 256usize);
        let a2: Vec<f32> = (0..m * k2).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b2: Vec<f32> = (0..k2 * n2).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut par2 = vec![0f32; m * n2];
        matmul_into(&mut par2, &a2, &b2, m, k2, n2);
        let mut ser2 = vec![0f32; m * n2];
        matmul_serial(&mut ser2, &a2, &b2, k2, n2);
        assert_eq!(par2, ser2, "row-parallel must be bit-identical to serial");
    }

    #[test]
    fn copyful_decode_matches_inplace_bitwise() {
        // The retained pre-PR path is the equivalence oracle: both paths
        // must produce bit-identical hidden states AND caches.
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
        let weights = Rc::new(ModelWeights::synthetic(&cfg, 91));
        let node = NodeRuntime::new(engine, weights.clone(), 0..2, true).unwrap();
        let tokens: Vec<u32> = vec![9, 41, 300];
        let x = weights.embed_padded(&tokens, cfg.prefill_len);
        let (_, rows) = node.prefill(&x).unwrap();
        let mut kv_a = node.install_prefill_kv(&rows, tokens.len());
        let mut kv_b = kv_a.clone();
        let xt = weights.embed(&[123]);
        for step in 0..3 {
            let pos = tokens.len() + step;
            let h_a = node.decode(&xt, &mut kv_a, pos).unwrap();
            let h_b = node.decode_copyful(&xt, &mut kv_b, pos).unwrap();
            assert_eq!(h_a, h_b, "step {step}: hidden state diverged");
            assert_eq!(kv_a, kv_b, "step {step}: caches diverged");
        }
    }

    #[test]
    fn stacked_decode_bit_identical_to_sequential() {
        // ACCEPTANCE (batched decode): layer_decode_batch over B stacked
        // sessions == B sequential layer_decode calls, bit for bit, on
        // hidden rows, caches, and lm-head logits.
        run_cases(6, 0xB7, |_, rng| {
            let mut cfg = ModelConfig::sim7b();
            cfg.n_layers = 1 + rng.below(2);
            let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
            let weights = Rc::new(ModelWeights::synthetic(&cfg, 77 + rng.below(4) as u64));
            let node = NodeRuntime::new(engine, weights.clone(), 0..cfg.n_layers, true).unwrap();
            let d = cfg.d_model;
            let b = 2 + rng.below(4); // 2..=5 stacked sessions
            let mut solo_kv: Vec<Vec<LayerKv>> = Vec::new();
            let mut positions = Vec::new();
            let mut xs: Vec<Vec<f32>> = Vec::new();
            for _ in 0..b {
                let plen = 2 + rng.below(6);
                let tokens: Vec<u32> = (0..plen).map(|_| rng.below(cfg.vocab) as u32).collect();
                let x = weights.embed_padded(&tokens, cfg.prefill_len);
                let (_, rows) = node.prefill(&x).unwrap();
                solo_kv.push(node.install_prefill_kv(&rows, plen));
                positions.push(plen);
                xs.push(weights.embed(&[rng.below(cfg.vocab) as u32]));
            }
            let mut batch_kv = solo_kv.clone();
            let mut solo_h: Vec<Vec<f32>> = Vec::new();
            for (i, x) in xs.iter().enumerate() {
                solo_h.push(node.decode(x, &mut solo_kv[i], positions[i]).unwrap());
            }
            let mut hs: Vec<f32> = xs.iter().flat_map(|x| x.iter().copied()).collect();
            {
                let mut refs: Vec<&mut [LayerKv]> =
                    batch_kv.iter_mut().map(|c| c.as_mut_slice()).collect();
                node.decode_batch(&mut hs, &mut refs, &positions).unwrap();
            }
            for i in 0..b {
                assert_eq!(&hs[i * d..(i + 1) * d], solo_h[i].as_slice(), "hidden row {i}");
                assert_eq!(batch_kv[i], solo_kv[i], "caches of session {i}");
            }
            let stacked = node.logits_decode_batch(&hs, b).unwrap();
            for (i, h) in solo_h.iter().enumerate() {
                let solo = node.logits_decode(h).unwrap();
                assert_eq!(
                    &stacked[i * cfg.vocab..(i + 1) * cfg.vocab],
                    solo.as_slice(),
                    "logits row {i}"
                );
            }
        });
    }

    #[test]
    fn inplace_decode_performs_zero_uploads() {
        // The tentpole invariant: a decode step neither clones nor
        // round-trips the KV caches through the upload surface.
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
        let weights = Rc::new(ModelWeights::synthetic(&cfg, 17));
        let node = NodeRuntime::new(engine.clone(), weights.clone(), 0..2, true).unwrap();
        let tokens: Vec<u32> = vec![4, 8, 15];
        let x = weights.embed_padded(&tokens, cfg.prefill_len);
        let (_, rows) = node.prefill(&x).unwrap();
        let mut kv = node.install_prefill_kv(&rows, tokens.len());
        let xt = weights.embed(&[16]);
        let before = engine.uploaded_elems();
        let h = node.decode(&xt, &mut kv, tokens.len()).unwrap();
        let _ = node.logits_decode(&h).unwrap();
        assert_eq!(engine.uploaded_elems(), before, "in-place decode must not upload");
        // ... while the copyful baseline demonstrably round-trips caches.
        let _ = node.decode_copyful(&xt, &mut kv, tokens.len() + 1).unwrap();
        assert!(engine.uploaded_elems() > before, "copyful baseline uploads caches");
    }

    #[test]
    fn rope_tables_match_direct_formula() {
        // Guards the hoisted inverse-frequency computation.
        let t = RopeTables::new(32, 16, 10000.0);
        let half = 8;
        for p in [0usize, 3, 31] {
            for i in 0..half {
                let inv = 1.0 / 10000f64.powf((2 * i) as f64 / 16.0);
                let ang = p as f64 * inv;
                assert_eq!(t.cos[p * half + i], ang.cos() as f32, "cos({p},{i})");
                assert_eq!(t.sin[p * half + i], ang.sin() as f32, "sin({p},{i})");
            }
        }
    }

    #[test]
    fn suffix_prefill_is_bit_identical_to_whole_block() {
        // ACCEPTANCE (prefix cache): prefilling only the suffix rows with
        // cached prefix K/V must reproduce the whole-block prefill's
        // suffix hidden rows AND suffix K/V rows bit for bit — on the
        // front segment, the back segment, and the logits behind them.
        run_cases(4, 0x9F1F, |case, rng| {
            let mut cfg = ModelConfig::sim7b();
            cfg.n_layers = 1 + rng.below(3);
            let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
            let weights = Rc::new(ModelWeights::synthetic(&cfg, 300 + case as u64));
            let node =
                NodeRuntime::new(engine, weights.clone(), 0..cfg.n_layers, true).unwrap();
            let d = cfg.d_model;
            let kvw = cfg.kv_width();
            let p = cfg.prefill_len;
            let start = 1 + rng.below(p - 1); // split the block anywhere
            let tokens: Vec<u32> = (0..p).map(|_| rng.below(cfg.vocab) as u32).collect();
            let x = weights.embed_padded(&tokens, p);

            let (h_full, kv_full) = node.prefill(&x).unwrap();
            let prefix_kv: Vec<(Vec<f32>, Vec<f32>)> = kv_full
                .iter()
                .map(|(k, v)| (k[..start * kvw].to_vec(), v[..start * kvw].to_vec()))
                .collect();
            let (h_suf, kv_suf) = node.prefill_suffix(&x[start * d..], start, &prefix_kv).unwrap();

            assert_eq!(h_suf.as_slice(), &h_full[start * d..], "suffix hidden rows");
            for (li, ((ks, vs), (kf, vf))) in kv_suf.iter().zip(&kv_full).enumerate() {
                assert_eq!(ks.as_slice(), &kf[start * kvw..], "layer {li} suffix K rows");
                assert_eq!(vs.as_slice(), &vf[start * kvw..], "layer {li} suffix V rows");
            }
            // Logits over the suffix block == the same rows of the full
            // block's logits (the warm cloud samples from these).
            let lg_full = node.logits_prefill(&h_full).unwrap();
            let lg_suf = node.logits_rows(&h_suf, p - start).unwrap();
            assert_eq!(lg_suf.as_slice(), &lg_full[start * cfg.vocab..], "suffix logits");
        });
    }

    #[test]
    fn prefill_kv_install_prefix_and_zero_tail() {
        let k_rows: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let v_rows: Vec<f32> = (0..6).map(|i| (10 + i) as f32).collect();
        let c = LayerKv::from_prefill_rows(&k_rows, &v_rows, 4, 3);
        assert_eq!(c.k.len(), 12);
        assert_eq!(&c.k[..6], k_rows.as_slice());
        assert!(c.k[6..].iter().all(|&x| x == 0.0), "k tail must be zero");
        assert_eq!(&c.v[..6], v_rows.as_slice());
        assert!(c.v[6..].iter().all(|&x| x == 0.0), "v tail must be zero");
    }
}
