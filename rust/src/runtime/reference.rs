//! Pure-Rust reference engine: executes the per-layer decoder math on the
//! host, mirroring the jnp oracles in `python/compile/kernels/ref.py`
//! (RMSNorm → rotary QKV → causal / cached attention → SwiGLU FFN).
//!
//! This is the default engine (no `pjrt` feature): it needs no artifacts,
//! no `xla` bindings and no `make artifacts` step, which keeps the whole
//! test and bench suite runnable offline. The API is a drop-in for the
//! PJRT engine — `NodeRuntime` cannot tell them apart. Shapes are derived
//! from the buffers themselves, so both shape classes (and any depth
//! sweep) run without configuration.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use super::manifest::ShapeClassManifest;
use crate::model::ModelConfig;

/// Host tensor standing in for a device-resident PJRT buffer.
#[derive(Clone, Debug)]
pub enum Buffer {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Buffer {
    fn f32(&self) -> Result<(&[f32], &[usize])> {
        match self {
            Buffer::F32 { data, dims } => Ok((data, dims)),
            Buffer::I32 { .. } => bail!("expected f32 buffer, got i32"),
        }
    }

    fn i32(&self) -> Result<(&[i32], &[usize])> {
        match self {
            Buffer::I32 { data, dims } => Ok((data, dims)),
            Buffer::F32 { .. } => bail!("expected i32 buffer, got f32"),
        }
    }
}

pub struct Engine {
    /// Synthetic shape-class manifest (no artifacts on disk in reference
    /// mode); `artifacts` is empty, which `splitserve doctor` reports.
    pub class: ShapeClassManifest,
}

const EPS: f32 = 1e-5;

impl Engine {
    /// Construct the reference engine for `cfg`'s shape class. The
    /// `artifacts_dir` argument is accepted for API parity with the PJRT
    /// engine and ignored — the reference engine needs no artifacts.
    pub fn load(_artifacts_dir: &str, cfg: &ModelConfig) -> Result<Engine> {
        Ok(Engine {
            class: ShapeClassManifest {
                name: cfg.shape_class.dir_name().to_string(),
                d_model: cfg.d_model,
                n_heads: cfg.n_heads,
                head_dim: cfg.head_dim,
                d_ff: cfg.d_ff,
                vocab: cfg.vocab,
                max_seq: cfg.max_seq,
                prefill_len: cfg.prefill_len,
                artifacts: BTreeMap::new(),
                golden: BTreeMap::new(),
            },
        })
    }

    /// Host tensor "upload" (clone; the PJRT engine copies to device).
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        ensure!(dims.iter().product::<usize>() == data.len(), "upload shape mismatch");
        Ok(Buffer::F32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        ensure!(dims.iter().product::<usize>() == data.len(), "upload shape mismatch");
        Ok(Buffer::I32 { data: data.to_vec(), dims: dims.to_vec() })
    }

    /// Execute an "artifact" by name. Same entrypoints and argument order
    /// as the AOT modules (python/compile/model.py).
    pub fn run(&self, name: &str, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        match name {
            "layer_prefill" => self.layer_prefill(args),
            "layer_decode" => self.layer_decode(args),
            "lm_head_prefill" | "lm_head_decode" => self.lm_head(args),
            other => bail!("reference engine: unknown artifact '{other}'"),
        }
    }

    /// x(P,d), cos(P,D/2), sin(P,D/2), wq wk wv wo(d,d), w_gate w_up(d,f),
    /// w_down(f,d), g1(d), g2(d) → [y(P,d), k_rows(P,d), v_rows(P,d)].
    fn layer_prefill(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        ensure!(args.len() == 12, "layer_prefill wants 12 args, got {}", args.len());
        let (x, xd) = args[0].f32()?;
        let (cos, cd) = args[1].f32()?;
        let (sin, _) = args[2].f32()?;
        let (w, d) = (xd[0], xd[1]);
        let half = cd[1];
        let head_dim = 2 * half;
        ensure!(d % head_dim == 0, "d_model {d} not divisible by head_dim {head_dim}");
        let heads = d / head_dim;
        let (wq, _) = args[3].f32()?;
        let (wk, _) = args[4].f32()?;
        let (wv, _) = args[5].f32()?;
        let (wo, _) = args[6].f32()?;
        let (wg, wgd) = args[7].f32()?;
        let (wu, _) = args[8].f32()?;
        let (wd_, _) = args[9].f32()?;
        let (g1, _) = args[10].f32()?;
        let (g2, _) = args[11].f32()?;
        let f = wgd[1];

        let h = rms_norm(x, w, d, g1);
        let mut q = matmul(&h, wq, w, d, d);
        let mut k = matmul(&h, wk, w, d, d);
        let v = matmul(&h, wv, w, d, d);
        apply_rope(&mut q, w, heads, head_dim, cos, sin);
        apply_rope(&mut k, w, heads, head_dim, cos, sin);
        let attn = causal_attention(&q, &k, &v, w, heads, head_dim);
        let proj = matmul(&attn, wo, w, d, d);
        let mut x2 = x.to_vec();
        add_assign(&mut x2, &proj);
        let y = ffn(&x2, w, d, f, g2, wg, wu, wd_);
        Ok(vec![y, k, v])
    }

    /// x(1,d), k_cache(W,kvw), v_cache(W,kvw), pos i32[1], cos(1,D/2),
    /// sin(1,D/2), 9 weights → [y(1,d), k_cache', v_cache'].
    fn layer_decode(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        ensure!(args.len() == 15, "layer_decode wants 15 args, got {}", args.len());
        let (x, xd) = args[0].f32()?;
        let (kc, kcd) = args[1].f32()?;
        let (vc, _) = args[2].f32()?;
        let (pos, _) = args[3].i32()?;
        let (cos, cd) = args[4].f32()?;
        let (sin, _) = args[5].f32()?;
        let d = xd[1];
        let (cache_w, kvw) = (kcd[0], kcd[1]);
        ensure!(kvw == d, "reference engine assumes kv_width == d_model");
        let half = cd[1];
        let head_dim = 2 * half;
        let heads = d / head_dim;
        let pos = pos[0] as usize;
        ensure!(pos < cache_w, "decode position {pos} beyond cache {cache_w}");
        let (wq, _) = args[6].f32()?;
        let (wk, _) = args[7].f32()?;
        let (wv, _) = args[8].f32()?;
        let (wo, _) = args[9].f32()?;
        let (wg, wgd) = args[10].f32()?;
        let (wu, _) = args[11].f32()?;
        let (wd_, _) = args[12].f32()?;
        let (g1, _) = args[13].f32()?;
        let (g2, _) = args[14].f32()?;
        let f = wgd[1];

        let h = rms_norm(x, 1, d, g1);
        let mut q = matmul(&h, wq, 1, d, d);
        let mut k = matmul(&h, wk, 1, d, d);
        let v = matmul(&h, wv, 1, d, d);
        apply_rope(&mut q, 1, heads, head_dim, cos, sin);
        apply_rope(&mut k, 1, heads, head_dim, cos, sin);
        let mut k_cache = kc.to_vec();
        let mut v_cache = vc.to_vec();
        k_cache[pos * kvw..(pos + 1) * kvw].copy_from_slice(&k);
        v_cache[pos * kvw..(pos + 1) * kvw].copy_from_slice(&v);
        let attn = decode_attention(&q, &k_cache, &v_cache, pos, heads, head_dim);
        let proj = matmul(&attn, wo, 1, d, d);
        let mut x2 = x.to_vec();
        add_assign(&mut x2, &proj);
        let y = ffn(&x2, 1, d, f, g2, wg, wu, wd_);
        Ok(vec![y, k_cache, v_cache])
    }

    /// x(w,d), gf(d), w_out(d,vocab) → [logits(w,vocab)].
    fn lm_head(&self, args: &[&Buffer]) -> Result<Vec<Vec<f32>>> {
        ensure!(args.len() == 3, "lm_head wants 3 args, got {}", args.len());
        let (x, xd) = args[0].f32()?;
        let (gf, _) = args[1].f32()?;
        let (w_out, wod) = args[2].f32()?;
        let (w, d) = (xd[0], xd[1]);
        let vocab = wod[1];
        let h = rms_norm(x, w, d, gf);
        Ok(vec![matmul(&h, w_out, w, d, vocab)])
    }
}

/// RMSNorm over the last axis: x / sqrt(mean(x^2) + eps) * gamma.
fn rms_norm(x: &[f32], rows: usize, d: usize, gamma: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let var: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for c in 0..d {
            out[r * d + c] = row[c] * inv * gamma[c];
        }
    }
    out
}

/// Row-major (m,k) @ (k,n) → (m,n).
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += aik * brow[j];
            }
        }
    }
    out
}

fn add_assign(a: &mut [f32], b: &[f32]) {
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Rotate-half rotary embedding in place. x: (w, H, D); cos/sin: (w, D/2).
fn apply_rope(x: &mut [f32], w: usize, heads: usize, head_dim: usize, cos: &[f32], sin: &[f32]) {
    let half = head_dim / 2;
    for t in 0..w {
        let (ct, st) = (&cos[t * half..(t + 1) * half], &sin[t * half..(t + 1) * half]);
        for h in 0..heads {
            let base = (t * heads + h) * head_dim;
            for i in 0..half {
                let x1 = x[base + i];
                let x2 = x[base + half + i];
                x[base + i] = x1 * ct[i] - x2 * st[i];
                x[base + half + i] = x2 * ct[i] + x1 * st[i];
            }
        }
    }
}

/// Causal multi-head attention. q,k,v: (w, H*D) → (w, H*D).
fn causal_attention(q: &[f32], k: &[f32], v: &[f32], w: usize, heads: usize, head_dim: usize) -> Vec<f32> {
    let kvw = heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut out = vec![0f32; w * kvw];
    let mut scores = vec![0f32; w];
    for h in 0..heads {
        let off = h * head_dim;
        for i in 0..w {
            let qi = &q[i * kvw + off..i * kvw + off + head_dim];
            let mut smax = f32::NEG_INFINITY;
            for (j, sc) in scores.iter_mut().enumerate().take(i + 1) {
                let kj = &k[j * kvw + off..j * kvw + off + head_dim];
                let dot: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                *sc = dot * scale;
                smax = smax.max(*sc);
            }
            let mut z = 0f32;
            for sc in scores.iter_mut().take(i + 1) {
                *sc = (*sc - smax).exp();
                z += *sc;
            }
            let orow = &mut out[i * kvw + off..i * kvw + off + head_dim];
            for (j, &p) in scores.iter().enumerate().take(i + 1) {
                let vj = &v[j * kvw + off..j * kvw + off + head_dim];
                let pw = p / z;
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += pw * vv;
                }
            }
        }
    }
    out
}

/// Single-token attention over a static KV cache; rows > pos are masked.
/// q: (H*D), caches: (W, H*D) → (H*D).
fn decode_attention(q: &[f32], kc: &[f32], vc: &[f32], pos: usize, heads: usize, head_dim: usize) -> Vec<f32> {
    let kvw = heads * head_dim;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut out = vec![0f32; kvw];
    let mut scores = vec![0f32; pos + 1];
    for h in 0..heads {
        let off = h * head_dim;
        let qh = &q[off..off + head_dim];
        let mut smax = f32::NEG_INFINITY;
        for (j, sc) in scores.iter_mut().enumerate() {
            let kj = &kc[j * kvw + off..j * kvw + off + head_dim];
            let dot: f32 = qh.iter().zip(kj).map(|(a, b)| a * b).sum();
            *sc = dot * scale;
            smax = smax.max(*sc);
        }
        let mut z = 0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - smax).exp();
            z += *sc;
        }
        let orow = &mut out[off..off + head_dim];
        for (j, &p) in scores.iter().enumerate() {
            let vj = &vc[j * kvw + off..j * kvw + off + head_dim];
            let pw = p / z;
            for (o, &vv) in orow.iter_mut().zip(vj) {
                *o += pw * vv;
            }
        }
    }
    out
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// SwiGLU FFN with pre-norm: x + (silu(h@wg) * (h@wu)) @ wd, h = rms(x,g2).
fn ffn(x: &[f32], w: usize, d: usize, f: usize, g2: &[f32], wg: &[f32], wu: &[f32], wd: &[f32]) -> Vec<f32> {
    let h = rms_norm(x, w, d, g2);
    let mut gate = matmul(&h, wg, w, d, f);
    let up = matmul(&h, wu, w, d, f);
    for (g, u) in gate.iter_mut().zip(&up) {
        *g = silu(*g) * u;
    }
    let down = matmul(&gate, wd, w, f, d);
    let mut out = x.to_vec();
    add_assign(&mut out, &down);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, ModelWeights};
    use crate::runtime::{LayerKv, NodeRuntime};
    use std::rc::Rc;

    fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        let mut worst = 0f32;
        for (g, w) in got.iter().zip(want) {
            worst = worst.max((g - w).abs());
        }
        assert!(worst <= tol, "{what}: max abs err {worst} > {tol}");
    }

    #[test]
    fn decode_reproduces_prefill_rows() {
        // The serving-critical invariant: decode(t) with caches from
        // prefill rows 0..t must equal prefill row t.
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
        let weights = Rc::new(ModelWeights::synthetic(&cfg, 42));
        let node = NodeRuntime::new(engine, weights.clone(), 0..2, true).unwrap();

        let tokens: Vec<u32> = (0..10u32).map(|i| (i * 37) % 512).collect();
        let x = weights.embed_padded(&tokens, cfg.prefill_len);
        let (h_pre, kv_rows) = node.prefill(&x).unwrap();

        let t = 6usize;
        let kvw = cfg.kv_width();
        let mut kv: Vec<LayerKv> = kv_rows
            .iter()
            .map(|(k_rows, v_rows)| {
                let mut c = LayerKv::zeros(cfg.max_seq, kvw);
                c.k[..t * kvw].copy_from_slice(&k_rows[..t * kvw]);
                c.v[..t * kvw].copy_from_slice(&v_rows[..t * kvw]);
                c
            })
            .collect();
        let xt = weights.embed(&tokens[t..t + 1]);
        let h_dec = node.decode(&xt, &mut kv, t).unwrap();
        let d = cfg.d_model;
        assert_close(&h_dec, &h_pre[t * d..(t + 1) * d], 5e-3, "decode vs prefill row");
    }

    #[test]
    fn split_across_two_nodes_matches_single_node() {
        let mut cfg = ModelConfig::sim7b();
        cfg.n_layers = 2;
        let engine = Rc::new(Engine::load("artifacts", &cfg).unwrap());
        let weights = Rc::new(ModelWeights::synthetic(&cfg, 43));
        let full = NodeRuntime::new(engine.clone(), weights.clone(), 0..2, true).unwrap();
        let front = NodeRuntime::new(engine.clone(), weights.clone(), 0..1, false).unwrap();
        let back = NodeRuntime::new(engine.clone(), weights.clone(), 1..2, true).unwrap();

        let tokens: Vec<u32> = vec![5, 99, 210, 340];
        let x = weights.embed_padded(&tokens, cfg.prefill_len);
        let (h_full, _) = full.prefill(&x).unwrap();
        let (h_mid, _) = front.prefill(&x).unwrap();
        let (h_split, _) = back.prefill(&h_mid).unwrap();
        assert_close(&h_split, &h_full, 1e-4, "split prefill == full prefill");
    }

    #[test]
    fn attention_weights_sum_to_one_effectively() {
        // constant V must pass through attention unchanged
        let (heads, head_dim, w) = (2usize, 4usize, 5usize);
        let kvw = heads * head_dim;
        let q: Vec<f32> = (0..w * kvw).map(|i| (i % 7) as f32 * 0.1).collect();
        let k: Vec<f32> = (0..w * kvw).map(|i| (i % 5) as f32 * 0.2).collect();
        let v = vec![3.5f32; w * kvw];
        let out = causal_attention(&q, &k, &v, w, heads, head_dim);
        for o in out {
            assert!((o - 3.5).abs() < 1e-5, "attention must be a convex combination");
        }
    }

    #[test]
    fn rope_preserves_pair_norm() {
        let (heads, head_dim, w) = (1usize, 8usize, 3usize);
        let half = head_dim / 2;
        let mut x: Vec<f32> = (0..w * heads * head_dim).map(|i| (i as f32).sin()).collect();
        let orig = x.clone();
        let cos: Vec<f32> = (0..w * half).map(|i| ((i as f32) * 0.3).cos()).collect();
        let sin: Vec<f32> = (0..w * half).map(|i| ((i as f32) * 0.3).sin()).collect();
        apply_rope(&mut x, w, heads, head_dim, &cos, &sin);
        for t in 0..w {
            for i in 0..half {
                let b = t * head_dim;
                let n0 = orig[b + i].hypot(orig[b + half + i]);
                let n1 = x[b + i].hypot(x[b + half + i]);
                assert!((n0 - n1).abs() < 1e-5, "rotation must preserve norms");
            }
        }
    }
}
