//! Runtime: artifact manifest, engine, and the per-node layer pipeline.
//!
//! The per-step contract is in-place and borrowed: KV caches are mutated
//! through `&mut LayerKv` (no clone/upload/return round-trips), per-step
//! activations live in a reusable `EngineScratch` arena, and
//! `NodeRuntime::decode_batch` stacks B concurrent sessions into one
//! weight-matrix traversal per layer.
//!
//! Two interchangeable engines sit behind the same API:
//!   * `pjrt` feature ON — the PJRT engine (`engine.rs`): loads the
//!     HLO-text artifacts produced by `make artifacts` and executes them
//!     through the vendored `xla` bindings.
//!   * default — the pure-Rust reference engine (`reference.rs`): executes
//!     the same per-layer math (mirroring `python/compile/kernels/ref.py`)
//!     with no external dependency, so the default
//!     `cargo build --release && cargo test -q` is green offline.

pub mod manifest;
pub mod node;

// Enabling `pjrt` without the vendored `xla` bindings would otherwise die
// in a spray of E0433s; fail once, with instructions. The vendoring setup
// (see rust/Cargo.toml) builds with RUSTFLAGS="--cfg xla_vendored".
#[cfg(all(feature = "pjrt", not(xla_vendored)))]
compile_error!(
    "feature `pjrt` needs the vendored `xla` bindings: add the `xla` \
     dependency in rust/Cargo.toml and build with \
     RUSTFLAGS=\"--cfg xla_vendored\" --features pjrt"
);

#[cfg(all(feature = "pjrt", xla_vendored))]
pub mod engine;
#[cfg(all(feature = "pjrt", xla_vendored))]
pub use engine::{Buffer, Engine};

#[cfg(not(all(feature = "pjrt", xla_vendored)))]
pub mod reference;
#[cfg(not(all(feature = "pjrt", xla_vendored)))]
pub use reference::{Buffer, Engine};

pub use manifest::Manifest;
pub use node::{DecodeStep, EngineScratch, LayerKv, NodeRuntime, RopeTables};

/// Quick engine availability probe (used by `splitserve doctor`).
#[cfg(all(feature = "pjrt", xla_vendored))]
pub fn smoke() -> anyhow::Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}

/// Quick engine availability probe (used by `splitserve doctor`).
#[cfg(not(all(feature = "pjrt", xla_vendored)))]
pub fn smoke() -> anyhow::Result<String> {
    Ok("reference engine (pure Rust, no PJRT)".to_string())
}
