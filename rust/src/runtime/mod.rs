//! PJRT runtime: artifact manifest, engine (compiled executables), and the
//! per-node layer pipeline. Python never runs here — the artifacts under
//! `artifacts/` are AOT products of `make artifacts`.

pub mod engine;
pub mod manifest;
pub mod node;

pub use engine::Engine;
pub use manifest::Manifest;
pub use node::{LayerKv, NodeRuntime, RopeTables};

/// Quick PJRT availability probe (used by `splitserve doctor`).
pub fn smoke() -> anyhow::Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}
