//! PJRT engine: loads the HLO-text artifacts and owns the compiled
//! executables for one shape class.
//!
//! Pattern (see /opt/xla-example/load_hlo): `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile`. HLO *text* is the
//! interchange format — jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! PJRT handles are not `Send`; the whole serving stack runs on one thread
//! (the coordinator is a discrete-event simulation — DESIGN.md §1).

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Manifest, ShapeClassManifest};
use crate::model::ModelConfig;

/// Device-resident tensor handle (PJRT buffer). The reference engine
/// (`reference.rs`, default build) provides a host-side equivalent under
/// the same name so `NodeRuntime` is engine-agnostic.
pub type Buffer = xla::PjRtBuffer;

pub struct Engine {
    pub client: xla::PjRtClient,
    pub class: ShapeClassManifest,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Load + compile every artifact of `cfg`'s shape class.
    pub fn load(artifacts_dir: &str, cfg: &ModelConfig) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let class = manifest.class(cfg.shape_class.dir_name())?.clone();
        class.check_compatible(cfg)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for (name, info) in &class.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                info.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
            )
            .with_context(|| format!("parsing {}", info.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            exes.insert(name.clone(), exe);
        }
        Ok(Engine { client, class, exes })
    }

    pub fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded (have {:?})",
                self.exes.keys().collect::<Vec<_>>()))
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    /// Execute an artifact on device buffers; returns the untupled outputs
    /// as host vectors (the artifacts are lowered with return_tuple=True).
    pub fn run(
        &self,
        name: &str,
        args: &[&Buffer],
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self.exe(name)?;
        let out = exe.execute_b::<&Buffer>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        parts
            .into_iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect::<Result<Vec<_>>>()
    }
}

// Tests requiring real artifacts live in rust/tests/runtime_integration.rs
// (they need `make artifacts` to have run).
